"""Data-pipeline benchmark: PBM vs LRU host page cache, concurrent streams.

The training-side deployment of the paper's policies: a fast train stream, a
slow eval stream trailing through the same shards (reuse at a *distance* —
the concurrent-scan pattern), and a noise stream over disjoint shards that
pollutes an LRU cache but lands in PBM's far-future buckets.  Metric: pages
re-read from slow storage (miss volume), the paper's I/O-volume metric.
"""

from __future__ import annotations

import argparse
import itertools
import json
from typing import Dict, List

from repro.data import DataStream, DatasetSpec, HostPageCache, MultiStreamLoader


def run_policy(policy: str, *, capacity_pages=48, rounds=600) -> Dict:
    spec = DatasetSpec(n_shards=12, pages_per_shard=16)
    # virtual clock driven by work done, so PBM speed estimates are stable
    tick = itertools.count()
    cache = HostPageCache(spec, capacity_pages=capacity_pages, policy=policy,
                          clock=lambda: next(tick) * 1e-3)
    loader = MultiStreamLoader(cache)
    shared = list(range(8))          # shards 0-7: train + eval reuse
    noise = list(range(8, 12))       # shards 8-11: single-scan pollution
    loader.add_stream(DataStream(cache, shared, batch=8, seq_len=1024, name="train"))
    loader.add_stream(DataStream(cache, shared, batch=2, seq_len=1024, name="eval"))
    loader.add_stream(DataStream(cache, noise, batch=8, seq_len=1024, name="noise"))
    for _ in range(rounds):
        loader.next_round()
    total = cache.miss_pages + cache.hit_pages
    return {
        "policy": policy,
        "miss_pages": cache.miss_pages,
        "hit_pages": cache.hit_pages,
        "hit_rate": round(cache.hit_pages / max(1, total), 3),
        "reread_gb": round(cache.miss_bytes / 1e9, 3),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    rows = [run_policy(p) for p in ("lru", "pbm", "opt")]
    for r in rows:
        print(f"  data/{r['policy']:4s} miss={r['miss_pages']:5d} "
              f"hit_rate={r['hit_rate']:.1%} reread={r['reread_gb']:.2f}GB")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=2)


if __name__ == "__main__":
    main()
