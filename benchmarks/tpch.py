"""TPC-H-like throughput run — paper Figures 14, 15, 16.

8 tables / 61 columns, 22 query templates per stream (qgen-style rotated
permutations), ~7.5GB accessed with 8 streams.  Defaults match the paper's
operating point: 600 MB/s I/O, buffer = 30% of accessed volume.
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

from repro.core import EngineConfig, run_workload, simulate_belady
from repro.core.workload import make_tpch_db, tpch_accessed_bytes, tpch_streams

POLICIES = ["lru", "cscan", "pbm", "opt"]

DEFAULTS = dict(n_streams=8, bandwidth=600e6, buffer_frac=0.3, seed=7)


def one_point(db, policies, *, n_streams, bandwidth, buffer_frac, seed,
              time_slice=0.1) -> List[Dict]:
    streams = tpch_streams(db, n_streams=n_streams, seed=seed)
    ws = tpch_accessed_bytes(db, streams)
    rows = []
    pbm_trace = None
    for pol in policies:
        cfg = EngineConfig(
            bandwidth=bandwidth,
            buffer_bytes=max(1 << 22, int(buffer_frac * ws)),
            sample_interval=5.0,
            record_trace=(pol == "pbm"),
            pbm_time_slice=time_slice,
        )
        t0 = time.time()
        r = run_workload(db, streams, pol, cfg)
        rows.append({
            "policy": pol,
            "avg_stream_time_s": round(r.avg_stream_time, 3),
            "io_gb": round(r.io_gb, 3),
            "wall_s": round(time.time() - t0, 2),
        })
        if pol == "pbm":
            pbm_trace = (r.trace, r.page_sizes)
    if pbm_trace is not None and "opt" in policies:
        trace, sizes = pbm_trace
        _, missed = simulate_belady(
            trace, page_sizes=sizes,
            capacity_bytes=max(1 << 22, int(buffer_frac * ws)),
        )
        for row in rows:
            if row["policy"] == "opt":
                row["io_gb_belady_trace"] = round(missed / 1e9, 3)
    return rows


def sweep(which: str, policies: List[str], scale: float = 1.0, seed: int = 7):
    db = make_tpch_db(scale=scale)
    points = {
        "buffer": [0.1, 0.2, 0.3, 0.45, 0.6, 0.8],
        "bandwidth": [200e6, 400e6, 600e6, 900e6, 1200e6, 1600e6],
        "streams": [1, 2, 4, 8, 16, 24],
    }[which]
    out = []
    for p in points:
        kw = dict(DEFAULTS)
        kw["seed"] = seed
        if which == "buffer":
            kw["buffer_frac"] = p
        elif which == "bandwidth":
            kw["bandwidth"] = p
        else:
            kw["n_streams"] = int(p)
        rows = one_point(db, policies, **kw)
        for r in rows:
            r["sweep"] = f"tpch_{which}"
            r["point"] = p
        out.extend(rows)
        label = f"{p:.0%}" if which == "buffer" else (
            f"{p/1e6:.0f}MB/s" if which == "bandwidth" else f"{int(p)} streams")
        summary = " ".join(
            f"{r['policy']}={r['avg_stream_time_s']:.1f}s/{r['io_gb']:.1f}GB"
            for r in rows
        )
        print(f"  tpch/{which} @ {label:10s} {summary}", flush=True)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sweep", choices=["buffer", "bandwidth", "streams", "all"],
                    default="all")
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    sweeps = ["buffer", "bandwidth", "streams"] if args.sweep == "all" else [args.sweep]
    rows = []
    for s in sweeps:
        rows.extend(sweep(s, POLICIES, scale=args.scale))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=2)


if __name__ == "__main__":
    main()
