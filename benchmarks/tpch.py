"""TPC-H-like throughput run — paper Figures 14, 15, 16.

8 tables / 61 columns, 22 query templates per stream (qgen-style rotated
permutations), ~7.5GB accessed with 8 streams.  Defaults match the paper's
operating point: 600 MB/s I/O, buffer = 30% of accessed volume.

``--backend=array`` lowers the multi-table workload through
``repro.core.array_sim.compiler`` and runs the FULL paper policy set
(lru / cscan / pbm / opt) on the vmap-able array substrate: every
(policy x sweep-point) lane of a sweep executes as ONE batched
computation — by default on the event-horizon stepper
(``--stepper fixed`` for the classic cadence) and lane-sharded across
every visible device (``--mesh off`` to stay on one; array runs expose
one XLA host device per CPU core up to 8).  ``--smoke`` restricts to
the buffer sweep at a quick scale — the CI configuration (same flag
semantics as ``benchmarks/microbench.py``).

Policy lists come from ``repro.core.policy_registry`` — one source of
truth for both backends; unknown names fail there with the known-name
list.
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List, Optional

from repro.core import EngineConfig, run_workload, simulate_belady
from repro.core.policy_registry import names as policy_names
from repro.core.workload import make_tpch_db, tpch_accessed_bytes, tpch_streams

POLICIES = policy_names(backend="event", paper_only=True)
ARRAY_POLICIES = policy_names(backend="array")

DEFAULTS = dict(n_streams=8, bandwidth=600e6, buffer_frac=0.3, seed=7)
#: --smoke scale per backend.  The array smoke runs at 0.05 (the batched
#: step's CPU cost bounds CI); the EVENT smoke stays at CI's historical
#: 0.25 — at 0.05 the 10%-buffer point drops the pool (~75 pages) below
#: streams x columns x prefetch wanted pages and the dict engine's churn
#: spiral turns a smoke run into tens of minutes.  The array step handles
#: that regime (it finishes the 0.1 lane in ~20s of sim time), which is
#: exactly the asymmetry the batched substrate exists for.
SMOKE_SCALE = 0.05
EVENT_SMOKE_SCALE = 0.25

SWEEP_POINTS = {
    "buffer": [0.1, 0.2, 0.3, 0.45, 0.6, 0.8],
    "bandwidth": [200e6, 400e6, 600e6, 900e6, 1200e6, 1600e6],
    "streams": [1, 2, 4, 8, 16, 24],
}


def one_point(db, policies, *, n_streams, bandwidth, buffer_frac, seed,
              time_slice=0.1) -> List[Dict]:
    streams = tpch_streams(db, n_streams=n_streams, seed=seed)
    ws = tpch_accessed_bytes(db, streams)
    # ONE capacity for the pool and the Belady replay: computing it twice
    # (as the seed did) invites silent divergence between the run and its
    # OPT reference when either expression drifts
    cap = max(1 << 22, int(buffer_frac * ws))
    rows = []
    pbm_trace = None
    for pol in policies:
        cfg = EngineConfig(
            bandwidth=bandwidth,
            buffer_bytes=cap,
            sample_interval=5.0,
            record_trace=(pol == "pbm"),
            pbm_time_slice=time_slice,
        )
        t0 = time.time()
        r = run_workload(db, streams, pol, cfg)
        rows.append({
            "policy": pol,
            "avg_stream_time_s": round(r.avg_stream_time, 3),
            "io_gb": round(r.io_gb, 3),
            "wall_s": round(time.time() - t0, 2),
        })
        if pol == "pbm":
            pbm_trace = (r.trace, r.page_sizes)
    if pbm_trace is not None and "opt" in policies:
        trace, sizes = pbm_trace
        _, missed = simulate_belady(
            trace, page_sizes=sizes, capacity_bytes=cap,
        )
        for row in rows:
            if row["policy"] == "opt":
                row["io_gb_belady_trace"] = round(missed / 1e9, 3)
    return rows


def _point_label(which: str, p) -> str:
    return f"{p:.0%}" if which == "buffer" else (
        f"{p/1e6:.0f}MB/s" if which == "bandwidth" else f"{int(p)} streams")


def sweep(which: str, policies: List[str], scale: float = 1.0, seed: int = 7):
    db = make_tpch_db(scale=scale)
    out = []
    for p in SWEEP_POINTS[which]:
        kw = dict(DEFAULTS)
        kw["seed"] = seed
        if which == "buffer":
            kw["buffer_frac"] = p
        elif which == "bandwidth":
            kw["bandwidth"] = p
        else:
            kw["n_streams"] = int(p)
        # PBM bucket resolution scales with the (scaled) workload duration
        # — the microbench convention (EngineConfig.pbm_time_slice: "scale
        # it down together with the workload").  The seed ran scaled TPC-H
        # sweeps at the fixed 0.1s slice, so scaled-run PBM rows (CI smoke
        # included) shift once against pre-PR-3 trend baselines.
        rows = one_point(db, policies, time_slice=0.1 * scale, **kw)
        for r in rows:
            r["sweep"] = f"tpch_{which}"
            r["point"] = p
        out.extend(rows)
        summary = " ".join(
            f"{r['policy']}={r['avg_stream_time_s']:.1f}s/{r['io_gb']:.1f}GB"
            for r in rows
        )
        print(f"  tpch/{which} @ {_point_label(which, p):10s} {summary}",
              flush=True)
    return out


def lane_mesh(n_lanes: int):
    """One-axis device mesh for lane-sharded execution, or ``None`` when
    only one device is visible.  Uses the largest device count that
    divides the lane count evenly (``shard_map`` needs equal shards);
    the host device count comes from ``XLA_FLAGS
    --xla_force_host_platform_device_count`` (set by :func:`main` for
    array runs before JAX initialises)."""
    import jax
    import numpy as np
    from jax.sharding import Mesh

    devs = jax.devices()
    n = len(devs)
    while n > 1 and n_lanes % n != 0:
        n -= 1
    if n <= 1:
        return None
    return Mesh(np.array(devs[:n]), ("lanes",))


def sweep_array(which: str, policies=None, scale: float = 1.0, seed: int = 7,
                step_pages: float = 1.0, stepper: str = "horizon",
                mesh: bool = True):
    """Array-backend TPC-H sweep: same row schema as :func:`sweep` for
    every registered array policy (the paper's full four-way comparison).

    For the buffer and bandwidth axes the workload shape is constant, so
    the compiled spec is lowered once and EVERY (policy x point) lane runs
    in one batched call — the runner is compiled over the whole policy
    set and treats policy, capacity and bandwidth as traced config
    scalars; with ``mesh`` (default) the lanes additionally spread across
    every visible device via ``shard_map``.  The streams axis changes the
    spec shape per point and falls back to per-point batched-policy runs.
    ``step_pages=2.0`` is the coarse fast mode the batched races use
    (~2x fewer steps for a few % fidelity) — the CI smoke runs the
    24-lane sweep with it to stay inside the job budget; validation
    always runs full fidelity (``validate.py``).  ``stepper`` picks the
    time engine — the event-horizon stepper is the default benchmark
    lane (validated against the same bars as the fixed cadence).
    """
    import jax

    from repro.core.array_sim import (
        compile_workload, make_config, make_runner, result_from_state,
        stack_configs,
    )

    policies = policies or ARRAY_POLICIES
    db = make_tpch_db(scale=scale)
    time_slice = 0.1 * scale
    points = SWEEP_POINTS[which]
    out: List[Dict] = []

    def rows_from(states, lanes, batch_wall, dt_ref, man=None):
        # wall_s is the batch wall amortised per lane — the lanes run
        # LOCKSTEP inside one vmapped call, so no per-lane wall exists
        # (unlike the sequential micro array rows); batch_wall_s/
        # batch_lanes carry the real measurement
        rows = []
        for i, (p, pol) in enumerate(lanes):
            r = result_from_state(
                jax.tree.map(lambda x, i=i: x[i], states), pol,
                dt_ref=dt_ref)
            rows.append({
                "policy": pol,
                "avg_stream_time_s": round(r.avg_stream_time, 3),
                "io_gb": round(r.io_gb, 3),
                "wall_s": round(batch_wall / max(1, len(lanes)), 2),
                "batch_wall_s": round(batch_wall, 2),
                "batch_lanes": len(lanes),
                "sweep": f"tpch_{which}",
                "point": p,
                "backend": "array",
                "stepper": stepper,
                "macro_steps": r.extras.get("macro_steps", r.steps),
                "skipped_time": r.extras.get("skipped_time", 0.0),
                "truncated": r.extras.get("truncated", False),
                "manifest": man,
            })
        return rows

    def run_lanes(spec, cfgs):
        m = lane_mesh(len(cfgs)) if mesh else None
        runner = make_runner(spec, bandwidth_ref=DEFAULTS["bandwidth"],
                             time_slice=time_slice, policies=policies,
                             step_pages=step_pages, stepper=stepper,
                             mesh=m)
        batched = runner if m is not None else jax.jit(jax.vmap(runner))
        t0 = time.time()
        states = jax.block_until_ready(batched(stack_configs(cfgs)))
        wall = time.time() - t0
        from repro.obs import manifest as _m
        man = _m.collect(spec=spec, runner=runner, backend="array")
        return states, wall, runner.dt_ref, man

    if which in ("buffer", "bandwidth"):
        streams = tpch_streams(db, n_streams=DEFAULTS["n_streams"], seed=seed)
        ws = tpch_accessed_bytes(db, streams)
        spec = compile_workload(db, streams)
        lanes, cfgs = [], []
        for p in points:
            frac = p if which == "buffer" else DEFAULTS["buffer_frac"]
            bw = p if which == "bandwidth" else DEFAULTS["bandwidth"]
            cap = max(1 << 22, int(frac * ws))
            for pol in policies:
                lanes.append((p, pol))
                cfgs.append(make_config(spec, cap, bw, pol))
        states, wall, dt_ref, man = run_lanes(spec, cfgs)
        out = rows_from(states, lanes, wall, dt_ref, man)
    else:
        for p in points:
            n_s = int(p)
            streams = tpch_streams(db, n_streams=n_s, seed=seed)
            ws = tpch_accessed_bytes(db, streams)
            spec = compile_workload(db, streams)
            cap = max(1 << 22, int(DEFAULTS["buffer_frac"] * ws))
            lanes = [(p, pol) for pol in policies]
            cfgs = [make_config(spec, cap, DEFAULTS["bandwidth"], pol)
                    for pol in policies]
            states, wall, dt_ref, man = run_lanes(spec, cfgs)
            out.extend(rows_from(states, lanes, wall, dt_ref, man))

    truncated = [(r["point"], r["policy"]) for r in out if r["truncated"]]
    if truncated:
        print(f"  tpch[array] WARNING: truncated lanes (livelock guard): "
              f"{truncated}", flush=True)
    for p in points:
        rows = [r for r in out if r["point"] == p]
        summary = " ".join(
            f"{r['policy']}={r['avg_stream_time_s']:.1f}s/{r['io_gb']:.1f}GB"
            for r in rows
        )
        print(f"  tpch[array]/{which} @ {_point_label(which, p):10s} "
              f"{summary}", flush=True)
    return out


def scaling_curve(scale: float = SMOKE_SCALE, seed: int = 7,
                  policy: str = "pbm", fracs=None):
    """Wall-clock vs mesh shape for the batched buffer sweep — the
    sharding scaling curve behind ``--scaling``.

    Runs the same 4-lane (buffer-frac) batched sweep on the horizon
    stepper under every usable mesh shape: plain vmap (1 device),
    lane-sharded one-axis meshes over 2/4 host devices, and the two-axis
    ``('lanes', 'page')`` meshes that page-shard the per-step candidate
    scans.  Every timed wall is compile-separated (cold run first, then
    the timed warm run) and trace-guarded: ``runner.trace_count()`` must
    be exactly 1 afterwards or the row is marked re-traced and its wall
    is not trustworthy.  Writes rows ``trend.py`` diffs run-over-run
    (>20% warm-wall growth per mesh shape flags a regression).
    """
    import jax
    import numpy as np
    from jax.sharding import Mesh

    from repro.core.array_sim import (
        compile_workload, make_config, make_runner, result_from_state,
        stack_configs,
    )

    db = make_tpch_db(scale=scale)
    streams = tpch_streams(db, n_streams=DEFAULTS["n_streams"], seed=seed)
    ws = tpch_accessed_bytes(db, streams)
    spec = compile_workload(db, streams)
    fracs = list(fracs) if fracs is not None else [0.1, 0.2, 0.3, 0.45]
    cfgs = stack_configs([
        make_config(spec, max(1 << 22, int(f * ws)), DEFAULTS["bandwidth"],
                    policy)
        for f in fracs
    ])
    devs = jax.devices()
    P = int(spec.page_size.shape[0])
    n_lanes = len(fracs)

    shapes = [(1, 1)]
    for k in (2, 4, 8):
        if k <= len(devs) and n_lanes % k == 0:
            shapes.append((k, 1))
    for lanes, pages in ((2, 2), (4, 2)):
        if lanes * pages <= len(devs) and n_lanes % lanes == 0 \
                and P % pages == 0:
            shapes.append((lanes, pages))

    rows = []
    for lanes, pages in shapes:
        n_dev = lanes * pages
        if n_dev == 1:
            mesh, label = None, "vmap"
        elif pages == 1:
            mesh = Mesh(np.array(devs[:n_dev]), ("lanes",))
            label = f"({lanes},) lanes"
        else:
            mesh = Mesh(np.array(devs[:n_dev]).reshape(lanes, pages),
                        ("lanes", "page"))
            label = f"({lanes}, {pages}) lanes x page"
        runner = make_runner(spec, bandwidth_ref=DEFAULTS["bandwidth"],
                             time_slice=0.1 * scale, policies=(policy,),
                             step_pages=2.0, stepper="horizon", mesh=mesh)
        vrun = runner if mesh is not None else jax.jit(jax.vmap(runner))
        t0 = time.time()
        states = jax.block_until_ready(vrun(cfgs))
        cold = time.time() - t0
        t0 = time.time()
        states = jax.block_until_ready(vrun(cfgs))
        warm = time.time() - t0
        traces = runner.trace_count()
        results = [
            result_from_state(jax.tree.map(lambda x, i=i: x[i], states),
                              policy, dt_ref=runner.dt_ref)
            for i in range(n_lanes)
        ]
        rows.append({
            "mesh": label,
            "devices": n_dev,
            "lane_shards": lanes,
            "page_shards": pages,
            "wall_s": round(warm, 3),
            "cold_wall_s": round(cold, 3),
            "trace_count": traces,
            "retraced": traces != 1,
            "macro_steps": [r.extras.get("macro_steps", r.steps)
                            for r in results],
            "avg_stream_time_s": [round(r.avg_stream_time, 3)
                                  for r in results],
        })
        print(f"  tpch scaling [{label:22s} {n_dev} device(s)]: "
              f"warm {warm:6.2f}s cold {cold:6.2f}s traces={traces}",
              flush=True)
    base = rows[0]["wall_s"]
    for r in rows:
        r["speedup_vs_vmap"] = round(base / max(r["wall_s"], 1e-9), 3)
    # stream times must not depend on the mesh shape — the sharded
    # candidate construction is bitwise-identical by design, so any
    # disagreement is a sharding bug, not noise
    for r in rows[1:]:
        if r["avg_stream_time_s"] != rows[0]["avg_stream_time_s"]:
            print(f"  tpch scaling WARNING: {r['mesh']} results diverge "
                  f"from vmap — page/lane sharding is not reduction-safe",
                  flush=True)
            r["diverged"] = True
    from repro.obs import manifest as _m
    return {
        "workload": "tpch",
        "policy": policy,
        "scale": scale,
        "fracs": fracs,
        "stepper": "horizon",
        "rows": rows,
        "manifest": _m.collect(spec=spec, backend="scaling",
                               workload="tpch"),
    }


def batched_tpch_race(scale: float = 1.0, seed: int = 7, fracs=None,
                      policy: str = "pbm"):
    """The batched TPC-H policy x buffer sweep vs the same points run
    sequentially on the event engine — the multi-table analogue of
    ``microbench.batched_buffer_race``, tracked as a CI trend metric.

    Races BOTH time engines: the ``fixed`` row is the PR-4 configuration
    (fixed-dt, one vmapped call on one device — the historical baseline
    the per-stepper ``speedup_ratio`` is measured against), the
    ``horizon`` row is the new default batched lane (event-horizon
    macro-stepping, lane-sharded across every visible device).  Returns
    the summary dict that lands in ``tpch_race.json``; the legacy
    top-level keys mirror the default (horizon) lane.
    """
    import jax

    from repro.core.array_sim import (
        compile_workload, make_config, make_runner, result_from_state,
        stack_configs,
    )

    db = make_tpch_db(scale=scale)
    streams = tpch_streams(db, n_streams=DEFAULTS["n_streams"], seed=seed)
    ws = tpch_accessed_bytes(db, streams)
    time_slice = 0.1 * scale
    spec = compile_workload(db, streams)
    fracs = list(fracs) if fracs is not None else [0.1, 0.2, 0.3, 0.45]
    caps = [max(1 << 22, int(f * ws)) for f in fracs]

    t0 = time.time()
    ev_rows = []
    for cap in caps:
        cfg = EngineConfig(bandwidth=DEFAULTS["bandwidth"], buffer_bytes=cap,
                           sample_interval=5.0, pbm_time_slice=time_slice)
        ev_rows.append(run_workload(db, streams, policy, cfg))
    event_wall = time.time() - t0

    cfgs = stack_configs([
        make_config(spec, cap, DEFAULTS["bandwidth"], policy) for cap in caps
    ])
    steppers: Dict[str, Dict] = {}
    for stepper in ("fixed", "horizon"):
        mesh = lane_mesh(len(fracs)) if stepper == "horizon" else None
        runner = make_runner(spec, bandwidth_ref=DEFAULTS["bandwidth"],
                             time_slice=time_slice, policies=(policy,),
                             step_pages=2.0, stepper=stepper, mesh=mesh)
        vrun = runner if mesh is not None else jax.jit(jax.vmap(runner))
        # compile-separated timing: the cold call pays the trace+compile,
        # the warm call is the measured wall.  trace_count() guards the
        # separation — a second trace on the warm call means the timed
        # number silently includes compilation and the race is invalid.
        t0 = time.time()
        states = jax.block_until_ready(vrun(cfgs))
        cold = time.time() - t0
        t0 = time.time()
        states = jax.block_until_ready(vrun(cfgs))
        wall = time.time() - t0
        traces = runner.trace_count()
        if traces != 1:
            print(f"  tpch batched sweep WARNING: {traces} jit traces "
                  f"for the {stepper} runner — warm wall is "
                  "compile-contaminated, race is invalid", flush=True)
        results = [
            result_from_state(jax.tree.map(lambda x, i=i: x[i], states),
                              policy, dt_ref=runner.dt_ref)
            for i in range(len(fracs))
        ]
        truncated = [f for f, r in zip(fracs, results)
                     if r.extras.get("truncated")]
        if truncated:
            print(f"  tpch batched sweep WARNING: truncated lanes "
                  f"(livelock guard) at buffer fracs {truncated} "
                  f"[{stepper}] — race is invalid", flush=True)
        steppers[stepper] = {
            "wall_s": round(wall, 3),
            "cold_wall_s": round(cold, 3),
            "trace_count": traces,
            "mesh_devices": 1 if mesh is None else mesh.size,
            "speedup_vs_event": round(event_wall / max(wall, 1e-9), 3),
            "avg_stream_time_s": [round(r.avg_stream_time, 3)
                                  for r in results],
            "macro_steps": [r.extras.get("macro_steps", r.steps)
                            for r in results],
            "skipped_time_s": [r.extras.get("skipped_time", 0.0)
                               for r in results],
            "truncated_fracs": truncated,
        }
        print(
            f"  tpch batched sweep [{policy}, {len(fracs)} buffer points, "
            f"{stepper}, {steppers[stepper]['mesh_devices']} device(s)]: "
            f"array = {wall:.2f}s (cold {cold:.2f}s incl. compile) vs "
            f"sequential event engine = {event_wall:.2f}s -> "
            f"{'array WINS' if wall < event_wall else 'event wins'} "
            f"({event_wall / max(wall, 1e-9):.2f}x)",
            flush=True,
        )

    # telemetry pass on the default (horizon) lane: a separate static
    # telemetry=True runner so neither timed lane above carries counters;
    # plain vmap (no mesh) — one extra compile, the numbers not the wall
    # matter here
    from repro.obs import counters as obs_counters
    from repro.obs import manifest as _m
    runner_t = make_runner(spec, bandwidth_ref=DEFAULTS["bandwidth"],
                           time_slice=time_slice, policies=(policy,),
                           step_pages=2.0, stepper="horizon", telemetry=True)
    states_t, tele = jax.block_until_ready(jax.jit(jax.vmap(runner_t))(cfgs))
    tele_rows = []
    for i in range(len(fracs)):
        r_t = result_from_state(
            jax.tree.map(lambda x, i=i: x[i], states_t), policy,
            dt_ref=runner_t.dt_ref)
        tele_rows.append(obs_counters.summarize(
            obs_counters.lane_slice(tele, i),
            policies=runner_t.policy_names, steps=r_t.steps))
    steppers["horizon"]["hit_rate"] = [t["hit_rate"] for t in tele_rows]
    steppers["horizon"]["array_evictions"] = [t["evictions"]
                                              for t in tele_rows]
    steppers["horizon"]["telemetry"] = tele_rows

    fixed, hor = steppers["fixed"], steppers["horizon"]
    ratio = {
        # per-backend/stepper wall-clock ratios vs the sequential event
        # engine, plus the headline tentpole ratio: the new default lane
        # against the PR-4 fixed-dt configuration
        "event": 1.0,
        "array_fixed": fixed["speedup_vs_event"],
        "array_horizon": hor["speedup_vs_event"],
        "horizon_vs_pr4_fixed": round(
            fixed["wall_s"] / max(hor["wall_s"], 1e-9), 3),
    }
    print(f"  tpch race speedup_ratio: {ratio}", flush=True)
    return {
        "workload": "tpch",
        "policy": policy,
        "fracs": list(fracs),
        "steppers": steppers,
        "speedup_ratio": ratio,
        # legacy headline keys = the default batched lane (horizon)
        "array_vmapped_wall_s": hor["wall_s"],
        "array_cold_wall_s": hor["cold_wall_s"],
        "event_sequential_wall_s": round(event_wall, 3),
        "speedup": hor["speedup_vs_event"],
        "truncated_fracs": hor["truncated_fracs"],
        "array_avg_stream_time_s": hor["avg_stream_time_s"],
        "event_avg_stream_time_s": [round(r.avg_stream_time, 3)
                                    for r in ev_rows],
        "macro_steps": hor["macro_steps"],
        "skipped_time_s": hor["skipped_time_s"],
        "hit_rate": hor["hit_rate"],
        "array_evictions": hor["array_evictions"],
        "event_evictions": [r.total_evictions for r in ev_rows],
        "manifest": _m.collect(spec=spec, runner=runner_t,
                               backend="race", workload="tpch"),
    }


def setup_lane_devices(n: Optional[int] = None) -> None:
    """Expose several XLA host devices for lane-sharded CPU execution.

    Must run before JAX initialises (the flag is read once at backend
    creation); a no-op when the flag is already set, when running on a
    real accelerator platform, or when JAX is already imported.

    Deliberately exposes MORE devices than cores (8 by default): one
    lane per device lets short lanes finish and hand their cores to the
    long ones — with one device per core, the slowest lane shares its
    device with another lane for its whole life, which on a 2-core box
    costs ~2x on the race (the OS scheduler beats a static lane
    partition)."""
    import sys

    if "jax" in sys.modules:
        return  # too late — keep whatever the session initialised
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in flags:
        return
    if n is None:
        n = 8
    if n > 1:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={n}".strip()
        )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sweep", choices=["buffer", "bandwidth", "streams", "all"],
                    default="all")
    ap.add_argument("--scale", type=float, default=None,
                    help=f"table-size scale (default 1.0; under --smoke: "
                         f"{SMOKE_SCALE} array / {EVENT_SMOKE_SCALE} event)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: quick scale, buffer sweep only (same "
                         "semantics as microbench.py --smoke)")
    ap.add_argument("--backend", choices=["event", "array"], default="event")
    ap.add_argument("--stepper", choices=["fixed", "horizon"],
                    default="horizon",
                    help="array time engine for the sweep rows (the race "
                         "always measures both)")
    ap.add_argument("--mesh", choices=["auto", "off"], default="auto",
                    help="lane-sharded execution: spread batched lanes "
                         "across host devices via shard_map (auto), or "
                         "run the whole batch on one device (off)")
    ap.add_argument("--scaling", action="store_true",
                    help="run the sharding scaling curve (batched buffer "
                         "sweep wall vs mesh shape, incl. page-axis "
                         "meshes) and write "
                         "experiments/results/scaling_curve.json")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.scaling:
        setup_lane_devices()
        scale = args.scale if args.scale is not None else SMOKE_SCALE
        curve = scaling_curve(scale=scale)
        out = args.out or "experiments/results/scaling_curve.json"
        os.makedirs(os.path.dirname(out), exist_ok=True)
        with open(out, "w") as f:
            json.dump(curve, f, indent=2)
        print(f"  tpch scaling curve -> {out}")
        return
    if args.backend == "array" and args.mesh == "auto":
        setup_lane_devices()
    smoke_scale = SMOKE_SCALE if args.backend == "array" \
        else EVENT_SMOKE_SCALE
    scale = args.scale if args.scale is not None else (
        smoke_scale if args.smoke else 1.0)
    if args.smoke:
        sweeps = ["buffer"]
    else:
        sweeps = (["buffer", "bandwidth", "streams"]
                  if args.sweep == "all" else [args.sweep])
    rows = []
    for s in sweeps:
        if args.backend == "array":
            rows.extend(sweep_array(s, ARRAY_POLICIES, scale=scale,
                                    step_pages=2.0 if args.smoke else 1.0,
                                    stepper=args.stepper,
                                    mesh=args.mesh == "auto"))
        else:
            rows.extend(sweep(s, POLICIES, scale=scale))
    if args.backend == "array":
        race = batched_tpch_race(scale=scale)
        print(f"  tpch batched race speedup: {race['speedup']}x "
              f"(horizon vs PR-4 fixed: "
              f"{race['speedup_ratio']['horizon_vs_pr4_fixed']}x)",
              flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=2)


if __name__ == "__main__":
    main()
