"""Benchmark trend report: diff two results directories into a markdown table.

CI runs the benchmark smoke on every PR and uploads
``experiments/results/*.json``; this tool compares the fresh results
against the previous successful run's artifact and prints a per-policy
delta table (average stream time and I/O volume per sweep point) suitable
for ``$GITHUB_STEP_SUMMARY``:

    python benchmarks/trend.py <previous-dir> <current-dir>

Missing files, unknown schemas, and first runs (no baseline) degrade to a
note instead of an error — the trend step must never fail the build.
"""

from __future__ import annotations

import json
import os
import sys
from typing import Dict, List, Tuple

#: result files carrying sweep rows (policy/sweep/point/avg_stream_time_s/io_gb)
SWEEP_FILES = ("micro.json", "micro_array.json", "tpch.json",
               "tpch_array.json")

#: batched-race summary files (one dict each, see _race_section)
RACE_FILES = ("batched_race.json", "tpch_race.json")

#: serving-tier sweep rows (policy/sweep/point/p95_token_gap/swap_gb),
#: written by benchmarks/serving_bench.py via benchmarks/run.py and the
#: CI serving smoke lane
SERVING_FILE = "serving_bench.json"

#: sharding scaling curve (wall vs mesh shape), written by
#: ``benchmarks/tpch.py --scaling``
SCALING_FILE = "scaling_curve.json"


def _load_rows(path: str) -> List[dict]:
    try:
        with open(path) as f:
            rows = json.load(f)
        return rows if isinstance(rows, list) else []
    except (OSError, ValueError):
        return []


def _index(rows: List[dict]) -> Dict[Tuple, dict]:
    out = {}
    for r in rows:
        if not isinstance(r, dict):
            continue
        key = (r.get("sweep"), r.get("point"), r.get("policy"))
        if None in key:
            continue
        out[key] = r
    return out


def _fmt_delta(new: float, old: float) -> str:
    if old in (None, 0) or new is None:
        return "n/a"
    d = new / old - 1
    return f"{d*100:+.1f}%"


def _race_section(prev_dir: str, cur_dir: str, fname: str) -> List[str]:
    """Render a batched-race summary (speedup of the batched array sweep
    vs sequential event runs) — the substrate's headline wall-clock trend.
    ``fname`` holds a single summary dict, not a row list (micro and TPC-H
    each write their own).  Races carrying the per-backend/stepper
    ``speedup_ratio`` map (PR 5+) get one row per ratio, and a current
    ratio more than 20% below the previous run's is flagged as a
    REGRESSION.  Races carrying telemetry (PR 8) additionally diff the
    horizon lane's macro-step total (a >20% increase flags — more steps
    for the same workload means the time engine jumped less), skipped
    time, and the counter-derived hit rates / eviction counts; the
    manifest line makes the comparison attributable (which sha, which
    jax)."""
    def _load_dict(path):
        try:
            with open(path) as f:
                d = json.load(f)
            return d if isinstance(d, dict) else None
        except (OSError, ValueError):
            return None

    cur = _load_dict(os.path.join(cur_dir, fname))
    prev = _load_dict(os.path.join(prev_dir, fname))
    if cur is None:
        return []
    lines = [f"### {fname}", "",
             "| metric | current | previous | Δ |", "|---|---|---|---|"]
    pv = prev or {}
    for key in ("speedup", "array_vmapped_wall_s", "event_sequential_wall_s"):
        lines.append(
            f"| {key} | {cur.get(key)} | {pv.get(key, 'n/a')} | "
            f"{_fmt_delta(cur.get(key), pv.get(key))} |"
        )
    cur_ratio = cur.get("speedup_ratio") or {}
    prev_ratio = pv.get("speedup_ratio") or {}
    regressions = []
    for key in sorted(cur_ratio):
        c, p = cur_ratio.get(key), prev_ratio.get(key)
        flag = ""
        if isinstance(c, (int, float)) and isinstance(p, (int, float)) \
                and p > 0 and c < 0.8 * p:
            flag = " ⚠️ REGRESSION"
            regressions.append(key)
        lines.append(
            f"| speedup_ratio.{key} | {c} | "
            f"{p if p is not None else 'n/a'} | {_fmt_delta(c, p)}{flag} |"
        )
    def _total(d, key):
        v = d.get(key)
        if isinstance(v, list) and v \
                and all(isinstance(x, (int, float)) for x in v):
            return round(sum(v), 3)
        return None

    c_ms, p_ms = _total(cur, "macro_steps"), _total(pv, "macro_steps")
    if c_ms is not None:
        flag = ""
        if isinstance(p_ms, (int, float)) and p_ms > 0 and c_ms > 1.2 * p_ms:
            flag = " ⚠️ REGRESSION"
            regressions.append("macro_steps")
        lines.append(f"| macro_steps (total) | {c_ms} | "
                     f"{p_ms if p_ms is not None else 'n/a'} | "
                     f"{_fmt_delta(c_ms, p_ms)}{flag} |")
    c_sk, p_sk = _total(cur, "skipped_time_s"), _total(pv, "skipped_time_s")
    if c_sk is not None:
        lines.append(f"| skipped_time_s (total) | {c_sk} | "
                     f"{p_sk if p_sk is not None else 'n/a'} | "
                     f"{_fmt_delta(c_sk, p_sk)} |")
    if cur.get("hit_rate"):
        lines.append(f"| hit_rate (per frac) | {cur['hit_rate']} | "
                     f"{pv.get('hit_rate', 'n/a')} | |")
    if cur.get("array_evictions") is not None:
        lines.append(f"| evictions array/event | {cur['array_evictions']} / "
                     f"{cur.get('event_evictions')} | "
                     f"{pv.get('array_evictions', 'n/a')} / "
                     f"{pv.get('event_evictions', 'n/a')} | |")
    if cur.get("truncated_fracs"):
        lines.append(f"| truncated lanes | {cur['truncated_fracs']} | | |")
    if regressions:
        lines.append("")
        lines.append(f"**⚠️ regression >20% in {fname}: "
                     f"{', '.join(regressions)}**")
    cm = cur.get("manifest") or {}
    pm = pv.get("manifest") or {}
    if cm:
        attr = (f"_current: sha `{cm.get('git_sha')}` jax {cm.get('jax')} "
                f"spec `{cm.get('spec_hash', '?')}`")
        if pm:
            attr += (f" · previous: sha `{pm.get('git_sha')}` "
                     f"jax {pm.get('jax')}")
        lines.append("")
        lines.append(attr + "_")
    lines.append("")
    return lines


def _scaling_section(prev_dir: str, cur_dir: str) -> List[str]:
    """Wall-clock vs mesh shape from the sharding scaling curve
    (``tpch.py --scaling``): one row per mesh shape with the warm
    (compile-separated) wall and its delta vs the previous run.  A warm
    wall more than 20% above the previous run's for the same mesh shape
    flags a REGRESSION; re-traced rows (trace-guard tripped) and rows
    whose results diverged from the vmap baseline are called out — both
    invalidate the measurement, not just degrade it."""
    def _load_dict(path):
        try:
            with open(path) as f:
                d = json.load(f)
            return d if isinstance(d, dict) else None
        except (OSError, ValueError):
            return None

    cur = _load_dict(os.path.join(cur_dir, SCALING_FILE))
    if cur is None or not isinstance(cur.get("rows"), list):
        return []
    prev = _load_dict(os.path.join(prev_dir, SCALING_FILE)) or {}
    prev_rows = {r.get("mesh"): r for r in prev.get("rows", [])
                 if isinstance(r, dict)}
    lines = [f"### {SCALING_FILE}", "",
             "| mesh | devices | wall (s) | Δ wall | speedup vs vmap | "
             "notes |", "|---|---|---|---|---|---|"]
    regressions = []
    for r in cur["rows"]:
        if not isinstance(r, dict):
            continue
        mesh = r.get("mesh")
        w, p = r.get("wall_s"), prev_rows.get(mesh, {}).get("wall_s")
        notes, flag = [], ""
        if r.get("retraced"):
            notes.append("⚠️ re-traced (wall includes compile)")
        if r.get("diverged"):
            notes.append("⚠️ results diverge from vmap")
        if isinstance(w, (int, float)) and isinstance(p, (int, float)) \
                and p > 0 and w > 1.2 * p:
            flag = " ⚠️ REGRESSION"
            regressions.append(str(mesh))
        lines.append(
            f"| {mesh} | {r.get('devices')} | {w} | "
            f"{_fmt_delta(w, p)}{flag} | {r.get('speedup_vs_vmap')} | "
            f"{'; '.join(notes)} |"
        )
    if regressions:
        lines.append("")
        lines.append(f"**⚠️ wall-clock regression >20% in {SCALING_FILE}: "
                     f"{', '.join(regressions)}**")
    cm = cur.get("manifest") or {}
    if cm:
        lines.append("")
        lines.append(f"_current: sha `{cm.get('git_sha')}` "
                     f"jax {cm.get('jax')}_")
    lines.append("")
    return lines


def _serving_section(prev_dir: str, cur_dir: str) -> List[str]:
    """Serving-tier trend: p95 token latency, swap traffic, preemptions
    and prefetched resumes per (sweep, point, policy) from the
    concurrent-load harness.  A current p95 token gap more than 20% above
    the previous run's is flagged as a REGRESSION — the serving analogue
    of the races' wall-clock flag.  The preemption/prefetch columns were
    collected since PR 6 but dropped before the diff; they are the
    scheduler-churn context a p95 move needs to be readable."""
    cur = _index(_load_rows(os.path.join(cur_dir, SERVING_FILE)))
    if not cur:
        return []
    prev = _index(_load_rows(os.path.join(prev_dir, SERVING_FILE)))
    lines = [f"### {SERVING_FILE}", ""]
    if not prev:
        lines.append("_no baseline in previous artifact (first run?)_")
        lines.append("")
        return lines
    lines.append("| sweep | point | policy | p95 token gap | Δ p95 | "
                 "swap (GB) | Δ swap | preempt | Δ preempt | "
                 "prefetch-resume | Δ |")
    lines.append("|---|---|---|---|---|---|---|---|---|---|---|")
    regressions = []
    for key in sorted(cur.keys(), key=str):
        c = cur[key]
        p = prev.get(key)
        gap, swap = c.get("p95_token_gap"), c.get("swap_gb")
        pre, pref = c.get("preemptions"), c.get("prefetched_resumes")
        if p is None:
            lines.append(f"| {key[0]} | {key[1]} | {key[2]} | {gap} | new | "
                         f"{swap} | new | {pre} | new | {pref} | new |")
            continue
        pgap = p.get("p95_token_gap")
        flag = ""
        if isinstance(gap, (int, float)) and isinstance(pgap, (int, float)) \
                and pgap > 0 and gap > 1.2 * pgap:
            flag = " ⚠️ REGRESSION"
            regressions.append(f"{key[0]}={key[1]}/{key[2]}")
        lines.append(
            f"| {key[0]} | {key[1]} | {key[2]} | {gap} | "
            f"{_fmt_delta(gap, pgap)}{flag} | "
            f"{swap} | {_fmt_delta(swap, p.get('swap_gb'))} | "
            f"{pre} | {_fmt_delta(pre, p.get('preemptions'))} | "
            f"{pref} | {_fmt_delta(pref, p.get('prefetched_resumes'))} |"
        )
    if regressions:
        lines.append("")
        lines.append(f"**⚠️ p95 token-latency regression >20% in "
                     f"{SERVING_FILE}: {', '.join(regressions)}**")
    lines.append("")
    return lines


def report(prev_dir: str, cur_dir: str) -> str:
    lines: List[str] = ["## Benchmark trend vs previous run", ""]
    any_table = False
    for fname in SWEEP_FILES:
        prev = _index(_load_rows(os.path.join(prev_dir, fname)))
        cur = _index(_load_rows(os.path.join(cur_dir, fname)))
        if not cur:
            continue
        if not prev:
            lines.append(f"_{fname}: no baseline in previous artifact "
                         "(first run?)_")
            lines.append("")
            continue
        any_table = True
        lines.append(f"### {fname}")
        lines.append("")
        lines.append("| sweep | point | policy | stream time (s) | Δ time | "
                     "io (GB) | Δ io |")
        lines.append("|---|---|---|---|---|---|---|")
        for key in sorted(cur.keys(), key=str):
            c = cur[key]
            p = prev.get(key)
            t_new, io_new = c.get("avg_stream_time_s"), c.get("io_gb")
            if p is None:
                lines.append(
                    f"| {key[0]} | {key[1]} | {key[2]} | {t_new} | new | "
                    f"{io_new} | new |"
                )
                continue
            lines.append(
                f"| {key[0]} | {key[1]} | {key[2]} | {t_new} | "
                f"{_fmt_delta(t_new, p.get('avg_stream_time_s'))} | "
                f"{io_new} | {_fmt_delta(io_new, p.get('io_gb'))} |"
            )
        lines.append("")
    for fname in RACE_FILES:
        race = _race_section(prev_dir, cur_dir, fname)
        if race:
            any_table = True
            lines.extend(race)
    scaling = _scaling_section(prev_dir, cur_dir)
    if scaling:
        any_table = True
        lines.extend(scaling)
    serving = _serving_section(prev_dir, cur_dir)
    if serving:
        any_table = True
        lines.extend(serving)
    if not any_table and len(lines) <= 2:
        lines.append("_no comparable sweep results found_")
    return "\n".join(lines)


def main() -> int:
    if len(sys.argv) != 3:
        print("usage: python benchmarks/trend.py <previous-dir> <current-dir>",
              file=sys.stderr)
        return 0  # never fail the build
    print(report(sys.argv[1], sys.argv[2]))
    return 0


if __name__ == "__main__":
    sys.exit(main())
