"""Trip-count-aware accounting over optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts every computation ONCE — a
``lax.scan`` over 95 layers reports one layer of FLOPs.  The roofline needs
per-*step* totals, so this module parses the optimized HLO module itself:

1. split into computations; parse each instruction's result shape(s),
   opcode, operands and attributes;
2. build the call graph (while bodies/conditions, fusions, calls,
   conditional branches) with multiplicities: a while's
   ``known_trip_count`` multiplies everything beneath it;
3. account per computation:
   * FLOPs  — dot ops: 2 x prod(output) x prod(contracting dims)
     (convolutions analogously); elementwise ignored (dots dominate);
   * bytes  — sum of operand + result bytes of top-level instructions
     (mirrors XLA's no-reuse "bytes accessed" convention); fusion-internal
     instructions are skipped (the fusion op's I/O is the access);
   * collective bytes — result bytes of all-gather / all-reduce /
     reduce-scatter / all-to-all / collective-permute, by kind;
4. total = sum over computations of (multiplicity x metrics).

Validated against cost_analysis on loop-free programs (exact match for dot
flops) and against hand counts on scanned programs (tests/test_roofline.py).
"""

from __future__ import annotations

import gzip
import math
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

# Excluded from the BYTES metric (TPU-target accounting):
#  * control-flow ops: their operand/result tuples double-count the body's
#    own traffic (the body computation is accounted separately);
#  * convert: the CPU backend has no native bf16 dot, so it materialises
#    f32 converts of every bf16 dot operand — on the TPU MXU these do not
#    exist (bf16 inputs, f32 accumulate in-register);
#  * copy: donation/loop-carry copies the TPU runtime elides.
_BYTES_SKIP_OPS = (
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "while", "conditional", "call", "convert", "copy",
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16, "token": 0,
    "opaque": 0, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{$")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^=]+?\)?)\s+([\w\-]+)\(")
_TRIP = re.compile(r'"known_trip_count":\s*\{"n":\s*"(\d+)"')
_CALLED = re.compile(
    r"(?:body|to_apply|calls)=%?([\w.\-]+)|condition=%?([\w.\-]+)"
)
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERANDS = re.compile(r"%([\w.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _parse_shapes(sig: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(sig):
        dt, dims = m.groups()
        if dt in _DTYPE_BYTES or dt in ("token", "opaque"):
            shape = [int(d) for d in dims.split(",") if d]
            out.append((dt, shape))
    return out


def _nbytes(sig: str) -> int:
    total = 0
    for dt, shape in _parse_shapes(sig):
        total += _DTYPE_BYTES.get(dt, 4) * math.prod(shape) if shape else \
            _DTYPE_BYTES.get(dt, 4)
    return total


@dataclass
class Instr:
    name: str
    sig: str
    opcode: str
    line: str
    operands: List[str] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    is_fusion_body: bool = False


COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


class HloModule:
    def __init__(self, text: str):
        self.comps: Dict[str, Computation] = {}
        self.shapes: Dict[Tuple[str, str], str] = {}  # (comp, instr) -> sig
        self._parse(text)

    # ------------------------------------------------------------- parsing
    def _parse(self, text: str) -> None:
        cur: Optional[Computation] = None
        self.entry: Optional[str] = None
        comment = re.compile(r"/\*.*?\*/")
        for raw in text.splitlines():
            line = comment.sub("", raw).rstrip()
            if not line:
                continue
            hdr = _COMP_HDR.match(line.strip())
            if hdr:
                name = hdr.group(1)
                cur = Computation(name=name)
                cur.is_fusion_body = name.startswith(("fused_", "wide."))
                self.comps[name] = cur
                if line.strip().startswith("ENTRY"):
                    self.entry = name
                continue
            if line.strip() == "}":
                cur = None
                continue
            if cur is None:
                continue
            m = _INSTR.match(line)
            if not m:
                continue
            iname, sig, opcode = m.groups()
            rest = line[m.end():]
            args = rest.split("),", 1)[0] if ")," in rest else rest.rstrip(")")
            operands = _OPERANDS.findall(args)
            inst = Instr(iname, sig.strip(), opcode, line, operands)
            cur.instrs.append(inst)
            self.shapes[(cur.name, iname)] = sig.strip()
        if self.entry is None:
            # fall back: last computation is usually the entry
            self.entry = list(self.comps)[-1] if self.comps else None

    # --------------------------------------------------------- call graph
    def _while_trips(self, inst: Instr) -> float:
        """Trip count of a while op.

        TPU/GPU HLO carries ``known_trip_count`` in backend_config; the CPU
        backend does not, but scan-lowered loops compare the induction var
        (from 0, step 1) against a constant in the *condition* computation —
        read that constant."""
        tm = _TRIP.search(inst.line)
        if tm:
            return float(tm.group(1))
        cm = re.search(r"condition=%?([\w.\-]+)", inst.line)
        if cm and cm.group(1) in self.comps:
            cond = self.comps[cm.group(1)]
            # search the condition (and anything it fuses) for the bound
            names = [cond.name]
            for ci in cond.instrs:
                for m in _CALLED.finditer(ci.line):
                    names.extend(c for c in m.groups() if c)
            bound = None
            for n in names:
                comp = self.comps.get(n)
                if comp is None:
                    continue
                for ci in comp.instrs:
                    m = re.search(r"constant\((\d+)\)", ci.line)
                    if m:
                        bound = max(bound or 0, int(m.group(1)))
            if bound:
                return float(bound)
        self.unknown_trips += 1
        return 1.0

    def multiplicities(self) -> Dict[str, float]:
        """computation -> execution count per step (trip counts composed)."""
        mult: Dict[str, float] = defaultdict(float)
        self.unknown_trips = 0
        if self.entry is None:
            return mult

        def visit(comp_name: str, k: float, stack: Tuple[str, ...]) -> None:
            if comp_name not in self.comps or comp_name in stack:
                return
            mult[comp_name] += k
            comp = self.comps[comp_name]
            for inst in comp.instrs:
                called: List[str] = []
                for m in _CALLED.finditer(inst.line):
                    called.extend(c for c in m.groups() if c)
                bm = _BRANCHES.search(inst.line)
                if bm:
                    called.extend(
                        c.strip().lstrip("%") for c in bm.group(1).split(",")
                    )
                if not called:
                    continue
                trips = self._while_trips(inst) if inst.opcode == "while" else 1.0
                for c in called:
                    visit(c, k * trips, stack + (comp_name,))

        visit(self.entry, 1.0, ())
        return dict(mult)

    # ----------------------------------------------------------- metrics
    def _dot_flops(self, comp: Computation, inst: Instr) -> float:
        out_elems = sum(math.prod(s) for _, s in _parse_shapes(inst.sig))
        cm = _CONTRACT.search(inst.line)
        k = 1
        if cm and inst.operands:
            lhs_sig = self.shapes.get((comp.name, inst.operands[0]))
            if lhs_sig:
                shapes = _parse_shapes(lhs_sig)
                if shapes:
                    lhs_shape = shapes[0][1]
                    for d in cm.group(1).split(","):
                        if d and int(d) < len(lhs_shape):
                            k *= lhs_shape[int(d)]
        return 2.0 * out_elems * k

    def comp_metrics(self, comp: Computation) -> Dict[str, float]:
        flops = 0.0
        bytes_ = 0.0
        coll: Dict[str, float] = defaultdict(float)
        for inst in comp.instrs:
            if inst.opcode in ("dot", "convolution"):
                flops += self._dot_flops(comp, inst)
            if inst.opcode in COLLECTIVES or any(
                inst.opcode.startswith(c) for c in COLLECTIVES
            ):
                kind = next(c for c in COLLECTIVES if inst.opcode.startswith(c))
                coll[kind] += _nbytes(inst.sig)
            if comp.is_fusion_body:
                continue  # fusion I/O accounted at the call site
            if inst.opcode in ("while", "conditional", "call") or \
                    inst.opcode in ("parameter", "constant",
                                    "get-tuple-element", "tuple", "bitcast",
                                    "after-all"):
                continue
            is_convert = inst.opcode in ("convert", "copy") or (
                inst.opcode == "fusion" and "wrapped_convert" in inst.line
            )
            if not is_convert:
                bytes_ += _nbytes(inst.sig)
            # converts/copies still READ their source once (the bf16 weights
            # feeding a CPU-upcast dot are real HBM traffic on TPU too); the
            # f32 result materialisation is the CPU-only artifact.
            for op in inst.operands:
                sig = self.shapes.get((comp.name, op))
                if sig:
                    bytes_ += _nbytes(sig)
        return {"flops": flops, "bytes": bytes_, "collectives": dict(coll)}

    def totals(self) -> Dict[str, object]:
        mult = self.multiplicities()
        flops = 0.0
        bytes_ = 0.0
        coll: Dict[str, float] = defaultdict(float)
        per_op: Dict[Tuple[str, str], float] = defaultdict(float)
        self._top_bytes: Dict[Tuple[str, str], float] = {}
        for name, k in mult.items():
            comp = self.comps[name]
            m = self.comp_metrics(comp)
            flops += k * m["flops"]
            bytes_ += k * m["bytes"]
            for kind, v in m["collectives"].items():
                coll[kind] += k * v
            for inst in comp.instrs:
                if any(inst.opcode.startswith(c) for c in COLLECTIVES):
                    kind = next(c for c in COLLECTIVES
                                if inst.opcode.startswith(c))
                    per_op[(kind, inst.sig[:90])] += k * _nbytes(inst.sig)
                if not comp.is_fusion_body and inst.opcode not in _BYTES_SKIP_OPS:
                    nb = _nbytes(inst.sig) + sum(
                        _nbytes(self.shapes[(comp.name, op)])
                        for op in inst.operands
                        if (comp.name, op) in self.shapes
                    )
                    self._top_bytes[(inst.opcode, inst.sig[:70])] = (
                        self._top_bytes.get((inst.opcode, inst.sig[:70]), 0)
                        + k * nb
                    )
        top = sorted(per_op.items(), key=lambda kv: -kv[1])[:12]
        return {
            "flops": flops,
            "bytes": bytes_,
            "collective_bytes": dict(coll),
            "collective_total": sum(coll.values()),
            "top_collectives": [
                {"kind": k[0], "shape": k[1], "bytes": v} for k, v in top
            ],
            "top_bytes": [
                {"op": k[0], "shape": k[1], "bytes": v}
                for k, v in sorted(self._top_bytes.items(),
                                   key=lambda kv: -kv[1])[:12]
            ],
        }


def analyse_file(path: str) -> Dict[str, object]:
    opener = gzip.open if path.endswith(".gz") else open
    with opener(path, "rt") as f:
        return HloModule(f.read()).totals()
