"""Roofline analysis per (arch x shape) on the single-pod mesh.

Reads the dry-run artifacts (JSON + gzipped optimized HLO) and derives the
three roofline terms per chip per step:

  compute    = HLO_FLOPs  / PEAK_FLOPS          (197 TFLOP/s bf16, v5e)
  memory     = HLO_bytes  / HBM_BW              (819 GB/s)
  collective = coll_bytes / ICI_BW              (~50 GB/s/link)

HLO_FLOPs/bytes come from benchmarks.hlo_analysis (trip-count aware — XLA's
cost_analysis counts scan bodies once); the compiled module is already
SPMD-partitioned, so all numbers are per-chip.

Also reported: MODEL_FLOPS (6*N*D train / 2*N*D forward; N_active for MoE),
the useful-compute ratio MODEL_FLOPS/HLO_FLOPS (catches remat/redundant
compute), the dominant term, and the roofline fraction

  frac = (MODEL_FLOPS/chips / PEAK) / max(term)

i.e. model-flops utilisation assuming the step runs at the binding term —
the number §Perf hillclimbs.

``--kernels`` switches to the substrate's own Pallas ops
(``fifo_grant`` / ``batched_evict`` / ``wake_solve``): each is lowered at
a representative
queue shape, costed with XLA's compiled ``cost_analysis()``, and executed
once under a ``jax.profiler.TraceAnnotation`` span matching the
``jax.named_scope`` in ``kernels/ops.py`` — so a Perfetto capture of any
run shows the same ``kernel:*`` names this table prices.  CI's
bench-smoke job writes the result as ``roofline.json`` next to the race
artifacts.

Usage: PYTHONPATH=src:. python -m benchmarks.roofline [--json out.json]
       PYTHONPATH=src:. python -m benchmarks.roofline --kernels --json roofline.json
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

from .hlo_analysis import analyse_file

PEAK_FLOPS = 197e12      # bf16 / chip (TPU v5e)
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link
DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32_768 * 32,
    "decode_32k": 128,       # one token per sequence per step
    "long_500k": 1,
}


def model_flops(rec: Dict) -> float:
    n = rec["active_param_count"]
    tokens = SHAPE_TOKENS[rec["shape"]]
    if rec["shape"] == "train_4k":
        return 6.0 * n * tokens
    return 2.0 * n * tokens


def decode_ideal_seconds(rec: Dict) -> Optional[float]:
    """Bandwidth roofline for decode: one step must read the (TP-sharded)
    active params once per chip plus this chip's share of the KV/state
    cache — that HBM traffic, not FLOPs, is the decode roofline."""
    if rec["shape"] not in ("decode_32k", "long_500k"):
        return None
    try:
        import sys
        sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
        from repro.configs import SHAPES, get_config
        from repro.models import build_model, tree_paths
        import math as _m

        cfg = get_config(rec["arch"])
        model = build_model(cfg)
        shape = SHAPES[rec["shape"]]
        cache = model.cache_specs(shape.global_batch, shape.seq_len)
        import jax

        cache_bytes = sum(
            _m.prod(l.shape) * l.dtype.itemsize for l in jax.tree.leaves(cache)
        )
        tp = 16
        param_bytes_per_chip = 2.0 * rec["active_param_count"] / tp
        cache_per_chip = cache_bytes / rec["chips"]
        return (param_bytes_per_chip + cache_per_chip) / HBM_BW
    except Exception:
        return None


def analyse_cell(rec: Dict, hlo_path: str) -> Optional[Dict]:
    if rec["status"] != "ok" or not os.path.exists(hlo_path):
        return None
    tot = analyse_file(hlo_path)
    chips = rec["chips"]
    compute = tot["flops"] / PEAK_FLOPS
    memory = tot["bytes"] / HBM_BW
    coll = tot["collective_total"] / ICI_BW
    bound = max(compute, memory, coll, 1e-12)
    mf = model_flops(rec)
    ideal = mf / chips / PEAK_FLOPS
    d_ideal = decode_ideal_seconds(rec)
    if d_ideal is not None:
        ideal = max(ideal, d_ideal)
    dominant = (
        "compute" if bound == compute else "memory" if bound == memory
        else "collective"
    )
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "chips": chips,
        "flops_per_chip": tot["flops"],
        "bytes_per_chip": tot["bytes"],
        "coll_bytes_per_chip": tot["collective_total"],
        "coll_by_kind": tot["collective_bytes"],
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": coll,
        "bound_s": bound,
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": mf / chips / max(tot["flops"], 1.0),
        "roofline_frac": ideal / bound,
    }


def _cost(compiled) -> Dict:
    """Normalise ``compiled.cost_analysis()`` (dict on new jax, list of
    one dict on older releases) to a plain dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca or {})


def kernel_rows(n_pages: int = 4096) -> List[Dict]:
    """Roofline rows for the substrate's own ops at a representative
    shape (``n_pages`` ~ the batched sim's page-table width)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    key_f = (jnp.arange(n_pages, dtype=jnp.float32) * 37.0) % 1009.0
    key_i = (jnp.arange(n_pages, dtype=jnp.int32) * 37) % 1009
    sizes = jnp.full((n_pages,), 512.0 * 1024.0, jnp.float32)
    evictable = (jnp.arange(n_pages) % 3) != 0
    cases = [
        ("fifo_grant", ops.fifo_grant,
         (key_i, sizes, jnp.float32(64 << 20), jnp.int32(16))),
        ("batched_evict", ops.batched_evict,
         (key_f, sizes, evictable, jnp.float32(32 << 20))),
        ("wake_solve", ops.wake_solve,
         (key_i, sizes, jnp.float32(4 << 20), jnp.float32(1 << 20),
          jnp.int32(6))),
    ]
    rows = []
    for name, fn, fnargs in cases:
        jfn = jax.jit(fn)
        compiled = jfn.lower(*fnargs).compile()
        c = _cost(compiled)
        flops = float(c.get("flops", 0.0))
        nbytes = float(c.get("bytes accessed", 0.0))
        compute = flops / PEAK_FLOPS
        memory = nbytes / HBM_BW
        # exercise the span: the TraceAnnotation nests around the op's own
        # jax.named_scope, so profiler captures carry both labels
        with jax.profiler.TraceAnnotation(f"kernel:{name}"):
            jax.block_until_ready(jfn(*fnargs))
        rows.append({
            "kernel": name,
            "backend": ops.get_backend(),
            "platform": jax.default_backend(),
            "n_pages": n_pages,
            "flops": flops,
            "bytes": nbytes,
            "transcendentals": float(c.get("transcendentals", 0.0)),
            "compute_s": compute,
            "memory_s": memory,
            "dominant": "compute" if compute >= memory else "memory",
        })
    return rows


def fmt_kernel_table(rows: List[Dict]) -> str:
    hdr = (f"{'kernel':16s} {'P':>6s} {'flops':>12s} {'bytes':>12s} "
           f"{'comp_us':>9s} {'mem_us':>9s} {'bound':>8s}")
    out = [hdr, "-" * len(hdr)]
    for r in rows:
        out.append(
            f"{r['kernel']:16s} {r['n_pages']:6d} {r['flops']:12.3e} "
            f"{r['bytes']:12.3e} {r['compute_s']*1e6:9.3f} "
            f"{r['memory_s']*1e6:9.3f} {r['dominant']:>8s}"
        )
    return "\n".join(out)


def run(dryrun_dir: str = DRYRUN_DIR) -> List[Dict]:
    rows = []
    for jf in sorted(glob.glob(os.path.join(dryrun_dir, "*__pod.json"))):
        with open(jf) as fh:
            rec = json.load(fh)
        if rec["status"] == "skipped":
            rows.append({
                "arch": rec["arch"], "shape": rec["shape"],
                "dominant": "SKIPPED", "note": rec["reason"],
            })
            continue
        hlo = jf.replace(".json", ".hlo.gz")
        r = analyse_cell(rec, hlo)
        if r:
            rows.append(r)
    return rows


def fmt_table(rows: List[Dict]) -> str:
    hdr = (f"{'arch':26s} {'shape':12s} {'comp_ms':>9s} {'mem_ms':>9s} "
           f"{'coll_ms':>9s} {'bound':>10s} {'useful':>7s} {'RLfrac':>7s}")
    out = [hdr, "-" * len(hdr)]
    for r in rows:
        if r.get("dominant") == "SKIPPED":
            out.append(f"{r['arch']:26s} {r['shape']:12s} {'— skipped: ' + r['note']}")
            continue
        out.append(
            f"{r['arch']:26s} {r['shape']:12s} "
            f"{r['compute_s']*1e3:9.3f} {r['memory_s']*1e3:9.3f} "
            f"{r['collective_s']*1e3:9.3f} {r['dominant']:>10s} "
            f"{r['useful_ratio']:7.3f} {r['roofline_frac']:7.3f}"
        )
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default=DRYRUN_DIR)
    ap.add_argument("--json", default=None)
    ap.add_argument("--kernels", action="store_true",
                    help="cost the substrate's fifo_grant/batched_evict "
                         "ops instead of the dry-run artifacts")
    ap.add_argument("--pages", type=int, default=4096,
                    help="--kernels queue width")
    args = ap.parse_args()
    if args.kernels:
        rows = kernel_rows(args.pages)
        print(fmt_kernel_table(rows))
        payload: object = rows
        try:
            from repro.obs import manifest as _manifest
            payload = {"manifest": _manifest.collect(), "kernels": rows}
        except Exception:
            pass
    else:
        rows = run(args.dryrun_dir)
        print(fmt_table(rows))
        payload = rows
    if args.json:
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)


if __name__ == "__main__":
    main()
