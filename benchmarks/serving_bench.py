"""Serving-tier benchmark: KV page-pool policies under concurrent decode load.

The ML-side analogue of the paper's throughput run (§4.2): a stream of
decode requests arrives over time at an engine whose HBM page pool is
oversubscribed, so some request's pages must spill to host.  Which pages
leave, in what order preempted requests resume, and what gets prepared
ahead is the buffer-management policy under test — resolved by NAME
through ``repro.core.policy_registry``, the same table the event engine
and the batched array simulator use (lru / cscan / pbm / opt).

Reported per policy and operating point: p50/p95 **token latency** (engine
steps between successive tokens of one request — the stall a user feels
mid-stream), p50/p95 TTFT and completion latency, swap traffic, and
completion throughput.  ``sweep()`` walks n_requests x pool_pages x
prefix-share ratio around :data:`DEFAULT_POINT`; rows carry
``sweep``/``point``/``policy`` keys so ``benchmarks/trend.py`` tracks them
across CI runs (>20% p95 token-latency growth is flagged).
"""

from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional

import numpy as np

from repro.core import policy_registry
from repro.serving import PagePool, Request, ServingEngine

#: The documented operating point (EXPERIMENTS.md "serving"): pool holds
#: ~60% of peak demand, half the requests share a system prompt, arrivals
#: keep the batch saturated.  At this point PBM must strictly beat LRU on
#: p95 token latency or swap volume, with OPT bounding both — asserted in
#: tests/test_serving_policy.py.
DEFAULT_POINT: Dict = dict(
    n_requests=32, pool_pages=28, page_size=16, prefix_len=64,
    share_ratio=0.5, max_batch=8, arrival_interval=1,
    gen_lo=16, gen_hi=160, seed=1,
)


def _pct(xs: List[float], q: float) -> float:
    return float(np.percentile(xs, q)) if xs else 0.0


def run_policy(policy: str, *, n_requests=32, pool_pages=28, page_size=16,
               prefix_len=64, share_ratio=0.5, max_batch=8,
               arrival_interval=2, gen_lo=16, gen_hi=160, seed=1,
               sweep: str = "default", point: str = "default",
               record_events: bool = False,
               events_out: Optional[List] = None) -> Dict:
    """One policy at one operating point under timed arrivals.

    ``record_events=True`` turns on the engine's structured scheduler
    events (admit/preempt/resume/prefetch with the policy verdict);
    they are appended to ``events_out`` so ``--trace`` can render a
    Perfetto track of the run."""
    # resolve through the registry FIRST: unknown or non-serving names die
    # here with the registered list, not deep inside the engine
    policy_registry.serving_policy(policy)
    pool = PagePool(
        n_pages=pool_pages, page_size=page_size,
        page_bytes=page_size * 2 * 8 * 128 * 2,   # tokens*kv*heads*dh*bf16
    )

    def step_fn(reqs):
        return [int((r.kv.length * 2654435761) % 50000) for r in reqs]

    eng = ServingEngine(pool, step_fn, policy=policy, max_batch=max_batch,
                        record_events=record_events)
    rng = np.random.default_rng(seed)
    common = list(range(prefix_len))  # shared system prompt
    lengths = rng.integers(gen_lo, gen_hi, n_requests)
    shared = rng.random(n_requests) < share_ratio
    plan: List[Request] = []
    for i in range(n_requests):
        prefix = common if shared[i] else [1000 + i] * prefix_len
        plan.append(Request(
            prompt=prefix + list(rng.integers(0, 100, 16)),
            max_new_tokens=int(lengths[i]),
        ))
    # timed arrivals: one request every arrival_interval steps — the
    # engine runs WHILE load arrives instead of draining a pre-filled queue
    due = 0
    while len(eng.finished) < n_requests and eng.stats.steps < 50_000:
        while due < n_requests and eng.stats.steps >= due * arrival_interval:
            eng.submit(plan[due])
            due += 1
        eng.step()
    st = eng.stats
    done = eng.finished
    if events_out is not None:
        events_out.extend(eng.events)
    ttft = [r.first_token_step - r.arrival_step for r in done]
    completion = [r.done_step - r.arrival_step for r in done]
    from repro.obs import manifest as _manifest
    return {
        "manifest": _manifest.collect(backend="serving"),
        "sweep": sweep,
        "point": point,
        "policy": policy,
        "steps": st.steps,
        "completed": len(done),
        "tokens": st.tokens_generated,
        "tokens_per_step": round(st.tokens_generated / max(1, st.steps), 3),
        "p50_token_gap": round(_pct(eng.token_gaps, 50), 2),
        "p95_token_gap": round(_pct(eng.token_gaps, 95), 2),
        "p50_ttft": round(_pct(ttft, 50), 1),
        "p95_ttft": round(_pct(ttft, 95), 1),
        "p50_completion": round(_pct(completion, 50), 1),
        "p95_completion": round(_pct(completion, 95), 1),
        "preemptions": st.preemptions,
        "resumes": st.resumes,
        "prefetched_resumes": st.prefetched_resumes,
        "shared_prefix_pages": st.shared_prefix_pages,
        "swap_gb": round((st.swap_out_bytes + st.swap_in_bytes) / 1e9, 4),
    }


#: sweep axes around DEFAULT_POINT (key -> values to substitute)
SWEEP_AXES = {
    "n_requests": (16, 32, 48),
    "pool_pages": (24, 28, 40),
    "share_ratio": (0.0, 0.5, 0.9),
}


def sweep(policies: Optional[List[str]] = None, smoke: bool = False
          ) -> List[Dict]:
    """n_requests x pool_pages x share_ratio sweep, one row per policy."""
    if policies is None:
        policies = policy_registry.names(backend="serving")
    rows: List[Dict] = []
    axes = {"pool_pages": SWEEP_AXES["pool_pages"]} if smoke else SWEEP_AXES
    for axis, values in axes.items():
        for v in values:
            kw = dict(DEFAULT_POINT)
            kw[axis] = v
            for p in policies:
                rows.append(run_policy(p, sweep=axis, point=str(v), **kw))
    return rows


def main() -> None:
    names = policy_registry.names(backend="serving")
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--policy", default=None,
                    help=f"one registry policy (default: all of {names})")
    ap.add_argument("--out", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="pool_pages axis only (CI lane)")
    ap.add_argument("--requests", type=int, default=None,
                    help="override n_requests on every point")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="also run the default point with scheduler-event "
                         "recording on and write a Perfetto (chrome://"
                         "tracing) JSON of admit/preempt/resume/prefetch")
    args = ap.parse_args()
    policies = [args.policy] if args.policy else names
    if args.requests is not None:
        DEFAULT_POINT["n_requests"] = args.requests
    rows = sweep(policies, smoke=args.smoke)
    if args.trace:
        from repro.obs.trace import serving_events_to_chrome
        events: List[Dict] = []
        pol = args.policy or "pbm"
        row = run_policy(pol, record_events=True, events_out=events,
                         **DEFAULT_POINT)
        with open(args.trace, "w") as f:
            json.dump(serving_events_to_chrome(
                events, label=f"serving[{pol}]"), f)
        print(f"  wrote {args.trace}: {len(events)} scheduler events "
              f"({row['preemptions']} preemptions, "
              f"{row['resumes']} resumes)")
    for r in rows:
        print(f"  serve/{r['sweep']}={r['point']:>7s} {r['policy']:6s} "
              f"p95gap={r['p95_token_gap']:6.2f} "
              f"p95ttft={r['p95_ttft']:6.1f} "
              f"tok/step={r['tokens_per_step']:5.2f} "
              f"preempt={r['preemptions']:3d} swap={r['swap_gb']:.3f}GB")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=2)


if __name__ == "__main__":
    main()
