"""Serving-tier benchmark: KV page-pool policies under HBM oversubscription.

The ML-side analogue of the paper's throughput run: many concurrent decode
requests over an oversubscribed HBM page pool with a shared prompt prefix.
Compares preemption/spill policies lru / pbm / belady on swap I/O volume
and completion steps — the serving deployment of the paper's idea
(DESIGN.md §2, integration 2).
"""

from __future__ import annotations

import argparse
import json
from typing import Dict, List

import numpy as np

from repro.serving import PagePool, Request, ServingEngine


def run_policy(policy: str, *, n_requests=32, pool_pages=36, page_size=16,
               prefix_len=64, max_batch=12, seed=1) -> Dict:
    pool = PagePool(
        n_pages=pool_pages, page_size=page_size,
        page_bytes=page_size * 2 * 8 * 128 * 2,   # tokens*kv*heads*dh*bf16
    )

    def step_fn(reqs):
        return [int((r.kv.length * 2654435761) % 50000) for r in reqs]

    eng = ServingEngine(pool, step_fn, policy=policy, max_batch=max_batch)
    rng = np.random.default_rng(seed)
    common = list(range(prefix_len))  # shared system prompt
    lengths = rng.integers(16, 160, n_requests)
    for i in range(n_requests):
        eng.submit(Request(
            prompt=common + list(rng.integers(0, 100, 16)),
            max_new_tokens=int(lengths[i]),
        ))
    st = eng.run_to_completion(max_steps=20_000)
    return {
        "policy": policy,
        "steps": st.steps,
        "tokens": st.tokens_generated,
        "tokens_per_step": round(st.tokens_generated / max(1, st.steps), 2),
        "preemptions": st.preemptions,
        "shared_prefix_pages": st.shared_prefix_pages,
        "swap_gb": round((st.swap_out_bytes + st.swap_in_bytes) / 1e9, 4),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--pool-pages", type=int, default=36)
    args = ap.parse_args()
    rows = [
        run_policy(p, n_requests=args.requests, pool_pages=args.pool_pages)
        for p in ("lru", "pbm", "belady")
    ]
    for r in rows:
        print(f"  serve/{r['policy']:6s} steps={r['steps']:5d} "
              f"tok/step={r['tokens_per_step']:5.2f} preempt={r['preemptions']:3d} "
              f"swap={r['swap_gb']:.3f}GB shared={r['shared_prefix_pages']}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=2)


if __name__ == "__main__":
    main()
