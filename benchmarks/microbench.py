"""Microbenchmark sweeps — paper Figures 11, 12, 13.

Q1/Q6-style range scans over lineitem SF30 (~1.26GB accessed working set),
sweeping buffer-pool size / I/O bandwidth / concurrent streams, comparing
LRU, CScans, PBM, OPT (+ beyond-paper PBM/LRU and Attach&Throttle with
--extended).  OPT is reported two ways, matching the paper's methodology:
I/O volume from Belady's MIN replayed on the PBM run's reference trace, and
stream time from the in-engine exact-distance oracle policy.
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

from repro.core import EngineConfig, run_workload, simulate_belady
from repro.core.policy_registry import names as policy_names
from repro.core.workload import (
    make_lineitem_db,
    micro_accessed_bytes,
    micro_streams,
)

# one source of truth for policy lists: the registry shared by both
# backends (unknown names fail there with the known-name list)
POLICIES = policy_names(backend="event", paper_only=True)
EXTENDED = [n for n in policy_names(backend="event")
            if n not in POLICIES]
ARRAY_POLICIES = policy_names(backend="array")

DEFAULTS = dict(n_streams=8, queries=16, bandwidth=700e6, buffer_frac=0.4, seed=3)


def _manifest(**extra) -> Dict:
    """RunManifest for one batch of rows (computed once, shared by
    reference — json serialises it per row, which is the contract:
    every benchmark JSON row is attributable on its own)."""
    from repro.obs import manifest as _m
    return _m.collect(**extra)


def one_point(db, ws, policies, *, n_streams, queries, bandwidth, buffer_frac,
              seed, fraction=None, time_slice=0.1) -> List[Dict]:
    streams = micro_streams(db, n_streams=n_streams, queries_per_stream=queries,
                            fraction=fraction, seed=seed)
    rows = []
    pbm_trace = None
    for pol in policies:
        cfg = EngineConfig(
            bandwidth=bandwidth,
            buffer_bytes=max(1 << 22, int(buffer_frac * ws)),
            sample_interval=2.0,
            record_trace=(pol == "pbm"),
            pbm_time_slice=time_slice,
        )
        t0 = time.time()
        r = run_workload(db, streams, pol, cfg)
        row = {
            "policy": pol,
            "avg_stream_time_s": round(r.avg_stream_time, 3),
            "io_gb": round(r.io_gb, 3),
            "wall_s": round(time.time() - t0, 2),
        }
        if pol == "pbm":
            pbm_trace = (r.trace, r.page_sizes)
        rows.append(row)
    if pbm_trace is not None and "opt" in policies:
        # paper methodology: Belady's MIN on the PBM run's reference string
        trace, sizes = pbm_trace
        cfgb = max(1 << 22, int(buffer_frac * ws))
        misses, missed_bytes = simulate_belady(
            trace, page_sizes=sizes, capacity_bytes=cfgb
        )
        for row in rows:
            if row["policy"] == "opt":
                row["io_gb_belady_trace"] = round(missed_bytes / 1e9, 3)
    return rows


def sweep(which: str, policies: List[str], scale: float = 1.0, seed: int = 3):
    db = make_lineitem_db(scale_tuples=int(180_000_000 * scale))
    ws = micro_accessed_bytes(db)
    points = {
        "buffer": [0.1, 0.2, 0.4, 0.6, 0.8, 1.0],
        "bandwidth": [200e6, 400e6, 700e6, 1000e6, 1400e6, 2000e6],
        "streams": [1, 2, 4, 8, 16, 32],
    }[which]
    out = []
    for p in points:
        kw = dict(DEFAULTS)
        kw["seed"] = seed
        if which == "buffer":
            kw["buffer_frac"] = p
        elif which == "bandwidth":
            kw["bandwidth"] = p
        else:
            kw["n_streams"] = int(p)
        fraction = 0.5 if which == "streams" else None  # paper Fig 13: 50% scans
        # PBM bucket resolution scales with the (scaled) workload duration
        rows = one_point(db, ws, policies, fraction=fraction,
                         time_slice=0.1 * scale, **kw)
        for r in rows:
            r["sweep"] = which
            r["point"] = p
            r["manifest"] = _manifest(backend="event")
        out.extend(rows)
        label = f"{p:.0%}" if which == "buffer" else (
            f"{p/1e6:.0f}MB/s" if which == "bandwidth" else f"{int(p)} streams")
        summary = " ".join(
            f"{r['policy']}={r['avg_stream_time_s']:.1f}s/{r['io_gb']:.1f}GB"
            for r in rows
        )
        print(f"  micro/{which} @ {label:10s} {summary}", flush=True)
    return out


def sweep_array(which: str, policies=None, scale: float = 1.0, seed: int = 3,
                stepper: str = "horizon"):
    """Array-backend (``repro.core.array_sim``) version of :func:`sweep`.

    Emits rows with the same schema (policy / avg_stream_time_s / io_gb /
    wall_s / sweep / point) for every registered array policy — the
    paper's full four-way comparison.  One jitted runner per
    (streams-config, policy) is reused across sweep points: the capacity
    and bandwidth of each point are traced config scalars.  ``stepper``
    picks the time engine (``repro.core.array_sim.make_runner``) — the
    event-horizon stepper is the default benchmark lane.
    """
    from repro.core.array_sim import build_spec, make_runner, run_workload_array

    policies = policies or ARRAY_POLICIES
    db = make_lineitem_db(scale_tuples=int(180_000_000 * scale))
    ws = micro_accessed_bytes(db)
    points = {
        "buffer": [0.1, 0.2, 0.4, 0.6, 0.8, 1.0],
        "bandwidth": [200e6, 400e6, 700e6, 1000e6, 1400e6, 2000e6],
        "streams": [1, 2, 4, 8, 16, 32],
    }[which]
    time_slice = 0.1 * scale
    out = []
    spec_cache = {}
    # per-page plan-trigger semantics: a scan blocks per column at the
    # first absent trigger, so every pool size down to the eviction batch
    # makes progress — no envelope skips (the old all-columns-resident
    # model could not run pools below streams x columns + batch pages)
    for p in points:
        kw = dict(DEFAULTS)
        kw["seed"] = seed
        if which == "buffer":
            kw["buffer_frac"] = p
        elif which == "bandwidth":
            kw["bandwidth"] = p
        else:
            kw["n_streams"] = int(p)
        fraction = 0.5 if which == "streams" else None
        skey = (kw["n_streams"], kw["queries"], fraction, seed)
        if skey not in spec_cache:
            streams = micro_streams(db, n_streams=kw["n_streams"],
                                    queries_per_stream=kw["queries"],
                                    fraction=fraction, seed=seed)
            spec = build_spec(db, streams)
            runners = {
                pol: make_runner(spec, bandwidth_ref=700e6,
                                 time_slice=time_slice, policies=(pol,),
                                 stepper=stepper)
                for pol in policies
            }
            spec_cache[skey] = (streams, spec, runners)
        streams, spec, runners = spec_cache[skey]
        cap = max(1 << 22, int(kw["buffer_frac"] * ws))
        rows = []
        manifest = _manifest(spec=spec, stepper=stepper, backend="array")
        for pol in policies:
            r = run_workload_array(
                db, streams, pol, capacity_bytes=cap,
                bandwidth=kw["bandwidth"], time_slice=time_slice,
                spec=spec, runner=runners[pol],
            )
            rows.append({
                "policy": pol,
                "avg_stream_time_s": round(r.avg_stream_time, 3),
                "io_gb": round(r.io_gb, 3),
                "wall_s": round(r.wall_s, 2),
                "sweep": which,
                "point": p,
                "backend": "array",
                "stepper": stepper,
                "macro_steps": r.extras.get("macro_steps", r.steps),
                "skipped_time": r.extras.get("skipped_time", 0.0),
                "truncated": r.extras.get("truncated", False),
                "manifest": dict(manifest,
                                 trace_count=runners[pol].trace_count()),
            })
        out.extend(rows)
        label = f"{p:.0%}" if which == "buffer" else (
            f"{p/1e6:.0f}MB/s" if which == "bandwidth" else f"{int(p)} streams")
        summary = " ".join(
            f"{r['policy']}={r['avg_stream_time_s']:.1f}s/{r['io_gb']:.1f}GB"
            for r in rows
        )
        print(f"  micro[array]/{which} @ {label:10s} {summary}", flush=True)
    return out


def batched_buffer_race(scale: float = 1.0, seed: int = 3,
                        fracs=None, policy: str = "pbm"):
    """One vmapped array run over the paper's buffer points (small pools
    included — per-page plan-trigger semantics make every pool size
    runnable) vs the same points run sequentially on the event engine.
    Tracks the batched substrate's wall-clock trend in CI: on CPU the
    plan-trigger step's fidelity costs op-count per step and the dict
    engine currently wins at quick scale; the batched path is the one
    that vectorises across sweep axes and devices (see ROADMAP).  The
    batched runner uses the coarse 2-page step mode.  Returns (and the
    caller prints) a summary dict."""
    import jax

    from repro.core import EngineConfig, run_workload
    from repro.core.array_sim import (
        build_spec, make_config, make_runner, result_from_state, stack_configs,
    )

    db = make_lineitem_db(scale_tuples=int(180_000_000 * scale))
    ws = micro_accessed_bytes(db)
    streams = micro_streams(db, n_streams=8, queries_per_stream=16, seed=seed)
    time_slice = 0.1 * scale
    spec = build_spec(db, streams)
    # per-page plan-trigger semantics: every pool size makes progress, so
    # the race sweeps the paper's own small-buffer points directly
    fracs = list(fracs) if fracs is not None else [0.1, 0.2, 0.4, 0.6]
    caps = [max(1 << 22, int(f * ws)) for f in fracs]

    t0 = time.time()
    ev_rows = []
    for cap in caps:
        cfg = EngineConfig(bandwidth=700e6, buffer_bytes=cap,
                           sample_interval=2.0, pbm_time_slice=time_slice)
        ev_rows.append(run_workload(db, streams, policy, cfg))
    event_wall = time.time() - t0

    runner = make_runner(spec, bandwidth_ref=700e6, time_slice=time_slice,
                         policies=(policy,), step_pages=2.0)
    vrun = jax.jit(jax.vmap(runner))
    cfgs = stack_configs([make_config(spec, cap, 700e6, policy) for cap in caps])
    t0 = time.time()
    states = jax.block_until_ready(vrun(cfgs))
    array_cold = time.time() - t0
    t0 = time.time()
    states = jax.block_until_ready(vrun(cfgs))
    array_wall = time.time() - t0

    results = [
        result_from_state(jax.tree.map(lambda x, i=i: x[i], states), policy)
        for i in range(len(fracs))
    ]
    # telemetry pass: a SEPARATE runner so the timed race above stays the
    # bare program (the knob is static — the timed runner's jaxpr never
    # carries counters); hit rates and per-lane eviction counts come from
    # this one extra vmapped run
    from repro.obs import counters as obs_counters
    runner_t = make_runner(spec, bandwidth_ref=700e6, time_slice=time_slice,
                           policies=(policy,), step_pages=2.0, telemetry=True)
    _, tele = jax.block_until_ready(jax.jit(jax.vmap(runner_t))(cfgs))
    tele_rows = [
        obs_counters.summarize(obs_counters.lane_slice(tele, i),
                               policies=runner_t.policy_names,
                               steps=results[i].steps)
        for i in range(len(fracs))
    ]
    # a lane cut short by the max_time livelock guard would report its
    # stream times as complete and its spin time as wall-clock — flag it
    # so the CI trend metric is never silently poisoned
    truncated = [f for f, r in zip(fracs, results)
                 if r.extras.get("truncated")]
    if truncated:
        print(f"  batched sweep WARNING: truncated lanes (livelock guard) "
              f"at buffer fracs {truncated} — wall-clock race is invalid",
              flush=True)
    print(
        f"  batched sweep [{policy}, {len(fracs)} buffer points]: "
        f"vmapped array = {array_wall:.2f}s (cold {array_cold:.2f}s incl. "
        f"compile) vs sequential event engine = {event_wall:.2f}s "
        f"-> {'array WINS' if array_wall < event_wall else 'event wins'} "
        f"({event_wall / max(array_wall, 1e-9):.2f}x)",
        flush=True,
    )
    return {
        "policy": policy,
        "fracs": list(fracs),
        "array_vmapped_wall_s": round(array_wall, 3),
        "array_cold_wall_s": round(array_cold, 3),
        "event_sequential_wall_s": round(event_wall, 3),
        "speedup": round(event_wall / max(array_wall, 1e-9), 3),
        "truncated_fracs": truncated,
        "array_avg_stream_time_s": [round(r.avg_stream_time, 3) for r in results],
        "event_avg_stream_time_s": [round(r.avg_stream_time, 3) for r in ev_rows],
        "macro_steps": [int(r.extras.get("macro_steps", r.steps))
                        for r in results],
        "skipped_time_s": [round(float(r.extras.get("skipped_time", 0.0)), 3)
                           for r in results],
        "hit_rate": [t["hit_rate"] for t in tele_rows],
        "array_evictions": [t["evictions"] for t in tele_rows],
        "event_evictions": [r.total_evictions for r in ev_rows],
        "telemetry": tele_rows,
        "manifest": _manifest(spec=spec, runner=runner, stepper="horizon",
                              backend="race"),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sweep", choices=["buffer", "bandwidth", "streams", "all"],
                    default="all")
    ap.add_argument("--scale", type=float, default=None,
                    help="table-size scale (default 1.0; 0.25 under --smoke)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: quick scale, buffer sweep only (same "
                         "semantics as benchmarks/run.py --smoke)")
    ap.add_argument("--extended", action="store_true")
    ap.add_argument("--backend", choices=["event", "array"], default="event")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    scale = args.scale if args.scale is not None else (
        0.25 if args.smoke else 1.0)
    if args.smoke:
        sweeps = ["buffer"]
    else:
        sweeps = (["buffer", "bandwidth", "streams"]
                  if args.sweep == "all" else [args.sweep])
    rows = []
    if args.backend == "array":
        for s in sweeps:
            rows.extend(sweep_array(s, ARRAY_POLICIES, scale=scale))
        batched_buffer_race(scale=scale)
    else:
        policies = POLICIES + (EXTENDED if args.extended else [])
        for s in sweeps:
            rows.extend(sweep(s, policies, scale=scale))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=2)


if __name__ == "__main__":
    main()
