"""Microbenchmark sweeps — paper Figures 11, 12, 13.

Q1/Q6-style range scans over lineitem SF30 (~1.26GB accessed working set),
sweeping buffer-pool size / I/O bandwidth / concurrent streams, comparing
LRU, CScans, PBM, OPT (+ beyond-paper PBM/LRU and Attach&Throttle with
--extended).  OPT is reported two ways, matching the paper's methodology:
I/O volume from Belady's MIN replayed on the PBM run's reference trace, and
stream time from the in-engine exact-distance oracle policy.
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Optional

from repro.core import EngineConfig, run_workload, simulate_belady
from repro.core.workload import (
    make_lineitem_db,
    micro_accessed_bytes,
    micro_streams,
)

POLICIES = ["lru", "cscan", "pbm", "opt"]
EXTENDED = ["mru", "pbm_lru", "attach"]

DEFAULTS = dict(n_streams=8, queries=16, bandwidth=700e6, buffer_frac=0.4, seed=3)


def one_point(db, ws, policies, *, n_streams, queries, bandwidth, buffer_frac,
              seed, fraction=None, time_slice=0.1) -> List[Dict]:
    streams = micro_streams(db, n_streams=n_streams, queries_per_stream=queries,
                            fraction=fraction, seed=seed)
    rows = []
    pbm_trace = None
    for pol in policies:
        cfg = EngineConfig(
            bandwidth=bandwidth,
            buffer_bytes=max(1 << 22, int(buffer_frac * ws)),
            sample_interval=2.0,
            record_trace=(pol == "pbm"),
            pbm_time_slice=time_slice,
        )
        t0 = time.time()
        r = run_workload(db, streams, pol, cfg)
        row = {
            "policy": pol,
            "avg_stream_time_s": round(r.avg_stream_time, 3),
            "io_gb": round(r.io_gb, 3),
            "wall_s": round(time.time() - t0, 2),
        }
        if pol == "pbm":
            pbm_trace = (r.trace, r.page_sizes)
        rows.append(row)
    if pbm_trace is not None and "opt" in policies:
        # paper methodology: Belady's MIN on the PBM run's reference string
        trace, sizes = pbm_trace
        cfgb = max(1 << 22, int(buffer_frac * ws))
        misses, missed_bytes = simulate_belady(
            trace, page_sizes=sizes, capacity_bytes=cfgb
        )
        for row in rows:
            if row["policy"] == "opt":
                row["io_gb_belady_trace"] = round(missed_bytes / 1e9, 3)
    return rows


def sweep(which: str, policies: List[str], scale: float = 1.0, seed: int = 3):
    db = make_lineitem_db(scale_tuples=int(180_000_000 * scale))
    ws = micro_accessed_bytes(db)
    points = {
        "buffer": [0.1, 0.2, 0.4, 0.6, 0.8, 1.0],
        "bandwidth": [200e6, 400e6, 700e6, 1000e6, 1400e6, 2000e6],
        "streams": [1, 2, 4, 8, 16, 32],
    }[which]
    out = []
    for p in points:
        kw = dict(DEFAULTS)
        kw["seed"] = seed
        if which == "buffer":
            kw["buffer_frac"] = p
        elif which == "bandwidth":
            kw["bandwidth"] = p
        else:
            kw["n_streams"] = int(p)
        fraction = 0.5 if which == "streams" else None  # paper Fig 13: 50% scans
        # PBM bucket resolution scales with the (scaled) workload duration
        rows = one_point(db, ws, policies, fraction=fraction,
                         time_slice=0.1 * scale, **kw)
        for r in rows:
            r["sweep"] = which
            r["point"] = p
        out.extend(rows)
        label = f"{p:.0%}" if which == "buffer" else (
            f"{p/1e6:.0f}MB/s" if which == "bandwidth" else f"{int(p)} streams")
        summary = " ".join(
            f"{r['policy']}={r['avg_stream_time_s']:.1f}s/{r['io_gb']:.1f}GB"
            for r in rows
        )
        print(f"  micro/{which} @ {label:10s} {summary}", flush=True)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sweep", choices=["buffer", "bandwidth", "streams", "all"],
                    default="all")
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--extended", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    policies = POLICIES + (EXTENDED if args.extended else [])
    sweeps = ["buffer", "bandwidth", "streams"] if args.sweep == "all" else [args.sweep]
    rows = []
    for s in sweeps:
        rows.extend(sweep(s, policies, scale=args.scale))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=2)


if __name__ == "__main__":
    main()
