"""Benchmark driver: one section per paper table/figure + framework benches.

Prints ``name,us_per_call,derived`` CSV rows (spec format): for the policy
benchmarks us_per_call is the simulated avg stream time in microseconds and
``derived`` is total I/O GB; for roofline rows us_per_call is the binding
roofline term per step and derived the roofline fraction.

  PYTHONPATH=src:. python -m benchmarks.run            # quick (scaled) pass
  PYTHONPATH=src:. python -m benchmarks.run --full     # paper-scale sweeps
  PYTHONPATH=src:. python -m benchmarks.run --backend=array   # array-native
  PYTHONPATH=src:. python -m benchmarks.run --smoke    # CI smoke (tiny scale)

``--backend=array`` runs the microbenchmark AND the compiled TPC-H
multi-table sweeps on the vmap-able array substrate
(``repro.core.array_sim``) for EVERY registered array policy — the
paper's full four-way comparison (lru / cscan / pbm / opt), policy lists
derived from ``repro.core.policy_registry`` — with the same CSV/JSON row
schema, and measures batched (vmapped) buffer sweeps against sequential
event-engine runs of the same points (micro + TPC-H races).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

_here = os.path.dirname(__file__)
sys.path.insert(0, os.path.join(_here, "..", "src"))
sys.path.insert(0, os.path.join(_here, ".."))

RESULTS_DIR = os.path.join(_here, "..", "experiments", "results")


def _csv(name: str, us: float, derived) -> None:
    print(f"{name},{us:.1f},{derived}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sweeps (minutes); default is a scaled "
                         "quick pass")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: quick scale, buffer sweep only — every "
                         "buffer point runs on both backends (the array "
                         "step's plan-trigger semantics need no envelope "
                         "skips)")
    ap.add_argument("--backend", choices=["event", "array"], default="event",
                    help="microbenchmark backend: dict/heapq event engine "
                         "or the vmap-able array substrate")
    ap.add_argument("--stepper", choices=["fixed", "horizon"],
                    default="horizon",
                    help="array time engine for the sweep rows (the races "
                         "measure both; horizon is the default lane)")
    ap.add_argument("--mesh", choices=["auto", "off"], default="auto",
                    help="lane-sharded execution for array sweeps/races: "
                         "expose up to 8 XLA host devices and shard_map "
                         "batched lanes across them (auto), or keep the "
                         "pre-PR-5 one-device batch (off)")
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args()
    os.makedirs(RESULTS_DIR, exist_ok=True)
    scale = 1.0 if args.full else 0.25
    sweeps = ("buffer",) if args.smoke else ("buffer", "bandwidth", "streams")
    if args.backend == "array" and args.mesh == "auto":
        # lane-sharded execution: expose up to 8 XLA host devices BEFORE
        # jax initialises, so batched sweeps/races can spread lanes
        # across them via shard_map (more devices than cores on small
        # boxes — short lanes free their cores to the long ones)
        from benchmarks.tpch import setup_lane_devices
        setup_lane_devices()

    from benchmarks import microbench, tpch, sharing, serving_bench, data_bench

    # run-level provenance: one manifest for the whole artifact directory
    # (each row also carries its own — this one records the driver flags)
    from repro.obs import manifest as run_manifest
    with open(os.path.join(RESULTS_DIR, "run_manifest.json"), "w") as f:
        json.dump(run_manifest.collect(
            backend=args.backend, stepper=args.stepper, scale=scale,
            smoke=args.smoke, sweeps=list(sweeps)), f, indent=2)

    print("# === microbenchmark (paper Figs 11-13) ===", file=sys.stderr)
    rows = []
    if args.backend == "array":
        print("# backend=array: all four paper policies "
              f"({', '.join(microbench.ARRAY_POLICIES)}) on "
              "repro.core.array_sim", file=sys.stderr)
        for s in sweeps:
            rows.extend(microbench.sweep_array(
                s, microbench.ARRAY_POLICIES, scale=scale,
                stepper=args.stepper))
    else:
        for s in sweeps:
            rows.extend(microbench.sweep(s, microbench.POLICIES, scale=scale))
    # per-backend filename: CI runs both backends back to back and uploads
    # everything, so neither run may clobber the other's rows
    micro_name = "micro_array.json" if args.backend == "array" else "micro.json"
    with open(os.path.join(RESULTS_DIR, micro_name), "w") as f:
        json.dump(rows, f, indent=2)
    for r in rows:
        _csv(
            f"micro_{r['sweep']}_{r['point']}_{r['policy']}",
            r["avg_stream_time_s"] * 1e6,
            r["io_gb"],
        )
    if args.backend == "array":
        print("# === batched (vmapped) sweep vs sequential event engine ===",
              file=sys.stderr)
        race = microbench.batched_buffer_race(scale=scale)
        with open(os.path.join(RESULTS_DIR, "batched_race.json"), "w") as f:
            json.dump(race, f, indent=2)
        _csv("micro_batched_sweep_pbm",
             race["array_vmapped_wall_s"] * 1e6, race["speedup"])

    print("# === TPC-H throughput (paper Figs 14-16) ===", file=sys.stderr)
    rows = []
    if args.backend == "array":
        # the compiled multi-table workload on the vmap-able substrate:
        # every (policy x point) lane of a sweep is ONE batched call.
        # TPC-H array rows run at the tpch smoke scale under --smoke (the
        # event engine handles 0.25 in CI; the batched step's CPU cost
        # does not yet) — trend.py compares like against like across runs.
        tpch_scale = tpch.SMOKE_SCALE if args.smoke else scale
        for s in sweeps:
            # --smoke uses the coarse 2-page step (the races' fast mode):
            # the four-policy 24-lane vmapped sweep stays in the CI budget
            rows.extend(tpch.sweep_array(
                s, tpch.ARRAY_POLICIES, scale=tpch_scale,
                step_pages=2.0 if args.smoke else 1.0,
                stepper=args.stepper, mesh=args.mesh == "auto"))
        tpch_name = "tpch_array.json"
    else:
        for s in sweeps:
            rows.extend(tpch.sweep(s, tpch.POLICIES, scale=scale))
        tpch_name = "tpch.json"
    with open(os.path.join(RESULTS_DIR, tpch_name), "w") as f:
        json.dump(rows, f, indent=2)
    for r in rows:
        _csv(
            f"{r['sweep']}_{r['point']}_{r['policy']}",
            r["avg_stream_time_s"] * 1e6,
            r["io_gb"],
        )
    if args.backend == "array":
        print("# === TPC-H batched (vmapped) sweep vs event engine ===",
              file=sys.stderr)
        race = tpch.batched_tpch_race(scale=tpch_scale)
        with open(os.path.join(RESULTS_DIR, "tpch_race.json"), "w") as f:
            json.dump(race, f, indent=2)
        _csv("tpch_batched_sweep_pbm",
             race["array_vmapped_wall_s"] * 1e6, race["speedup"])

    print("# === sharing potential (paper Figs 17-18) ===", file=sys.stderr)
    srows = [sharing.analyse("micro", scale), sharing.analyse("tpch", scale)]
    with open(os.path.join(RESULTS_DIR, "sharing.json"), "w") as f:
        json.dump(srows, f, indent=2)
    for r in srows:
        _csv(f"sharing_{r['workload']}", 0.0, r["reusable_fraction"])

    print("# === serving KV-tier policies (registry) ===", file=sys.stderr)
    # concurrent-load harness, policy list from the registry's serving
    # capability; --smoke keeps the pool_pages axis only (the CI lane)
    vrows = serving_bench.sweep(smoke=args.smoke)
    with open(os.path.join(RESULTS_DIR, "serving_bench.json"), "w") as f:
        json.dump(vrows, f, indent=2)
    for r in vrows:
        _csv(f"serve_{r['sweep']}_{r['point']}_{r['policy']}",
             r["p95_token_gap"] * 1e6, r["swap_gb"])

    print("# === data-pipeline cache (framework) ===", file=sys.stderr)
    drows = [data_bench.run_policy(p) for p in ("lru", "pbm", "opt")]
    with open(os.path.join(RESULTS_DIR, "data.json"), "w") as f:
        json.dump(drows, f, indent=2)
    for r in drows:
        _csv(f"datacache_{r['policy']}", r["miss_pages"] * 1e6, r["hit_rate"])

    if not args.skip_roofline:
        print("# === roofline (from dry-run artifacts) ===", file=sys.stderr)
        try:
            from benchmarks import roofline

            rrows = roofline.run()
            with open(os.path.join(RESULTS_DIR, "roofline.json"), "w") as f:
                json.dump(rrows, f, indent=2)
            for r in rrows:
                if r.get("dominant") == "SKIPPED":
                    continue
                _csv(
                    f"roofline_{r['arch']}_{r['shape']}",
                    r["bound_s"] * 1e6,
                    f"{r['roofline_frac']:.4f}",
                )
        except Exception as e:  # noqa: BLE001
            print(f"# roofline unavailable: {e}", file=sys.stderr)


if __name__ == "__main__":
    main()
