"""Sharing-potential analysis — paper Figures 17, 18.

Samples, over simulated time, how many bytes are wanted by exactly k active
scans (k = 1, 2, 3, 4+) in the microbenchmark vs the TPC-H run — the
paper's explanation for why PBM ~= CScans on TPC-H (low reuse potential)
but not under extreme pressure in the microbenchmark (high potential).
"""

from __future__ import annotations

import argparse
import json
from typing import Dict, List

from repro.core import EngineConfig, run_workload
from repro.core.stats import sharing_potential
from repro.core.workload import (
    make_lineitem_db, make_tpch_db,
    micro_accessed_bytes, micro_streams,
    tpch_accessed_bytes, tpch_streams,
)


def analyse(which: str, scale: float = 1.0) -> Dict:
    if which == "micro":
        db = make_lineitem_db(scale_tuples=int(180_000_000 * scale))
        streams = micro_streams(db, n_streams=8, queries_per_stream=16, seed=3)
        ws = micro_accessed_bytes(db)
        cfg = EngineConfig(bandwidth=700e6, buffer_bytes=int(0.4 * ws),
                           sample_interval=1.0)
    else:
        db = make_tpch_db(scale=scale)
        streams = tpch_streams(db, n_streams=8, seed=7)
        ws = tpch_accessed_bytes(db, streams)
        cfg = EngineConfig(bandwidth=600e6, buffer_bytes=int(0.3 * ws),
                           sample_interval=2.0)
    r = run_workload(db, streams, "pbm", cfg)
    sp = sharing_potential(r)
    total = sum(sp.by_count.values()) or 1.0
    return {
        "workload": which,
        "bytes_by_scan_count": {str(k): round(v / 1e6, 1) for k, v in sp.by_count.items()},
        "fraction_by_scan_count": {
            str(k): round(v / total, 3) for k, v in sp.by_count.items()
        },
        "reusable_fraction": round(sp.reusable_fraction, 3),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    rows = [analyse("micro", args.scale), analyse("tpch", args.scale)]
    for r in rows:
        print(f"  sharing/{r['workload']:5s} reusable={r['reusable_fraction']:.1%} "
              f"by_count={r['fraction_by_scan_count']}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=2)


if __name__ == "__main__":
    main()
