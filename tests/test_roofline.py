"""HLO trip-count accounting (benchmarks.hlo_analysis) validation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.hlo_analysis import HloModule


def _totals(fn, *args):
    from repro.launch.dryrun import cost_analysis_dict

    c = jax.jit(fn).lower(*args).compile()
    mod = HloModule(c.as_text())
    return mod.totals(), cost_analysis_dict(c)


A = jax.ShapeDtypeStruct((256, 256), jnp.float32)
FL = 2 * 256 ** 3


def test_matches_xla_on_loop_free():
    t, ca = _totals(lambda a: a @ a, A)
    assert abs(t["flops"] - ca["flops"]) / ca["flops"] < 1e-6


def test_scan_scaled_by_trip_count():
    def f(a):
        return jax.lax.scan(lambda c, _: (c @ a, None), a, None, length=7)[0]
    t, ca = _totals(f, A)
    assert abs(t["flops"] - 7 * FL) / (7 * FL) < 0.01
    assert ca["flops"] < t["flops"]  # XLA counts the body once


def test_nested_scan_compose():
    def f(a):
        def outer(c, _):
            d = jax.lax.scan(lambda x, _: (x @ a, None), c, None, length=5)[0]
            return d, None
        return jax.lax.scan(outer, a, None, length=3)[0]
    t, _ = _totals(f, A)
    assert abs(t["flops"] - 15 * FL) / (15 * FL) < 0.01


def test_grad_scan_counts_fwd_and_bwd():
    def loss(a):
        out = jax.lax.scan(lambda c, _: (jnp.tanh(c @ a), None), a, None,
                           length=4)[0]
        return out.sum()
    t, _ = _totals(jax.grad(loss), A)
    # 1 fwd dot + 2 bwd dots per layer = 12 dots
    assert abs(t["flops"] - 12 * FL) / (12 * FL) < 0.02


def test_collectives_counted_with_trips():
    mesh = jax.make_mesh((1,), ("x",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    def f(a):
        def body(c, _):
            s = jax.lax.psum(c, "x")
            return s @ a, None
        return jax.lax.scan(body, a, None, length=3)[0]

    from functools import partial
    from jax.experimental.shard_map import shard_map
    fn = shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(),
                   check_rep=False)
    c = jax.jit(fn).lower(jnp.ones((64, 64))).compile()
    mod = HloModule(c.as_text())
    t = mod.totals()
    # 3 iterations x all-reduce of a 64x64 f32 (single device still emits it
    # or folds it; accept either zero or 3x shape bytes)
    if t["collective_total"]:
        assert t["collective_total"] in (3 * 64 * 64 * 4, 64 * 64 * 4 * 3)


def test_bytes_nonzero_and_scale_with_trips():
    def f1(a):
        return jax.lax.scan(lambda c, _: (c @ a, None), a, None, length=2)[0]
    def f2(a):
        return jax.lax.scan(lambda c, _: (c @ a, None), a, None, length=8)[0]
    t1, _ = _totals(f1, A)
    t2, _ = _totals(f2, A)
    assert t2["bytes"] > 2.5 * t1["bytes"]
