"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + properties.

All kernels run in interpret mode (CPU executes the kernel body in Python);
on TPU the same pallas_call lowers to Mosaic.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: property tests need it
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.mamba2_scan import mamba2_scan_kernel
from repro.kernels.mlstm import mlstm_chunked_kernel
from repro.kernels.paged_attention import paged_attention_kernel
from repro.kernels import ref
from repro.models.ssm import ssd_chunked
from repro.models.xlstm import gla_chunked

RNG = np.random.default_rng(0)


def randn(*shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(0, scale, shape), dtype)


# ------------------------------------------------------- paged attention ---

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,Hk,dh,page_size,pages_per_seq",
    [
        (2, 4, 1, 128, 16, 2),     # MQA
        (4, 8, 2, 128, 16, 4),     # GQA 4:1
        (1, 2, 2, 256, 8, 3),      # MHA, gemma head_dim
        (3, 6, 2, 128, 32, 2),     # qwen-like 3:1
    ],
)
def test_paged_attention_sweep(B, H, Hk, dh, page_size, pages_per_seq, dtype):
    n_pages = B * pages_per_seq + 4
    q = randn(B, H, dh, dtype=dtype)
    kp = randn(n_pages, page_size, Hk, dh, dtype=dtype, scale=0.5)
    vp = randn(n_pages, page_size, Hk, dh, dtype=dtype, scale=0.5)
    pt = jnp.asarray(
        RNG.permutation(n_pages)[: B * pages_per_seq].reshape(B, pages_per_seq),
        jnp.int32,
    )
    sl = jnp.asarray(
        RNG.integers(1, pages_per_seq * page_size + 1, B), jnp.int32
    )
    out = paged_attention_kernel(q, kp, vp, pt, sl, interpret=True)
    exp = ref.paged_attention_ref(q, kp, vp, pt, sl)
    atol = 3e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32), atol=atol,
        rtol=atol,
    )


@settings(max_examples=12, deadline=None)
@given(
    st.integers(1, 3), st.integers(1, 2), st.integers(1, 3), st.integers(1, 4),
    st.randoms(),
)
def test_paged_attention_property(b, hk, g, pages, rnd):
    """Random GQA ratios, page tables and ragged lengths agree with oracle."""
    h = hk * g
    dh, page_size = 128, 8
    n_pages = b * pages + 2
    rng = np.random.default_rng(rnd.randrange(1 << 30))
    q = jnp.asarray(rng.normal(size=(b, h, dh)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(n_pages, page_size, hk, dh)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(n_pages, page_size, hk, dh)), jnp.float32)
    pt = jnp.asarray(rng.permutation(n_pages)[: b * pages].reshape(b, pages),
                     jnp.int32)
    sl = jnp.asarray(rng.integers(1, pages * page_size + 1, b), jnp.int32)
    out = paged_attention_kernel(q, kp, vp, pt, sl, interpret=True)
    exp = ref.paged_attention_ref(q, kp, vp, pt, sl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=5e-5,
                               rtol=5e-5)


# ------------------------------------------------------- flash attention ---

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "T,S,window,causal",
    [(128, 128, None, True), (192, 192, 64, True), (96, 96, None, False),
     (130, 130, 32, True)],
)
def test_flash_attention_sweep(T, S, window, causal, dtype):
    q = randn(2, T, 4, 128, dtype=dtype, scale=0.5)
    k = randn(2, S, 4, 128, dtype=dtype, scale=0.5)
    v = randn(2, S, 4, 128, dtype=dtype, scale=0.5)
    out = flash_attention_kernel(q, k, v, causal=causal, window=window,
                                 block_q=64, block_kv=64, interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    atol = 3e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=atol, rtol=atol)


def test_flash_matches_blocked_reference_train_path():
    from repro.models.attention import blocked_attention
    q = randn(1, 160, 2, 128, scale=0.5)
    k = randn(1, 160, 2, 128, scale=0.5)
    v = randn(1, 160, 2, 128, scale=0.5)
    a = blocked_attention(q, k, v, causal=True)
    b = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5, rtol=3e-5)


# ------------------------------------------------------------ mamba2 scan --

@pytest.mark.parametrize("T,chunk", [(128, 64), (100, 32), (256, 128)])
def test_mamba2_scan_kernel(T, chunk):
    B, H, P, N = 2, 2, 64, 64
    xh = randn(B, T, H, P, scale=0.5)
    a = jnp.asarray(RNG.uniform(0.6, 1.0, (B, T, H)), jnp.float32)
    b = randn(B, T, N, scale=0.3)
    c = randn(B, T, N, scale=0.3)
    yk = mamba2_scan_kernel(xh, a, b, c, chunk=chunk, interpret=True)
    yr, _ = ref.mamba2_scan_ref(xh, a, b, c)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), atol=2e-3,
                               rtol=2e-3)


def test_ssd_chunked_jnp_matches_sequential():
    B, T, H, P, N = 2, 192, 3, 32, 16
    xh = randn(B, T, H, P, scale=0.5)
    a = jnp.asarray(RNG.uniform(0.7, 1.0, (B, T, H)), jnp.float32)
    b = randn(B, T, N, scale=0.3)
    c = randn(B, T, N, scale=0.3)
    yj, hj = ssd_chunked(xh, a, b, c, chunk=64)
    yr, hr = ref.mamba2_scan_ref(xh, a, b, c)
    np.testing.assert_allclose(np.asarray(yj), np.asarray(yr), atol=2e-3,
                               rtol=2e-3)
    np.testing.assert_allclose(np.asarray(hj), np.asarray(hr), atol=2e-3,
                               rtol=2e-3)


@settings(max_examples=8, deadline=None)
@given(st.integers(16, 80), st.integers(1, 3), st.randoms())
def test_mamba2_state_carry_property(T, B, rnd):
    """Chunked scan's final state equals the sequential recurrence's."""
    rng = np.random.default_rng(rnd.randrange(1 << 30))
    H, P, N = 2, 16, 8
    xh = jnp.asarray(rng.normal(0, 0.5, (B, T, H, P)), jnp.float32)
    a = jnp.asarray(rng.uniform(0.6, 1.0, (B, T, H)), jnp.float32)
    b = jnp.asarray(rng.normal(0, 0.3, (B, T, N)), jnp.float32)
    c = jnp.asarray(rng.normal(0, 0.3, (B, T, N)), jnp.float32)
    _, h1 = ssd_chunked(xh, a, b, c, chunk=32)
    _, h2 = ref.mamba2_scan_ref(xh, a, b, c)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=2e-3,
                               rtol=2e-3)


# ---------------------------------------------------------------- mLSTM ----

@pytest.mark.parametrize("T,chunk", [(128, 64), (96, 32)])
def test_mlstm_kernel(T, chunk):
    B, H, K, P = 2, 2, 64, 64
    q = randn(B, T, H, K)
    k = randn(B, T, H, K, scale=0.3)
    v = randn(B, T, H, P)
    a = jnp.asarray(RNG.uniform(0.7, 1.0, (B, T, H)), jnp.float32)
    i = jnp.asarray(RNG.uniform(0.1, 1.0, (B, T, H)), jnp.float32)
    yk = mlstm_chunked_kernel(q, k, v, a, i, chunk=chunk, interpret=True)
    yr = ref.gla_ref(q, k, v, a, i)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), atol=2e-3,
                               rtol=2e-3)


def test_gla_chunked_jnp_matches_sequential():
    B, T, H, K, P = 1, 160, 2, 32, 32
    q = randn(B, T, H, K)
    k = randn(B, T, H, K, scale=0.3)
    v = randn(B, T, H, P)
    a = jnp.asarray(RNG.uniform(0.8, 1.0, (B, T, H)), jnp.float32)
    i = jnp.asarray(RNG.uniform(0.1, 1.0, (B, T, H)), jnp.float32)
    yj, _, _ = gla_chunked(q, k, v, a, i, chunk=64)
    yr = ref.gla_ref(q, k, v, a, i)
    np.testing.assert_allclose(np.asarray(yj), np.asarray(yr), atol=2e-3,
                               rtol=2e-3)
