"""The policy registry: one policy surface for all three backends.

Completeness (every registered name resolves on each backend it
declares — the CI registry check), the stable array-id contract,
helpful unknown-name errors, registry-derived benchmark policy lists,
and the hard errors that replaced the pre-registry kwarg shims.
"""

import pytest

from repro.core import EngineConfig, policy_registry
from repro.core.policies.base import Policy


def test_registry_completeness_every_name_resolves():
    """Every registered policy resolves on BOTH backends, or is
    explicitly single-backend (its entry declares so) — nothing may be
    silently broken on either engine."""
    cfg = EngineConfig()
    for name in policy_registry.names():
        entry = policy_registry.get(name)
        assert entry.backends, name
        if "event" in entry.backends:
            pol, coop = policy_registry.event_policy(name, cfg)
            assert coop == entry.cooperative
            if not coop:
                assert isinstance(pol, Policy), name
        if "array" in entry.backends:
            ap = policy_registry.array_policy(name)
            assert ap.name == name
            assert entry.array_id is not None
        else:
            # explicitly event-only: the array resolver must say so
            with pytest.raises(KeyError, match="event-engine-only"):
                policy_registry.array_policy(name)
    assert policy_registry._check(verbose=False) == 0


def test_paper_comparison_runs_on_all_backends():
    """The paper's four-way comparison is fully capable on every
    backend — event, array, AND the serving path: no policy of
    Figs 9-16 is engine-only anywhere."""
    paper = policy_registry.names(paper_only=True)
    assert paper == ["lru", "cscan", "pbm", "opt"]
    for name in paper:
        assert set(policy_registry.get(name).backends) == {
            "event", "array", "serving"}


def test_array_ids_are_the_stable_contract():
    """lru=0 / pbm=1 predate the registry (result JSONs carry them);
    cscan/opt extend the space without renumbering."""
    ids = policy_registry.array_ids()
    assert ids["lru"] == 0 and ids["pbm"] == 1
    assert ids["cscan"] == 2 and ids["opt"] == 3
    for name, pid in ids.items():
        assert policy_registry.array_name(pid) == name
    assert policy_registry.array_name(999) is None


def test_unknown_names_list_registered_policies():
    with pytest.raises(KeyError, match="registered policies"):
        policy_registry.get("belady2000")
    with pytest.raises(KeyError, match="registered policies"):
        policy_registry.event_policy("nope", EngineConfig())
    # event-only names get a targeted error from the array side
    with pytest.raises(KeyError, match="event-engine-only"):
        policy_registry.array_policy("mru")
    # ... and from the array config constructor
    from repro.core.array_sim import make_config
    from repro.core.pages import Database
    from repro.core.scans import ScanSpec
    from repro.core.array_sim import build_spec
    db = Database()
    db.add_table("t", 10_000, {"c": 2.0}, page_bytes=1 << 14)
    spec = build_spec(db, [[ScanSpec("t", ("c",), ((0, 10_000),))]])
    with pytest.raises(KeyError, match="event-engine-only"):
        make_config(spec, 1 << 20, policy="mru")


def test_benchmark_policy_lists_derive_from_registry():
    from benchmarks import microbench, tpch

    assert microbench.POLICIES == policy_registry.names(
        backend="event", paper_only=True)
    assert tpch.POLICIES == microbench.POLICIES
    assert microbench.ARRAY_POLICIES == policy_registry.names(
        backend="array")
    assert tpch.ARRAY_POLICIES == microbench.ARRAY_POLICIES
    assert set(microbench.EXTENDED) == {"mru", "pbm_lru", "attach"}


def test_config_outside_compiled_policy_set_truncates_not_mislabels():
    """A config whose policy id is not in the runner's compiled set must
    NOT silently run as some other policy (a mislabeled lane in a stacked
    sweep would be wrong science): the lane trips the livelock guard on
    its first step and reports ``truncated`` with zero I/O."""
    jax = pytest.importorskip("jax")
    from repro.core.pages import Database
    from repro.core.scans import ScanSpec
    from repro.core.array_sim import (
        build_spec, make_config, make_runner, result_from_state,
    )

    db = Database()
    db.add_table("t", 50_000, {"c": 2.0}, page_bytes=1 << 14)
    spec = build_spec(db, [[ScanSpec("t", ("c",), ((0, 50_000),))]])
    runner = make_runner(spec, time_slice=0.01, policies=("lru", "pbm"))
    bad = jax.block_until_ready(runner(make_config(spec, 1 << 20, policy="opt")))
    r = result_from_state(bad, "opt")
    assert r.extras["truncated"] and r.total_io_bytes == 0.0
    good = jax.block_until_ready(runner(make_config(spec, 1 << 20, policy="lru")))
    assert not result_from_state(good, "lru").extras["truncated"]


def test_pre_registry_spellings_are_hard_errors():
    """The deprecation shims are gone: ``static_policy=`` on make_runner
    and integer policy ids on make_config raise TypeError with a pointer
    at the registry surface — not a warning, not a silent reroute."""
    pytest.importorskip("jax")
    from repro.core.pages import Database
    from repro.core.scans import ScanSpec
    from repro.core.array_sim import (
        build_spec, make_config, make_runner,
    )

    db = Database()
    db.add_table("t", 50_000, {"c": 2.0}, page_bytes=1 << 14)
    spec = build_spec(db, [[ScanSpec("t", ("c",), ((0, 50_000),))]])
    with pytest.raises(TypeError, match="policy_registry"):
        make_runner(spec, time_slice=0.01, static_policy="pbm")
    with pytest.raises(TypeError, match="policy_registry"):
        make_runner(spec, time_slice=0.01, static_policy=None)
    with pytest.raises(TypeError, match="registry name"):
        make_config(spec, 1 << 20, policy=1)
    # the registry spelling is the one that works
    cfg = make_config(spec, 1 << 20, policy="pbm")
    assert int(cfg.policy) == policy_registry.array_ids()["pbm"]


def test_serving_policy_resolves_through_registry():
    """Every serving-capable name builds a ServingPolicy whose ``name``
    round-trips; non-serving names fail with the capable list."""
    from repro.serving import ServingPolicy

    serving = policy_registry.names(backend="serving")
    assert serving == ["lru", "cscan", "pbm", "opt"]
    for name in serving:
        pol = policy_registry.serving_policy(name)
        assert isinstance(pol, ServingPolicy) and pol.name == name
    with pytest.raises(KeyError, match="serving-capable"):
        policy_registry.serving_policy("mru")
    with pytest.raises(KeyError, match="registered policies"):
        policy_registry.serving_policy("belady2000")
