"""Kernel contract verifier tests (DESIGN.md §9).

Three layers of assurance, mirroring the verifier's own structure:

* the shipped tree is clean — ``verify_kernels()`` returns no findings
  (this is the CI gate's kernel half);
* every rule class fires on a seeded violation: the AST rules on
  virtual kernel sources, the abstract-interpretation rules on toy
  ``pl.pallas_call`` wrappers built to violate exactly one contract
  each (BlockSpec coverage, index bounds, write races, VMEM budget);
* the kernels the verifier guards actually match their oracles:
  a numpy-seeded differential fuzz asserts EXACT agreement between the
  interpret-mode Pallas kernels and the ``ref.py`` oracles for
  ``batched_evict`` / ``fifo_grant`` across random shapes (including
  P not a multiple of 128), ``vmax`` smaller than the victim count,
  zero budgets and all-ineligible pools — plus the 2^24 integer-key
  regression the ``kernel-float-mantissa-cast`` rule pins.

The fuzz layer is deterministic (seeded ``numpy.random.Generator``) so
CI failures reproduce; when ``hypothesis`` is installed an extra
property-based pass widens the shape coverage.
"""

from __future__ import annotations

import textwrap

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402
from jax.experimental import pallas as pl  # noqa: E402
from jax.experimental.pallas import tpu as pltpu  # noqa: E402

from repro.analysis import lint_source, verify_kernels  # noqa: E402
from repro.analysis.absint import capture_calls, check_call  # noqa: E402
from repro.analysis.kernels import (  # noqa: E402
    KernelContract,
    check_contracts,
    kernel_lint_source,
)
from repro.kernels import ops, ref  # noqa: E402
from repro.kernels.pbm_timeline import (  # noqa: E402
    batched_evict_kernel,
    fifo_grant_kernel,
)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # hypothesis is optional in the test image
    HAVE_HYPOTHESIS = False


def rules(findings):
    return sorted({f.rule for f in findings})


# ---------------------------------------------------------------------------
# the gate: shipped tree is clean
# ---------------------------------------------------------------------------

def test_shipped_kernels_are_clean():
    findings = verify_kernels()
    assert findings == [], "\n".join(f.format() for f in findings)


def test_stale_contract_is_a_finding():
    # a contract whose wrapper never reaches pl.pallas_call is itself a
    # finding — the table must not rot as kernels change
    def build():
        return (lambda x: x + 1, (jnp.ones(4),), {})

    fs = check_contracts([KernelContract("stale", build)])
    assert rules(fs) == ["kernel-contract-error"]
    assert "no pallas_call" in fs[0].message


def test_crashing_wrapper_is_a_finding():
    def build():
        def wrapper():
            raise RuntimeError("boom")
        return (wrapper, (), {})

    fs = check_contracts([KernelContract("crash", build)])
    assert rules(fs) == ["kernel-contract-error"]
    assert "boom" in fs[0].message


# ---------------------------------------------------------------------------
# layer 1: AST rules on seeded virtual sources
# ---------------------------------------------------------------------------

def test_blockspec_without_memory_space_flagged():
    src = textwrap.dedent("""
        def _body(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        def toy_kernel(x):
            return pl.pallas_call(
                _body,
                out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
                in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
                out_specs=pl.BlockSpec(
                    (8, 128), lambda i: (i, 0), memory_space=pltpu.VMEM),
                grid=(4,),
            )(x)
    """)
    fs = kernel_lint_source(src, "repro/kernels/toy.py", {"toy_ref"})
    assert rules(fs) == ["kernel-memory-space"]
    assert len(fs) == 1  # only the undeclared in_spec, not the out_spec


def test_mxu_without_preferred_element_type_flagged():
    src = textwrap.dedent("""
        def _body(x_ref, o_ref):
            a = x_ref[...]
            o_ref[...] = jnp.dot(a, a)
            o_ref[...] += jax.lax.dot_general(a, a, (((1,), (0,)), ((), ())))
    """)
    fs = kernel_lint_source(src, "repro/kernels/toy.py", None)
    assert rules(fs) == ["kernel-mxu-element-type"]
    assert len(fs) == 2


def test_mxu_with_preferred_element_type_clean():
    src = textwrap.dedent("""
        def _body(x_ref, o_ref):
            a = x_ref[...]
            o_ref[...] = jnp.dot(a, a, preferred_element_type=jnp.float32)
    """)
    assert kernel_lint_source(src, "repro/kernels/toy.py", None) == []


def test_unconditional_float_key_cast_flagged():
    # the exact bug class this PR fixed in batched_evict_kernel
    src = textwrap.dedent("""
        def toy_kernel(key, sizes):
            key_row = key.reshape(1, -1).astype(jnp.float32)
            return key_row
    """)
    fs = kernel_lint_source(src, "repro/kernels/toy.py", {"toy_ref"})
    assert rules(fs) == ["kernel-float-mantissa-cast"]
    assert "2^24" in fs[0].message


def test_dispatched_float_key_cast_clean():
    # the sanctioned pattern: dtype dispatch keeps integers on an i32 path
    src = textwrap.dedent("""
        def toy_kernel(key, sizes):
            int_key = bool(jnp.issubdtype(key.dtype, jnp.integer))
            key_row = (key.astype(jnp.int32) if int_key
                       else key.astype(jnp.float32))
            return key_row
    """)
    assert kernel_lint_source(src, "repro/kernels/toy.py", {"toy_ref"}) == []


def test_missing_oracle_flagged():
    src = "def orphan_kernel(x):\n    return x\n"
    fs = kernel_lint_source(src, "repro/kernels/toy.py", {"toy_ref"})
    assert rules(fs) == ["kernel-missing-oracle"]


def test_oracle_pragma_satisfies_pairing():
    src = ("# analysis: oracle=toy_ref\n"
           "def orphan_kernel(x):\n    return x\n")
    assert kernel_lint_source(src, "repro/kernels/toy.py", {"toy_ref"}) == []


def test_oracle_pragma_naming_missing_ref_flagged():
    src = ("# analysis: oracle=ghost_ref\n"
           "def orphan_kernel(x):\n    return x\n")
    fs = kernel_lint_source(src, "repro/kernels/toy.py", {"toy_ref"})
    assert rules(fs) == ["kernel-missing-oracle"]
    assert "ghost_ref" in fs[0].message


def test_unknown_analysis_pragma_flagged():
    src = ("def helper(x):  # analysis: hosted\n"
           "    return x\n")
    fs = lint_source(src, "repro/obs/toy.py")
    assert "unknown-analysis-pragma" in rules(fs)


def test_known_pragmas_not_flagged():
    src = ("# analysis: host\n"
           "def helper(x):\n"
           "    return x  # analysis: revisit is mentioned fine elsewhere\n")
    fs = lint_source(src, "repro/obs/toy.py")
    assert "unknown-analysis-pragma" not in rules(fs)


# ---------------------------------------------------------------------------
# layer 2: abstract interpretation on seeded toy wrappers
# ---------------------------------------------------------------------------

def _copy_body(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def _captured(wrapper, *operands):
    calls = []
    with capture_calls(calls):
        wrapper(*operands)
    assert calls, "toy wrapper made no pallas_call"
    return calls


def _toy_call(in_shape, out_shape, grid, in_spec, out_spec, kernel=None):
    x = jnp.zeros(in_shape, jnp.float32)

    def wrapper(x):
        return pl.pallas_call(
            kernel or _copy_body,
            out_shape=jax.ShapeDtypeStruct(out_shape, jnp.float32),
            in_specs=[in_spec],
            out_specs=out_spec,
            grid=grid,
        )(x)

    return _captured(wrapper, x)[0]


def test_block_not_dividing_operand_flagged():
    call = _toy_call(
        (100, 128), (100, 128), (2,),
        pl.BlockSpec((48, 128), lambda i: (i, 0), memory_space=pltpu.VMEM),
        pl.BlockSpec((48, 128), lambda i: (i, 0), memory_space=pltpu.VMEM),
    )
    fs = check_call(call)
    assert "kernel-block-coverage" in rules(fs)
    assert any("does not divide" in f.message for f in fs)


def test_index_map_out_of_bounds_flagged():
    call = _toy_call(
        (4, 128), (4, 128), (4,),
        pl.BlockSpec((1, 128), lambda i: (i + 1, 0),  # last point OOB
                     memory_space=pltpu.VMEM),
        pl.BlockSpec((1, 128), lambda i: (i, 0), memory_space=pltpu.VMEM),
    )
    fs = check_call(call)
    assert "kernel-index-oob" in rules(fs)


def test_output_block_never_written_flagged():
    call = _toy_call(
        (4, 128), (4, 128), (2,),
        pl.BlockSpec((1, 128), lambda i: (i, 0), memory_space=pltpu.VMEM),
        pl.BlockSpec((1, 128), lambda i: (i, 0),  # blocks 2, 3 unwritten
                     memory_space=pltpu.VMEM),
    )
    fs = check_call(call)
    assert any(f.rule == "kernel-block-coverage"
               and "never written" in f.message for f in fs)


def test_unguarded_output_revisit_flagged():
    call = _toy_call(
        (4, 128), (1, 128), (4,),
        pl.BlockSpec((1, 128), lambda i: (i, 0), memory_space=pltpu.VMEM),
        pl.BlockSpec((1, 128), lambda i: (0, 0),  # every point, same block
                     memory_space=pltpu.VMEM),
    )
    fs = check_call(call)
    assert "kernel-write-race" in rules(fs)


def test_when_guarded_revisit_sanctioned():
    def guarded(x_ref, o_ref):
        i = pl.program_id(0)

        @pl.when(i == 3)
        def commit():
            o_ref[...] = x_ref[...]

    call = _toy_call(
        (4, 128), (1, 128), (4,),
        pl.BlockSpec((1, 128), lambda i: (i, 0), memory_space=pltpu.VMEM),
        pl.BlockSpec((1, 128), lambda i: (0, 0), memory_space=pltpu.VMEM),
        kernel=guarded,
    )
    assert "kernel-write-race" not in rules(check_call(call))


def test_revisit_pragma_sanctions():
    def blessed(x_ref, o_ref):  # analysis: revisit
        o_ref[...] = x_ref[...]

    call = _toy_call(
        (4, 128), (1, 128), (4,),
        pl.BlockSpec((1, 128), lambda i: (i, 0), memory_space=pltpu.VMEM),
        pl.BlockSpec((1, 128), lambda i: (0, 0), memory_space=pltpu.VMEM),
        kernel=blessed,
    )
    assert "kernel-write-race" not in rules(check_call(call))


def test_vmem_budget_exceeded_flagged():
    call = _toy_call(
        (1024, 1024), (1024, 1024), (2,),
        pl.BlockSpec((512, 1024), lambda i: (i, 0), memory_space=pltpu.VMEM),
        pl.BlockSpec((512, 1024), lambda i: (i, 0), memory_space=pltpu.VMEM),
    )
    # two f32 (512, 1024) blocks, double-buffered = 8 MiB: fine at the
    # default 16 MiB budget, over a 4 MiB one
    assert "kernel-vmem-budget" not in rules(check_call(call))
    fs = check_call(call, vmem_budget=4 * 1024 * 1024)
    assert "kernel-vmem-budget" in rules(fs)


def test_scalar_block_on_vmem_flagged():
    x = jnp.zeros((1, 1), jnp.float32)

    def wrapper(x):
        return pl.pallas_call(
            _copy_body,
            out_shape=jax.ShapeDtypeStruct((1, 128), jnp.float32),
            in_specs=[pl.BlockSpec(memory_space=pltpu.VMEM)],  # scalar!
            out_specs=pl.BlockSpec(memory_space=pltpu.VMEM),
        )(x)

    fs = check_call(_captured(wrapper, x)[0])
    assert "kernel-memory-space" in rules(fs)
    assert any("SMEM" in f.message for f in fs)


def test_dense_block_on_smem_flagged():
    x = jnp.zeros((1, 256), jnp.float32)

    def wrapper(x):
        return pl.pallas_call(
            _copy_body,
            out_shape=jax.ShapeDtypeStruct((1, 256), jnp.float32),
            in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM)],  # dense row!
            out_specs=pl.BlockSpec(memory_space=pltpu.VMEM),
        )(x)

    fs = check_call(_captured(wrapper, x)[0])
    assert "kernel-memory-space" in rules(fs)
    assert any("VMEM" in f.message for f in fs)


# ---------------------------------------------------------------------------
# the registry UX satellite: set_backend validates at set time
# ---------------------------------------------------------------------------

def test_set_backend_rejects_unknown_name():
    with pytest.raises(ValueError, match="valid backends"):
        ops.set_backend("mosaic")
    assert ops.get_backend() == "auto"  # the bad set did not stick


def test_set_backend_accepts_known_names():
    try:
        for name in ops.BACKENDS:
            ops.set_backend(name)
            assert ops.get_backend() == name
    finally:
        ops.set_backend("auto")


# ---------------------------------------------------------------------------
# differential fuzz: interpret-mode kernels == oracles, EXACTLY
# ---------------------------------------------------------------------------
# Sizes are integer-valued f32 and keys stay within [-2^30, 2^30), so
# every sum the kernels take (MXU prefix bytes vs the oracle's cumsum)
# is exact in f32 — any mismatch is a real semantics bug, not rounding.

def _evict_case(rng, P, *, int_keys, all_ineligible=False, zero_need=False,
                vmax=None):
    if int_keys:
        key = jnp.asarray(
            rng.integers(-2**30, 2**30, P, dtype=np.int64), jnp.int32)
    else:
        # integer-valued floats with deliberate ties (tie-break by index)
        key = jnp.asarray(rng.integers(-50, 50, P), jnp.float32)
    sizes = jnp.asarray(rng.integers(1, 9, P), jnp.float32)
    if all_ineligible:
        evictable = jnp.zeros(P, bool)
    else:
        evictable = jnp.asarray(rng.random(P) < 0.6)
    need = jnp.float32(0.0 if zero_need
                       else float(rng.integers(1, 5 * P // 2)))
    vmax = vmax if vmax is not None else int(rng.integers(1, P + 1))
    return key, sizes, evictable, need, vmax


def _assert_evict_agrees(key, sizes, evictable, need, vmax):
    got = batched_evict_kernel(key, sizes, evictable, need,
                               vmax=vmax, interpret=True)
    want = ref.batched_evict_ref(key, sizes, evictable, need, vmax=vmax)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_batched_evict_fuzz_matches_ref_exactly():
    rng = np.random.default_rng(0)
    # P deliberately includes non-multiples of 128 (interpret mode takes
    # them; the array sim pads — the kernel must not depend on padding)
    for P in (8, 100, 128, 200, 256):
        for int_keys in (False, True):
            _assert_evict_agrees(*_evict_case(rng, P, int_keys=int_keys))


def test_batched_evict_vmax_below_victim_count():
    rng = np.random.default_rng(1)
    for trial in range(4):
        key, sizes, evictable, _, _ = _evict_case(rng, 128, int_keys=False)
        # demand more bytes than vmax candidates can ever free
        _assert_evict_agrees(key, sizes, evictable, jnp.float32(1e6), 7)


def test_batched_evict_edge_cases():
    rng = np.random.default_rng(2)
    _assert_evict_agrees(*_evict_case(rng, 64, int_keys=True,
                                      all_ineligible=True))
    _assert_evict_agrees(*_evict_case(rng, 64, int_keys=False,
                                      zero_need=True))
    _assert_evict_agrees(*_evict_case(rng, 1, int_keys=True))


def test_batched_evict_integer_keys_beyond_2_24():
    # the regression the kernel-float-mantissa-cast rule pins: under the
    # old unconditional f32 cast, 2^24 and 2^24 + 1 collapse to the same
    # float and the WRONG page wins the eviction pop
    key = jnp.asarray([2**24, 2**24 + 1, 0, 0], jnp.int32)
    sizes = jnp.ones(4, jnp.float32)
    evictable = jnp.ones(4, bool)
    got = batched_evict_kernel(key, sizes, evictable, jnp.float32(1.0),
                               vmax=4, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(got), [False, True, False, False])
    _assert_evict_agrees(key, sizes, evictable, jnp.float32(1.0), 4)
    # wide OPT-style distances, dense around the mantissa edge
    rng = np.random.default_rng(3)
    wide = jnp.asarray(2**24 + rng.integers(0, 64, 128), jnp.int32)
    szs = jnp.asarray(rng.integers(1, 4, 128), jnp.float32)
    ev = jnp.asarray(rng.random(128) < 0.8)
    _assert_evict_agrees(wide, szs, ev, jnp.float32(40.0), 32)


def _grant_case(rng, P, *, zero_budget=False, none_wanted=False):
    key = jnp.asarray(rng.integers(-1, 2**29, P, dtype=np.int64), jnp.int32)
    if none_wanted:
        key = jnp.full((P,), -1, jnp.int32)
    sizes = jnp.asarray(rng.integers(1, 9, P), jnp.float32)
    budget = jnp.float32(0.0 if zero_budget
                         else float(rng.integers(1, 4 * P)))
    pops = jnp.int32(int(rng.integers(1, 33)))
    vmax = int(rng.integers(1, P + 1))
    return key, sizes, budget, pops, vmax


def _assert_grant_agrees(key, sizes, budget, pops, vmax):
    g_mask, g_bytes, g_n = fifo_grant_kernel(key, sizes, budget, pops,
                                             vmax=vmax, interpret=True)
    w_mask, w_bytes, w_n = ref.fifo_grant_ref(key, sizes, budget, pops,
                                              vmax=vmax)
    np.testing.assert_array_equal(np.asarray(g_mask), np.asarray(w_mask))
    np.testing.assert_array_equal(np.asarray(g_bytes), np.asarray(w_bytes))
    np.testing.assert_array_equal(np.asarray(g_n), np.asarray(w_n))


def test_fifo_grant_fuzz_matches_ref_exactly():
    rng = np.random.default_rng(4)
    for P in (8, 100, 128, 200):
        _assert_grant_agrees(*_grant_case(rng, P))


def test_fifo_grant_edge_cases():
    rng = np.random.default_rng(5)
    _assert_grant_agrees(*_grant_case(rng, 64, zero_budget=True))
    _assert_grant_agrees(*_grant_case(rng, 64, none_wanted=True))
    _assert_grant_agrees(*_grant_case(rng, 1))


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(
        P=st.integers(min_value=1, max_value=160),
        int_keys=st.booleans(),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_batched_evict_hypothesis(P, int_keys, seed):
        rng = np.random.default_rng(seed)
        _assert_evict_agrees(*_evict_case(rng, P, int_keys=int_keys))

    @settings(max_examples=25, deadline=None)
    @given(
        P=st.integers(min_value=1, max_value=160),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_fifo_grant_hypothesis(P, seed):
        rng = np.random.default_rng(seed)
        _assert_grant_agrees(*_grant_case(rng, P))
