"""Telemetry tier (PR 8): counters, flight recorder, provenance, lint.

The contracts under test, in acceptance order:

* ``make_runner(telemetry=False)`` output is BIT-EQUAL to the
  pre-telemetry program for every registry policy on both steppers (the
  static knob compiles to nothing when off);
* ``telemetry=True`` adds zero jit traces — the counters are ordinary
  carry leaves, so the one-trace-per-runner contract holds unchanged;
* the flight recorder (``TraceSession``) reconstructs, from per-step
  residency diffs, the SAME eviction count the carried counter reports
  (exactly) and the event engine reports (within the validation bars);
* a ``jax.debug.print`` seeded into a policy hook is caught by the
  ``jit-host-callback`` lint rule, and ``# analysis: obs`` escapes it;
* every RunManifest carries the attribution fields trend.py needs;
* ``ServingEngine`` structured events agree with ``EngineStats``.
"""

import textwrap

import numpy as np
import pytest

from repro.analysis import lint_source
from repro.core import EngineConfig, run_workload
from repro.core.array_sim import (
    build_spec,
    make_config,
    make_runner,
    run_workload_array,
)
from repro.core.workload import (
    make_lineitem_db,
    micro_accessed_bytes,
    micro_streams,
)
from repro.obs import collect_manifest, counters, spec_hash
from repro.obs.trace import TraceSession, serving_events_to_chrome

TRACED_REL = "repro/core/array_sim/policies.py"


def _lint(src: str, rel: str = TRACED_REL):
    return [f.rule for f in lint_source(textwrap.dedent(src), rel)]


def _tiny_point():
    db = make_lineitem_db(scale_tuples=2_000_000)
    streams = micro_streams(db, n_streams=2, queries_per_stream=1, seed=3)
    return db, streams, build_spec(db, streams), 16 << 20


def _micro_point(scale=0.1, frac=0.4):
    """The trace CLI's default point (repro.obs.trace main())."""
    db = make_lineitem_db(scale_tuples=int(6_001_215 * scale))
    streams = micro_streams(db, n_streams=4, queries_per_stream=4, seed=3)
    spec = build_spec(db, streams)
    cap = max(1 << 22, int(frac * micro_accessed_bytes(db)))
    return db, streams, spec, cap


# ------------------------------------------------ tier 1: carried counters --

@pytest.mark.parametrize("stepper", ["fixed", "horizon"])
def test_telemetry_off_bit_equal_and_on_adds_no_trace(stepper):
    """The static-knob contract, all four registry policies x both
    steppers: the off path's SimState is bit-equal to the on path's, and
    each runner still traces exactly once across the whole policy sweep."""
    from repro.core import policy_registry

    _, _, spec, cap = _tiny_point()
    base = make_runner(spec, bandwidth_ref=700e6, time_slice=0.01,
                       stepper=stepper)
    teler = make_runner(spec, bandwidth_ref=700e6, time_slice=0.01,
                        stepper=stepper, telemetry=True)
    assert teler.telemetry is True and base.telemetry is False
    for pol in policy_registry.names(backend="array"):
        cfg = make_config(spec, cap, 700e6, pol)
        st0 = base(cfg)
        st1, tele = teler(cfg)
        for name in st0._fields:
            if name == "pstate":
                continue  # nested per-policy tuple, compared below
            np.testing.assert_array_equal(
                np.asarray(getattr(st0, name)), np.asarray(getattr(st1, name)),
                err_msg=f"{stepper}/{pol}/{name}")
        import jax
        for a, b in zip(jax.tree.leaves(st0.pstate),
                        jax.tree.leaves(st1.pstate)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # the counters themselves agree with the state's own ground truth
        assert int(tele.loads) == int(st1.loads), (stepper, pol)
        assert int(tele.hits) + int(tele.misses) > 0, (stepper, pol)
    assert base.trace_count() == 1, stepper
    assert teler.trace_count() == 1, stepper


def test_workload_result_carries_telemetry_summary():
    db, streams, spec, cap = _tiny_point()
    res = run_workload_array(db, streams, "pbm", capacity_bytes=cap,
                             time_slice=0.01, spec=spec, stepper="horizon",
                             telemetry=True)
    t = res.extras["telemetry"]
    assert 0.0 <= t["hit_rate"] <= 1.0
    assert t["loads"] >= t["misses"] >= 0
    assert len(t["jump_hist"]) == counters.N_BINS
    # the horizon stepper must have jumped at least once somewhere past
    # bin 0 OR done everything in single fine steps — either way the
    # histogram mass equals the macro-step count
    assert sum(t["jump_hist"]) == res.extras.get("macro_steps", res.steps)
    assert "pbm" in t.get("policy_obs", {}) or t["hits"] == 0


# --------------------------------------------- tier 2: the flight recorder --

def test_trace_reconstructs_eviction_counts():
    """Acceptance: the exported Perfetto trace for the default micro
    point reconstructs the eviction count (a) exactly equal to the
    carried counter, and (b) equal to the event engine's
    ``total_evictions`` within the existing validation bars."""
    from repro.core.array_sim.validate import ERROR_BARS

    db, streams, spec, cap = _micro_point()
    sess = TraceSession(spec, policies=("pbm",))
    state = sess.run(make_config(spec, cap, 700e6, "pbm"))
    te = sess.eviction_total()
    assert te > 0, "micro point must induce evictions to test anything"

    # (a) exact agreement with the carried counter: same compiled step,
    # host-looped vs device-looped
    runner = make_runner(spec, bandwidth_ref=700e6, time_slice=0.1,
                         policies=("pbm",), stepper="horizon",
                         telemetry=True)
    st, tele = runner(make_config(spec, cap, 700e6, "pbm"))
    assert int(tele.evictions) == te
    assert float(st.t) == float(state.t)

    # (b) event engine within the validated envelope
    ev = run_workload(db, streams, "pbm", EngineConfig(
        bandwidth=700e6, buffer_bytes=cap, sample_interval=2.0,
        pbm_time_slice=0.1))
    bar = ERROR_BARS[(0.4, "pbm")]
    assert abs(te - ev.total_evictions) <= max(2, bar * ev.total_evictions), (
        f"trace={te} event={ev.total_evictions}")

    # the chrome export carries the same per-step numbers it was built from
    chrome = sess.to_chrome()
    xs = [e for e in chrome["traceEvents"] if e["ph"] == "X"]
    assert sum(e["args"]["evicted"] for e in xs) == te
    assert all(e["dur"] > 0 for e in xs)
    assert any(e["ph"] == "C" for e in chrome["traceEvents"])


def test_trace_session_fixed_stepper_runs():
    _, _, spec, cap = _tiny_point()
    sess = TraceSession(spec, policies=("lru",), stepper="fixed",
                        time_slice=0.01)
    sess.run(make_config(spec, cap, 700e6, "lru"))
    assert sess.events
    assert all(e["kind"] in ("fine", "refresh") for e in sess.events)


# ------------------------------------------------------- tier 3: manifest --

def test_manifest_fields_and_spec_hash():
    _, _, spec, cap = _tiny_point()
    runner = make_runner(spec, bandwidth_ref=700e6, time_slice=0.01,
                         stepper="horizon")
    runner(make_config(spec, cap, 700e6, "pbm"))
    man = collect_manifest(spec=spec, runner=runner, extra_key="x")
    for key in ("git_sha", "python", "jax", "jaxlib", "platform"):
        assert man[key], key
    assert man["spec_hash"] == spec_hash(spec)
    assert len(man["spec_hash"]) == 12
    assert man["stepper"] == "horizon"
    assert man["sanitize"] is False
    assert man["trace_count"] == 1
    assert man["extra_key"] == "x"
    # content hash: a different workload hashes differently
    _, _, spec2, _ = _micro_point(scale=0.02)
    assert spec_hash(spec2) != man["spec_hash"]


# ------------------------------------------- the jit-host-callback lint rule --

def test_debug_print_in_policy_hook_is_flagged():
    rules = _lint("""
        import jax
        class P:
            def score_victims(self, pstate, ctx):
                jax.debug.print("score={x}", x=pstate)
                return pstate
    """)
    assert "jit-host-callback" in rules


def test_obs_pragma_escapes_callback_ban_only():
    rules = _lint("""
        import jax
        # analysis: obs
        def key_of(pstate, ctx):
            jax.debug.print("k={x}", x=pstate)
            return pstate
    """)
    assert "jit-host-callback" not in rules
    # the escape is scoped: purity rules still apply under the pragma
    rules = _lint("""
        import jax
        # analysis: obs
        def key_of(pstate, ctx):
            jax.debug.print("k={x}", x=pstate)
            return float(pstate)
    """)
    assert "jit-coercion" in rules


def test_callback_spellings_are_all_caught():
    rules = _lint("""
        import jax
        from jax import debug
        from jax.experimental import io_callback, host_callback

        def key_of(pstate, ctx):
            debug.print("{x}", x=pstate)
            jax.pure_callback(lambda x: x, pstate, pstate)
            io_callback(lambda x: x, pstate, pstate)
            host_callback.id_print(pstate)
            return pstate
    """)
    assert rules.count("jit-host-callback") == 4


def test_obs_counters_module_is_a_traced_region():
    rules = _lint("""
        import jax
        def count(c, event):
            jax.debug.print("{c}", c=c)
            return c
    """, rel="repro/obs/counters.py")
    assert "jit-host-callback" in rules


# ------------------------------------------------- serving structured events --

def test_serving_events_agree_with_stats():
    from benchmarks.serving_bench import DEFAULT_POINT, run_policy

    events = []
    row = run_policy("pbm", record_events=True, events_out=events,
                     **DEFAULT_POINT)
    assert events, "oversubscribed default point must preempt"
    kinds = {}
    for e in events:
        kinds[e["kind"]] = kinds.get(e["kind"], 0) + 1
        assert e["policy"] == "pbm"
        assert "step" in e and "rid" in e
    assert kinds.get("preempt", 0) == row["preemptions"]
    assert kinds.get("resume", 0) == row["resumes"]
    prefetched = sum(1 for e in events
                     if e["kind"] == "resume" and e.get("prefetched"))
    assert prefetched == row["prefetched_resumes"]
    chrome = serving_events_to_chrome(events, label="test")
    assert (sum(1 for e in chrome["traceEvents"] if e["ph"] == "i")
            == len(events))
    assert row["manifest"]["git_sha"]


def test_serving_events_off_by_default():
    from repro.serving import PagePool, ServingEngine

    pool = PagePool(n_pages=8, page_size=4, page_bytes=1024)
    eng = ServingEngine(pool, lambda reqs: [0] * len(reqs), policy="lru")
    assert eng.record_events is False
    eng._emit("admit")
    assert eng.events == []
