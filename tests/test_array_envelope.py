"""Small-pool envelope regression tests for the array backend.

PR 2 ported the event engine's per-page plan-trigger semantics into the
batched step: a scan blocks per column at the first absent trigger instead
of needing every under-cursor page resident, which unlocks the paper's
headline small-buffer operating points (10-40% of the accessed working
set).  These tests pin that envelope:

* array-vs-event parity at the newly-unlocked 10% and 20% buffer points
  for LRU and PBM (bars from ``validate.ERROR_BARS`` — 10% everywhere
  except the documented 13% LRU deep-thrash residual at 10% buffer);
* the full microbenchmark buffer sweep emits a row for every point with
  zero envelope skips and zero truncated runs;
* the ``max_time`` livelock guard marks truncated runs instead of
  silently reporting them as complete;
* ``build_spec`` rejects zero-page columns with a clear error.
"""

import pytest

from repro.core.scans import ScanSpec
from repro.core.workload import Q6_COLUMNS, make_lineitem_db
from repro.core.array_sim import build_spec, run_workload_array
from repro.core.array_sim.validate import ERROR_BARS, cross_validate_sweep


# ------------------------------------------------ small-pool parity -------

def test_small_pool_parity_lru_and_pbm():
    """Array LRU/PBM within the validated error bars of the event engine
    at the 10% and 20% buffer points (quick-pass scale) — the operating
    range where PBM's Belady approximation beats LRU hardest and where
    the pre-PR-2 array model could not run at all."""
    rows = cross_validate_sweep(fracs=(0.1, 0.2), scale=0.25,
                                policies=("lru", "pbm"))
    assert len(rows) == 4
    for r in rows:
        bar = ERROR_BARS[(r["buffer_frac"], r["policy"])]
        assert not r["truncated"], r
        assert abs(r["stream_time_rel_err"]) <= bar, r
        assert abs(r["io_rel_err"]) <= bar, r
    # the paper's ordering must hold where buffer management matters most:
    # PBM beats LRU at both small pools, in both simulators
    by = {(r["buffer_frac"], r["policy"]): r for r in rows}
    for f in (0.1, 0.2):
        assert by[(f, "pbm")]["array_stream_time_s"] < \
            by[(f, "lru")]["array_stream_time_s"]
        assert by[(f, "pbm")]["event_stream_time_s"] < \
            by[(f, "lru")]["event_stream_time_s"]


# ------------------------------------------- sweep has every point --------

def test_buffer_sweep_covers_all_paper_fractions():
    """``sweep_array("buffer", ...)`` emits rows for every buffer point —
    including the paper fractions 0.1/0.2/0.4 that the old all-columns-
    resident model skipped — with no truncated runs."""
    from benchmarks import microbench

    rows = microbench.sweep_array("buffer", ["pbm"], scale=0.1)
    points = sorted({r["point"] for r in rows})
    assert points == [0.1, 0.2, 0.4, 0.6, 0.8, 1.0]
    for frac in (0.1, 0.2, 0.4, 0.6):
        assert any(r["point"] == frac for r in rows), frac
    for r in rows:
        assert not r.get("truncated"), r
        assert r["avg_stream_time_s"] > 0
        assert r["io_gb"] > 0


# ------------------------------------------------ truncation flag ---------

def test_livelock_guard_sets_truncated_flag():
    """A run cut short by ``max_time`` reports ``extras['truncated']``
    and the unfinished-stream count instead of posing as complete."""
    db = make_lineitem_db(scale_tuples=2_000_000)
    spec = ScanSpec("lineitem", Q6_COLUMNS, ((0, 2_000_000),),
                    tuple_rate=240e6)
    r = run_workload_array(db, [[spec]], "lru", capacity_bytes=64 << 20,
                           bandwidth=700e6, time_slice=0.005,
                           max_time=1e-3)
    assert r.extras["truncated"] is True
    assert r.extras["unfinished_streams"] == 1
    ok = run_workload_array(db, [[spec]], "lru", capacity_bytes=64 << 20,
                            bandwidth=700e6, time_slice=0.005)
    assert ok.extras["truncated"] is False
    assert ok.extras["unfinished_streams"] == 0


# ------------------------------------------------ build_spec guard --------

def test_build_spec_rejects_zero_page_column():
    db = make_lineitem_db(scale_tuples=1_000_000)
    db.tables["lineitem"].columns["l_tax"].pages = []
    spec = ScanSpec("lineitem", ("l_quantity",), ((0, 1_000_000),))
    with pytest.raises(ValueError, match="lineitem.l_tax"):
        build_spec(db, [[spec]])
