"""Substrate contract checker: both directions.

Green direction — the shipped tree lints clean (jit-purity, deprecated
surfaces, registry coherence) and every runner traces exactly once.
Red direction — seeded violations (a ``float()`` in ``score_victims``,
Python ``if`` on a traced value, a resurrected ``static_policy=``, a
PolicyEntry claiming a backend it does not implement, a forced retrace
under ``sanitize=True``) each produce the specific finding or error.
"""

import json
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import pytest

from repro.analysis import check_registry, lint_source, run_checks
from repro.core.policy_registry import PolicyEntry
from repro.core.workload import make_lineitem_db, micro_streams
from repro.core.array_sim import (
    build_spec,
    make_config,
    make_runner,
    result_from_state,
)

TRACED_REL = "repro/core/array_sim/policies.py"


def _lint(src: str, rel: str = TRACED_REL):
    return lint_source(textwrap.dedent(src), rel)


def _rules(findings):
    return [f.rule for f in findings]


# ------------------------------------------------------- green direction --

def test_shipped_tree_is_clean():
    """The acceptance gate: zero findings on the tree as shipped."""
    findings = run_checks()
    assert findings == [], "\n".join(f.format() for f in findings)


def test_registry_is_coherent():
    assert check_registry() == []


# ------------------------------------------------- seeded jit violations --

def test_float_on_traced_score_is_flagged():
    findings = _lint("""
        class P:
            def score_victims(self, state, ctx):
                score = state.last_used + 1.0
                return float(score)
    """)
    assert _rules(findings) == ["jit-coercion"]
    assert findings[0].line == 5
    assert findings[0].path == TRACED_REL


def test_python_if_on_traced_value_is_flagged():
    findings = _lint("""
        def hook(state, ctx):
            if state.clock > 0:
                return state
            return state
    """)
    assert _rules(findings) == ["jit-control-flow"]


def test_static_branches_are_not_flagged():
    """`ctx.refresh`, `x is None`, isinstance, and shape metadata are
    static under tracing — the exact idioms the substrate relies on."""
    findings = _lint("""
        def hook(state, ctx, extra=None):
            if ctx.refresh:
                state = state + 1
            if extra is None:
                extra = 0
            if isinstance(state, tuple):
                pass
            if state.shape[0] > 4:
                pass
            return state + extra
    """)
    assert findings == []


def test_host_module_call_is_flagged_but_constants_are_not():
    findings = _lint("""
        def hook(state, ctx):
            lo = np.inf
            return np.median(state) + lo
    """)
    assert _rules(findings) == ["jit-host-call"]


def test_item_materialisation_is_flagged():
    findings = _lint("""
        def hook(state, ctx):
            return state.clock.item()
    """)
    assert _rules(findings) == ["jit-coercion"]


def test_loop_over_traced_array_is_flagged():
    findings = _lint("""
        def hook(state, ctx):
            acc = 0
            for v in state.last_used:
                acc = acc + v
            for _ in range(state.n_live):
                acc = acc + 1
            return acc
    """)
    assert _rules(findings) == ["jit-control-flow", "jit-control-flow"]


def test_loop_over_python_container_of_traced_leaves_is_fine():
    findings = _lint("""
        def hook(state, ctx):
            leaves = [state.a, state.b]
            acc = 0
            for v in leaves:
                acc = acc + v
            return acc
    """)
    assert findings == []


def test_pragma_host_opts_out():
    findings = _lint("""
        def geometry(db, tnames):  # analysis: host
            return float(db.total_bytes)
    """)
    assert findings == []


def test_kernels_kwonly_params_are_static():
    """The Pallas compile-time-knob idiom: kwonly params may branch;
    positional (traced) params may not."""
    findings = _lint("""
        def kernel(x, *, block):
            if block > 8:
                x = x * 2
            if x.sum() > 0:
                x = x + 1
            return x
    """, rel="repro/kernels/fused.py")
    assert _rules(findings) == ["jit-control-flow"]
    assert findings[0].line == 5


# ---------------------------------------------------- deprecated surfaces --

def test_static_policy_keyword_is_flagged():
    findings = _lint("""
        r = make_runner(spec, static_policy=my_policy)
    """, rel="repro/extras/runner_glue.py")
    assert _rules(findings) == ["deprecated-static-policy"]


def test_int_policy_id_is_flagged():
    findings = _lint("""
        cfg = make_config(spec, cap, bw, policy=3)
        cfgs = stack(spec, policies=[0, 1])
    """, rel="repro/extras/runner_glue.py")
    assert _rules(findings) == [
        "deprecated-int-policy-id", "deprecated-int-policy-id",
    ]


def test_time_passed_is_flagged():
    findings = _lint("""
        def report(state):
            return state.time_passed
    """, rel="repro/extras/report.py")
    assert _rules(findings) == ["deprecated-time-passed"]


# ---------------------------------------------------- registry coherence --

def test_entry_claiming_serving_without_implementation():
    """A PolicyEntry whose serving_factory builds an object that never
    overrides ServingPolicy.victim_key is a finding, not a runtime
    NotImplementedError mid-sweep."""
    from repro.serving.policy_driver import ServingPolicy

    class Hollow(ServingPolicy):
        name = "bogus"

    entry = PolicyEntry(name="bogus", summary="claims serving, does not",
                        serving_factory=Hollow)
    findings = check_registry({"bogus": entry})
    assert len(findings) == 1
    assert "victim_key" in findings[0].message


def test_entry_with_mislabeled_array_policy():
    from repro.core.array_sim.policies import ArrayPolicy

    class Mislabeled(ArrayPolicy):
        name = "other"

        def score_victims(self, state, ctx):
            return state.last_used

    entry = PolicyEntry(name="bogus", summary="name mismatch",
                        array_factory=Mislabeled, array_id=99)
    findings = check_registry({"bogus": entry})
    assert len(findings) == 1
    assert "reports name" in findings[0].message


# ------------------------------------------------------------------- CLI --

def test_cli_check_reports_seeded_violation(tmp_path):
    """`python -m repro.analysis --check --root <bad tree>` exits nonzero
    with a file:line finding and a JSON artifact; the shipped tree (the
    default root) is covered by test_shipped_tree_is_clean + CI."""
    bad = tmp_path / "kernels"
    bad.mkdir()
    (bad / "bad_kernel.py").write_text(
        "def k(x):\n    return float(x)\n", encoding="utf-8")
    out = tmp_path / "findings.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--check", "--no-registry",
         "--root", str(tmp_path), "--json", str(out)],
        capture_output=True, text=True,
    )
    assert proc.returncode == 1, proc.stdout + proc.stderr
    assert "bad_kernel.py:2" in proc.stdout
    assert "jit-coercion" in proc.stdout
    payload = json.loads(out.read_text())
    assert payload["count"] == 1
    assert payload["findings"][0]["rule"] == "jit-coercion"


# ----------------------------------------- trace counting / sanitize mode --

def _tiny_point():
    db = make_lineitem_db(scale_tuples=2_000_000)
    streams = micro_streams(db, n_streams=2, queries_per_stream=1, seed=3)
    spec = build_spec(db, streams)
    cap = 16 << 20
    return spec, cap


@pytest.mark.parametrize("stepper", ["fixed", "horizon"])
def test_one_trace_per_runner_across_all_policies(stepper):
    """The recompile contract: one runner serves every registered array
    policy (the policy id is a traced leaf) with exactly ONE jit trace —
    a retrace on a policy switch would mean a static leak in the step."""
    from repro.core import policy_registry

    spec, cap = _tiny_point()
    runner = make_runner(spec, bandwidth_ref=700e6, time_slice=0.01,
                         stepper=stepper)
    assert runner.trace_count() == 0
    for pol in policy_registry.names(backend="array"):
        state = runner(make_config(spec, cap, 700e6, pol))
        res = result_from_state(state, pol, dt_ref=runner.dt_ref)
        assert not res.extras["truncated"], (stepper, pol)
    assert runner.trace_count() == 1, (
        f"{stepper}: {runner.trace_count()} traces across the policy sweep")
    # same shapes/dtypes again: still no retrace
    runner(make_config(spec, cap, 700e6, "lru"))
    assert runner.trace_count() == 1


def test_sanitize_runner_passes_and_counts_one_trace():
    spec, cap = _tiny_point()
    runner = make_runner(spec, bandwidth_ref=700e6, time_slice=0.01,
                         sanitize=True)
    state = runner(make_config(spec, cap, 700e6, "pbm"))
    res = result_from_state(state, "pbm", dt_ref=runner.dt_ref)
    assert not res.extras["truncated"]
    assert runner.sanitize is True
    assert runner.trace_count() == 1


def test_sanitize_retrace_is_a_hard_error():
    """Changing a leaf dtype forces a second trace of the same runner —
    under sanitize=True that is a RuntimeError, not a silent recompile."""
    spec, cap = _tiny_point()
    runner = make_runner(spec, bandwidth_ref=700e6, time_slice=0.01,
                         sanitize=True)
    cfg = make_config(spec, cap, 700e6, "lru")
    runner(cfg)
    assert runner.trace_count() == 1
    retraced = cfg._replace(capacity_bytes=jnp.int32(cap))
    with pytest.raises(RuntimeError, match="jit traces for one runner"):
        runner(retraced)


def test_sanitize_rejects_mesh():
    spec, _ = _tiny_point()
    with pytest.raises(ValueError, match="sanitize"):
        make_runner(spec, bandwidth_ref=700e6, time_slice=0.01,
                    sanitize=True, mesh=object())
