"""The serving policy surface: registry-driven KV-page tiering.

PagePool edge cases the refcounted prefix index must survive (release
order, full-pool swap round-trips, alloc against a host-resident prefix),
ServingEngine policy resolution through ``repro.core.policy_registry``,
the forward-progress resume fallback, and the paper-ordering acceptance
run at the benchmark's default operating point: PBM strictly beats LRU on
swap volume with OPT bounding both.
"""

import pytest

from repro.core import policy_registry
from repro.serving import (
    PagePool, PolicyDriver, Request, RequestKV, ServingEngine, prefix_hash,
)


def _stub(reqs):
    return [7 for _ in reqs]


# ------------------------------------------------------------ page pool ---

def test_prefix_release_order_any_interleaving():
    """Shared prefix pages survive any release order: the last holder's
    release frees the slot and drops the index entry, earlier releases
    only decrement."""
    pool = PagePool(n_pages=8, page_size=4, page_bytes=64)
    h = prefix_hash(list(range(4)))
    a = pool.alloc(prefix_hash=h)
    b = pool.alloc(prefix_hash=h)
    c = pool.alloc(prefix_hash=h)
    assert a == b == c and pool.meta[a].ref_count == 3
    assert pool.free_count == 7
    pool.release(b)
    pool.release(a)
    assert pool.meta[a].ref_count == 1
    assert pool.prefix_index[h] == a      # still indexed while held
    pool.release(c)
    assert a not in pool.meta
    assert h not in pool.prefix_index     # last release drops the entry
    assert pool.free_count == 8


def test_swap_round_trip_with_full_pool():
    """swap_out frees slots that new allocs may take; swap_in then fails
    cleanly (None, no partial state) until room exists, and the returned
    pages keep their content identity (same meta object, new slot)."""
    pool = PagePool(n_pages=4, page_size=4, page_bytes=64)
    held = [pool.alloc() for _ in range(4)]
    assert pool.free_count == 0 and pool.alloc() is None
    mapping = pool.swap_out(held[:2])
    uids = [mapping[p] for p in held[:2]]
    assert all(u < 0 for u in uids) and pool.free_count == 2
    filler = [pool.alloc(), pool.alloc()]     # pool full again
    assert pool.swap_in(uids) is None         # no room: clean refusal
    assert all(pool.meta[u].on_host for u in uids)
    for p in filler:
        pool.release(p)
    back = pool.swap_in(uids)
    assert back is not None and len(back) == 2
    assert all(not pool.meta[s].on_host for s in back.values())
    assert pool.swap_in_bytes == pool.swap_out_bytes == 2 * 64


def test_alloc_on_host_resident_prefix_takes_fresh_page():
    """A prefix page spilled to host must NOT be handed out by alloc (its
    content is not in HBM): a new request for the same prefix gets a fresh
    page, and the returning host copy keeps its own identity."""
    pool = PagePool(n_pages=4, page_size=4, page_bytes=64)
    h = prefix_hash([1, 2, 3, 4])
    first = pool.alloc(prefix_hash=h)
    mapping = pool.swap_out([first])
    uid = mapping[first]
    fresh = pool.alloc(prefix_hash=h)
    assert fresh is not None and fresh != uid
    assert pool.meta[fresh].ref_count == 1    # no sharing with a host copy
    assert pool.prefix_index[h] == fresh
    back = pool.swap_in([uid])
    slot = back[uid]
    # the established mapping wins; the returned copy serves its own owner
    assert pool.prefix_index[h] == fresh and slot != fresh
    pool.release(slot)
    assert h in pool.prefix_index             # fresh page still indexed
    pool.release(fresh)
    assert h not in pool.prefix_index


# ------------------------------------------------ registry resolution -----

def test_engine_resolves_policy_strings_via_registry():
    for name in policy_registry.names(backend="serving"):
        pool = PagePool(n_pages=16, page_size=4, page_bytes=64)
        eng = ServingEngine(pool, _stub, policy=name, max_batch=4)
        assert eng.policy == name
        assert eng.driver.policy.name == name


def test_engine_rejects_unknown_and_non_serving_names():
    pool = PagePool(n_pages=16, page_size=4, page_bytes=64)
    with pytest.raises(KeyError, match="registered policies"):
        ServingEngine(pool, _stub, policy="belady")
    with pytest.raises(KeyError, match="serving-capable"):
        ServingEngine(pool, _stub, policy="mru")


# ------------------------------------------------- engine behaviour -------

def test_resume_falls_through_policy_order_on_empty_machine():
    """Forward progress when the preferred resume does not fit: with no
    active requests and the nearest-completion candidate's host pages
    exceeding free HBM, the engine resumes the next candidate in policy
    order instead of wedging (the OPT deadlock regression)."""
    pool = PagePool(n_pages=10, page_size=4, page_bytes=64)
    eng = ServingEngine(pool, _stub, policy="opt", max_batch=2)
    big = Request(prompt=list(range(4)), max_new_tokens=2)
    small = Request(prompt=[9, 9, 9, 9], max_new_tokens=40)
    for r, npages in ((big, 9), (small, 1)):
        kv = RequestKV(pool, pool.page_size)
        assert kv.attach_prefix(r.prompt) >= 0
        assert kv.append_tokens(4 * (npages - 1))
        r.kv = kv
        eng.active.append(r)
    # preempt both by hand so the machine is empty
    for r in (big, small):
        eng.active.remove(r)
        r.swapped = True
        mapping = pool.swap_out(r.kv.pages)
        r.kv.pages = [mapping.get(p, p) for p in r.kv.pages]
        eng.swapped.append(r)
    # occupy HBM so big (9 host pages, nearest completion => preferred by
    # opt's resume order) cannot fit, but small (1 page) can
    blockers = [pool.alloc() for _ in range(8)]
    assert all(b is not None for b in blockers)
    eng._try_admit()
    assert small in eng.active and big in eng.swapped
    # order itself is still the policy's: big (2 remaining) before small
    order = eng.driver.resume_order(eng.driver.view(eng))
    assert order and order[0] is big


def test_prefetch_stages_pages_while_batch_full():
    """With a full batch and free headroom, the next resume candidate's
    host pages come back ahead of need and its resume skips swap_delay."""
    pool = PagePool(n_pages=64, page_size=4, page_bytes=64)
    eng = ServingEngine(pool, _stub, policy="pbm", max_batch=2)
    for _ in range(2):
        eng.submit(Request(prompt=[1, 2, 3, 4], max_new_tokens=30))
    eng.step()
    assert len(eng.active) == 2            # batch full
    # a previously-preempted request waits on the swapped queue
    waiting = Request(prompt=[9, 9, 9, 9], max_new_tokens=20)
    kv = RequestKV(pool, pool.page_size)
    assert kv.attach_prefix(waiting.prompt) >= 0
    assert kv.append_tokens(8)
    waiting.kv = kv
    waiting.swapped = True
    mapping = pool.swap_out(kv.pages)
    kv.pages = [mapping.get(p, p) for p in kv.pages]
    assert any(p < 0 for p in kv.pages)    # its pages live on host
    eng.swapped.append(waiting)
    eng._prefetch_ahead()
    assert waiting.prefetched
    assert all(p >= 0 for p in waiting.kv.pages)   # staged back into HBM
    while waiting not in eng.active and eng.stats.steps < 200:
        eng.step()
    assert waiting in eng.active
    assert waiting.ready_step == waiting.admitted_step  # no swap_delay paid
    assert eng.stats.prefetched_resumes == 1


def test_engine_completes_under_every_registry_policy():
    for name in policy_registry.names(backend="serving"):
        pool = PagePool(n_pages=20, page_size=8, page_bytes=128)
        eng = ServingEngine(pool, _stub, policy=name, max_batch=4)
        for _ in range(8):
            eng.submit(Request(prompt=list(range(12)), max_new_tokens=24))
        eng.run_to_completion(max_steps=5_000)
        assert len(eng.finished) == 8, name
        assert pool.free_count == pool.n_pages, name


# ------------------------------------------------- paper ordering ---------

def test_policy_ordering_at_default_operating_point():
    """The acceptance run (benchmarks/serving_bench.py DEFAULT_POINT):
    PBM strictly beats LRU on total swap volume, OPT bounds both, and no
    policy is worse than LRU on p95 token latency."""
    from benchmarks.serving_bench import DEFAULT_POINT, run_policy

    rows = {p: run_policy(p, **DEFAULT_POINT)
            for p in ("lru", "pbm", "opt")}
    n = DEFAULT_POINT["n_requests"]
    assert all(r["completed"] == n for r in rows.values())
    # swap volume: opt <= pbm < lru — prediction pays, the oracle bounds it
    assert rows["pbm"]["swap_gb"] < rows["lru"]["swap_gb"]
    assert rows["opt"]["swap_gb"] <= rows["pbm"]["swap_gb"]
    # latency tail: neither predictive policy may stall worse than LRU
    assert rows["pbm"]["p95_token_gap"] <= rows["lru"]["p95_token_gap"]
    assert rows["opt"]["p95_token_gap"] <= rows["lru"]["p95_token_gap"]
