"""Data pipeline, serving tier, checkpointing, elastic, compression tests."""

import itertools
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: property tests need it
from hypothesis import given, settings, strategies as st

from repro.data import (
    DataStream, DatasetSpec, HostPageCache, MultiStreamLoader, generate_page,
)
from repro.serving import PagePool, Request, RequestKV, ServingEngine
from repro.train.checkpoint import CheckpointManager
from repro.train.compression import compress_decompress, ef_compress
from repro.train.elastic import (
    CANDIDATE_MESHES, plan_after_failure, rebalance_microbatches,
)
from repro.train.optimizer import OptimizerConfig, init_opt_state
from repro.train.train_step import make_train_step


# ------------------------------------------------------------ dataset ------

def test_pages_deterministic():
    spec = DatasetSpec(seed=7)
    a = generate_page(spec, 3, 5)
    b = generate_page(spec, 3, 5)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (32_768,)
    assert a.min() >= 0 and a.max() < spec.vocab_size


def test_stream_restart_resumes_exactly():
    spec = DatasetSpec(n_shards=2, pages_per_shard=4)
    cache = HostPageCache(spec, capacity_pages=8)
    s = DataStream(cache, [0, 1], batch=2, seq_len=512)
    batches = [s.next_batch() for _ in range(5)]
    state = s.state_dict()
    next_expected = s.next_batch()
    # simulate restart: new cache+stream, load position
    cache2 = HostPageCache(spec, capacity_pages=8)
    s2 = DataStream(cache2, [0, 1], batch=2, seq_len=512)
    s2.load_state_dict(state)
    resumed = s2.next_batch()
    np.testing.assert_array_equal(next_expected, resumed)


def test_cache_capacity_respected():
    spec = DatasetSpec(n_shards=4, pages_per_shard=8)
    cache = HostPageCache(spec, capacity_pages=6)
    s = DataStream(cache, [0, 1, 2, 3], batch=4, seq_len=2048)
    for _ in range(100):
        s.next_batch()
    assert cache.pool.used_bytes <= cache.pool.capacity_bytes


def test_work_stealing_extends_range():
    spec = DatasetSpec(n_shards=4, pages_per_shard=2)
    cache = HostPageCache(spec, capacity_pages=8)
    loader = MultiStreamLoader(cache)
    a = DataStream(cache, [0, 1], batch=1, seq_len=128, name="a")
    b = DataStream(cache, [2, 3], batch=1, seq_len=128, name="b")
    loader.add_stream(a)
    loader.add_stream(b)
    loader.steal_from("b", "a")
    assert "b" not in loader.streams
    assert 2 in a.state.shard_order or 3 in a.state.shard_order


# ------------------------------------------------------------ serving ------

def _mk_engine(policy="pbm", pool_pages=32, page_size=16):
    pool = PagePool(n_pages=pool_pages, page_size=page_size,
                    page_bytes=page_size * 1024)
    step = lambda reqs: [7 for _ in reqs]
    return pool, ServingEngine(pool, step, policy=policy, max_batch=8)


def test_engine_completes_all_requests():
    pool, eng = _mk_engine()
    for _ in range(10):
        eng.submit(Request(prompt=list(range(40)), max_new_tokens=20))
    st_ = eng.run_to_completion(max_steps=5000)
    assert len(eng.finished) == 10
    assert all(len(r.generated) == 20 for r in eng.finished)
    # all pages returned
    assert pool.free_count == pool.n_pages


def test_prefix_pages_shared_across_requests():
    pool, eng = _mk_engine(pool_pages=64)
    common = list(range(32))  # 2 full pages at page_size=16
    for _ in range(6):
        eng.submit(Request(prompt=common + [99], max_new_tokens=4))
    eng.run_to_completion(max_steps=1000)
    assert eng.stats.shared_prefix_pages >= 5 * 2  # 5 later requests x 2 pages


def test_swap_accounting_and_pool_invariants():
    pool, eng = _mk_engine(policy="opt", pool_pages=24)
    for _ in range(12):
        eng.submit(Request(prompt=list(range(24)), max_new_tokens=60))
    st_ = eng.run_to_completion(max_steps=10_000)
    assert len(eng.finished) == 12
    assert pool.free_count == pool.n_pages
    assert pool.swap_in_bytes <= pool.swap_out_bytes
    if st_.preemptions:
        assert pool.swap_out_bytes > 0


@settings(max_examples=40, deadline=None)
@given(st.lists(st.sampled_from(["alloc", "release", "spill"]), max_size=60),
       st.randoms())
def test_pool_invariants_property(ops, rnd):
    pool = PagePool(n_pages=12, page_size=4, page_bytes=64)
    held = []
    spilled = []
    for op in ops:
        if op == "alloc":
            pid = pool.alloc()
            if pid is not None:
                held.append(pid)
        elif op == "release" and held:
            pool.release(held.pop(rnd.randrange(len(held))))
        elif op == "spill" and held:
            i = rnd.randrange(len(held))
            mapping = pool.swap_out([held[i]])
            if held[i] in mapping:
                spilled.append(mapping[held[i]])
                held.pop(i)
        # invariant: free + live HBM metas == n_pages; uids negative
        live_hbm = [p for p in pool.meta if p >= 0]
        assert len(pool.free) + len(live_hbm) == pool.n_pages
        assert all(u < 0 for u in pool.meta if pool.meta[u].on_host)


# --------------------------------------------------------- checkpoints -----

def test_checkpoint_roundtrip(tmp_path):
    params = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
              "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    opt = init_opt_state(params)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, params, opt, extra={"data": {"page": 3}})
    step, p2, o2, extra = mgr.restore(None, params, opt)
    assert step == 5
    assert extra == {"data": {"page": 3}}
    np.testing.assert_array_equal(np.asarray(params["a"]), np.asarray(p2["a"]))
    assert p2["b"]["c"].dtype == jnp.bfloat16
    assert int(o2.step) == 0


def test_checkpoint_async_and_gc(tmp_path):
    params = {"w": jnp.zeros((8, 8))}
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, params, async_=True)
        mgr.wait()
    assert mgr.all_steps() == [3, 4]


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    params = {"w": jnp.zeros((4,))}
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, params)
    assert not [d for d in os.listdir(tmp_path) if d.startswith(".tmp")]


# ------------------------------------------------------------- elastic -----

def test_plan_after_failure_prefers_largest_fit():
    assert plan_after_failure(512).chips == 512
    assert plan_after_failure(511).chips == 256
    assert plan_after_failure(300).chips == 256
    assert plan_after_failure(200).chips == 128
    assert plan_after_failure(10) is None


def test_rebalance_keeps_global_batch():
    mb = rebalance_microbatches(global_batch=256, old_dp=32, new_dp=16,
                                old_microbatches=2)
    assert mb >= 4  # per-replica tokens doubled -> microbatches at least x2


# --------------------------------------------------------- compression -----

def test_compress_decompress_bounded_error():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(0, 2, (64, 64)),
                          jnp.float32)}
    gq = compress_decompress(g)
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert float(jnp.max(jnp.abs(gq["w"] - g["w"]))) <= scale * 0.5 + 1e-6


def test_error_feedback_reduces_bias():
    rng = np.random.default_rng(1)
    g = {"w": jnp.asarray(rng.normal(0, 1, (32, 32)), jnp.float32)}
    residual = None
    acc_plain = jnp.zeros_like(g["w"])
    acc_ef = jnp.zeros_like(g["w"])
    for _ in range(50):
        acc_plain = acc_plain + compress_decompress(g)["w"]
        cq, residual = ef_compress(g, residual)
        acc_ef = acc_ef + cq["w"]
    target = g["w"] * 50
    assert float(jnp.abs(acc_ef - target).mean()) <= float(
        jnp.abs(acc_plain - target).mean()
    ) + 1e-4


# ------------------------------------------------------- training loop -----

def test_tiny_training_reduces_loss():
    from repro.configs import get_config
    from repro.models import build_model, init_params

    cfg = get_config("qwen2_1_5b", smoke=True)
    model = build_model(cfg)
    params = init_params(model.param_specs, jax.random.PRNGKey(0), jnp.float32)
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(
        model, OptimizerConfig(learning_rate=3e-3, warmup_steps=2,
                               total_steps=30)))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, 64, (4, 33)), jnp.int32)
    batch = {"tokens": toks[:, :-1]}
    losses = []
    for _ in range(15):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses


def test_grad_accumulation_matches_full_batch():
    from repro.configs import get_config
    from repro.models import build_model, init_params

    cfg = get_config("qwen2_1_5b", smoke=True)
    model = build_model(cfg)
    params = init_params(model.param_specs, jax.random.PRNGKey(1), jnp.float32)
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(2).integers(0, 64, (4, 16)), jnp.int32)}
    ocfg = OptimizerConfig(learning_rate=1e-3, warmup_steps=1, total_steps=5)
    s1 = make_train_step(model, ocfg, microbatches=1)
    s2 = make_train_step(model, ocfg, microbatches=2)
    p1, _, m1 = s1(params, init_opt_state(params), batch)
    p2, _, m2 = s2(params, init_opt_state(params), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-4)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4)
