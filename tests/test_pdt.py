"""PDT SID/RID translation (paper §2.1 Fig. 4) — unit + property tests."""

import pytest
pytest.importorskip("hypothesis")  # optional dep: property tests need it
from hypothesis import given, settings, strategies as st

from repro.core import PDT, CScanMergeState


def test_identity_when_empty():
    p = PDT(10)
    for s in range(10):
        assert p.sid_to_rid_low(s) == s
        assert p.sid_to_rid_high(s) == s
        assert p.rid_to_sid(s) == s


def test_paper_example_semantics():
    # delete sid 3; two inserts anchored at 5
    p = PDT(10)
    p.delete(3)
    p.insert(5, "a")
    p.insert(5, "b")
    assert p.n_visible == 11
    # deleted tuple: no RID maps to it, but its SID still translates to the
    # lowest RID of a higher SID (paper: one-way arrows)
    assert p.sid_to_rid_low(3) == 3
    assert p.sid_to_rid_high(3) == 3
    assert p.rid_to_sid(3) == 4            # rid 3 is stable tuple sid=4
    # inserts widen sid 5's rid range: [low, high] = [4, 6]
    assert p.sid_to_rid_low(5) == 4
    assert p.sid_to_rid_high(5) == 6
    # rid->sid is NOT injective: rids 4,5,6 all map to sid 5
    assert [p.rid_to_sid(r) for r in (4, 5, 6)] == [5, 5, 5]


def test_merge_state_trims_overlap():
    p = PDT(10)
    p.delete(3)
    p.insert(5, "a")
    p.insert(5, "b")
    m = CScanMergeState()
    # out-of-order delivery: second half first
    r2 = m.deliver_chunk(p, 5, 10)
    r1 = m.deliver_chunk(p, 0, 5)
    produced = sorted(r1 + r2)
    # full coverage, no duplicates
    assert m.produced_count == p.n_visible
    flat = []
    for a, b in produced:
        flat.extend(range(a, b))
    assert flat == list(range(p.n_visible))


@st.composite
def pdt_ops(draw):
    n = draw(st.integers(4, 60))
    ops = draw(
        st.lists(
            st.tuples(
                st.sampled_from(["ins", "del", "mod"]),
                st.integers(0, n - 1),
            ),
            max_size=25,
        )
    )
    return n, ops


@settings(max_examples=60, deadline=None)
@given(pdt_ops())
def test_roundtrip_property(case):
    """Every visible RID maps into [low(sid), high(sid)] of its SID, and
    low/high are monotone in SID."""
    n, ops = case
    p = PDT(n)
    for kind, pos in ops:
        if kind == "ins":
            p.insert(pos)
        elif kind == "del":
            p.delete(pos)
        else:
            p.modify(pos, 42)
    lows = [p.sid_to_rid_low(s) for s in range(n + 1)]
    assert lows == sorted(lows)
    for r in range(p.n_visible):
        s = p.rid_to_sid(r)
        assert p.sid_to_rid_low(s) <= r <= p.sid_to_rid_high(s)


@settings(max_examples=40, deadline=None)
@given(pdt_ops(), st.randoms())
def test_out_of_order_merge_covers_everything(case, rnd):
    n, ops = case
    p = PDT(n)
    for kind, pos in ops:
        if kind == "ins":
            p.insert(pos)
        elif kind == "del":
            p.delete(pos)
    # random chunking, random delivery order (ABM out-of-order delivery)
    bounds = sorted({0, n} | {rnd.randrange(0, n + 1) for _ in range(3)})
    chunks = list(zip(bounds[:-1], bounds[1:]))
    rnd.shuffle(chunks)
    m = CScanMergeState()
    for lo, hi in chunks:
        m.deliver_chunk(p, lo, hi)
    assert m.produced_count == p.n_visible
