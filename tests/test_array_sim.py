"""Array-native simulation core: cross-validation vs the event engine,
vmap batching, kernel/oracle parity, and cold-scan exactness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.scans import ScanSpec
from repro.core.workload import (
    Q6_COLUMNS,
    make_lineitem_db,
    micro_accessed_bytes,
    micro_streams,
)
from repro.core.array_sim import (
    build_spec,
    cross_validate,
    make_config,
    make_runner,
    result_from_state,
    run_workload_array,
    stack_configs,
)


# ----------------------------------------------------- cold-scan anchor ----

def test_single_scan_io_is_exact_cold_volume():
    """One cold scan with a pool that fits it: I/O must equal the page
    bytes of the accessed ranges exactly (no phantom or missing loads)."""
    db = make_lineitem_db(scale_tuples=4_000_000)
    t = db.tables["lineitem"]
    spec = ScanSpec("lineitem", Q6_COLUMNS, ((0, 4_000_000),), tuple_rate=240e6)
    expected = t.scan_bytes(Q6_COLUMNS, 0, 4_000_000)
    r = run_workload_array(db, [[spec]], "lru", capacity_bytes=64 << 20,
                           bandwidth=700e6, time_slice=0.0025)
    assert r.total_io_bytes == pytest.approx(expected, rel=1e-6)
    assert r.stream_times[0] > 0


# -------------------------------------------- cross-validation (10% bar) ---

def test_cross_validation_scaled_microbenchmark():
    """Acceptance: array-LRU / array-PBM avg stream time within 10% of the
    event engine on the scaled microbenchmark default operating point
    (quick-pass scale, buffer = 40% of working set, 700 MB/s, 8 streams)."""
    rows = cross_validate(scale=0.25, buffer_frac=0.4)
    for r in rows:
        assert abs(r["stream_time_rel_err"]) < 0.10, r
        assert abs(r["io_rel_err"]) < 0.15, r


# ----------------------------------------------------------- vmap smoke ----

def test_vmap_batches_four_buffer_points_in_one_call():
    db = make_lineitem_db(scale_tuples=6_000_000)
    ws = micro_accessed_bytes(db)
    streams = micro_streams(db, n_streams=2, queries_per_stream=2, seed=3)
    spec = build_spec(db, streams)
    runner = make_runner(spec, bandwidth_ref=700e6, time_slice=0.005,
                         static_policy="pbm")
    fracs = [0.4, 0.6, 0.8, 1.0]
    cfgs = stack_configs([
        make_config(spec, max(1 << 22, int(f * ws)), 700e6, "pbm")
        for f in fracs
    ])
    states = jax.block_until_ready(jax.jit(jax.vmap(runner))(cfgs))
    assert states.io_bytes.shape == (4,)
    results = [
        result_from_state(jax.tree.map(lambda x, i=i: x[i], states), "pbm")
        for i in range(4)
    ]
    for r in results:
        assert all(t >= 0 for t in r.stream_times)
        assert r.total_io_bytes > 0
        assert np.isfinite(r.avg_stream_time)
    # more buffer -> no more I/O (weak monotonicity with 5% slack)
    ios = [r.total_io_bytes for r in results]
    for a, b in zip(ios, ios[1:]):
        assert b <= a * 1.05

    # batched configs must agree with one-at-a-time runs
    solo = jax.block_until_ready(runner(jax.tree.map(lambda x: x[1], cfgs)))
    assert float(solo.io_bytes) == pytest.approx(ios[1], rel=1e-6)


def test_vmap_batches_policies_with_generic_runner():
    db = make_lineitem_db(scale_tuples=6_000_000)
    ws = micro_accessed_bytes(db)
    streams = micro_streams(db, n_streams=2, queries_per_stream=2, seed=3)
    spec = build_spec(db, streams)
    runner = make_runner(spec, bandwidth_ref=700e6, time_slice=0.005)
    cap = max(1 << 22, int(0.5 * ws))
    cfgs = stack_configs([
        make_config(spec, cap, 700e6, pol)
        for pol in ("lru", "pbm", "lru", "pbm")
    ])
    states = jax.block_until_ready(jax.jit(jax.vmap(runner))(cfgs))
    io = np.asarray(states.io_bytes)
    assert np.all(io > 0)
    # identical configs inside the batch give identical results
    assert io[0] == io[2] and io[1] == io[3]


# ----------------------------------------- Pallas kernel vs jnp oracle -----

def test_pbm_timeline_kernel_matches_reference_interpret():
    from repro.kernels.pbm_timeline import pbm_timeline_step_kernel
    from repro.kernels.ref import pbm_timeline_step_ref

    rng = np.random.default_rng(7)
    P, nb, m = 128, 40, 4
    for _ in range(8):
        bucket = jnp.asarray(rng.integers(0, nb + 1, P), jnp.int32)
        b_target = jnp.asarray(rng.integers(0, nb + 1, P), jnp.int32)
        last_used = jnp.asarray(rng.random(P) * 10, jnp.float32)
        sizes = jnp.asarray(
            rng.choice([524288.0, 262144.0, 1024.0], P), jnp.float32)
        evictable = jnp.asarray(rng.random(P) > 0.4)
        tp = jnp.int32(rng.integers(0, 1000))
        k = jnp.int32(rng.integers(0, 5))
        need = jnp.float32(rng.choice([0.0, 1e6, 8e6, 5e7]))
        pol = jnp.int32(rng.integers(0, 2))
        now = jnp.float32(12.0)
        br, er = pbm_timeline_step_ref(
            bucket, b_target, last_used, sizes, evictable,
            tp, k, need, pol, now, nb=nb, m=m)
        bk, ek = pbm_timeline_step_kernel(
            bucket, b_target, last_used, sizes, evictable,
            tp, k, need, pol, now, nb=nb, m=m, interpret=True)
        np.testing.assert_array_equal(np.asarray(br), np.asarray(bk))
        np.testing.assert_array_equal(np.asarray(er), np.asarray(ek))


# --------------------------------------------------- CSV row schema --------

def test_array_rows_share_event_row_schema():
    from benchmarks import microbench

    rows = microbench.sweep_array("buffer", ["pbm"], scale=0.05)
    assert rows, "every point was skipped"
    event_keys = {"policy", "avg_stream_time_s", "io_gb", "wall_s",
                  "sweep", "point"}
    for r in rows:
        assert event_keys <= set(r.keys())
        assert isinstance(r["avg_stream_time_s"], float)
        assert isinstance(r["io_gb"], float)
