"""Array-native simulation core: cross-validation vs the event engine,
vmap batching, kernel/oracle parity, and cold-scan exactness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.scans import ScanSpec
from repro.core.workload import (
    Q6_COLUMNS,
    make_lineitem_db,
    micro_accessed_bytes,
    micro_streams,
)
from repro.core.array_sim import (
    build_spec,
    cross_validate,
    make_config,
    make_runner,
    result_from_state,
    run_workload_array,
    stack_configs,
)


# ----------------------------------------------------- cold-scan anchor ----

def test_single_scan_io_is_exact_cold_volume():
    """One cold scan with a pool that fits it: I/O must equal the page
    bytes of the accessed ranges exactly (no phantom or missing loads)."""
    db = make_lineitem_db(scale_tuples=4_000_000)
    t = db.tables["lineitem"]
    spec = ScanSpec("lineitem", Q6_COLUMNS, ((0, 4_000_000),), tuple_rate=240e6)
    expected = t.scan_bytes(Q6_COLUMNS, 0, 4_000_000)
    r = run_workload_array(db, [[spec]], "lru", capacity_bytes=64 << 20,
                           bandwidth=700e6, time_slice=0.0025)
    assert r.total_io_bytes == pytest.approx(expected, rel=1e-6)
    assert r.stream_times[0] > 0


# -------------------------------------------- cross-validation (10% bar) ---

def test_cross_validation_scaled_microbenchmark():
    """Acceptance: every registered array policy within its validated
    error bar of the event engine on the scaled microbenchmark default
    operating point (quick-pass scale, buffer = 40% of working set,
    700 MB/s, 8 streams) — the full four-policy paper comparison, on
    BOTH time engines (the slow event-engine reference runs are shared
    between the fixed and event-horizon steppers via the cache in
    ``_shared``)."""
    from repro.core.workload import make_lineitem_db as _mk
    from repro.core.array_sim.spec import build_spec as _bs
    from repro.core.array_sim.validate import ERROR_BARS

    db = _mk(scale_tuples=int(180_000_000 * 0.25))
    ws = micro_accessed_bytes(db)
    streams = micro_streams(db, n_streams=8, queries_per_stream=16, seed=3)
    shared = (db, ws, streams, _bs(db, streams), {}, {})
    for stepper in ("fixed", "horizon"):
        rows = cross_validate(scale=0.25, buffer_frac=0.4, stepper=stepper,
                              _shared=shared)
        assert {r["policy"] for r in rows} == {"lru", "cscan", "pbm", "opt"}
        for r in rows:
            bar = ERROR_BARS[(0.4, r["policy"])]
            assert abs(r["stream_time_rel_err"]) <= bar, (stepper, r)
            assert abs(r["io_rel_err"]) <= bar, (stepper, r)


# ----------------------------------------------------------- vmap smoke ----

def test_vmap_batches_four_buffer_points_in_one_call():
    db = make_lineitem_db(scale_tuples=6_000_000)
    ws = micro_accessed_bytes(db)
    streams = micro_streams(db, n_streams=2, queries_per_stream=2, seed=3)
    spec = build_spec(db, streams)
    runner = make_runner(spec, bandwidth_ref=700e6, time_slice=0.005,
                         policies=("pbm",))
    fracs = [0.4, 0.6, 0.8, 1.0]
    cfgs = stack_configs([
        make_config(spec, max(1 << 22, int(f * ws)), 700e6, "pbm")
        for f in fracs
    ])
    states = jax.block_until_ready(jax.jit(jax.vmap(runner))(cfgs))
    assert states.io_bytes.shape == (4,)
    results = [
        result_from_state(jax.tree.map(lambda x, i=i: x[i], states), "pbm")
        for i in range(4)
    ]
    for r in results:
        assert all(t >= 0 for t in r.stream_times)
        assert r.total_io_bytes > 0
        assert np.isfinite(r.avg_stream_time)
    # more buffer -> no more I/O (weak monotonicity with 5% slack)
    ios = [r.total_io_bytes for r in results]
    for a, b in zip(ios, ios[1:]):
        assert b <= a * 1.05

    # batched configs must agree with one-at-a-time runs
    solo = jax.block_until_ready(runner(jax.tree.map(lambda x: x[1], cfgs)))
    assert float(solo.io_bytes) == pytest.approx(ios[1], rel=1e-6)


def test_vmap_batches_policies_with_generic_runner():
    db = make_lineitem_db(scale_tuples=6_000_000)
    ws = micro_accessed_bytes(db)
    streams = micro_streams(db, n_streams=2, queries_per_stream=2, seed=3)
    spec = build_spec(db, streams)
    runner = make_runner(spec, bandwidth_ref=700e6, time_slice=0.005)
    cap = max(1 << 22, int(0.5 * ws))
    cfgs = stack_configs([
        make_config(spec, cap, 700e6, pol)
        for pol in ("lru", "pbm", "lru", "pbm")
    ])
    states = jax.block_until_ready(jax.jit(jax.vmap(runner))(cfgs))
    io = np.asarray(states.io_bytes)
    assert np.all(io > 0)
    # identical configs inside the batch give identical results
    assert io[0] == io[2] and io[1] == io[3]


# ----------------------------------------- Pallas kernel vs jnp oracle -----

def test_batched_evict_kernel_matches_reference_interpret():
    """The eviction kernel takes a policy-provided score array — the
    Pallas MXU prefix-pop must agree exactly with the top_k oracle for
    arbitrary keys (every registered policy's score shape included:
    negative keys, banded keys, exact ties)."""
    from repro.kernels.pbm_timeline import batched_evict_kernel
    from repro.kernels.ref import batched_evict_ref

    rng = np.random.default_rng(7)
    P = 128
    for i in range(8):
        if i % 3 == 0:     # PBM-shaped: bucket level + tie in [0, nb+1)
            key = rng.integers(0, 41, P) + 0.5 * rng.random(P)
        elif i % 3 == 1:   # CScan-shaped: -interest + chunk tie (negative)
            key = -rng.integers(0, 8, P) + 0.5 * rng.random(P)
        else:              # OPT/LRU-shaped: ages, with exact ties
            key = rng.choice([0.25, 0.5, 2.5, 1e9], P)
        key = jnp.asarray(key, jnp.float32)
        sizes = jnp.asarray(
            rng.choice([524288.0, 262144.0, 1024.0], P), jnp.float32)
        evictable = jnp.asarray(rng.random(P) > 0.4)
        need = jnp.float32(rng.choice([0.0, 1e6, 8e6, 5e7]))
        er = batched_evict_ref(key, sizes, evictable, need, vmax=64)
        ek = batched_evict_kernel(key, sizes, evictable, need,
                                  vmax=64, interpret=True)
        np.testing.assert_array_equal(np.asarray(er), np.asarray(ek))


# --------------------------------------------------- CSV row schema --------

def test_array_rows_share_event_row_schema():
    from benchmarks import microbench

    rows = microbench.sweep_array("buffer", ["pbm"], scale=0.05)
    assert rows, "every point was skipped"
    event_keys = {"policy", "avg_stream_time_s", "io_gb", "wall_s",
                  "sweep", "point"}
    for r in rows:
        assert event_keys <= set(r.keys())
        assert isinstance(r["avg_stream_time_s"], float)
        assert isinstance(r["io_gb"], float)
