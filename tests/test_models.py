"""Per-arch smoke tests (reduced configs, CPU): one train step + decode."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model, init_params, param_shardings, tree_paths
from repro.configs.base import mesh_rules
from repro.train.optimizer import OptimizerConfig, init_opt_state
from repro.train.train_step import make_train_step


def make_batch(cfg, B=2, T=32):
    if cfg.family == "vlm":
        return {
            "tokens": jnp.ones((B, T - cfg.frontend_tokens), jnp.int32),
            "patch_embeds": jnp.ones((B, cfg.frontend_tokens, cfg.d_model),
                                     jnp.float32),
        }
    if cfg.is_encdec:
        return {
            "src_embeds": jnp.ones((B, T, cfg.d_model), jnp.float32),
            "tgt_tokens": jnp.ones((B, T), jnp.int32),
        }
    return {"tokens": jnp.ones((B, T), jnp.int32)}


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_and_finite(arch, rng):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = init_params(model.param_specs, rng, jnp.float32)
    loss, metrics = model.train_loss(params, make_batch(cfg))
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    logits = model.prefill_logits(params, make_batch(cfg))
    assert logits.shape[-1] == cfg.padded_vocab
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step_updates_params(arch, rng):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = init_params(model.param_specs, rng, jnp.float32)
    opt = init_opt_state(params)
    step = make_train_step(model, OptimizerConfig(learning_rate=1e-3,
                                                  warmup_steps=1,
                                                  total_steps=10))
    p2, opt2, metrics = step(params, opt, make_batch(cfg))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(opt2.step) == 1
    # at least one leaf moved
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))
    )
    assert moved, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_two_steps(arch, rng):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = init_params(model.param_specs, rng, jnp.float32)
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                         model.cache_specs(2, 64))
    batch = {"token": jnp.ones((2, 1), jnp.int32), "pos": jnp.int32(0)}
    logits, cache = model.serve_step(params, cache, batch)
    batch = {"token": jnp.argmax(logits[:, -1:], -1).astype(jnp.int32),
             "pos": jnp.int32(1)}
    logits2, _ = model.serve_step(params, cache, batch)
    assert bool(jnp.all(jnp.isfinite(logits2))), arch


@pytest.mark.parametrize("arch", ["qwen2_1_5b", "deepseek_67b", "gemma3_12b",
                                  "gemma_7b"])
def test_decode_matches_prefill(arch, rng):
    """Sequential decode must reproduce the prefill forward (same params).

    f32 caches here: the serving default is bf16, whose quantisation noise
    would mask real wiring regressions."""
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = init_params(model.param_specs, rng, jnp.float32)
    toks = jax.random.randint(rng, (1, 8), 0, cfg.vocab_size)
    pre = model.prefill_logits(params, {"tokens": toks})  # (1,1,V) at last pos
    cache = jax.tree.map(
        lambda s: jnp.zeros(
            s.shape, jnp.float32 if s.dtype == jnp.bfloat16 else s.dtype
        ),
        model.cache_specs(1, 64),
    )
    logits = None
    for i in range(8):
        logits, cache = model.serve_step(
            params, cache, {"token": toks[:, i:i + 1], "pos": jnp.int32(i)}
        )
    np.testing.assert_allclose(
        np.asarray(pre[0, -1], np.float32),
        np.asarray(logits[0, -1], np.float32),
        atol=2e-2, rtol=2e-2,
    )


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_shardings_cover_tree(arch):
    cfg = get_config(arch)   # FULL config: sharding must be defined for all
    model = build_model(cfg)
    rules = mesh_rules("train", ("data", "model"))
    shardings = param_shardings(model.param_specs, rules)
    n_specs = len(tree_paths(model.param_specs))
    n_shard = len(jax.tree.leaves(shardings, is_leaf=lambda x: hasattr(x, "index") or x is None))
    assert n_specs > 0
    # every ParamSpec got a PartitionSpec
    flat = tree_paths(model.param_specs)
    from repro.configs.base import logical_to_spec
    for path, spec in flat.items():
        ps = logical_to_spec(spec.logical, rules)
        assert len(ps) == len(spec.shape), (path, ps, spec.shape)


def test_gemma3_ring_cache_smaller_than_global():
    cfg = get_config("gemma3_12b")
    model = build_model(cfg)
    cache = model.cache_specs(4, 32_768)
    local_s = cache["local"]["k"].shape[2]
    global_s = cache["global"]["k"].shape[2]
    assert local_s == cfg.sliding_window
    assert global_s == 32_768
