"""Workload-compiler regression tests.

``repro.core.array_sim.compiler`` is now the single lowering from the
event engine's object world into ``SimSpec`` arrays; ``build_spec`` is a
thin single-table wrapper over it.  ``_legacy_build_spec`` below is a
frozen copy of the seed's hand-rolled single-table lowering (PR 1/2) —
the oracle that pins the compiler's output bit-for-bit on the
microbenchmark shape, so re-routing the micro path through the compiler
can never silently move the validated operating points.
"""

import numpy as np
import pytest

from repro.core.pages import Database
from repro.core.scans import ScanSpec
from repro.core.workload import make_lineitem_db, micro_streams
from repro.core.array_sim import build_spec, compile_workload
from repro.core.array_sim.compiler import referenced_tables
from repro.core.array_sim.spec import PAGE_PAD, SimSpec


def _legacy_build_spec(db, streams, n_groups=10, buckets_per_group=4):
    """Frozen seed lowering (single table) — do not modernise: this is the
    bit-for-bit reference the compiler must reproduce."""
    tables = {s.table for stream in streams for s in stream}
    assert len(tables) == 1
    table = db.tables[next(iter(tables))]
    col_names = list(table.columns)
    cindex = {c: i for i, c in enumerate(col_names)}
    C = len(col_names)

    sizes, firsts, lasts, pcols = [], [], [], []
    col_start = np.zeros(C, np.int32)
    col_npages = np.zeros(C, np.int32)
    col_tpp = np.zeros(C, np.float32)
    off = 0
    for ci, cname in enumerate(col_names):
        col = table.columns[cname]
        col_start[ci] = off
        col_npages[ci] = len(col.pages)
        col_tpp[ci] = col.n_tuples / len(col.pages)
        for p in col.pages:
            sizes.append(p.size_bytes)
            firsts.append(p.first_tuple)
            lasts.append(p.last_tuple)
            pcols.append(ci)
        off += len(col.pages)

    P = ((off + PAGE_PAD - 1) // PAGE_PAD) * PAGE_PAD
    pad = P - off
    S = len(streams)
    Q = max(len(s) for s in streams)
    q_start = np.zeros((S, Q), np.float32)
    q_len = np.ones((S, Q), np.float32)
    q_rate = np.full((S, Q), 1.0, np.float32)
    q_cols = np.zeros((S, Q, C), bool)
    n_q = np.zeros(S, np.int32)
    for si, stream in enumerate(streams):
        n_q[si] = len(stream)
        for qi, spec in enumerate(stream):
            a, b = spec.ranges[0]
            q_start[si, qi] = a
            q_len[si, qi] = b - a
            q_rate[si, qi] = spec.tuple_rate
            for c in spec.columns:
                q_cols[si, qi, cindex[c]] = True

    return SimSpec(
        n_pages=P,
        n_streams=S,
        n_queries=Q,
        n_cols=C,
        n_groups=n_groups,
        buckets_per_group=buckets_per_group,
        page_size=np.asarray(sizes + [0] * pad, np.float32),
        page_first=np.asarray(firsts + [0] * pad, np.float32),
        page_last=np.asarray(lasts + [0] * pad, np.float32),
        page_col=np.asarray(pcols + [0] * pad, np.int32),
        page_valid=np.asarray([True] * off + [False] * pad, bool),
        col_start=col_start,
        col_npages=col_npages,
        col_tpp=col_tpp,
        col_ntuples=np.full(C, float(table.n_tuples), np.float32),
        q_start=q_start,
        q_len=q_len,
        q_rate=q_rate,
        q_cols=q_cols,
        n_q=n_q,
    )


#: the array fields of the seed SimSpec — the bit-for-bit contract
_SEED_ARRAY_FIELDS = (
    "page_size", "page_first", "page_last", "page_col", "page_valid",
    "col_start", "col_npages", "col_tpp", "col_ntuples",
    "q_start", "q_len", "q_rate", "q_cols", "n_q",
)
_SEED_SCALAR_FIELDS = (
    "n_pages", "n_streams", "n_queries", "n_cols", "n_groups",
    "buckets_per_group",
)


# ------------------------------------------------- round-trip pin ---------

def test_compiler_reproduces_seed_build_spec_bit_for_bit():
    """Compiling the single-table microbenchmark through the workload
    compiler must reproduce the seed ``build_spec`` arrays exactly —
    same dtypes, same bytes."""
    db = make_lineitem_db(scale_tuples=4_000_000)
    streams = micro_streams(db, n_streams=4, queries_per_stream=6, seed=3)
    legacy = _legacy_build_spec(db, streams)
    for spec in (compile_workload(db, streams), build_spec(db, streams)):
        for f in _SEED_SCALAR_FIELDS:
            assert getattr(spec, f) == getattr(legacy, f), f
        for f in _SEED_ARRAY_FIELDS:
            a, b = getattr(spec, f), getattr(legacy, f)
            assert a.dtype == b.dtype, f
            np.testing.assert_array_equal(a, b, err_msg=f)


def test_compiler_multitable_fields_on_single_table():
    db = make_lineitem_db(scale_tuples=2_000_000)
    streams = micro_streams(db, n_streams=2, queries_per_stream=2, seed=3)
    spec = compile_workload(db, streams)
    assert spec.n_tables == 1
    assert spec.table_names == ("lineitem",)
    assert np.all(spec.col_table == 0)
    assert np.all(spec.q_table == 0)


# ------------------------------------------------- multi-table layout -----

def _two_table_db():
    db = Database()
    db.add_table("a", 1_000_000, {"x": 2.0, "y": 0.5}, page_bytes=128 << 10)
    db.add_table("b", 300_000, {"u": 4.0}, page_bytes=128 << 10)
    return db


def test_global_page_indexing_offsets_and_coords():
    """Two tables with different pages-per-column: columns are laid out
    contiguously in db order, offsets are cumulative, and page tuple
    coordinates stay in each table's own coordinate system."""
    db = _two_table_db()
    st = [[ScanSpec("a", ("x", "y"), ((0, 1_000_000),)),
           ScanSpec("b", ("u",), ((0, 300_000),))]]
    spec = compile_workload(db, st)
    assert spec.table_names == ("a", "b")
    # a.x: 1M*2.0B / 128KB = 16 pages; a.y: 1M*0.5B -> 4; b.u: 300k*4B -> 10
    np.testing.assert_array_equal(spec.col_npages, [16, 4, 10])
    np.testing.assert_array_equal(
        spec.col_start, np.cumsum([0, 16, 4])[:3])
    np.testing.assert_array_equal(spec.col_table, [0, 0, 1])
    # per-column tuple grids: a's columns span [0, 1M), b's span [0, 300k)
    for ci, (lo, hi) in enumerate([(0, 1_000_000), (0, 1_000_000),
                                   (0, 300_000)]):
        s, n = int(spec.col_start[ci]), int(spec.col_npages[ci])
        assert spec.page_first[s] == lo
        assert spec.page_last[s + n - 1] == hi
        assert np.all(np.diff(spec.page_first[s:s + n]) > 0)
    # query rows: global column mask selects only the query's table
    np.testing.assert_array_equal(spec.q_table[0], [0, 1])
    np.testing.assert_array_equal(spec.q_cols[0, 0], [True, True, False])
    np.testing.assert_array_equal(spec.q_cols[0, 1], [False, False, True])


def test_compiler_drops_unreferenced_tables():
    db = _two_table_db()
    db.add_table("never_scanned", 500_000, {"z": 8.0}, page_bytes=128 << 10)
    st = [[ScanSpec("a", ("x",), ((0, 1_000_000),))]]
    spec = compile_workload(db, st)
    assert spec.table_names == ("a",)
    assert spec.n_cols == 2  # every column of a referenced table compiles
    assert referenced_tables(db, st) == ["a"]
    # ... unless the table set is pinned explicitly
    spec_all = compile_workload(db, st, tables=["a", "b", "never_scanned"])
    assert spec_all.n_tables == 3
    assert spec_all.n_cols == 4


# ------------------------------------------------- error contracts --------

def test_build_spec_still_rejects_multi_table():
    db = _two_table_db()
    st = [[ScanSpec("a", ("x",), ((0, 10),)),
           ScanSpec("b", ("u",), ((0, 10),))]]
    with pytest.raises(ValueError, match="single table"):
        build_spec(db, st)
    compile_workload(db, st)  # the compiler lowers it fine


def test_compiler_rejects_multi_range_and_unknown():
    db = _two_table_db()
    with pytest.raises(ValueError, match="single-range"):
        compile_workload(db, [[ScanSpec("a", ("x",), ((0, 10), (20, 30)))]])
    with pytest.raises(ValueError, match="unknown tables"):
        compile_workload(db, [[ScanSpec("nope", ("x",), ((0, 10),))]])
    # a too-narrow tables= override gets the friendly error, not a KeyError
    with pytest.raises(ValueError, match="compiled table set"):
        compile_workload(db, [[ScanSpec("b", ("u",), ((0, 10),))]],
                         tables=["a"])
    with pytest.raises(ValueError, match="zero pages"):
        db.tables["a"].columns["y"].pages = []
        compile_workload(db, [[ScanSpec("a", ("x",), ((0, 10),))]])


def test_trigger_window_capped_by_tiny_tables():
    """A one-page dimension table (dense tuples-per-page grid) must not
    inflate the global trigger window: the per-column cap bounds it by
    the column's page count."""
    db = _two_table_db()
    db.add_table("dim", 25, {"d": 4.0}, page_bytes=128 << 10)  # 1 tiny page
    st = [[ScanSpec("a", ("x",), ((0, 1_000_000),), tuple_rate=240e6),
           ScanSpec("dim", ("d",), ((0, 25),), tuple_rate=240e6)]]
    spec = compile_workload(db, st)
    dt = float(np.max(spec.page_size)) / 700e6
    w = spec.trigger_window(dt)
    naive = int(np.ceil(1.1 * spec.max_rate * dt / spec.min_tpp)) + 1
    assert w <= 8          # stays a practical window size
    assert naive > 1000    # the uncapped bound would explode
