import os
import sys

_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for p in (os.path.join(_root, "src"), _root):
    if p not in sys.path:
        sys.path.insert(0, p)
