"""End-to-end behaviour tests: the paper's claims at test scale + the
dry-run machinery on a small in-process mesh."""

import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.core import EngineConfig, run_workload, simulate_belady
from repro.core.stats import sharing_potential
from repro.core.workload import (
    make_lineitem_db, make_tpch_db,
    micro_accessed_bytes, micro_streams,
    tpch_accessed_bytes, tpch_streams,
)


@pytest.fixture(scope="module")
def micro():
    db = make_lineitem_db(scale_tuples=6_000_000, page_bytes=16 << 10)
    return db, micro_accessed_bytes(db)


def test_claim_c1_pbm_close_to_cscan_beats_lru(micro):
    """Paper C1: PBM ~= CScans, both >> LRU (medium buffer)."""
    db, ws = micro
    streams = micro_streams(db, n_streams=8, queries_per_stream=8, seed=3)
    res = {}
    for pol in ("lru", "pbm", "cscan"):
        cfg = EngineConfig(bandwidth=700e6, buffer_bytes=int(0.4 * ws),
                           pbm_time_slice=0.01)
        res[pol] = run_workload(db, streams, pol, cfg)
    assert res["pbm"].total_io_bytes < 0.8 * res["lru"].total_io_bytes
    assert res["cscan"].total_io_bytes < 0.8 * res["lru"].total_io_bytes


def test_claim_c4_io_volume_constant_vs_bandwidth(micro):
    """Paper C4: total I/O volume ~constant across bandwidths."""
    db, ws = micro
    streams = micro_streams(db, n_streams=4, queries_per_stream=6, seed=5)
    vols = []
    for bw in (300e6, 700e6, 1500e6):
        cfg = EngineConfig(bandwidth=bw, buffer_bytes=int(0.4 * ws))
        vols.append(run_workload(db, streams, "pbm", cfg).total_io_bytes)
    lo, hi = min(vols), max(vols)
    assert hi <= 1.3 * lo, vols


def test_claim_c6_sharing_micro_exceeds_tpch():
    """Paper C6/Figs 17-18: microbenchmark has more sharing potential.

    At test scale the contrast needs the paper's own operating point for
    Fig 17 — long scans (50-100%) over one table; full-scale numbers live in
    the benchmark suite / EXPERIMENTS.md."""
    db_m = make_lineitem_db(scale_tuples=6_000_000, page_bytes=16 << 10)
    ws_m = micro_accessed_bytes(db_m)
    s_m = micro_streams(db_m, n_streams=8, queries_per_stream=4, seed=3,
                        fraction=1.0)
    r_m = run_workload(db_m, s_m, "pbm", EngineConfig(
        bandwidth=700e6, buffer_bytes=int(0.4 * ws_m), sample_interval=0.2))
    db_t = make_tpch_db(scale=0.03, page_bytes=16 << 10)
    s_t = tpch_streams(db_t, n_streams=8, seed=7)
    ws_t = tpch_accessed_bytes(db_t, s_t)
    r_t = run_workload(db_t, s_t, "pbm", EngineConfig(
        bandwidth=600e6, buffer_bytes=int(0.3 * ws_t), sample_interval=0.2))
    assert (sharing_potential(r_m).reusable_fraction
            > sharing_potential(r_t).reusable_fraction)


def test_belady_on_trace_bounds_inorder_policies(micro):
    """OPT replay (paper methodology) never exceeds PBM's miss volume."""
    db, ws = micro
    streams = micro_streams(db, n_streams=4, queries_per_stream=4, seed=8)
    cfg = EngineConfig(bandwidth=700e6, buffer_bytes=int(0.3 * ws),
                       record_trace=True)
    r = run_workload(db, streams, "pbm", cfg)
    _, opt_bytes = simulate_belady(
        r.trace, page_sizes=r.page_sizes, capacity_bytes=int(0.3 * ws)
    )
    assert opt_bytes <= r.total_io_bytes


# ------------------------------------------------- dry-run on a tiny mesh --

@pytest.mark.parametrize("arch", ["qwen2_1_5b", "granite_moe_1b_a400m",
                                  "zamba2_2_7b", "xlstm_350m"])
def test_smoke_dryrun_lowering_small_mesh(arch):
    """lower+compile the real step pipeline on a 1x1 in-process mesh using
    the SMOKE config (the 512-device run is launch/dryrun.py)."""
    from jax.sharding import NamedSharding
    from repro.configs import SHAPES, get_config
    from repro.launch.inputs import cell_shardings, input_specs
    from repro.models import abstract_params, build_model
    from repro.train.optimizer import abstract_opt_state, opt_state_shardings
    from repro.train.train_step import make_train_step
    from repro.train.optimizer import OptimizerConfig
    import dataclasses as dc

    cfg = get_config(arch, smoke=True)
    shape = dc.replace(SHAPES["train_4k"], seq_len=64, global_batch=4)
    model = build_model(cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params_abs = abstract_params(model.param_specs, jnp.float32)
    p_specs, b_specs, _ = cell_shardings(cfg, shape, model, mesh)
    opt_abs = abstract_opt_state(params_abs)
    o_specs = opt_state_shardings(p_specs)
    batch_abs = input_specs(cfg, shape)
    named = lambda specs: jax.tree.map(lambda s: NamedSharding(mesh, s), specs)
    step = make_train_step(model, OptimizerConfig())
    with mesh:
        lowered = jax.jit(
            step,
            in_shardings=(named(p_specs), named(o_specs), named(b_specs)),
        ).lower(params_abs, opt_abs, batch_abs)
        compiled = lowered.compile()
    from repro.launch.dryrun import cost_analysis_dict

    assert cost_analysis_dict(compiled).get("flops", 0) > 0


def test_dryrun_artifacts_exist_and_pass():
    """The committed 512-device dry-run results: every cell ok or documented
    skip, both meshes."""
    import glob, os

    d = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")
    files = glob.glob(os.path.join(d, "*.json"))
    if not files:
        pytest.skip("dry-run artifacts not generated yet")
    by_mesh = {"pod": [], "multipod": []}
    for f in files:
        with open(f) as fh:
            rec = json.load(fh)
        by_mesh[rec["mesh"]].append(rec)
    for mesh, recs in by_mesh.items():
        assert len(recs) == 40, (mesh, len(recs))
        bad = [r for r in recs if r["status"] not in ("ok", "skipped")]
        assert not bad, [(r["arch"], r["shape"]) for r in bad]
