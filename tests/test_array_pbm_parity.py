"""Property tests: array PBM vs the dict-based ``policies/pbm.py``.

On random scan registrations the array backend must reproduce the dict
implementation's bucket assignment (``TimeToBucketNumber`` over
``PageNextConsumption``), and given the same bucket state the batched
eviction op must pop the same victims as ``choose_victims`` up to the
documented within-bucket arbitrariness (the dict drains buckets in
insertion order, the array in index order — both blur priorities only
inside one bucket).
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: property tests need it
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import BufferPool, Database, PBMPolicy, ScanSpec, ScanState
from repro.core.array_sim.policies import (
    ArrayPBM, StepCtx, next_consumption, target_buckets,
)
from repro.core.array_sim.spec import build_spec
from repro.kernels.ref import batched_evict_ref


def _pbm_key(spec, bucket, last_used, now):
    """PBM's composite eviction key via the ArrayPolicy surface (only the
    fields ``score_victims`` reads are populated)."""
    ctx = StepCtx(
        spec=spec, refresh=False, time_slice=jnp.float32(1.0),
        now=jnp.float32(now), steps=None, slices_done=None, dt=None,
        page_first=None, page_last=None, page_col=None, page_valid=None,
        resident=None, last_used=last_used, load_mask=None, load_cand=None,
        load_ok=None, cross_pidx=None, crossed=None, active=None,
        cols=None, cur=None, end=None, start=None, eps=None, rate=None,
        speed_push=None,
    )
    return ArrayPBM().score_victims(bucket, ctx)

N_TUPLES = 102_400            # 25 pages of exactly 4096 bytes per column
PAGE_BYTES = 1 << 12
NOT_REQUESTED_DICT = -2


def make_db():
    db = Database()
    db.add_table(
        "t", n_tuples=N_TUPLES, columns={"c0": 1.0, "c1": 1.0},
        chunk_tuples=20_480, page_bytes=PAGE_BYTES,
    )
    return db


scan_strategy = st.tuples(
    st.sampled_from(["c0", "c1"]),
    st.integers(0, N_TUPLES - 1000),          # start
    st.integers(1000, N_TUPLES),              # length (clipped)
    st.sampled_from([1e3, 1e4, 1e5, 1e6]),    # tuple rate
)


def page_order(db):
    """Page list in the array backend's global index order."""
    t = db.tables["t"]
    return t.columns["c0"].pages + t.columns["c1"].pages


def register_both(scans, time_slice=1.0):
    """Register the same scans in the dict PBM (all pages resident, pool
    exactly full) and compute the array side's target buckets."""
    db = make_db()
    pages = page_order(db)
    total = sum(p.size_bytes for p in pages)
    pool = BufferPool(capacity_bytes=total)
    pbm = PBMPolicy(time_slice=time_slice, n_groups=10, buckets_per_group=4)
    pbm.attach(pool, 0.0)
    for p in pages:
        pool.admit(p)
        pbm.on_loaded(p, 0.0)

    streams = []
    for col, start, length, rate in scans:
        length = min(length, N_TUPLES - start)
        spec_q = ScanSpec("t", (col,), ((start, start + length),),
                          tuple_rate=rate)
        streams.append([spec_q])
        pbm.register_scan(ScanState(spec_q, db), 0.0)

    spec = build_spec(db, streams)
    S = spec.n_streams
    cur = jnp.asarray(spec.q_start[:, 0])
    end = cur + jnp.asarray(spec.q_len[:, 0])
    speed = jnp.asarray(spec.q_rate[:, 0])
    cols = jnp.asarray(spec.q_cols[jnp.arange(S), 0])
    eta = next_consumption(
        jnp.asarray(spec.page_first), jnp.asarray(spec.page_last),
        jnp.asarray(spec.page_col), cols, cur, end, speed,
        jnp.ones(S, bool),
    )
    b_arr = np.asarray(target_buckets(
        eta, jnp.float32(time_slice), spec.n_groups, spec.buckets_per_group,
        jnp.asarray(spec.page_valid),
    ))
    return db, pbm, spec, np.asarray(eta), b_arr


def dict_level(pbm, pid, nb):
    meta = pbm._meta.get(pid)
    if meta is None or meta.bucket == NOT_REQUESTED_DICT:
        return nb
    return meta.bucket


@settings(max_examples=40, deadline=None)
@given(st.lists(scan_strategy, min_size=1, max_size=5))
def test_bucket_assignment_matches_dict_pbm(scans):
    db, pbm, spec, eta, b_arr = register_both(scans)
    nb = spec.nb
    for gid, page in enumerate(page_order(db)):
        bd = dict_level(pbm, page.pid, nb)
        ba = int(b_arr[gid])
        if bd == ba:
            continue
        # f32 vs f64 can disagree only when eta sits on a bucket edge
        assert abs(bd - ba) <= 1, (page.pid, bd, ba, eta[gid])
        e = float(eta[gid])
        lo = pbm.time_to_bucket(e * (1 - 1e-5))
        hi = pbm.time_to_bucket(e * (1 + 1e-5) + 1e-9)
        assert lo != hi, (page.pid, bd, ba, e)


@settings(max_examples=25, deadline=None)
@given(st.lists(scan_strategy, min_size=1, max_size=4),
       st.integers(1, 30))
def test_eviction_order_matches_dict_pbm(scans, n_evict):
    """Same bucket state in -> same Belady-rule pop out: not-requested
    first, then furthest-future buckets, identical membership for every
    fully drained bucket."""
    db, pbm, spec, eta, b_arr = register_both(scans)
    nb = spec.nb
    pages = page_order(db)
    need = float(n_evict) * PAGE_BYTES

    # snapshot dict levels BEFORE choose_victims mutates the buckets, and
    # feed the SAME levels to the array op so the property isolates the
    # eviction rule from bucket-assignment rounding
    levels = {p.pid: dict_level(pbm, p.pid, nb) for p in pages}
    victims_dict = pbm.choose_victims(need, set(), 0.0)

    P = spec.n_pages
    bucket_in = np.full(P, nb, np.int32)
    for gid, page in enumerate(pages):
        bucket_in[gid] = levels[page.pid]
    key = _pbm_key(spec, jnp.asarray(bucket_in),
                   jnp.full(P, -1e9, jnp.float32), 0.0)
    evict = batched_evict_ref(
        key, jnp.asarray(spec.page_size), jnp.asarray(spec.page_valid),
        jnp.float32(need), vmax=P,
    )
    evict = np.asarray(evict)
    victims_arr = {pages[g].pid for g in np.flatnonzero(evict[:len(pages)])}

    # uniform page sizes -> identical victim count
    assert len(victims_arr) == len(victims_dict)
    # identical multiset of bucket levels (the Belady rule itself)
    lv_d = sorted(levels[p.pid] for p in victims_dict)
    lv_a = sorted(levels[p] for p in victims_arr)
    assert lv_a == lv_d
    # identical membership for every fully drained level
    per_level_total = {}
    for page in pages:
        per_level_total.setdefault(levels[page.pid], set()).add(page.pid)
    took_d = {}
    for p in victims_dict:
        took_d.setdefault(levels[p.pid], set()).add(p.pid)
    took_a = {}
    for p in victims_arr:
        took_a.setdefault(levels[p], set()).add(p)
    for lvl, total in per_level_total.items():
        if took_d.get(lvl, set()) == total:
            assert took_a.get(lvl, set()) == total, lvl
