"""Multi-table semantics of the array backend (workload compiler + step).

Pins the behaviours the TPC-H throughput figures depend on:

* two tables with different pages-per-column simulate correctly (exact
  cold I/O over the union of both tables' accessed pages);
* a stream whose consecutive queries switch tables agrees with the event
  engine under a constrained pool;
* a vmapped (policy x buffer) sweep over the compiled TPC-H spec agrees
  with the event engine within the validated TPC-H error bars, lane for
  lane, in ONE batched call.
"""

import jax
import numpy as np
import pytest

from repro.core import EngineConfig, run_workload
from repro.core.pages import Database
from repro.core.scans import ScanSpec
from repro.core.workload import make_tpch_db, tpch_accessed_bytes, tpch_streams
from repro.core.array_sim import (
    compile_workload,
    make_config,
    make_runner,
    result_from_state,
    run_workload_array,
    stack_configs,
)
from repro.core.array_sim.validate import TPCH_DEFAULTS, TPCH_ERROR_BARS


def _two_table_db(page_bytes=128 << 10):
    db = Database()
    # deliberately different page grids: a.x 16 pages, a.y 4, b.u 10
    db.add_table("a", 1_000_000, {"x": 2.0, "y": 0.5}, page_bytes=page_bytes)
    db.add_table("b", 300_000, {"u": 4.0}, page_bytes=page_bytes)
    return db


# ------------------------------------------------ cold exactness ----------

def test_two_table_cold_scan_io_is_exact():
    """A cold pass over two tables with room for everything must load
    exactly the union of accessed page bytes — per-table offsets cannot
    leak I/O across tables."""
    db = _two_table_db()
    st = [[ScanSpec("a", ("x", "y"), ((0, 1_000_000),), tuple_rate=50e6),
           ScanSpec("b", ("u",), ((0, 300_000),), tuple_rate=50e6)]]
    expected = (db.tables["a"].scan_bytes(("x", "y"), 0, 1_000_000)
                + db.tables["b"].scan_bytes(("u",), 0, 300_000))
    r = run_workload_array(db, st, "lru", capacity_bytes=64 << 20,
                           bandwidth=700e6, time_slice=0.002)
    assert r.total_io_bytes == pytest.approx(expected, rel=1e-6)
    assert not r.extras["truncated"]


# ------------------------------------------ table-switching streams -------

def test_stream_switching_tables_matches_event_engine():
    """Streams that alternate tables between consecutive queries (the
    interleaving the rotated TPC-H permutations produce), under a pool a
    third of the joint working set: array LRU/PBM must stay close to the
    event engine on both paper metrics.  Built on the TPC-H table
    geometry — the fluid step's fidelity was calibrated at realistic page
    grids and rates, not at toy scans a few steps long."""
    import random

    db = make_tpch_db(scale=0.05)
    rng = random.Random(5)

    def q(tname, s):
        t = db.tables[tname]
        cols = tuple(sorted(t.columns)[:4])
        ln = max(1, int(t.n_tuples * 0.5))
        a = rng.randrange(0, max(1, t.n_tuples - ln + 1))
        return ScanSpec(tname, cols, ((a, a + ln),), tuple_rate=80e6,
                        stream=s)

    # stream s alternates lineitem/orders starting in anti-phase with s+1,
    # so consecutive queries ALWAYS switch tables and streams overlap on
    # both tables at staggered times
    streams = [
        [q(("lineitem", "orders")[(i + s) % 2], s) for i in range(8)]
        for s in range(4)
    ]
    seen, ws = set(), 0
    for stream in streams:
        for sp in stream:
            t = db.tables[sp.table]
            for c in sp.columns:
                for p in t.columns[c].pages_for_range(*sp.ranges[0]):
                    if p.pid not in seen:
                        seen.add(p.pid)
                        ws += p.size_bytes
    cap = max(1 << 22, int(0.3 * ws))
    for pol in ("lru", "pbm"):
        cfg = EngineConfig(bandwidth=600e6, buffer_bytes=cap,
                           sample_interval=5.0, pbm_time_slice=0.005)
        ev = run_workload(db, streams, pol, cfg)
        ar = run_workload_array(db, streams, pol, capacity_bytes=cap,
                                bandwidth=600e6, time_slice=0.005)
        assert not ar.extras["truncated"]
        dt = ar.avg_stream_time / ev.avg_stream_time - 1
        dio = ar.io_gb / ev.io_gb - 1
        assert abs(dt) <= 0.15, (pol, dt, dio)
        assert abs(dio) <= 0.15, (pol, dt, dio)


# ----------------------------- vmapped TPC-H sweep vs event engine --------

def test_vmapped_tpch_four_policy_buffer_sweep_within_validation_bars():
    """The acceptance shape of the ArrayPolicy tentpole: the FULL paper
    comparison — all four policies (lru / cscan / pbm / opt) x every
    validated buffer point — over the compiled TPC-H spec runs as ONE
    vmapped computation, and every lane agrees with the event engine
    within the validated TPC-H bars (<= 15% for the array-CScan /
    array-OPT ports).  Uses the quick-pass TPC-H point the bars were
    fit at."""
    from repro.core.policy_registry import names as policy_names

    policies = policy_names(backend="array")
    assert set(policies) == {"lru", "cscan", "pbm", "opt"}
    scale = TPCH_DEFAULTS["scale"]
    bw = TPCH_DEFAULTS["bandwidth"]
    db = make_tpch_db(scale=scale)
    streams = tpch_streams(db, n_streams=TPCH_DEFAULTS["n_streams"],
                           seed=TPCH_DEFAULTS["seed"])
    ws = tpch_accessed_bytes(db, streams)
    spec = compile_workload(db, streams)
    assert spec.n_tables >= 6          # the TPC-H fact + dimension tables
    assert spec.n_cols >= 50
    time_slice = 0.1 * scale
    # one runner over the whole registry: the policy axis itself is a
    # traced config scalar (the default policies=None means "all")
    runner = make_runner(spec, bandwidth_ref=bw, time_slice=time_slice)
    fracs = sorted({f for (f, _p) in TPCH_ERROR_BARS})
    lanes = [(f, pol) for f in fracs for pol in policies]
    cfgs = stack_configs([
        make_config(spec, max(1 << 22, int(f * ws)), bw, pol)
        for f, pol in lanes
    ])
    states = jax.block_until_ready(jax.jit(jax.vmap(runner))(cfgs))
    ios, times = {}, {}
    for i, (f, pol) in enumerate(lanes):
        ar = result_from_state(jax.tree.map(lambda x, i=i: x[i], states), pol)
        assert not ar.extras["truncated"], (f, pol)
        cap = max(1 << 22, int(f * ws))
        cfg = EngineConfig(bandwidth=bw, buffer_bytes=cap,
                           sample_interval=5.0, pbm_time_slice=time_slice)
        ev = run_workload(db, streams, pol, cfg)
        bar = TPCH_ERROR_BARS[(f, pol)]
        dt = ar.avg_stream_time / ev.avg_stream_time - 1
        dio = ar.io_gb / ev.io_gb - 1
        assert abs(dt) <= bar, (f, pol, dt, dio)
        assert abs(dio) <= bar, (f, pol, dt, dio)
        ios[(f, pol)] = ar.total_io_bytes
        times[(f, pol)] = ar.avg_stream_time
    # more buffer -> no more I/O per policy (weak monotonicity, 5% slack)
    for pol in policies:
        seq = [ios[(f, pol)] for f in fracs]
        for a, b in zip(seq, seq[1:]):
            assert b <= a * 1.05, (pol, seq)
    # the paper's policy ordering holds on the array backend too: the
    # cooperative scans beat every order-preserving policy, and OPT
    # bounds LRU, at every validated buffer point (Figs 14-16)
    for f in fracs:
        assert times[(f, "cscan")] < times[(f, "opt")], (f, times)
        assert times[(f, "opt")] < times[(f, "lru")], (f, times)


def test_multitable_batched_lane_matches_solo_run():
    """A lane of the vmapped TPC-H batch must equal the same config run
    solo — batching cannot change multi-table semantics."""
    db = make_tpch_db(scale=0.02)
    streams = tpch_streams(db, n_streams=2, seed=7)
    ws = tpch_accessed_bytes(db, streams)
    spec = compile_workload(db, streams)
    runner = make_runner(spec, bandwidth_ref=600e6, time_slice=0.002,
                         policies=("pbm",))
    cfgs = stack_configs([
        make_config(spec, max(1 << 22, int(f * ws)), 600e6, "pbm")
        for f in (0.2, 0.4)
    ])
    states = jax.block_until_ready(jax.jit(jax.vmap(runner))(cfgs))
    solo = jax.block_until_ready(runner(jax.tree.map(lambda x: x[0], cfgs)))
    np.testing.assert_allclose(
        float(solo.io_bytes), float(states.io_bytes[0]), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(solo.stream_done_t), np.asarray(states.stream_done_t[0]),
        rtol=1e-5)
