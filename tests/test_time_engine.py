"""Event-horizon time engine: variable-dt macro-stepping, lane sharding,
and the ``slices_done`` livelock-guard rename.

The batched substrate now models time two ways (``make_runner(stepper=)``):
the classic fixed-dt cadence and the event-horizon stepper, which jumps
each lane to its next interesting time (trigger arrival, chunk
completion, io-credit horizon, stream completion, slice refresh).  These
tests pin the contracts the refactor introduced:

* dt-invariance — coarse (``step_pages=2``) vs fine fixed-dt vs the
  horizon stepper agree within the documented array-vs-array bars on the
  micro and TPC-H smoke workloads;
* frozen-lane invariance — a finished lane of a batched run is bit-equal
  to the same config run solo (its state freezes while slow lanes
  continue);
* ``shard_map`` lane mode — a single-device mesh is bit-equal to plain
  ``vmap``;
* the horizon's work is observable (``steps`` / ``macro_steps`` /
  ``skipped_time`` extras), not inferred;
* ``SimState.time_passed`` (a slice count that was never a time) is
  gone — the field is ``slices_done``, the old name no longer reads —
  and truncated runs still raise in ``cross_validate``;
* the budgeted FIFO-grant kernel matches its jnp oracle exactly.
"""

import os

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core.scans import ScanSpec
from repro.core.workload import (
    Q6_COLUMNS,
    make_lineitem_db,
    make_tpch_db,
    micro_accessed_bytes,
    micro_streams,
    tpch_accessed_bytes,
    tpch_streams,
)
from repro.core.array_sim import (
    ArrayCScan,
    ArrayPolicy,
    HorizonView,
    SimState,
    build_spec,
    compile_workload,
    make_config,
    make_runner,
    result_from_state,
    run_workload_array,
    stack_configs,
)

#: array-vs-array agreement bar between time discretisations (the
#: cross-backend bars live in validate.{ERROR_BARS,TPCH_ERROR_BARS};
#: between two array discretisations of the SAME machine we hold the
#: coarse/fine/horizon triangle to the same 12% envelope the validated
#: points use)
DT_INVARIANCE_BAR = 0.12


def _micro_shared():
    db = make_lineitem_db(scale_tuples=int(180_000_000 * 0.1))
    ws = micro_accessed_bytes(db)
    streams = micro_streams(db, n_streams=4, queries_per_stream=4, seed=3)
    return db, ws, streams


# ------------------------------------------------------ dt invariance -----

def test_dt_invariance_micro_fixed_coarse_horizon():
    """Coarse fixed (2-page steps), fine fixed, and the horizon stepper
    are three discretisations of one machine: both paper metrics must
    agree within the documented bar for LRU and PBM on the micro shape."""
    db, ws, streams = _micro_shared()
    spec = build_spec(db, streams)
    for pol in ("lru", "pbm"):
        runs = {}
        for tag, kw in (
            ("fine", dict(step_pages=1.0)),
            ("coarse", dict(step_pages=2.0)),
            ("horizon", dict(step_pages=1.0, stepper="horizon")),
        ):
            runner = make_runner(spec, bandwidth_ref=700e6, time_slice=0.01,
                                 policies=(pol,), **kw)
            runs[tag] = run_workload_array(
                db, streams, pol, capacity_bytes=int(0.3 * ws),
                bandwidth=700e6, time_slice=0.01, spec=spec, runner=runner,
            )
        ref = runs["fine"]
        assert not ref.extras["truncated"]
        for tag in ("coarse", "horizon"):
            r = runs[tag]
            assert not r.extras["truncated"], (pol, tag)
            dt_st = r.avg_stream_time / ref.avg_stream_time - 1
            dt_io = r.total_io_bytes / ref.total_io_bytes - 1
            assert abs(dt_st) <= DT_INVARIANCE_BAR, (pol, tag, dt_st)
            assert abs(dt_io) <= DT_INVARIANCE_BAR, (pol, tag, dt_io)


def test_dt_invariance_tpch_smoke():
    """Fixed vs horizon on the compiled multi-table TPC-H smoke workload
    (all four registered policies ride the same spec)."""
    db = make_tpch_db(scale=0.02)
    streams = tpch_streams(db, n_streams=3, seed=7)
    ws = tpch_accessed_bytes(db, streams)
    spec = compile_workload(db, streams)
    for pol in ("pbm", "cscan"):
        rs = {}
        for stepper in ("fixed", "horizon"):
            runner = make_runner(spec, bandwidth_ref=600e6,
                                 time_slice=0.002, policies=(pol,),
                                 stepper=stepper)
            rs[stepper] = run_workload_array(
                db, streams, pol, capacity_bytes=max(1 << 22, int(0.3 * ws)),
                bandwidth=600e6, time_slice=0.002, spec=spec, runner=runner,
            )
        dt_st = rs["horizon"].avg_stream_time / rs["fixed"].avg_stream_time - 1
        dt_io = rs["horizon"].total_io_bytes / rs["fixed"].total_io_bytes - 1
        assert abs(dt_st) <= DT_INVARIANCE_BAR, (pol, dt_st)
        assert abs(dt_io) <= DT_INVARIANCE_BAR, (pol, dt_io)


# ------------------------------------------------ frozen-lane freeze ------

def test_frozen_lane_is_bit_stable_while_slow_lanes_continue():
    """In a batched horizon run, a lane that finishes early freezes: its
    final state must be BIT-equal to the same config run solo, however
    long the slowest lane keeps stepping."""
    db, ws, streams = _micro_shared()
    spec = build_spec(db, streams)
    runner = make_runner(spec, bandwidth_ref=700e6, time_slice=0.01,
                         policies=("pbm",), stepper="horizon")
    fast = make_config(spec, int(1.0 * ws), 700e6, "pbm")   # roomy: finishes
    slow = make_config(spec, int(0.15 * ws), 700e6, "pbm")  # thrash: slow
    states = jax.block_until_ready(
        jax.jit(jax.vmap(runner))(stack_configs([fast, slow])))
    solo = jax.block_until_ready(runner(fast))
    fast_lane = jax.tree.map(lambda x: x[0], states)
    assert float(fast_lane.t) > 0
    # the slow lane really did keep going after the fast lane finished
    assert int(states.steps[1]) > int(states.steps[0])
    for name in ("t", "steps", "slices_done", "io_bytes", "loads",
                 "churn", "stream_done_t", "pos", "consumed"):
        np.testing.assert_array_equal(
            np.asarray(getattr(fast_lane, name)),
            np.asarray(getattr(solo, name)), err_msg=name)


# ------------------------------------------------ shard_map lane mode -----

def test_mesh_single_device_equivalence():
    """``make_runner(mesh=...)`` over a one-device mesh must be bit-equal
    to the plain vmapped runner — for both steppers (the acceptance
    equivalence test of the shard_map lane mode)."""
    from jax.sharding import Mesh

    db, ws, streams = _micro_shared()
    spec = build_spec(db, streams)
    cfgs = stack_configs([
        make_config(spec, int(f * ws), 700e6, "pbm") for f in (0.3, 0.6)
    ])
    mesh = Mesh(np.array(jax.devices()[:1]), ("lanes",))
    for stepper in ("fixed", "horizon"):
        plain = make_runner(spec, bandwidth_ref=700e6, time_slice=0.01,
                            policies=("pbm",), stepper=stepper)
        sharded = make_runner(spec, bandwidth_ref=700e6, time_slice=0.01,
                              policies=("pbm",), stepper=stepper, mesh=mesh)
        a = jax.block_until_ready(jax.jit(jax.vmap(plain))(cfgs))
        b = jax.block_until_ready(sharded(cfgs))
        np.testing.assert_array_equal(np.asarray(a.io_bytes),
                                      np.asarray(b.io_bytes))
        np.testing.assert_array_equal(np.asarray(a.stream_done_t),
                                      np.asarray(b.stream_done_t))
        np.testing.assert_array_equal(np.asarray(a.steps),
                                      np.asarray(b.steps))


def test_mesh_rejects_three_axes():
    """One lane axis or a two-axis ('lane', 'page') mesh are the only
    accepted shapes; a third axis has no meaning here."""
    from jax.sharding import Mesh

    db, ws, streams = _micro_shared()
    spec = build_spec(db, streams)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1, 1), ("a", "b", "c"))
    with pytest.raises(ValueError, match="one-axis"):
        make_runner(spec, policies=("pbm",), mesh=mesh)


def test_mesh_page_axis_equivalence():
    """A two-axis ('lanes', 'page') mesh page-shards the candidate scans
    inside each step; the construction is reduction-safe, so the run
    must stay BIT-equal to the plain vmapped runner."""
    from jax.sharding import Mesh

    db, ws, streams = _micro_shared()
    spec = build_spec(db, streams)
    cfgs = stack_configs([
        make_config(spec, int(f * ws), 700e6, "pbm") for f in (0.15, 0.3)
    ])
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("lanes", "page"))
    plain = make_runner(spec, bandwidth_ref=700e6, time_slice=0.01,
                        policies=("pbm",), stepper="horizon")
    sharded = make_runner(spec, bandwidth_ref=700e6, time_slice=0.01,
                          policies=("pbm",), stepper="horizon", mesh=mesh)
    assert sharded.page_axis == "page"
    a = jax.block_until_ready(jax.jit(jax.vmap(plain))(cfgs))
    b = jax.block_until_ready(sharded(cfgs))
    for name in ("io_bytes", "loads", "churn", "stream_done_t", "steps"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a, name)), np.asarray(getattr(b, name)),
            err_msg=name)


def test_page_sharded_ops_match_unsharded_on_multi_device_mesh():
    """The page-sharded candidate construction must be bitwise-identical
    to the unsharded oracles with REAL page shards (P split across >1
    devices).  Extra host devices must exist before JAX initialises, so
    this runs op-level checks in a subprocess with
    ``--xla_force_host_platform_device_count=4``."""
    import subprocess
    import sys

    code = """
import numpy as np, jax, jax.numpy as jnp
from functools import partial
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.kernels import ops, ref

mesh = Mesh(np.array(jax.devices()[:4]), ("page",))
rng = np.random.default_rng(5)
Pn = 1024
for trial in range(4):
    key = jnp.asarray(rng.integers(-1, 1 << 28, Pn), jnp.int32)
    sizes = jnp.asarray(rng.choice([524288.0, 4096.0], Pn), jnp.float32)
    budget = jnp.float32(4e6)
    pops = jnp.int32(9)
    need = jnp.float32(3e6)
    ev = jnp.asarray(rng.random(Pn) < 0.7)
    fkey = jnp.asarray(rng.random(Pn), jnp.float32)

    g = shard_map(
        partial(ops.fifo_grant, vmax=16, page_axis="page"),
        mesh=mesh, in_specs=(P(), P(), P(), P()),
        out_specs=(P(), P(), P()), check_rep=False,
    )(key, sizes, budget, pops)
    gr = ref.fifo_grant_ref(key, sizes, budget, pops, vmax=16)
    assert (np.asarray(g[0]) == np.asarray(gr[0])).all(), trial
    assert float(g[1]) == float(gr[1]) and int(g[2]) == int(gr[2]), trial

    e = shard_map(
        partial(ops.batched_evict, vmax=64, page_axis="page"),
        mesh=mesh, in_specs=(P(), P(), P(), P()),
        out_specs=P(), check_rep=False,
    )(fkey, sizes, ev, need)
    er = ref.batched_evict_ref(fkey, sizes, ev, need, vmax=64)
    assert (np.asarray(e) == np.asarray(er)).all(), trial
print("OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    env["PYTHONPATH"] = os.pathsep.join(sys.path)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


# ------------------------------------------- observability + rename -------

def test_horizon_reports_macro_steps_and_skipped_time():
    """Speedups are observable, not inferred: extras carry the executed
    step count and the simulated time the horizon jumped past."""
    db, ws, streams = _micro_shared()
    r_fix = run_workload_array(db, streams, "pbm",
                               capacity_bytes=int(0.5 * ws),
                               bandwidth=700e6, time_slice=0.01)
    r_hor = run_workload_array(db, streams, "pbm",
                               capacity_bytes=int(0.5 * ws),
                               bandwidth=700e6, time_slice=0.01,
                               stepper="horizon")
    for r in (r_fix, r_hor):
        assert r.extras["steps"] == r.steps
        assert r.extras["macro_steps"] == r.steps
        assert "skipped_time" in r.extras
        assert r.extras["slices_done"] > 0
    # the fixed cadence covers ~one fine step per step; the horizon
    # stepper must actually have jumped on this roomy pool
    assert r_fix.extras["skipped_time"] == pytest.approx(0.0, abs=1e-3)
    assert r_hor.extras["skipped_time"] > 0.0
    assert r_hor.steps < r_fix.steps


def test_time_passed_alias_is_gone():
    """``SimState.time_passed`` counted PBM slices, never time; the field
    is ``slices_done`` and the deprecated alias was removed — reading the
    old name is an AttributeError, not a warning."""
    assert "slices_done" in SimState._fields
    assert "time_passed" not in SimState._fields
    db, ws, streams = _micro_shared()
    spec = build_spec(db, streams)
    from repro.core.array_sim.sim import init_state
    st = init_state(spec, ())
    assert int(st.slices_done) == 0
    with pytest.raises(AttributeError):
        st.time_passed


def test_truncated_runs_still_raise_in_cross_validate(monkeypatch):
    """The livelock guard compares ``slices_done`` (né ``time_passed``)
    against ``max_slices``; a truncated array run must still abort
    cross-validation instead of comparing a lower bound."""
    from repro.core.array_sim import validate as v

    real = v.run_workload_array

    def forced_truncation(*args, **kw):
        kw["max_time"] = 1e-3
        return real(*args, **kw)

    monkeypatch.setattr(v, "run_workload_array", forced_truncation)
    with pytest.raises(RuntimeError, match="truncated by the livelock"):
        v.cross_validate(scale=0.02, n_streams=2, queries_per_stream=2,
                         buffer_frac=0.4, policies=("lru",))


def test_max_slices_guard_truncates_on_slices_done():
    """A tiny ``max_slices`` trips the guard via the renamed counter on
    BOTH steppers."""
    db = make_lineitem_db(scale_tuples=2_000_000)
    spec_q = ScanSpec("lineitem", Q6_COLUMNS, ((0, 2_000_000),),
                      tuple_rate=240e6)
    spec = build_spec(db, [[spec_q]])
    for stepper in ("fixed", "horizon"):
        runner = make_runner(spec, bandwidth_ref=700e6, time_slice=0.005,
                             policies=("lru",), max_slices=2,
                             stepper=stepper)
        st = jax.block_until_ready(
            runner(make_config(spec, 64 << 20, 700e6, "lru")))
        r = result_from_state(st, "lru")
        assert r.extras["truncated"], stepper
        assert int(st.slices_done) <= 2


# ------------------------------------------- horizon-provider protocol ----

def test_scan_horizon_protocol():
    """Policies are horizon providers: the default is unconstrained
    (``None``); the cooperative CScan reports per-stream chunk horizons."""
    db = make_tpch_db(scale=0.02)
    streams = tpch_streams(db, n_streams=3, seed=7)
    spec = compile_workload(db, streams)
    assert ArrayPolicy().scan_horizon((), None) is None
    cs = ArrayCScan()
    pstate = cs.init_state(spec)
    hz = HorizonView(
        spec=spec,
        active=jnp.ones(spec.n_streams, bool),
        start=jnp.zeros(spec.n_streams, jnp.float32),
        end=jnp.full(spec.n_streams, 1e6, jnp.float32),
        rate=jnp.full(spec.n_streams, 1e6, jnp.float32),
        dt_ref=jnp.float32(1e-3),
    )
    t = cs.scan_horizon(pstate, hz)
    assert t.shape == (spec.n_streams,)
    # idle active scans need a fine step to run the pick loop
    np.testing.assert_allclose(np.asarray(t), 1e-3)


# ------------------------------------------------ wake-exact stepper ------

def test_wake_exact_supersaturated_contract():
    """The supersaturated regime (capacity below one round of every
    stream's in-flight pages) used to pin the horizon stepper to the
    fine cadence; the wake-exact queue model replaces that never-jump
    rule.  Three contracts on a saturated deep-thrash point:

    * ``wake_exact=False`` preserves the PR-9 rule — results bit-equal
      to the fixed stepper;
    * ``wake_exact=True`` (the default) strictly reduces macro steps;
    * the fluid drift it introduces stays inside the documented
      array-vs-array bar (the queue model is exact; the residual drift
      is the stochastic per-step sampling collapsing onto macro steps).
    """
    db, ws, streams = _micro_shared()
    spec = build_spec(db, streams)
    cap = int(0.1 * ws)
    # the point must actually be supersaturated (pool below the scans'
    # aggregate plan-window bytes) or the contract is vacuous
    assert cap < spec.n_streams * 8 * float(np.max(spec.page_size))
    runs = {}
    for tag, kw in (
        ("fixed", dict(stepper="fixed")),
        ("off", dict(stepper="horizon", wake_exact=False)),
        ("on", dict(stepper="horizon", wake_exact=True)),
    ):
        runner = make_runner(spec, bandwidth_ref=700e6, time_slice=0.01,
                             policies=("pbm",), **kw)
        runs[tag] = jax.block_until_ready(
            runner(make_config(spec, cap, 700e6, "pbm")))
    # results are bit-equal; the internal clock `t` is excluded — the two
    # cadences partition the same span into different float additions
    for name in ("io_bytes", "loads", "churn", "stream_done_t"):
        np.testing.assert_array_equal(
            np.asarray(getattr(runs["fixed"], name)),
            np.asarray(getattr(runs["off"], name)), err_msg=name)
    # wake-exact macro-jumps: strictly fewer steps, bounded drift
    assert int(runs["on"].steps) < int(runs["off"].steps)
    assert int(runs["on"].steps) <= int(0.9 * int(runs["off"].steps))
    t_on = float(jnp.max(runs["on"].stream_done_t))
    t_fix = float(jnp.max(runs["fixed"].stream_done_t))
    assert abs(t_on / t_fix - 1) <= DT_INVARIANCE_BAR


def test_wake_exact_supersaturated_contract_tpch():
    """The wake-exact contract on the compiled multi-table TPC-H
    workload at a saturated buffer point — the race's 8-stream shape,
    scaled to test size (supersaturation is a per-stream in-flight
    bound, so it needs the full stream count; fewer/deeper-thrashed
    streams livelock or leave the validated regime entirely).
    ``wake_exact=False`` stays bit-equal to ``fixed`` on the result
    fields, ``wake_exact=True`` strictly cuts macro steps with drift
    inside the documented invariance bar."""
    db = make_tpch_db(scale=0.02)
    streams = tpch_streams(db, n_streams=8, seed=7)
    ws = tpch_accessed_bytes(db, streams)
    spec = compile_workload(db, streams)
    cap = max(1 << 22, int(0.3 * ws))
    assert cap < spec.n_streams * 8 * float(np.max(spec.page_size))
    runs = {}
    for tag, kw in (
        ("fixed", dict(stepper="fixed")),
        ("off", dict(stepper="horizon", wake_exact=False)),
        ("on", dict(stepper="horizon", wake_exact=True)),
    ):
        runner = make_runner(spec, bandwidth_ref=600e6, time_slice=0.002,
                             policies=("pbm",), **kw)
        runs[tag] = jax.block_until_ready(
            runner(make_config(spec, cap, 600e6, "pbm")))
    for name in ("io_bytes", "loads", "churn", "stream_done_t"):
        np.testing.assert_array_equal(
            np.asarray(getattr(runs["fixed"], name)),
            np.asarray(getattr(runs["off"], name)), err_msg=name)
    assert int(runs["on"].steps) < int(runs["off"].steps)
    t_on = float(jnp.max(runs["on"].stream_done_t))
    t_fix = float(jnp.max(runs["fixed"].stream_done_t))
    assert abs(t_on / t_fix - 1) <= DT_INVARIANCE_BAR


def test_wake_exact_no_effect_outside_saturation():
    """Non-saturated lanes never take the wake path: ``wake_exact`` on
    vs off must be BIT-identical at a buffer point above the
    supersaturation threshold."""
    db, ws, streams = _micro_shared()
    spec = build_spec(db, streams)
    cap = int(0.2 * ws)
    assert cap >= spec.n_streams * 8 * float(np.max(spec.page_size))
    runs = {}
    for tag, on in (("off", False), ("on", True)):
        runner = make_runner(spec, bandwidth_ref=700e6, time_slice=0.01,
                             policies=("pbm",), stepper="horizon",
                             wake_exact=on)
        runs[tag] = jax.block_until_ready(
            runner(make_config(spec, cap, 700e6, "pbm")))
    for name in ("t", "steps", "io_bytes", "loads", "churn",
                 "stream_done_t"):
        np.testing.assert_array_equal(
            np.asarray(getattr(runs["off"], name)),
            np.asarray(getattr(runs["on"], name)), err_msg=name)


# ------------------------------------------------ fifo-grant kernel -------

def test_fifo_grant_kernel_matches_reference_interpret():
    """The budgeted FIFO-grant kernel (the horizon step's macro I/O pop)
    must agree exactly with the top_k oracle: strict head-of-line
    admission, pops cap, ties by page index, empty queues."""
    from repro.kernels.pbm_timeline import fifo_grant_kernel
    from repro.kernels.ref import fifo_grant_ref

    rng = np.random.default_rng(11)
    P = 128
    for i in range(10):
        if i % 3 == 0:
            # stamp-FIFO shaped keys with a -1 tail — full 30-bit range:
            # stamp_age*32768 + tie goes far past 2^24, where an f32
            # cast would silently round the tie bits away
            key = rng.integers(-1, (32767 << 15) + 32767, P)
        elif i % 3 == 1:  # dense ties on old stamps (tie bits past 2^24)
            key = (1 << 26) + rng.integers(-2, 4, P) * 3
        else:             # nothing wanted
            key = np.full(P, -1)
        key = jnp.asarray(key, jnp.int32)
        sizes = jnp.asarray(
            rng.choice([524288.0, 262144.0, 4096.0], P), jnp.float32)
        budget = jnp.float32(rng.choice([0.0, 5e5, 2e6, 1e7]))
        pops = jnp.int32(rng.integers(0, 14))
        mr, br, nr_ = fifo_grant_ref(key, sizes, budget, pops, vmax=12)
        mk, bk, nk = fifo_grant_kernel(key, sizes, budget, pops, vmax=12,
                                       interpret=True)
        np.testing.assert_array_equal(np.asarray(mr), np.asarray(mk))
        assert float(br) == float(bk)
        assert int(nr_) == int(nk)


def test_wake_solve_kernel_matches_reference_interpret():
    """The wake-solve kernel (per-page grant step of the frozen serial
    I/O server) must agree exactly with the jnp oracle — including the
    ragged-tail blocked geometry (P not a multiple of the page block)
    and the not-granted sentinel ``h_cap + 1``."""
    from repro.kernels.pbm_timeline import wake_solve_kernel
    from repro.kernels.ref import wake_solve_ref

    rng = np.random.default_rng(23)
    for trial, P in enumerate((128, 512 + 37)):
        for i in range(4):
            if i == 3:  # nothing queued: every page gets the sentinel
                key = np.full(P, -1)
            else:
                key = rng.integers(-1, (32767 << 15) + 32767, P)
            key = jnp.asarray(key, jnp.int32)
            sizes = jnp.asarray(
                rng.choice([524288.0, 262144.0, 4096.0], P), jnp.float32)
            credit0 = jnp.float32(rng.choice([0.0, 3e5, 2e6]))
            inc = jnp.float32(rng.choice([2e5, 6e5]))
            pops = jnp.int32(rng.integers(1, 8))
            wr = wake_solve_ref(key, sizes, credit0, inc, pops, h_cap=12)
            wk = wake_solve_kernel(key, sizes, credit0, inc, pops,
                                   h_cap=12, interpret=True)
            np.testing.assert_array_equal(
                np.asarray(wr), np.asarray(wk), err_msg=f"P={P} i={i}")
            if i == 3:
                assert int(np.min(np.asarray(wk))) == 13
