"""Policy unit + property tests: PBM bucket geometry, Belady optimality,
eviction preferences, shared-chunk behaviour of ABM relevance functions."""

import random

import pytest
pytest.importorskip("hypothesis")  # optional dep: property tests need it
from hypothesis import given, settings, strategies as st

from repro.core import (
    ABM,
    BufferPool,
    Database,
    EngineConfig,
    LRUPolicy,
    OraclePolicy,
    PBMPolicy,
    ScanSpec,
    ScanState,
    simulate_belady,
)
from repro.core.pages import PageId


def make_db(n_tuples=100_000, cols=2, page_bytes=1 << 12):
    db = Database()
    db.add_table(
        "t",
        n_tuples=n_tuples,
        columns={f"c{i}": 1.0 for i in range(cols)},
        chunk_tuples=20_000,
        page_bytes=page_bytes,
    )
    return db


# ---------------------------------------------------------------- PBM ------

def test_time_to_bucket_monotone_and_bounded():
    p = PBMPolicy(time_slice=0.1, n_groups=5, buckets_per_group=4)
    prev = 0
    for i in range(2000):
        t = i * 0.01
        b = p.time_to_bucket(t)
        assert 0 <= b < p.nb
        assert b >= prev or b == p.nb - 1
        prev = max(prev, b)
    assert p.time_to_bucket(0.0) == 0
    assert p.time_to_bucket(1e9) == p.nb - 1


@settings(max_examples=100, deadline=None)
@given(st.floats(0, 1e6), st.integers(2, 8), st.integers(2, 8))
def test_time_to_bucket_property(t, groups, m):
    p = PBMPolicy(time_slice=0.05, n_groups=groups, buckets_per_group=m)
    b = p.time_to_bucket(t)
    assert 0 <= b < p.nb
    # bucket widths double per group: recompute the bucket's range and check
    g = b // m
    start = m * ((1 << g) - 1) * p.time_slice
    width = (1 << g) * p.time_slice
    lo = start + (b - g * m) * width
    if b < p.nb - 1:
        assert lo <= t + 1e-9
        assert t < lo + width + 1e-6


def test_pbm_evicts_furthest_future_first():
    db = make_db()
    near = ScanState(ScanSpec("t", ("c0",), ((0, 100_000),), tuple_rate=1e6), db)
    far = ScanState(ScanSpec("t", ("c1",), ((0, 100_000),), tuple_rate=1e3), db)
    p_near = near.plan[2][1]   # needed soon (fast scan)
    p_far = far.plan[20][1]    # needed late (slow scan, deep page)
    pool = BufferPool(capacity_bytes=p_near.size_bytes + p_far.size_bytes)
    pbm = PBMPolicy()
    pbm.attach(pool, 0.0)
    pbm.register_scan(near, 0.0)
    pbm.register_scan(far, 0.0)
    for pg in (p_near, p_far):
        pool.admit(pg)
        pbm.on_loaded(pg, 0.0)
    victims = pbm.choose_victims(p_far.size_bytes, set(), 0.0)
    assert victims and victims[0].pid == p_far.pid


def test_pbm_not_requested_evicted_first():
    db = make_db()
    scan = ScanState(ScanSpec("t", ("c0",), ((0, 100_000),), tuple_rate=1e6), db)
    wanted = scan.plan[0][1]
    unwanted = db.tables["t"].columns["c1"].pages[0]
    pool = BufferPool(capacity_bytes=wanted.size_bytes + unwanted.size_bytes)
    pbm = PBMPolicy()
    pbm.attach(pool, 0.0)
    pbm.register_scan(scan, 0.0)
    for pg in (wanted, unwanted):
        pool.admit(pg)
        pbm.on_loaded(pg, 0.0)
    victims = pbm.choose_victims(unwanted.size_bytes, set(), 0.0)
    assert victims[0].pid == unwanted.pid


def test_pbm_bucket_refresh_shifts_left():
    pbm = PBMPolicy(time_slice=0.1, n_groups=3, buckets_per_group=2)
    pool = BufferPool(capacity_bytes=1 << 30)
    pbm.attach(pool, 0.0)
    db = make_db()
    scan = ScanState(ScanSpec("t", ("c0",), ((0, 100_000),), tuple_rate=1e5), db)
    pbm.register_scan(scan, 0.0)
    page = scan.plan[-1][1]
    pool.admit(page)
    pbm.on_loaded(page, 0.0)
    b0 = pbm._meta[page.pid].bucket
    pbm.refresh_requested_buckets(0.35)   # 3 slices pass
    b1 = pbm._meta[page.pid].bucket
    assert b1 <= b0


# ------------------------------------------------------------- Belady ------

def _lru_trace_misses(trace, capacity):
    resident = []
    misses = 0
    for pid in trace:
        if pid in resident:
            resident.remove(pid)
            resident.append(pid)
            continue
        misses += 1
        if len(resident) >= capacity:
            resident.pop(0)
        resident.append(pid)
    return misses


@settings(max_examples=60, deadline=None)
@given(
    st.lists(st.integers(0, 12), min_size=5, max_size=200),
    st.integers(2, 8),
)
def test_belady_not_worse_than_lru(ref_ints, capacity):
    trace = [PageId("t", "c", i) for i in ref_ints]
    opt_misses, _ = simulate_belady(trace, capacity_pages=capacity)
    lru_misses = _lru_trace_misses(trace, capacity)
    assert opt_misses <= lru_misses


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 6), min_size=5, max_size=40), st.integers(2, 4),
       st.randoms())
def test_belady_not_worse_than_random(ref_ints, capacity, rnd):
    trace = [PageId("t", "c", i) for i in ref_ints]
    opt_misses, _ = simulate_belady(trace, capacity_pages=capacity)
    # random eviction baseline
    resident, misses = set(), 0
    for pid in trace:
        if pid in resident:
            continue
        misses += 1
        if len(resident) >= capacity:
            resident.discard(rnd.choice(sorted(resident, key=str)))
        resident.add(pid)
    assert opt_misses <= misses


def test_belady_exact_small_case():
    # classic: A B C A B C with capacity 2 -> OPT misses = 3 + 1 = 4? check
    ids = ["A", "B", "C", "A", "B", "C"]
    trace = [PageId("t", "c", ord(x)) for x in ids]
    misses, _ = simulate_belady(trace, capacity_pages=2)
    assert misses == 4  # A,B miss; C evicts B (A sooner); A hit; B miss; C hit


# ---------------------------------------------------------------- ABM ------

def test_abm_relevance_functions():
    db = make_db(n_tuples=100_000)
    pool = BufferPool(capacity_bytes=1 << 30)
    abm = ABM(db, pool)
    s1 = ScanState(ScanSpec("t", ("c0",), ((0, 100_000),)), db)
    s2 = ScanState(ScanSpec("t", ("c0",), ((0, 40_000),)), db)
    abm.register(s1, 0.0)
    abm.register(s2, 0.0)
    # chunk 0 interests both scans; chunk 4 only s1 -> load relevance higher
    assert abm.load_relevance(("t", 0)) > abm.load_relevance(("t", 4))
    # starved short query beats long non-starved on QueryRelevance
    assert abm.query_relevance(s2, starved=True) > abm.query_relevance(s1, starved=False)
    # UseRelevance prefers chunks fewer OTHERS want
    assert abm.use_relevance(("t", 4), s1) > abm.use_relevance(("t", 0), s1)


def test_abm_keep_vs_load_eviction_rule():
    db = make_db(n_tuples=100_000, page_bytes=1 << 12)
    # pool fits exactly one chunk's pages
    t = db.tables["t"]
    chunk_bytes = sum(p.size_bytes for p in t.chunk_pages(0, ("c0", "c1")))
    pool = BufferPool(capacity_bytes=chunk_bytes)
    abm = ABM(db, pool)
    s1 = ScanState(ScanSpec("t", ("c0", "c1"), ((0, 100_000),)), db)
    abm.register(s1, 0.0)
    dec = abm.next_load(0.0, starved={s1.scan_id})
    assert dec is not None
    for p in dec.pages:
        pool.admit(p)
    # chunk 0 resident & still wanted by s1; next load must NOT evict it for
    # an equally-relevant chunk (Keep >= Load -> denied) unless space exists
    dec2 = abm.next_load(0.0, starved=set())
    if dec2 is not None:
        assert all(v.pid not in {p.pid for p in dec.pages} for v in dec2.evict)
