"""Engine integration tests: completion, conservation, policy orderings."""

import pytest
pytest.importorskip("hypothesis")  # optional dep: property tests need it
from hypothesis import given, settings, strategies as st

from repro.core import EngineConfig, run_workload
from repro.core.workload import (
    make_lineitem_db,
    micro_accessed_bytes,
    micro_streams,
)

SCALE = 4_000_000  # tuples (1/45 of SF30): fast but non-trivial


@pytest.fixture(scope="module")
def db():
    return make_lineitem_db(scale_tuples=SCALE, page_bytes=16 << 10)


@pytest.fixture(scope="module")
def ws(db):
    return micro_accessed_bytes(db)


ALL_POLICIES = ["lru", "mru", "pbm", "opt", "cscan", "pbm_lru", "attach"]


@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_all_policies_complete(db, ws, policy):
    streams = micro_streams(db, n_streams=4, queries_per_stream=4, seed=11)
    cfg = EngineConfig(bandwidth=700e6, buffer_bytes=int(0.4 * ws),
                       sample_interval=0.5)
    r = run_workload(db, streams, policy, cfg)
    assert len(r.stream_times) == 4
    assert all(t > 0 for t in r.stream_times)
    assert len(r.query_latencies) == 16
    assert r.total_io_bytes > 0


def test_cold_run_loads_at_least_working_set(db, ws):
    streams = [[s for st_ in micro_streams(db, 1, 1, fraction=1.0, seed=1)
                for s in st_]]
    cfg = EngineConfig(bandwidth=1e9, buffer_bytes=2 * ws)
    r = run_workload(db, streams, "lru", cfg)
    spec = streams[0][0]
    t = db.tables[spec.table]
    expected = t.scan_bytes(spec.columns, *spec.ranges[0])
    assert r.total_io_bytes == expected  # big buffer: exactly one load each


def test_big_buffer_makes_policies_equal(db, ws):
    streams = micro_streams(db, n_streams=4, queries_per_stream=4, seed=5)
    ios = {}
    for pol in ("lru", "pbm", "opt"):
        cfg = EngineConfig(bandwidth=700e6, buffer_bytes=2 * ws)
        ios[pol] = run_workload(db, streams, pol, cfg).total_io_bytes
    assert ios["lru"] == ios["pbm"] == ios["opt"]


def test_policy_ordering_under_pressure(db, ws):
    """The paper's headline: PBM and CScans beat LRU at medium pressure."""
    streams = micro_streams(db, n_streams=8, queries_per_stream=8, seed=3)
    res = {}
    for pol in ("lru", "pbm", "cscan"):
        cfg = EngineConfig(bandwidth=700e6, buffer_bytes=int(0.4 * ws),
                           sample_interval=1.0, pbm_time_slice=0.01)
        res[pol] = run_workload(db, streams, pol, cfg)
    assert res["pbm"].total_io_bytes < res["lru"].total_io_bytes
    assert res["cscan"].total_io_bytes < res["lru"].total_io_bytes
    assert res["pbm"].avg_stream_time < res["lru"].avg_stream_time


def test_determinism(db, ws):
    streams = micro_streams(db, n_streams=2, queries_per_stream=3, seed=9)
    cfg = EngineConfig(bandwidth=700e6, buffer_bytes=int(0.3 * ws))
    a = run_workload(db, streams, "pbm", cfg)
    b = run_workload(db, streams, "pbm", cfg)
    assert a.total_io_bytes == b.total_io_bytes
    assert a.stream_times == b.stream_times


def test_trace_recording_matches_consumption(db, ws):
    streams = micro_streams(db, n_streams=2, queries_per_stream=2, seed=4)
    cfg = EngineConfig(bandwidth=700e6, buffer_bytes=int(0.4 * ws),
                       record_trace=True)
    r = run_workload(db, streams, "pbm", cfg)
    total_plan = sum(len(__import__("repro.core.scans", fromlist=["ScanState"])
                         .ScanState(s, db).plan)
                     for stream in streams for s in stream)
    assert len(r.trace) == total_plan


def test_sharing_samples_have_bytes(db, ws):
    streams = micro_streams(db, n_streams=8, queries_per_stream=4,
                            fraction=1.0, seed=2)
    cfg = EngineConfig(bandwidth=400e6, buffer_bytes=int(0.3 * ws),
                       sample_interval=0.25)
    r = run_workload(db, streams, "pbm", cfg)
    assert r.sharing_samples
    # with 8 full-table scans there must be moments with >= 2-way sharing
    assert any(k >= 2 for s in r.sharing_samples for k in s)
