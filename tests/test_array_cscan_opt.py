"""The two policies the ArrayPolicy redesign brought onto the batched
substrate: array-OPT (Belady on exact plan distances) and array-CScan
(the chunk-granular cooperative substrate).

* array-OPT vs the event ``OraclePolicy``: cold two-stream exactness
  (both oracles load exactly the union volume when nothing must be
  evicted) and the micro sweep within the validated bars;
* array-CScan: the paper's headline ordering (Fig 9) — CScan's stream
  time never loses to LRU at ANY buffer point — plus chunk-geometry
  invariants of the compiled spec.
"""

import numpy as np
import pytest

from repro.core import EngineConfig, run_workload
from repro.core.pages import Database
from repro.core.scans import ScanSpec
from repro.core.workload import (
    Q6_COLUMNS,
    make_lineitem_db,
    micro_accessed_bytes,
    micro_streams,
)
from repro.core.array_sim import (
    build_spec,
    compile_workload,
    run_workload_array,
)
from repro.core.array_sim.validate import ERROR_BARS, cross_validate_sweep


# ------------------------------------------- array-OPT vs OraclePolicy ----

def test_opt_cold_two_stream_exactness():
    """Two overlapping streams, pool big enough to never evict: both the
    event oracle and the array oracle must load exactly the union of
    accessed page bytes — the perfect-knowledge baseline admits no
    phantom or duplicated I/O."""
    db = make_lineitem_db(scale_tuples=4_000_000)
    t = db.tables["lineitem"]
    streams = [
        [ScanSpec("lineitem", Q6_COLUMNS, ((0, 3_000_000),),
                  tuple_rate=240e6)],
        [ScanSpec("lineitem", Q6_COLUMNS, ((1_000_000, 4_000_000),),
                  tuple_rate=120e6)],
    ]
    expected = t.scan_bytes(Q6_COLUMNS, 0, 4_000_000)  # union of ranges
    cfg = EngineConfig(bandwidth=700e6, buffer_bytes=256 << 20,
                       sample_interval=2.0, pbm_time_slice=0.0025)
    ev = run_workload(db, streams, "opt", cfg)
    ar = run_workload_array(db, streams, "opt", capacity_bytes=256 << 20,
                            bandwidth=700e6, time_slice=0.0025)
    assert ev.total_io_bytes == expected
    assert ar.total_io_bytes == pytest.approx(expected, rel=1e-6)
    assert not ar.extras["truncated"]


def test_opt_micro_sweep_within_bars():
    """Array-OPT vs event ``OraclePolicy`` across the validated micro
    buffer points (quick-pass scale): within ``ERROR_BARS`` on both
    paper metrics.  The array oracle deliberately holds its ranking
    stale on the slice cadence (see ``ArrayOPT``); these bars pin how
    much of the event oracle's churn that reproduces."""
    rows = cross_validate_sweep(fracs=(0.1, 0.4), scale=0.25,
                                policies=("opt",))
    assert len(rows) == 2
    for r in rows:
        bar = ERROR_BARS[(r["buffer_frac"], "opt")]
        assert not r["truncated"], r
        assert abs(r["stream_time_rel_err"]) <= bar, r
        assert abs(r["io_rel_err"]) <= bar, r


# ------------------------------------------- array-CScan ordering ---------

def test_cscan_never_loses_to_lru_at_any_buffer_point():
    """Fig 9's headline: cooperative scans dominate LRU at EVERY buffer
    size.  Run the array backend's full buffer sweep for both policies
    and assert the ordering point by point (2% tolerance for the
    CPU-bound top end, where both sit at the same floor)."""
    from benchmarks import microbench

    rows = microbench.sweep_array("buffer", ["cscan", "lru"], scale=0.1)
    by = {(r["point"], r["policy"]): r for r in rows}
    points = sorted({p for (p, _) in by})
    assert len(points) == 6            # every paper fraction, no skips
    for p in points:
        cs, lr = by[(p, "cscan")], by[(p, "lru")]
        assert not cs["truncated"] and not lr["truncated"], p
        assert cs["avg_stream_time_s"] <= lr["avg_stream_time_s"] * 1.02, \
            (p, cs["avg_stream_time_s"], lr["avg_stream_time_s"])
        assert cs["io_gb"] <= lr["io_gb"] * 1.02, (p, cs, lr)


def test_cscan_micro_point_within_bars():
    """One enforced micro cross-validation point for the cooperative
    substrate (the full sweep runs in validate.py / CI)."""
    rows = cross_validate_sweep(fracs=(0.2,), scale=0.25,
                                policies=("cscan",))
    (r,) = rows
    bar = ERROR_BARS[(0.2, "cscan")]
    assert abs(r["stream_time_rel_err"]) <= bar, r
    assert abs(r["io_rel_err"]) <= bar, r


# ------------------------------------------- chunk geometry ---------------

def test_compiled_chunk_geometry_matches_tables():
    """The compiler's global chunk layout mirrors ``Table.chunk_range``
    and ABM's page->chunk unique-ownership rule (a page belongs to the
    chunk containing its first tuple)."""
    db = Database()
    db.add_table("a", 1_000_000, {"x": 2.0, "y": 0.5},
                 chunk_tuples=100_000, page_bytes=128 << 10)
    db.add_table("b", 300_000, {"u": 4.0},
                 chunk_tuples=100_000, page_bytes=128 << 10)
    st = [[ScanSpec("a", ("x", "y"), ((0, 1_000_000),)),
           ScanSpec("b", ("u",), ((0, 300_000),))]]
    spec = compile_workload(db, st)
    assert spec.n_chunks == db.tables["a"].n_chunks + db.tables["b"].n_chunks
    # per-table chunk ranges laid out contiguously in table order
    a_ch = db.tables["a"].n_chunks
    np.testing.assert_array_equal(spec.chunk_table[:a_ch], 0)
    np.testing.assert_array_equal(spec.chunk_table[a_ch:], 1)
    for ch in range(a_ch):
        lo, hi = db.tables["a"].chunk_range(ch)
        assert spec.chunk_first[ch] == lo and spec.chunk_last[ch] == hi
    # ownership: every valid page's chunk contains its first tuple
    for gi in np.flatnonzero(spec.page_valid):
        ch = spec.page_chunk[gi]
        assert spec.chunk_table[ch] == spec.col_table[spec.page_col[gi]]
        assert spec.chunk_first[ch] <= spec.page_first[gi] \
            < spec.chunk_last[ch]


def test_build_spec_workloads_carry_chunk_geometry():
    """The single-table legacy entry point lowers through the compiler,
    so seed-shaped workloads can run the cooperative policy too."""
    db = make_lineitem_db(scale_tuples=2_000_000)
    streams = micro_streams(db, n_streams=2, queries_per_stream=2, seed=3)
    spec = build_spec(db, streams)
    assert spec.n_chunks == db.tables["lineitem"].n_chunks
    assert spec.page_chunk is not None
    ws = micro_accessed_bytes(db)
    r = run_workload_array(db, streams, "cscan", capacity_bytes=ws,
                           bandwidth=700e6, time_slice=0.005, spec=spec)
    assert r.total_loads > 0 and not r.extras["truncated"]
