from .kv_cache import PagePool, RequestKV, prefix_hash
from .engine import EngineStats, Request, ServingEngine

__all__ = [
    "EngineStats", "PagePool", "Request", "RequestKV", "ServingEngine",
    "prefix_hash",
]
