from .kv_cache import PagePool, RequestKV, prefix_hash
from .engine import EngineStats, Request, ServingEngine
from .policy_driver import (
    DecodeSchedule, PolicyDriver, ServingCScan, ServingLRU, ServingOPT,
    ServingPBM, ServingPolicy,
)

__all__ = [
    "DecodeSchedule", "EngineStats", "PagePool", "PolicyDriver", "Request",
    "RequestKV", "ServingCScan", "ServingEngine", "ServingLRU", "ServingOPT",
    "ServingPBM", "ServingPolicy", "prefix_hash",
]
