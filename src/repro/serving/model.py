"""PagedTinyLM: a small decoder LM that decodes *through* the page pool.

Integration glue between the three layers of the serving stack:
``kernels.paged_attention`` (compute) <- page tables from ``kv_cache``
(policy-managed pool) <- scheduled by ``engine`` (continuous batching).
Used by examples/serve_paged.py and the integration tests; production archs
would plug their own weights into the same layout.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from .engine import Request
from .kv_cache import PagePool


@dataclass
class TinyConfig:
    vocab: int = 512
    d_model: int = 64
    n_layers: int = 2
    n_heads: int = 2
    n_kv_heads: int = 1
    head_dim: int = 128
    page_size: int = 16
    n_pages: int = 128


class PagedTinyLM:
    def __init__(self, cfg: TinyConfig, seed: int = 0):
        self.cfg = cfg
        rng = np.random.default_rng(seed)
        d, h, hk, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        s = lambda *sh: jnp.asarray(rng.normal(0, 0.05, sh), jnp.float32)
        self.params = {
            "embed": s(cfg.vocab, d),
            "layers": [
                {
                    "wq": s(d, h * dh), "wk": s(d, hk * dh), "wv": s(d, hk * dh),
                    "wo": s(h * dh, d), "w1": s(d, 4 * d), "w2": s(4 * d, d),
                }
                for _ in range(cfg.n_layers)
            ],
        }
        # the physical page pool arrays, one per layer
        self.k_pages = [
            jnp.zeros((cfg.n_pages, cfg.page_size, hk, dh), jnp.float32)
            for _ in range(cfg.n_layers)
        ]
        self.v_pages = [
            jnp.zeros((cfg.n_pages, cfg.page_size, hk, dh), jnp.float32)
            for _ in range(cfg.n_layers)
        ]

    # ------------------------------------------------------------- helpers
    def _write_kv(self, layer: int, page_id: int, slot: int, k, v) -> None:
        self.k_pages[layer] = self.k_pages[layer].at[page_id, slot].set(k)
        self.v_pages[layer] = self.v_pages[layer].at[page_id, slot].set(v)

    def _forward_token(
        self, token: int, kv_pages: List[int], pos: int, write: bool = True
    ) -> jnp.ndarray:
        cfg = self.cfg
        x = self.params["embed"][token][None]          # (1, d)
        page = kv_pages[pos // cfg.page_size]
        slot = pos % cfg.page_size
        pt = jnp.asarray([kv_pages], jnp.int32)
        sl = jnp.asarray([pos + 1], jnp.int32)
        for li, lp in enumerate(self.params["layers"]):
            q = (x @ lp["wq"]).reshape(cfg.n_heads, cfg.head_dim)
            k = (x @ lp["wk"]).reshape(cfg.n_kv_heads, cfg.head_dim)
            v = (x @ lp["wv"]).reshape(cfg.n_kv_heads, cfg.head_dim)
            if write:
                self._write_kv(li, page, slot, k, v)
            att = ops.paged_attention(
                q[None], self.k_pages[li], self.v_pages[li], pt, sl
            )[0]                                        # (H, dh)
            x = x + att.reshape(1, -1) @ lp["wo"]
            x = x + jax.nn.gelu(x @ lp["w1"]) @ lp["w2"]
        logits = x @ self.params["embed"].T
        return logits[0]

    # ------------------------------------------------------- engine step_fn
    def prefill(self, req: Request) -> None:
        for i, tok in enumerate(req.prompt):
            self._forward_token(int(tok), req.kv.pages, i)

    def step_fn(self, reqs: Sequence[Request]) -> List[int]:
        out = []
        for req in reqs:
            if req.last_decode_step < 0:
                self.prefill(req)
                last_tok = req.prompt[-1]
            else:
                last_tok = req.generated[-1]
            pos = req.kv.length - 1   # slot already reserved by the engine
            logits = self._forward_token(int(last_tok), req.kv.pages, pos)
            out.append(int(jnp.argmax(logits)))
        return out
