"""Continuous-batching serving engine with registry-driven KV tiering.

The scheduler is where the paper's insight lands in serving: under HBM
oversubscription some request's pages must leave the pool, and the
scheduler *knows the future* — its own queue discloses which request will
run furthest in the future.  Eviction (preemption), spill (swap-out),
resume order and prefetch (swap-in ahead of need) are all delegated to a
:class:`~repro.serving.policy_driver.PolicyDriver` around a policy
resolved through ``repro.core.policy_registry`` — the SAME name table the
event engine and the batched array simulator use (``lru`` / ``pbm`` /
``cscan`` / ``opt``; see DESIGN.md §2: the paper's "unattainable" OPT
becomes attainable when the future is the scheduler's own plan).

Token generation is abstracted behind ``step_fn`` so the engine (page
management = the paper's contribution) is testable without a model;
``examples/serve_paged.py`` wires a real tiny model through
``kernels.paged_attention``.

Swap-in costs one engine step (``swap_delay``): a resumed request's pages
are in flight for that long before it decodes, unless the driver's
prepare-ahead stage already staged them while the batch was full — the
push-based prefetch half of the policy surface (zicIO blueprint: prepare
pages just before workers touch them).
"""

from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, List, Optional, Sequence, Union

from .kv_cache import PagePool, RequestKV
from .policy_driver import PolicyDriver, ServingPolicy

_req_ids = itertools.count()


@dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int
    rid: int = field(default_factory=lambda: next(_req_ids))
    generated: List[int] = field(default_factory=list)
    kv: Optional[RequestKV] = None
    last_decode_step: int = -1
    arrival_step: int = 0
    admitted_step: int = -1
    first_token_step: int = -1
    done_step: int = -1
    ready_step: int = 0        # swap-in transfer completes at this step
    prefetched: bool = False   # host pages staged back ahead of resume
    swapped: bool = False
    done: bool = False

    @property
    def remaining(self) -> int:
        return self.max_new_tokens - len(self.generated)


@dataclass
class EngineStats:
    steps: int = 0
    tokens_generated: int = 0
    prefills: int = 0
    preemptions: int = 0
    resumes: int = 0
    prefetched_resumes: int = 0
    shared_prefix_pages: int = 0
    swap_out_bytes: int = 0
    swap_in_bytes: int = 0


class ServingEngine:
    def __init__(
        self,
        pool: PagePool,
        step_fn: Callable[[Sequence[Request]], List[int]],
        policy: Union[str, ServingPolicy] = "pbm",
        max_batch: int = 8,
        swap_delay: int = 1,
        record_events: bool = False,
    ) -> None:
        if isinstance(policy, str):
            from repro.core import policy_registry
            policy = policy_registry.serving_policy(policy)
        self.driver = PolicyDriver(policy)
        self.policy = policy.name
        self.pool = pool
        self.step_fn = step_fn
        self.max_batch = max_batch
        self.swap_delay = swap_delay
        self.pending: Deque[Request] = deque()
        self.active: List[Request] = []
        self.swapped: Deque[Request] = deque()
        self.finished: List[Request] = []
        self.stats = EngineStats()
        self.token_gaps: List[int] = []   # steps between successive tokens
        self._decode_rate = 1.0  # tokens/step/request (measured)
        # structured scheduler events (admit/preempt/resume/prefetch with
        # the policy verdict attached) — the serving half of the obs tier;
        # serving_bench.py --trace renders them as a Perfetto track
        self.record_events = record_events
        self.events: List[dict] = []

    def _emit(self, kind: str, req: Optional[Request] = None, **args) -> None:
        if not self.record_events:
            return
        ev = {"step": self.stats.steps, "kind": kind, "policy": self.policy}
        if req is not None:
            ev["rid"] = req.rid
            ev["remaining"] = req.remaining
        ev.update(args)
        self.events.append(ev)

    # ---------------------------------------------------------------- admit
    def submit(self, req: Request) -> None:
        req.arrival_step = self.stats.steps
        self.pending.append(req)

    def _host_page_count(self, req: Request) -> int:
        return sum(1 for p in req.kv.pages if p < 0)

    def _resume(self, req: Request) -> bool:
        """Swap a preempted request's host pages back in; True on success."""
        mapping = self.pool.swap_in(req.kv.pages)
        if mapping is None:
            return False
        req.kv.pages = [mapping.get(p, p) for p in req.kv.pages]
        # prepared-ahead pages are already resident: no transfer to wait on
        req.ready_step = self.stats.steps + (
            0 if req.prefetched else self.swap_delay
        )
        self.stats.resumes += 1
        self.stats.prefetched_resumes += bool(req.prefetched)
        self._emit("resume", req, prefetched=req.prefetched,
                   ready_step=req.ready_step)
        req.prefetched = False
        req.swapped = False
        req.admitted_step = self.stats.steps
        self.swapped.remove(req)
        self.active.append(req)
        return True

    def _try_admit(self) -> None:
        # Admission control: swap-in/prefill happen only out of FREE pages —
        # preemption is reserved for *growth* of already-running requests
        # (step()), where the victim choice is the policy decision under
        # test.  Without this watermark the engine thrashes exactly like an
        # unthrottled buffer pool.
        watermark = max(2, len(self.active))
        # resume preempted requests first (they hold finished prefills); the
        # ORDER is the policy's resume_key — FIFO for lru, nearest-completion
        # first for pbm/opt, most-shared first for cscan
        while self.swapped and len(self.active) < self.max_batch:
            sched = self.driver.view(self)
            req = self.driver.next_resume(sched)
            need = self._host_page_count(req)
            if need and self.pool.free_count < need + watermark and self.active:
                break
            if not self._resume(req):
                if self.active:
                    break
                # empty machine and the policy's preferred candidate does
                # not fit the free pool (other swapped requests pin their
                # shared prefix pages resident) — forward progress demands
                # resuming SOMETHING: walk the policy's resume order and
                # take the first candidate that fits
                if not any(self._resume(cand)
                           for cand in self.driver.resume_order(sched)
                           if cand is not req):
                    break
                continue
        while self.pending and len(self.active) < self.max_batch:
            req = self.pending[0]
            need = len(req.prompt) // self.pool.page_size + 1
            if self.pool.free_count < need + watermark and self.active:
                break
            kv = RequestKV(self.pool, self.pool.page_size)
            shared = kv.attach_prefix(req.prompt)
            if shared < 0:
                kv.release_all()
                if self.active or not self._make_room():
                    break
                continue
            self.stats.shared_prefix_pages += shared
            req.kv = kv
            req.admitted_step = self.stats.steps
            req.ready_step = self.stats.steps
            self.stats.prefills += 1
            self._emit("admit", req, shared_prefix_pages=shared,
                       prompt_pages=need)
            self.pending.popleft()
            self.active.append(req)

    def _prefetch_ahead(self) -> None:
        """Push-based prepare-ahead (the zicIO half of the policy surface):
        while the batch is full, stage the next resume candidate's host
        pages back into FREE HBM so the swap-in delay is paid before a
        batch slot opens.  Strictly watermark-gated — prefetch never takes
        pages the active batch's growth would want next."""
        if len(self.active) < self.max_batch or not self.swapped:
            return
        req = self.driver.next_resume(self.driver.view(self))
        if req is None or req.prefetched:
            return
        need = self._host_page_count(req)
        if need == 0:
            return
        watermark = max(2, len(self.active))
        if self.pool.free_count < need + 2 * watermark:
            return
        mapping = self.pool.swap_in(req.kv.pages)
        if mapping is None:
            return
        req.kv.pages = [mapping.get(p, p) for p in req.kv.pages]
        req.prefetched = True
        self._emit("prefetch", req, pages=need)

    # ------------------------------------------------------------- preempt
    def _victim(self) -> Optional[Request]:
        # anti-ping-pong: a request admitted THIS step is not preemptible,
        # so each request swaps at most once per engine step.  The choice
        # among candidates is the registry policy's victim_key.
        cands = [r for r in self.active if r.admitted_step != self.stats.steps]
        return self.driver.choose_victim(cands, self.driver.view(self))

    def _make_room(self, for_swap_in: int = 0) -> bool:
        """Preempt until at least one HBM slot is actually freed.

        A victim whose pages are all shared prefix pages frees nothing
        (shared chunks stay resident); keep preempting further victims and
        report False if no candidate frees a slot."""
        progressed = False
        while not progressed:
            victim = self._victim()
            if victim is None:
                return False
            self.active.remove(victim)
            victim.swapped = True
            victim.prefetched = False
            mapping = self.pool.swap_out(victim.kv.pages)
            victim.kv.pages = [mapping.get(p, p) for p in victim.kv.pages]
            self.swapped.append(victim)
            self.stats.preemptions += 1
            self._emit("preempt", victim, freed_pages=len(mapping),
                       for_swap_in=bool(for_swap_in))
            progressed = bool(mapping)
        return True

    # ---------------------------------------------------------------- step
    def step(self) -> int:
        """One engine iteration: admit, decode one token per active request."""
        self._try_admit()
        self._prefetch_ahead()
        if not self.active:
            self.stats.steps += 1
            return 0
        # ensure every active request has a slot for one more token
        runnable: List[Request] = []
        for req in list(self.active):
            if req.ready_step > self.stats.steps:
                continue  # swap-in transfer still in flight
            if req.kv.append_tokens(1):
                runnable.append(req)
            else:
                if not self._make_room():
                    break
                if req.kv.append_tokens(1):
                    runnable.append(req)
        # a runnable request may have been chosen as a growth victim for a
        # later request in the same pass — only decode those still active
        runnable = [r for r in runnable if not r.swapped]
        new_tokens = self.step_fn(runnable)
        for req, tok in zip(runnable, new_tokens):
            req.generated.append(int(tok))
            if req.first_token_step < 0:
                req.first_token_step = self.stats.steps
            else:
                self.token_gaps.append(
                    self.stats.steps - req.last_decode_step
                )
            req.last_decode_step = self.stats.steps
            if req.remaining <= 0:
                req.done = True
                req.done_step = self.stats.steps
                req.kv.release_all()
                self.active.remove(req)
                self.finished.append(req)
        self.stats.steps += 1
        self.stats.tokens_generated += len(runnable)
        self._decode_rate = 0.9 * self._decode_rate + 0.1 * max(len(runnable), 1) / max(
            len(self.active) + len(self.swapped), 1
        )
        self.stats.swap_out_bytes = self.pool.swap_out_bytes
        self.stats.swap_in_bytes = self.pool.swap_in_bytes
        return len(runnable)

    def run_to_completion(self, max_steps: int = 100_000) -> EngineStats:
        while (self.pending or self.active or self.swapped) and self.stats.steps < max_steps:
            self.step()
        return self.stats
