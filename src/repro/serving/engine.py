"""Continuous-batching serving engine with policy-driven KV tiering.

The scheduler is where the paper's insight lands in serving: under HBM
oversubscription some request's pages must leave the pool, and the
scheduler *knows the future* — its own queue discloses which request will
run furthest in the future.  Three interchangeable preemption policies:

* ``lru``    — preempt the least-recently-decoded active request (classic);
* ``pbm``    — preempt the request with the largest estimated time to next
  schedule slot (queue position / measured decode rate) — the paper's
  time-of-next-consumption estimate;
* ``belady`` — preempt the request that is *provably* scheduled furthest
  (exact queue order) — OPT, implementable here because the scheduler is
  the oracle (DESIGN.md §2: the paper's "unattainable" OPT becomes
  attainable when the future is the scheduler's own plan).

Token generation is abstracted behind ``step_fn`` so the engine (page
management = the paper's contribution) is testable without a model;
``examples/serve_paged.py`` wires a real tiny model through
``kernels.paged_attention``.
"""

from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

from .kv_cache import PagePool, RequestKV

_req_ids = itertools.count()


@dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int
    rid: int = field(default_factory=lambda: next(_req_ids))
    generated: List[int] = field(default_factory=list)
    kv: Optional[RequestKV] = None
    last_decode_step: int = -1
    arrival_step: int = 0
    admitted_step: int = -1
    swapped: bool = False
    done: bool = False

    @property
    def remaining(self) -> int:
        return self.max_new_tokens - len(self.generated)


@dataclass
class EngineStats:
    steps: int = 0
    tokens_generated: int = 0
    prefills: int = 0
    preemptions: int = 0
    shared_prefix_pages: int = 0
    swap_out_bytes: int = 0
    swap_in_bytes: int = 0


class ServingEngine:
    def __init__(
        self,
        pool: PagePool,
        step_fn: Callable[[Sequence[Request]], List[int]],
        policy: str = "pbm",
        max_batch: int = 8,
    ) -> None:
        assert policy in ("lru", "pbm", "belady")
        self.pool = pool
        self.step_fn = step_fn
        self.policy = policy
        self.max_batch = max_batch
        self.pending: Deque[Request] = deque()
        self.active: List[Request] = []
        self.swapped: Deque[Request] = deque()
        self.finished: List[Request] = []
        self.stats = EngineStats()
        self._decode_rate = 1.0  # tokens/step/request (measured)

    # ---------------------------------------------------------------- admit
    def submit(self, req: Request) -> None:
        req.arrival_step = self.stats.steps
        self.pending.append(req)

    def _try_admit(self) -> None:
        # Admission control: swap-in/prefill happen only out of FREE pages —
        # preemption is reserved for *growth* of already-running requests
        # (step()), where the victim choice is the policy decision under
        # test.  Without this watermark the engine thrashes exactly like an
        # unthrottled buffer pool.
        watermark = max(2, len(self.active))
        # resume swapped requests first (they block the queue's head)
        while self.swapped and len(self.active) < self.max_batch:
            req = self.swapped[0]
            if self.pool.free_count < len(req.kv.pages) + watermark and self.active:
                break
            mapping = self.pool.swap_in(req.kv.pages)
            if mapping is None:
                if self.active or not self._make_room(for_swap_in=len(req.kv.pages)):
                    break
                continue
            req.kv.pages = [mapping.get(p, p) for p in req.kv.pages]
            req.swapped = False
            req.admitted_step = self.stats.steps
            self.swapped.popleft()
            self.active.append(req)
        while self.pending and len(self.active) < self.max_batch:
            req = self.pending[0]
            need = len(req.prompt) // self.pool.page_size + 1
            if self.pool.free_count < need + watermark and self.active:
                break
            kv = RequestKV(self.pool, self.pool.page_size)
            shared = kv.attach_prefix(req.prompt)
            if shared < 0:
                kv.release_all()
                if self.active or not self._make_room():
                    break
                continue
            self.stats.shared_prefix_pages += shared
            req.kv = kv
            req.admitted_step = self.stats.steps
            self.stats.prefills += 1
            self.pending.popleft()
            self.active.append(req)

    # ------------------------------------------------------------- preempt
    def _victim(self) -> Optional[Request]:
        # anti-ping-pong: a request admitted THIS step is not preemptible,
        # so each request swaps at most once per engine step.
        cands = [r for r in self.active if r.admitted_step != self.stats.steps]
        if not cands:
            return None
        if self.policy == "lru":
            return min(cands, key=lambda r: r.last_decode_step)
        # next consumption time = when this request would next be scheduled.
        # With continuous batching every active request decodes each step, so
        # the victim is the one whose *completion* (then re-queue of others)
        # is furthest — approximated by remaining work (pbm: estimated via
        # measured rate; belady: exact remaining tokens).
        if self.policy == "pbm":
            rate = max(self._decode_rate, 1e-6)
            return max(cands, key=lambda r: r.remaining / rate)
        return max(cands, key=lambda r: r.remaining)   # belady

    def _make_room(self, for_swap_in: int = 0) -> bool:
        """Preempt until at least one HBM slot is actually freed.

        A victim whose pages are all shared prefix pages frees nothing
        (shared chunks stay resident); keep preempting further victims and
        report False if no candidate frees a slot."""
        progressed = False
        while not progressed:
            victim = self._victim()
            if victim is None:
                return False
            self.active.remove(victim)
            victim.swapped = True
            mapping = self.pool.swap_out(victim.kv.pages)
            victim.kv.pages = [mapping.get(p, p) for p in victim.kv.pages]
            self.swapped.append(victim)
            self.stats.preemptions += 1
            progressed = bool(mapping)
        return True

    # ---------------------------------------------------------------- step
    def step(self) -> int:
        """One engine iteration: admit, decode one token per active request."""
        self._try_admit()
        if not self.active:
            self.stats.steps += 1
            return 0
        # ensure every active request has a slot for one more token
        runnable: List[Request] = []
        for req in list(self.active):
            if req.kv.append_tokens(1):
                runnable.append(req)
            else:
                if not self._make_room():
                    break
                if req.kv.append_tokens(1):
                    runnable.append(req)
        # a runnable request may have been chosen as a growth victim for a
        # later request in the same pass — only decode those still active
        runnable = [r for r in runnable if not r.swapped]
        new_tokens = self.step_fn(runnable)
        for req, tok in zip(runnable, new_tokens):
            req.generated.append(int(tok))
            req.last_decode_step = self.stats.steps
            if req.remaining <= 0:
                req.done = True
                req.kv.release_all()
                self.active.remove(req)
                self.finished.append(req)
        self.stats.steps += 1
        self.stats.tokens_generated += len(runnable)
        self._decode_rate = 0.9 * self._decode_rate + 0.1 * max(len(runnable), 1) / max(
            len(self.active) + len(self.swapped), 1
        )
        self.stats.swap_out_bytes = self.pool.swap_out_bytes
        self.stats.swap_in_bytes = self.pool.swap_in_bytes
        return len(runnable)

    def run_to_completion(self, max_steps: int = 100_000) -> EngineStats:
        while (self.pending or self.active or self.swapped) and self.stats.steps < max_steps:
            self.step()
        return self.stats
