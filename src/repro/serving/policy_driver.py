"""Serving-side policy driver: the policy registry on the decode path.

This is where the reproduction's policy API leaves the simulator and lands
in the inference stack (DESIGN.md §2).  The continuous-batching scheduler
*is* the paper's "known future": its own queue discloses exactly which
request decodes next, which resumes when, and how long every page stays in
the access sequence.  The driver translates that schedule into the same
policy surface both simulation backends already use — one name table
(``repro.core.policy_registry``), four paper policies:

* ``lru``   — preempt the least-recently-decoded request (classic baseline);
* ``pbm``   — the paper's time-of-next-consumption estimate: each request's
  remaining tokens over the *measured* decode rate, quantised into the
  PBM priority-bucket geometry (paper Fig. 10) — victims come from the
  furthest bucket, LRU inside a bucket;
* ``cscan`` — CScan-style relevance: prefix-shared refcounted pages are the
  paper's shared chunks (many consumers still want them — spilling their
  owner frees nothing and loses sharing), so the victim is the request
  whose footprint is most *exclusive* per freed slot;
* ``opt``   — exact Belady distances from ``Request.remaining``: the
  scheduler is the oracle, so the paper's "unattainable" OPT is attainable.

Per-page next-access estimates (:meth:`DecodeSchedule.page_horizons`):
while a request is scheduled, paged attention re-reads its whole page
table every decode step — the next access of every resident page is the
very next step.  What differentiates victims is the **occupancy horizon**:
how long a page stays in the future access sequence, which is its owner's
remaining decode work (estimated for PBM, exact for OPT) and, for shared
prefix pages, the *furthest* of the sharers' horizons.

The driver also owns the prefetch half — the push-based prepare-ahead
design of the zicIO / shared-IO line (PAPERS.md arXiv 1905.07113): while
the batch is full, the next resume candidate's host pages are staged back
into free HBM *before* a batch slot opens, so its swap-in delay is paid in
the shadow of other requests' decode steps.  Which request resumes next is
itself a policy decision (:meth:`ServingPolicy.resume_key`): LRU keeps
FIFO arrival order; PBM/OPT resume the request with the nearest
(estimated/exact) completion first — the known future says it frees the
pool soonest; CScan resumes the request with the most shared pages first
(highest keep-relevance per slot).
"""

from __future__ import annotations

import math
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence

if TYPE_CHECKING:  # pragma: no cover - type-only, avoids an import cycle
    from .engine import Request
    from .kv_cache import PagePool

__all__ = [
    "DecodeSchedule", "PolicyDriver", "ServingPolicy",
    "ServingCScan", "ServingLRU", "ServingOPT", "ServingPBM",
    "pbm_bucket",
]

#: PBM bucket geometry on the serving path: ``slice`` is the serving
#: analogue of the simulator's time slice, measured in engine steps.
SLICE_STEPS = 4.0
N_GROUPS = 10
BUCKETS_PER_GROUP = 2


def pbm_bucket(eta_steps: float, slice_steps: float = SLICE_STEPS,
               n_groups: int = N_GROUPS,
               m: int = BUCKETS_PER_GROUP) -> int:
    """The paper's ``TimeToBucketNumber`` (Fig. 10) for one scalar eta:
    group ``g`` covers slice offsets ``[m*(2^g - 1), m*(2^(g+1) - 1))``
    with bucket width ``2^g`` slices — log-spaced lookahead, exactly the
    geometry the simulator's vectorised ``time_to_bucket`` implements."""
    s = max(eta_steps, 0.0) / slice_steps
    g = int(math.floor(math.log2(s / m + 1.0)))
    g = min(max(g, 0), n_groups - 1)
    start = m * ((1 << g) - 1)
    width = 1 << g
    idx = int((s - start) // width)
    return min(max(g * m + idx, 0), n_groups * m - 1)


class DecodeSchedule:
    """One step's view of the engine's own future.

    Built by the driver from live engine state (never carried): the active
    batch, the swapped queue, the measured decode rate, and the page pool's
    refcounts.  Policies read the future through this object only."""

    def __init__(self, *, step: int, rate: float,
                 active: Sequence["Request"], swapped: Sequence["Request"],
                 pool: "PagePool"):
        self.step = step
        self.rate = max(rate, 1e-6)    # measured tokens/step/request
        self.active = active
        self.swapped = swapped
        self.pool = pool

    # ------------------------------------------------- request horizons --
    def remaining_tokens(self, req: "Request") -> int:
        """Exact Belady distance: the scheduler's own plan says precisely
        how many decode steps this request's pages stay in the access
        sequence (``max_new_tokens`` is the serving contract)."""
        return req.remaining

    def eta_steps(self, req: "Request") -> float:
        """PBM's estimate of the same horizon: remaining tokens over the
        *measured* decode rate (the serving analogue of the simulator's
        per-slice speed estimator)."""
        return req.remaining / self.rate

    # ---------------------------------------------------- page estimates --
    def sharers(self, pid: int) -> int:
        """Refcount of a page — how many requests' page tables hold it.
        Shared prompt-prefix pages are the paper's shared chunks."""
        m = self.pool.meta.get(pid)
        return 0 if m is None else m.ref_count

    def page_horizons(self, exact: bool = False) -> Dict[int, float]:
        """Per-page occupancy horizon over every scheduled request's pages:
        steps until the page leaves the future access sequence.  A shared
        page inherits the furthest sharer's horizon (some consumer still
        reads it until then)."""
        out: Dict[int, float] = {}
        for req in self.active:
            if req.kv is None:
                continue
            h = float(self.remaining_tokens(req)) if exact \
                else self.eta_steps(req)
            for pid in req.kv.pages:
                out[pid] = max(out.get(pid, 0.0), h)
        return out


class ServingPolicy:
    """One buffer policy on the serving path.

    ``victim_key`` orders preemption (higher = preempt first) among the
    engine's candidates; ``resume_key`` orders swap-in (lower = resume
    first) over the swapped queue.  Keys may be tuples (lexicographic).
    """

    name: str = "?"

    def victim_key(self, req: "Request", sched: DecodeSchedule):
        raise NotImplementedError

    def resume_key(self, req: "Request", sched: DecodeSchedule):
        return (req.arrival_step, req.rid)          # FIFO

    def __repr__(self) -> str:  # pragma: no cover
        return f"{type(self).__name__}({self.name})"


class ServingLRU(ServingPolicy):
    """Classic baseline: preempt the least-recently-decoded request, resume
    in arrival order.  Under continuous batching every active request
    decodes every step, so "least recent" degenerates to "longest in the
    batch" — usually the request *closest* to completion, which must then
    resume almost immediately: the ping-pong the predictive policies
    avoid."""

    name = "lru"

    def victim_key(self, req, sched):
        return (sched.step - req.last_decode_step, -req.rid)


class ServingPBM(ServingPolicy):
    """Predictive Buffer Manager: remaining tokens over the measured decode
    rate, pushed through the paper's priority-bucket geometry.  Victims
    come from the furthest bucket (LRU order inside a bucket — the
    bucketed timeline blurs priorities only within one bucket, exactly
    like the simulator); resumes take the nearest-completion bucket
    first."""

    name = "pbm"

    def victim_key(self, req, sched):
        return (pbm_bucket(sched.eta_steps(req)),
                sched.step - req.last_decode_step, -req.rid)

    def resume_key(self, req, sched):
        return (pbm_bucket(sched.eta_steps(req)), req.arrival_step, req.rid)


class ServingCScan(ServingPolicy):
    """CScan-style relevance over prefix-shared refcounted pages.

    KeepRelevance maps to refcounts: a shared prefix page is a chunk many
    consumers still want — ``PagePool.swap_out`` keeps it resident anyway,
    so preempting its owner frees nothing for it and costs a preemption.
    The victim is the request that frees the most *exclusive* slots per
    unit of lost relevance (most exclusive pages first, fewest shared
    pages as the penalty), ties broken toward the furthest completion;
    resumes take the most-shared request first (highest keep-relevance
    per occupied slot)."""

    name = "cscan"

    @staticmethod
    def _split(req, sched):
        pages = req.kv.pages if req.kv is not None else []
        shared = sum(1 for p in pages if sched.sharers(p) > 1)
        return len(pages) - shared, shared

    def victim_key(self, req, sched):
        exclusive, shared = self._split(req, sched)
        return (exclusive - shared, sched.remaining_tokens(req), -req.rid)

    def resume_key(self, req, sched):
        exclusive, shared = self._split(req, sched)
        return (-shared, req.arrival_step, req.rid)


class ServingOPT(ServingPolicy):
    """Belady, attainable: the decode schedule is the oracle.  Preempt the
    request whose pages stay in the access sequence longest (exact
    remaining tokens); resume the one that completes soonest."""

    name = "opt"

    def victim_key(self, req, sched):
        return (sched.remaining_tokens(req), -req.rid)

    def resume_key(self, req, sched):
        return (sched.remaining_tokens(req), req.arrival_step, req.rid)


class PolicyDriver:
    """Glue between the engine and a registry :class:`ServingPolicy`:
    builds the :class:`DecodeSchedule` view each step and answers the
    three questions the engine asks — whom to preempt, whom to resume
    next, and whether to prepare the next resume ahead of need."""

    def __init__(self, policy: ServingPolicy):
        self.policy = policy

    def view(self, engine) -> DecodeSchedule:
        return DecodeSchedule(
            step=engine.stats.steps, rate=engine._decode_rate,
            active=engine.active, swapped=engine.swapped, pool=engine.pool,
        )

    def choose_victim(self, candidates: Sequence["Request"],
                      sched: DecodeSchedule) -> Optional["Request"]:
        if not candidates:
            return None
        return max(candidates,
                   key=lambda r: self.policy.victim_key(r, sched))

    def next_resume(self, sched: DecodeSchedule) -> Optional["Request"]:
        if not sched.swapped:
            return None
        return min(sched.swapped,
                   key=lambda r: self.policy.resume_key(r, sched))

    def resume_order(self, sched: DecodeSchedule) -> List["Request"]:
        """The full swapped queue in the policy's resume order — the
        engine walks it when the preferred candidate does not fit the
        free pool (forward-progress fallback)."""
        return sorted(sched.swapped,
                      key=lambda r: self.policy.resume_key(r, sched))
