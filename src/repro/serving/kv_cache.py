"""Paged KV cache: page pool, per-request page tables, prefix sharing.

The serving-side realisation of the paper's storage model (DESIGN.md §2):

* the HBM page pool is the buffer pool; a decode request's KV pages are the
  pages of its "scan";
* prompt-prefix pages shared by many requests are the paper's **shared
  chunks** (snapshot common prefixes, §2.1): refcounted, evicted last;
* pages of preempted requests can spill to the host tier (swap), the
  decision being the buffer-management policy under test (see scheduler).

The pool hands out *page ids* compatible with ``kernels.paged_attention``'s
page-table layout; actual K/V tensors live in one (n_pages, page_size, Hk,
dh) array per layer group, owned by whoever runs the model.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple


@dataclass
class PageMeta:
    page_id: int
    ref_count: int = 0
    prefix_hash: Optional[bytes] = None   # set for shared prompt pages
    on_host: bool = False                 # spilled to host tier


class PagePool:
    """Fixed-size pool of KV pages with refcounts and a host spill tier."""

    def __init__(self, n_pages: int, page_size: int, page_bytes: int):
        self.n_pages = n_pages
        self.page_size = page_size
        self.page_bytes = page_bytes
        self.free: List[int] = list(range(n_pages))
        self.meta: Dict[int, PageMeta] = {}
        self.prefix_index: Dict[bytes, int] = {}   # prefix hash -> page id
        self.swap_out_bytes = 0
        self.swap_in_bytes = 0
        self._next_host_uid = -1

    # ------------------------------------------------------------- alloc
    @property
    def free_count(self) -> int:
        return len(self.free)

    @property
    def used_count(self) -> int:
        return self.n_pages - len(self.free)

    def alloc(self, prefix_hash: Optional[bytes] = None) -> Optional[int]:
        if prefix_hash is not None and prefix_hash in self.prefix_index:
            pid = self.prefix_index[prefix_hash]
            m = self.meta[pid]
            if not m.on_host:
                m.ref_count += 1
                return pid            # shared-chunk hit: no new page
        if not self.free:
            return None
        pid = self.free.pop()
        self.meta[pid] = PageMeta(page_id=pid, ref_count=1, prefix_hash=prefix_hash)
        if prefix_hash is not None:
            self.prefix_index[prefix_hash] = pid
        return pid

    def release(self, pid: int) -> None:
        m = self.meta.get(pid)
        if m is None:
            return
        m.ref_count -= 1
        if m.ref_count <= 0:
            # two pages can carry the same prefix hash (a spilled prefix
            # page's host copy plus a fresh HBM page allocated for the same
            # prefix while it was away) — only the page the index actually
            # points at may drop the entry
            if m.prefix_hash is not None and \
                    self.prefix_index.get(m.prefix_hash) == pid:
                self.prefix_index.pop(m.prefix_hash, None)
            del self.meta[pid]
            if pid >= 0:  # host uids (< 0) are not HBM slots
                self.free.append(pid)

    # -------------------------------------------------------------- spill
    # Host-tier pages get fresh NEGATIVE uids so a freed HBM slot can be
    # reallocated without aliasing the host copy's identity.  Shared prefix
    # pages (ref_count > 1) are never spilled — they are the paper's shared
    # chunks: other scans still want them, keep them hot.
    def swap_out(self, pids: Sequence[int]) -> Dict[int, int]:
        """Spill exclusively-owned pages to host. Returns {hbm_id: host_uid}."""
        mapping: Dict[int, int] = {}
        for pid in pids:
            m = self.meta.get(pid)
            if m is None or m.on_host or pid < 0:
                continue
            if m.ref_count > 1:
                continue  # shared prefix page stays resident
            uid = self._next_host_uid
            self._next_host_uid -= 1
            del self.meta[pid]
            m.on_host = True
            m.page_id = uid
            self.meta[uid] = m
            if m.prefix_hash is not None:
                self.prefix_index.pop(m.prefix_hash, None)
            self.free.append(pid)
            mapping[pid] = uid
            self.swap_out_bytes += self.page_bytes
        return mapping

    def swap_in(self, uids: Sequence[int]) -> Optional[Dict[int, int]]:
        """Bring host pages back. Returns {host_uid: hbm_id}; None if no room."""
        need = [u for u in uids if u < 0 and u in self.meta]
        if len(self.free) < len(need):
            return None
        mapping: Dict[int, int] = {}
        for uid in need:
            m = self.meta.pop(uid)
            slot = self.free.pop()
            m.on_host = False
            m.page_id = slot
            self.meta[slot] = m
            if m.prefix_hash is not None:
                # a fresh page may have taken this prefix while the copy
                # was on host — keep the established mapping, the returned
                # copy serves only its own request
                self.prefix_index.setdefault(m.prefix_hash, slot)
            mapping[uid] = slot
            self.swap_in_bytes += self.page_bytes
        return mapping


def prefix_hash(tokens: Sequence[int]) -> bytes:
    return hashlib.blake2b(bytes(str(list(tokens)), "utf8"), digest_size=16).digest()


@dataclass
class RequestKV:
    """Per-request page table over the pool."""

    pool: PagePool
    page_size: int
    pages: List[int] = field(default_factory=list)
    shared_prefix_pages: int = 0
    length: int = 0

    def append_tokens(self, n: int) -> bool:
        """Ensure capacity for n more tokens; allocate pages as needed."""
        target = self.length + n
        while len(self.pages) * self.page_size < target:
            pid = self.pool.alloc()
            if pid is None:
                return False
            self.pages.append(pid)
        self.length = target
        return True

    def attach_prefix(self, prompt: Sequence[int]) -> int:
        """Allocate prompt pages, sharing full pages with identical prefixes.

        Returns the number of *shared* (reused) pages — the paper's shared
        chunks metric."""
        shared = 0
        full_pages = len(prompt) // self.page_size
        for p in range(full_pages):
            h = prefix_hash(prompt[: (p + 1) * self.page_size])
            before = self.pool.prefix_index.get(h)
            pid = self.pool.alloc(prefix_hash=h)
            if pid is None:
                return -1
            if before is not None and before == pid:
                shared += 1
            self.pages.append(pid)
        rem = len(prompt) - full_pages * self.page_size
        if rem:
            pid = self.pool.alloc()
            if pid is None:
                return -1
            self.pages.append(pid)
        self.length = len(prompt)
        self.shared_prefix_pages = shared
        return shared

    def release_all(self) -> None:
        for pid in self.pages:
            self.pool.release(pid)
        self.pages.clear()
        self.length = 0
