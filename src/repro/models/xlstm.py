"""xLSTM blocks: chunkwise-parallel mLSTM + sequential sLSTM (arXiv:2405.04517).

TPU adaptation notes (DESIGN.md §6): the mLSTM matrix-memory recurrence
``C_t = f_t C_{t-1} + i_t v_t k_t^T`` is a gated-linear-attention form, so we
use the same chunked decomposition as SSD — intra-chunk dense matmuls on the
MXU, inter-chunk state carry via ``lax.scan`` (:func:`gla_chunked`).  sLSTM
has a true sequential dependency through its block-diagonal recurrent
weights; it stays a ``lax.scan`` over time (the paper itself says sLSTM is
not parallelizable), which XLA pipelines fine at the 1-in-8 cadence
xLSTM-350m uses.  Both carry O(1) state for decode — the reason xlstm runs
the ``long_500k`` cell that full-attention archs skip.
"""

from __future__ import annotations

from typing import Any, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from .layers import ParamSpec, rms_norm


# ---------------------------------------------------------------------------
# chunked gated linear attention (mLSTM core)
# ---------------------------------------------------------------------------

def gla_chunked(
    q: jax.Array,    # (B, T, H, K)
    k: jax.Array,    # (B, T, H, K)
    v: jax.Array,    # (B, T, H, P)
    a: jax.Array,    # (B, T, H) per-step decay in (0, 1]
    i: jax.Array,    # (B, T, H) input-gate scale
    chunk: int = 256,
    c0: Optional[jax.Array] = None,
    n0: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """y_t = (q_t . C_t) / max(|q_t . n_t|, 1);  C_t = a_t C + i_t k_t v_t^T.

    Returns (y, C_final (B,H,K,P), n_final (B,H,K)).
    """
    B, T, H, K = q.shape
    P = v.shape[-1]
    nc = max(1, (T + chunk - 1) // chunk)
    pad = nc * chunk - T
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        i = jnp.pad(i, ((0, 0), (0, pad), (0, 0)))
    f32 = jnp.float32
    qc = q.reshape(B, nc, chunk, H, K).astype(f32) * (K ** -0.5)
    kc = k.reshape(B, nc, chunk, H, K).astype(f32)
    vc = v.reshape(B, nc, chunk, H, P).astype(f32)
    ac = a.reshape(B, nc, chunk, H).astype(f32)
    ic = i.reshape(B, nc, chunk, H).astype(f32)

    loga = jnp.log(jnp.clip(ac, 1e-20))
    cum = jnp.cumsum(loga, axis=2)                        # (B,nc,Q,H)
    total = cum[:, :, -1:, :]
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # (B,nc,Q,S,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(li), 0.0)
    qk = jnp.einsum("bnchk,bnshk->bncsh", qc, kc)
    w = qk * L * ic[:, :, None, :, :]                     # gated scores
    y_intra = jnp.einsum("bncsh,bnshp->bnchp", w, vc)
    nrm_intra = jnp.einsum("bncsh,bnsh->bnch", w, jnp.ones_like(ic))

    decay_to_end = jnp.exp(total - cum) * ic              # (B,nc,Q,H)
    chunk_state = jnp.einsum("bnshk,bnsh,bnshp->bnhkp", kc, decay_to_end, vc)
    chunk_norm = jnp.einsum("bnshk,bnsh->bnhk", kc, decay_to_end)
    chunk_decay = jnp.exp(total[:, :, 0, :])              # (B,nc,H)

    def carry(cn, inp):
        (C, n) = cn
        cs, cn_, cd = inp
        C_in, n_in = C, n
        C = C * cd[:, :, None, None] + cs
        n = n * cd[:, :, None] + cn_
        return (C, n), (C_in, n_in)

    if c0 is None:
        c0 = jnp.zeros((B, H, K, P), f32)
    if n0 is None:
        n0 = jnp.zeros((B, H, K), f32)
    cs_t = jnp.moveaxis(chunk_state, 1, 0)
    cn_t = jnp.moveaxis(chunk_norm, 1, 0)
    cd_t = jnp.moveaxis(chunk_decay, 1, 0)
    (C_f, n_f), (C_prev, n_prev) = jax.lax.scan(carry, (c0, n0), (cs_t, cn_t, cd_t))
    C_prev = jnp.moveaxis(C_prev, 0, 1)                   # (B,nc,H,K,P)
    n_prev = jnp.moveaxis(n_prev, 0, 1)                   # (B,nc,H,K)

    dstart = jnp.exp(cum)                                 # (B,nc,Q,H)
    y_inter = jnp.einsum("bnchk,bnhkp,bnch->bnchp", qc, C_prev, dstart)
    nrm_inter = jnp.einsum("bnchk,bnhk,bnch->bnch", qc, n_prev, dstart)
    y = y_intra + y_inter
    nrm = nrm_intra + nrm_inter
    y = y / jnp.maximum(jnp.abs(nrm), 1.0)[..., None]
    y = y.reshape(B, nc * chunk, H, P)[:, :T]
    return y.astype(v.dtype), C_f, n_f


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------

def mlstm_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    di = 2 * d  # proj factor 2
    h = cfg.n_heads
    return {
        "w_up": ParamSpec((d, 2 * di), ("embed", "mlp")),
        "wq": ParamSpec((di, di), ("mlp", "q_dim")),
        "wk": ParamSpec((di, di), ("mlp", "q_dim")),
        "wv": ParamSpec((di, di), ("mlp", "q_dim")),
        "w_if": ParamSpec((di, 2 * h), ("mlp", None), init="zeros"),
        "b_if": ParamSpec((2 * h,), (None,), init="zeros"),
        "w_down": ParamSpec((di, d), ("mlp", "embed")),
    }


def mlstm_block(params: Mapping[str, jax.Array], x: jax.Array, cfg: ArchConfig) -> jax.Array:
    b, t, d = x.shape
    h = cfg.n_heads
    di = 2 * d
    up = jnp.einsum("btd,de->bte", x, params["w_up"])
    xi, z = jnp.split(up, 2, axis=-1)
    q = jnp.einsum("bte,ef->btf", xi, params["wq"]).reshape(b, t, h, di // h)
    k = jnp.einsum("bte,ef->btf", xi, params["wk"]).reshape(b, t, h, di // h)
    v = jnp.einsum("bte,ef->btf", xi, params["wv"]).reshape(b, t, h, di // h)
    gates = jnp.einsum("bte,eg->btg", xi, params["w_if"]) + params["b_if"]
    ig, fg = jnp.split(gates, 2, axis=-1)                 # (B,T,H) each
    a = jax.nn.sigmoid(fg.astype(jnp.float32))            # forget in (0,1)
    i = jnp.exp(jnp.clip(ig.astype(jnp.float32), -10.0, 10.0))
    y, _, _ = gla_chunked(q, k, v, a, i)
    y = y.reshape(b, t, di) * jax.nn.silu(z)
    return jnp.einsum("bte,ed->btd", y, params["w_down"])


def mlstm_decode_step(
    params: Mapping[str, jax.Array],
    x: jax.Array,                        # (B,1,d)
    C: jax.Array, n: jax.Array,          # (B,H,K,P), (B,H,K)
    cfg: ArchConfig,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    b, _, d = x.shape
    h = cfg.n_heads
    di = 2 * d
    up = jnp.einsum("btd,de->bte", x, params["w_up"])
    xi, z = jnp.split(up, 2, axis=-1)
    q = jnp.einsum("bte,ef->btf", xi, params["wq"]).reshape(b, h, di // h)
    k = jnp.einsum("bte,ef->btf", xi, params["wk"]).reshape(b, h, di // h)
    v = jnp.einsum("bte,ef->btf", xi, params["wv"]).reshape(b, h, di // h)
    gates = (jnp.einsum("bte,eg->btg", xi, params["w_if"]) + params["b_if"])[:, 0]
    ig, fg = jnp.split(gates, 2, axis=-1)
    a = jax.nn.sigmoid(fg.astype(jnp.float32))
    i = jnp.exp(jnp.clip(ig.astype(jnp.float32), -10.0, 10.0))
    C = C * a[:, :, None, None] + i[:, :, None, None] * jnp.einsum(
        "bhk,bhp->bhkp", k.astype(jnp.float32), v.astype(jnp.float32)
    )
    n = n * a[:, :, None] + i[:, :, None] * k.astype(jnp.float32)
    qs = q.astype(jnp.float32) * ((di // h) ** -0.5)
    num = jnp.einsum("bhk,bhkp->bhp", qs, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qs, n)), 1.0)
    y = (num / den[..., None]).reshape(b, 1, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    return jnp.einsum("bte,ed->btd", y, params["w_down"]), C, n


# ---------------------------------------------------------------------------
# sLSTM block (sequential scan; block-diagonal recurrence per head)
# ---------------------------------------------------------------------------

def slstm_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    ff = ((4 * d // 3) + 127) // 128 * 128
    return {
        "w_in": ParamSpec((d, 4 * d), ("embed", "mlp")),      # z,i,f,o inputs
        # block-diagonal recurrence: tiny (4 heads) — replicate, don't shard
        "r": ParamSpec((4, h, dh, dh), (None, None, None, None), scale=0.1),
        "b": ParamSpec((4 * d,), (None,), init="zeros"),
        "ff_gate": ParamSpec((d, ff), ("embed", "mlp")),
        "ff_up": ParamSpec((d, ff), ("embed", "mlp")),
        "ff_down": ParamSpec((ff, d), ("mlp", "embed")),
    }


def _slstm_cell(params, xt, state, cfg: ArchConfig):
    """xt: (B, 4d) precomputed input proj; state: dict of (B,H,dh)."""
    h_heads = state["h"]
    b, H, dh = h_heads.shape
    rz, ri, rf, ro = params["r"]
    rec = jnp.stack(
        [jnp.einsum("bhd,hde->bhe", h_heads, r) for r in (rz, ri, rf, ro)],
        axis=0,
    )  # (4, B, H, dh)
    zi, ii, fi, oi = jnp.split(xt + params["b"], 4, axis=-1)
    shape = (b, H, dh)
    z = jnp.tanh(zi.reshape(shape).astype(jnp.float32) + rec[0])
    it = ii.reshape(shape).astype(jnp.float32) + rec[1]
    ft = fi.reshape(shape).astype(jnp.float32) + rec[2]
    o = jax.nn.sigmoid(oi.reshape(shape).astype(jnp.float32) + rec[3])
    m = jnp.maximum(ft + state["m"], it)                  # stabiliser
    i = jnp.exp(it - m)
    f = jnp.exp(ft + state["m"] - m)
    c = f * state["c"] + i * z
    n = f * state["n"] + i
    hh = o * c / jnp.maximum(n, 1.0)
    return {"c": c, "n": n, "h": hh, "m": m}


def slstm_block(params: Mapping[str, jax.Array], x: jax.Array, cfg: ArchConfig) -> jax.Array:
    b, t, d = x.shape
    H = cfg.n_heads
    dh = d // H
    xin = jnp.einsum("btd,de->bte", x, params["w_in"])    # (B,T,4d)
    state0 = {
        "c": jnp.zeros((b, H, dh), jnp.float32),
        "n": jnp.zeros((b, H, dh), jnp.float32),
        "h": jnp.zeros((b, H, dh), jnp.float32),
        "m": jnp.full((b, H, dh), -1e9, jnp.float32),
    }

    def step(state, xt):
        new = _slstm_cell(params, xt, state, cfg)
        return new, new["h"]

    _, hs = jax.lax.scan(step, state0, jnp.moveaxis(xin, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(b, t, d).astype(x.dtype)
    # post-block gated FFN (proj factor 4/3, GeGLU)
    g = jax.nn.gelu(jnp.einsum("btd,df->btf", y, params["ff_gate"]), approximate=True)
    u = jnp.einsum("btd,df->btf", y, params["ff_up"])
    return jnp.einsum("btf,fd->btd", g * u, params["ff_down"])


def slstm_decode_step(
    params: Mapping[str, jax.Array],
    x: jax.Array,                         # (B,1,d)
    state: Dict[str, jax.Array],
    cfg: ArchConfig,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    b, _, d = x.shape
    xt = jnp.einsum("btd,de->bte", x, params["w_in"])[:, 0]
    new = _slstm_cell(params, xt, state, cfg)
    y = new["h"].reshape(b, 1, d).astype(x.dtype)
    g = jax.nn.gelu(jnp.einsum("btd,df->btf", y, params["ff_gate"]), approximate=True)
    u = jnp.einsum("btd,df->btf", y, params["ff_up"])
    return jnp.einsum("btf,fd->btd", g * u, params["ff_down"]), new
