"""Mixture-of-Experts FFN with production-style sorted capacity dispatch.

Two implementations sharing one parameter layout (experts sharded over
"model" = expert parallelism):

* ``sorted`` (default): tokens are routed top-k, flattened, sorted by
  expert, truncated to a per-expert capacity ``C = ceil(S*k/E * cf)``, and
  processed as (E, C, d) grouped matmuls — the TPU analogue of
  megablocks/gmm, expressed with gather/scatter so GSPMD can place the
  all-to-all.  Dropped tokens (over capacity) contribute zero, standard
  Switch-style semantics.
* ``dense``: every expert runs on every token, combined with the routing
  weights.  E× FLOPs — used only as the correctness oracle in tests.
"""

from __future__ import annotations

import math
from typing import Dict, Mapping, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from .layers import ParamSpec


def _constrain_experts(x: jax.Array) -> jax.Array:
    """Pin the leading expert axis of an (E, cap, d) buffer to "model"."""
    from jax.sharding import PartitionSpec as P

    try:
        return jax.lax.with_sharding_constraint(
            x, P("model", *([None] * (x.ndim - 1)))
        )
    except (ValueError, RuntimeError, NameError):
        return x  # no mesh context (CPU unit tests)


def moe_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": ParamSpec((d, e), ("embed", "experts")),
        "w_gate": ParamSpec((e, d, ff), ("experts", "embed", "mlp")),
        "w_up": ParamSpec((e, d, ff), ("experts", "embed", "mlp")),
        "w_down": ParamSpec((e, ff, d), ("experts", "mlp", "embed")),
    }


def _route(params: Mapping[str, jax.Array], x: jax.Array, cfg: ArchConfig):
    logits = jnp.einsum("sd,de->se", x.astype(jnp.float32), params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)          # (S, k)
    gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)
    return gates, idx, probs


def moe_ffn_sorted(
    params: Mapping[str, jax.Array], x: jax.Array, cfg: ArchConfig
) -> Tuple[jax.Array, jax.Array]:
    """x: (B, T, d) (or (..., d) — leading dims treated as batch rows).

    Dispatch is **per batch row** (vmapped sort/scatter over B): every
    routing op then carries the data-sharded batch axis and stays shard-
    local, while the (B, E, cap, d) expert buffers are sharded (data on B,
    model on E) — the token->expert movement is the only cross-shard
    traffic.  (The earlier flat global-token argsort forced GSPMD to
    all-reduce 1M x d buffers per layer — §Perf hillclimb C.)  Capacity is
    per row: cap = ceil(T*k/E * cf); overflow drops are Switch-style.
    """
    orig_shape = x.shape
    d = orig_shape[-1]
    xr = x.reshape(-1, orig_shape[-2], d) if x.ndim > 2 else x[None]
    B, T, _ = xr.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = int(math.ceil(T * k / e * cfg.capacity_factor))
    cap = max(8, ((cap + 7) // 8) * 8)  # sublane-aligned groups

    gates, idx, probs = _route(params, xr.reshape(-1, d), cfg)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        (jax.nn.one_hot(idx, e, dtype=jnp.float32)).sum(1), axis=0
    ) / k
    aux = e * jnp.sum(me * ce)
    gates = gates.reshape(B, T, k)
    idx = idx.reshape(B, T, k)

    def dispatch_row(xrow, idx_row, gates_row):
        """One sequence: sort its T*k assignments into (E, cap, d)."""
        flat_e = idx_row.reshape(-1)                      # (T*k,)
        flat_t = jnp.repeat(jnp.arange(T), k)
        flat_g = gates_row.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        se, st, sg = flat_e[order], flat_t[order], flat_g[order]
        counts = jnp.bincount(se, length=e)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(T * k) - starts[se]
        keep = pos < cap
        slot = jnp.where(keep, se * cap + pos, e * cap)
        xd = jnp.zeros((e * cap + 1, d), xrow.dtype).at[slot].set(
            xrow[st] * keep[:, None].astype(xrow.dtype)
        )
        return xd[: e * cap].reshape(e, cap, d), (st, sg, keep, slot)

    xe, (st, sg, keep, slot) = jax.vmap(dispatch_row)(xr, idx, gates)
    # (B, E, cap, d): B data-sharded, E expert(model)-sharded
    xe = _constrain_experts(xe)
    h_gate = jnp.einsum("becd,edf->becf", xe, params["w_gate"])
    h_up = jnp.einsum("becd,edf->becf", xe, params["w_up"])
    if cfg.ffn_act == "geglu":
        h = jax.nn.gelu(h_gate, approximate=True) * h_up
    else:
        h = jax.nn.silu(h_gate) * h_up
    ye = jnp.einsum("becf,efd->becd", h, params["w_down"])
    ye = _constrain_experts(ye)

    def combine_row(ye_row, st_row, sg_row, keep_row, slot_row):
        y_slots = jnp.concatenate(
            [ye_row.reshape(e * cap, d), jnp.zeros((1, d), ye_row.dtype)], 0
        )
        y_tok = y_slots[slot_row] * (sg_row * keep_row).astype(ye_row.dtype)[:, None]
        return jnp.zeros((T, d), ye_row.dtype).at[st_row].add(y_tok)

    y = jax.vmap(combine_row)(ye, st, sg, keep, slot)
    return y.reshape(orig_shape).astype(x.dtype), aux.astype(jnp.float32)


def moe_ffn_dense(
    params: Mapping[str, jax.Array], x: jax.Array, cfg: ArchConfig
) -> Tuple[jax.Array, jax.Array]:
    """Oracle path: compute all experts for all tokens (E x FLOPs)."""
    orig_shape = x.shape
    xf = x.reshape(-1, orig_shape[-1])
    s = xf.shape[0]
    e = cfg.n_experts
    gates, idx, probs = _route(params, xf, cfg)
    combine = jnp.zeros((s, e), jnp.float32)
    for j in range(cfg.top_k):  # static small k
        combine = combine + jax.nn.one_hot(idx[:, j], e) * gates[:, j:j + 1]
    h_gate = jnp.einsum("sd,edf->esf", xf, params["w_gate"])
    h_up = jnp.einsum("sd,edf->esf", xf, params["w_up"])
    if cfg.ffn_act == "geglu":
        h = jax.nn.gelu(h_gate, approximate=True) * h_up
    else:
        h = jax.nn.silu(h_gate) * h_up
    ye = jnp.einsum("esf,efd->esd", h, params["w_down"])
    y = jnp.einsum("esd,se->sd", ye.astype(jnp.float32), combine)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(idx, e, dtype=jnp.float32).sum(1), axis=0) / cfg.top_k
    aux = e * jnp.sum(me * ce)
    return y.reshape(orig_shape).astype(x.dtype), aux.astype(jnp.float32)


def moe_ffn(params, x, cfg: ArchConfig) -> Tuple[jax.Array, jax.Array]:
    if cfg.moe_impl == "dense":
        return moe_ffn_dense(params, x, cfg)
    return moe_ffn_sorted(params, x, cfg)
