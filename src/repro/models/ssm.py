"""Mamba2 (SSD) blocks — chunked parallel scan, TPU-idiomatic.

The CUDA selective-scan has no TPU analogue; the TPU-native formulation is
the *chunked* SSD decomposition (Dao & Gu 2024): split time into chunks of
Q steps, compute intra-chunk interactions as dense matmuls (MXU-friendly),
and carry the inter-chunk SSM state with a short ``lax.scan``.  The Pallas
kernel in ``repro.kernels.mamba2_scan`` tiles exactly this structure; this
module is the jnp reference + the layer plumbing (projections, conv, gate).

State convention: h has shape (B, H, dh, N) with N = ssm_state; scalar
per-head decay a_t = exp(dt_t * A) (A < 0), input B_t/C_t shared across
heads (ngroups=1, as in Mamba2 / Zamba2).
"""

from __future__ import annotations

from typing import Any, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from .layers import ParamSpec


def mamba2_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    nh = di // cfg.ssm_head_dim
    return {
        # z (gate), x, B, C, dt  in one fused projection
        "in_proj": ParamSpec((d, 2 * di + 2 * n + nh), ("embed", "mlp")),
        "conv_w": ParamSpec((cfg.ssm_conv, di + 2 * n), ("conv", "mlp"), scale=0.5),
        "A_log": ParamSpec((nh,), ("state",), init="zeros"),
        "D": ParamSpec((nh,), ("state",), init="ones"),
        "dt_bias": ParamSpec((nh,), ("state",), init="zeros"),
        "norm_w": ParamSpec((di,), ("mlp",), init="zeros"),
        "out_proj": ParamSpec((di, d), ("mlp", "embed")),
    }


def _split_proj(proj: jax.Array, cfg: ArchConfig):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    nh = di // cfg.ssm_head_dim
    z, xs, b, c, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1
    )
    return z, xs, b, c, dt, di, n, nh


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (B, T, C); w: (K, C) depthwise causal conv."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):  # tiny static K (4)
        out = out + xp[:, i: i + x.shape[1], :] * w[i]
    return out


def ssd_chunked(
    xh: jax.Array,    # (B, T, H, P)   inputs per head
    a: jax.Array,     # (B, T, H)      per-step decay in (0,1)
    b: jax.Array,     # (B, T, N)      input projection (shared groups)
    c: jax.Array,     # (B, T, N)      output projection
    chunk: int = 256,
    h0: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD: y_t = C_t . h_t ;  h_t = a_t h_{t-1} + B_t x_t^T.

    Returns (y, h_final) with y: (B,T,H,P), h: (B,H,P,N).
    """
    B, T, H, P = xh.shape
    N = b.shape[-1]
    nc = max(1, (T + chunk - 1) // chunk)
    pad = nc * chunk - T
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    Tp = nc * chunk
    f32 = jnp.float32
    xh_ = xh.reshape(B, nc, chunk, H, P).astype(f32)
    a_ = a.reshape(B, nc, chunk, H).astype(f32)
    b_ = b.reshape(B, nc, chunk, N).astype(f32)
    c_ = c.reshape(B, nc, chunk, N).astype(f32)

    loga = jnp.log(jnp.clip(a_, 1e-20))
    cum = jnp.cumsum(loga, axis=2)                      # (B,nc,Q,H)
    total = cum[:, :, -1:, :]                           # chunk decay
    # intra-chunk: L[q, s] = exp(cum_q - cum_s) for q >= s  (per head)
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Q,Q,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(li), 0.0)
    cb = jnp.einsum("bnqk,bnsk->bnqs", c_, b_)          # (B,nc,Q,Q)
    y_intra = jnp.einsum("bnqs,bnqsh,bnshp->bnqhp", cb, L, xh_)

    # chunk-local states to carry: sum_s B_s x_s^T * decay(s->end)
    decay_to_end = jnp.exp(total - cum)                 # (B,nc,Q,H)
    chunk_state = jnp.einsum("bnsk,bnsh,bnshp->bnhpk", b_, decay_to_end, xh_)
    chunk_decay = jnp.exp(total[:, :, 0, :])            # (B,nc,H)

    def carry_fn(h, inp):
        cs, cd = inp                                    # (B,H,P,N), (B,H)
        h_in = h
        h = h * cd[:, :, None, None] + cs
        return h, h_in

    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), f32)
    cs_t = jnp.moveaxis(chunk_state, 1, 0)              # (nc,B,H,P,N)
    cd_t = jnp.moveaxis(chunk_decay, 1, 0)              # (nc,B,H)
    h_final, h_prevs = jax.lax.scan(carry_fn, h0, (cs_t, cd_t))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)               # (B,nc,H,P,N)

    # inter-chunk contribution: y += (C_q . h_prev) * decay(0->q)
    decay_from_start = jnp.exp(cum)                     # (B,nc,Q,H)
    y_inter = jnp.einsum(
        "bnqk,bnhpk,bnqh->bnqhp", c_, h_prevs, decay_from_start
    )
    y = (y_intra + y_inter).reshape(B, Tp, H, P)[:, :T]
    return y.astype(xh.dtype), h_final


def mamba2_block(
    params: Mapping[str, jax.Array],
    x: jax.Array,                       # (B, T, d)
    cfg: ArchConfig,
) -> jax.Array:
    proj = jnp.einsum("btd,de->bte", x, params["in_proj"])
    z, xs, b, c, dt, di, n, nh = _split_proj(proj, cfg)
    conv_in = jnp.concatenate([xs, b, c], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, params["conv_w"]))
    xs, b, c = jnp.split(conv_out, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt + params["dt_bias"])        # (B,T,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))   # (H,) negative
    a = jnp.exp(dt * A)                                 # per-step decay
    xh = xs.reshape(*xs.shape[:-1], nh, cfg.ssm_head_dim)
    xh = xh * dt[..., None]                             # dt-scaled input
    y, _ = ssd_chunked(xh, a, b, c)
    y = y + xh * params["D"][:, None]
    y = y.reshape(*x.shape[:-1], di)
    # gated RMSNorm (Mamba2)
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + cfg.norm_eps) * (1.0 + params["norm_w"])
    return jnp.einsum("bte,ed->btd", yf.astype(x.dtype), params["out_proj"])


def mamba2_decode_step(
    params: Mapping[str, jax.Array],
    x: jax.Array,                       # (B, 1, d)
    state: jax.Array,                   # (B, H, P, N)
    conv_state: jax.Array,              # (B, K-1, di+2N)
    cfg: ArchConfig,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Single-token recurrent step: O(1) in context length."""
    proj = jnp.einsum("btd,de->bte", x, params["in_proj"])
    z, xs, b, c, dt, di, n, nh = _split_proj(proj, cfg)
    conv_in = jnp.concatenate([xs, b, c], axis=-1)      # (B,1,C)
    window = jnp.concatenate([conv_state, conv_in], axis=1)  # (B,K,C)
    conv_out = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", window, params["conv_w"])
    )[:, None, :]
    new_conv_state = window[:, 1:]
    xs, b, c = jnp.split(conv_out, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt + params["dt_bias"])
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    a = jnp.exp(dt * A)                                 # (B,1,H)
    xh = (xs.reshape(*xs.shape[:-1], nh, cfg.ssm_head_dim) * dt[..., None])
    # h = a h + B x^T ; y = C . h
    h = state * a[:, 0, :, None, None] + jnp.einsum(
        "bk,bhp->bhpk", b[:, 0].astype(jnp.float32), xh[:, 0].astype(jnp.float32)
    )
    y = jnp.einsum("bk,bhpk->bhp", c[:, 0].astype(jnp.float32), h)
    y = y[:, None] + xh * params["D"][:, None]
    y = y.reshape(*x.shape[:-1], di)
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + cfg.norm_eps) * (1.0 + params["norm_w"])
    out = jnp.einsum("bte,ed->btd", yf.astype(x.dtype), params["out_proj"])
    return out, h, new_conv_state
