"""Activation-sharding context: pin the batch axis through the layer stack.

With FSDP weights sharded over ("model","data") on their output dims, GSPMD
has two legal plans for每 layer matmul: (a) all-gather the small weight over
"data" and keep activations batch-sharded, or (b) gather the huge activation
batch and keep the weight sharded.  Left alone it picked (b) on the 95-layer
dense cell (§Perf hillclimb B: 17TB/step of activation all-gathers).
Constraining every block boundary to batch-sharded activations forces (a).

The launcher (dryrun/train) sets the batch mesh axes before tracing; model
code calls :func:`constrain_batch` at block boundaries.  No-op when unset
(tests, single-device runs).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax

_BATCH_AXES: Optional[Tuple[str, ...]] = None


def set_batch_axes(axes) -> None:
    """axes: mesh axis name(s) carrying the batch dim, or None to disable."""
    global _BATCH_AXES
    if axes is None:
        _BATCH_AXES = None
    elif isinstance(axes, str):
        _BATCH_AXES = (axes,)
    else:
        _BATCH_AXES = tuple(axes)


def constrain_batch(x: jax.Array) -> jax.Array:
    """Constrain a (batch, ...) activation to batch-sharded, rest replicated
    at this point (GSPMD still refines the trailing dims)."""
    if _BATCH_AXES is None:
        return x
    from jax.sharding import PartitionSpec as P

    spec = P(_BATCH_AXES, *([None] * (x.ndim - 1)))
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x  # no mesh context (plain CPU tests)
