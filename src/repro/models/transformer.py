"""Model assembly: every assigned architecture as one composable definition.

Families and their layer-stack structure (all scan-over-layers so HLO size is
O(1) in depth — deepseek's 95 layers compile as one scanned block):

* ``dense | moe | vlm``  — decoder-only LM; plain ``lax.scan`` over L blocks.
  gemma3's 5:1 local:global pattern becomes a two-level scan: outer over
  L/6 groups, inner = 5 sliding-window layers (stacked) + 1 global layer.
* ``audio`` (seamless)    — encoder-decoder: bidirectional encoder scan +
  causal decoder scan with cross-attention; modality frontend is a stub
  (precomputed frame embeddings arrive as inputs, per the assignment).
* ``hybrid`` (zamba2)     — scan over Mamba2 blocks; ONE shared attention+MLP
  block (zamba's parameter-sharing trick) applied every ``attn_every``
  layers via ``lax.cond`` on the layer index, reading concat([h, emb]).
* ``ssm`` (xlstm)         — groups of 7 chunked mLSTM blocks + 1 sequential
  sLSTM block (xLSTM[7:1]).

Each family exposes: ``param_specs``, ``train_loss``, ``prefill_logits``,
``serve_step`` (+ cache specs) through :func:`build_model`.
"""

from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from . import attention as A
from . import moe as M
from . import ssm as S
from . import xlstm as X
from .shardctx import constrain_batch
from .layers import (
    ParamSpec,
    embed_tokens,
    embedding_specs,
    gated_mlp,
    gated_mlp_specs,
    rms_norm,
)


def _stack_specs(specs: Any, n: int) -> Any:
    """Prepend a layer dimension to every ParamSpec in a tree."""
    if isinstance(specs, ParamSpec):
        return ParamSpec(
            (n,) + specs.shape, ("layers",) + specs.logical, specs.init, specs.scale
        )
    return {k: _stack_specs(v, n) for k, v in specs.items()}


def _remat(fn: Callable, mode: str) -> Callable:
    if mode == "full":
        return jax.checkpoint(fn)
    if mode == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return fn


# ---------------------------------------------------------------------------
# decoder-only transformer (dense / moe / vlm backbone)
# ---------------------------------------------------------------------------

def _block_specs(cfg: ArchConfig) -> Dict[str, Any]:
    specs: Dict[str, Any] = {
        "ln_attn": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
        "attn": A.attn_specs(cfg),
        "ln_ffn": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
    }
    if cfg.moe:
        specs["moe"] = M.moe_specs(cfg)
    else:
        specs["mlp"] = gated_mlp_specs(cfg.d_model, cfg.d_ff)
    return specs


def _block_apply(
    params: Mapping[str, Any],
    x: jax.Array,
    cfg: ArchConfig,
    window: Optional[Any],
) -> Tuple[jax.Array, jax.Array]:
    x = constrain_batch(x)
    h = rms_norm(x, params["ln_attn"], cfg.norm_eps)
    x = x + A.mha_train(params["attn"], h, cfg, window=window)
    x = constrain_batch(x)
    h = rms_norm(x, params["ln_ffn"], cfg.norm_eps)
    if cfg.moe:
        y, aux = M.moe_ffn(params["moe"], h, cfg)
    else:
        y, aux = gated_mlp(params["mlp"], h, cfg.ffn_act), jnp.zeros((), jnp.float32)
    return constrain_batch(x + y), aux


def _block_decode(
    params: Mapping[str, Any],
    x: jax.Array,
    k: jax.Array,
    v: jax.Array,
    pos: jax.Array,
    cfg: ArchConfig,
    window: Optional[Any],
    ring: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    h = rms_norm(x, params["ln_attn"], cfg.norm_eps)
    y, k, v = A.mha_decode(params["attn"], h, k, v, pos, cfg, window=window,
                           ring=ring)
    x = x + y
    h = rms_norm(x, params["ln_ffn"], cfg.norm_eps)
    if cfg.moe:
        y, _ = M.moe_ffn(params["moe"], h, cfg)
    else:
        y = gated_mlp(params["mlp"], h, cfg.ffn_act)
    return x + y, k, v


@dataclass
class Model:
    cfg: ArchConfig
    param_specs: Any
    train_loss: Callable          # (params, batch) -> (loss, metrics)
    prefill_logits: Callable      # (params, batch) -> logits
    serve_step: Callable          # (params, cache, batch) -> (logits, cache)
    cache_specs: Callable         # (batch, seq) -> tree of ShapeDtypeStruct


def build_model(cfg: ArchConfig) -> Model:
    if cfg.family == "audio" or cfg.is_encdec:
        return _build_encdec(cfg)
    if cfg.family == "hybrid":
        return _build_hybrid(cfg)
    if cfg.family == "ssm":
        return _build_xlstm(cfg)
    return _build_lm(cfg)


# ------------------------------------------------------------------ LM ----

def _lm_specs(cfg: ArchConfig) -> Dict[str, Any]:
    specs: Dict[str, Any] = {
        "embed": embedding_specs(cfg.padded_vocab, cfg.d_model),
        "ln_f": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
    }
    ratio = cfg.local_global_ratio
    if ratio > 0:
        period = ratio + 1
        n_groups = cfg.n_layers // period
        specs["local"] = _stack_specs(_stack_specs(_block_specs(cfg), ratio), n_groups)
        specs["global"] = _stack_specs(_block_specs(cfg), n_groups)
    else:
        specs["layers"] = _stack_specs(_block_specs(cfg), cfg.n_layers)
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec(
            (cfg.padded_vocab, cfg.d_model), ("vocab", "embed"), scale=1.0
        )
    return specs


def _lm_embed_inputs(params, batch, cfg: ArchConfig) -> jax.Array:
    x = embed_tokens(params["embed"], batch["tokens"])
    if cfg.frontend == "vision":
        # stub frontend: precomputed patch embeddings prepended to the text
        x = jnp.concatenate([batch["patch_embeds"].astype(x.dtype), x], axis=1)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)  # gemma embed scaling
    return x


def _lm_backbone(params, x, cfg: ArchConfig) -> Tuple[jax.Array, jax.Array]:
    ratio = cfg.local_global_ratio

    if ratio > 0:
        def group(carry, gp):
            x, aux = carry

            local_fn = _remat(
                lambda lp, xx: _block_apply(lp, xx, cfg, cfg.sliding_window),
                cfg.remat,
            )
            global_fn = _remat(
                lambda lp, xx: _block_apply(lp, xx, cfg, None), cfg.remat
            )

            def local_layer(c, lp):
                xx, au = c
                xx, a = local_fn(lp, xx)
                return (xx, au + a), None

            (x, aux), _ = jax.lax.scan(local_layer, (x, aux), gp["local"])
            x, a = global_fn(gp["global"], x)
            return (x, aux + a), None

        (x, aux), _ = jax.lax.scan(
            group, (x, jnp.zeros((), jnp.float32)),
            {"local": params["local"], "global": params["global"]},
        )
    else:
        layer_fn = _remat(
            lambda lp, xx: _block_apply(lp, xx, cfg, cfg.sliding_window),
            cfg.remat,
        )

        def layer(carry, lp):
            x, aux = carry
            x, a = layer_fn(lp, x)
            return (x, aux + a), None

        (x, aux), _ = jax.lax.scan(
            layer, (x, jnp.zeros((), jnp.float32)), params["layers"]
        )
    return rms_norm(x, params["ln_f"], cfg.norm_eps), aux


def _lm_logits(params, x, cfg: ArchConfig) -> jax.Array:
    head = params.get("lm_head", params["embed"]["table"])
    return jnp.einsum("...d,vd->...v", x, head)


def _xent(logits: jax.Array, labels: jax.Array, mask: jax.Array) -> jax.Array:
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None], axis=-1
    )[..., 0]
    nll = (lse - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


def _build_lm(cfg: ArchConfig) -> Model:
    specs = _lm_specs(cfg)

    def train_loss(params, batch):
        x = _lm_embed_inputs(params, batch, cfg)
        x, aux = _lm_backbone(params, x, cfg)
        logits = _lm_logits(params, x, cfg)
        tokens = batch["tokens"]
        pre = x.shape[1] - tokens.shape[1]     # frontend positions carry no loss
        logits_txt = logits[:, pre:]
        labels = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
        mask = jnp.pad(
            jnp.ones_like(tokens[:, 1:], jnp.float32), ((0, 0), (0, 1))
        )
        loss = _xent(logits_txt, labels, mask) + 0.01 * aux
        return loss, {"loss": loss, "aux": aux}

    def prefill_logits(params, batch):
        x = _lm_embed_inputs(params, batch, cfg)
        x, _ = _lm_backbone(params, x, cfg)
        return _lm_logits(params, x[:, -1:], cfg)

    ratio = cfg.local_global_ratio

    def cache_specs(batch: int, seq: int):
        if ratio > 0:
            period = ratio + 1
            g = cfg.n_layers // period
            w = min(cfg.sliding_window or seq, seq)
            return {
                "local": A.kv_cache_specs(cfg, batch, w, n_layers=g * ratio),
                "global": A.kv_cache_specs(cfg, batch, seq, n_layers=g),
            }
        return A.kv_cache_specs(cfg, batch, seq)

    def serve_step(params, cache, batch):
        tok, pos = batch["token"], batch["pos"]
        x = embed_tokens(params["embed"], tok)
        if cfg.name.startswith("gemma"):
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)  # gemma scaling
        if ratio > 0:
            period = ratio + 1
            g = cfg.n_layers // period
            lk = cache["local"]["k"].reshape(g, ratio, *cache["local"]["k"].shape[1:])
            lv = cache["local"]["v"].reshape(g, ratio, *cache["local"]["v"].shape[1:])

            # Ring-buffer local caches: write slot pos % window, RoPE applied
            # at the absolute position (see mha_decode ring semantics).
            def group(x, gp):
                lparams, gk, gv, gparams, pk, pv = gp

                def local_layer(xx, lp):
                    p, k, v = lp
                    xx, k, v = _block_decode(p, xx, k, v, pos, cfg, None,
                                             ring=True)
                    return xx, (k, v)

                x, (gk, gv) = jax.lax.scan(local_layer, x, (lparams, gk, gv))
                x, pk, pv = _block_decode(gparams, x, pk, pv, pos, cfg, None)
                return x, (gk, gv, pk, pv)

            x, (lk2, lv2, gk2, gv2) = jax.lax.scan(
                group, x,
                (params["local"], lk, lv, params["global"],
                 cache["global"]["k"], cache["global"]["v"]),
            )
            new_cache = {
                "local": {
                    "k": lk2.reshape(g * ratio, *lk2.shape[2:]),
                    "v": lv2.reshape(g * ratio, *lv2.shape[2:]),
                },
                "global": {"k": gk2, "v": gv2},
            }
        else:
            def layer(x, lp):
                p, k, v = lp
                x, k, v = _block_decode(p, x, k, v, pos, cfg, cfg.sliding_window)
                return x, (k, v)

            x, (ks, vs) = jax.lax.scan(
                layer, x, (params["layers"], cache["k"], cache["v"])
            )
            new_cache = {"k": ks, "v": vs}
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        return _lm_logits(params, x, cfg), new_cache

    return Model(cfg, specs, train_loss, prefill_logits, serve_step, cache_specs)


# ------------------------------------------------------- encoder-decoder ---

def _build_encdec(cfg: ArchConfig) -> Model:
    enc_block = {
        "ln_attn": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
        "attn": A.attn_specs(cfg),
        "ln_ffn": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
        "mlp": gated_mlp_specs(cfg.d_model, cfg.d_ff),
    }
    dec_block = dict(enc_block)
    dec_block = {
        **enc_block,
        "ln_cross": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
        "cross": A.attn_specs(cfg),
    }
    specs = {
        "embed": embedding_specs(cfg.padded_vocab, cfg.d_model),
        "encoder": _stack_specs(enc_block, cfg.encoder_layers),
        "decoder": _stack_specs(dec_block, cfg.n_layers),
        "ln_enc": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
        "ln_f": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
    }

    def encode(params, src):
        def layer(x, lp):
            x = constrain_batch(x)
            h = rms_norm(x, lp["ln_attn"], cfg.norm_eps)
            x = x + A.mha_train(lp["attn"], h, cfg, causal=False)
            h = rms_norm(x, lp["ln_ffn"], cfg.norm_eps)
            return constrain_batch(x + gated_mlp(lp["mlp"], h, cfg.ffn_act)), None

        layer_fn = _remat(layer, cfg.remat)
        x, _ = jax.lax.scan(lambda c, lp: layer_fn(c, lp), src, params["encoder"])
        return rms_norm(x, params["ln_enc"], cfg.norm_eps)

    def dec_layer_train(x, lp, enc):
        x = constrain_batch(x)
        h = rms_norm(x, lp["ln_attn"], cfg.norm_eps)
        x = x + A.mha_train(lp["attn"], h, cfg, causal=True)
        h = rms_norm(x, lp["ln_cross"], cfg.norm_eps)
        x = x + A.mha_train(lp["cross"], h, cfg, kv_src=enc, causal=False)
        h = rms_norm(x, lp["ln_ffn"], cfg.norm_eps)
        return constrain_batch(x + gated_mlp(lp["mlp"], h, cfg.ffn_act))

    def decode_train(params, tgt_x, enc):
        dec_fn = _remat(lambda x, lp: dec_layer_train(x, lp, enc), cfg.remat)

        def layer(x, lp):
            return dec_fn(x, lp), None

        x, _ = jax.lax.scan(layer, tgt_x, params["decoder"])
        return rms_norm(x, params["ln_f"], cfg.norm_eps)

    def train_loss(params, batch):
        enc = encode(params, batch["src_embeds"].astype(params["embed"]["table"].dtype))
        tgt = batch["tgt_tokens"]
        x = embed_tokens(params["embed"], tgt)
        x = decode_train(params, x, enc)
        logits = jnp.einsum("...d,vd->...v", x, params["embed"]["table"])
        labels = jnp.pad(tgt[:, 1:], ((0, 0), (0, 1)))
        mask = jnp.pad(jnp.ones_like(tgt[:, 1:], jnp.float32), ((0, 0), (0, 1)))
        loss = _xent(logits, labels, mask)
        return loss, {"loss": loss}

    def prefill_logits(params, batch):
        enc = encode(params, batch["src_embeds"].astype(params["embed"]["table"].dtype))
        x = embed_tokens(params["embed"], batch["tgt_tokens"])
        x = decode_train(params, x, enc)
        return jnp.einsum("...d,vd->...v", x[:, -1:], params["embed"]["table"])

    def cache_specs(batch: int, seq: int):
        src = 4096  # encoder frames for serving (stub frontend length)
        return {
            "self": A.kv_cache_specs(cfg, batch, seq),
            "cross": A.kv_cache_specs(cfg, batch, src),
            "enc_done": jax.ShapeDtypeStruct((), jnp.int32),
        }

    def serve_step(params, cache, batch):
        tok, pos = batch["token"], batch["pos"]
        x = embed_tokens(params["embed"], tok)

        def layer(x, lp):
            p, k, v, ck, cv = lp
            x, k, v = _block_decode_encdec(p, x, k, v, ck, cv, pos, cfg)
            return x, (k, v)

        x, (ks, vs) = jax.lax.scan(
            layer,
            x,
            (params["decoder"], cache["self"]["k"], cache["self"]["v"],
             cache["cross"]["k"], cache["cross"]["v"]),
        )
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = jnp.einsum("...d,vd->...v", x, params["embed"]["table"])
        new_cache = {
            "self": {"k": ks, "v": vs},
            "cross": cache["cross"],
            "enc_done": cache["enc_done"],
        }
        return logits, new_cache

    return Model(cfg, specs, train_loss, prefill_logits, serve_step, cache_specs)


def _block_decode_encdec(p, x, k, v, ck, cv, pos, cfg: ArchConfig):
    h = rms_norm(x, p["ln_attn"], cfg.norm_eps)
    y, k, v = A.mha_decode(p["attn"], h, k, v, pos, cfg)
    x = x + y
    # cross-attention against the precomputed encoder KV (no cache update)
    h = rms_norm(x, p["ln_cross"], cfg.norm_eps)
    b = x.shape[0]
    dh = cfg.head_dim_
    q = jnp.einsum("...d,df->...f", h, p["cross"]["wq"])
    q = q.reshape(b, 1, cfg.n_heads, dh)
    kf = A._expand_kv(ck, cfg.n_heads)
    vf = A._expand_kv(cv, cfg.n_heads)
    scores = jnp.einsum(
        "bqhd,bshd->bhqs", q.astype(jnp.float32), kf.astype(jnp.float32)
    ) * dh ** -0.5
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", probs, vf.astype(jnp.float32))
    out = out.astype(x.dtype).reshape(b, 1, cfg.n_heads * dh)
    x = x + jnp.einsum("...f,fd->...d", out, p["cross"]["wo"])
    h = rms_norm(x, p["ln_ffn"], cfg.norm_eps)
    return x + gated_mlp(p["mlp"], h, cfg.ffn_act), k, v


# ----------------------------------------------------------- hybrid (zamba)

def _shared_attn_specs(cfg: ArchConfig) -> Dict[str, Any]:
    """Zamba2 shared block: attention over concat([h, emb]) (2d) -> d."""
    d2 = 2 * cfg.d_model
    dh = d2 // cfg.n_heads
    return {
        "ln": ParamSpec((d2,), ("embed",), init="zeros"),
        "wq": ParamSpec((d2, cfg.n_heads * dh), ("embed", "q_dim")),
        "wk": ParamSpec((d2, cfg.n_kv_heads * dh), ("embed", "q_dim")),
        "wv": ParamSpec((d2, cfg.n_kv_heads * dh), ("embed", "q_dim")),
        "wo": ParamSpec((cfg.n_heads * dh, cfg.d_model), ("q_dim", "embed")),
        "ln_ffn": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
        "mlp": gated_mlp_specs(cfg.d_model, cfg.d_ff),
    }


def _shared_attn_apply(p, x, emb, cfg: ArchConfig, kv=None, pos=None):
    """Train path (kv=None) or decode path (kv=(k, v) cache slices)."""
    d2 = 2 * cfg.d_model
    dh = d2 // cfg.n_heads
    hk = cfg.n_kv_heads
    cat = jnp.concatenate([x, emb], axis=-1)
    h = rms_norm(cat, p["ln"], cfg.norm_eps)
    b, t, _ = h.shape
    q = jnp.einsum("...d,df->...f", h, p["wq"]).reshape(b, t, cfg.n_heads, dh)
    k = jnp.einsum("...d,df->...f", h, p["wk"]).reshape(b, t, hk, dh)
    v = jnp.einsum("...d,df->...f", h, p["wv"]).reshape(b, t, hk, dh)
    if kv is None:
        positions = jnp.arange(t)[None, :]
        q = A.apply_rope(q, positions, cfg.rope_theta)
        k = A.apply_rope(k, positions, cfg.rope_theta)
        out = A.blocked_attention(
            q,
            A._expand_kv(k, cfg.n_heads),
            A._expand_kv(v, cfg.n_heads),
            causal=True,
        )
        new_kv = None
    else:
        ck, cv = kv
        positions = jnp.full((b, 1), pos)
        q = A.apply_rope(q, positions, cfg.rope_theta)
        k = A.apply_rope(k, positions, cfg.rope_theta)
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), pos, axis=1)
        kf = A._expand_kv(ck, cfg.n_heads)
        vf = A._expand_kv(cv, cfg.n_heads)
        s = kf.shape[1]
        scores = jnp.einsum(
            "bqhd,bshd->bhqs", q.astype(jnp.float32), kf.astype(jnp.float32)
        ) * dh ** -0.5
        valid = jnp.arange(s)[None, :] <= pos
        scores = jnp.where(valid[:, None, None, :], scores, A.NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhqs,bshd->bqhd", probs, vf.astype(jnp.float32))
        out = out.astype(x.dtype)
        new_kv = (ck, cv)
    out = out.reshape(b, t, cfg.n_heads * dh)
    x = x + jnp.einsum("...f,fd->...d", out, p["wo"])
    hh = rms_norm(x, p["ln_ffn"], cfg.norm_eps)
    return x + gated_mlp(p["mlp"], hh, cfg.ffn_act), new_kv


def _build_hybrid(cfg: ArchConfig) -> Model:
    specs = {
        "embed": embedding_specs(cfg.padded_vocab, cfg.d_model),
        "mamba": _stack_specs(S.mamba2_specs(cfg), cfg.n_layers),
        "shared_attn": _shared_attn_specs(cfg),
        "ln_f": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
    }
    every = max(1, cfg.attn_every)

    def backbone_train(params, x):
        emb = x

        def layer(carry, lp):
            x, idx = carry
            p, use_attn = lp
            x = constrain_batch(x + S.mamba2_block(p, x, cfg))

            def with_attn(x):
                y, _ = _shared_attn_apply(params["shared_attn"], x, emb, cfg)
                return y

            x = jax.lax.cond(use_attn, with_attn, lambda x: x, x)
            return (x, idx + 1), None

        flags = (jnp.arange(cfg.n_layers) % every) == (every - 1)
        layer_fn = _remat(layer, cfg.remat)
        (x, _), _ = jax.lax.scan(
            lambda c, lp: layer_fn(c, lp),
            (x, jnp.zeros((), jnp.int32)),
            (params["mamba"], flags),
        )
        return rms_norm(x, params["ln_f"], cfg.norm_eps)

    def train_loss(params, batch):
        tokens = batch["tokens"]
        x = embed_tokens(params["embed"], tokens)
        x = backbone_train(params, x)
        logits = jnp.einsum("...d,vd->...v", x, params["embed"]["table"])
        labels = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
        mask = jnp.pad(jnp.ones_like(tokens[:, 1:], jnp.float32), ((0, 0), (0, 1)))
        loss = _xent(logits, labels, mask)
        return loss, {"loss": loss}

    def prefill_logits(params, batch):
        x = embed_tokens(params["embed"], batch["tokens"])
        x = backbone_train(params, x)
        return jnp.einsum("...d,vd->...v", x[:, -1:], params["embed"]["table"])

    n_uses = cfg.n_layers // every
    di = cfg.ssm_expand * cfg.d_model
    nh = di // cfg.ssm_head_dim
    d2h = 2 * cfg.d_model // cfg.n_heads

    def cache_specs(batch: int, seq: int):
        return {
            "mamba_h": jax.ShapeDtypeStruct(
                (cfg.n_layers, batch, nh, cfg.ssm_head_dim, cfg.ssm_state),
                jnp.float32,
            ),
            "mamba_conv": jax.ShapeDtypeStruct(
                (cfg.n_layers, batch, cfg.ssm_conv - 1, di + 2 * cfg.ssm_state),
                jnp.bfloat16,
            ),
            "attn_k": jax.ShapeDtypeStruct(
                (n_uses, batch, seq, cfg.n_kv_heads, d2h), jnp.bfloat16
            ),
            "attn_v": jax.ShapeDtypeStruct(
                (n_uses, batch, seq, cfg.n_kv_heads, d2h), jnp.bfloat16
            ),
        }

    def serve_step(params, cache, batch):
        tok, pos = batch["token"], batch["pos"]
        x = embed_tokens(params["embed"], tok)
        emb = x
        # mamba layers scanned; shared attn applied at the cadence points by
        # unrolling over the (few) attention uses — cache group per use.
        mh, mc = cache["mamba_h"], cache["mamba_conv"]
        ak, av = cache["attn_k"], cache["attn_v"]
        mh_l = mh.reshape(n_uses, every, *mh.shape[1:])
        mc_l = mc.reshape(n_uses, every, *mc.shape[1:])

        def use_group(x, gp):
            mparams, h_g, c_g, k_g, v_g = gp

            def mlayer(x, lp):
                p, h, c = lp
                y, h, c = S.mamba2_decode_step(p, x, h, c, cfg)
                return x + y, (h, c)

            x, (h_g, c_g) = jax.lax.scan(mlayer, x, (mparams, h_g, c_g))
            x, (k_g, v_g) = _shared_attn_apply(
                params["shared_attn"], x, emb, cfg, kv=(k_g, v_g), pos=pos
            )
            return x, (h_g, c_g, k_g, v_g)

        mp = jax.tree.map(
            lambda a: a.reshape(n_uses, every, *a.shape[1:]), params["mamba"]
        )
        x, (h2, c2, k2, v2) = jax.lax.scan(use_group, x, (mp, mh_l, mc_l, ak, av))
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = jnp.einsum("...d,vd->...v", x, params["embed"]["table"])
        new_cache = {
            "mamba_h": h2.reshape(cfg.n_layers, *h2.shape[2:]),
            "mamba_conv": c2.reshape(cfg.n_layers, *c2.shape[2:]),
            "attn_k": k2,
            "attn_v": v2,
        }
        return logits, new_cache

    return Model(cfg, specs, train_loss, prefill_logits, serve_step, cache_specs)


# ------------------------------------------------------------- xLSTM -------

def _build_xlstm(cfg: ArchConfig) -> Model:
    period = max(2, cfg.xlstm_slstm_every)          # e.g. 8 => 7 mLSTM + 1 sLSTM
    n_groups = cfg.n_layers // period
    n_m = period - 1
    m_block = {
        "ln": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
        "m": X.mlstm_specs(cfg),
    }
    s_block = {
        "ln": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
        "s": X.slstm_specs(cfg),
    }
    specs = {
        "embed": embedding_specs(cfg.padded_vocab, cfg.d_model),
        "mlstm": _stack_specs(_stack_specs(m_block, n_m), n_groups),
        "slstm": _stack_specs(s_block, n_groups),
        "ln_f": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
    }

    def backbone_train(params, x):
        def group(x, gp):
            def mlayer(x, lp):
                x = constrain_batch(x)
                h = rms_norm(x, lp["ln"], cfg.norm_eps)
                return x + X.mlstm_block(lp["m"], h, cfg), None

            mfn = _remat(mlayer, cfg.remat)
            x, _ = jax.lax.scan(lambda c, lp: mfn(c, lp), x, gp["mlstm"])
            h = rms_norm(x, gp["slstm"]["ln"], cfg.norm_eps)
            x = x + X.slstm_block(gp["slstm"]["s"], h, cfg)
            return x, None

        x, _ = jax.lax.scan(
            group, x, {"mlstm": params["mlstm"], "slstm": params["slstm"]}
        )
        return rms_norm(x, params["ln_f"], cfg.norm_eps)

    def train_loss(params, batch):
        tokens = batch["tokens"]
        x = embed_tokens(params["embed"], tokens)
        x = backbone_train(params, x)
        logits = jnp.einsum("...d,vd->...v", x, params["embed"]["table"])
        labels = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
        mask = jnp.pad(jnp.ones_like(tokens[:, 1:], jnp.float32), ((0, 0), (0, 1)))
        loss = _xent(logits, labels, mask)
        return loss, {"loss": loss}

    def prefill_logits(params, batch):
        x = embed_tokens(params["embed"], batch["tokens"])
        x = backbone_train(params, x)
        return jnp.einsum("...d,vd->...v", x[:, -1:], params["embed"]["table"])

    di = 2 * cfg.d_model
    K = di // cfg.n_heads
    dh = cfg.d_model // cfg.n_heads

    def cache_specs(batch: int, seq: int):
        del seq  # recurrent state: O(1) in context length (the point of xLSTM)
        f32 = jnp.float32
        return {
            "mC": jax.ShapeDtypeStruct((n_groups, n_m, batch, cfg.n_heads, K, K), f32),
            "mN": jax.ShapeDtypeStruct((n_groups, n_m, batch, cfg.n_heads, K), f32),
            "sc": jax.ShapeDtypeStruct((n_groups, batch, cfg.n_heads, dh), f32),
            "sn": jax.ShapeDtypeStruct((n_groups, batch, cfg.n_heads, dh), f32),
            "sh": jax.ShapeDtypeStruct((n_groups, batch, cfg.n_heads, dh), f32),
            "sm": jax.ShapeDtypeStruct((n_groups, batch, cfg.n_heads, dh), f32),
        }

    def serve_step(params, cache, batch):
        tok = batch["token"]
        x = embed_tokens(params["embed"], tok)

        def group(x, gp):
            mparams, sparams, mC, mN, sc, sn, sh, sm = gp

            def mlayer(x, lp):
                p, C, n = lp
                h = rms_norm(x, p["ln"], cfg.norm_eps)
                y, C, n = X.mlstm_decode_step(p["m"], h, C, n, cfg)
                return x + y, (C, n)

            x, (mC, mN) = jax.lax.scan(mlayer, x, (mparams, mC, mN))
            h = rms_norm(x, sparams["ln"], cfg.norm_eps)
            st = {"c": sc, "n": sn, "h": sh, "m": sm}
            y, st = X.slstm_decode_step(sparams["s"], h, st, cfg)
            x = x + y
            return x, (mC, mN, st["c"], st["n"], st["h"], st["m"])

        x, (mC, mN, sc, sn, sh, sm) = jax.lax.scan(
            group,
            x,
            (params["mlstm"], params["slstm"], cache["mC"], cache["mN"],
             cache["sc"], cache["sn"], cache["sh"], cache["sm"]),
        )
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = jnp.einsum("...d,vd->...v", x, params["embed"]["table"])
        return logits, {
            "mC": mC, "mN": mN, "sc": sc, "sn": sn, "sh": sh, "sm": sm
        }

    return Model(cfg, specs, train_loss, prefill_logits, serve_step, cache_specs)
