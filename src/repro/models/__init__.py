from .transformer import Model, build_model
from .layers import (
    ParamSpec, abstract_params, init_params, param_shardings, tree_paths,
)

__all__ = [
    "Model", "ParamSpec", "abstract_params", "build_model", "init_params",
    "param_shardings", "tree_paths",
]
