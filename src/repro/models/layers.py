"""Foundational layers + the parameter-spec system.

Parameters are declared as :class:`ParamSpec` trees (shape + logical axes +
init), from which three things derive mechanically:

* real initialisation (``init_params``) for smoke tests / the train driver,
* abstract ``ShapeDtypeStruct`` trees (``abstract_params``) for the dry-run
  (.lower/.compile without ever allocating 67B parameters), and
* ``PartitionSpec`` trees (``param_shardings``) via the logical-axis rules
  in ``configs.base`` (train mode = FSDP over "data" + TP over "model";
  decode mode = TP only).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import logical_to_spec


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"     # normal | zeros | ones
    scale: Optional[float] = None  # default: 1/sqrt(fan_in)

    def fan_in_scale(self) -> float:
        if self.scale is not None:
            return self.scale
        fan_in = self.shape[0] if len(self.shape) > 1 else self.shape[-1]
        return 1.0 / float(np.sqrt(max(1, fan_in)))


ParamTree = Any  # nested dict of ParamSpec / jnp arrays


def tree_paths(specs: ParamTree, prefix: str = "") -> Dict[str, ParamSpec]:
    out: Dict[str, ParamSpec] = {}
    if isinstance(specs, ParamSpec):
        out[prefix] = specs
        return out
    for k, v in specs.items():
        out.update(tree_paths(v, f"{prefix}/{k}" if prefix else k))
    return out


def init_params(specs: ParamTree, rng: jax.Array, dtype: Any) -> ParamTree:
    flat = tree_paths(specs)
    keys = jax.random.split(rng, max(1, len(flat)))
    out: Dict[str, jax.Array] = {}
    for (path, spec), key in zip(sorted(flat.items()), keys):
        if spec.init == "zeros":
            arr = jnp.zeros(spec.shape, dtype)
        elif spec.init == "ones":
            arr = jnp.ones(spec.shape, dtype)
        else:
            arr = (
                jax.random.normal(key, spec.shape, jnp.float32)
                * spec.fan_in_scale()
            ).astype(dtype)
        out[path] = arr
    return _unflatten(out)


def abstract_params(specs: ParamTree, dtype: Any) -> ParamTree:
    flat = tree_paths(specs)
    out = {
        path: jax.ShapeDtypeStruct(spec.shape, dtype)
        for path, spec in flat.items()
    }
    return _unflatten(out)


def param_shardings(
    specs: ParamTree, rules: Mapping[str, Any], mesh=None
) -> ParamTree:
    """PartitionSpec per ParamSpec; with a mesh, mesh-axis components that
    do not divide the tensor dim are dropped greedily (e.g. xlstm's 1408-wide
    FFN keeps "model" 16-way FSDP but drops the extra "data" 16-way)."""
    flat = tree_paths(specs)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh is not None else {}

    def fit(spec: ParamSpec):
        ps = logical_to_spec(spec.logical, rules)
        if not sizes:
            return ps
        fixed = []
        for dim, axis in zip(spec.shape, ps):
            if axis is None:
                fixed.append(None)
                continue
            comps = (axis,) if isinstance(axis, str) else tuple(axis)
            kept = []
            prod = 1
            for c in comps:
                if dim % (prod * sizes.get(c, 1)) == 0:
                    kept.append(c)
                    prod *= sizes.get(c, 1)
            fixed.append(None if not kept else
                         (kept[0] if len(kept) == 1 else tuple(kept)))
        from jax.sharding import PartitionSpec as P

        return P(*fixed)

    out = {path: fit(spec) for path, spec in flat.items()}
    return _unflatten(out)


def _unflatten(flat: Dict[str, Any]) -> ParamTree:
    tree: Dict[str, Any] = {}
    for path, v in flat.items():
        parts = path.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


# ---------------------------------------------------------------------------
# numeric layers
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + weight.astype(jnp.float32))).astype(dt)


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(
    x: jax.Array, positions: jax.Array, theta: float = 1e4
) -> jax.Array:
    """x: (..., T, H, Dh); positions: broadcastable to (..., T)."""
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)                       # (Dh/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (...,T,Dh/2)
    cos = jnp.cos(angles)[..., :, None, :]                    # (...,T,1,Dh/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def gated_mlp_specs(d_model: int, d_ff: int) -> Dict[str, ParamSpec]:
    return {
        "w_gate": ParamSpec((d_model, d_ff), ("embed", "mlp")),
        "w_up": ParamSpec((d_model, d_ff), ("embed", "mlp")),
        "w_down": ParamSpec((d_ff, d_model), ("mlp", "embed")),
    }


def gated_mlp(params: Mapping[str, jax.Array], x: jax.Array, act: str) -> jax.Array:
    gate = jnp.einsum("...d,df->...f", x, params["w_gate"])
    up = jnp.einsum("...d,df->...f", x, params["w_up"])
    if act == "geglu":
        h = jax.nn.gelu(gate, approximate=True) * up
    else:  # swiglu
        h = jax.nn.silu(gate) * up
    return jnp.einsum("...f,fd->...d", h, params["w_down"])


def embedding_specs(vocab: int, d_model: int) -> Dict[str, ParamSpec]:
    return {"table": ParamSpec((vocab, d_model), ("vocab", "embed"), scale=1.0)}


def embed_tokens(params: Mapping[str, jax.Array], tokens: jax.Array) -> jax.Array:
    return jnp.take(params["table"], tokens, axis=0)


def lm_logits(
    embed_params: Mapping[str, jax.Array],
    x: jax.Array,
    head: Optional[jax.Array] = None,
) -> jax.Array:
    table = head if head is not None else embed_params["table"]
    return jnp.einsum("...d,vd->...v", x, table)
