"""Grouped-query attention: training/prefill (chunked flash-style), decode
(contiguous or paged KV cache), cross-attention, sliding windows.

Memory discipline: prefill/train attention never materialises the full
(T, T) score matrix — a ``lax.scan`` over query blocks keeps the working set
at (B, H, block, T) like flash attention (the Pallas kernel in
``repro.kernels`` is the TPU-optimised realisation; this jnp path is the
oracle and the CPU/dry-run path — identical FLOPs, fusable by XLA).

Decode reads the KV cache with q-length 1; the cache sequence axis is
sharded over "model" (flash-decode style) per ``configs.base.mesh_rules`` —
XLA inserts the partial-softmax combine collectives automatically.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from .layers import ParamSpec, apply_rope

NEG_INF = -1e30


def attn_specs(cfg: ArchConfig, cross: bool = False) -> Dict[str, ParamSpec]:
    d, dh = cfg.d_model, cfg.head_dim_
    h, hk = cfg.n_heads, cfg.n_kv_heads
    specs = {
        "wq": ParamSpec((d, h * dh), ("embed", "q_dim")),
        "wk": ParamSpec((d, hk * dh), ("embed", "q_dim")),
        "wv": ParamSpec((d, hk * dh), ("embed", "q_dim")),
        "wo": ParamSpec((h * dh, d), ("q_dim", "embed")),
    }
    if cfg.qkv_bias:
        specs["bq"] = ParamSpec((h * dh,), ("q_dim",), init="zeros")
        specs["bk"] = ParamSpec((hk * dh,), ("q_dim",), init="zeros")
        specs["bv"] = ParamSpec((hk * dh,), ("q_dim",), init="zeros")
    return specs


def _project_qkv(
    params: Mapping[str, jax.Array],
    x: jax.Array,
    kv_src: jax.Array,
    cfg: ArchConfig,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    dh = cfg.head_dim_
    q = jnp.einsum("...d,df->...f", x, params["wq"])
    k = jnp.einsum("...d,df->...f", kv_src, params["wk"])
    v = jnp.einsum("...d,df->...f", kv_src, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(*q.shape[:-1], cfg.n_heads, dh)
    k = k.reshape(*k.shape[:-1], cfg.n_kv_heads, dh)
    v = v.reshape(*v.shape[:-1], cfg.n_kv_heads, dh)
    return q, k, v


def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B, S, Hk, Dh) -> (B, S, H, Dh) by GQA group broadcast."""
    hk = k.shape[-2]
    if hk == n_heads:
        return k
    reps = n_heads // hk
    return jnp.repeat(k, reps, axis=-2)


def blocked_attention(
    q: jax.Array,            # (B, T, H, Dh)
    k: jax.Array,            # (B, S, Hk, Dh) — GQA heads, NOT pre-expanded
    v: jax.Array,            # (B, S, Hk, Dh)
    causal: bool,
    window: Optional[Any] = None,   # int or traced scalar; None = unbounded
    q_offset: int = 0,
    block: int = 512,
) -> jax.Array:
    """Flash-style attention: scan over query blocks, full K per block.

    GQA is computed in grouped form (B, Hk, G, ...) — the KV heads are never
    materialised H/Hk times (§Perf: the jnp.repeat expansion showed up as an
    8x bytes/collective multiplier in the dry-run HLO).  f32 accumulation
    happens inside the dots via preferred_element_type, not via f32 copies.
    """
    b, t, h, dh = q.shape
    s, hk = k.shape[1], k.shape[2]
    g = h // hk
    scale = dh ** -0.5
    nblk = max(1, (t + block - 1) // block)
    pad = nblk * block - t
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qb = q.reshape(b, nblk, block, hk, g, dh).transpose(1, 0, 3, 4, 2, 5)
    # (n, B, Hk, G, blk, Dh)
    kpos = jnp.arange(s)
    f32 = jnp.float32

    def one_block(carry, inp):
        qi, blk_idx = inp
        scores = jnp.einsum(
            "bkgqd,bskd->bkgqs", qi, k, preferred_element_type=f32
        ) * scale
        qpos = q_offset + blk_idx * block + jnp.arange(block)
        rel = qpos[:, None] - kpos[None, :]
        mask = jnp.ones((block, s), dtype=bool)
        if causal:
            mask &= rel >= 0
        if window is not None:
            mask &= rel < window
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum(
            "bkgqs,bskd->bkgqd", probs, v, preferred_element_type=f32
        )
        return carry, out.astype(q.dtype)

    _, outs = jax.lax.scan(
        one_block, None, (qb, jnp.arange(nblk)), length=nblk
    )  # (n, B, Hk, G, blk, Dh)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, nblk * block, h, dh)
    return out[:, :t]


def mha_train(
    params: Mapping[str, jax.Array],
    x: jax.Array,                      # (B, T, d)
    cfg: ArchConfig,
    positions: Optional[jax.Array] = None,
    window: Optional[Any] = None,
    causal: bool = True,
    kv_src: Optional[jax.Array] = None,  # cross-attention source
    rope: bool = True,
) -> jax.Array:
    b, t, _ = x.shape
    src = kv_src if kv_src is not None else x
    q, k, v = _project_qkv(params, x, src, cfg)
    if positions is None:
        positions = jnp.arange(t)[None, :]
    if rope and kv_src is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    out = blocked_attention(q, k, v, causal=causal and kv_src is None,
                            window=window)
    out = out.reshape(b, t, cfg.n_heads * cfg.head_dim_)
    return jnp.einsum("...f,fd->...d", out, params["wo"])


# ---------------------------------------------------------------------------
# decode path (one new token, contiguous KV cache)
# ---------------------------------------------------------------------------

def init_kv_cache(
    cfg: ArchConfig, batch: int, max_len: int, n_layers: Optional[int] = None,
    dtype: Any = jnp.bfloat16,
) -> Dict[str, jax.Array]:
    L = n_layers if n_layers is not None else cfg.n_layers
    shape = (L, batch, max_len, cfg.n_kv_heads, cfg.head_dim_)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def kv_cache_specs(
    cfg: ArchConfig, batch: int, max_len: int, n_layers: Optional[int] = None,
    dtype: Any = jnp.bfloat16,
):
    L = n_layers if n_layers is not None else cfg.n_layers
    shape = (L, batch, max_len, cfg.n_kv_heads, cfg.head_dim_)
    return {
        "k": jax.ShapeDtypeStruct(shape, dtype),
        "v": jax.ShapeDtypeStruct(shape, dtype),
    }


def mha_decode(
    params: Mapping[str, jax.Array],
    x: jax.Array,                     # (B, 1, d) new token activations
    layer_k: jax.Array,               # (B, S, Hk, Dh)
    layer_v: jax.Array,
    pos: jax.Array,                   # scalar: absolute position of new token
    cfg: ArchConfig,
    window: Optional[Any] = None,
    ring: bool = False,               # sliding-window ring-buffer cache
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One decode step against a contiguous cache. Returns (y, new_k, new_v).

    With ``ring=True`` the cache holds only the last S positions: the write
    slot is ``pos % S`` and every slot is valid once ``pos >= S-1``.  RoPE is
    always applied at the *absolute* position (write-time rotation), so
    reads need no re-rotation.
    """
    b = x.shape[0]
    dh = cfg.head_dim_
    hk = cfg.n_kv_heads
    g = cfg.n_heads // hk
    q, k_new, v_new = _project_qkv(params, x, x, cfg)
    positions = jnp.full((b, 1), pos)
    q = apply_rope(q, positions, cfg.rope_theta)
    k_new = apply_rope(k_new, positions, cfg.rope_theta)
    s = layer_k.shape[1]
    kpos = jnp.arange(s)
    if ring:
        # small local window caches are unsharded: a dynamic slice is cheap
        slot = jnp.mod(pos, s)
        layer_k = jax.lax.dynamic_update_slice_in_dim(
            layer_k, k_new.astype(layer_k.dtype), slot, axis=1
        )
        layer_v = jax.lax.dynamic_update_slice_in_dim(
            layer_v, v_new.astype(layer_v.dtype), slot, axis=1
        )
        valid = kpos[None, :] < jnp.minimum(pos + 1, s)
    else:
        # mask-write: a dynamic-update-slice at ``pos`` on the SHARDED cache
        # sequence axis forces GSPMD to replicate the whole cache (§Perf:
        # 204GB/step of all-gather on deepseek decode); the elementwise
        # select keeps every shard's slice local.
        hit = (kpos == pos)[None, :, None, None]
        layer_k = jnp.where(hit, k_new.astype(layer_k.dtype), layer_k)
        layer_v = jnp.where(hit, v_new.astype(layer_v.dtype), layer_v)
        valid = kpos[None, :] <= pos
        if window is not None:
            valid &= (pos - kpos[None, :]) < window
    # grouped GQA: never expand KV heads (see blocked_attention note)
    qg = q.reshape(b, 1, hk, g, dh)
    scale = dh ** -0.5
    scores = jnp.einsum(
        "bqkgd,bskd->bkgqs", qg, layer_k, preferred_element_type=jnp.float32
    ) * scale
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bkgqs,bskd->bqkgd", probs, layer_v,
        preferred_element_type=jnp.float32,
    )
    out = out.astype(x.dtype).reshape(b, 1, cfg.n_heads * dh)
    y = jnp.einsum("...f,fd->...d", out, params["wo"])
    return y, layer_k, layer_v
