"""Chunkwise mLSTM kernel (xLSTM matrix memory) — gated linear attention.

Same chunked structure as the SSD kernel, but keys/queries are per-head and
a (K,) normalizer state n rides along with the (K, P) matrix memory C:

  C_t = a_t C_{t-1} + i_t k_t v_t^T        n_t = a_t n_{t-1} + i_t k_t
  y_t = (q_t C_t) / max(|q_t n_t|, 1)

grid = (batch, head, chunk); scratch: C (K, P) + n (K, 1) f32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _kernel(
    q_ref,   # (1, chunk, 1, K)
    k_ref,   # (1, chunk, 1, K)
    v_ref,   # (1, chunk, 1, P)
    a_ref,   # (1, chunk, 1)
    i_ref,   # (1, chunk, 1)
    y_ref,   # (1, chunk, 1, P)
    C_ref,   # (K, P) f32 scratch
    n_ref,   # (K, 1) f32 scratch
    *,
    chunk: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        C_ref[...] = jnp.zeros_like(C_ref)
        n_ref[...] = jnp.zeros_like(n_ref)

    K = q_ref.shape[-1]
    q = q_ref[0, :, 0, :].astype(jnp.float32) * (K ** -0.5)  # (Q, K)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)                # (Q, P)
    a = a_ref[0, :, 0].astype(jnp.float32)                   # (Q,)
    ig = i_ref[0, :, 0].astype(jnp.float32)

    loga = jnp.log(jnp.clip(a, 1e-20, None))
    cum = jnp.cumsum(loga)
    total = cum[-1]
    li = cum[:, None] - cum[None, :]
    mask = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(mask, jnp.exp(li), 0.0)
    qk = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    w = qk * L * ig[None, :]
    y_intra = jax.lax.dot_general(w, v, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    nrm_intra = w.sum(axis=-1)                                # (Q,)

    dstart = jnp.exp(cum)
    y_inter = jax.lax.dot_general(
        q, C_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * dstart[:, None]
    nrm_inter = jax.lax.dot_general(
        q, n_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[:, 0] * dstart
    nrm = jnp.maximum(jnp.abs(nrm_intra + nrm_inter), 1.0)
    y_ref[0, :, 0, :] = ((y_intra + y_inter) / nrm[:, None]).astype(y_ref.dtype)

    dte = jnp.exp(total - cum) * ig                           # (Q,)
    kw = k * dte[:, None]                                     # (Q, K)
    C_ref[...] = C_ref[...] * jnp.exp(total) + jax.lax.dot_general(
        kw, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    n_ref[...] = n_ref[...] * jnp.exp(total) + kw.sum(axis=0)[:, None]


# analysis: oracle=gla_ref  (the mLSTM recurrence is the GLA family's)
def mlstm_chunked_kernel(
    q: jax.Array,   # (B, T, H, K)
    k: jax.Array,
    v: jax.Array,   # (B, T, H, P)
    a: jax.Array,   # (B, T, H) forget gate in (0,1]
    i: jax.Array,   # (B, T, H) input gate
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, T, H, K = q.shape
    P = v.shape[-1]
    nc = (T + chunk - 1) // chunk
    Tp = nc * chunk
    if Tp != T:
        pad4 = ((0, 0), (0, Tp - T), (0, 0), (0, 0))
        pad3 = ((0, 0), (0, Tp - T), (0, 0))
        q = jnp.pad(q, pad4)
        k = jnp.pad(k, pad4)
        v = jnp.pad(v, pad4)
        a = jnp.pad(a, pad3, constant_values=1.0)
        i = jnp.pad(i, pad3)

    grid = (B, H, nc)
    qkv_spec = lambda last: pl.BlockSpec(
        (1, chunk, 1, last), lambda bi, hi, ci: (bi, ci, hi, 0),
        memory_space=pltpu.VMEM,
    )
    gate_spec = pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi),
                             memory_space=pltpu.VMEM)
    out = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[qkv_spec(K), qkv_spec(K), qkv_spec(P), gate_spec, gate_spec],
        out_specs=qkv_spec(P),
        scratch_shapes=[
            pltpu.VMEM((K, P), jnp.float32),
            pltpu.VMEM((K, 1), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((B, Tp, H, P), v.dtype),
        interpret=interpret,
    )(q, k, v, a, i)
    return out[:, :T]
