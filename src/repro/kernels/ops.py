"""jit'd dispatch wrappers for the Pallas kernels.

Backend policy (process-global, settable):
  * "auto"      — Pallas on TPU, jnp reference elsewhere (CPU dry-run/test)
  * "pallas"    — force the compiled Pallas path (real TPU)
  * "interpret" — Pallas kernel body interpreted in Python (CPU correctness)
  * "reference" — force the jnp oracle

The model code calls these wrappers, so swapping kernels on/off never touches
model definitions — and the dry-run lowers the reference path (XLA HLO),
which is what cost_analysis reads.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention_kernel
from .mamba2_scan import mamba2_scan_kernel
from .mlstm import mlstm_chunked_kernel
from .paged_attention import paged_attention_kernel
from .pbm_timeline import batched_evict_kernel, fifo_grant_kernel

_BACKEND = "auto"
#: the known backend names; set_backend validates eagerly so a typo
#: fails at the call site with the valid list (the policy registry's
#: unknown-name UX), not later at dispatch inside a traced step
BACKENDS = ("auto", "pallas", "interpret", "reference")


def set_backend(name: str) -> None:
    global _BACKEND
    if name not in BACKENDS:
        raise ValueError(
            f"unknown kernel backend {name!r}; valid backends: "
            f"{sorted(BACKENDS)} (see repro.kernels.ops)"
        )
    _BACKEND = name


def get_backend() -> str:
    return _BACKEND


def _use_pallas() -> Optional[bool]:
    """True = compiled pallas, False = reference, None -> interpret."""
    if _BACKEND == "pallas":
        return True
    if _BACKEND == "reference":
        return False
    if _BACKEND == "interpret":
        return None
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=())
def paged_attention(q, k_pages, v_pages, page_table, seq_lens):
    mode = _use_pallas()
    if mode is True:
        return paged_attention_kernel(q, k_pages, v_pages, page_table, seq_lens)
    if mode is None:
        return paged_attention_kernel(
            q, k_pages, v_pages, page_table, seq_lens, interpret=True
        )
    return ref.paged_attention_ref(q, k_pages, v_pages, page_table, seq_lens)


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention(q, k, v, causal: bool = True, window: Optional[int] = None):
    mode = _use_pallas()
    if mode is True:
        return flash_attention_kernel(q, k, v, causal=causal, window=window)
    if mode is None:
        return flash_attention_kernel(
            q, k, v, causal=causal, window=window,
            block_q=64, block_kv=64, interpret=True,
        )
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window)


@functools.partial(jax.jit, static_argnames=("chunk",))
def mamba2_scan(xh, a, b, c, chunk: int = 128):
    mode = _use_pallas()
    if mode is True:
        return mamba2_scan_kernel(xh, a, b, c, chunk=chunk)
    if mode is None:
        return mamba2_scan_kernel(xh, a, b, c, chunk=chunk, interpret=True)
    y, _ = ref.mamba2_scan_ref(xh, a, b, c)
    return y


def fifo_grant(key, sizes, budget, pops, *, vmax: int = 16):
    """Budgeted FIFO grant over the request-queue key array (the array
    sim's serial I/O server pop, macro-step sized).

    The service order is fully encoded in ``key`` (stamp-FIFO with
    policy-provided cohort ties, -1 = not wanted); strict head-of-line
    admission against ``budget`` bytes and ``pops`` pops.  Called from
    inside the already-jitted event-horizon step, so no jit wrapper;
    backend policy picks the Mosaic kernel on TPU and the jnp oracle
    (one ``top_k`` + prefix product) elsewhere.

    The ``jax.named_scope`` span names this op in profiler traces and in
    lowered HLO, so ``benchmarks/roofline.py --kernels`` and a Perfetto
    capture both attribute its cost to ``kernel:fifo_grant``."""
    with jax.named_scope("kernel:fifo_grant"):
        mode = _use_pallas()
        if mode is not False:
            return fifo_grant_kernel(
                key, sizes, budget, pops, vmax=vmax, interpret=(mode is None),
            )
        return ref.fifo_grant_ref(key, sizes, budget, pops, vmax=vmax)


def batched_evict(key, sizes, evictable, need_free, *, vmax: int = 64):
    """Batched evict selection over a policy score array (array-sim core).

    The eviction policy is fully encoded in ``key`` — the
    ``ArrayPolicy.score_victims`` output for this step — so this one op
    serves LRU, PBM, CScan, OPT, and any future registered policy.
    Integer score arrays (exact Belady next-use distances) are honoured
    bit-exactly: both the kernel and the oracle keep them on an integer
    path instead of an f32 cast that would collapse keys beyond 2^24.
    Called from inside the already-jitted ``array_sim`` step, so no jit
    wrapper here; backend policy picks the Mosaic kernel on TPU and the
    jnp oracle elsewhere (the oracle is itself fully vectorised).

    Wrapped in a ``jax.named_scope`` span so profiler traces and
    ``roofline.py --kernels`` attribute it as ``kernel:batched_evict``.
    """
    with jax.named_scope("kernel:batched_evict"):
        mode = _use_pallas()
        if mode is not False:
            return batched_evict_kernel(
                key, sizes, evictable, need_free,
                vmax=vmax, interpret=(mode is None),
            )
        return ref.batched_evict_ref(
            key, sizes, evictable, need_free, vmax=vmax,
        )


@functools.partial(jax.jit, static_argnames=("chunk",))
def mlstm_chunked(q, k, v, a, i, chunk: int = 128):
    mode = _use_pallas()
    if mode is True:
        return mlstm_chunked_kernel(q, k, v, a, i, chunk=chunk)
    if mode is None:
        return mlstm_chunked_kernel(q, k, v, a, i, chunk=chunk, interpret=True)
    return ref.gla_ref(q, k, v, a, i)
