"""jit'd dispatch wrappers for the Pallas kernels.

Backend policy (process-global, settable):
  * "auto"      — Pallas on TPU, jnp reference elsewhere (CPU dry-run/test)
  * "pallas"    — force the compiled Pallas path (real TPU)
  * "interpret" — Pallas kernel body interpreted in Python (CPU correctness)
  * "reference" — force the jnp oracle

The model code calls these wrappers, so swapping kernels on/off never touches
model definitions — and the dry-run lowers the reference path (XLA HLO),
which is what cost_analysis reads.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention_kernel
from .mamba2_scan import mamba2_scan_kernel
from .mlstm import mlstm_chunked_kernel
from .paged_attention import paged_attention_kernel
from .pbm_timeline import (
    batched_evict_kernel,
    fifo_grant_kernel,
    wake_solve_kernel,
)

_BACKEND = "auto"
#: the known backend names; set_backend validates eagerly so a typo
#: fails at the call site with the valid list (the policy registry's
#: unknown-name UX), not later at dispatch inside a traced step
BACKENDS = ("auto", "pallas", "interpret", "reference")


def set_backend(name: str) -> None:
    global _BACKEND
    if name not in BACKENDS:
        raise ValueError(
            f"unknown kernel backend {name!r}; valid backends: "
            f"{sorted(BACKENDS)} (see repro.kernels.ops)"
        )
    _BACKEND = name


def get_backend() -> str:
    return _BACKEND


def _use_pallas() -> Optional[bool]:
    """True = compiled pallas, False = reference, None -> interpret."""
    if _BACKEND == "pallas":
        return True
    if _BACKEND == "reference":
        return False
    if _BACKEND == "interpret":
        return None
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=())
def paged_attention(q, k_pages, v_pages, page_table, seq_lens):
    mode = _use_pallas()
    if mode is True:
        return paged_attention_kernel(q, k_pages, v_pages, page_table, seq_lens)
    if mode is None:
        return paged_attention_kernel(
            q, k_pages, v_pages, page_table, seq_lens, interpret=True
        )
    return ref.paged_attention_ref(q, k_pages, v_pages, page_table, seq_lens)


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention(q, k, v, causal: bool = True, window: Optional[int] = None):
    mode = _use_pallas()
    if mode is True:
        return flash_attention_kernel(q, k, v, causal=causal, window=window)
    if mode is None:
        return flash_attention_kernel(
            q, k, v, causal=causal, window=window,
            block_q=64, block_kv=64, interpret=True,
        )
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window)


@functools.partial(jax.jit, static_argnames=("chunk",))
def mamba2_scan(xh, a, b, c, chunk: int = 128):
    mode = _use_pallas()
    if mode is True:
        return mamba2_scan_kernel(xh, a, b, c, chunk=chunk)
    if mode is None:
        return mamba2_scan_kernel(xh, a, b, c, chunk=chunk, interpret=True)
    y, _ = ref.mamba2_scan_ref(xh, a, b, c)
    return y


# ---------------------------------------------------------------- sharding --
#
# Page-axis sharding (make_runner 2-axis mesh): per-page state is
# replicated across the page axis, but each shard only *scans* its own
# P/n slice of the pool for candidates — the O(P) candidate selection
# divides across the mesh, and the vmax-bounded prefix solve runs on the
# gathered compact candidate list.  The construction is reduction-safe
# by being bitwise-identical to the unsharded oracle: a page in the
# global top-vmax is necessarily in its own shard's local top-vmax, and
# re-ordering the gathered candidates by ascending global index before a
# stable top_k reproduces the exact (key desc, index asc) service order
# — so the f32 prefix sums visit identical values in identical order.


def _page_shard_candidates(key, aux, axis: str, vmax: int):
    """Local top-``vmax`` per page shard, gathered and re-ordered into
    the exact global service order.

    Returns ``(kv, gidx, *aux_vals)`` flattened over shards and sorted
    ascending by global index (so a stable ``top_k`` on ``kv`` resolves
    ties exactly like the unsharded oracle's)."""
    P = key.shape[0]
    aux = list(aux)
    n = int(jax.lax.psum(1, axis))
    if P % n:
        raise ValueError(
            f"page axis {axis!r} has {n} shards which do not divide the "
            f"padded pool size P={P}")
    p_loc = P // n
    start = jax.lax.axis_index(axis) * p_loc
    k_loc = jax.lax.dynamic_slice(key, (start,), (p_loc,))
    _, cand = jax.lax.top_k(k_loc, min(vmax, p_loc))
    rows = [k_loc[cand], cand + start]
    rows += [jax.lax.dynamic_slice(a, (start,), (p_loc,))[cand] for a in aux]
    gathered = [jax.lax.all_gather(r, axis).reshape(-1) for r in rows]
    order = jnp.argsort(gathered[1])
    return [g[order] for g in gathered]


def _grant_page_sharded(key, sizes, budget, pops, vmax: int, axis: str):
    kv, gidx, sz = _page_shard_candidates(key, [sizes], axis, vmax)
    take = min(vmax, kv.shape[0])
    kv_top, pos = jax.lax.top_k(kv, take)
    sz_c = sz[pos]
    csum = jnp.cumsum(sz_c)
    ok = jnp.cumprod(
        ((kv_top >= 0) & (csum <= budget)
         & (jnp.arange(take) < pops)).astype(jnp.int32)
    ).astype(bool)
    mask = jnp.zeros((key.shape[0],), bool).at[gidx[pos]].set(ok)
    return mask, jnp.sum(jnp.where(ok, sz_c, 0.0)), jnp.sum(ok)


def _evict_page_sharded(key, sizes, evictable, need_free, vmax: int,
                        axis: str):
    if jnp.issubdtype(key.dtype, jnp.integer):
        keym = jnp.where(evictable, key, jnp.iinfo(key.dtype).min)
    else:
        keym = jnp.where(evictable, key, -jnp.inf)
    kv, gidx, sz, ev = _page_shard_candidates(
        keym, [sizes, evictable], axis, vmax)
    take = min(vmax, kv.shape[0])
    _, pos = jax.lax.top_k(kv, take)
    c_ok = ev[pos]
    sz_c = jnp.where(c_ok, sz[pos], 0.0)
    csum = jnp.cumsum(sz_c)
    take_mask = c_ok & (csum - sz_c < need_free) & (need_free > 0)
    return jnp.zeros((key.shape[0],), bool).at[gidx[pos]].set(take_mask)


def fifo_grant(key, sizes, budget, pops, *, vmax: int = 16,
               page_axis: Optional[str] = None):
    """Budgeted FIFO grant over the request-queue key array (the array
    sim's serial I/O server pop, macro-step sized).

    The service order is fully encoded in ``key`` (stamp-FIFO with
    policy-provided cohort ties, -1 = not wanted); strict head-of-line
    admission against ``budget`` bytes and ``pops`` pops.  Called from
    inside the already-jitted event-horizon step, so no jit wrapper;
    backend policy picks the Mosaic kernel on TPU and the jnp oracle
    (one ``top_k`` + prefix product) elsewhere.

    With ``page_axis`` (inside a page-sharded ``shard_map`` body) each
    shard scans only its own P/n pool slice for candidates and the
    prefix solve runs on the gathered compact list — bitwise-identical
    to the unsharded path (see the sharding note above).

    The ``jax.named_scope`` span names this op in profiler traces and in
    lowered HLO, so ``benchmarks/roofline.py --kernels`` and a Perfetto
    capture both attribute its cost to ``kernel:fifo_grant``."""
    with jax.named_scope("kernel:fifo_grant"):
        if page_axis is not None:
            return _grant_page_sharded(key, sizes, budget, pops, vmax,
                                       page_axis)
        mode = _use_pallas()
        if mode is not False:
            return fifo_grant_kernel(
                key, sizes, budget, pops, vmax=vmax, interpret=(mode is None),
            )
        return ref.fifo_grant_ref(key, sizes, budget, pops, vmax=vmax)


def batched_evict(key, sizes, evictable, need_free, *, vmax: int = 64,
                  page_axis: Optional[str] = None):
    """Batched evict selection over a policy score array (array-sim core).

    The eviction policy is fully encoded in ``key`` — the
    ``ArrayPolicy.score_victims`` output for this step — so this one op
    serves LRU, PBM, CScan, OPT, and any future registered policy.
    Integer score arrays (exact Belady next-use distances) are honoured
    bit-exactly: both the kernel and the oracle keep them on an integer
    path instead of an f32 cast that would collapse keys beyond 2^24.
    Called from inside the already-jitted ``array_sim`` step, so no jit
    wrapper here; backend policy picks the Mosaic kernel on TPU and the
    jnp oracle elsewhere (the oracle is itself fully vectorised).

    With ``page_axis`` (inside a page-sharded ``shard_map`` body) each
    shard scans only its own P/n pool slice for victim candidates —
    bitwise-identical to the unsharded path (see the sharding note
    above).

    Wrapped in a ``jax.named_scope`` span so profiler traces and
    ``roofline.py --kernels`` attribute it as ``kernel:batched_evict``.
    """
    with jax.named_scope("kernel:batched_evict"):
        if page_axis is not None:
            return _evict_page_sharded(key, sizes, evictable, need_free,
                                       vmax, page_axis)
        mode = _use_pallas()
        if mode is not False:
            return batched_evict_kernel(
                key, sizes, evictable, need_free,
                vmax=vmax, interpret=(mode is None),
            )
        return ref.batched_evict_ref(
            key, sizes, evictable, need_free, vmax=vmax,
        )


def wake_solve(key, sizes, credit0, inc, pops, *, h_cap: int = 64):
    """Per-page grant step of the frozen serial I/O server — the
    event-horizon stepper's wake-exact queue model (how many fine steps
    until each queued page is granted, given the io-credit cadence
    ``credit0 + k*inc`` and the per-step ``pops`` cap).

    Pages not wanted (``key < 0``) or not granted within ``h_cap`` fine
    steps carry the sentinel ``h_cap + 1``.  Called from inside the
    already-jitted event-horizon step, so no jit wrapper; backend policy
    picks the page-blocked Mosaic kernel on TPU and the jnp oracle (one
    stable argsort + the pop-rate recursion) elsewhere.  Under page
    sharding the inputs are replicated and the solve's outputs feed
    lane-global jump decisions, so it runs replicated as-is.

    Wrapped in a ``jax.named_scope`` span so profiler traces and
    ``roofline.py --kernels`` attribute it as ``kernel:wake_solve``."""
    with jax.named_scope("kernel:wake_solve"):
        mode = _use_pallas()
        if mode is not False:
            return wake_solve_kernel(
                key, sizes, credit0, inc, pops,
                h_cap=h_cap, interpret=(mode is None),
            )
        return ref.wake_solve_ref(
            key, sizes, credit0, inc, pops, h_cap=h_cap,
        )


@functools.partial(jax.jit, static_argnames=("chunk",))
def mlstm_chunked(q, k, v, a, i, chunk: int = 128):
    mode = _use_pallas()
    if mode is True:
        return mlstm_chunked_kernel(q, k, v, a, i, chunk=chunk)
    if mode is None:
        return mlstm_chunked_kernel(q, k, v, a, i, chunk=chunk, interpret=True)
    return ref.gla_ref(q, k, v, a, i)
