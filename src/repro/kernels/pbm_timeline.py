"""Pallas kernels: batched buffer-pool ops for the array simulation.

The hot inner operations of the array-native buffer-manager simulation
(`repro.core.array_sim`): eviction-victim selection, the serial
I/O-server FIFO grant, and the wake-solve (serial-server grant
schedule) that lets the event-horizon stepper macro-jump inside the
supersaturated regime.  The *policy* is entirely encoded in the ``key``
input — the score array an
:class:`repro.core.array_sim.policies.ArrayPolicy` computed for this
step (PBM's shifted bucketed timeline, LRU's age, OPT's exact next-use
distance, CScan's keep-relevance) — so a single kernel serves every
registered policy and a vmapped sweep can mix policies per lane by
selecting between their score arrays.

Design notes
------------
* All per-page state is dense ``(1, P)`` rows; wrappers pad P up to a
  multiple of ``_BLOCK`` with exact sentinels (non-wanted key, zero
  size, non-evictable) and slice the padding back off, so any P works
  and every BlockSpec divides its operand.
* Victim/grant selection is a prefix-sum over the priority order.
  Instead of sorting (awkward on the VPU), we compute for every page
  the bytes that would be freed/served *before* it via a masked
  comparison tile contracted against page sizes on the MXU.
* Since PR 10 the O(P^2) prefix work is **gridded over page blocks**:
  grid ``(i, j)`` walks (row-block, col-block) tiles of the comparison
  matrix with j innermost, accumulating per-row prefix bytes and ranks
  in VMEM scratch (reset at ``j == 0``, committed under
  ``pl.when(j == n_j - 1)`` — the sanctioned accumulator-revisit
  pattern).  Per-step VMEM is O(_BLOCK^2) regardless of P, so
  P >> VMEM satisfies the contract verifier's vmem-budget rule.
  Passes that need a *global* intermediate (the grant kernel's strict
  head-of-line ``fits`` vector, the wake kernel's per-page rank/prefix
  bytes) run as an extra leading phase axis: TPU grids are sequential,
  so phase 0 fully populates the (1, P_pad) scratch before phase 1
  reads it.

Semantics are defined by the oracles in ``repro.kernels.ref``
(``batched_evict_ref`` / ``fifo_grant_ref`` / ``wake_solve_ref``);
tests assert exact agreement in interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30  # plain float: a jnp scalar would be a captured constant
NEG_I32 = -(2**31) + 1  # i32 sentinel for the integer-key path

#: page-block width of the gridded kernels — each grid step touches an
#: O(_BLOCK^2) comparison tile, so VMEM stays bounded for any P
_BLOCK = 512


def _blocks(P: int) -> tuple[int, int]:
    p_pad = -(-P // _BLOCK) * _BLOCK
    return p_pad, p_pad // _BLOCK


def _pad_row(row: jax.Array, p_pad: int, fill) -> jax.Array:
    pad = p_pad - row.shape[-1]
    if pad == 0:
        return row
    return jnp.pad(row, ((0, 0), (0, pad)), constant_values=fill)


def _kernel(fscal_ref, key_i_ref, key_j_ref, sizes_j_ref, ev_i_ref, ev_j_ref,
            evict_out_ref, freed_acc_ref, rank_acc_ref,
            *, vmax: int, block: int, n_j: int, int_key: bool = False):
    i = pl.program_id(0)
    j = pl.program_id(1)
    need_free = fscal_ref[0, 0]

    @pl.when(j == 0)
    def _init():
        freed_acc_ref[...] = jnp.zeros_like(freed_acc_ref)
        rank_acc_ref[...] = jnp.zeros_like(rank_acc_ref)

    ev_i = ev_i_ref[:]                # (1, block) f32 0/1 — the row pages p
    ev_j = ev_j_ref[:]                # (1, block): candidate predecessors q
    neg = NEG_I32 if int_key else NEG
    key_p = jnp.where(ev_i > 0, key_i_ref[:], neg).reshape(block, 1)
    key_q = jnp.where(ev_j > 0, key_j_ref[:], neg)

    # ---- one (block, block) tile of the priority-order prefix matrix -----
    gq = j * block + jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)
    gp = i * block + jax.lax.broadcasted_iota(jnp.int32, (block, block), 0)
    before = (key_q > key_p) | ((key_q == key_p) & (gq < gp))
    sz = (sizes_j_ref[:] * ev_j).reshape(block, 1)
    freed_acc_ref[...] = freed_acc_ref[...] + jnp.dot(
        before.astype(jnp.float32), sz, preferred_element_type=jnp.float32,
    ).reshape(1, block)                # bytes freed before page p (partial)
    rank_acc_ref[...] = rank_acc_ref[...] + jnp.sum(
        before, axis=1, dtype=jnp.float32,
    ).reshape(1, block)

    @pl.when(j == n_j - 1)
    def _commit():
        # candidate cap: page p participates only if fewer than vmax pages
        # precede it in priority order (== membership of the oracle's top_k)
        take = (
            (ev_i > 0)
            & (freed_acc_ref[...] < need_free)
            & (rank_acc_ref[...] < vmax)
            & (need_free > 0)
        )
        evict_out_ref[...] = take.astype(jnp.float32)


def _grant_kernel(iscal_ref, fscal_ref, key_i_ref, key_j_ref,
                  sizes_i_ref, sizes_j_ref, grant_out_ref,
                  fits_ref, bytes_acc_ref, rank_acc_ref, blk_acc_ref,
                  *, vmax: int, block: int, n_j: int):
    ph = pl.program_id(0)
    i = pl.program_id(1)
    j = pl.program_id(2)
    pops = iscal_ref[0, 0]
    budget = fscal_ref[0, 0]

    key_p = key_i_ref[:].reshape(block, 1)
    key_q = key_j_ref[:]              # (1, block) i32 — the FIFO keys use up
    wanted_p = key_i_ref[:] >= 0      # to ~30 bits (stamp*32768 + tie), so
    wanted_q = key_q >= 0             # an f32 cast would round away the
                                      # tie bits beyond 2^24
    # service order: descending key, ties by ascending global index
    gq = j * block + jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)
    gp = i * block + jax.lax.broadcasted_iota(jnp.int32, (block, block), 0)
    before = ((key_q > key_p) | ((key_q == key_p) & (gq < gp))) & wanted_q

    # ---- phase 0: budget/pops feasibility per page ("fits") --------------
    @pl.when((ph == 0) & (j == 0))
    def _init_fits():
        bytes_acc_ref[...] = jnp.zeros_like(bytes_acc_ref)
        rank_acc_ref[...] = jnp.zeros_like(rank_acc_ref)

    @pl.when(ph == 0)
    def _acc_fits():
        sz = (sizes_j_ref[:] * wanted_q).reshape(block, 1)
        bytes_acc_ref[...] = bytes_acc_ref[...] + jnp.dot(
            before.astype(jnp.float32), sz,
            preferred_element_type=jnp.float32,
        ).reshape(1, block)
        rank_acc_ref[...] = rank_acc_ref[...] + jnp.sum(
            before, axis=1, dtype=jnp.float32,
        ).reshape(1, block)

    @pl.when((ph == 0) & (j == n_j - 1))
    def _store_fits():
        cap = jnp.minimum(pops, vmax).astype(jnp.float32)
        fits = (
            wanted_p
            & (bytes_acc_ref[...] + sizes_i_ref[:] <= budget)
            & (rank_acc_ref[...] < cap)
        )
        fits_ref[0, pl.ds(i * block, block)] = \
            fits.astype(jnp.float32).reshape(block)

    # ---- phase 1: strict head-of-line — a non-fitting wanted predecessor
    # blocks every later pop, like the engine's serial server ---------------
    @pl.when((ph == 1) & (j == 0))
    def _init_blk():
        blk_acc_ref[...] = jnp.zeros_like(blk_acc_ref)

    @pl.when(ph == 1)
    def _acc_blk():
        fits_j = fits_ref[0, pl.ds(j * block, block)].reshape(1, block)
        nonfit = (wanted_q & (fits_j == 0)).astype(jnp.float32)
        blk_acc_ref[...] = blk_acc_ref[...] + jnp.dot(
            before.astype(jnp.float32), nonfit.reshape(block, 1),
            preferred_element_type=jnp.float32,
        ).reshape(1, block)

    @pl.when((ph == 1) & (j == n_j - 1))
    def _commit():
        fits_i = fits_ref[0, pl.ds(i * block, block)].reshape(1, block)
        grant_out_ref[...] = \
            ((fits_i > 0) & (blk_acc_ref[...] == 0)).astype(jnp.float32)


def _wake_kernel(iscal_ref, fscal_ref, key_i_ref, key_j_ref,
                 sizes_i_ref, sizes_j_ref, wake_out_ref,
                 csum_ref, rank_ref, bytes_acc_ref, rank_acc_ref,
                 cnt_ref, nk_ref,
                 *, h_cap: int, block: int, n_j: int):
    ph = pl.program_id(0)
    i = pl.program_id(1)
    j = pl.program_id(2)
    pops = iscal_ref[0, 0]
    credit0 = fscal_ref[0, 0]
    inc = fscal_ref[0, 1]

    key_p = key_i_ref[:].reshape(block, 1)
    key_q = key_j_ref[:]
    wanted_p = key_i_ref[:] >= 0
    wanted_q = key_q >= 0
    gq = j * block + jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)
    gp = i * block + jax.lax.broadcasted_iota(jnp.int32, (block, block), 0)
    before = ((key_q > key_p) | ((key_q == key_p) & (gq < gp))) & wanted_q

    # ---- phase 0: service rank + prefix-inclusive queue bytes per page ---
    @pl.when((ph == 0) & (j == 0))
    def _init_prefix():
        bytes_acc_ref[...] = jnp.zeros_like(bytes_acc_ref)
        rank_acc_ref[...] = jnp.zeros_like(rank_acc_ref)

    @pl.when(ph == 0)
    def _acc_prefix():
        sz = (sizes_j_ref[:] * wanted_q).reshape(block, 1)
        bytes_acc_ref[...] = bytes_acc_ref[...] + jnp.dot(
            before.astype(jnp.float32), sz,
            preferred_element_type=jnp.float32,
        ).reshape(1, block)
        rank_acc_ref[...] = rank_acc_ref[...] + jnp.sum(
            before, axis=1, dtype=jnp.float32,
        ).reshape(1, block)

    @pl.when((ph == 0) & (j == n_j - 1))
    def _store_prefix():
        own = sizes_i_ref[:] * wanted_p
        csum_ref[0, pl.ds(i * block, block)] = \
            (bytes_acc_ref[...] + own).reshape(block)
        rank_ref[0, pl.ds(i * block, block)] = rank_acc_ref[...].reshape(block)

    # ---- phase 1: grants the banked credit alone allows after k steps ----
    @pl.when((ph == 1) & (i == 0) & (j == 0))
    def _init_cnt():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    @pl.when((ph == 1) & (i == 0))
    def _acc_cnt():
        cs = csum_ref[0, pl.ds(j * block, block)].reshape(1, block)
        ks = 1.0 + jax.lax.broadcasted_iota(jnp.float32, (h_cap, block), 0)
        ok = wanted_q & (cs <= credit0 + ks * inc)
        cnt_ref[...] = cnt_ref[...] + jnp.sum(
            ok, axis=1, dtype=jnp.float32,
        ).reshape(h_cap, 1)

    # ---- phase 2: pop-rate recursion, then per-page wake step ------------
    # n_k = min(cnt_k, n_{k-1} + pops) unrolled to
    # min(min_{1<=jj<=k}(cnt_jj + (k-jj)*pops), k*pops) — one (h_cap, h_cap)
    # min-plus tile instead of a sequential scan
    @pl.when((ph == 2) & (i == 0) & (j == 0))
    def _solve_ramp():
        popf = jnp.maximum(pops, 0).astype(jnp.float32)
        kk = 1.0 + jax.lax.broadcasted_iota(jnp.float32, (h_cap, h_cap), 0)
        jj = 1.0 + jax.lax.broadcasted_iota(jnp.float32, (h_cap, h_cap), 1)
        gap = kk - jj
        ramp = jnp.where(
            gap >= 0, cnt_ref[...].reshape(1, h_cap) + gap * popf, jnp.inf)
        ks = 1.0 + jax.lax.broadcasted_iota(jnp.float32, (h_cap, 1), 0)
        nk_ref[...] = jnp.minimum(
            jnp.min(ramp, axis=1).reshape(h_cap, 1), ks * popf)

    @pl.when((ph == 2) & (j == n_j - 1))
    def _commit():
        rk = rank_ref[0, pl.ds(i * block, block)].reshape(1, block)
        step = 1.0 + jnp.sum(
            nk_ref[...] < (rk + 1.0), axis=0, dtype=jnp.float32,
        ).reshape(1, block)
        wake_out_ref[...] = jnp.where(
            wanted_p, step, float(h_cap + 1)).astype(jnp.int32)


def fifo_grant_kernel(
    key: jax.Array,          # (P,) i32 queue priority (-1 = not wanted)
    sizes: jax.Array,        # (P,) f32
    budget: jax.Array,       # () f32
    pops: jax.Array,         # () i32
    *,
    vmax: int = 16,
    interpret: bool = False,
):
    """Budgeted FIFO grant selection (the array sim's I/O server pop) as
    a page-blocked MXU prefix computation (grid = (phase, i, j), phase 0
    feasibility / phase 1 head-of-line).  Returns ``(grant_mask,
    granted_bytes, n_granted)``; semantics defined by
    ``ref.fifo_grant_ref`` (tests assert exact agreement in interpret
    mode)."""
    P = key.shape[0]
    p_pad, n_b = _blocks(P)
    key_row = _pad_row(key.reshape(1, P).astype(jnp.int32), p_pad, -1)
    sz_row = _pad_row(sizes.reshape(1, P).astype(jnp.float32), p_pad, 0.0)
    iscal = jnp.asarray(pops, jnp.int32).reshape(1, 1)
    fscal = jnp.asarray(budget, jnp.float32).reshape(1, 1)
    smem = pl.BlockSpec(memory_space=pltpu.SMEM)
    row_i = pl.BlockSpec((1, _BLOCK), lambda p, i, j: (0, i),
                         memory_space=pltpu.VMEM)
    row_j = pl.BlockSpec((1, _BLOCK), lambda p, i, j: (0, j),
                         memory_space=pltpu.VMEM)
    grant = pl.pallas_call(
        functools.partial(_grant_kernel, vmax=min(vmax, P), block=_BLOCK,
                          n_j=n_b),
        grid=(2, n_b, n_b),
        out_shape=jax.ShapeDtypeStruct((1, p_pad), jnp.float32),
        in_specs=[smem, smem, row_i, row_j, row_i, row_j],
        out_specs=pl.BlockSpec((1, _BLOCK), lambda p, i, j: (0, i),
                               memory_space=pltpu.VMEM),
        scratch_shapes=[
            pltpu.VMEM((1, p_pad), jnp.float32),   # fits (global, phase 0->1)
            pltpu.VMEM((1, _BLOCK), jnp.float32),  # prefix-bytes accumulator
            pltpu.VMEM((1, _BLOCK), jnp.float32),  # rank accumulator
            pltpu.VMEM((1, _BLOCK), jnp.float32),  # blocked accumulator
        ],
        interpret=interpret,
    )(iscal, fscal, key_row, key_row, sz_row, sz_row)
    mask = grant[0, :P] > 0
    granted = jnp.where(mask, sizes, 0.0)
    return mask, jnp.sum(granted), jnp.sum(mask)


def batched_evict_kernel(
    key: jax.Array,          # (P,) f32 OR int policy score (higher = first)
    sizes: jax.Array,        # (P,) f32
    evictable: jax.Array,    # (P,) bool
    need_free: jax.Array,    # () f32
    *,
    vmax: int = 64,
    interpret: bool = False,
) -> jax.Array:
    """Batched evict selection over a policy score array, gridded over
    (row, col) page blocks.  Returns the ``(P,) bool`` evict mask.

    Integer score arrays (array-OPT's exact next-use distances) ride an
    i32 path end to end: an unconditional f32 cast would round away key
    bits beyond 2^24 (f32 carries a 24-bit mantissa), silently merging
    distinct priorities exactly like the FIFO-tie trap documented on
    ``fifo_grant_kernel`` — the kernel verifier's
    ``kernel-float-mantissa-cast`` rule pins this dispatch."""
    P = key.shape[0]
    p_pad, n_b = _blocks(P)
    int_key = bool(jnp.issubdtype(key.dtype, jnp.integer))
    if int_key:
        key_row = _pad_row(key.reshape(1, P).astype(jnp.int32), p_pad, NEG_I32)
    else:
        key_row = _pad_row(key.reshape(1, P).astype(jnp.float32), p_pad, NEG)
    sz_row = _pad_row(sizes.reshape(1, P).astype(jnp.float32), p_pad, 0.0)
    ev_row = _pad_row(
        evictable.reshape(1, P).astype(jnp.float32), p_pad, 0.0)
    fscal = jnp.asarray(need_free, jnp.float32).reshape(1, 1)
    smem = pl.BlockSpec(memory_space=pltpu.SMEM)
    row_i = pl.BlockSpec((1, _BLOCK), lambda i, j: (0, i),
                         memory_space=pltpu.VMEM)
    row_j = pl.BlockSpec((1, _BLOCK), lambda i, j: (0, j),
                         memory_space=pltpu.VMEM)
    evict = pl.pallas_call(
        functools.partial(_kernel, vmax=min(vmax, P), block=_BLOCK,
                          n_j=n_b, int_key=int_key),
        grid=(n_b, n_b),
        out_shape=jax.ShapeDtypeStruct((1, p_pad), jnp.float32),
        in_specs=[smem, row_i, row_j, row_j, row_i, row_j],
        out_specs=pl.BlockSpec((1, _BLOCK), lambda i, j: (0, i),
                               memory_space=pltpu.VMEM),
        scratch_shapes=[
            pltpu.VMEM((1, _BLOCK), jnp.float32),  # freed-before accumulator
            pltpu.VMEM((1, _BLOCK), jnp.float32),  # rank accumulator
        ],
        interpret=interpret,
    )(fscal, key_row, key_row, sz_row, ev_row, ev_row)
    return evict[0, :P] > 0


def wake_solve_kernel(
    key: jax.Array,          # (P,) i32 queue priority (-1 = not wanted)
    sizes: jax.Array,        # (P,) f32
    credit0: jax.Array,      # () f32 banked io-credit
    inc: jax.Array,          # () f32 credit bytes per fine step
    pops: jax.Array,         # () i32 max pops per fine step
    *,
    h_cap: int = 64,
    interpret: bool = False,
) -> jax.Array:
    """Per-page grant step of the frozen serial I/O server (the
    event-horizon stepper's wake-exact queue model), page-blocked.

    Grid = (phase, i, j): phase 0 writes every page's service rank and
    prefix-inclusive queue bytes into global scratch, phase 1 folds them
    into per-step feasible grant counts ``cnt_k``, phase 2 solves the
    pop-rate recursion ``n_k = min(cnt_k, n_{k-1} + pops)`` as one
    min-plus tile and emits each page's first ``k`` with
    ``n_k >= rank + 1``.  Returns ``(P,) i32`` in ``1..h_cap`` with
    sentinel ``h_cap + 1``; semantics defined by ``ref.wake_solve_ref``
    (tests assert exact agreement in interpret mode)."""
    P = key.shape[0]
    p_pad, n_b = _blocks(P)
    key_row = _pad_row(key.reshape(1, P).astype(jnp.int32), p_pad, -1)
    sz_row = _pad_row(sizes.reshape(1, P).astype(jnp.float32), p_pad, 0.0)
    iscal = jnp.asarray(pops, jnp.int32).reshape(1, 1)
    fscal = jnp.stack([
        jnp.asarray(credit0, jnp.float32), jnp.asarray(inc, jnp.float32),
    ]).reshape(1, 2)
    smem = pl.BlockSpec(memory_space=pltpu.SMEM)
    row_i = pl.BlockSpec((1, _BLOCK), lambda p, i, j: (0, i),
                         memory_space=pltpu.VMEM)
    row_j = pl.BlockSpec((1, _BLOCK), lambda p, i, j: (0, j),
                         memory_space=pltpu.VMEM)
    wake = pl.pallas_call(
        functools.partial(_wake_kernel, h_cap=h_cap, block=_BLOCK, n_j=n_b),
        grid=(3, n_b, n_b),
        out_shape=jax.ShapeDtypeStruct((1, p_pad), jnp.int32),
        in_specs=[smem, smem, row_i, row_j, row_i, row_j],
        out_specs=pl.BlockSpec((1, _BLOCK), lambda p, i, j: (0, i),
                               memory_space=pltpu.VMEM),
        scratch_shapes=[
            pltpu.VMEM((1, p_pad), jnp.float32),    # prefix bytes (global)
            pltpu.VMEM((1, p_pad), jnp.float32),    # service rank (global)
            pltpu.VMEM((1, _BLOCK), jnp.float32),   # prefix-bytes accumulator
            pltpu.VMEM((1, _BLOCK), jnp.float32),   # rank accumulator
            pltpu.VMEM((h_cap, 1), jnp.float32),    # cnt_k
            pltpu.VMEM((h_cap, 1), jnp.float32),    # n_k
        ],
        interpret=interpret,
    )(iscal, fscal, key_row, key_row, sz_row, sz_row)
    return wake[0, :P]
