"""Pallas kernel: PBM bucketed-timeline shift + spill + batched eviction.

The hot inner operation of the array-native buffer-manager simulation
(`repro.core.array_sim`): one call advances the paper's bucketed timeline
by ``k`` slices (``RefreshRequestedBuckets``, Fig. 9/10) and selects the
batch of eviction victims under the Belady rule (not-requested bucket
first, then furthest-future buckets) for a byte budget.

Design notes
------------
* All per-page state is dense ``(1, P)`` rows in VMEM (P is padded to a
  multiple of 128 by ``SimSpec``); scalars ride in SMEM.
* The shift is elementwise: bucket ``b`` (length ``2**(b//m)`` slices)
  moves left when the slice counter divides its length; pages shifted
  past position 0 spill and are re-bucketed at their freshly recomputed
  ``b_target`` — the self-correction step of the paper.
* Victim selection is a prefix-sum over the eviction priority order.
  Instead of sorting (awkward on the VPU), we compute for every page the
  bytes that would be freed *before* it via a masked (P, P) comparison
  matrix contracted against page sizes on the MXU — pages whose prefix
  stays below ``need_free`` are the victims.  O(P^2) but one MXU matmul.

Semantics are defined by ``repro.kernels.ref.pbm_timeline_step_ref``;
tests assert exact agreement in interpret mode.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30  # plain float: a jnp scalar would be a captured constant


def _kernel(iscal_ref, fscal_ref, bucket_ref, b_target_ref, last_used_ref,
            sizes_ref, evictable_ref, bucket_out_ref, evict_out_ref,
            *, nb: int, m: int, vmax: int):
    time_passed = iscal_ref[0, 0]
    k = iscal_ref[0, 1]
    policy = iscal_ref[0, 2]
    need_free = fscal_ref[0, 0]
    now = fscal_ref[0, 1]

    bucket = bucket_ref[:]            # (1, P) i32
    b_target = b_target_ref[:]
    P = bucket.shape[-1]

    # ---- timeline shift + spill (k slices) -------------------------------
    def shift_once(i, b):
        tp = time_passed + i + 1
        blen = jnp.left_shift(jnp.int32(1), jnp.clip(b, 0, nb - 1) // m)
        req = (b >= 0) & (b < nb)
        moved = req & ((tp % blen) == 0)
        b2 = jnp.where(moved, b - 1, b)
        return jnp.where(b2 < 0, b_target, b2)

    bucket2 = jax.lax.fori_loop(0, jnp.maximum(k, 0), shift_once, bucket)
    bucket_out_ref[:] = bucket2

    # ---- eviction key ----------------------------------------------------
    ev = evictable_ref[:]             # (1, P) f32 0/1
    age = jnp.maximum(now - last_used_ref[:], 0.0)
    # requested-bucket tie-break: per-(page, call) hash, not page index —
    # a fixed index order would keep the same elite resident every call
    # (see pbm_timeline_step_ref)
    idxi = jax.lax.broadcasted_iota(jnp.uint32, (1, P), 1)
    seed = jax.lax.bitcast_convert_type(now + 1.0, jnp.uint32)
    h32 = idxi * jnp.uint32(2654435761) + seed * jnp.uint32(40503)
    tie = (h32 >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(2.0**-24)
    tb = jnp.where(bucket2 == nb, age / (age + 1.0), tie)
    key_pbm = bucket2.astype(jnp.float32) + 0.5 * tb
    key = jnp.where(policy == 1, key_pbm, age)
    key = jnp.where(ev > 0, key, NEG)

    # ---- batched Belady-rule pop via prefix bytes on the MXU -------------
    key_p = key.reshape(P, 1)         # priority of the row page p
    key_q = key                       # (1, P): candidate predecessors q
    iq = jax.lax.broadcasted_iota(jnp.int32, (P, P), 1)
    ip = jax.lax.broadcasted_iota(jnp.int32, (P, P), 0)
    before = (key_q > key_p) | ((key_q == key_p) & (iq < ip))
    sz = (sizes_ref[:] * ev).reshape(P, 1)
    freed_before = jnp.dot(
        before.astype(jnp.float32), sz, preferred_element_type=jnp.float32
    )                                  # (P, 1) bytes freed before page p
    # candidate cap: page p participates only if fewer than vmax pages
    # precede it in priority order (== membership of the oracle's top_k)
    rank = jnp.sum(before, axis=1).reshape(1, P)
    take = (
        (ev > 0)
        & (freed_before.reshape(1, P) < need_free)
        & (rank < vmax)
        & (need_free > 0)
    )
    evict_out_ref[:] = take.astype(jnp.float32)


def pbm_timeline_step_kernel(
    bucket: jax.Array,      # (P,) i32
    b_target: jax.Array,    # (P,) i32
    last_used: jax.Array,   # (P,) f32
    sizes: jax.Array,       # (P,) f32
    evictable: jax.Array,   # (P,) bool
    time_passed: jax.Array,  # () i32
    k: jax.Array,            # () i32
    need_free: jax.Array,    # () f32
    policy: jax.Array,       # () i32
    now: jax.Array,          # () f32
    *,
    nb: int,
    m: int,
    vmax: int = 64,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Fused timeline shift + batched evict selection.  Returns
    ``(new_bucket (P,) i32, evict_mask (P,) bool)``."""
    P = bucket.shape[0]
    iscal = jnp.stack(
        [jnp.asarray(time_passed, jnp.int32), jnp.asarray(k, jnp.int32),
         jnp.asarray(policy, jnp.int32)]
    ).reshape(1, 3)
    fscal = jnp.stack(
        [jnp.asarray(need_free, jnp.float32), jnp.asarray(now, jnp.float32)]
    ).reshape(1, 2)
    smem = pl.BlockSpec(memory_space=pltpu.SMEM)
    vmem = pl.BlockSpec(memory_space=pltpu.VMEM)
    new_bucket, evict = pl.pallas_call(
        functools.partial(_kernel, nb=nb, m=m, vmax=min(vmax, P)),
        out_shape=(
            jax.ShapeDtypeStruct((1, P), jnp.int32),
            jax.ShapeDtypeStruct((1, P), jnp.float32),
        ),
        in_specs=[smem, smem, vmem, vmem, vmem, vmem, vmem],
        out_specs=(vmem, vmem),
        interpret=interpret,
    )(
        iscal,
        fscal,
        bucket.reshape(1, P).astype(jnp.int32),
        b_target.reshape(1, P).astype(jnp.int32),
        last_used.reshape(1, P).astype(jnp.float32),
        sizes.reshape(1, P).astype(jnp.float32),
        evictable.reshape(1, P).astype(jnp.float32),
    )
    return new_bucket.reshape(P), evict.reshape(P) > 0
