"""Pallas kernel: batched buffer-pool eviction for the array simulation.

The hot inner operation of the array-native buffer-manager simulation
(`repro.core.array_sim`): one call selects the batch of eviction victims
for a byte budget by popping a priority order.  The *policy* is entirely
encoded in the ``key`` input — the score array an
:class:`repro.core.array_sim.policies.ArrayPolicy` computed for this step
(PBM's shifted bucketed timeline, LRU's age, OPT's exact next-use
distance, CScan's keep-relevance) — so a single kernel serves every
registered policy and a vmapped sweep can mix policies per lane by
selecting between their score arrays.

Historical note: this kernel used to fuse the PBM timeline shift and
hardcode the LRU-vs-PBM key choice behind an integer policy id.  The
shift (``RefreshRequestedBuckets``, paper Fig. 9/10) is elementwise and
now lives with the PBM policy itself
(``array_sim.policies.shift_timeline``); the key dispatch moved to the
policy protocol.

Design notes
------------
* All per-page state is dense ``(1, P)`` rows in VMEM (P is padded to a
  multiple of 128 by ``SimSpec``); scalars ride in SMEM.
* Victim selection is a prefix-sum over the eviction priority order.
  Instead of sorting (awkward on the VPU), we compute for every page the
  bytes that would be freed *before* it via a masked (P, P) comparison
  matrix contracted against page sizes on the MXU — pages whose prefix
  stays below ``need_free`` are the victims.  O(P^2) but one MXU matmul.

Semantics are defined by ``repro.kernels.ref.batched_evict_ref``;
tests assert exact agreement in interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30  # plain float: a jnp scalar would be a captured constant
NEG_I32 = -(2**31) + 1  # i32 sentinel for the integer-key path


def _kernel(fscal_ref, key_ref, sizes_ref, evictable_ref, evict_out_ref,
            *, vmax: int, int_key: bool = False):
    need_free = fscal_ref[0, 0]

    ev = evictable_ref[:]             # (1, P) f32 0/1
    key = jnp.where(ev > 0, key_ref[:], NEG_I32 if int_key else NEG)
    P = key.shape[-1]

    # ---- batched priority pop via prefix bytes on the MXU ----------------
    key_p = key.reshape(P, 1)         # priority of the row page p
    key_q = key                       # (1, P): candidate predecessors q
    iq = jax.lax.broadcasted_iota(jnp.int32, (P, P), 1)
    ip = jax.lax.broadcasted_iota(jnp.int32, (P, P), 0)
    before = (key_q > key_p) | ((key_q == key_p) & (iq < ip))
    sz = (sizes_ref[:] * ev).reshape(P, 1)
    freed_before = jnp.dot(
        before.astype(jnp.float32), sz, preferred_element_type=jnp.float32
    )                                  # (P, 1) bytes freed before page p
    # candidate cap: page p participates only if fewer than vmax pages
    # precede it in priority order (== membership of the oracle's top_k)
    rank = jnp.sum(before, axis=1).reshape(1, P)
    take = (
        (ev > 0)
        & (freed_before.reshape(1, P) < need_free)
        & (rank < vmax)
        & (need_free > 0)
    )
    evict_out_ref[:] = take.astype(jnp.float32)


def _grant_kernel(iscal_ref, fscal_ref, key_ref, sizes_ref, grant_out_ref,
                  *, vmax: int):
    pops = iscal_ref[0, 0]
    budget = fscal_ref[0, 0]

    key = key_ref[:]                  # (1, P) i32 — the FIFO keys use up
    wanted = key >= 0                 # to ~30 bits (stamp*32768 + tie), so
                                      # an f32 cast would round away the
                                      # tie bits beyond 2^24
    P = key.shape[-1]

    # ---- budgeted FIFO pop via prefix bytes on the MXU -------------------
    # service order: descending key, ties by ascending index — the same
    # prefix trick as the eviction kernel, but with STRICT head-of-line
    # admission: a predecessor that does not fit (or falls beyond the
    # pops cap) blocks every later pop, like the engine's serial server.
    key_p = key.reshape(P, 1)
    key_q = key                       # (1, P)
    iq = jax.lax.broadcasted_iota(jnp.int32, (P, P), 1)
    ip = jax.lax.broadcasted_iota(jnp.int32, (P, P), 0)
    before = ((key_q > key_p) | ((key_q == key_p) & (iq < ip))) & (key_q >= 0)
    sz = (sizes_ref[:] * wanted).reshape(P, 1)
    bytes_before = jnp.dot(
        before.astype(jnp.float32), sz, preferred_element_type=jnp.float32
    ).reshape(1, P)
    rank = jnp.sum(before, axis=1).reshape(1, P)
    fits = (
        wanted
        & (bytes_before + sizes_ref[:] <= budget)
        & (rank < jnp.minimum(pops, vmax))
    )
    # strict prefix: drop any page with a non-fitting wanted predecessor
    blocked = jnp.dot(
        before.astype(jnp.float32),
        (wanted & ~fits).astype(jnp.float32).reshape(P, 1),
        preferred_element_type=jnp.float32,
    ).reshape(1, P)
    grant_out_ref[:] = (fits & (blocked == 0)).astype(jnp.float32)


def fifo_grant_kernel(
    key: jax.Array,          # (P,) i32 queue priority (-1 = not wanted)
    sizes: jax.Array,        # (P,) f32
    budget: jax.Array,       # () f32
    pops: jax.Array,         # () i32
    *,
    vmax: int = 16,
    interpret: bool = False,
):
    """Budgeted FIFO grant selection (the array sim's I/O server pop) as
    one MXU prefix computation.  Returns ``(grant_mask, granted_bytes,
    n_granted)``; semantics defined by ``ref.fifo_grant_ref`` (tests
    assert exact agreement in interpret mode)."""
    P = key.shape[0]
    iscal = jnp.asarray(pops, jnp.int32).reshape(1, 1)
    fscal = jnp.asarray(budget, jnp.float32).reshape(1, 1)
    smem = pl.BlockSpec(memory_space=pltpu.SMEM)
    vmem = pl.BlockSpec(memory_space=pltpu.VMEM)
    grant = pl.pallas_call(
        functools.partial(_grant_kernel, vmax=min(vmax, P)),
        out_shape=jax.ShapeDtypeStruct((1, P), jnp.float32),
        in_specs=[smem, smem, vmem, vmem],
        out_specs=vmem,
        interpret=interpret,
    )(
        iscal,
        fscal,
        key.reshape(1, P).astype(jnp.int32),
        sizes.reshape(1, P).astype(jnp.float32),
    )
    mask = grant.reshape(P) > 0
    granted = jnp.where(mask, sizes, 0.0)
    return mask, jnp.sum(granted), jnp.sum(mask)


def batched_evict_kernel(
    key: jax.Array,          # (P,) f32 OR int policy score (higher = first)
    sizes: jax.Array,        # (P,) f32
    evictable: jax.Array,    # (P,) bool
    need_free: jax.Array,    # () f32
    *,
    vmax: int = 64,
    interpret: bool = False,
) -> jax.Array:
    """Batched evict selection over a policy score array.  Returns the
    ``(P,) bool`` evict mask.

    Integer score arrays (array-OPT's exact next-use distances) ride an
    i32 path end to end: an unconditional f32 cast would round away key
    bits beyond 2^24 (f32 carries a 24-bit mantissa), silently merging
    distinct priorities exactly like the FIFO-tie trap documented on
    ``fifo_grant_kernel`` — the kernel verifier's
    ``kernel-float-mantissa-cast`` rule pins this dispatch."""
    P = key.shape[0]
    int_key = bool(jnp.issubdtype(key.dtype, jnp.integer))
    key_row = (key.reshape(1, P).astype(jnp.int32) if int_key
               else key.reshape(1, P).astype(jnp.float32))
    fscal = jnp.asarray(need_free, jnp.float32).reshape(1, 1)
    smem = pl.BlockSpec(memory_space=pltpu.SMEM)
    vmem = pl.BlockSpec(memory_space=pltpu.VMEM)
    evict = pl.pallas_call(
        functools.partial(_kernel, vmax=min(vmax, P), int_key=int_key),
        out_shape=jax.ShapeDtypeStruct((1, P), jnp.float32),
        in_specs=[smem, vmem, vmem, vmem],
        out_specs=vmem,
        interpret=interpret,
    )(
        fscal,
        key_row,
        sizes.reshape(1, P).astype(jnp.float32),
        evictable.reshape(1, P).astype(jnp.float32),
    )
    return evict.reshape(P) > 0
