"""Chunked SSD (Mamba2) scan kernel — TPU-native selective scan.

The CUDA selective-scan is a warp-level sequential scan; the TPU-idiomatic
formulation makes the intra-chunk work dense matmuls (MXU) and carries the
(P x N) SSM state across chunks in VMEM scratch:

  grid = (batch, head, chunk)   — chunk innermost, so the state scratch
                                   persists across a (batch, head)'s chunks
  blocks: xh (Q, P), a (Q,), b/c (Q, N); Q = chunk length (sublane-aligned),
  P = head dim, N = state dim (64/128 — lane-aligned enough; P=64 pads to
  the 128 lane but the (Q,Q) and (Q,N) matmuls dominate).

Per chunk:  L = exp(segsum(log a))  (Q,Q, causal-masked)
            y_intra = (C B^T . L) X
            y_inter = C h_prev^T . exp(cumlog a)
            h_new   = h_prev * exp(total) + (B * decay_to_end)^T X
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _kernel(
    xh_ref,   # (1, chunk, 1, P)
    a_ref,    # (1, chunk, 1)
    b_ref,    # (1, chunk, N)
    c_ref,    # (1, chunk, N)
    y_ref,    # (1, chunk, 1, P)
    h_ref,    # (P, N) f32 scratch — carried SSM state
    *,
    chunk: int,
    n_chunks: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = xh_ref[0, :, 0, :].astype(jnp.float32)       # (Q, P)
    a = a_ref[0, :, 0].astype(jnp.float32)           # (Q,)
    b = b_ref[0].astype(jnp.float32)                 # (Q, N)
    c = c_ref[0].astype(jnp.float32)                 # (Q, N)

    loga = jnp.log(jnp.clip(a, 1e-20, None))
    cum = jnp.cumsum(loga)                           # (Q,)
    total = cum[-1]
    # intra-chunk decay matrix L[q, s] = exp(cum_q - cum_s), q >= s
    li = cum[:, None] - cum[None, :]
    mask = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(mask, jnp.exp(li), 0.0)
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    y_intra = jax.lax.dot_general(cb * L, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the carried state
    dstart = jnp.exp(cum)                            # (Q,)
    ch = jax.lax.dot_general(c, h_ref[...], (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, P)
    y_inter = ch * dstart[:, None]
    y_ref[0, :, 0, :] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: h = h * exp(total) + sum_s decay_to_end_s * x_s B_s^T
    dte = jnp.exp(total - cum)                       # (Q,)
    xw = x * dte[:, None]                            # (Q, P)
    hb = jax.lax.dot_general(xw, b, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (P, N)
    h_ref[...] = h_ref[...] * jnp.exp(total) + hb


def mamba2_scan_kernel(
    xh: jax.Array,   # (B, T, H, P)
    a: jax.Array,    # (B, T, H)
    b: jax.Array,    # (B, T, N)  (shared across heads, ngroups=1)
    c: jax.Array,    # (B, T, N)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, T, H, P = xh.shape
    N = b.shape[-1]
    nc = (T + chunk - 1) // chunk
    Tp = nc * chunk
    if Tp != T:
        xh = jnp.pad(xh, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, Tp - T), (0, 0)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, Tp - T), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, Tp - T), (0, 0)))

    grid = (B, H, nc)
    out = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, n_chunks=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda bi, hi, ci: (bi, ci, hi, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, chunk, N), lambda bi, hi, ci: (bi, ci, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, chunk, N), lambda bi, hi, ci: (bi, ci, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec(
            (1, chunk, 1, P), lambda bi, hi, ci: (bi, ci, hi, 0),
            memory_space=pltpu.VMEM,
        ),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((B, Tp, H, P), xh.dtype),
        interpret=interpret,
    )(xh, a, b, c)
    return out[:, :T]
