"""Flash attention (prefill/training) — causal + sliding-window, TPU tiling.

Grid = (batch*heads, q_blocks, kv_blocks), kv innermost so the online-softmax
state (m, l, acc) for one q-block lives in VMEM scratch across kv steps.
Blocks are (block_q, dh) x (block_kv, dh) with dh lane-aligned (128/256) and
block_q/block_kv multiples of the 8-sublane tile; the (block_q, block_kv)
score tile feeds the MXU.  Causality is enforced by masking; fully-masked
kv blocks are skipped with ``pl.when`` (no FLOPs burned above the diagonal).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _kernel(
    q_ref,    # (1, block_q, dh)
    k_ref,    # (1, block_kv, dh)
    v_ref,    # (1, block_kv, dh)
    o_ref,    # (1, block_q, dh)
    m_ref,    # (block_q, 1)
    l_ref,    # (block_q, 1)
    acc_ref,  # (block_q, dh)
    *,
    block_q: int,
    block_kv: int,
    n_kv: int,
    causal: bool,
    window: Optional[int],
    seq_q: int,
    seq_kv: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_kv
    # block-level skip: in causal mode a kv block strictly above the diagonal
    # contributes nothing; with a window, blocks entirely behind it neither.
    needed = True
    if causal:
        needed = k_start <= q_start + block_q - 1
    if window is not None:
        needed = jnp.logical_and(
            needed, k_start + block_kv - 1 >= q_start - (window - 1)
        ) if causal else needed

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        dh = q.shape[-1]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * (dh ** -0.5)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        rel = qpos - kpos
        mask = jnp.logical_and(qpos < seq_q, kpos < seq_kv)
        if causal:
            mask = jnp.logical_and(mask, rel >= 0)
        if window is not None:
            mask = jnp.logical_and(mask, rel < window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...][:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_ref[...] = (l_ref[...][:, 0] * alpha + p.sum(axis=-1))[:, None]
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new[:, None]

    @pl.when(ki == n_kv - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...][:, 0], 1e-30)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_kernel(
    q: jax.Array,   # (B, T, H, dh)
    k: jax.Array,   # (B, S, H, dh)  (KV heads pre-expanded to H)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, t, h, dh = q.shape
    s = k.shape[1]
    nq = (t + block_q - 1) // block_q
    nk = (s + block_kv - 1) // block_kv
    tp, sp = nq * block_q, nk * block_kv
    qp = jnp.pad(q, ((0, 0), (0, tp - t), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    q_r = qp.transpose(0, 2, 1, 3).reshape(b * h, tp, dh)
    k_r = kp.transpose(0, 2, 1, 3).reshape(b * h, sp, dh)
    v_r = vp.transpose(0, 2, 1, 3).reshape(b * h, sp, dh)

    grid = (b * h, nq, nk)
    out = pl.pallas_call(
        functools.partial(
            _kernel, block_q=block_q, block_kv=block_kv, n_kv=nk,
            causal=causal, window=window, seq_q=t, seq_kv=s,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda bh, qi, ki: (bh, qi, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, block_kv, dh), lambda bh, qi, ki: (bh, ki, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, block_kv, dh), lambda bh, qi, ki: (bh, ki, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda bh, qi, ki: (bh, qi, 0),
                               memory_space=pltpu.VMEM),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((b * h, tp, dh), q.dtype),
        interpret=interpret,
    )(q_r, k_r, v_r)
    return out.reshape(b, h, tp, dh).transpose(0, 2, 1, 3)[:, :t]
