"""Paged decode attention — the kernel-level realisation of PBM-managed KV.

One decode step reads K/V through a **page table**: the KV cache lives in a
pool of fixed-size pages (non-contiguous in HBM), exactly the structure the
serving tier's PBM policy manages (``repro.serving``).  TPU-native design:

* ``PrefetchScalarGridSpec`` prefetches the page table; the K/V BlockSpec
  ``index_map`` reads it, so the DMA engine gathers pages HBM->VMEM *by
  table lookup* — no materialised gather, no contiguity requirement.  This
  replaces the CUDA approach (warp-per-page gather) with Mosaic's
  grid-indexed DMA, per the hardware-adaptation note in DESIGN.md.
* Grid = (batch, kv_head, page); the page axis is innermost, so the online-
  softmax accumulator lives in VMEM scratch across page steps of one
  (batch, head) and is written once at the last page.
* Blocks: q (G, dh) with G = query heads per KV head (GQA group), K/V page
  (page_size, dh).  dh is 128/256 (lane-aligned); page_size a multiple of 8
  (sublane-aligned); the (G, page_size) score tile hits the MXU.

Numerics: f32 accumulation, online softmax with running max — validated
against ``ref.paged_attention_ref`` in interpret mode (tests sweep shapes,
dtypes, GQA ratios, ragged lengths).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _kernel(
    # scalar prefetch
    page_table_ref,   # (B, pages_per_seq) int32
    seq_lens_ref,     # (B,) int32
    # blocks
    q_ref,            # (1, 1, G, dh)
    k_ref,            # (1, page_size, dh)
    v_ref,            # (1, page_size, dh)
    o_ref,            # (1, 1, G, dh)
    # scratch
    m_ref,            # (G, 1) f32 running max
    l_ref,            # (G, 1) f32 running denom
    acc_ref,          # (G, dh) f32 numerator
    *,
    page_size: int,
    pages_per_seq: int,
):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (G, dh)
    k = k_ref[0].astype(jnp.float32)               # (S, dh)
    v = v_ref[0].astype(jnp.float32)               # (S, dh)
    dh = q.shape[-1]
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * (dh ** -0.5)                                # (G, S)

    pos = p * page_size + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    valid = pos < seq_lens_ref[b]
    scores = jnp.where(valid, scores, NEG_INF)

    m_prev = m_ref[...][:, 0]                       # (G,)
    m_new = jnp.maximum(m_prev, scores.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)                 # (G,)
    probs = jnp.exp(scores - m_new[:, None])        # (G, S)
    probs = jnp.where(valid, probs, 0.0)
    l_ref[...] = (l_ref[...][:, 0] * alpha + probs.sum(axis=-1))[:, None]
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        probs, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new[:, None]

    @pl.when(p == pages_per_seq - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...][:, 0], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def paged_attention_kernel(
    q: jax.Array,            # (B, H, dh)
    k_pages: jax.Array,      # (n_pages, page_size, Hk, dh)
    v_pages: jax.Array,      # (n_pages, page_size, Hk, dh)
    page_table: jax.Array,   # (B, pages_per_seq) int32 — pool page ids
    seq_lens: jax.Array,     # (B,) int32
    *,
    interpret: bool = False,
) -> jax.Array:
    b, h, dh = q.shape
    n_pages, page_size, hk, _ = k_pages.shape
    assert h % hk == 0, (h, hk)
    g = h // hk
    pages_per_seq = page_table.shape[1]

    # (B, Hk, G, dh) view of queries: one grid row per KV head
    q_r = q.reshape(b, hk, g, dh)
    # move the kv-head axis outward so K/V blocks are (1, page_size, dh)
    k_r = k_pages.transpose(2, 0, 1, 3).reshape(hk * n_pages, page_size, dh)
    v_r = v_pages.transpose(2, 0, 1, 3).reshape(hk * n_pages, page_size, dh)

    grid = (b, hk, pages_per_seq)

    def q_map(bi, hi, pi, pt, sl):
        return (bi, hi, 0, 0)

    def kv_map(bi, hi, pi, pt, sl):
        return (hi * n_pages + pt[bi, pi], 0, 0)

    gs = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, dh), q_map, memory_space=pltpu.VMEM),
            pl.BlockSpec((1, page_size, dh), kv_map,
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, page_size, dh), kv_map,
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh), q_map,
                               memory_space=pltpu.VMEM),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(
            _kernel, page_size=page_size, pages_per_seq=pages_per_seq
        ),
        grid_spec=gs,
        out_shape=jax.ShapeDtypeStruct((b, hk, g, dh), q.dtype),
        interpret=interpret,
    )(page_table, seq_lens, q_r, k_r, v_r)
    return out.reshape(b, h, dh)
