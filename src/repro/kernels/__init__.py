"""Pallas TPU kernels for the perf-critical compute layers, with jnp
oracles (ref.py) and backend dispatch (ops.py).

  paged_attention — decode attention through a page table (PBM-managed KV)
  flash_attention — prefill/training attention (causal + sliding window)
  mamba2_scan     — chunked SSD selective scan (zamba2)
  mlstm_chunked   — chunkwise mLSTM matrix memory (xlstm)
"""

from . import ops, ref
from .ops import (
    flash_attention, get_backend, mamba2_scan, mlstm_chunked,
    paged_attention, set_backend,
)

__all__ = [
    "flash_attention", "get_backend", "mamba2_scan", "mlstm_chunked", "ops",
    "paged_attention", "ref", "set_backend",
]
