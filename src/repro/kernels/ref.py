"""Pure-jnp oracles for every kernel.

Deliberately *naive* implementations (full softmax, sequential recurrences)
— obviously correct, used by tests to validate both the Pallas kernels
(interpret mode) and the fast chunked jnp paths in ``repro.models``.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_attention_ref(
    q: jax.Array,            # (B, H, dh)
    k_pages: jax.Array,      # (n_pages, page_size, Hk, dh)
    v_pages: jax.Array,      # (n_pages, page_size, Hk, dh)
    page_table: jax.Array,   # (B, pages_per_seq)
    seq_lens: jax.Array,     # (B,)
) -> jax.Array:
    b, h, dh = q.shape
    n_pages, page_size, hk, _ = k_pages.shape
    g = h // hk
    pages = page_table.shape[1]
    # gather the full (ragged) K/V per sequence, then plain masked softmax
    k_seq = k_pages[page_table]                     # (B, pages, S, Hk, dh)
    v_seq = v_pages[page_table]
    k_seq = k_seq.reshape(b, pages * page_size, hk, dh)
    v_seq = v_seq.reshape(b, pages * page_size, hk, dh)
    qf = q.reshape(b, hk, g, dh).astype(jnp.float32)
    kf = k_seq.astype(jnp.float32)
    vf = v_seq.astype(jnp.float32)
    scores = jnp.einsum("bkgd,bskd->bkgs", qf, kf) * (dh ** -0.5)
    valid = jnp.arange(pages * page_size)[None, :] < seq_lens[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, vf)
    return out.reshape(b, h, dh).astype(q.dtype)


def flash_attention_ref(
    q: jax.Array,            # (B, T, H, dh)
    k: jax.Array,            # (B, S, H, dh)
    v: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
) -> jax.Array:
    b, t, h, dh = q.shape
    s = k.shape[1]
    scores = jnp.einsum(
        "bthd,bshd->bhts", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * (dh ** -0.5)
    rel = jnp.arange(t)[:, None] - jnp.arange(s)[None, :] + (s - t)
    mask = jnp.ones((t, s), bool)
    if causal:
        mask &= rel >= 0
    if window is not None:
        mask &= rel < window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhts,bshd->bthd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def mamba2_scan_ref(
    xh: jax.Array,   # (B, T, H, P)
    a: jax.Array,    # (B, T, H) decay in (0,1]
    b: jax.Array,    # (B, T, N)
    c: jax.Array,    # (B, T, N)
    h0: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Sequential SSM recurrence: h_t = a_t h_{t-1} + B_t x_t^T; y_t = C_t.h_t."""
    B, T, H, P = xh.shape
    N = b.shape[-1]
    f32 = jnp.float32
    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), f32)

    def step(h, inp):
        x_t, a_t, b_t, c_t = inp
        h = h * a_t[:, :, None, None] + jnp.einsum(
            "bn,bhp->bhpn", b_t.astype(f32), x_t.astype(f32)
        )
        y = jnp.einsum("bn,bhpn->bhp", c_t.astype(f32), h)
        return h, y

    xs = (
        jnp.moveaxis(xh, 1, 0),
        jnp.moveaxis(a, 1, 0),
        jnp.moveaxis(b, 1, 0),
        jnp.moveaxis(c, 1, 0),
    )
    h_f, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(xh.dtype), h_f


def pbm_timeline_step_ref(
    bucket: jax.Array,      # (P,) i32 current bucket (nb == not-requested)
    b_target: jax.Array,    # (P,) i32 recomputed bucket if (re)pushed now
    last_used: jax.Array,   # (P,) f32 last consumption time (LRU clock)
    sizes: jax.Array,       # (P,) f32 page bytes
    evictable: jax.Array,   # (P,) bool resident & unpinned & valid
    time_passed: jax.Array, # () i32 timeline slices elapsed so far
    k: jax.Array,           # () i32 slices to shift this call
    need_free: jax.Array,   # () f32 bytes that must be freed
    policy: jax.Array,      # () i32 0 = LRU, 1 = PBM
    now: jax.Array,         # () f32 sim time (for LRU age)
    *,
    nb: int,
    m: int,
    vmax: int = 64,
) -> Tuple[jax.Array, jax.Array]:
    """Oracle for the PBM timeline kernel: shift + spill + batched evict.

    Semantics mirror ``PBMPolicy.refresh_requested_buckets`` +
    ``choose_victims``: per elapsed slice, bucket ``b`` moves left when the
    slice count divides its length ``2**(b//m)``; a page shifted past
    position 0 is *spilled* and re-bucketed at ``b_target`` (its freshly
    recomputed priority).  Eviction then pops the not-requested bucket
    first (LRU order), then the furthest-future buckets, until
    ``need_free`` bytes are covered — Belady's rule under estimation —
    considering at most the ``vmax`` highest-priority candidates per call
    (a full argsort per step would dominate the simulation).
    Returns ``(new_bucket, evict_mask)``.
    """
    P = bucket.shape[0]

    def shift_once(i, b):
        tp = time_passed + i + 1
        blen = jnp.left_shift(jnp.int32(1), jnp.clip(b, 0, nb - 1) // m)
        req = (b >= 0) & (b < nb)
        moved = req & ((tp % blen) == 0)
        b2 = jnp.where(moved, b - 1, b)
        return jnp.where(b2 < 0, b_target, b2)

    bucket2 = jax.lax.fori_loop(0, jnp.maximum(k, 0), shift_once, bucket)

    age = jnp.maximum(now - last_used, 0.0)
    # composite PBM key: bucket level dominates; not-requested (== nb) is
    # the top level with LRU order inside; requested buckets break ties by
    # a per-(page, call) hash (the dict impl's insertion order is equally
    # arbitrary, but a FIXED index order would carve a stable always-kept
    # elite out of every bucket — systematic retention the dict engine's
    # churning insertion order never develops).
    idxi = jnp.arange(P, dtype=jnp.uint32)
    seed = jax.lax.bitcast_convert_type(
        jnp.float32(now) + 1.0, jnp.uint32
    ).astype(jnp.uint32)
    h32 = idxi * jnp.uint32(2654435761) + seed * jnp.uint32(40503)
    tie = (h32 >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(2.0**-24)
    tb = jnp.where(bucket2 == nb, age / (age + 1.0), tie)
    key_pbm = bucket2.astype(jnp.float32) + 0.5 * tb
    key = jnp.where(policy == 1, key_pbm, age)
    key = jnp.where(evictable, key, -jnp.inf)
    _, cand = jax.lax.top_k(key, min(vmax, P))  # ties -> ascending index
    c_ok = evictable[cand]
    sz_c = jnp.where(c_ok, sizes[cand], 0.0)
    csum = jnp.cumsum(sz_c)
    take = c_ok & (csum - sz_c < need_free) & (need_free > 0)
    evict = jnp.zeros((P,), bool).at[cand].set(take)
    return bucket2, evict


def gla_ref(
    q: jax.Array,    # (B, T, H, K)
    k: jax.Array,
    v: jax.Array,    # (B, T, H, P)
    a: jax.Array,    # (B, T, H)
    i: jax.Array,    # (B, T, H)
) -> jax.Array:
    """Sequential mLSTM recurrence (matrix memory + normalizer)."""
    B, T, H, K = q.shape
    P = v.shape[-1]
    f32 = jnp.float32
    C0 = jnp.zeros((B, H, K, P), f32)
    n0 = jnp.zeros((B, H, K), f32)
    scale = K ** -0.5

    def step(carry, inp):
        C, n = carry
        q_t, k_t, v_t, a_t, i_t = inp
        C = C * a_t[:, :, None, None] + i_t[:, :, None, None] * jnp.einsum(
            "bhk,bhp->bhkp", k_t.astype(f32), v_t.astype(f32)
        )
        n = n * a_t[:, :, None] + i_t[:, :, None] * k_t.astype(f32)
        qs = q_t.astype(f32) * scale
        num = jnp.einsum("bhk,bhkp->bhp", qs, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qs, n)), 1.0)
        return (C, n), num / den[..., None]

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (q, k, v, a, i))
    _, ys = jax.lax.scan(step, (C0, n0), xs)
    return jnp.moveaxis(ys, 0, 1).astype(v.dtype)
