"""Pure-jnp oracles for every kernel.

Deliberately *naive* implementations (full softmax, sequential recurrences)
— obviously correct, used by tests to validate both the Pallas kernels
(interpret mode) and the fast chunked jnp paths in ``repro.models``.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_attention_ref(
    q: jax.Array,            # (B, H, dh)
    k_pages: jax.Array,      # (n_pages, page_size, Hk, dh)
    v_pages: jax.Array,      # (n_pages, page_size, Hk, dh)
    page_table: jax.Array,   # (B, pages_per_seq)
    seq_lens: jax.Array,     # (B,)
) -> jax.Array:
    b, h, dh = q.shape
    n_pages, page_size, hk, _ = k_pages.shape
    g = h // hk
    pages = page_table.shape[1]
    # gather the full (ragged) K/V per sequence, then plain masked softmax
    k_seq = k_pages[page_table]                     # (B, pages, S, Hk, dh)
    v_seq = v_pages[page_table]
    k_seq = k_seq.reshape(b, pages * page_size, hk, dh)
    v_seq = v_seq.reshape(b, pages * page_size, hk, dh)
    qf = q.reshape(b, hk, g, dh).astype(jnp.float32)
    kf = k_seq.astype(jnp.float32)
    vf = v_seq.astype(jnp.float32)
    scores = jnp.einsum("bkgd,bskd->bkgs", qf, kf) * (dh ** -0.5)
    valid = jnp.arange(pages * page_size)[None, :] < seq_lens[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, vf)
    return out.reshape(b, h, dh).astype(q.dtype)


def flash_attention_ref(
    q: jax.Array,            # (B, T, H, dh)
    k: jax.Array,            # (B, S, H, dh)
    v: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
) -> jax.Array:
    b, t, h, dh = q.shape
    s = k.shape[1]
    scores = jnp.einsum(
        "bthd,bshd->bhts", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * (dh ** -0.5)
    rel = jnp.arange(t)[:, None] - jnp.arange(s)[None, :] + (s - t)
    mask = jnp.ones((t, s), bool)
    if causal:
        mask &= rel >= 0
    if window is not None:
        mask &= rel < window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhts,bshd->bthd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def mamba2_scan_ref(
    xh: jax.Array,   # (B, T, H, P)
    a: jax.Array,    # (B, T, H) decay in (0,1]
    b: jax.Array,    # (B, T, N)
    c: jax.Array,    # (B, T, N)
    h0: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Sequential SSM recurrence: h_t = a_t h_{t-1} + B_t x_t^T; y_t = C_t.h_t."""
    B, T, H, P = xh.shape
    N = b.shape[-1]
    f32 = jnp.float32
    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), f32)

    def step(h, inp):
        x_t, a_t, b_t, c_t = inp
        h = h * a_t[:, :, None, None] + jnp.einsum(
            "bn,bhp->bhpn", b_t.astype(f32), x_t.astype(f32)
        )
        y = jnp.einsum("bn,bhpn->bhp", c_t.astype(f32), h)
        return h, y

    xs = (
        jnp.moveaxis(xh, 1, 0),
        jnp.moveaxis(a, 1, 0),
        jnp.moveaxis(b, 1, 0),
        jnp.moveaxis(c, 1, 0),
    )
    h_f, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(xh.dtype), h_f


def batched_evict_ref(
    key: jax.Array,         # (P,) f32 OR int priority (higher = evict first)
    sizes: jax.Array,       # (P,) f32 page bytes
    evictable: jax.Array,   # (P,) bool resident & unpinned & valid
    need_free: jax.Array,   # () f32 bytes that must be freed
    *,
    vmax: int = 64,
) -> jax.Array:
    """Oracle for the batched eviction kernel: pop the priority order.

    The eviction *policy* lives entirely in ``key`` — an
    ``ArrayPolicy.score_victims`` array (PBM's shifted-timeline composite,
    LRU's age, OPT's exact next-use distance, CScan's keep-relevance…) —
    so one op serves every registered policy.  Victims are taken in
    descending key order until ``need_free`` bytes are covered,
    considering at most the ``vmax`` highest-priority candidates per call
    (a full argsort per step would dominate the simulation).  Key ties
    resolve by ascending page index.  Returns the evict mask.

    Integer keys stay integer through the pop (an ``-inf`` sentinel
    would promote them to float and round away bits beyond the mantissa
    — the 2^24 trap the kernel verifier pins); the masked sentinel is
    the dtype's own minimum instead.
    """
    P = key.shape[0]
    if jnp.issubdtype(key.dtype, jnp.integer):
        key = jnp.where(evictable, key, jnp.iinfo(key.dtype).min)
    else:
        key = jnp.where(evictable, key, -jnp.inf)
    _, cand = jax.lax.top_k(key, min(vmax, P))  # ties -> ascending index
    c_ok = evictable[cand]
    sz_c = jnp.where(c_ok, sizes[cand], 0.0)
    csum = jnp.cumsum(sz_c)
    take = c_ok & (csum - sz_c < need_free) & (need_free > 0)
    return jnp.zeros((P,), bool).at[cand].set(take)


def fifo_grant_ref(
    key: jax.Array,        # (P,) i32 queue priority (-1 = not wanted)
    sizes: jax.Array,      # (P,) f32 page bytes
    budget: jax.Array,     # () f32 byte budget of this grant
    pops: jax.Array,       # () i32 max queue pops (serial-server cap)
    *,
    vmax: int = 16,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Oracle for the budgeted FIFO-grant kernel (array-sim I/O server).

    Pops the request queue in descending ``key`` order (ties by ascending
    page index — the stamp-FIFO service order the array sim encodes into
    ``key``) with STRICT head-of-line semantics: the first page that does
    not fit in ``budget``, is beyond the ``pops`` cap, or is not wanted
    (``key < 0``) blocks everything behind it — exactly the event
    engine's serial server.  At most the ``vmax`` highest-priority
    entries are considered per call (a macro-step stands in for a few
    fine steps, never a full queue drain).

    Returns ``(grant_mask, granted_bytes, n_granted)``.
    """
    P = key.shape[0]
    kv, cand = jax.lax.top_k(key, min(vmax, P))  # ties -> ascending index
    sz = sizes[cand]
    csum = jnp.cumsum(sz)
    n = kv.shape[0]
    ok = jnp.cumprod(
        ((kv >= 0) & (csum <= budget)
         & (jnp.arange(n) < pops)).astype(jnp.int32)
    ).astype(bool)
    mask = jnp.zeros((P,), bool).at[cand].set(ok)
    return mask, jnp.sum(jnp.where(ok, sz, 0.0)), jnp.sum(ok)


def wake_solve_ref(
    key: jax.Array,      # (P,) i32 queue priority (-1 = not wanted)
    sizes: jax.Array,    # (P,) f32 page bytes
    credit0: jax.Array,  # () f32 io-credit already banked
    inc: jax.Array,      # () f32 credit bytes gained per fine step
    pops: jax.Array,     # () i32 max queue pops per fine step
    *,
    h_cap: int = 64,
) -> jax.Array:
    """Oracle for the wake-solve kernel (serial-server grant schedule).

    With the request queue frozen at the end of a macro step, the serial
    I/O server's future is deterministic: each fine step banks ``inc``
    more credit bytes and pops at most ``pops`` queue heads whose
    cumulative bytes fit the banked credit.  The grant count after ``k``
    fine steps follows the recursion

        n_k = min(bytes_ok(credit0 + k*inc), n_{k-1} + pops),   n_0 = 0

    where ``bytes_ok(c)`` counts queue entries whose prefix-inclusive
    byte sum — in service order: descending ``key``, ties by ascending
    page index — fits ``c``.  (The naive closed form
    ``max(k_bytes, ceil(rank/pops))`` is WRONG: byte-starved early steps
    waste pop capacity instead of banking it; the recursion is exact.)
    A page at service rank ``r`` is granted at the first ``k`` with
    ``n_k >= r + 1``.

    Returns the per-page grant step (i32 in ``1..h_cap``); pages not
    wanted (``key < 0``) or not granted within ``h_cap`` steps carry the
    sentinel ``h_cap + 1``.  ``n_k`` is non-decreasing (``bytes_ok`` is
    monotone in credit), so "first k" is a searchsorted count.
    """
    P = key.shape[0]
    order = jnp.argsort(-key)  # stable: descending key, ties ascending idx
    kv = key[order]
    w_ord = kv >= 0
    sz = jnp.where(w_ord, sizes[order], 0.0)
    csum = jnp.cumsum(sz)
    ks = jnp.arange(1, h_cap + 1, dtype=jnp.float32)
    # grants the banked credit alone allows after k steps (byte feasibility)
    cnt = jnp.sum(
        w_ord[None, :] & (csum[None, :] <= credit0 + ks[:, None] * inc),
        axis=1,
    ).astype(jnp.float32)
    popf = jnp.maximum(pops, 0).astype(jnp.float32)
    # unrolled recursion: n_k = min(min_{1<=j<=k}(cnt_j + (k-j)*pops), k*pops)
    gap = ks[:, None] - ks[None, :]            # (k, j) -> k - j
    ramp = jnp.where(gap >= 0, cnt[None, :] + gap * popf, jnp.inf)
    n_k = jnp.minimum(jnp.min(ramp, axis=1), ks * popf)
    rank = jnp.arange(P, dtype=jnp.float32)
    step = 1 + jnp.sum(n_k[None, :] < (rank[:, None] + 1.0), axis=1)
    step = jnp.where(w_ord, step, h_cap + 1).astype(jnp.int32)
    return jnp.zeros((P,), jnp.int32).at[order].set(step)


def gla_ref(
    q: jax.Array,    # (B, T, H, K)
    k: jax.Array,
    v: jax.Array,    # (B, T, H, P)
    a: jax.Array,    # (B, T, H)
    i: jax.Array,    # (B, T, H)
) -> jax.Array:
    """Sequential mLSTM recurrence (matrix memory + normalizer)."""
    B, T, H, K = q.shape
    P = v.shape[-1]
    f32 = jnp.float32
    C0 = jnp.zeros((B, H, K, P), f32)
    n0 = jnp.zeros((B, H, K), f32)
    scale = K ** -0.5

    def step(carry, inp):
        C, n = carry
        q_t, k_t, v_t, a_t, i_t = inp
        C = C * a_t[:, :, None, None] + i_t[:, :, None, None] * jnp.einsum(
            "bhk,bhp->bhkp", k_t.astype(f32), v_t.astype(f32)
        )
        n = n * a_t[:, :, None] + i_t[:, :, None] * k_t.astype(f32)
        qs = q_t.astype(f32) * scale
        num = jnp.einsum("bhk,bhkp->bhp", qs, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qs, n)), 1.0)
        return (C, n), num / den[..., None]

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (q, k, v, a, i))
    _, ys = jax.lax.scan(step, (C0, n0), xs)
    return jnp.moveaxis(ys, 0, 1).astype(v.dtype)
