"""Elastic scaling: survive node loss by re-meshing and resharding.

Protocol (coordinator-driven, matches the checkpoint contract):

1. Failure detected (missed heartbeat / collective timeout) -> the run
   controller picks the largest healthy mesh from ``candidate_meshes``
   (e.g. 2x16x16 -> 16x16 -> 8x16: always shrink the pure-DP axes first so
   TP groups stay intact and no weight layout changes).
2. Every healthy host restarts the step loop with the new mesh; params/opt
   restore from the latest checkpoint via ``CheckpointManager.restore`` with
   the new mesh's NamedShardings (device_put reshards transparently).
3. The global batch is preserved by raising grad-accumulation microbatches
   by the DP shrink factor (`rebalance_microbatches`), so optimizer
   semantics (and the LR schedule) are unchanged — only step time grows.
4. Data streams resume exactly: positions (epoch, shard, page, offset) are
   in the checkpoint `extra`; lost readers' ranges are adopted via the
   pipeline's work stealing.

This module provides the pure decision logic (testable on CPU); the mesh
construction itself is ordinary ``jax.make_mesh`` over the surviving slice
topology.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]

    @property
    def chips(self) -> int:
        out = 1
        for s in self.shape:
            out *= s
        return out

    @property
    def dp_degree(self) -> int:
        out = 1
        for s, a in zip(self.shape, self.axes):
            if a in ("pod", "data"):
                out *= s
        return out


CANDIDATE_MESHES: List[MeshPlan] = [
    MeshPlan((2, 16, 16), ("pod", "data", "model")),
    MeshPlan((16, 16), ("data", "model")),
    MeshPlan((8, 16), ("data", "model")),
    MeshPlan((4, 16), ("data", "model")),
]


def plan_after_failure(
    healthy_chips: int, candidates: Sequence[MeshPlan] = CANDIDATE_MESHES
) -> Optional[MeshPlan]:
    """Largest candidate mesh that fits the surviving chips, preserving the
    model (TP) axis width so no parameter relayout is needed."""
    for plan in candidates:
        if plan.chips <= healthy_chips:
            return plan
    return None


def rebalance_microbatches(
    global_batch: int, old_dp: int, new_dp: int, old_microbatches: int
) -> int:
    """Keep the global batch (optimizer semantics) across a DP shrink."""
    assert global_batch % old_dp == 0
    per_replica = global_batch // old_dp * old_microbatches
    if global_batch % new_dp:
        raise ValueError(f"global batch {global_batch} not divisible by dp={new_dp}")
    per_replica_new = global_batch // new_dp
    # microbatch count grows so per-microbatch memory stays constant
    scale = max(1, per_replica_new * old_microbatches // max(per_replica, 1))
    return old_microbatches * max(1, scale)


def reassign_data_ranges(
    failed_readers: Sequence[int], healthy_readers: Sequence[int]
) -> List[Tuple[int, int]]:
    """Round-robin adoption of failed readers' shard ranges (work stealing)."""
    out = []
    for i, f in enumerate(failed_readers):
        out.append((f, healthy_readers[i % len(healthy_readers)]))
    return out
