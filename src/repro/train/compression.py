"""Gradient compression for the cross-pod all-reduce.

int8 per-tensor-scaled quantisation with optional error feedback: the pod
axis rides on DCN (much slower than ICI), so compressing the gradient
all-reduce across "pod" cuts the slowest collective 2x (bf16->int8).  The
compressor is applied *before* the optimizer (the pjit sharding makes XLA
place the cross-pod reduce on the compressed tensor).

``compress_decompress`` is the stateless variant (quantisation noise acts
like gradient noise); ``ef_compress`` carries the quantisation residual to
the next step (error feedback — unbiased in the long run).
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def _q8(g: jax.Array) -> jax.Array:
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def compress_decompress(grads: Any) -> Any:
    """Simulate int8-on-the-wire: quantise+dequantise every leaf."""
    return jax.tree.map(_q8, grads)


def ef_compress(grads: Any, residual: Optional[Any]) -> Tuple[Any, Any]:
    """Error-feedback int8: returns (compressed grads, new residual)."""
    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        q = _q8(corrected)
        return q, corrected - q

    flat_g, td = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    qs, rs = [], []
    for g, r in zip(flat_g, flat_r):
        q, nr = one(g, r)
        qs.append(q)
        rs.append(nr)
    return jax.tree.unflatten(td, qs), jax.tree.unflatten(td, rs)
