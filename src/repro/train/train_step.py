"""Train-step factory: loss -> grads -> clip -> AdamW, with optional
microbatch gradient accumulation (compute/comm overlap: XLA overlaps the
per-microbatch reduce-scatter of FSDP gradients with the next microbatch's
compute inside the accumulation scan) and optional int8 error-feedback
gradient compression for the cross-pod all-reduce."""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.transformer import Model
from .optimizer import OptimizerConfig, OptState, adamw_update


def make_train_step(
    model: Model,
    opt_cfg: OptimizerConfig,
    microbatches: int = 1,
    compress_grads: bool = False,
) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics)."""

    loss_fn = lambda p, b: model.train_loss(p, b)

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        return loss, metrics, grads

    def accumulate(params, batch):
        if microbatches <= 1:
            return grads_of(params, batch)
        # split the global batch on the leading axis into microbatches
        def reshape(x):
            b = x.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])

        mb = jax.tree.map(reshape, batch)
        zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(carry, mbatch):
            acc, loss_acc = carry
            loss, _, grads = grads_of(params, mbatch)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / microbatches, acc, grads
            )
            return (acc, loss_acc + loss / microbatches), None

        (grads, loss), _ = jax.lax.scan(body, (zero_g, jnp.zeros(())), mb)
        return loss, {"loss": loss}, grads

    def train_step(params, opt_state: OptState, batch):
        loss, metrics, grads = accumulate(params, batch)
        if compress_grads:
            from .compression import compress_decompress

            grads = compress_decompress(grads)
        params, opt_state, opt_metrics = adamw_update(
            opt_cfg, params, grads, opt_state
        )
        metrics = {**metrics, **opt_metrics}
        return params, opt_state, metrics

    return train_step
