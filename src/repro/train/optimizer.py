"""AdamW with global-norm clipping and warmup-cosine schedule.

Sharding: the optimizer state tree mirrors the parameter tree, so m/v
inherit the params' PartitionSpecs (FSDP over "data" + TP over "model" in
train mode) — ZeRO-style sharded optimizer state for free.  Moments are kept
in f32 regardless of the param dtype (bf16 params + f32 moments is the
standard large-scale JAX recipe).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    step: jax.Array
    mu: Any       # f32 tree like params
    nu: Any       # f32 tree like params


def init_opt_state(params: Any) -> OptState:
    zeros = lambda: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros(), nu=zeros())


def abstract_opt_state(params: Any) -> OptState:
    z = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params)
    return OptState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        mu=z,
        nu=jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), z),
    )


def opt_state_shardings(param_specs_tree: Any) -> OptState:
    """PartitionSpec tree for OptState given the params' spec tree."""
    from jax.sharding import PartitionSpec as P

    return OptState(
        step=P(),
        mu=param_specs_tree,
        nu=jax.tree.map(lambda s: s, param_specs_tree),
    )


def lr_at(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    step_f = step.astype(jnp.float32)
    warm = cfg.learning_rate * jnp.minimum(1.0, (step_f + 1) / max(1, cfg.warmup_steps))
    frac = jnp.clip(
        (step_f - cfg.warmup_steps) / max(1, cfg.total_steps - cfg.warmup_steps),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return jnp.where(step_f < cfg.warmup_steps, warm, cfg.learning_rate * cos)


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def adamw_update(
    cfg: OptimizerConfig, params: Any, grads: Any, state: OptState
) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = lr_at(cfg, state.step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        np_, nm, nv = upd(p, g, m, v)
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)
    return (
        jax.tree.unflatten(treedef, new_p),
        OptState(step=step, mu=jax.tree.unflatten(treedef, new_m),
                 nu=jax.tree.unflatten(treedef, new_v)),
        {"grad_norm": gnorm, "lr": lr},
    )
