"""Sharded checkpoint save/restore with async writes and elastic resharding.

Layout: <dir>/step_<N>/
  manifest.json        — tree structure, shapes, dtypes, step, mesh shape,
                         data-stream positions (exact-resume data order)
  <flatkey>.npy        — one file per param/opt leaf (host-gathered here;
                         on a real pod each host writes its addressable
                         shards — the manifest records the layout either way)

Fault-tolerance contract:
* save is atomic (write to tmp dir, rename) — a crash mid-save never
  corrupts the latest checkpoint;
* ``restore`` takes the *target* mesh/shardings, so a checkpoint written on
  512 chips restores onto 256 (elastic downscale: see elastic.py) — leaves
  are device_put with the new NamedSharding;
* async mode returns immediately and overlaps serialisation with step N+1
  (the paper's compute/IO overlap, applied to checkpoints).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}/{k}" if prefix else str(k)))
        return out
    if isinstance(tree, (tuple, list)) or hasattr(tree, "_fields"):
        items = tree._asdict().items() if hasattr(tree, "_asdict") else enumerate(tree)
        for k, v in items:
            out.update(_flatten(v, f"{prefix}/{k}" if prefix else str(k)))
        return out
    out[prefix] = tree
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(
        self,
        step: int,
        params: Any,
        opt_state: Any = None,
        extra: Optional[Dict] = None,
        async_: bool = False,
    ) -> None:
        trees = {"params": params}
        if opt_state is not None:
            trees["opt"] = opt_state
        flat = _flatten(trees)
        # host-gather before handing to the writer thread; bf16 has no
        # portable npy representation -> store as f32, restore to template
        arrays = {}
        dtypes = {}
        for k, v in flat.items():
            a = np.asarray(v)
            dtypes[k] = str(a.dtype)
            if a.dtype.name == "bfloat16":
                a = a.astype(np.float32)
            arrays[k] = a
        manifest = {
            "step": step,
            "keys": {k: {"shape": list(a.shape), "dtype": dtypes[k]}
                     for k, a in arrays.items()},
            "extra": extra or {},
        }

        def write():
            tmp = os.path.join(self.dir, f".tmp_step_{step}")
            final = os.path.join(self.dir, f"step_{step}")
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            for k, a in arrays.items():
                np.save(os.path.join(tmp, k.replace("/", "__") + ".npy"), a)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)  # atomic publish
            self._gc()

        if async_:
            self.wait()
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        step: Optional[int],
        params_template: Any,
        opt_template: Any = None,
        shardings: Any = None,
        opt_shardings: Any = None,
    ) -> Tuple[int, Any, Any, Dict]:
        """Restore onto the *current* mesh: leaves are device_put with the
        provided shardings (elastic: mesh may differ from save time)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)

        def load_tree(template, shard_tree, prefix):
            flat_t = _flatten({prefix: template})
            flat_s = _flatten({prefix: shard_tree}) if shard_tree is not None else {}
            loaded = {}
            for k, tmpl in flat_t.items():
                a = np.load(os.path.join(d, k.replace("/", "__") + ".npy"))
                arr = jax.numpy.asarray(a)
                if hasattr(tmpl, "dtype"):
                    arr = arr.astype(tmpl.dtype)  # bf16 restored here
                sh = flat_s.get(k)
                loaded[k] = jax.device_put(arr, sh) if sh is not None else arr
            return _unflatten_like({prefix: template}, loaded)[prefix]

        params = load_tree(params_template, shardings, "params")
        opt = (
            load_tree(opt_template, opt_shardings, "opt")
            if opt_template is not None
            else None
        )
        return step, params, opt, manifest.get("extra", {})


def _unflatten_like(template: Any, flat: Dict[str, Any], prefix: str = "") -> Any:
    if isinstance(template, dict):
        return {
            k: _unflatten_like(v, flat, f"{prefix}/{k}" if prefix else str(k))
            for k, v in template.items()
        }
    if hasattr(template, "_fields"):  # NamedTuple (OptState)
        vals = {
            k: _unflatten_like(v, flat, f"{prefix}/{k}" if prefix else str(k))
            for k, v in template._asdict().items()
        }
        return type(template)(**vals)
    if isinstance(template, (tuple, list)):
        return type(template)(
            _unflatten_like(v, flat, f"{prefix}/{i}") for i, v in enumerate(template)
        )
    return flat[prefix]
