"""Abstract interpretation of ``pl.pallas_call`` sites — grid/BlockSpec
checks over symbolic grid points, before any hardware time is spent.

The ROADMAP's accelerator push grids the buffer-manager kernels over
page blocks for P >> VMEM — exactly the regime where the bug classes
live that Mosaic either rejects with an opaque error on real hardware or
(worse) compiles into silent corruption: an index_map stepping past the
operand, a BlockSpec×grid product that under- or over-covers it, two
grid points racing on one output block, a per-step footprint past VMEM.
None of these fail in interpret-mode CPU tests, because interpret mode
follows the same index maps the checks validate — they fail on the TPU,
a queue slot and a toolchain away.

This module runs each kernel *wrapper* (the host-side function that
builds grids and BlockSpecs and calls ``pl.pallas_call``) against small
example operands with ``pl.pallas_call`` swapped for a recorder: the
wrapper's own padding/reshape/transpose logic executes for real, the
kernel body never runs, and the recorder captures the exact grid,
BlockSpecs, scalar-prefetch operands and scratch the real call would
get.  The checks then enumerate the grid (it is small for the example
shapes — the properties checked are shape-relative, so they transfer to
any P) and evaluate every ``index_map`` as a plain Python function:

* ``kernel-index-oob``     — some grid point's block reaches outside the
  operand (first/last point included; table-driven maps are evaluated
  against the captured scalar-prefetch values, so a page-table entry at
  the pool edge exercises the bound);
* ``kernel-block-coverage`` — block_shape does not divide the operand
  (Mosaic pads the tail block: reads see garbage lanes, reductions over
  them are wrong), or the output index_map never writes some block;
* ``kernel-write-race``    — two grid points map to the same output
  block.  The online-softmax accumulator pattern (flash / paged
  attention revisit the output across the innermost axis and commit once
  under ``pl.when(last step)``) is the sanctioned exception: a revisit
  is allowed iff every write to that output in the kernel body is
  guarded by a ``pl.when`` condition on a revisited grid axis, or the
  kernel def carries ``# analysis: revisit``;
* ``kernel-vmem-budget``   — Σ (double-buffered block bytes) + declared
  scratch exceeds the budget (default 16 MiB — one TPU core's VMEM);
* ``kernel-memory-space``  — a (1, 1) scalar block riding VMEM or a
  dense row riding SMEM (scalars must ride SMEM, dense rows VMEM).

``capture_calls`` is the entry point tests and
:mod:`repro.analysis.kernels` share; seeded-violation tests build toy
wrappers and assert each rule fires.
"""

from __future__ import annotations

import ast
import contextlib
import inspect
import itertools
import re
import textwrap
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from .findings import Finding

__all__ = [
    "CapturedCall",
    "DEFAULT_VMEM_BUDGET",
    "capture_calls",
    "check_call",
]

#: one TPU core's VMEM; the checker budgets double-buffered blocks
#: + declared scratch against it (compute temporaries are the kernel
#: author's problem — this bounds what the BlockSpecs alone commit to)
DEFAULT_VMEM_BUDGET = 16 * 1024 * 1024

#: grids larger than this are probed at axis corners instead of densely
_DENSE_GRID_LIMIT = 4096

_PRAGMA_REVISIT = re.compile(r"#\s*analysis:\s*revisit\b")


@dataclass
class CapturedCall:
    """One recorded ``pl.pallas_call`` invocation."""

    name: str                              # kernel function __name__
    kernel_fn: Callable                    # unwrapped (partial.func)
    path: str                              # repo-relative source file
    line: int                              # kernel def line
    grid: Tuple[int, ...]
    num_scalar_prefetch: int
    in_specs: List[Any]                    # pl.BlockSpec per operand
    out_specs: List[Any]
    in_shapes: List[Tuple[Tuple[int, ...], Any]]    # (shape, dtype)
    out_shapes: List[Tuple[Tuple[int, ...], Any]]
    scratch_shapes: List[Any]
    prefetch: List[np.ndarray] = field(default_factory=list)


def _rel_path(path: Optional[str]) -> str:
    if not path:
        return "?"
    marker = "src/"
    return path[path.index(marker):] if marker in path else path


def _unwrap(fn: Callable) -> Callable:
    while hasattr(fn, "func"):      # functools.partial chains
        fn = fn.func
    return fn


def _as_list(x) -> list:
    if x is None:
        return []
    if isinstance(x, (list, tuple)):
        return list(x)
    return [x]


def _aval(x) -> Tuple[Tuple[int, ...], Any]:
    return tuple(int(d) for d in x.shape), x.dtype


@contextlib.contextmanager
def capture_calls(calls: List[CapturedCall]):
    """Swap ``pl.pallas_call`` for a recorder appending to ``calls``.

    The replacement returns zeros of ``out_shape`` so the wrapper's
    post-call reshape/slice logic still runs; the kernel body never
    executes.  Kernel modules resolve ``pl.pallas_call`` by attribute at
    call time, so patching the module attribute reaches every wrapper.
    """
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    real = pl.pallas_call

    def fake(kernel, *, out_shape=None, grid=None, grid_spec=None,
             in_specs=None, out_specs=None, scratch_shapes=(),
             interpret=False, **_kw):
        n_prefetch = 0
        if grid_spec is not None:
            grid = grid_spec.grid
            in_specs = _as_list(grid_spec.in_specs)
            out_specs = _as_list(grid_spec.out_specs)
            scratch_shapes = _as_list(
                getattr(grid_spec, "scratch_shapes", ()))
            n_prefetch = int(getattr(grid_spec, "num_scalar_prefetch", 0))
        grid_t = tuple(int(g) for g in _as_list(grid))
        outs = _as_list(out_shape)
        fn = _unwrap(kernel)
        try:
            path = inspect.getsourcefile(fn)
            line = inspect.getsourcelines(fn)[1]
        except (OSError, TypeError):
            path, line = None, 0

        def runner(*operands):
            pre = [np.asarray(o) for o in operands[:n_prefetch]]
            ins = operands[n_prefetch:]
            calls.append(CapturedCall(
                name=getattr(fn, "__name__", "<kernel>"),
                kernel_fn=fn,
                path=_rel_path(path),
                line=line,
                grid=grid_t,
                num_scalar_prefetch=n_prefetch,
                in_specs=_as_list(in_specs),
                out_specs=_as_list(out_specs),
                in_shapes=[_aval(o) for o in ins],
                out_shapes=[(tuple(int(d) for d in o.shape), o.dtype)
                            for o in outs],
                scratch_shapes=_as_list(scratch_shapes),
                prefetch=pre,
            ))
            zeros = tuple(jnp.zeros(o.shape, o.dtype) for o in outs)
            return zeros[0] if not isinstance(out_shape, (list, tuple)) \
                else zeros
        return runner

    pl.pallas_call = fake
    try:
        yield
    finally:
        pl.pallas_call = real


# ------------------------------------------------------------ grid probing --

def _grid_points(grid: Sequence[int]) -> List[Tuple[int, ...]]:
    """All grid points when the product is small, else the axis corners
    (every combination of {0, g-1}) — first and last point included."""
    if not grid:
        return [()]
    total = 1
    for g in grid:
        total *= g
    if total <= _DENSE_GRID_LIMIT:
        return list(itertools.product(*[range(g) for g in grid]))
    return list(itertools.product(*[
        sorted({0, g - 1}) for g in grid
    ]))


def _block_index(spec, point: Tuple[int, ...],
                 prefetch: Sequence[np.ndarray]) -> Optional[Tuple[int, ...]]:
    """Evaluate one BlockSpec's index_map at a concrete grid point."""
    index_map = getattr(spec, "index_map", None)
    if index_map is None:
        return None
    out = index_map(*point, *prefetch)
    if not isinstance(out, tuple):
        out = (out,)
    return tuple(int(i) for i in out)


def _block_dims(spec) -> Optional[Tuple[int, ...]]:
    bs = getattr(spec, "block_shape", None)
    if bs is None:
        return None
    return tuple(1 if d is None else int(d) for d in bs)


def _dtype_bytes(dtype) -> int:
    return int(np.dtype(dtype).itemsize)


def _numel(shape: Sequence[int]) -> int:
    n = 1
    for d in shape:
        n *= int(d)
    return n


# -------------------------------------------------- write-race sanctioning --

def _kernel_ast(fn: Callable) -> Optional[ast.Module]:
    try:
        src = textwrap.dedent(inspect.getsource(fn))
    except (OSError, TypeError):
        return None
    try:
        return ast.parse(src)
    except SyntaxError:
        return None


def _has_revisit_pragma(fn: Callable) -> bool:
    try:
        src = inspect.getsource(fn)
    except (OSError, TypeError):
        return False
    head = src.splitlines()[:2]
    return any(_PRAGMA_REVISIT.search(line) for line in head)


def _dotted(func: ast.expr) -> str:
    parts: List[str] = []
    node = func
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def _root_name(node: ast.expr) -> Optional[str]:
    while isinstance(node, (ast.Attribute, ast.Subscript, ast.Call)):
        node = node.func if isinstance(node, ast.Call) else node.value
    return node.id if isinstance(node, ast.Name) else None


class _WriteGuardScan(ast.NodeVisitor):
    """Finds writes to one ref parameter and the ``pl.when`` program-id
    axes guarding each (lexically, through nested decorated defs)."""

    def __init__(self, out_param: str):
        self.out_param = out_param
        self.pid_axes: Dict[str, int] = {}     # name -> program_id axis
        self.guard_stack: List[Set[int]] = []
        self.writes: List[Set[int]] = []       # guard axes per write

    def _axes_in(self, node: ast.expr) -> Set[int]:
        axes: Set[int] = set()
        for sub in ast.walk(node):
            if isinstance(sub, ast.Name) and sub.id in self.pid_axes:
                axes.add(self.pid_axes[sub.id])
            elif isinstance(sub, ast.Call) \
                    and _dotted(sub.func).endswith("program_id") \
                    and sub.args and isinstance(sub.args[0], ast.Constant):
                axes.add(int(sub.args[0].value))
        return axes

    def visit_Assign(self, node: ast.Assign) -> None:
        # program-id bindings: p = pl.program_id(2)
        if (len(node.targets) == 1 and isinstance(node.targets[0], ast.Name)
                and isinstance(node.value, ast.Call)
                and _dotted(node.value.func).endswith("program_id")
                and node.value.args
                and isinstance(node.value.args[0], ast.Constant)):
            self.pid_axes[node.targets[0].id] = int(node.value.args[0].value)
        for t in node.targets:
            if isinstance(t, ast.Subscript) \
                    and _root_name(t.value) == self.out_param:
                active: Set[int] = set()
                for g in self.guard_stack:
                    active |= g
                self.writes.append(active)
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        # pl.store(o_ref, idx, val) counts as a write too
        if _dotted(node.func).endswith("store") and node.args \
                and _root_name(node.args[0]) == self.out_param:
            active: Set[int] = set()
            for g in self.guard_stack:
                active |= g
            self.writes.append(active)
        self.generic_visit(node)

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        axes: Set[int] = set()
        for dec in node.decorator_list:
            if isinstance(dec, ast.Call) and _dotted(dec.func).endswith("when"):
                for arg in dec.args:
                    axes |= self._axes_in(arg)
        self.guard_stack.append(axes)
        self.generic_visit(node)
        self.guard_stack.pop()


def _writes_guarded(call: CapturedCall, out_index: int,
                    revisit_axes: Set[int]) -> bool:
    """Every kernel-body write to output ``out_index`` sits under a
    ``pl.when`` on a revisited axis (the sanctioned accumulator-commit
    pattern)."""
    tree = _kernel_ast(call.kernel_fn)
    if tree is None or not tree.body \
            or not isinstance(tree.body[0], ast.FunctionDef):
        return False
    fndef = tree.body[0]
    params = [a.arg for a in fndef.args.posonlyargs + fndef.args.args]
    pos = call.num_scalar_prefetch + len(call.in_specs) + out_index
    if pos >= len(params):
        return False
    scan = _WriteGuardScan(params[pos])
    # seed program-id bindings before walking nested defs in order
    for stmt in fndef.body:
        scan.visit(stmt)
    if not scan.writes:
        return False
    return all(axes & revisit_axes for axes in scan.writes)


# ------------------------------------------------------------- the checks --

def check_call(call: CapturedCall, *,
               vmem_budget: int = DEFAULT_VMEM_BUDGET) -> List[Finding]:
    """Run every grid/BlockSpec check against one captured call."""
    findings: List[Finding] = []

    def emit(rule: str, message: str) -> None:
        findings.append(Finding(rule=rule, path=call.path, line=call.line,
                                message=f"{call.name}: {message}"))

    points = _grid_points(call.grid)
    operands = (
        [("in", i, s, a) for i, (s, a) in
         zip(range(len(call.in_specs)), call.in_shapes)]
        + [("out", i, s, a) for i, (s, a) in
           zip(range(len(call.out_specs)), call.out_shapes)]
    )
    specs = call.in_specs + call.out_specs

    vmem_bytes = 0
    for (kind, idx, shape, dtype), spec in zip(operands, specs):
        label = f"{kind}[{idx}]"
        block = _block_dims(spec)
        space = str(getattr(spec, "memory_space", None) or "")

        # ---- memory-space placement ---------------------------------------
        eff = block if block is not None else shape
        if _numel(eff) <= 2 and space == "vmem":
            emit("kernel-memory-space",
                 f"{label} is a scalar block {tuple(eff)} riding VMEM — "
                 "scalars ride SMEM (a VMEM scalar burns a full "
                 "(8, 128) tile and a DMA slot)")
        elif _numel(eff) >= 128 and space == "smem":
            emit("kernel-memory-space",
                 f"{label} is a dense block {tuple(eff)} riding SMEM — "
                 "dense rows ride VMEM (SMEM is for scalars and control)")

        # ---- VMEM budget accounting ---------------------------------------
        if space != "smem":
            mult = 2 if call.grid else 1   # Mosaic double-buffers blocks
            vmem_bytes += _numel(eff) * _dtype_bytes(dtype) * mult

        if block is None:
            continue

        # ---- divisibility --------------------------------------------------
        if len(block) != len(shape):
            emit("kernel-block-coverage",
                 f"{label} block rank {len(block)} != operand rank "
                 f"{len(shape)} {shape}")
            continue
        for d, (b, s) in enumerate(zip(block, shape)):
            if s % b != 0:
                emit("kernel-block-coverage",
                     f"{label} dim {d}: block {b} does not divide operand "
                     f"{s} — Mosaic pads the tail block and reductions "
                     "see garbage lanes (pad the operand to a block "
                     "multiple in the wrapper)")

        # ---- index bounds over the grid -----------------------------------
        nblocks = tuple(max(1, -(-s // b)) for b, s in zip(block, shape))
        seen: Dict[Tuple[int, ...], Tuple[int, ...]] = {}
        oob_reported = False
        for pt in points:
            try:
                bi = _block_index(spec, pt, call.prefetch)
            except Exception as exc:  # noqa: BLE001 — a crash IS the finding
                emit("kernel-index-oob",
                     f"{label} index_map raised {type(exc).__name__} at "
                     f"grid point {pt}: {exc}")
                oob_reported = True
                break
            if bi is None:
                break
            if len(bi) != len(block):
                emit("kernel-index-oob",
                     f"{label} index_map returns rank {len(bi)} for a "
                     f"rank-{len(block)} block")
                oob_reported = True
                break
            if not oob_reported and any(
                    i < 0 or i >= n for i, n in zip(bi, nblocks)):
                emit("kernel-index-oob",
                     f"{label} index_map reaches block {bi} at grid point "
                     f"{pt}; valid blocks are {tuple(nblocks)} — the DMA "
                     "would read/write outside the operand on hardware")
                oob_reported = True
            if pt in seen:
                continue
            seen[pt] = bi

        # ---- output coverage + write races --------------------------------
        if kind == "out" and not oob_reported and seen:
            by_block: Dict[Tuple[int, ...], List[Tuple[int, ...]]] = {}
            for pt, bi in seen.items():
                by_block.setdefault(bi, []).append(pt)

            dense = len(points) == max(
                1, int(np.prod(call.grid)) if call.grid else 1)
            if dense and all(s % b == 0 for b, s in zip(block, shape)):
                missing = [bi for bi in itertools.product(
                    *[range(n) for n in nblocks]) if bi not in by_block]
                if missing:
                    emit("kernel-block-coverage",
                         f"{label} blocks {missing[:4]} (of "
                         f"{int(np.prod(nblocks))}) are never written by "
                         "any grid point — stale memory ships as output")

            revisit_axes: Set[int] = set()
            revisited = False
            for bi, pts in by_block.items():
                if len(pts) > 1:
                    revisited = True
                    for ax in range(len(call.grid)):
                        vals = {p[ax] for p in pts}
                        if len(vals) > 1:
                            revisit_axes.add(ax)
            if revisited:
                sanctioned = (
                    _has_revisit_pragma(call.kernel_fn)
                    or _writes_guarded(call, idx, revisit_axes)
                )
                if not sanctioned:
                    emit("kernel-write-race",
                         f"{label} is written by multiple grid points "
                         f"(revisit over grid axes {sorted(revisit_axes)}) "
                         "without a pl.when commit guard on a revisited "
                         "axis — on hardware the steps race; guard the "
                         "final write with pl.when(last step) (the "
                         "accumulator pattern) or mark the kernel "
                         "`# analysis: revisit`")

    # ---- scratch + budget -------------------------------------------------
    for sc in call.scratch_shapes:
        shape = getattr(sc, "shape", None)
        dtype = getattr(sc, "dtype", None)
        if shape is not None and dtype is not None:
            vmem_bytes += _numel(shape) * _dtype_bytes(dtype)
    if vmem_bytes > vmem_budget:
        emit("kernel-vmem-budget",
             f"per-step VMEM footprint {vmem_bytes} bytes (double-buffered "
             f"blocks + scratch) exceeds the {vmem_budget}-byte budget — "
             "shrink blocks or grid over more axes (the P >> VMEM tiling "
             "plan, ROADMAP)")
    return findings
