"""The one result type every analysis pass emits."""

from __future__ import annotations

from dataclasses import asdict, dataclass


@dataclass(frozen=True)
class Finding:
    """One contract violation, pinned to ``path:line`` for the CI log."""

    rule: str      # e.g. "jit-coercion", "registry-coherence"
    path: str      # repo-relative where possible
    line: int
    message: str
    col: int = 0

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule}: {self.message}"

    def to_json(self) -> dict:
        return asdict(self)
