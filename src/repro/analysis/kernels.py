"""Pallas kernel contract verifier — the static gate before the
accelerator push (DESIGN.md §9).

Two layers over ``src/repro/kernels``:

Layer 1 — AST rules (this module, PR-7 style: stdlib ``ast``, file:line
findings, no imports of the checked code):

* ``kernel-missing-oracle``    — every public ``*_kernel`` wrapper must
  pair with a ``ref.py`` oracle: ``<stem>_ref`` by name, or an explicit
  ``# analysis: oracle=<name>`` pragma on the def (``mlstm_chunked``'s
  oracle is ``gla_ref``).  A kernel without an oracle has no exact
  semantics to test against — the repo's whole validation chain
  (interpret-mode equality, differential fuzz) hangs off the pairing.
* ``kernel-memory-space``      — every ``pl.BlockSpec(...)`` must
  declare ``memory_space=``: scalars ride SMEM, dense rows VMEM, and an
  undeclared spec silently takes whatever default the Pallas version
  ships, which is exactly the kind of contract that breaks under a
  toolchain bump.
* ``kernel-mxu-element-type``  — ``jnp.dot`` / ``lax.dot_general`` /
  ``pl.dot`` must set ``preferred_element_type``: the MXU accumulates
  bf16 inputs in bf16 unless told otherwise, and the prefix-sum trick
  in the buffer-manager kernels is exact only under f32 accumulation.
* ``kernel-float-mantissa-cast`` — an integer-keyed input must not be
  cast to a float dtype whose mantissa is narrower than the key's used
  bits (the ``fifo_grant`` 2^24 rule generalized: its FIFO keys use
  ~30 bits, f32 carries 24).  A ``key``-named parameter cast via
  ``.astype(float dtype)`` is flagged unless the enclosing function
  dispatches on ``jnp.issubdtype(key.dtype, jnp.integer)`` — the
  sanctioned pattern: integers ride an i32 path, floats the f32 one.

Layer 2 — abstract interpretation (:mod:`repro.analysis.absint`): each
kernel wrapper in :data:`CONTRACTS` runs against small example operands
with ``pl.pallas_call`` swapped for a recorder, and the captured
grid/BlockSpec geometry is checked for coverage, index bounds, write
races and the VMEM budget.  See ``absint``'s docstring for the rules.

``verify_kernels()`` is the combined entry point wired into
``python -m repro.analysis --check``.
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import Callable, Dict, List, NamedTuple, Optional, Sequence, Set

from .findings import Finding
from .lint import repo_src_root

__all__ = [
    "CONTRACTS",
    "KernelContract",
    "check_contracts",
    "kernel_lint_source",
    "ref_oracle_names",
    "verify_kernels",
]

#: float dtypes by mantissa width (bits of exact integer headroom)
FLOAT_MANTISSA = {
    "float64": 53, "float32": 24, "float16": 11, "bfloat16": 8,
}
#: parameter names treated as integer-capable sort/priority keys
_KEY_PARAM = ("key", "keys")
#: dotted-call tails that hit the MXU and must pin their accumulator type
_MXU_TAILS = ("dot", "dot_general", "matmul")
#: files in kernels/ that are not kernel-wrapper modules
_NON_KERNEL_FILES = {"__init__.py", "ref.py", "ops.py"}

_ORACLE_PRAGMA = "# analysis: oracle="


def _dotted(func: ast.expr) -> Optional[str]:
    parts: List[str] = []
    node = func
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _root_name(node: ast.expr) -> Optional[str]:
    while isinstance(node, (ast.Attribute, ast.Subscript, ast.Call)):
        node = node.func if isinstance(node, ast.Call) else node.value
    return node.id if isinstance(node, ast.Name) else None


def _is_key_param(name: Optional[str]) -> bool:
    return name is not None and (
        name in _KEY_PARAM or name.endswith("_key") or name.endswith("_keys")
    )


def _float_target(node: ast.expr) -> Optional[str]:
    """Float dtype name if this astype target is one, else None."""
    if isinstance(node, ast.Attribute) and node.attr in FLOAT_MANTISSA:
        return node.attr
    if isinstance(node, ast.Name) and node.id in FLOAT_MANTISSA:
        return node.id
    if isinstance(node, ast.Constant) and node.value in FLOAT_MANTISSA:
        return str(node.value)
    return None


def ref_oracle_names(ref_source: str) -> Set[str]:
    """Module-level def names of ``kernels/ref.py``."""
    try:
        tree = ast.parse(ref_source)
    except SyntaxError:
        return set()
    return {n.name for n in tree.body if isinstance(n, ast.FunctionDef)}


def _oracle_pragma(src_lines: Sequence[str], node: ast.AST) -> Optional[str]:
    """``# analysis: oracle=<name>`` on the def line or the line above."""
    for ln in (node.lineno - 1, node.lineno - 2):
        if 0 <= ln < len(src_lines):
            text = src_lines[ln]
            if _ORACLE_PRAGMA in text:
                tail = text.split(_ORACLE_PRAGMA, 1)[1]
                name = tail.split()[0].strip() if tail.split() else ""
                return name or None
    return None


class _FunctionScan(ast.NodeVisitor):
    """Per-function rule sites: MXU element type, BlockSpec memory
    space, float-mantissa key casts."""

    def __init__(self, rel: str, findings: List[Finding],
                 params: Set[str], dispatched: Set[str]):
        self.rel = rel
        self.findings = findings
        self.params = params
        self.dispatched = dispatched   # params with an issubdtype dispatch

    def _emit(self, rule: str, node: ast.AST, message: str) -> None:
        self.findings.append(Finding(
            rule=rule, path=self.rel, line=node.lineno,
            col=node.col_offset, message=message,
        ))

    def visit_Call(self, node: ast.Call) -> None:
        dotted = _dotted(node.func) or ""
        tail = dotted.rsplit(".", 1)[-1]
        kwargs = {kw.arg for kw in node.keywords}

        if tail == "BlockSpec" and "memory_space" not in kwargs:
            self._emit(
                "kernel-memory-space", node,
                "BlockSpec without memory_space= — declare it (scalars "
                "ride pltpu.SMEM, dense rows pltpu.VMEM); an undeclared "
                "spec takes the Pallas version's default",
            )
        if tail in _MXU_TAILS and dotted != tail \
                and "preferred_element_type" not in kwargs:
            self._emit(
                "kernel-mxu-element-type", node,
                f"`{dotted}` without preferred_element_type= — the MXU "
                "accumulates narrow inputs narrowly unless pinned; the "
                "prefix-sum kernels are exact only under f32 accumulation",
            )
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr == "astype" and node.args:
            target = _float_target(node.args[0])
            root = _root_name(node.func.value)
            if target is not None and _is_key_param(root) \
                    and root in self.params and root not in self.dispatched:
                bits = FLOAT_MANTISSA[target]
                self._emit(
                    "kernel-float-mantissa-cast", node,
                    f"`{root}.astype({target})` casts a priority key to a "
                    f"{bits}-bit-mantissa float unconditionally — integer "
                    f"keys wider than 2^{bits} collapse (the fifo_grant "
                    "2^24 rule).  Dispatch on jnp.issubdtype"
                    f"({root}.dtype, jnp.integer) and keep integers on an "
                    "i32 path",
                )
        self.generic_visit(node)


def _issubdtype_params(fn: ast.FunctionDef) -> Set[str]:
    """Parameters whose dtype the function dispatches on via
    ``jnp.issubdtype(<param>.dtype, ...)`` (the sanctioned guard)."""
    out: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Call) \
                and (_dotted(node.func) or "").endswith("issubdtype"):
            for arg in node.args:
                root = _root_name(arg)
                if root is not None:
                    out.add(root)
    return out


def kernel_lint_source(source: str, rel: str,
                       ref_names: Optional[Set[str]] = None) -> List[Finding]:
    """Layer-1 kernel rules over one kernel module's source.

    ``ref_names`` is the set of oracle defs in ``kernels/ref.py``
    (injectable for tests); ``None`` skips the oracle rule.
    """
    findings: List[Finding] = []
    try:
        tree = ast.parse(source, filename=rel)
    except SyntaxError as exc:
        findings.append(Finding(
            rule="syntax-error", path=rel, line=exc.lineno or 1,
            message=str(exc.msg),
        ))
        return findings
    src_lines = source.splitlines()

    for node in tree.body:
        if not isinstance(node, ast.FunctionDef):
            continue
        # oracle pairing for public wrappers
        if ref_names is not None and node.name.endswith("_kernel") \
                and not node.name.startswith("_"):
            stem = node.name[: -len("_kernel")]
            declared = _oracle_pragma(src_lines, node)
            if declared is not None:
                if declared not in ref_names:
                    findings.append(Finding(
                        rule="kernel-missing-oracle", path=rel,
                        line=node.lineno,
                        message=f"{node.name} declares oracle "
                                f"{declared!r} but kernels/ref.py does "
                                "not define it",
                    ))
            elif f"{stem}_ref" not in ref_names:
                findings.append(Finding(
                    rule="kernel-missing-oracle", path=rel,
                    line=node.lineno,
                    message=f"{node.name} has no ref.py oracle: define "
                            f"`{stem}_ref` (or declare the pairing with "
                            "`# analysis: oracle=<name>`) — interpret-"
                            "mode equality against the oracle is the "
                            "kernel's only executable spec",
                ))

    class _Walk(ast.NodeVisitor):
        def visit_FunctionDef(self, fn: ast.FunctionDef) -> None:
            params = {
                a.arg for a in (list(fn.args.posonlyargs) + list(fn.args.args)
                                + list(fn.args.kwonlyargs))
            }
            scan = _FunctionScan(rel, findings, params,
                                 _issubdtype_params(fn))
            for stmt in fn.body:
                scan.visit(stmt)
            self.generic_visit(fn)

    _Walk().visit(tree)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def lint_kernel_tree(root=None) -> List[Finding]:
    """Layer 1 over every kernel module under ``<root>/kernels``."""
    root = Path(root) if root is not None else repo_src_root()
    kdir = root / "kernels"
    if not kdir.is_dir():
        return []
    ref_path = kdir / "ref.py"
    ref_names = (
        ref_oracle_names(ref_path.read_text(encoding="utf-8"))
        if ref_path.exists() else set()
    )
    findings: List[Finding] = []
    for path in sorted(kdir.glob("*.py")):
        if path.name in _NON_KERNEL_FILES:
            continue
        try:
            rel = str(path.relative_to(root.parent))
        except ValueError:
            rel = str(path)
        findings += kernel_lint_source(
            path.read_text(encoding="utf-8"), rel, ref_names)
    return findings


# -------------------------------------------------------------- contracts --

class KernelContract(NamedTuple):
    """One pallas_call site + the example point it is verified at.

    ``build`` imports the wrapper lazily (the contract table must be
    importable without jax) and returns ``(fn, args, kwargs)``; the
    wrapper is then executed with ``pl.pallas_call`` patched to a
    recorder, so its real padding/grid/BlockSpec logic runs but the
    kernel body never does.  Example shapes are small — the checked
    properties (divisibility, bounds, injectivity, footprint) are
    shape-relative and transfer to any size the wrapper computes its
    geometry from.
    """

    name: str
    build: Callable


def _c_batched_evict():
    import jax.numpy as jnp
    from repro.kernels.pbm_timeline import batched_evict_kernel

    P = 4096
    return (batched_evict_kernel,
            (jnp.zeros(P, jnp.float32), jnp.ones(P, jnp.float32),
             jnp.ones(P, bool), jnp.float32(8.0)),
            {})


def _c_batched_evict_i32():
    """The integer-key path: array-OPT exact next-use distances must
    survive beyond 2^24 (the rule that caught the unconditional f32
    cast this PR fixed)."""
    import jax.numpy as jnp
    from repro.kernels.pbm_timeline import batched_evict_kernel

    P = 4096
    return (batched_evict_kernel,
            (jnp.zeros(P, jnp.int32), jnp.ones(P, jnp.float32),
             jnp.ones(P, bool), jnp.float32(8.0)),
            {})


def _c_fifo_grant():
    import jax.numpy as jnp
    from repro.kernels.pbm_timeline import fifo_grant_kernel

    P = 4096
    return (fifo_grant_kernel,
            (jnp.zeros(P, jnp.int32), jnp.ones(P, jnp.float32),
             jnp.float32(64.0), jnp.int32(8)),
            {})


def _c_fifo_grant_tail():
    """Blocked geometry with a ragged tail: P = 3 x _BLOCK + 129 pads to
    a fourth block, so the recorder sees the (phase, i, j) grid walking
    partially-padded edge tiles — the coverage/bounds rules must hold
    with padding in play, not just at the divisible example point."""
    import jax.numpy as jnp
    from repro.kernels.pbm_timeline import fifo_grant_kernel

    P = 3 * 512 + 129
    return (fifo_grant_kernel,
            (jnp.zeros(P, jnp.int32), jnp.ones(P, jnp.float32),
             jnp.float32(64.0), jnp.int32(8)),
            {})


def _c_wake_solve():
    import jax.numpy as jnp
    from repro.kernels.pbm_timeline import wake_solve_kernel

    P = 4096
    return (wake_solve_kernel,
            (jnp.zeros(P, jnp.int32), jnp.ones(P, jnp.float32),
             jnp.float32(4.0), jnp.float32(2.0), jnp.int32(6)),
            {"h_cap": 16})


def _c_wake_solve_tail():
    """Wake-solve at the ragged-tail geometry (P = 3 x _BLOCK + 129):
    its global scratch rows are sized to the PADDED pool, so the
    footprint and write-coverage checks must pass with the tail block
    present."""
    import jax.numpy as jnp
    from repro.kernels.pbm_timeline import wake_solve_kernel

    P = 3 * 512 + 129
    return (wake_solve_kernel,
            (jnp.zeros(P, jnp.int32), jnp.ones(P, jnp.float32),
             jnp.float32(4.0), jnp.float32(2.0), jnp.int32(6)),
            {"h_cap": 16})


def _c_paged_attention():
    import numpy as np
    import jax.numpy as jnp
    from repro.kernels.paged_attention import paged_attention_kernel

    b, h, hk, dh = 2, 4, 2, 128
    page_size, pages_per_seq, n_pages = 16, 3, 10
    # the page table deliberately includes the pool's LAST page id so the
    # bounds probe exercises the table-driven index_map at the pool edge
    pt = np.arange(b * pages_per_seq, dtype=np.int32).reshape(b, -1)
    pt[0, 0] = n_pages - 1
    return (paged_attention_kernel,
            (jnp.zeros((b, h, dh), jnp.float32),
             jnp.zeros((n_pages, page_size, hk, dh), jnp.float32),
             jnp.zeros((n_pages, page_size, hk, dh), jnp.float32),
             jnp.asarray(pt),
             jnp.full((b,), page_size * pages_per_seq, jnp.int32)),
            {})


def _c_flash_attention():
    import jax.numpy as jnp
    from repro.kernels.flash_attention import flash_attention_kernel

    b, t, h, dh = 1, 256, 2, 128
    z = jnp.zeros((b, t, h, dh), jnp.float32)
    return (flash_attention_kernel, (z, z, z),
            {"causal": True, "block_q": 128, "block_kv": 128})


def _c_mamba2_scan():
    import jax.numpy as jnp
    from repro.kernels.mamba2_scan import mamba2_scan_kernel

    B, T, H, P, N = 2, 256, 2, 64, 64
    return (mamba2_scan_kernel,
            (jnp.zeros((B, T, H, P), jnp.float32),
             jnp.ones((B, T, H), jnp.float32),
             jnp.zeros((B, T, N), jnp.float32),
             jnp.zeros((B, T, N), jnp.float32)),
            {"chunk": 128})


def _c_mlstm():
    import jax.numpy as jnp
    from repro.kernels.mlstm import mlstm_chunked_kernel

    B, T, H, K, P = 2, 256, 2, 64, 64
    qk = jnp.zeros((B, T, H, K), jnp.float32)
    v = jnp.zeros((B, T, H, P), jnp.float32)
    g = jnp.ones((B, T, H), jnp.float32)
    return (mlstm_chunked_kernel, (qk, qk, v, g, g), {"chunk": 128})


#: every pl.pallas_call site in src/repro/kernels, by wrapper
CONTRACTS = (
    KernelContract("batched_evict", _c_batched_evict),
    KernelContract("batched_evict[i32]", _c_batched_evict_i32),
    KernelContract("fifo_grant", _c_fifo_grant),
    KernelContract("fifo_grant[tail]", _c_fifo_grant_tail),
    KernelContract("wake_solve", _c_wake_solve),
    KernelContract("wake_solve[tail]", _c_wake_solve_tail),
    KernelContract("paged_attention", _c_paged_attention),
    KernelContract("flash_attention", _c_flash_attention),
    KernelContract("mamba2_scan", _c_mamba2_scan),
    KernelContract("mlstm_chunked", _c_mlstm),
)


def check_contracts(contracts: Sequence[KernelContract] = CONTRACTS,
                    vmem_budget: Optional[int] = None) -> List[Finding]:
    """Layer 2: run each contract's wrapper under the recorder and check
    every captured pallas_call."""
    from .absint import DEFAULT_VMEM_BUDGET, capture_calls, check_call

    budget = DEFAULT_VMEM_BUDGET if vmem_budget is None else vmem_budget
    findings: List[Finding] = []
    for contract in contracts:
        fn, args, kwargs = contract.build()
        calls: List = []
        try:
            with capture_calls(calls):
                fn(*args, **kwargs)
        except Exception as exc:  # noqa: BLE001 — wrapper crash IS a finding
            findings.append(Finding(
                rule="kernel-contract-error", path="repro/kernels", line=0,
                message=f"{contract.name}: wrapper raised "
                        f"{type(exc).__name__} under capture: {exc}",
            ))
            continue
        if not calls:
            findings.append(Finding(
                rule="kernel-contract-error", path="repro/kernels", line=0,
                message=f"{contract.name}: wrapper made no pallas_call "
                        "(contract is stale — update CONTRACTS)",
            ))
        for call in calls:
            findings += check_call(call, vmem_budget=budget)
    return findings


def verify_kernels(root=None,
                   vmem_budget: Optional[int] = None,
                   contracts: bool = True) -> List[Finding]:
    """Both layers.  ``root`` scopes the AST layer (PR-7 ``--root``
    convention); the contract layer always targets the *installed*
    kernels, so it is skipped when a custom root is given."""
    findings = lint_kernel_tree(root)
    if contracts and root is None:
        findings += check_contracts(vmem_budget=vmem_budget)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings
