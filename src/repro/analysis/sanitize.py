"""Runtime half of the contract checker: the jit sanitizer smoke.

``make_runner(sanitize=True)`` wraps the compiled run in
``jax.experimental.checkify`` NaN/OOB-index checks and a trace counter
(see ``array_sim.sim``).  This module drives that mode over the default
micro and TPC-H smoke points for every registered array policy on both
steppers — one runner per (stepper x workload), the whole four-policy
sweep through each runner — and requires:

* zero checkify errors (no NaN produced by any step primitive, no
  out-of-bounds gather/scatter index anywhere in the step);
* exactly ONE jit trace per runner across its whole sweep (a pytree
  leaf changing shape/dtype between configs would silently retrace and
  10x the sweep; the counter turns that into a hard failure);
* no truncated runs (the livelock guard firing on a known-good smoke
  point means the sanitized step diverged from the plain one).

CI runs this via ``python -m repro.analysis --sanitize-smoke``.
"""

from __future__ import annotations

from typing import Callable, List, Optional, Sequence

__all__ = ["sanitize_smoke"]

#: buffer fraction of the accessed set at the default smoke points
_BUFFER_FRAC = 0.4
_BANDWIDTH = 700e6


def _micro_point():
    from repro.core.workload import (
        make_lineitem_db, micro_accessed_bytes, micro_streams,
    )

    db = make_lineitem_db(scale_tuples=4_000_000)
    streams = micro_streams(db, n_streams=2, queries_per_stream=2, seed=3)
    return "micro", db, streams, _BUFFER_FRAC * micro_accessed_bytes(db)


def _tpch_point():
    from repro.core.workload import (
        make_tpch_db, tpch_accessed_bytes, tpch_streams,
    )

    db = make_tpch_db(scale=0.02)
    streams = tpch_streams(db, n_streams=2, seed=7)
    return ("tpch", db, streams,
            _BUFFER_FRAC * tpch_accessed_bytes(db, streams))


def sanitize_smoke(
    steppers: Sequence[str] = ("fixed", "horizon"),
    policies: Optional[Sequence[str]] = None,
    log: Optional[Callable[[str], None]] = print,
) -> List[str]:
    """Run the sanitized smoke sweep; returns a list of failure strings
    (empty = clean).  ``policies`` defaults to every registered array
    policy."""
    import jax

    from repro.core import policy_registry
    from repro.core.array_sim import (
        compile_workload, make_config, make_runner, result_from_state,
    )

    if policies is None:
        policies = policy_registry.names(backend="array")
    failures: List[str] = []
    for wl_name, db, streams, capacity in (_micro_point(), _tpch_point()):
        spec = compile_workload(db, streams)
        for stepper in steppers:
            runner = make_runner(spec, bandwidth_ref=_BANDWIDTH,
                                 stepper=stepper, sanitize=True)
            for pol in policies:
                cfg = make_config(spec, capacity, _BANDWIDTH, pol)
                tag = f"{wl_name}/{stepper}/{pol}"
                try:
                    state = jax.block_until_ready(runner(cfg))
                except Exception as exc:  # noqa: BLE001 — report, keep going
                    failures.append(f"{tag}: {type(exc).__name__}: {exc}")
                    continue
                res = result_from_state(state, pol,
                                        dt_ref=runner.dt_ref)
                if res.extras.get("truncated"):
                    failures.append(
                        f"{tag}: truncated "
                        f"({res.extras['unfinished_streams']} unfinished)")
                elif log is not None:
                    log(f"  {tag}: ok ({res.extras['steps']} steps, "
                        f"{res.total_io_bytes / 1e9:.2f} GB io)")
            traces = runner.trace_count()
            if traces != 1:
                failures.append(
                    f"{wl_name}/{stepper}: {traces} jit traces for one "
                    f"{len(policies)}-policy sweep (expected exactly 1)")
            elif log is not None:
                log(f"  {wl_name}/{stepper}: 1 trace across "
                    f"{len(policies)} policies")
    return failures
