"""Substrate contract checker: static analysis for the jit-purity,
deprecated-surface, and registry-coherence invariants (DESIGN.md
"substrate invariants").

The whole reproduction hangs off contracts nothing used to enforce
statically: ArrayPolicy hooks must be pure-jit pytree programs (PR 4),
steppers must stay bit-compatible and single-trace (PR 5), and every
registry capability must resolve on every backend it declares (PR 6).
This package checks them *before* a 48-point validation sweep has to
drift past its error bars:

* :mod:`repro.analysis.lint` — stdlib-``ast`` lint pass over
  ``src/repro`` (jit coercion / control flow / host calls in traced
  regions, resurrected deprecated surfaces);
* :mod:`repro.analysis.registry` — capability cross-check of every
  :class:`~repro.core.policy_registry.PolicyEntry` against the methods
  its factories' classes actually override;
* :mod:`repro.analysis.kernels` / :mod:`repro.analysis.absint` — the
  Pallas kernel contract verifier (DESIGN.md §9): AST rules over
  ``src/repro/kernels`` (oracle pairing, BlockSpec memory_space, MXU
  ``preferred_element_type``, the 2^24 float-mantissa key-cast rule)
  plus abstract interpretation of every ``pl.pallas_call`` site's
  grid/BlockSpec geometry (coverage, index bounds, write races, VMEM
  budget) — the static gate before the accelerator push;
* :mod:`repro.analysis.sanitize` — the runtime half: drives
  ``make_runner(sanitize=True)`` (checkify NaN/OOB + one-trace
  assertion) over the micro and TPC-H smoke points;
* ``python -m repro.analysis --check`` — the CI gate (exit 1 on any
  finding, ``--json`` writes the findings report artifact).
"""

from .findings import Finding
from .kernels import verify_kernels
from .lint import lint_paths, lint_source, repo_src_root
from .registry import check_registry

__all__ = [
    "Finding",
    "check_registry",
    "lint_paths",
    "lint_source",
    "repo_src_root",
    "run_checks",
    "verify_kernels",
]


def run_checks(root=None, registry: bool = True, kernels: bool = True,
               vmem_budget=None):
    """Run every static check; returns the combined finding list.

    ``kernels`` toggles the kernel contract verifier (both layers; the
    abstract-interpretation layer imports jax and runs the kernel
    wrappers under a recorder, so ``--no-kernels`` keeps a pure-AST
    mode available).  ``vmem_budget`` overrides the per-step VMEM
    byte budget the contract layer checks against."""
    findings = lint_paths(root)
    if registry:
        findings += check_registry()
    if kernels:
        findings += verify_kernels(root=root, vmem_budget=vmem_budget)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings
