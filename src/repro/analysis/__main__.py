"""CLI: ``python -m repro.analysis --check`` (the CI gate).

Exit status is the contract: 0 when the tree is clean, 1 with
``file:line: rule: message`` findings otherwise.  ``--json`` always
writes the findings report (empty list included) so CI can upload it as
an artifact next to the bench trend.  ``--sanitize-smoke`` runs the
runtime half (checkify + one-trace) over the micro/TPC-H smoke points.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from . import run_checks
from .sanitize import sanitize_smoke


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="substrate contract checker (DESIGN.md 'substrate "
                    "invariants')",
    )
    ap.add_argument("--check", action="store_true",
                    help="run the static checks (AST lint + registry "
                         "coherence); exit 1 on any finding")
    ap.add_argument("--root", default=None,
                    help="lint this tree instead of the installed "
                         "src/repro (a package dir named repro)")
    ap.add_argument("--json", dest="json_out", default=None, metavar="PATH",
                    help="write the findings report as JSON (CI artifact)")
    ap.add_argument("--no-registry", action="store_true",
                    help="skip the registry-coherence pass (pure AST mode; "
                         "no policy imports)")
    ap.add_argument("--no-kernels", action="store_true",
                    help="skip the Pallas kernel contract verifier (its "
                         "abstract-interpretation layer imports jax and "
                         "runs the kernel wrappers under a recorder)")
    ap.add_argument("--vmem-budget", type=int, default=None, metavar="BYTES",
                    help="per-step VMEM byte budget for the kernel "
                         "contract layer (default: 16 MiB, one TPU core)")
    ap.add_argument("--sanitize-smoke", action="store_true",
                    help="run make_runner(sanitize=True) over the micro + "
                         "TPC-H smoke points (checkify NaN/OOB + one-trace "
                         "assertion); exit 1 on any failure")
    args = ap.parse_args(argv)

    if not args.check and not args.sanitize_smoke:
        ap.error("nothing to do: pass --check and/or --sanitize-smoke")

    rc = 0
    findings = []
    if args.check:
        findings = run_checks(root=args.root, registry=not args.no_registry,
                              kernels=not args.no_kernels,
                              vmem_budget=args.vmem_budget)
        for f in findings:
            print(f.format())
        if findings:
            rc = 1
            print(f"repro.analysis: {len(findings)} finding(s)",
                  file=sys.stderr)
        else:
            print("repro.analysis: clean "
                  "(jit-purity + deprecated-surface + registry-coherence "
                  "+ kernel contracts)")

    if args.json_out is not None:
        out = Path(args.json_out)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(
            {"findings": [f.to_json() for f in findings],
             "count": len(findings)}, indent=2) + "\n")

    if args.sanitize_smoke:
        print("sanitize smoke (checkify nan/oob + one-trace):")
        failures = sanitize_smoke()
        for line in failures:
            print(f"FAIL {line}", file=sys.stderr)
        if failures:
            rc = 1
        else:
            print("sanitize smoke: clean")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
