"""AST lint pass over ``src/repro`` — the substrate's jit-purity contract.

The batched substrate only works because its traced regions are pure
pytree programs: no Python control flow on traced values, no
``float()``/``.item()`` materialisation mid-trace, no host library calls
(numpy / random / time) inside a jitted path.  These are exactly the
bugs that do NOT fail loudly — a ``float()`` on a traced scalar raises
only at trace time under some call patterns, a host call silently bakes
a trace-time constant into the compiled step, and Python ``if`` on a
traced bool raises a ConcretizationTypeError whose blast radius is a
48-point sweep later.  This pass finds them at lint time, with
file:line findings.

Traced regions (where the jit rules apply)
------------------------------------------
* every function in ``repro/kernels/*.py`` (the Pallas kernels and their
  dispatch wrappers run inside jit by construction);
* every function in ``repro/core/array_sim/policies.py`` and
  ``repro/core/array_sim/coop.py`` (policy hooks and the cooperative
  substrate are called from inside the traced step);
* every function in ``repro/obs/counters.py`` (the telemetry helpers
  accumulate counters inside the traced step — they must stay pure
  ``jnp``; the host-side summarisers carry ``# analysis: host``);
* the *nested* functions of ``make_step`` / ``make_runner`` in
  ``repro/core/array_sim/sim.py`` (the enclosing bodies are host-side
  step *builders*: their ``float()``/numpy use is trace-time constant
  folding and is allowed).

A ``# analysis: host`` comment on (or directly above) a ``def`` opts a
host-side helper out (e.g. ``coop.chunk_geometry``, the compiler-time
geometry builder); ``# analysis: traced`` opts extra functions in —
used for ``sim._u01`` / ``sim.init_state``, which are module-level but
called from inside the traced step.  Pragma names are validated: an
``# analysis:`` comment naming anything outside the vocabulary
(``host`` / ``traced`` / ``obs`` / ``revisit`` / ``oracle=<name>``) is
itself a finding (rule ``unknown-analysis-pragma``) — a typo'd opt-out
must fail the gate, not silently opt nothing out.

Host callbacks (rule ``jit-host-callback``)
-------------------------------------------
``jax.debug.print`` / ``jax.debug.callback`` / ``jax.debug.breakpoint``,
``jax.pure_callback``, ``io_callback`` and the legacy ``host_callback``
module are banned in traced regions outright — no taint analysis
needed, the call itself is the bug.  They look harmless (the program
still runs) but serialise vmapped lanes, block donated buffers and
perturb what XLA may fuse; per-step observability belongs in the
carry-threaded ``repro.obs`` counters instead (DESIGN.md §8).  A
deliberate debugging escape is spelled ``# analysis: obs`` on the
``def`` — it silences only this rule, the purity rules still apply.

Taint model
-----------
Function parameters are the traced roots (minus statics: ``self``,
``spec``, int/bool/str-annotated or -defaulted params, and — kernels
only — keyword-only params, the Pallas compile-time-knob idiom).
Attribute reads of ``.spec`` / ``.refresh`` cut taint (``StepCtx.spec``
is the static workload geometry and ``StepCtx.refresh`` the static
slice-boundary flag), as do shape-metadata attributes
(``.shape``/``.dtype``/``.ndim``/``.size`` — static under tracing).
Assignments propagate taint; values built as Python list/tuple/dict
literals or comprehensions are *containers* — iterating a Python list
of traced leaves is fine, iterating a traced array is not.

Deprecated surfaces (checked everywhere in ``src/repro``)
---------------------------------------------------------
* ``static_policy=`` call keyword — removed in PR 4 for the registry
  ``policies=(name,)`` spelling (the ``make_runner`` tombstone guard
  that raises on it is a parameter default, not a call, and stays);
* integer policy ids at call sites (``policy=3``) — policy names are
  the API, ids are a result-JSON contract owned by the registry;
* ``time_passed`` — renamed ``slices_done`` in PR 5 (the old name
  counted slices, not time; resurrecting it would miscount again).
"""

from __future__ import annotations

import ast
import io
import re
import tokenize
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .findings import Finding

__all__ = ["lint_paths", "lint_source", "repo_src_root"]

# ----------------------------------------------------------------- config --

#: parameters that are always static in traced regions
STATIC_PARAM_NAMES = {"self", "cls", "spec"}
#: attribute reads that cut taint: static workload geometry / flags —
#: ``.spec`` (SimSpec, static by construction), ``.refresh`` (the static
#: slice-boundary compile flag), ``.cooperative`` / ``.fifo_tie`` /
#: ``.name`` (static ArrayPolicy class knobs)
STATIC_CHAIN_ATTRS = {"spec", "refresh", "cooperative", "fifo_tie", "name"}
#: ... and shape metadata (static under tracing)
STATIC_META_ATTRS = {"shape", "dtype", "ndim", "size"}
#: host modules that must not be *called* inside a traced region
#: (attribute constants like ``np.inf`` / ``np.int32``-as-dtype are fine)
HOST_MODULES = {"np", "numpy", "random", "time", "_time"}
#: Python builtins that materialise a traced value
COERCIONS = {"float", "int", "bool"}
MATERIALIZERS = {"item", "tolist"}
#: builtins whose result is static structure inspection, not data
STATIC_INSPECTORS = {"isinstance", "hasattr", "len", "callable", "getattr"}
#: host-callback entry points banned in traced regions (rule
#: ``jit-host-callback``): matched against the call's dotted name, so
#: both ``jax.debug.print`` and a ``from jax import debug`` spelling hit
HOST_CALLBACK_NAMES = (
    "debug.print", "debug.callback", "debug.breakpoint",
    "pure_callback", "io_callback",
)

#: pragma grammar: the ``analysis:`` comment marker, a name, optional
#: trailing prose.  The name is matched exactly (word chars and ``=``,
#: so ``oracle=<name>`` is one token and surrounding backticks in prose
#: terminate it) — a typo'd name is a finding, not a silent no-op.
_PRAGMA_RE = re.compile(r"#\s*analysis:\s*([A-Za-z_][\w=]*)")
#: the full pragma vocabulary across the analysis package: lint's
#: region pragmas plus the kernel verifier's (``revisit`` sanctions an
#: output-block revisit in absint, ``oracle=<name>`` declares a ref.py
#: pairing in kernels)
PRAGMA_NAMES = {"host", "traced", "obs", "revisit"}
_PRAGMA_PREFIXES = ("oracle=",)


def repo_src_root() -> Path:
    """The ``src/repro`` package directory this module shipped in."""
    return Path(__file__).resolve().parent.parent


# ------------------------------------------------------- file classifiers --

def _norm(rel: str) -> str:
    return rel.replace("\\", "/")


def _file_kind(rel: str) -> str:
    """"kernels" | "traced" | "sim" | "host" for a repo-relative path."""
    rel = _norm(rel)
    if "/kernels/" in rel or rel.startswith("kernels/"):
        return "kernels"
    if rel.endswith(("core/array_sim/policies.py", "core/array_sim/coop.py",
                     "obs/counters.py")):
        return "traced"
    if rel.endswith("core/array_sim/sim.py"):
        return "sim"
    return "host"


def _pragma(src_lines: Sequence[str], node: ast.AST) -> Optional[str]:
    """The ``# analysis:`` pragma on the def line or the line above.

    Returns the pragma name only when it is one of lint's region pragmas
    (``host`` / ``traced`` / ``obs``) — a kernel-verifier pragma on the
    same def (``revisit``, ``oracle=``) is someone else's and must not
    leak a region classification here."""
    for ln in (node.lineno - 1, node.lineno - 2):
        if 0 <= ln < len(src_lines):
            m = _PRAGMA_RE.search(src_lines[ln])
            if m and m.group(1) in ("host", "traced", "obs"):
                return m.group(1)
    return None


def _check_pragmas(source: str, rel: str, findings: List[Finding]) -> None:
    """Rule ``unknown-analysis-pragma``: every ``# analysis:`` comment
    must name a known pragma.  Scanned over COMMENT tokens (docstring
    *mentions* of the spelling are strings and never match), so a typo'd
    opt-out — ``# analysis: hosted`` — fails the gate instead of
    silently opting nothing out."""
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(source).readline))
    except (tokenize.TokenError, IndentationError, SyntaxError):
        return  # the ast pass already reported the syntax error
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        m = _PRAGMA_RE.search(tok.string)
        if m is None:
            continue
        name = m.group(1)
        if name in PRAGMA_NAMES or name.startswith(_PRAGMA_PREFIXES):
            continue
        findings.append(Finding(
            rule="unknown-analysis-pragma", path=rel, line=tok.start[0],
            col=tok.start[1],
            message=f"unknown `# analysis: {name}` pragma — known names: "
                    f"{sorted(PRAGMA_NAMES)} plus `oracle=<name>`; a typo "
                    "here silently opts nothing out",
        ))


# ----------------------------------------------------------- taint engine --

class _Scope:
    """Name -> (tainted, container) for one traced function body."""

    def __init__(self, parent: Optional["_Scope"] = None):
        self.tainted: Dict[str, bool] = dict(parent.tainted) if parent else {}
        self.container: Set[str] = set(parent.container) if parent else set()

    def set(self, name: str, tainted: bool, container: bool = False) -> None:
        self.tainted[name] = tainted
        if container:
            self.container.add(name)
        else:
            self.container.discard(name)

    def is_tainted(self, name: str) -> bool:
        return self.tainted.get(name, False)

    def is_container(self, name: str) -> bool:
        return name in self.container


def _static_params(fn: ast.FunctionDef, kind: str) -> Set[str]:
    """Parameter names treated as static (trace-time constants)."""
    static: Set[str] = set()
    args = fn.args
    pos = list(args.posonlyargs) + list(args.args)
    defaults = list(args.defaults)
    # align defaults with the tail of the positional params
    pad = [None] * (len(pos) - len(defaults))
    for a, d in zip(pos, pad + defaults):
        if a.arg in STATIC_PARAM_NAMES:
            static.add(a.arg)
        elif _static_annotation(a.annotation):
            static.add(a.arg)
        elif _static_default(d):
            static.add(a.arg)
    for a, d in zip(args.kwonlyargs, args.kw_defaults):
        if (
            kind == "kernels"          # Pallas idiom: kwonly = compile-time
            or a.arg in STATIC_PARAM_NAMES
            or _static_annotation(a.annotation)
            or _static_default(d)
        ):
            static.add(a.arg)
    return static


def _static_annotation(ann: Optional[ast.expr]) -> bool:
    return isinstance(ann, ast.Name) and ann.id in ("int", "bool", "str")


def _static_default(d: Optional[ast.expr]) -> bool:
    return (
        isinstance(d, ast.Constant)
        and d.value is not None
        and isinstance(d.value, (int, bool, str))
        and not isinstance(d.value, float)
    )


def _is_host_module_call(func: ast.expr) -> Optional[str]:
    """Dotted-call root if it is a host module (``np.median`` -> "np")."""
    node = func
    while isinstance(node, ast.Attribute):
        node = node.value
    if isinstance(node, ast.Name) and node.id in HOST_MODULES:
        return node.id
    return None


def _dotted_name(func: ast.expr) -> Optional[str]:
    """Full dotted call name (``jax.debug.print``), or None if the root
    is not a plain name."""
    parts: List[str] = []
    node = func
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_host_callback(name: str) -> bool:
    if "host_callback" in name.split("."):
        return True
    return any(name == s or name.endswith("." + s)
               for s in HOST_CALLBACK_NAMES)


class _TracedChecker(ast.NodeVisitor):
    """Walks ONE traced function body, tracking taint per name."""

    def __init__(self, rel: str, kind: str, findings: List[Finding],
                 scope: _Scope, src_lines: Sequence[str] = (),
                 allow_callbacks: bool = False):
        self.rel = rel
        self.kind = kind
        self.findings = findings
        self.scope = scope
        self.src_lines = src_lines
        self.allow_callbacks = allow_callbacks

    # ------------------------------------------------------------ helpers --
    def _emit(self, rule: str, node: ast.AST, message: str) -> None:
        self.findings.append(Finding(
            rule=rule, path=self.rel, line=node.lineno,
            col=node.col_offset, message=message,
        ))

    def tainted(self, node: Optional[ast.expr]) -> bool:
        """Does this expression carry traced data?"""
        if node is None:
            return False
        if isinstance(node, ast.Name):
            return self.scope.is_tainted(node.id)
        if isinstance(node, ast.Attribute):
            if node.attr in STATIC_CHAIN_ATTRS or node.attr in STATIC_META_ATTRS:
                return False
            return self.tainted(node.value)
        if isinstance(node, ast.Subscript):
            return self.tainted(node.value) or self.tainted(node.slice)
        if isinstance(node, ast.Call):
            return (
                self.tainted(node.func)
                or any(self.tainted(a) for a in node.args)
                or any(self.tainted(k.value) for k in node.keywords)
            )
        if isinstance(node, ast.Constant):
            return False
        if isinstance(node, ast.Lambda):
            return False
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp)):
            return any(self.tainted(g.iter) for g in node.generators) \
                or self.tainted(node.elt)
        if isinstance(node, ast.DictComp):
            return any(self.tainted(g.iter) for g in node.generators) \
                or self.tainted(node.key) or self.tainted(node.value)
        return any(self.tainted(c) for c in ast.iter_child_nodes(node)
                   if isinstance(c, ast.expr))

    def container(self, node: Optional[ast.expr]) -> bool:
        """Is this expression a *Python* container (list/tuple/dict), so
        that iterating it is host-side structure, not a traced array?"""
        if isinstance(node, (ast.List, ast.Tuple, ast.Set, ast.Dict,
                             ast.ListComp, ast.SetComp, ast.DictComp,
                             ast.GeneratorExp)):
            return True
        if isinstance(node, ast.Name):
            return self.scope.is_container(node.id)
        if isinstance(node, ast.IfExp):
            return self.container(node.body) or self.container(node.orelse)
        if isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Name) and f.id in (
                    "list", "tuple", "dict", "zip", "enumerate", "range",
                    "sorted", "reversed", "map", "filter"):
                return True
        return False

    def _dynamic_test(self, test: ast.expr) -> bool:
        """Does a branch test depend on traced data?  ``is``/``is not``
        comparisons are static structure checks (the ``x is None``
        idiom) and never count; ``any()``/``all()`` over a Python
        container of traced leaves count only if their element test
        does."""
        if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
            return self._dynamic_test(test.operand)
        if isinstance(test, ast.BoolOp):
            return any(self._dynamic_test(v) for v in test.values)
        if isinstance(test, ast.Compare) and all(
                isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops):
            return False
        if isinstance(test, ast.Call):
            f = test.func
            if isinstance(f, ast.Name):
                if f.id in STATIC_INSPECTORS:
                    return False
                if f.id in ("any", "all") and len(test.args) == 1:
                    arg = test.args[0]
                    if isinstance(arg, ast.GeneratorExp):
                        # iterating a traced array is dynamic regardless
                        for g in arg.generators:
                            if self.tainted(g.iter) \
                                    and not self.container(g.iter):
                                return True
                        return self._dynamic_test(arg.elt)
        return self.tainted(test)

    def _bind_target(self, target: ast.expr, tainted: bool,
                     container: bool) -> None:
        if isinstance(target, ast.Name):
            self.scope.set(target.id, tainted, container)
        elif isinstance(target, ast.Starred):
            self._bind_target(target.value, tainted, container=True)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                self._bind_target(el, tainted, container=False)
        # attribute/subscript targets: no name to bind

    # --------------------------------------------------------- statements --
    def visit_Assign(self, node: ast.Assign) -> None:
        self.generic_visit(node)        # rule sites inside the value first
        t = self.tainted(node.value)
        c = self.container(node.value)
        for target in node.targets:
            if (isinstance(target, (ast.Tuple, ast.List))
                    and isinstance(node.value, (ast.Tuple, ast.List))
                    and len(target.elts) == len(node.value.elts)):
                for el, val in zip(target.elts, node.value.elts):
                    self._bind_target(el, self.tainted(val),
                                      self.container(val))
            else:
                self._bind_target(target, t, c)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        self.generic_visit(node)
        if node.value is not None:
            self._bind_target(node.target, self.tainted(node.value),
                              self.container(node.value))

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self.generic_visit(node)
        if isinstance(node.target, ast.Name):
            t = (self.scope.is_tainted(node.target.id)
                 or self.tainted(node.value))
            self.scope.set(node.target.id, t,
                           self.scope.is_container(node.target.id))

    def visit_If(self, node: ast.If) -> None:
        if self._dynamic_test(node.test):
            self._emit(
                "jit-control-flow", node,
                "Python `if` on a traced value inside a jitted region "
                "(use jnp.where / lax.cond; `ctx.refresh` and other "
                "static closure flags MAY branch)",
            )
        self.generic_visit(node)

    def visit_While(self, node: ast.While) -> None:
        if self._dynamic_test(node.test):
            self._emit(
                "jit-control-flow", node,
                "Python `while` on a traced value inside a jitted region "
                "(use jax.lax.while_loop)",
            )
        self.generic_visit(node)

    def _check_loop_iter(self, node: ast.AST, it: ast.expr) -> None:
        bare = isinstance(it, (ast.Name, ast.Attribute, ast.Subscript))
        if bare and self.tainted(it) and not self.container(it):
            self._emit(
                "jit-control-flow", node,
                "Python `for` over a traced array inside a jitted region "
                "(use jax.lax.fori_loop / scan, or keep the iterable a "
                "static Python sequence)",
            )
        elif isinstance(it, ast.Call) and isinstance(it.func, ast.Name) \
                and it.func.id == "range" \
                and any(self.tainted(a) for a in it.args):
            self._emit(
                "jit-control-flow", node,
                "`range()` over a traced length inside a jitted region "
                "(lengths must be static: shapes, closure ints)",
            )

    def visit_For(self, node: ast.For) -> None:
        self._check_loop_iter(node, node.iter)
        self._bind_target(node.target, self.tainted(node.iter), False)
        self.generic_visit(node)

    def visit_comprehension(self, node: ast.comprehension) -> None:
        self._check_loop_iter(node.iter, node.iter)
        self._bind_target(node.target, self.tainted(node.iter), False)
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        func = node.func
        if isinstance(func, ast.Name) and func.id in COERCIONS:
            if any(self.tainted(a) for a in node.args):
                self._emit(
                    "jit-coercion", node,
                    f"`{func.id}()` on a traced value inside a jitted "
                    "region (materialises at trace time; keep it an "
                    "array, or derive the scalar from static geometry)",
                )
        elif isinstance(func, ast.Attribute) and func.attr in MATERIALIZERS:
            if self.tainted(func.value):
                self._emit(
                    "jit-coercion", node,
                    f"`.{func.attr}()` on a traced value inside a jitted "
                    "region (host materialisation breaks the pure-pytree "
                    "step contract)",
                )
        root = _is_host_module_call(func)
        if root is not None:
            self._emit(
                "jit-host-call", node,
                f"`{ast.unparse(func)}()` call inside a jitted region "
                f"({root} runs on host at trace time: the result is baked "
                "in as a constant — use jnp, or hoist to the static "
                "step-builder body)",
            )
        dotted = _dotted_name(func)
        if dotted is not None and not self.allow_callbacks \
                and _is_host_callback(dotted):
            self._emit(
                "jit-host-callback", node,
                f"`{dotted}()` inside a jitted region: host callbacks "
                "serialise vmapped lanes and block buffer donation — "
                "thread a counter through the step carry instead "
                "(repro.obs, DESIGN.md §8), or mark a deliberate "
                "debugging escape with `# analysis: obs`",
            )
        self.generic_visit(node)

    # nested defs inherit the enclosing taint environment (and may carry
    # their own pragma: `# analysis: obs` scopes the callback escape to
    # exactly one nested def)
    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        prag = _pragma(self.src_lines, node)
        if prag == "host":
            return
        _check_traced_function(
            node, self.rel, self.kind, self.findings, parent=self.scope,
            src_lines=self.src_lines,
            allow_callbacks=self.allow_callbacks or prag == "obs",
        )

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Lambda(self, node: ast.Lambda) -> None:
        scope = _Scope(self.scope)
        for a in node.args.args + node.args.kwonlyargs:
            scope.set(a.arg, True)
        sub = _TracedChecker(self.rel, self.kind, self.findings, scope,
                             self.src_lines, self.allow_callbacks)
        sub.visit(node.body)


def _check_traced_function(fn: ast.FunctionDef, rel: str, kind: str,
                           findings: List[Finding],
                           parent: Optional[_Scope] = None,
                           src_lines: Sequence[str] = (),
                           allow_callbacks: bool = False) -> None:
    scope = _Scope(parent)
    static = _static_params(fn, kind)
    args = fn.args
    for a in (list(args.posonlyargs) + list(args.args)
              + list(args.kwonlyargs)):
        scope.set(a.arg, a.arg not in static)
    if args.vararg is not None:
        scope.set(args.vararg.arg, True, container=True)
    if args.kwarg is not None:
        scope.set(args.kwarg.arg, True, container=True)
    checker = _TracedChecker(rel, kind, findings, scope, src_lines,
                             allow_callbacks)
    for stmt in fn.body:
        checker.visit(stmt)


# --------------------------------------------------- deprecated surfaces --

class _DeprecatedChecker(ast.NodeVisitor):
    """Whole-file rules: resurrected pre-registry / pre-PR-5 surfaces."""

    def __init__(self, rel: str, findings: List[Finding]):
        self.rel = rel
        self.findings = findings

    def _emit(self, rule: str, node: ast.AST, message: str) -> None:
        self.findings.append(Finding(
            rule=rule, path=self.rel, line=node.lineno,
            col=node.col_offset, message=message,
        ))

    def visit_Call(self, node: ast.Call) -> None:
        for kw in node.keywords:
            if kw.arg == "static_policy":
                self._emit(
                    "deprecated-static-policy", kw.value,
                    "`static_policy=` was removed in PR 4; pass "
                    "`policies=(name,)` resolved through "
                    "repro.core.policy_registry",
                )
            elif kw.arg == "policy" and isinstance(kw.value, ast.Constant) \
                    and type(kw.value.value) is int:
                self._emit(
                    "deprecated-int-policy-id", kw.value,
                    "integer policy id at a call site; policy *names* are "
                    "the API — ids are a registry-owned result-JSON "
                    "contract (policy_registry.array_ids)",
                )
            elif kw.arg == "policies" and isinstance(
                    kw.value, (ast.Tuple, ast.List)) and any(
                    isinstance(el, ast.Constant) and type(el.value) is int
                    for el in kw.value.elts):
                self._emit(
                    "deprecated-int-policy-id", kw.value,
                    "integer policy ids in a `policies=` call keyword; "
                    "pass registry names",
                )
            if kw.arg == "time_passed":
                self._emit(
                    "deprecated-time-passed", kw.value,
                    "`time_passed` was renamed `slices_done` in PR 5",
                )
        self.generic_visit(node)

    def _check_name(self, node: ast.AST, name: str) -> None:
        if name == "time_passed":
            self._emit(
                "deprecated-time-passed", node,
                "`time_passed` was renamed `slices_done` in PR 5 (it "
                "counted slices, never time; the old name must not read)",
            )

    def visit_Name(self, node: ast.Name) -> None:
        self._check_name(node, node.id)

    def visit_Attribute(self, node: ast.Attribute) -> None:
        self._check_name(node, node.attr)
        self.generic_visit(node)

    def visit_arg(self, node: ast.arg) -> None:
        self._check_name(node, node.arg)


# -------------------------------------------------------------- file pass --

#: ``sim.py`` step/runner builders whose *nested* defs are the traced step
_SIM_BUILDERS = {"make_step", "make_runner"}


def _walk_defs(body: Sequence[ast.stmt]):
    """Top-level and class-level defs of a module body (not nested ones —
    those belong to their enclosing traced region)."""
    for node in body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node
        elif isinstance(node, ast.ClassDef):
            for sub in node.body:
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    yield sub


def lint_source(source: str, rel: str) -> List[Finding]:
    """Lint one file's source; ``rel`` is its repo-relative path (used to
    classify traced regions, so virtual paths work for tests)."""
    findings: List[Finding] = []
    try:
        tree = ast.parse(source, filename=rel)
    except SyntaxError as exc:
        findings.append(Finding(
            rule="syntax-error", path=rel, line=exc.lineno or 1,
            message=str(exc.msg),
        ))
        return findings
    src_lines = source.splitlines()
    _check_pragmas(source, rel, findings)
    _DeprecatedChecker(rel, findings).visit(tree)

    kind = _file_kind(rel)
    if kind in ("kernels", "traced"):
        for fn in _walk_defs(tree.body):
            prag = _pragma(src_lines, fn)
            if prag != "host":
                _check_traced_function(
                    fn, rel, kind, findings, src_lines=src_lines,
                    allow_callbacks=(prag == "obs"))
    elif kind == "sim":
        for fn in _walk_defs(tree.body):
            prag = _pragma(src_lines, fn)
            if prag == "traced":
                _check_traced_function(fn, rel, kind, findings,
                                       src_lines=src_lines)
            elif fn.name in _SIM_BUILDERS:
                for sub in fn.body:
                    if isinstance(sub, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
                        sp = _pragma(src_lines, sub)
                        if sp == "host":
                            continue
                        _check_traced_function(
                            sub, rel, kind, findings, src_lines=src_lines,
                            allow_callbacks=(sp == "obs"))
    return findings


def lint_paths(root=None) -> List[Finding]:
    """Lint every ``*.py`` under ``root`` (default: the installed
    ``src/repro`` tree)."""
    root = Path(root) if root is not None else repo_src_root()
    findings: List[Finding] = []
    for path in sorted(root.rglob("*.py")):
        try:
            rel = str(path.relative_to(root.parent))
        except ValueError:
            rel = str(path)
        findings += lint_source(path.read_text(encoding="utf-8"), rel)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings
