"""Registry-coherence rule: declared capabilities vs implemented methods.

A :class:`~repro.core.policy_registry.PolicyEntry` *declares* backends
(event / array / serving) through which factories it carries; nothing
used to check that the object a factory builds actually *implements*
that backend's decision method.  A capability without an implementation
is then a runtime ``NotImplementedError`` in the middle of a sweep (or,
worse, a silently-inherited base-class default).  This pass makes it a
lint finding instead:

* ``event``   — the policy must override ``Policy.choose_victims``
  (cooperative entries are exempt: the engine drives the ABM itself);
* ``array``   — the policy must be an ``ArrayPolicy`` overriding
  ``score_victims``, carry the entry's ``name``, and have an
  ``array_id``;
* ``serving`` — the policy must override ``ServingPolicy.victim_key``
  and carry the entry's ``name``.

Findings point at the ``register(PolicyEntry(...))`` call site in
``policy_registry.py`` where one exists, else at the factory's class.
"""

from __future__ import annotations

import ast
import contextlib
import inspect
from typing import Dict, List, Optional

from .findings import Finding

__all__ = ["check_registry"]

_RULE = "registry-coherence"


def _entry_lines() -> Dict[str, int]:
    """name -> line of its ``register(PolicyEntry(name=...))`` call."""
    from repro.core import policy_registry as reg

    out: Dict[str, int] = {}
    try:
        tree = ast.parse(inspect.getsource(reg))
    except (OSError, TypeError):
        return out
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Name)
                and node.func.id == "register"):
            continue
        for arg in node.args:
            if isinstance(arg, ast.Call):
                for kw in arg.keywords:
                    if kw.arg == "name" and isinstance(kw.value, ast.Constant):
                        out[kw.value.value] = node.lineno
    return out


def _registry_path() -> str:
    from repro.core import policy_registry as reg

    path = getattr(reg, "__file__", "policy_registry.py") or "?"
    marker = "src/"
    return path[path.index(marker):] if marker in path else path


def _overrides(obj: object, base: type, method: str) -> bool:
    impl = getattr(type(obj), method, None)
    return impl is not None and impl is not getattr(base, method)


def check_registry(registry: Optional[dict] = None) -> List[Finding]:
    """Cross-check every entry's declared backends against what its
    factories build.  ``registry`` (name -> PolicyEntry) defaults to the
    live :mod:`repro.core.policy_registry` table — tests pass a copy with
    a broken entry to exercise the negative direction."""
    from repro.core import policy_registry as reg

    entries = dict(reg._REGISTRY) if registry is None else dict(registry)
    lines = _entry_lines()
    path = _registry_path()
    findings: List[Finding] = []

    def emit(entry, message: str, obj: object = None) -> None:
        line = lines.get(entry.name, 0)
        loc = path
        if line == 0 and obj is not None:
            # dynamically-registered entry: point at the implementing class
            with contextlib.suppress(OSError, TypeError):
                loc = inspect.getsourcefile(type(obj)) or path
                line = inspect.getsourcelines(type(obj))[1]
        findings.append(Finding(rule=_RULE, path=loc, line=line,
                                message=f"policy {entry.name!r}: {message}"))

    for entry in entries.values():
        if not entry.backends:
            emit(entry, "declares no backend at all")
        if entry.cooperative and "event" not in entry.backends:
            emit(entry, "cooperative flag set but the event backend is "
                        "not declared (the ABM runs in the event engine)")

        if "event" in entry.backends and not entry.cooperative:
            from repro.core.policies.base import Policy as EventPolicy

            obj = _build(entry, "event_factory", emit, _event_config())
            if obj is not None and not _overrides(
                    obj, EventPolicy, "choose_victims"):
                emit(entry, "declares the event backend but "
                     f"{type(obj).__name__} does not override "
                     "Policy.choose_victims", obj)

        if "array" in entry.backends:
            from repro.core.array_sim.policies import ArrayPolicy

            obj = _build(entry, "array_factory", emit)
            if obj is not None:
                if not isinstance(obj, ArrayPolicy):
                    emit(entry, "array_factory returned "
                         f"{type(obj).__name__}, not an ArrayPolicy", obj)
                elif not _overrides(obj, ArrayPolicy, "score_victims"):
                    emit(entry, "declares the array backend but "
                         f"{type(obj).__name__} does not override "
                         "ArrayPolicy.score_victims", obj)
                elif getattr(obj, "name", None) != entry.name:
                    emit(entry, "array policy reports name "
                         f"{getattr(obj, 'name', None)!r} (result rows "
                         "would be mislabeled)", obj)
            if entry.array_id is None:
                emit(entry, "array backend without an array_id (stacked "
                            "configs cannot encode the lane)")

        if "serving" in entry.backends:
            from repro.serving.policy_driver import ServingPolicy

            obj = _build(entry, "serving_factory", emit)
            if obj is not None:
                if not _overrides(obj, ServingPolicy, "victim_key"):
                    emit(entry, "declares the serving backend but "
                         f"{type(obj).__name__} does not override "
                         "ServingPolicy.victim_key", obj)
                elif getattr(obj, "name", None) != entry.name:
                    emit(entry, "serving policy reports name "
                         f"{getattr(obj, 'name', None)!r}", obj)

    findings.sort(key=lambda f: (f.path, f.line))
    return findings


def _event_config():
    from repro.core.engine import EngineConfig

    return EngineConfig()


def _build(entry, factory_name: str, emit, *args):
    factory = getattr(entry, factory_name)
    if factory is None:
        # backends is derived from the factories, so this only happens on
        # a hand-built (test) entry claiming a capability it cannot build
        emit(entry, f"declares a backend but {factory_name} is None")
        return None
    try:
        return factory(*args)
    except NotImplementedError:
        emit(entry, f"{factory_name} itself raises NotImplementedError")
    except Exception as exc:  # noqa: BLE001 — any factory crash is a finding
        emit(entry, f"{factory_name} raised {type(exc).__name__}: {exc}")
    return None
