"""repro: Predictive Buffer Management (VLDB'12) as a first-class feature of
a multi-pod JAX training/serving framework. See DESIGN.md."""

__version__ = "1.0.0"
