"""Production meshes.

Single pod: TPU v5e 16x16 = 256 chips, axes ("data", "model").
Multi-pod:  2 pods x 256 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis carries only data parallelism + gradient all-reduce (DCN-friendly:
no model-sharded collective ever crosses the pod boundary).

A FUNCTION (not a module constant) so importing never touches jax device
state; the dry-run forces 512 host devices *before* calling this.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1, data: Optional[int] = None):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if data is None:
        data = n // model
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_chip_count(mesh) -> int:
    import math

    return math.prod(mesh.devices.shape)
