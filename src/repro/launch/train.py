"""End-to-end training driver.

CPU demo (any arch, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2_1_5b --smoke \
      --steps 50 --batch 8 --seq 256

Production shape (on a pod; on CPU use --dry-run to lower+compile only):
  python -m repro.launch.train --arch deepseek_67b --shape train_4k

Features wired here: PBM-cached multi-stream data pipeline, jitted
train_step (grad accum, remat per config), checkpoint save/restore (+exact
data-position resume), failure injection + elastic re-mesh demo.
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.data import DataStream, DatasetSpec, HostPageCache, MultiStreamLoader
from repro.launch.inputs import cell_shardings
from repro.launch.mesh import make_local_mesh
from repro.models import abstract_params, build_model, init_params
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import OptimizerConfig, init_opt_state
from repro.train.train_step import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2_1_5b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--cache-policy", choices=["lru", "pbm", "opt"], default="pbm")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.family in ("vlm", "audio"):
        print(f"note: {args.arch} uses a stub frontend; training on text side")
    model = build_model(cfg)
    mesh = make_local_mesh(model=1)
    print(f"arch={cfg.name} params~{cfg.param_count()/1e6:.1f}M mesh={mesh.shape}")

    # ---- data pipeline (PBM host cache) -----------------------------------
    spec = DatasetSpec(n_shards=8, pages_per_shard=32, vocab_size=cfg.vocab_size)
    cache = HostPageCache(spec, capacity_pages=64, policy=args.cache_policy)
    loader = MultiStreamLoader(cache)
    train_stream = DataStream(cache, list(range(spec.n_shards)), args.batch,
                              args.seq + 1, name="train")
    loader.add_stream(train_stream)

    # ---- params / optimizer ------------------------------------------------
    rng = jax.random.PRNGKey(args.seed)
    params = init_params(model.param_specs, rng, jnp.float32)
    opt_cfg = OptimizerConfig(learning_rate=args.lr, warmup_steps=20,
                              total_steps=args.steps)
    opt_state = init_opt_state(params)
    step0 = 0

    ckpt = CheckpointManager(args.checkpoint_dir) if args.checkpoint_dir else None
    if ckpt and args.resume and ckpt.latest_step() is not None:
        step0, params, opt_state, extra = ckpt.restore(None, params, opt_state)
        if "data" in extra:
            train_stream.load_state_dict(extra["data"])
        print(f"resumed from step {step0}")

    train_step = jax.jit(
        make_train_step(model, opt_cfg, microbatches=args.microbatches),
        donate_argnums=(0, 1),
    )

    # ---- loop --------------------------------------------------------------
    losses = []
    t_start = time.time()
    for step in range(step0, args.steps):
        toks = loader.next_round()["train"]
        batch = _make_batch(cfg, toks, args.seq)
        params, opt_state, metrics = train_step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t_start
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"cache miss/hit {cache.miss_pages}/{cache.hit_pages} "
                  f"({dt:.1f}s)")
        if ckpt and (step + 1) % args.checkpoint_every == 0:
            ckpt.save(step + 1, params, opt_state,
                      extra={"data": train_stream.state_dict()}, async_=True)
    if ckpt:
        ckpt.wait()
    first, last = losses[0], np.mean(losses[-5:])
    print(f"done: loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")


def _make_batch(cfg, toks: np.ndarray, seq: int):
    tokens = jnp.asarray(toks[:, : seq + 1][:, :-1] % cfg.padded_vocab, jnp.int32)
    if cfg.family == "vlm":
        b = tokens.shape[0]
        p = cfg.frontend_tokens
        return {
            "tokens": tokens[:, : max(8, seq - p)],
            "patch_embeds": jnp.zeros((b, p, cfg.d_model), jnp.float32),
        }
    if cfg.is_encdec:
        b = tokens.shape[0]
        return {
            "src_embeds": jnp.zeros((b, seq, cfg.d_model), jnp.float32),
            "tgt_tokens": tokens,
        }
    return {"tokens": tokens}


if __name__ == "__main__":
    main()
