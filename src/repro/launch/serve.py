"""Serving driver: batched requests through the paged-KV engine.

  PYTHONPATH=src python -m repro.launch.serve --requests 16 --policy pbm

Runs the continuous-batching engine over an oversubscribed HBM page pool
with a shared system prompt; ``--real-model`` decodes through the Pallas
paged-attention kernel (interpret mode on CPU) instead of the fast stub.
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.serving import PagePool, Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--pool-pages", type=int, default=48)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=8)
    from repro.core import policy_registry
    ap.add_argument("--policy", default="pbm",
                    choices=policy_registry.names(backend="serving"))
    ap.add_argument("--prefix-len", type=int, default=64)
    ap.add_argument("--real-model", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    rng = np.random.default_rng(args.seed)
    if args.real_model:
        from repro.kernels import ops
        from repro.serving.model import PagedTinyLM, TinyConfig

        ops.set_backend("interpret")
        cfg = TinyConfig(n_pages=args.pool_pages, page_size=args.page_size)
        lm = PagedTinyLM(cfg, seed=args.seed)
        step_fn = lm.step_fn
        page_bytes = args.page_size * cfg.n_kv_heads * cfg.head_dim * 2 * 2
    else:
        step_fn = lambda reqs: [int((r.kv.length * 0x9E3779B1) % 50000)
                                for r in reqs]
        page_bytes = args.page_size * 8 * 128 * 2 * 2

    pool = PagePool(args.pool_pages, args.page_size, page_bytes)
    eng = ServingEngine(pool, step_fn, policy=args.policy,
                        max_batch=args.max_batch)
    prefix = list(rng.integers(0, 100, args.prefix_len))
    for _ in range(args.requests):
        eng.submit(Request(
            prompt=prefix + list(rng.integers(0, 100, 8)),
            max_new_tokens=int(rng.integers(16, 96)),
        ))
    st = eng.run_to_completion(max_steps=50_000)
    print(f"policy={args.policy} served={len(eng.finished)} steps={st.steps} "
          f"tokens={st.tokens_generated} tok/step={st.tokens_generated/max(1,st.steps):.2f}")
    print(f"prefix_shared_pages={st.shared_prefix_pages} "
          f"preemptions={st.preemptions} "
          f"swap={(st.swap_out_bytes + st.swap_in_bytes)/1e6:.2f}MB")


if __name__ == "__main__":
    main()
