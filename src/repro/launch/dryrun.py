import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST run before any jax import/init: jax locks the device count on first
#   use.  The dry-run (and only the dry-run) builds 512 placeholder host
#   devices so the production meshes are real Mesh objects.

"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell.

For each cell this builds the *real* jitted step (train_step with AdamW for
train shapes; serve_step against a full-length KV/state cache for decode
shapes; prefill forward for prefill shapes) from abstract inputs only —
no parameter or cache is ever allocated — and records:

* compiled.memory_analysis()  -> bytes/device (proves the cell fits/placement)
* compiled.cost_analysis()    -> HLO FLOPs & bytes for the roofline terms
* collective byte counts parsed from the optimized HLO (all-gather,
  all-reduce, reduce-scatter, all-to-all, collective-permute)

Artifacts: experiments/dryrun/<arch>__<shape>__<mesh>.json (read by
benchmarks/roofline.py and EXPERIMENTS.md).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek_67b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, cell_is_skipped, get_config
from repro.models import abstract_params, build_model
from repro.models.transformer import Model
from repro.launch.mesh import make_production_mesh, mesh_chip_count
from repro.launch.inputs import (
    batch_shardings,
    cache_shardings,
    cell_mode,
    cell_shardings,
    input_specs,
)
from repro.train.optimizer import (
    OptimizerConfig,
    abstract_opt_state,
    opt_state_shardings,
)
from repro.train.train_step import make_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

# HLO collective ops we account under the "collective" roofline term
_COLL_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*(\S+)\s+(all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)"
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}


def _shape_bytes(sig: str) -> int:
    m = _SHAPE_RE.match(sig.strip().lstrip("("))
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes of every collective op in the HLO, by kind."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        _, sig, kind = m.groups()
        nbytes = 0
        if sig.startswith("("):
            for part in re.findall(r"[a-z0-9]+\[[0-9,]*\]", sig):
                nbytes += _shape_bytes(part)
        else:
            nbytes = _shape_bytes(sig)
        out[kind] = out.get(kind, 0) + nbytes
    return out


def _scan_trip_counts(hlo_text: str):
    """Best-effort: while-loop trip counts so per-iteration collectives can
    be scaled to full-step volumes (XLA reports the loop body once)."""
    counts = []
    for m in re.finditer(r"trip_count=(\d+)", hlo_text):
        counts.append(int(m.group(1)))
    return counts


def probe_configs(cfg):
    """Two reduced-depth clones of ``cfg`` (same family constraints).

    XLA's cost_analysis counts a scan body ONCE regardless of trip count, so
    per-step FLOPs/bytes/collectives are recovered by compiling the same cell
    at depths L1 < L2 and extrapolating linearly to the real depth
    (benchmarks/roofline.py does the fit).
    """
    import dataclasses as dc

    if cfg.local_global_ratio > 0:
        base = cfg.local_global_ratio + 1
    elif cfg.family == "hybrid":
        base = max(1, cfg.attn_every)
    elif cfg.family == "ssm":
        base = max(2, cfg.xlstm_slstm_every)
    else:
        base = 2
    out = []
    for L in (base, 2 * base):
        kw = {"n_layers": L}
        if cfg.is_encdec:
            kw["encoder_layers"] = L
        out.append((dc.replace(cfg, **kw), L))
    return out


def _lower_cell(cfg, shape, mesh):
    """Build + lower the jitted step for one cell. Returns lowered."""
    from repro.configs.base import mesh_rules
    from repro.models import shardctx

    mode = cell_mode(cfg, shape)
    rules = mesh_rules(mode, mesh.axis_names)
    shardctx.set_batch_axes(rules["batch"])
    model = build_model(cfg)
    params_abs = abstract_params(model.param_specs, jnp.bfloat16)
    batch_abs = input_specs(cfg, shape)

    def named(tree, specs):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)

    if shape.kind == "train":
        p_specs, b_specs, _ = cell_shardings(cfg, shape, model, mesh)
        opt_abs = abstract_opt_state(params_abs)
        o_specs = opt_state_shardings(p_specs)
        step = make_train_step(model, OptimizerConfig())
        jitted = jax.jit(
            step,
            in_shardings=(named(params_abs, p_specs), named(opt_abs, o_specs),
                          named(batch_abs, b_specs)),
            donate_argnums=(0, 1),
        )
        with mesh:
            return jitted.lower(params_abs, opt_abs, batch_abs)
    if shape.kind == "prefill":
        p_specs, b_specs, _ = cell_shardings(cfg, shape, model, mesh)
        jitted = jax.jit(
            model.prefill_logits,
            in_shardings=(named(params_abs, p_specs), named(batch_abs, b_specs)),
        )
        with mesh:
            return jitted.lower(params_abs, batch_abs)
    cache_abs = model.cache_specs(shape.global_batch, shape.seq_len)
    p_specs, b_specs, c_specs = cell_shardings(
        cfg, shape, model, mesh, cache_tree=cache_abs
    )
    jitted = jax.jit(
        model.serve_step,
        in_shardings=(named(params_abs, p_specs), named(cache_abs, c_specs),
                      named(batch_abs, b_specs)),
        donate_argnums=(1,),
    )
    with mesh:
        return jitted.lower(params_abs, cache_abs, batch_abs)


def cost_analysis_dict(compiled) -> Dict[str, Any]:
    """``compiled.cost_analysis()`` normalised across jaxlib versions
    (older releases returned ``[dict]`` instead of ``dict``)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    return cost


def _analyse(compiled) -> Dict[str, Any]:
    cost = cost_analysis_dict(compiled)
    hlo = compiled.as_text()
    return {
        "flops": cost.get("flops", 0.0),
        "hbm_bytes_accessed": cost.get("bytes accessed", 0.0),
        "collective_bytes": collective_bytes(hlo),
        "scan_trip_counts": _scan_trip_counts(hlo)[:16],
    }


def build_cell(
    arch: str, shape_name: str, mesh, probes: bool = False
) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    skip = cell_is_skipped(cfg, shape)
    if skip:
        return {"status": "skipped", "reason": skip}
    mode = cell_mode(cfg, shape)
    t0 = time.time()
    lowered = _lower_cell(cfg, shape, mesh)
    compiled = lowered.compile()
    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    result = {
        "status": "ok",
        "mode": mode,
        "chips": mesh_chip_count(mesh),
        "n_layers": cfg.n_layers,
        "compile_s": round(compile_s, 2),
        **_analyse(compiled),
        "memory": {
            "argument_size": getattr(mem, "argument_size_in_bytes", None),
            "output_size": getattr(mem, "output_size_in_bytes", None),
            "temp_size": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
    }
    if probes:
        result["probes"] = []
        for pcfg, L in probe_configs(cfg):
            pc = _lower_cell(pcfg, shape, mesh).compile()
            result["probes"].append({"n_layers": L, **_analyse(pc)})
    return result, compiled


def save_hlo(compiled, path: str) -> None:
    """Persist the optimized HLO (gzip) for trip-count-aware accounting
    (benchmarks/hlo_analysis.py): cost_analysis counts while bodies ONCE,
    so the roofline reads the HLO itself."""
    import gzip

    with gzip.open(path, "wt") as f:
        f.write(compiled.as_text())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"], default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--probes", action="store_true",
                    help="extra reduced-depth compiles (legacy extrapolation)")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = ARCH_IDS if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    failures = 0
    for mesh_name in meshes:
        mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
        for arch in archs:
            for shape_name in shapes:
                tag = f"{arch}__{shape_name}__{mesh_name}"
                path = os.path.join(args.out, tag + ".json")
                hlo_path = os.path.join(args.out, tag + ".hlo.gz")
                if (
                    os.path.exists(path)
                    and not args.force
                    and (mesh_name != "pod" or os.path.exists(hlo_path))
                ):
                    print(f"[{tag}] cached")
                    continue
                try:
                    res = build_cell(arch, shape_name, mesh, probes=args.probes)
                    if isinstance(res, tuple):
                        res, compiled = res
                        if mesh_name == "pod":  # roofline is single-pod only
                            save_hlo(compiled, hlo_path)
                except Exception as e:  # noqa: BLE001 — record and continue
                    res = {
                        "status": "error",
                        "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-4000:],
                    }
                    failures += 1
                res["arch"] = arch
                res["shape"] = shape_name
                res["mesh"] = mesh_name
                with open(path, "w") as f:
                    json.dump(res, f, indent=2)
                print(
                    f"[{tag}] {res['status']}"
                    + (f" compile={res.get('compile_s')}s flops={res.get('flops'):.3e}"
                       if res["status"] == "ok" else
                       (" " + res.get("reason", res.get("error", ""))[:120]))
                )
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
