"""ShapeDtypeStruct stand-ins + PartitionSpecs for every (arch x shape) cell.

``input_specs`` builds the abstract batch for a cell; ``cell_shardings``
builds the full (params, [cache/opt], batch) PartitionSpec trees the dry-run
passes as jit in_shardings.  Nothing here allocates device memory.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig, mesh_rules
from repro.models import param_shardings
from repro.models.transformer import Model

ENC_SRC_LEN = 4096  # serving-time encoder length for the enc-dec arch


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Abstract model inputs for one cell (batch dict of ShapeDtypeStruct)."""
    B, T = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        if cfg.family == "vlm":
            p = cfg.frontend_tokens
            return {
                "tokens": jax.ShapeDtypeStruct((B, T - p), i32),
                "patch_embeds": jax.ShapeDtypeStruct((B, p, cfg.d_model), jnp.bfloat16),
            }
        if cfg.is_encdec:
            return {
                "src_embeds": jax.ShapeDtypeStruct((B, T, cfg.d_model), jnp.bfloat16),
                "tgt_tokens": jax.ShapeDtypeStruct((B, T), i32),
            }
        return {"tokens": jax.ShapeDtypeStruct((B, T), i32)}
    # decode: one new token against a cache of length T
    return {
        "token": jax.ShapeDtypeStruct((B, 1), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
    }


def batch_shardings(cfg: ArchConfig, shape: ShapeConfig, rules) -> Dict[str, Any]:
    dp = rules["batch"]
    if shape.kind in ("train", "prefill"):
        if cfg.family == "vlm":
            return {"tokens": P(dp), "patch_embeds": P(dp)}
        if cfg.is_encdec:
            return {"src_embeds": P(dp), "tgt_tokens": P(dp)}
        return {"tokens": P(dp)}
    return {"token": P(dp), "pos": P()}


def cache_shardings(cfg: ArchConfig, shape: ShapeConfig, rules, cache_tree) -> Any:
    """PartitionSpecs for the serve cache tree, matched by structure."""
    dp = rules["batch"]
    kvs = rules["kv_seq"]

    def kv_spec(leaf_shape) -> P:
        # (L, B, S, Hk, Dh) contiguous KV cache
        return P(None, dp, kvs, None, None)

    def spec_for(path: Tuple[str, ...], leaf) -> P:
        nd = len(leaf.shape)
        name = path[-1]
        if name in ("k", "v", "attn_k", "attn_v"):
            if nd == 5:
                return P(None, dp, kvs, None, None)
            return P(dp, kvs, None, None)
        if name == "mamba_h":            # (L, B, H, P, N)
            return P(None, dp, "model", None, None)
        if name == "mamba_conv":         # (L, B, K-1, C)
            return P(None, dp, None, "model")
        if name in ("mC",):              # (G, nm, B, H, K, K)
            return P(None, None, dp, None, "model", None)
        if name in ("mN",):              # (G, nm, B, H, K)
            return P(None, None, dp, None, "model")
        if name in ("sc", "sn", "sh", "sm"):  # (G, B, H, dh)
            return P(None, dp, None, None)
        return P(*([None] * nd))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    out = []
    for path, leaf in flat:
        names = tuple(
            p.key if hasattr(p, "key") else str(p) for p in path
        )
        out.append(spec_for(names, leaf))
    return jax.tree_util.tree_unflatten(treedef, out)


def cell_mode(cfg: ArchConfig, shape: ShapeConfig) -> str:
    if shape.kind == "decode":
        return "decode_long" if shape.global_batch == 1 else "decode"
    return "train"


def cell_shardings(
    cfg: ArchConfig,
    shape: ShapeConfig,
    model: Model,
    mesh,
    cache_tree: Optional[Any] = None,
):
    """(param_specs, batch_specs, cache_specs?) PartitionSpec trees."""
    rules = mesh_rules(cell_mode(cfg, shape), mesh.axis_names)
    p_specs = param_shardings(model.param_specs, rules, mesh=mesh)
    b_specs = batch_shardings(cfg, shape, rules)
    c_specs = (
        cache_shardings(cfg, shape, rules, cache_tree)
        if cache_tree is not None
        else None
    )
    return p_specs, b_specs, c_specs
