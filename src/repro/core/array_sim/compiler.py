"""Workload compiler: lower ANY event-engine workload to the array sim.

``build_spec`` (PR 1) hand-flattened the single-table microbenchmark into
:class:`~repro.core.array_sim.spec.SimSpec` arrays.  This module is the
general lowering — the one place that turns the event engine's object
world (a :class:`~repro.core.pages.Database` of several tables, streams
whose queries name different tables and column sets, qgen-style rotated
permutations) into the fixed-shape dense arrays the batched step consumes:

* **global page indexing** — pages of every referenced (table, column)
  pair are laid out contiguously in one global id space; ``col_start``
  records each column's offset so the existing one-divide cursor→page
  mapping (``floor(cur / col_tpp) + col_start``) generalizes unchanged.
* **global column axis** — the per-query column mask ``q_cols`` spans the
  union of all referenced tables' columns.  A query's mask only ever
  selects columns of its own table, so every per-column computation in
  the step (frontier cursors, advance limits, next-consumption estimates)
  is automatically restricted to the query's table: the step needs no
  explicit table id.  Tuple coordinates stay *per table* — a cursor is a
  position in the current query's table, and pages of other tables are
  masked out before their (meaningless) local indices matter.
* **per-query rows** — each :class:`~repro.core.scans.ScanSpec` becomes
  one ``(table, start, len, rate, column-mask)`` row; a TPC-H template
  that expands to several table scans contributes several consecutive
  rows of its stream, exactly like the event engine runs them.

Tables never referenced by any query are left out of the page space (they
would only pad every per-page array).  The single-table lowering is the
degenerate case: ``build_spec`` now delegates here after its legacy
one-table check, so there is exactly one lowering in the tree
(``tests/test_array_compiler.py`` pins bit-for-bit agreement with the
seed arrays).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..pages import Database
from ..scans import ScanSpec
from .spec import PAGE_PAD, SimSpec


def referenced_tables(db: Database, streams: Sequence[Sequence[ScanSpec]],
                      ) -> List[str]:
    """Tables named by at least one query, in ``db.tables`` order (the
    deterministic global layout order of the compiled page space)."""
    used = {s.table for stream in streams for s in stream}
    missing = used - set(db.tables)
    if missing:
        raise ValueError(f"streams reference unknown tables: {sorted(missing)}")
    return [t for t in db.tables if t in used]


def compile_workload(
    db: Database,
    streams: Sequence[Sequence[ScanSpec]],
    n_groups: int = 10,
    buckets_per_group: int = 4,
    tables: Optional[Sequence[str]] = None,
) -> SimSpec:
    """Lower a multi-table workload into a :class:`SimSpec`.

    ``tables`` overrides the compiled table set (default: the tables the
    streams reference).  Every column of every compiled table enters the
    global page space — untouched columns cost padding only, and keeping
    them makes the single-table output bit-identical to the seed
    ``build_spec`` arrays.
    """
    tnames = list(tables) if tables is not None \
        else referenced_tables(db, streams)
    if not tnames:
        raise ValueError("empty workload: no stream references any table")

    # ---- global column axis: (table, column) pairs in layout order -------
    tindex = {t: i for i, t in enumerate(tnames)}
    col_names: List[Tuple[str, str]] = []   # (table, column)
    for tname in tnames:
        for cname in db.tables[tname].columns:
            col_names.append((tname, cname))
    cindex: Dict[Tuple[str, str], int] = {
        tc: i for i, tc in enumerate(col_names)
    }
    C = len(col_names)

    # ---- per-page constants with per-column global offsets ---------------
    sizes: List[float] = []
    firsts: List[float] = []
    lasts: List[float] = []
    pcols: List[int] = []
    page_rows: List[Tuple[int, float]] = []   # (table idx, first tuple)
    col_start = np.zeros(C, np.int32)
    col_npages = np.zeros(C, np.int32)
    col_tpp = np.zeros(C, np.float32)
    col_ntuples = np.zeros(C, np.float32)
    col_table = np.zeros(C, np.int32)
    off = 0
    for ci, (tname, cname) in enumerate(col_names):
        table = db.tables[tname]
        col = table.columns[cname]
        if not col.pages:
            raise ValueError(
                f"column {table.name}.{cname} has zero pages; every column "
                "needs at least one page to define its tuples-per-page grid "
                "(re-run Column.build_pages or drop the column)"
            )
        col_start[ci] = off
        col_npages[ci] = len(col.pages)
        col_tpp[ci] = col.n_tuples / len(col.pages)
        col_ntuples[ci] = float(table.n_tuples)
        col_table[ci] = tindex[tname]
        for p in col.pages:
            sizes.append(p.size_bytes)
            firsts.append(p.first_tuple)
            lasts.append(p.last_tuple)
            pcols.append(ci)
            page_rows.append((tindex[tname], p.first_tuple))
        off += len(col.pages)

    P = ((off + PAGE_PAD - 1) // PAGE_PAD) * PAGE_PAD
    pad = P - off
    page_size = np.asarray(sizes + [0] * pad, np.float32)
    page_first = np.asarray(firsts + [0] * pad, np.float32)
    page_last = np.asarray(lasts + [0] * pad, np.float32)
    page_col = np.asarray(pcols + [0] * pad, np.int32)
    page_valid = np.asarray([True] * off + [False] * pad, bool)

    # ---- chunk geometry (the cooperative substrate's unit) ---------------
    from .coop import chunk_geometry

    n_chunks, chunk_first, chunk_last, chunk_table, page_chunk0 = \
        chunk_geometry(db, tnames, page_rows)
    page_chunk = np.zeros(P, np.int32)
    page_chunk[:off] = page_chunk0

    # ---- per-stream query rows -------------------------------------------
    S = len(streams)
    Q = max(len(s) for s in streams)
    q_start = np.zeros((S, Q), np.float32)
    q_len = np.ones((S, Q), np.float32)
    q_rate = np.full((S, Q), 1.0, np.float32)
    q_cols = np.zeros((S, Q, C), bool)
    q_table = np.zeros((S, Q), np.int32)
    n_q = np.zeros(S, np.int32)
    # per-column trigger geometry for the event-horizon stepper: the
    # fastest rate that can ever advance a cursor over this column bounds
    # how many of its page triggers one macro-step can cross
    col_max_rate = np.zeros(C, np.float32)
    for si, stream in enumerate(streams):
        n_q[si] = len(stream)
        for qi, spec in enumerate(stream):
            if len(spec.ranges) != 1:
                raise ValueError("array backend supports single-range scans")
            if spec.table not in tindex:
                raise ValueError(
                    f"query table {spec.table!r} is not in the compiled "
                    f"table set {tnames} (tables= override too narrow?)"
                )
            a, b = spec.ranges[0]
            q_start[si, qi] = a
            q_len[si, qi] = b - a
            q_rate[si, qi] = spec.tuple_rate
            q_table[si, qi] = tindex[spec.table]
            for c in spec.columns:
                key = (spec.table, c)
                if key not in cindex:
                    raise ValueError(
                        f"query column {spec.table}.{c} is not in the "
                        f"compiled table set {tnames}"
                    )
                ci = cindex[key]
                q_cols[si, qi, ci] = True
                col_max_rate[ci] = max(col_max_rate[ci],
                                       float(spec.tuple_rate))

    return SimSpec(
        n_pages=P,
        n_streams=S,
        n_queries=Q,
        n_cols=C,
        n_groups=n_groups,
        buckets_per_group=buckets_per_group,
        page_size=page_size,
        page_first=page_first,
        page_last=page_last,
        page_col=page_col,
        page_valid=page_valid,
        col_start=col_start,
        col_npages=col_npages,
        col_tpp=col_tpp,
        col_ntuples=col_ntuples,
        q_start=q_start,
        q_len=q_len,
        q_rate=q_rate,
        q_cols=q_cols,
        n_q=n_q,
        n_tables=len(tnames),
        table_names=tuple(tnames),
        col_table=col_table,
        q_table=q_table,
        n_chunks=n_chunks,
        page_chunk=page_chunk,
        chunk_first=chunk_first,
        chunk_last=chunk_last,
        chunk_table=chunk_table,
        col_max_rate=col_max_rate,
    )
