"""Static, fixed-shape array description of a scan workload.

The event engine walks Python dicts of :class:`~repro.core.pages.Page`
objects; the array backend flattens the same storage model into dense
arrays once, up front, so the simulation step is pure array math:

* **pages** — one slot per physical page of the table, padded to a
  multiple of 128 (``page_valid`` masks the padding).  Per-page constants:
  byte size, covered tuple range, owning column.
* **columns** — tuples-per-page and the page-id offset of each column,
  which turn a cursor position into a page index with one divide
  (the array analogue of :meth:`Column.pages_for_range`).
* **streams** — each stream's queries as ``(start, length, rate, column
  mask)`` rows, padded to the longest stream.

Only single-table, single-range scans are supported — exactly the shape of
the paper's microbenchmark (Figs 11-13).  TPC-H multi-scan queries stay on
the event engine.
"""

from __future__ import annotations

from typing import List, NamedTuple, Sequence

import numpy as np

from ..pages import Database
from ..scans import ScanSpec

PAGE_PAD = 128


class SimSpec(NamedTuple):
    """Immutable workload description consumed by ``array_sim.sim``.

    Array fields are plain numpy; they are closed over by the jitted step
    function and become on-device constants.
    """

    # ---- static dims -----------------------------------------------------
    n_pages: int          # P (padded)
    n_streams: int        # S
    n_queries: int        # Q (padded per-stream query count)
    n_cols: int           # C
    # ---- PBM bucket geometry (paper Fig. 10) -----------------------------
    n_groups: int
    buckets_per_group: int
    # ---- per-page constants (P,) -----------------------------------------
    page_size: np.ndarray     # f32 bytes
    page_first: np.ndarray    # f32 first tuple (absolute)
    page_last: np.ndarray     # f32 last tuple, exclusive
    page_col: np.ndarray      # i32 owning column
    page_valid: np.ndarray    # bool
    # ---- per-column constants (C,) ---------------------------------------
    col_start: np.ndarray     # i32 page-id offset of the column
    col_npages: np.ndarray    # i32
    col_tpp: np.ndarray       # f32 tuples per page
    col_ntuples: np.ndarray   # f32
    # ---- per-stream queries (S, Q) ---------------------------------------
    q_start: np.ndarray       # f32 absolute first tuple
    q_len: np.ndarray         # f32 tuples scanned
    q_rate: np.ndarray        # f32 tuples/sec CPU rate
    q_cols: np.ndarray        # bool (S, Q, C) column mask
    n_q: np.ndarray           # i32 (S,) valid queries per stream

    @property
    def nb(self) -> int:
        """Number of requested buckets in the PBM timeline."""
        return self.n_groups * self.buckets_per_group

    @property
    def not_requested(self) -> int:
        """Bucket sentinel for resident pages no active scan wants."""
        return self.nb

    @property
    def max_rate(self) -> float:
        """Fastest CPU consumption rate of any query (tuples/sec)."""
        return float(np.max(self.q_rate))

    @property
    def min_tpp(self) -> float:
        """Fewest tuples per page of any column — the densest page grid."""
        return float(np.min(self.col_tpp))

    def trigger_window(self, dt: float) -> int:
        """Static per-column page-trigger lookahead for one step of length
        ``dt``: the most page boundaries the fastest scan can cross in the
        densest column, plus one so the conservative advance cap
        (``W``-th trigger) never throttles an unblocked scan."""
        return int(np.ceil(1.1 * self.max_rate * float(dt) / self.min_tpp)) + 1


def build_spec(
    db: Database,
    streams: Sequence[Sequence[ScanSpec]],
    n_groups: int = 10,
    buckets_per_group: int = 4,
) -> SimSpec:
    """Flatten a single-table workload into a :class:`SimSpec`."""
    tables = {s.table for stream in streams for s in stream}
    if len(tables) != 1:
        raise ValueError(f"array backend needs a single table, got {tables}")
    table = db.tables[next(iter(tables))]
    col_names: List[str] = list(table.columns)
    cindex = {c: i for i, c in enumerate(col_names)}
    C = len(col_names)

    sizes, firsts, lasts, pcols = [], [], [], []
    col_start = np.zeros(C, np.int32)
    col_npages = np.zeros(C, np.int32)
    col_tpp = np.zeros(C, np.float32)
    off = 0
    for ci, cname in enumerate(col_names):
        col = table.columns[cname]
        if not col.pages:
            raise ValueError(
                f"column {table.name}.{cname} has zero pages; every column "
                "needs at least one page to define its tuples-per-page grid "
                "(re-run Column.build_pages or drop the column)"
            )
        col_start[ci] = off
        col_npages[ci] = len(col.pages)
        col_tpp[ci] = col.n_tuples / len(col.pages)
        for p in col.pages:
            sizes.append(p.size_bytes)
            firsts.append(p.first_tuple)
            lasts.append(p.last_tuple)
            pcols.append(ci)
        off += len(col.pages)

    P = ((off + PAGE_PAD - 1) // PAGE_PAD) * PAGE_PAD
    pad = P - off
    page_size = np.asarray(sizes + [0] * pad, np.float32)
    page_first = np.asarray(firsts + [0] * pad, np.float32)
    page_last = np.asarray(lasts + [0] * pad, np.float32)
    page_col = np.asarray(pcols + [0] * pad, np.int32)
    page_valid = np.asarray([True] * off + [False] * pad, bool)

    S = len(streams)
    Q = max(len(s) for s in streams)
    q_start = np.zeros((S, Q), np.float32)
    q_len = np.ones((S, Q), np.float32)
    q_rate = np.full((S, Q), 1.0, np.float32)
    q_cols = np.zeros((S, Q, C), bool)
    n_q = np.zeros(S, np.int32)
    for si, stream in enumerate(streams):
        n_q[si] = len(stream)
        for qi, spec in enumerate(stream):
            if len(spec.ranges) != 1:
                raise ValueError("array backend supports single-range scans")
            a, b = spec.ranges[0]
            q_start[si, qi] = a
            q_len[si, qi] = b - a
            q_rate[si, qi] = spec.tuple_rate
            for c in spec.columns:
                q_cols[si, qi, cindex[c]] = True

    return SimSpec(
        n_pages=P,
        n_streams=S,
        n_queries=Q,
        n_cols=C,
        n_groups=n_groups,
        buckets_per_group=buckets_per_group,
        page_size=page_size,
        page_first=page_first,
        page_last=page_last,
        page_col=page_col,
        page_valid=page_valid,
        col_start=col_start,
        col_npages=col_npages,
        col_tpp=col_tpp,
        col_ntuples=np.full(C, float(table.n_tuples), np.float32),
        q_start=q_start,
        q_len=q_len,
        q_rate=q_rate,
        q_cols=q_cols,
        n_q=n_q,
    )
