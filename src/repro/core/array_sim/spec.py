"""Static, fixed-shape array description of a scan workload.

The event engine walks Python dicts of :class:`~repro.core.pages.Page`
objects; the array backend flattens the same storage model into dense
arrays once, up front, so the simulation step is pure array math:

* **pages** — one slot per physical page of the table, padded to a
  multiple of 128 (``page_valid`` masks the padding).  Per-page constants:
  byte size, covered tuple range, owning column.
* **columns** — tuples-per-page and the page-id offset of each column,
  which turn a cursor position into a page index with one divide
  (the array analogue of :meth:`Column.pages_for_range`).
* **streams** — each stream's queries as ``(table, start, length, rate,
  column mask)`` rows, padded to the longest stream.

Workloads over several tables (the paper's §4.2 TPC-H throughput run:
8 tables / 61 columns, 22 rotated query templates per stream) lower
through :mod:`repro.core.array_sim.compiler`, which lays the pages of
every referenced (table, column) pair out in one global id space; the
``multitable`` extension fields below record the table geometry.  Tuple
coordinates stay per table — each query's cursor lives in its own
table's coordinate system, and the global column mask restricts every
per-column computation to that table.  ``build_spec`` remains the
single-table entry point (the microbenchmark shape of Figs 11-13) and
delegates to the same compiler, so there is exactly one lowering.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Sequence, Tuple

import numpy as np

from ..pages import Database
from ..scans import ScanSpec

PAGE_PAD = 128


class SimSpec(NamedTuple):
    """Immutable workload description consumed by ``array_sim.sim``.

    Array fields are plain numpy; they are closed over by the jitted step
    function and become on-device constants.
    """

    # ---- static dims -----------------------------------------------------
    n_pages: int          # P (padded)
    n_streams: int        # S
    n_queries: int        # Q (padded per-stream query count)
    n_cols: int           # C
    # ---- PBM bucket geometry (paper Fig. 10) -----------------------------
    n_groups: int
    buckets_per_group: int
    # ---- per-page constants (P,) -----------------------------------------
    page_size: np.ndarray     # f32 bytes
    page_first: np.ndarray    # f32 first tuple (absolute)
    page_last: np.ndarray     # f32 last tuple, exclusive
    page_col: np.ndarray      # i32 owning column
    page_valid: np.ndarray    # bool
    # ---- per-column constants (C,) ---------------------------------------
    col_start: np.ndarray     # i32 page-id offset of the column
    col_npages: np.ndarray    # i32
    col_tpp: np.ndarray       # f32 tuples per page
    col_ntuples: np.ndarray   # f32
    # ---- per-stream queries (S, Q) ---------------------------------------
    q_start: np.ndarray       # f32 first tuple (in the query table's coords)
    q_len: np.ndarray         # f32 tuples scanned
    q_rate: np.ndarray        # f32 tuples/sec CPU rate
    q_cols: np.ndarray        # bool (S, Q, C) column mask
    n_q: np.ndarray           # i32 (S,) valid queries per stream
    # ---- multitable extension (compiler.py) ------------------------------
    # The step itself resolves everything through the per-column offset
    # tables above; these record the table geometry for introspection,
    # validation, and result attribution.
    n_tables: int = 1
    table_names: Tuple[str, ...] = ()
    col_table: Optional[np.ndarray] = None   # i32 (C,) owning table
    q_table: Optional[np.ndarray] = None     # i32 (S, Q) table of each query
    # ---- chunk geometry (cooperative substrate, compiler.py) -------------
    # The paper's logical chunks (a tuple range, NOT a page set): global
    # chunk ids across the compiled tables; a page belongs to the chunk
    # containing its first tuple (ABM's unique-ownership rule).  Consumed
    # by ``array_sim.coop`` for the array-CScan policy.
    n_chunks: int = 0
    page_chunk: Optional[np.ndarray] = None   # i32 (P,) owning chunk
    chunk_first: Optional[np.ndarray] = None  # f32 (CH,) table-local tuples
    chunk_last: Optional[np.ndarray] = None   # f32 (CH,) exclusive
    chunk_table: Optional[np.ndarray] = None  # i32 (CH,) owning table
    # ---- per-column trigger geometry (compiler.py, horizon stepper) ------
    # Fastest CPU rate of any query that actually scans each column.  The
    # event-horizon stepper sizes its trigger window for macro-steps of
    # up to ~h_max fine steps; bounding the crossing count with the
    # per-column rate (instead of the global max rate) keeps the window
    # from exploding on dense columns only slow scans ever touch.
    col_max_rate: Optional[np.ndarray] = None  # f32 (C,)

    @property
    def nb(self) -> int:
        """Number of requested buckets in the PBM timeline."""
        return self.n_groups * self.buckets_per_group

    @property
    def not_requested(self) -> int:
        """Bucket sentinel for resident pages no active scan wants."""
        return self.nb

    @property
    def max_rate(self) -> float:
        """Fastest CPU consumption rate of any query (tuples/sec)."""
        return float(np.max(self.q_rate))

    @property
    def min_tpp(self) -> float:
        """Fewest tuples per page of any column — the densest page grid."""
        return float(np.min(self.col_tpp))

    def trigger_window(self, dt: float, tight: bool = False) -> int:
        """Static per-column page-trigger lookahead for one step of length
        ``dt``: the most page boundaries the fastest scan can cross in the
        densest column, plus one so the conservative advance cap
        (``W``-th trigger) never throttles an unblocked scan.

        Computed per column and capped at the column's page count: a tiny
        dimension table (a handful of tuples per page, one page per
        column) has a dense tuple grid but nothing beyond its last page,
        so it must not inflate the global window the way a naive
        ``max_rate / min_tpp`` bound would in a multi-table spec.

        ``tight`` additionally bounds each column by the fastest rate of
        a query that actually scans it (``col_max_rate``, compiled per
        column) — still sufficient (no scan of the column is faster),
        but much smaller for the long macro-steps of the event-horizon
        stepper when the densest columns belong to slow scans only.
        """
        rate = self.max_rate
        if tight and self.col_max_rate is not None:
            rate = np.maximum(self.col_max_rate, 1.0)
        need = np.ceil(
            1.1 * rate * float(dt) / self.col_tpp
        ).astype(np.int64) + 1
        need = np.minimum(need, self.col_npages.astype(np.int64) + 1)
        return max(1, int(np.max(need)))


def build_spec(
    db: Database,
    streams: Sequence[Sequence[ScanSpec]],
    n_groups: int = 10,
    buckets_per_group: int = 4,
) -> SimSpec:
    """Flatten a single-table workload into a :class:`SimSpec`.

    Legacy entry point of the microbenchmark shape; the lowering itself
    lives in :func:`repro.core.array_sim.compiler.compile_workload` (this
    wrapper only keeps the historical one-table contract, which callers
    like the parity property tests rely on for early shape errors).
    """
    from .compiler import compile_workload

    tables = {s.table for stream in streams for s in stream}
    if len(tables) != 1:
        raise ValueError(
            f"array backend needs a single table, got {tables} — lower "
            "multi-table workloads with array_sim.compiler.compile_workload"
        )
    return compile_workload(
        db, streams, n_groups=n_groups, buckets_per_group=buckets_per_group
    )
