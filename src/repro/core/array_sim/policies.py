"""Array formulations of the LRU and PBM eviction policies.

These mirror ``repro.core.policies.{lru,pbm}`` but operate on dense
per-page arrays so they can run inside a jitted/vmapped simulation step:

* :func:`time_to_bucket` — the O(1) ``TimeToBucketNumber`` of paper
  Fig. 10, vectorised over a whole page array.
* :func:`next_consumption` — ``PageNextConsumption`` (paper Fig. 9)
  vectorised over the whole page array instead of per-page dict walks.
* :func:`target_buckets` — where every page *would* go if (re)pushed now;
  used for newly loaded pages, request-set transitions, and the
  spill-recalculation of the timeline shift.

The timeline shift + batched evict selection live in
``repro.kernels.pbm_timeline`` (Pallas) with a jnp oracle in
``repro.kernels.ref`` — this module only computes the inputs.
"""

from __future__ import annotations

import jax.numpy as jnp

# "no interest" sentinel: a finite big value, not inf — XLA:CPU fuses
# float arithmetic far better than inf/pred-heavy broadcasts
BIG = jnp.float32(1e30)
BIG_CUT = 1e29


def time_to_bucket(eta, time_slice, n_groups, m):
    """Vectorised TimeToBucketNumber: bucket index for each eta (seconds).

    Matches ``PBMPolicy.time_to_bucket`` elementwise: group ``g`` covers
    slice offsets ``[m*(2^g - 1), m*(2^(g+1) - 1))`` with bucket width
    ``2^g`` slices.  ``eta=inf`` maps to the last bucket (callers decide
    not-requested separately).
    """
    nb = n_groups * m
    s = jnp.maximum(eta, 0.0) / time_slice
    g = jnp.floor(jnp.log2(s / m + 1.0)).astype(jnp.int32)
    g = jnp.clip(g, 0, n_groups - 1)
    glen = jnp.left_shift(jnp.int32(1), g)
    start = m * (glen - 1)
    width = glen.astype(jnp.float32)
    idx = jnp.floor((s - start.astype(jnp.float32)) / width).astype(jnp.int32)
    b = jnp.clip(g * m + idx, 0, nb - 1)
    return jnp.where(eta <= 0.0, 0, b).astype(jnp.int32)


def next_consumption(page_first, page_last, page_col, cols_cur, cur_abs,
                     scan_end, speed, active, scan_start=None, eps=None):
    """``PageNextConsumption`` over the whole page array: min over streams
    of estimated seconds until the page's consumption, :data:`BIG` where no
    registered scan wants the page.

    Consumption is **plan-trigger granular**, mirroring the event engine's
    access plan: a page is consumed the instant the scan cursor crosses its
    *trigger* ``max(page_first, scan_start)`` (the page's first tuple, or
    the scan start for the page straddling it), and from then on the scan
    no longer registers interest — even while the cursor is still inside
    the page's tuple range.  ``eps`` absorbs f32 cursor rounding so a page
    whose trigger the cursor sits exactly on still counts as pending.

    ``scan_start=None`` keeps the legacy page-overlap interest
    (``page_last > cur``): the registration-time view where nothing has
    been consumed yet, used by the parity property tests.

    Unrolled over streams (S is small and static): 1-D elementwise ops per
    stream fuse to a single fast loop on CPU, where the equivalent (S, P)
    broadcast compiles to a pathologically slow predicate fusion.
    """
    S = cur_abs.shape[0]
    colmask_sp = cols_cur[:, page_col]           # one (S, P) gather
    eta = jnp.full(page_first.shape, BIG)
    for s in range(S):
        if scan_start is None:
            trigger = page_first
            pending = page_last > cur_abs[s]
        else:
            trigger = jnp.maximum(page_first, scan_start[s])
            tol = 0.0 if eps is None else eps[s]
            pending = (trigger >= cur_abs[s] - tol) & (
                page_last > scan_start[s]
            )
        interest = (
            colmask_sp[s]                        # scan touches the column
            & pending                            # trigger not yet crossed
            & (page_first < scan_end[s])         # inside the scanned range
            & active[s]
        )
        e = jnp.maximum(trigger - cur_abs[s], 0.0) / jnp.maximum(
            speed[s], 1e-6
        )
        eta = jnp.minimum(eta, jnp.where(interest, e, BIG))
    return eta


def target_buckets(eta, time_slice, n_groups, m, page_valid):
    """Bucket every page would get if pushed now: ``time_to_bucket`` for
    requested pages, the not-requested sentinel (== nb) otherwise."""
    nb = n_groups * m
    requested = (eta < BIG_CUT) & page_valid
    b = time_to_bucket(jnp.where(requested, eta, 0.0), time_slice, n_groups, m)
    return jnp.where(requested, b, nb).astype(jnp.int32)
