"""The ``ArrayPolicy`` surface: buffer policies as jit/vmap-safe data.

The batched step (``array_sim.sim.make_step``) no longer hardcodes any
policy: it drives a tuple of :class:`ArrayPolicy` objects — pure-pytree
state plus array-function hooks — and dispatches eviction on the score
arrays they provide.  One lane of a vmapped sweep selects its policy by
indexing the stacked per-policy arrays with the traced config id, so a
whole (policy x buffer x bandwidth) grid runs as ONE batched call.

The protocol (all hooks are traced inside the jitted step; everything
they return must be jit/vmap-safe arrays):

* :meth:`ArrayPolicy.init_state` — build the policy's private state
  pytree for a workload (``()`` for stateless policies);
* :meth:`ArrayPolicy.on_request` / :meth:`ArrayPolicy.on_consume` —
  advance that state from the step's observation window
  (:class:`StepCtx`: this step's I/O grants, crossed plan triggers,
  post-advance scan view, consumption-estimate thunks);
* :meth:`ArrayPolicy.score_victims` — the policy itself: a ``(P,)`` f32
  eviction priority (higher = evicted first) consumed by the batched
  eviction kernel (``repro.kernels.ops.batched_evict``);
* :meth:`ArrayPolicy.scan_horizon` — the policy as a **horizon
  provider** for the event-horizon time engine
  (``make_runner(stepper="horizon")``): per stream, the seconds until
  the policy's own state next needs attention.  The in-order candidates
  (trigger arrival, completion, io-credit) come from the step itself;
  a policy only overrides this when its consumption model has its own
  clock — array-CScan reports the current chunk's completion;
* static knobs: ``request_window`` (per-policy readahead width),
  ``fifo_tie`` (request-cohort service order), ``cooperative`` (the
  policy inverts control flow and schedules loads itself — CScan; the
  step then runs the chunk-granular cooperative substrate in
  ``array_sim.coop`` against this policy's state).

Policies register in ``repro.core.policy_registry`` — the single table
both the event engine and the array backend resolve names through.

This module also keeps the vectorised numeric cores the policies are
built from:

* :func:`time_to_bucket` — the O(1) ``TimeToBucketNumber`` of paper
  Fig. 10, vectorised over a whole page array.
* :func:`next_consumption` — ``PageNextConsumption`` (paper Fig. 9)
  vectorised over the whole page array instead of per-page dict walks.
* :func:`target_buckets` — where every page *would* go if (re)pushed now.
* :func:`shift_timeline` — ``RefreshRequestedBuckets``: the once-per-
  slice timeline shift with spill re-bucketing (previously fused into
  the eviction kernel; elementwise, so it lives with the policy).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

# "no interest" sentinel: a finite big value, not inf — XLA:CPU fuses
# float arithmetic far better than inf/pred-heavy broadcasts
BIG = jnp.float32(1e30)
BIG_CUT = 1e29


def time_to_bucket(eta, time_slice, n_groups, m):
    """Vectorised TimeToBucketNumber: bucket index for each eta (seconds).

    Matches ``PBMPolicy.time_to_bucket`` elementwise: group ``g`` covers
    slice offsets ``[m*(2^g - 1), m*(2^(g+1) - 1))`` with bucket width
    ``2^g`` slices.  ``eta=inf`` maps to the last bucket (callers decide
    not-requested separately).
    """
    nb = n_groups * m
    s = jnp.maximum(eta, 0.0) / time_slice
    g = jnp.floor(jnp.log2(s / m + 1.0)).astype(jnp.int32)
    g = jnp.clip(g, 0, n_groups - 1)
    glen = jnp.left_shift(jnp.int32(1), g)
    start = m * (glen - 1)
    width = glen.astype(jnp.float32)
    idx = jnp.floor((s - start.astype(jnp.float32)) / width).astype(jnp.int32)
    b = jnp.clip(g * m + idx, 0, nb - 1)
    return jnp.where(eta <= 0.0, 0, b).astype(jnp.int32)


def next_consumption(page_first, page_last, page_col, cols_cur, cur_abs,
                     scan_end, speed, active, scan_start=None, eps=None):
    """``PageNextConsumption`` over the whole page array: min over streams
    of estimated seconds until the page's consumption, :data:`BIG` where no
    registered scan wants the page.

    Consumption is **plan-trigger granular**, mirroring the event engine's
    access plan: a page is consumed the instant the scan cursor crosses its
    *trigger* ``max(page_first, scan_start)`` (the page's first tuple, or
    the scan start for the page straddling it), and from then on the scan
    no longer registers interest — even while the cursor is still inside
    the page's tuple range.  ``eps`` absorbs f32 cursor rounding so a page
    whose trigger the cursor sits exactly on still counts as pending.

    ``scan_start=None`` keeps the legacy page-overlap interest
    (``page_last > cur``): the registration-time view where nothing has
    been consumed yet, used by the parity property tests.

    Unrolled over streams (S is small and static): 1-D elementwise ops per
    stream fuse to a single fast loop on CPU, where the equivalent (S, P)
    broadcast compiles to a pathologically slow predicate fusion.
    """
    S = cur_abs.shape[0]
    colmask_sp = cols_cur[:, page_col]           # one (S, P) gather
    eta = jnp.full(page_first.shape, BIG)
    for s in range(S):
        if scan_start is None:
            trigger = page_first
            pending = page_last > cur_abs[s]
        else:
            trigger = jnp.maximum(page_first, scan_start[s])
            tol = 0.0 if eps is None else eps[s]
            pending = (trigger >= cur_abs[s] - tol) & (
                page_last > scan_start[s]
            )
        interest = (
            colmask_sp[s]                        # scan touches the column
            & pending                            # trigger not yet crossed
            & (page_first < scan_end[s])         # inside the scanned range
            & active[s]
        )
        e = jnp.maximum(trigger - cur_abs[s], 0.0) / jnp.maximum(
            speed[s], 1e-6
        )
        eta = jnp.minimum(eta, jnp.where(interest, e, BIG))
    return eta


def target_buckets(eta, time_slice, n_groups, m, page_valid):
    """Bucket every page would get if pushed now: ``time_to_bucket`` for
    requested pages, the not-requested sentinel (== nb) otherwise."""
    nb = n_groups * m
    requested = (eta < BIG_CUT) & page_valid
    b = time_to_bucket(jnp.where(requested, eta, 0.0), time_slice, n_groups, m)
    return jnp.where(requested, b, nb).astype(jnp.int32)


def shift_timeline(bucket, b_target, slices_done, k, *, nb, m):
    """``RefreshRequestedBuckets`` (paper Fig. 9/10): advance the bucketed
    timeline by ``k`` slices.  Per elapsed slice, bucket ``b`` (length
    ``2**(b//m)`` slices) moves left when the slice counter divides its
    length; a page shifted past position 0 is *spilled* and re-bucketed at
    ``b_target`` — its freshly recomputed priority, the self-correction
    step of the paper."""

    def shift_once(i, b):
        tp = slices_done + i + 1
        blen = jnp.left_shift(jnp.int32(1), jnp.clip(b, 0, nb - 1) // m)
        req = (b >= 0) & (b < nb)
        moved = req & ((tp % blen) == 0)
        b2 = jnp.where(moved, b - 1, b)
        return jnp.where(b2 < 0, b_target, b2)

    return jax.lax.fori_loop(0, jnp.maximum(k, 0), shift_once, bucket)


class StepCtx:
    """Observation window one simulation step hands to the policy hooks.

    Built fresh inside the traced step (never carried), after the CPU
    advance and the I/O grant phase, so hooks see this step's loads and
    trigger crossings plus the post-advance scan view.  The consumption
    estimates are *thunks* with per-step memoisation: however many
    policies ask for :meth:`eta_estimate` during one step, it is computed
    once — and a step compiled without a PBM-like policy never computes
    it at all.

    ``refresh`` is a static Python bool: the step is compiled separately
    for the cheap within-slice flavour and the once-per-``time_slice``
    boundary flavour, exactly like the paper's PBM cadence.
    """

    def __init__(self, *, spec, refresh: bool, time_slice, now, steps,
                 dt, page_first, page_last, page_col,
                 page_valid, resident, last_used, load_mask, load_cand,
                 load_ok, cross_pidx, crossed, active, cols, cur, end,
                 start, eps, rate, speed_push, coop=None,
                 slices_done=None, slices_elapsed=None,
                 upd_pages=None, upd_on=None):
        self.spec = spec
        self.refresh = refresh
        self.time_slice = time_slice
        self.now = now                  # f32 sim clock (end of this step)
        self.steps = steps
        self.slices_done = slices_done  # i32 PBM slices elapsed (pre-step)
        self.slices_elapsed = slices_elapsed
        # ^ i32 slices THIS refresh step stands in for (None == 1): the
        #   wake-exact horizon refresh may absorb whole slices beyond
        #   its own tail, and the timeline shift must advance by all of
        #   them (shift_timeline's k)
        self.dt = dt                    # step length: static under the fixed
                                        # stepper, traced under "horizon"
        self.page_first = page_first
        self.page_last = page_last
        self.page_col = page_col
        self.page_valid = page_valid
        self.resident = resident        # (P,) bool pre-eviction residency
        self.last_used = last_used      # (P,) f32 post-touch LRU clock
        self.load_mask = load_mask      # (P,) bool granted loads this step
        self.load_cand = load_cand      # (LOAD_MAX,) i32 candidate pages
        self.load_ok = load_ok          # (LOAD_MAX,) bool grant mask
        self.cross_pidx = cross_pidx    # (S, C, W) i32 windowed page ids
        self.crossed = crossed          # (S, C, W) bool triggers crossed
        self.upd_pages = upd_pages      # (U,) i32 compacted update set —
        self.upd_on = upd_on            #   loads + crossings deduplicated
                                        #   (horizon stepper; None = use
                                        #   the padded load/cross windows)
        self.active = active            # post-advance view ------------
        self.cols = cols                # (S, C) bool
        self.cur = cur                  # (S,) f32 absolute cursor
        self.end = end
        self.start = start
        self.eps = eps
        self.rate = rate                # (S,) f32 true current query rate
        self.speed_push = speed_push    # (S,) f32 estimator w/ engine dips
        self.coop = coop                # cooperative-substrate outputs
        self._eta_estimate = None
        self._eta_exact = None

    def eta_estimate(self):
        """PBM's estimated next consumption per page: plan-trigger
        granular, from the per-slice speed estimator with the engine's
        stall-exit dips folded in.  Memoised per step."""
        if self._eta_estimate is None:
            self._eta_estimate = next_consumption(
                self.page_first, self.page_last, self.page_col,
                self.cols, self.cur, self.end, self.speed_push,
                self.active, scan_start=self.start, eps=self.eps,
            )
        return self._eta_estimate

    def eta_estimate_at(self, pages):
        """:meth:`eta_estimate` for a small page-id subset (the within-
        slice update set: this step's loads + crossed triggers)."""
        return next_consumption(
            self.page_first[pages], self.page_last[pages],
            self.page_col[pages], self.cols, self.cur, self.end,
            self.speed_push, self.active, scan_start=self.start,
            eps=self.eps,
        )

    def eta_exact(self):
        """OPT's oracle: exact next-consumption distances from the true
        CPU rates of the *current* queries — computable because the scan
        plans are static.  Memoised per step."""
        if self._eta_exact is None:
            self._eta_exact = next_consumption(
                self.page_first, self.page_last, self.page_col,
                self.cols, self.cur, self.end, self.rate,
                self.active, scan_start=self.start, eps=self.eps,
            )
        return self._eta_exact


class HorizonView:
    """The slim observation window the event-horizon stepper hands to
    :meth:`ArrayPolicy.scan_horizon`: the post-advance per-stream scan
    view plus the fine step length.  Built at the END of a step (the
    horizon describes the NEXT step)."""

    def __init__(self, *, spec, active, start, end, rate, dt_ref):
        self.spec = spec
        self.active = active    # (S,) bool post-advance
        self.start = start      # (S,) f32 absolute scan start
        self.end = end          # (S,) f32 absolute scan end
        self.rate = rate        # (S,) f32 true current query rate
        self.dt_ref = dt_ref    # f32 fine step length (static)


class ArrayPolicy:
    """Base protocol: a buffer policy as pure-pytree state + array hooks.

    Subclasses override what they need; the defaults are a stateless
    policy that only scores victims.  Hook outputs must be jit/vmap-safe
    (no Python control flow on traced values); ``ctx.refresh`` is static
    and MAY branch Python-side.
    """

    #: registry name (also the event-engine counterpart's name)
    name: str = "?"
    #: the policy schedules loads itself (ABM); the step runs the
    #: cooperative chunk substrate against this policy's state
    cooperative: bool = False
    #: request-cohort service order: "stream" = per-stream blocks (the
    #: woken scan's window enqueues contiguously), "plan" = plan-
    #: deterministic page order (estimates absorb the timing noise)
    fifo_tie: str = "stream"

    def request_window(self, spec, prefetch_pages: int) -> int:
        """Plan-entry readahead width for this policy (static)."""
        return prefetch_pages

    def init_state(self, spec) -> Any:
        """Policy-private state pytree for a workload (device arrays)."""
        return ()

    def on_request(self, pstate, ctx: StepCtx):
        """Observe this step's request/grant activity (``ctx.load_*``)."""
        return pstate

    def on_consume(self, pstate, ctx: StepCtx):
        """Observe this step's consumption (``ctx.crossed`` and the
        post-advance view); ``ctx.refresh`` marks the slice boundary."""
        return pstate

    def score_victims(self, pstate, ctx: StepCtx) -> jax.Array:
        """``(P,) f32`` eviction priority, higher = evicted first.  The
        step masks non-evictable pages and pops the order in batch."""
        raise NotImplementedError

    def scan_horizon(self, pstate, hz: HorizonView):
        """Per-stream seconds until this policy's state next needs a step
        (``(S,) f32``), or ``None`` for no policy-specific constraint —
        the event-horizon stepper then jumps on the step's own candidates
        alone (trigger arrival, completion, io-credit).  Only policies
        whose consumption model owns a clock override this (array-CScan:
        the consuming chunk's completion)."""
        return None  # noqa: RET501  (hook contract: explicit None means no clock)

    def observe_init(self, spec):
        """Zeros prototype of this policy's telemetry row (``None`` =
        no policy-specific counters).  Only consulted when the runner
        is built with ``telemetry=True`` (``repro.obs``); the row is a
        fixed-shape f32 vector the step accumulates per step."""
        return None  # noqa: RET501  (hook contract: None means no row)

    def observe(self, pstate, ctx: StepCtx):
        """Telemetry row for this step, same shape as
        :meth:`observe_init` — pure ``jnp``, added into the carried
        ``Telemetry.pol_obs`` entry (lanes running another policy are
        masked out by the step)."""
        return None  # noqa: RET501

    def __repr__(self) -> str:  # pragma: no cover
        return f"{type(self).__name__}({self.name})"


def _lru_age(ctx: StepCtx) -> jax.Array:
    return jnp.maximum(ctx.now - ctx.last_used, 0.0)


class ArrayLRU(ArrayPolicy):
    """Least-recently-used: score = age of the last consumption touch."""

    name = "lru"
    fifo_tie = "stream"

    def request_window(self, spec, prefetch_pages: int) -> int:
        # calibrated vs the event engine: its 8-entry window underfeeds
        # the array LRU at deep thrash (its requests are colder); the +2
        # widening restores the engine's churn level.  SINGLE-TABLE
        # deep-thrash calibration (micro 0.1-0.2 buffer) — on multi-table
        # workloads the same +2 overfeeds churn at the paper's TPC-H
        # operating points, where the engine's own width tracks within
        # the validation bars.
        return prefetch_pages + 2 if spec.n_tables == 1 else prefetch_pages

    def score_victims(self, pstate, ctx: StepCtx) -> jax.Array:
        return _lru_age(ctx)

    def observe_init(self, spec):
        # [resident-page age mass (s), resident-page count] per step:
        # mean resident age = row[0] / row[1] over the run
        return jnp.zeros(2, jnp.float32)

    def observe(self, pstate, ctx: StepCtx):
        res = ctx.resident & ctx.page_valid
        age = jnp.where(res, _lru_age(ctx), 0.0)
        return jnp.stack([jnp.sum(age),
                          jnp.sum(res).astype(jnp.float32)])


class ArrayPBM(ArrayPolicy):
    """Predictive Buffer Manager: the paper's bucketed consumption
    timeline as policy state (one ``(P,)`` bucket array).

    Within a slice the timeline is static except for pages whose estimate
    just changed (this step's loads and crossed triggers — the dict impl
    re-pushes a page on every load and consume event); at the slice
    boundary every page's next consumption is recomputed, no-longer-
    requested pages demote, and the timeline shifts one slice with spill
    re-bucketing (``RefreshRequestedBuckets`` as one vector op)."""

    name = "pbm"
    fifo_tie = "plan"

    def init_state(self, spec):
        return jnp.full(spec.n_pages, spec.not_requested, jnp.int32)

    def on_consume(self, bucket, ctx: StepCtx):
        spec = ctx.spec
        NR = spec.not_requested
        m = spec.buckets_per_group
        if ctx.refresh:
            # slice boundary: full PageNextConsumption recompute (trigger-
            # granular: consumed pages drop out per column), bucket
            # transitions, and one timeline shift with spill re-bucketing
            eta = ctx.eta_estimate()
            b_target = target_buckets(eta, ctx.time_slice, spec.n_groups,
                                      m, ctx.page_valid)
            interested = (eta < BIG_CUT) & ctx.page_valid
            assign = (
                ctx.load_mask | ((bucket == NR) & interested)
                | (b_target == 0)
            )
            bucket_pre = jnp.where(
                ~interested, NR, jnp.where(assign, b_target, bucket)
            ).astype(jnp.int32)
            k = (jnp.int32(1) if ctx.slices_elapsed is None
                 else ctx.slices_elapsed)
            return shift_timeline(bucket_pre, b_target, ctx.slices_done,
                                  k, nb=spec.nb, m=m)
        # within a slice: one fused gather/scatter over the update set.
        # Combining (min) scatter with an NR+1 sentinel for off entries:
        # duplicate ON entries of one page carry identical b_u (eta is a
        # function of the page alone), so the result is deterministic
        # even when a page appears both on and off in ``upd``.  The
        # horizon stepper hands a compacted id list (its cross window is
        # sized for macro-jumps — walking it padded would cost more than
        # the whole fixed step); the fixed stepper keeps the padded
        # windows bit-for-bit.
        if ctx.upd_pages is not None:
            upd, upd_on = ctx.upd_pages, ctx.upd_on
        else:
            upd = jnp.concatenate(
                [ctx.load_cand, ctx.cross_pidx.reshape(-1)]
            )
            upd_on = jnp.concatenate([ctx.load_ok, ctx.crossed.reshape(-1)])
        eta_u = ctx.eta_estimate_at(upd)
        b_u = target_buckets(eta_u, ctx.time_slice, spec.n_groups, m,
                             jnp.ones(upd.shape[0], bool))
        new_b = jnp.full(spec.n_pages, NR + 1, jnp.int32).at[upd].min(
            jnp.where(upd_on, b_u, NR + 1)
        )
        return jnp.where(new_b <= NR, new_b, bucket)

    def score_victims(self, bucket, ctx: StepCtx) -> jax.Array:
        # composite key: bucket level dominates; not-requested (== nb) is
        # the top level with LRU order inside; requested buckets break
        # ties by a per-(page, call) hash (the dict impl's insertion
        # order is equally arbitrary, but a FIXED index order would carve
        # a stable always-kept elite out of every bucket — systematic
        # retention the dict engine's churning insertion order never
        # develops).
        P = bucket.shape[0]
        nb = ctx.spec.nb
        age = _lru_age(ctx)
        idxi = jnp.arange(P, dtype=jnp.uint32)
        seed = jax.lax.bitcast_convert_type(
            jnp.asarray(ctx.now, jnp.float32) + 1.0, jnp.uint32
        ).astype(jnp.uint32)
        h32 = idxi * jnp.uint32(2654435761) + seed * jnp.uint32(40503)
        tie = (h32 >> jnp.uint32(8)).astype(jnp.float32) \
            * jnp.float32(2.0**-24)
        tb = jnp.where(bucket == nb, age / (age + 1.0), tie)
        return bucket.astype(jnp.float32) + 0.5 * tb

    def observe_init(self, spec):
        # resident-page occupancy per timeline bucket (paper Fig. 10),
        # step-integrated; the last slot is the not-requested level
        return jnp.zeros(spec.nb + 1, jnp.float32)

    def observe(self, bucket, ctx: StepCtx):
        nb = ctx.spec.nb
        res = (ctx.resident & ctx.page_valid).astype(jnp.float32)
        return jnp.zeros(nb + 1, jnp.float32).at[
            jnp.clip(bucket, 0, nb)
        ].add(res)


class ArrayOPT(ArrayPolicy):
    """OPT / Belady on exact plan distances (paper §3, §4 "OPT simulator").

    The scan plans are static and in-order, so every page's exact next
    consumption is one :func:`next_consumption` over the TRUE current
    query rates — no estimator.  Eviction mirrors
    ``policies.opt.OraclePolicy``: unreferenced pages first in LRU order,
    then referenced pages by furthest exact next use.  Like the paper's
    OPT it bounds *order-preserving* policies only — CScans may beat it
    (the paper's "food for thought" footnote).

    The score array is recomputed once per PBM slice and held STALE in
    between (the policy state is the cached key).  This is deliberate
    engine parity, not an optimisation: the event oracle ranks victims
    from burst-quantised scan positions, so at saturation it keeps
    evicting just-arrived readahead whose scans still rank far — ~19% of
    its loads at the 10%-buffer micro point are evicted before first use.
    A continuously re-scored array oracle never makes that mistake and
    came out 12-24% *more optimal* than the machine it models; freezing
    the ranking on the slice cadence reproduces the engine's churn
    channel (fit: micro -5/-6/-10%, TPC-H -7/+1/+1% stream time at the
    validated points).
    """

    name = "opt"
    fifo_tie = "stream"

    def init_state(self, spec):
        return jnp.zeros(spec.n_pages, jnp.float32)

    def on_consume(self, key, ctx: StepCtx):
        if not ctx.refresh:
            return key
        eta = ctx.eta_exact()
        age = _lru_age(ctx)
        unreferenced = eta >= BIG_CUT
        # bands: referenced pages map to [0, 1) monotone in eta (furthest
        # next use evicted first), unreferenced to [2, 3) in LRU order —
        # always above every referenced page
        return jnp.where(
            unreferenced,
            2.0 + age / (age + 1.0),
            eta / (eta + 1.0),
        )

    def score_victims(self, key, ctx: StepCtx) -> jax.Array:
        return key

    def observe_init(self, spec):
        # [unreferenced resident pages, referenced resident pages] per
        # step (the oracle's two score bands — mass in the first slot
        # means the pool holds dead pages the plans no longer want)
        return jnp.zeros(2, jnp.float32)

    def observe(self, key, ctx: StepCtx):
        res = ctx.resident & ctx.page_valid
        unref = res & (key >= 2.0)
        return jnp.stack([
            jnp.sum(unref).astype(jnp.float32),
            jnp.sum(res & (key < 2.0)).astype(jnp.float32),
        ])


class ArrayCScan(ArrayPolicy):
    """Cooperative Scans' ABM as an array policy (paper §2).

    CScan *inverts* buffer-management control flow — ABM decides loads
    globally and delivers chunks out of order — so it cannot be expressed
    as an eviction score over the in-order substrate alone (it beats even
    OPT, which bounds every order-preserving policy).  ``cooperative``
    makes the step run the chunk-granular cooperative substrate
    (``array_sim.coop``: per-(stream, chunk) consumption state,
    availability, the choose-chunk/choose-scan relevance loop, chunk-at-
    a-time loads) against this policy's state; the policy itself
    contributes the KeepRelevance eviction score the substrate computed:
    chunks the fewest CScans are interested in go first, and the paper's
    "evict only if KeepRelevance < LoadRelevance" rule is enforced by the
    substrate's evictable mask."""

    name = "cscan"
    cooperative = True
    fifo_tie = "plan"

    def init_state(self, spec):
        from .coop import init_coop_state
        return init_coop_state(spec)

    def score_victims(self, pstate, ctx: StepCtx) -> jax.Array:
        assert ctx.coop is not None, (
            "ArrayCScan needs the cooperative substrate: compile the step "
            "with this policy in its policies tuple"
        )
        return ctx.coop.keep_key

    def scan_horizon(self, pstate, hz: HorizonView):
        # the chunk is CScan's clock: nothing interesting happens for a
        # consuming scan before its current chunk completes; an idle scan
        # needs a fine step to run the pick loop
        from .coop import chunk_horizon
        return chunk_horizon(hz.spec, pstate, hz)

    def observe_init(self, spec):
        # [chunks-done flags summed over streams, scans consuming a
        # chunk] per step (chunk picks themselves are counted by the
        # step via coop.chunk_pick — they need both inflight states)
        return jnp.zeros(2, jnp.float32)

    def observe(self, pstate, ctx: StepCtx):
        return jnp.stack([
            jnp.sum(pstate.done.astype(jnp.float32)),
            jnp.sum((pstate.cur_chunk >= 0).astype(jnp.float32)),
        ])
