"""Array-native batched simulation of concurrent scans over a buffer pool.

The event engine (``repro.core.engine``) replays the paper's machine one
heapq event at a time in Python.  This module re-expresses the same system
as a **pure, fixed-shape array program**:

* per-page state (residency, LRU clock, FIFO request stamp) and
  per-stream state (query index, cursor, speed estimate) live in dense
  JAX arrays (:class:`SimState`); policy-private state (PBM's bucket
  timeline, CScan's chunk flags) rides along as pure pytrees owned by
  the compiled :class:`~repro.core.array_sim.policies.ArrayPolicy`
  objects;
* a pure ``step(state, cfg) -> state`` advances the whole machine by one
  page-transfer time ``dt`` — scans consume tuples with the engine's
  **per-page plan-trigger semantics**: each column keeps a fractional
  frontier cursor, a page is needed only at the instant the cursor crosses
  its trigger (``max(page_first, scan_start)``), and a scan blocks exactly
  at the earliest absent trigger across its columns — never on pages whose
  trigger it already crossed.  A bandwidth-budgeted I/O server pops the
  request FIFO; eviction dispatches on the **score arrays the compiled
  policies provide** (``ArrayPolicy.score_victims`` through the batched
  eviction kernel) — the step itself knows no policy by name or id.
  Because a blocked scan pins nothing and a running burst pins only its
  last ~``segment_pages`` plan entries, pools far below ``streams x
  columns`` pages stay live — the paper's small-buffer operating points
  (10-40%) run on this substrate, cross-validated against the event
  engine (see ``validate.ERROR_BARS``);
* steps come in two flavours on the paper's own cadence: *within* a PBM
  time slice the bucketed timeline is static (cheap step: consume, load,
  evict), and once per ``time_slice`` a *refresh* step recomputes every
  page's estimated next consumption — the policies see the boundary as
  the static ``refresh`` flag of their observation window
  (:class:`~repro.core.array_sim.policies.StepCtx`);
* a **cooperative** policy (array-CScan) inverts the control flow: when
  one is compiled in, the step also runs the chunk-granular ABM
  substrate (``array_sim.coop``) and blends per-lane between the
  in-order and cooperative models by the traced policy id — so a vmapped
  sweep mixes all four paper policies in ONE batched call;
* everything is ``jax.jit``- and ``jax.vmap``-compatible, so an entire
  sweep axis (buffer sizes x bandwidths x policies) runs as ONE batched
  computation instead of N serial Python event loops;
* **time itself is modelled two ways** (``make_runner(stepper=...)``):
  the ``"fixed"`` stepper grinds the classic fixed-``dt`` cadence
  (bit-compatible with the pre-horizon engine), while the ``"horizon"``
  stepper exploits the paper's own premise — long scans make the near
  future *predictable* — by computing, per lane and per step, the
  earliest **interesting** time (next plan-trigger arrival, next chunk
  completion, io-credit horizon of the pending request queue, stream
  completion, next timeline refresh) and advancing all state arrays by
  that variable ``dt`` in one jump.  Jumps never cross a PBM slice
  boundary, so the refresh cadence — the paper's semantic clock — is
  identical in both modes; finished lanes freeze (their metrics are
  bit-stable while slower lanes continue).  A ``mesh=`` on
  ``make_runner`` layers ``shard_map`` execution over the lane axis on
  top, spreading a batched sweep across devices with per-lane horizons
  intact;
* workloads may span SEVERAL tables (``compiler.compile_workload``):
  pages live in one global id space with per-column offsets, each query
  row carries its own table's tuple coordinates, and the global column
  mask restricts every per-column computation (frontier cursors, advance
  limits, consumption estimates) to the query's table — the step itself
  never branches on a table id, which is what keeps the TPC-H throughput
  run (Figs 14-16) on the same jit/vmap path as the microbenchmark.

Policy names resolve through ``repro.core.policy_registry`` — the single
table shared with the event engine; the traced ``cfg.policy`` carries the
registry's stable array id.
"""

from __future__ import annotations

import time as _time
from dataclasses import dataclass, field
from typing import List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .. import policy_registry
from ...obs import counters as obs
from . import coop as coop_mod
from .policies import BIG_CUT, ArrayPolicy, HorizonView, StepCtx
from .spec import SimSpec

_REQ_NONE = 1 << 24   # FIFO stamp sentinel: page not currently requested
_JIT_STEPS = 6        # LRU-clock jitter amplitude in step-lengths
_LOAD_MAX = 6         # load grants per step (credit caps at ~5 pages)
_PROG_MIN = 1.0       # tuples: a slice with less progress skips the EWMA
_BURST_W = 0.75       # burst-report weight in the speed estimate: the
                      # engine's per-burst EWMA samples the CPU rate
                      # between stalls and the effective rate at stall
                      # exits, so its estimate sits between the two
_RATE_JIT = 0.08      # per-(stream, query) CPU pacing skew amplitude
_GATE_P = 0.105       # blocked-scan window-refresh rate (engine wakes
                      # re-issue the prefetch window every ~10-20ms)
_DIP_P = 0.31         # fraction of steps a stream's push speed dips to
                      # its effective rate (stall-exit EWMA crash)
_DIP_DEPTH = 0.8     # dip floor as a fraction of the effective rate
_SEG_PAGES = 2.0      # engine segment_pages: plan entries pinned per burst
_SEG_WIN = 2          # static back-window (pages/column) the pin scan walks
_MAX_ABSORB = 3       # whole slices a wake-exact refresh step may absorb
                      # beyond its own tail (bounds the multi-slice PBM
                      # timeline shift and the jump-length cap)


class ArraySimConfig(NamedTuple):
    """Traced runtime knobs: a batch of configs (one per sweep point) can
    be stacked leaf-wise and vmapped over."""

    capacity_bytes: jax.Array   # f32 buffer-pool capacity
    bandwidth: jax.Array        # f32 bytes/sec of the I/O server
    policy: jax.Array           # i32 registry array id (policy_registry)
    max_time: jax.Array         # f32 livelock guard


class SimState(NamedTuple):
    # ---- per-page (P,) ---------------------------------------------------
    resident: jax.Array       # bool
    last_used: jax.Array      # f32 LRU clock
    req_step: jax.Array       # i32 FIFO stamp: step the page was first wanted
    req_tie: jax.Array        # i32 within-cohort service rank fixed at stamp
    fresh: jax.Array          # bool: loaded but not consumed since (churn)
    # ---- per-stream (S,) -------------------------------------------------
    qidx: jax.Array           # i32 current query (== n_q when stream done)
    pos: jax.Array            # f32 tuples consumed within current query
    speed: jax.Array          # f32 EWMA tuples/sec (effective, stalls incl.)
    consumed: jax.Array       # f32 lifetime tuples consumed (speed input)
    consumed_ref: jax.Array   # f32 `consumed` at the last slice boundary
    stream_done_t: jax.Array  # f32 finish time, -1 while running
    # ---- scalars ---------------------------------------------------------
    t: jax.Array              # f32 sim clock
    steps: jax.Array          # i32 simulation steps executed (macro steps
                              #   under the horizon stepper)
    slices_done: jax.Array    # i32 PBM slices elapsed — the livelock guard
                              #   compares THIS against max_slices (the
                              #   pre-PR-5 name miscounted: it was always a
                              #   slice count, never a time)
    io_credit: jax.Array      # f32 banked I/O bytes (partial in-flight load)
    io_bytes: jax.Array       # f32 lifetime loaded bytes (paper I/O volume)
    loads: jax.Array          # i32 lifetime page loads
    loads_demand: jax.Array   # i32 loads granted for a blocking frontier
    churn: jax.Array          # i32 loads evicted before any consumption
    # ---- policy-private state (one pytree per compiled ArrayPolicy) ------
    pstate: Tuple = ()


@dataclass
class ArrayResult:
    """Mirror of ``EngineResult`` for the array backend rows."""

    policy: str
    stream_times: List[float]
    total_io_bytes: float
    total_loads: int
    sim_time: float
    steps: int
    wall_s: float = 0.0
    extras: dict = field(default_factory=dict)

    @property
    def avg_stream_time(self) -> float:
        return sum(self.stream_times) / max(1, len(self.stream_times))

    @property
    def io_gb(self) -> float:
        return self.total_io_bytes / 1e9


def resolve_policies(
    policies: Optional[Sequence] = None,
) -> Tuple[ArrayPolicy, ...]:
    """Resolve a policy list (names and/or :class:`ArrayPolicy` objects)
    through the registry; ``None`` means every registered array policy.
    At most one cooperative policy may be compiled into one step."""
    if policies is None:
        policies = policy_registry.names(backend="array")
    out = []
    for p in policies:
        out.append(policy_registry.array_policy(p) if isinstance(p, str)
                   else p)
    if sum(p.cooperative for p in out) > 1:
        raise ValueError(
            "at most one cooperative policy per compiled step, got "
            f"{[p.name for p in out if p.cooperative]}"
        )
    return tuple(out)


class _View(NamedTuple):
    """Derived per-stream view of the current query + per-column cursors.
    Carried alongside :class:`SimState` so each step computes it once (this
    step's post-advance view is the next step's pre-advance view).

    The *frontier* of a column is its first page whose trigger
    (``max(page_first, scan_start)``) the scan cursor has not crossed yet —
    the engine's ``plan_idx`` restricted to that column.  ``ftrig`` is the
    fractional per-column cursor: the absolute tuple position at which the
    column next needs a page resident."""

    active: jax.Array    # (S,) bool
    length: jax.Array    # (S,) f32
    rate: jax.Array      # (S,) f32
    cols: jax.Array      # (S, C) bool
    start: jax.Array     # (S,) f32 absolute scan start
    cur: jax.Array       # (S,) f32 absolute cursor
    end: jax.Array       # (S,) f32 absolute scan end
    eps: jax.Array       # (S,) f32 cursor tolerance (f32 rounding guard)
    frontier: jax.Array  # (S, C) i32 local index of next unconsumed page
                         #   (== col_npages when the column is exhausted)
    fpidx: jax.Array     # (S, C) i32 global page id of the frontier (clamped)
    ftrig: jax.Array     # (S, C) f32 fractional per-column cursor (trigger)
    fneed: jax.Array     # (S, C) bool frontier exists inside the scan range


def _u01(idx, t, t_mult, idx_mult=2654435761):
    """Deterministic per-(lane, time) uniform draw in [0, 1): Knuth
    multiplicative hash of a lane index against a time-like salt, top 24
    bits scaled.  Pure — the jit/vmap-safe stand-in for an RNG stream
    everywhere the step needs the event engine's timing noise.  ``t`` may
    be a scalar (sim step / slice counter) or an array shaped like
    ``idx`` (per-lane stamps); distinct ``t_mult``/``idx_mult`` pairs
    decorrelate the independent noise sources."""
    h = idx.astype(jnp.uint32) * jnp.uint32(idx_mult) + \
        jnp.asarray(t).astype(jnp.uint32) * jnp.uint32(t_mult)
    return (h >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(2.0**-24)


def make_config(
    spec: SimSpec,
    capacity_bytes: float,
    bandwidth: float = 700e6,
    policy: str = "pbm",
    max_time: float = 3e5,
) -> ArraySimConfig:
    """Build one traced config.  ``policy`` is a registry name — the one
    name table in ``repro.core.policy_registry``; raw integer ids were a
    pre-registry shim and are now a hard error."""
    if not isinstance(policy, str):
        raise TypeError(
            f"make_config(policy={policy!r}): integer policy ids were "
            "removed — pass a registry name from "
            "repro.core.policy_registry.names(backend='array') "
            f"({policy_registry.names(backend='array')})"
        )
    entry = policy_registry.get(policy)
    if entry.array_id is None:
        raise KeyError(
            f"policy {policy!r} is event-engine-only; array-backend "
            f"policies: {policy_registry.names(backend='array')}"
        )
    pid = entry.array_id
    return ArraySimConfig(
        capacity_bytes=jnp.float32(capacity_bytes),
        bandwidth=jnp.float32(bandwidth),
        policy=jnp.int32(pid),
        max_time=jnp.float32(max_time),
    )


def stack_configs(cfgs: Sequence[ArraySimConfig]) -> ArraySimConfig:
    """Stack N configs leaf-wise into one batched config for vmap."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *cfgs)


def init_state(spec: SimSpec,
               policies: Sequence[ArrayPolicy] = ()) -> SimState:
    P, S = spec.n_pages, spec.n_streams
    n_q = jnp.asarray(spec.n_q)
    return SimState(
        resident=jnp.zeros(P, bool),
        last_used=jnp.full(P, -1e9, jnp.float32),
        req_step=jnp.full(P, _REQ_NONE, jnp.int32),
        req_tie=jnp.zeros(P, jnp.int32),
        fresh=jnp.zeros(P, bool),
        qidx=jnp.zeros(S, jnp.int32),
        pos=jnp.zeros(S, jnp.float32),
        speed=jnp.asarray(spec.q_rate[:, 0]),
        consumed=jnp.zeros(S, jnp.float32),
        consumed_ref=jnp.zeros(S, jnp.float32),
        stream_done_t=jnp.where(n_q > 0, -1.0, 0.0).astype(jnp.float32),
        t=jnp.float32(0.0),
        steps=jnp.int32(0),
        slices_done=jnp.int32(0),
        io_credit=jnp.float32(0.0),
        io_bytes=jnp.float32(0.0),
        loads=jnp.int32(0),
        loads_demand=jnp.int32(0),
        churn=jnp.int32(0),
        pstate=tuple(p.init_state(spec) for p in policies),
    )


def _evict_candidates(spec: SimSpec) -> int:
    """Eviction-candidate window (``vmax``) for the eviction kernel: the
    top-k priority pages considered per eviction call must cover a whole
    amortised batch (16 pages) of *maximum-size* pages even when the
    priority order is led by small column-tail / dimension-table pages —
    a multi-table pool mixes page sizes, the micro pool does not.  64 is
    the validated single-table floor; the median valid page size bounds
    how many candidates one batch can need, capped at 256 to keep the
    kernel's O(P * vmax)-ish work flat."""
    sizes = spec.page_size[spec.page_valid]
    if sizes.size == 0:
        return 64
    med = float(np.median(sizes))
    need = int(np.ceil(16 * float(np.max(sizes)) / max(med, 1.0))) + 16
    return int(min(256, max(64, need)))


def make_step(spec: SimSpec, dt: float, time_slice: float,
              prefetch_pages: int = 8, refresh: bool = False,
              policies: Sequence[ArrayPolicy] = ("lru", "pbm"),
              vmax: Optional[int] = None, stepper: str = "fixed",
              h_max: float = 8.0, h_io: float = 3.0,
              wake_exact: bool = True,
              page_axis: Optional[str] = None,
              telemetry: bool = False):
    """Build the pure ``step(carry, cfg) -> carry`` for a policy set.

    ``refresh=False`` is the cheap within-slice step; ``refresh=True`` is
    the once-per-``time_slice`` boundary step (the policies' ``StepCtx``
    carries the flag; PBM recomputes every page's next consumption and
    shifts its timeline there, and the step drops dead request-queue
    entries).  ``policies`` are the lanes this step can serve: a config's
    ``cfg.policy`` (registry array id) selects per lane between the
    policy-provided score/readahead/tie arrays — the step itself contains
    no per-policy branches.  Compiling a single policy specialises the
    step (no stacking, no unused machinery); compiling a cooperative
    policy (array-CScan) additionally builds the chunk-granular ABM
    substrate and blends the two consumption models per lane.

    ``stepper`` picks the time model:

    * ``"fixed"`` — every step advances the static ``dt``; the carry is
      ``(state, view)`` (bit-compatible with the pre-horizon engine);
    * ``"horizon"`` — the step advances a **variable** ``dt``: each step
      closes by computing the next step's event horizon (the earliest
      interesting time over the trigger-arrival / chunk-completion /
      io-credit / completion candidates, clipped to ``[dt, h_max*dt]``)
      together with the trigger window of the post-advance view, and the
      carry ``(state, view, win, rem, next_dt)`` threads both forward —
      so the window math is computed once per step in either mode.
      ``rem`` is the whole-fine-step budget left in the current PBM
      slice; the cheap step jumps ``min(next_h, rem - 1)`` fine steps
      and the refresh step absorbs the final (``<= h_max``) jump, which
      is what lets an uneventful slice collapse to ``ceil(n_inner /
      h_max)`` macro-steps — one at the smoke scales, where the slice
      fits inside ``h_max``.  ``h_io`` bounds
      the jump, in fine steps, while requests are pending — the
      wake-quantisation knob of the I/O-bound regime.

    ``wake_exact`` (STATIC, horizon only) replaces the supersaturated
    never-jump rule with the exact serial-server wake computation
    (DESIGN.md §10): with the request queue frozen at the end of a step,
    each queued page's grant step is solved in closed form
    (``kernels.ops.wake_solve``) and a supersaturated lane jumps
    straight to the first fine step that unblocks a stream — spanning
    slice boundaries when the refresh step absorbs up to ``_MAX_ABSORB``
    whole slices.  ``wake_exact=False`` restores the never-jump rule
    bit-for-bit.  Non-saturated lanes behave identically either way.

    ``page_axis`` (STATIC) is the mesh axis name of a page-sharded
    ``shard_map`` enclosure: the batched evict/grant kernels then scan
    only their own ``P / n`` pool slice for candidates and combine over
    the gathered compact lists — bitwise-identical to the unsharded
    path (see ``kernels.ops``).  The wake solve runs replicated (its
    output feeds lane-global jump decisions).

    ``telemetry`` is the STATIC obs knob (``repro.obs``, DESIGN.md §8):
    with it on, the step threads a :class:`~repro.obs.counters.Telemetry`
    pytree as the LAST carry element and accumulates jit-pure counters
    from values the step already computes; with it off (the default) the
    carry, the compiled program, and the results are exactly the
    pre-telemetry ones.
    """
    from repro.kernels import ops as kops

    if stepper not in ("fixed", "horizon"):
        raise ValueError(f"unknown stepper {stepper!r}: fixed | horizon")
    horizon = stepper == "horizon"
    policies = resolve_policies(policies)
    P, S, Q, C = spec.n_pages, spec.n_streams, spec.n_queries, spec.n_cols
    vmax = _evict_candidates(spec) if vmax is None else int(vmax)
    K = int(prefetch_pages)
    # deepest per-column readahead actually reachable: the plan-entry-count
    # window spreads ~K entries over the scanned columns, so the scatter
    # only needs to walk K_LOOP+1 slots per (stream, column)
    K_LOOP = min(K, 4)
    # static per-column trigger lookahead: the most page triggers a scan
    # can cross in one step, plus one for the conservative advance cap.
    # The horizon stepper sizes it for the longest jump it can take —
    # h_max fine steps, or the whole slice when that is shorter (every
    # jump, the refresh tail included, is bounded by min(h_max, n_inner)
    # fine steps: inner_cond hands the tail to the refresh step only
    # once the clipped next_h reaches it),
    # with the compiler's per-column max-rate geometry keeping the window
    # from exploding on columns no fast scan ever touches.
    # one PBM slice is a whole number of fine steps (the fixed cadence
    # always rounded it so, and the whole validated envelope of PR 1-4
    # was fit against that rounding with the bucket math still using the
    # configured ``time_slice``).  The horizon stepper keeps BOTH: its
    # macro-steps are integer multiples of the fine step (``h`` fine
    # steps in one jump — which makes a non-jumping horizon run
    # bit-equal to the fixed stepper), and its slice budget is the same
    # ``n_inner`` fine steps.  At the deep-thrash operating points the
    # churn spiral is cliff-sensitive even to sub-ulp step-length drift
    # (a byte-credit equality at the grant boundary), which is exactly
    # why time is quantised instead of accumulated as f32 remainders.
    n_inner = max(1, int(round(time_slice / float(dt))))
    if horizon:
        h_max_i = max(1, int(round(h_max)))
        dt_long = float(dt) * min(h_max_i, n_inner)
        W = spec.trigger_window(max(float(dt), dt_long), tight=True)
        # budgeted FIFO pops per step: enough to drain an h_io-page jump
        # plus the banked credit (the fixed step's 6 cover ~2 pages + bank)
        n_rounds_io = max(_LOAD_MAX, int(round(h_io)) + 2)
        if wake_exact:
            # wake-exact supersaturated jumps span at most the slice
            # budget plus _MAX_ABSORB absorbed slices (and never more
            # than 64 fine steps — the wake solve's h_cap); the grant's
            # candidate window must cover every pop such a jump stands
            # in for.  Growing vmax alone is results-neutral: strict
            # head-of-line truncates at the pops SCALAR, which keeps the
            # PR-9 cap (n_rounds_io) on non-saturated lanes.
            wake_cap_i = min(64, max(h_max_i, (1 + _MAX_ABSORB) * n_inner))
            n_rounds = max(n_rounds_io, wake_cap_i * _LOAD_MAX)
        else:
            wake_cap_i = h_max_i
            n_rounds = n_rounds_io
    else:
        h_max_i = 1
        W = spec.trigger_window(float(dt))
        n_rounds = n_rounds_io = _LOAD_MAX
        wake_cap_i = 1
    dt_ref = jnp.float32(dt)
    h_io_f = jnp.float32(h_io)
    time_slice_f = jnp.float32(time_slice)

    page_size = jnp.asarray(spec.page_size)
    page_first = jnp.asarray(spec.page_first)
    page_last = jnp.asarray(spec.page_last)
    page_col = jnp.asarray(spec.page_col)
    page_valid = jnp.asarray(spec.page_valid)
    col_start = jnp.asarray(spec.col_start)
    col_npages = jnp.asarray(spec.col_npages)
    col_tpp = jnp.asarray(spec.col_tpp)
    q_start = jnp.asarray(spec.q_start)
    q_len = jnp.asarray(spec.q_len)
    q_rate = jnp.asarray(spec.q_rate)
    q_cols = jnp.asarray(spec.q_cols)
    n_q = jnp.asarray(spec.n_q)
    s_idx = jnp.arange(S)
    max_page = jnp.float32(float(np.max(spec.page_size)))
    INF = jnp.float32(np.inf)
    # supersaturation threshold for the horizon's io-credit candidate: the
    # aggregate plan-window bytes every stream can keep requested at once
    sat_bytes = jnp.float32(S * K * float(np.max(spec.page_size)))

    # ---- policy dispatch tables (policy-provided, id-indexed) ------------
    n_pol = len(policies)
    ids = policy_registry.array_ids()
    max_id = max(ids.values())
    lookup_np = np.zeros(max_id + 1, np.int32)
    valid_np = np.zeros(max_id + 1, bool)
    for j, p in enumerate(policies):
        lookup_np[ids[p.name]] = j
        valid_np[ids[p.name]] = True
    lookup = jnp.asarray(lookup_np)
    id_valid = jnp.asarray(valid_np)
    k_wins_np = np.asarray([p.request_window(spec, K) for p in policies],
                           np.int32)
    coop_idx = next((j for j, p in enumerate(policies) if p.cooperative),
                    None)
    has_coop = coop_idx is not None
    coop_flags = jnp.asarray([p.cooperative for p in policies])
    if has_coop:
        cc = coop_mod.coop_consts(spec)
        if spec.q_table is None:
            raise ValueError(
                "cooperative policy needs the multitable query-table map; "
                "lower the workload with compiler.compile_workload"
            )
        q_table = jnp.asarray(spec.q_table)

    def query_view(qidx, pos) -> _View:
        """Gather the per-stream view of the current query + per-column
        frontier cursors (plan-trigger granular, see :class:`_View`)."""
        qi = jnp.clip(qidx, 0, Q - 1)
        active = qidx < n_q
        start = q_start[s_idx, qi]
        length = q_len[s_idx, qi]
        rate = q_rate[s_idx, qi]
        cols = q_cols[s_idx, qi]                       # (S, C)
        cur = start + pos
        end = start + length
        # tolerance for "has the cursor crossed this trigger": one tuple
        # plus the f32 ulp of the cursor magnitude, so rounding in
        # ``cur + adv`` can never strand a trigger in limbo
        eps = 1.0 + 4e-7 * end
        local = jnp.floor(cur[:, None] / col_tpp[None, :]).astype(jnp.int32)
        local = jnp.clip(local, 0, col_npages[None, :] - 1)
        # page boundaries are exact ints but tpp is fractional: correct the
        # division so cur lands in [first, last) of its page (a cursor at a
        # boundary must map to the NEXT page or it stalls with adv_lim=0)
        pidx0 = col_start[None, :] + local
        local = local + (cur[:, None] >= page_last[pidx0]).astype(jnp.int32)
        local = local - (cur[:, None] < page_first[pidx0]).astype(jnp.int32)
        local = jnp.clip(local, 0, col_npages[None, :] - 1)
        pidx0 = col_start[None, :] + local
        # frontier: the containing page iff its trigger is still ahead of
        # (or at) the cursor, else the next page.  The trigger of the page
        # straddling the scan start is the start itself (engine plan).
        trig0 = jnp.maximum(page_first[pidx0], start[:, None])
        consumed0 = trig0 < cur[:, None] - eps[:, None]
        frontier = local + consumed0.astype(jnp.int32)  # (S, C), may == np
        fpidx = col_start[None, :] + jnp.minimum(
            frontier, col_npages[None, :] - 1
        )
        ftrig = jnp.maximum(page_first[fpidx], start[:, None])
        fneed = (
            active[:, None]
            & cols
            & (frontier < col_npages[None, :])
            & (page_first[fpidx] < end[:, None])
        )
        return _View(active, length, rate, cols, start, cur, end, eps,
                     frontier, fpidx, ftrig, fneed)

    def _sel(is_coop, coop_val, inorder_val):
        """Per-lane blend between the cooperative and in-order models.
        Specialises away when the compiled policy set is single-model."""
        if not has_coop:
            return inorder_val
        if n_pol == 1:
            return coop_val
        return jnp.where(is_coop, coop_val, inorder_val)

    # window of the next W+1 page triggers per (stream, column): entries
    # w < W gate the advance (block at the first absent trigger), entry
    # W is the conservative cap so one step never outruns the window
    wk = jnp.arange(W + 1)                                  # (W+1,)

    def window(view: _View):
        """Trigger-window geometry of a view: global page ids, triggers,
        need mask and cursor distance of the next W+1 plan triggers per
        (stream, column).  The fixed step computes it on its own view;
        the horizon step computes it once on the post-advance view and
        carries it to the next step (this step's ``view2`` window IS the
        next step's ``view`` window)."""
        w_local = view.frontier[:, :, None] + wk[None, None, :]
        w_pidx = col_start[None, :, None] + jnp.minimum(
            w_local, col_npages[None, :, None] - 1
        )
        w_trig = jnp.maximum(page_first[w_pidx], view.start[:, None, None])
        w_need = (
            view.fneed[:, :, None]
            & (w_local < col_npages[None, :, None])
            & (page_first[w_pidx] < view.end[:, None, None])
        )
        w_dist = jnp.maximum(w_trig - view.cur[:, None, None], 0.0)
        return w_pidx, w_trig, w_need, w_dist

    def adv_limit(win, resident):
        """Per-stream advance limit against a residency: distance to the
        first absent trigger, capped at the (W+1)-th trigger when every
        windowed page is resident (W is sized so the cap exceeds the
        longest step's advance for a full window)."""
        w_pidx, _w_trig, w_need, w_dist = win
        absent = w_need[:, :, :W] & ~resident[w_pidx[:, :, :W]]
        lim = jnp.min(jnp.where(absent, w_dist[:, :, :W], INF), axis=2)
        cap = jnp.where(w_need[:, :, W], w_dist[:, :, W], INF)
        return jnp.min(jnp.minimum(lim, cap), axis=1)       # (S,)

    def core(state: SimState, view: _View, win, cfg: ArraySimConfig, dt,
             h_u, adv_lim_in=None, pend_in=None, slices_u=None, tele=None):
        """One simulation step of length ``dt`` == ``h_u`` fine steps
        (``h_u`` is the static 1 under the fixed stepper, a traced i32
        under the horizon stepper — a macro-step stands in for ``h_u``
        fine steps and scales the per-fine-step processes accordingly).
        ``adv_lim_in`` is the carried advance limit the previous horizon
        step computed against this step's residency (the horizon IS that
        computation, so it is never done twice)."""
        # a config whose policy id is NOT in this step's compiled set must
        # not silently run as some other policy (a mislabeled lane in a
        # stacked sweep would be wrong science with no diagnostic).  A jit
        # step cannot raise, so an invalid lane trips the livelock guard
        # on its first step: the run terminates immediately with every
        # stream unfinished and ``extras["truncated"] = True`` — the flag
        # every harness already refuses to compare.
        ok_id = (
            (cfg.policy >= 0) & (cfg.policy <= max_id)
            & id_valid[jnp.clip(cfg.policy, 0, max_id)]
        )
        t2 = state.t + jnp.where(ok_id, dt, cfg.max_time + 1.0)
        pol_local = lookup[jnp.clip(cfg.policy, 0, max_id)]
        is_coop = coop_flags[pol_local] if has_coop else False
        # supersaturation of this lane (pool below the scans' aggregate
        # plan-window bytes): selects the wake-exact jump model in the
        # horizon tail and the matching pop cap at the macro grant
        sat = cfg.capacity_bytes < sat_bytes

        # ============ CPU: consume up to the first absent trigger =========
        (active, length, rate, _cols, start, cur, end, eps, frontier,
         _fpidx, _ftrig, fneed) = view
        # tie-break jitter for the LRU clock: every touch/load in one step
        # would otherwise share the exact timestamp t2, and eviction would
        # break those ties by page index — a SYSTEMATIC bias that carves
        # the pool into a stable always-evicted side and a resident elite
        # whose hit rate the event engine (with its total event-order
        # recency) never reaches.  A deterministic per-(page, step) hash
        # spanning _JIT_STEPS fine-step-lengths reproduces the engine's
        # order noise (its touch events spread over multi-step burst
        # intervals, so recency may genuinely invert across a few
        # neighbouring steps) while staying pure for jit/vmap (no RNG
        # state).  The amplitude is calibrated against the event engine at
        # the small-pool points, in units of the FINE step — a horizon
        # macro-step must not inflate it.
        jit_p = _JIT_STEPS * dt_ref * _u01(jnp.arange(P, dtype=jnp.uint32),
                                           state.steps, 40503)
        w_pidx, w_trig, w_need, w_dist = win                # (S, C, W+1)
        # per-(stream, query) CPU-rate skew: the event engine's burst
        # granularity paces each scan on its own event clock, so two scans
        # at the same position drift apart within a query; the fluid step
        # advances them in perfect lockstep, freezing phase alignments
        # that inflate sharing at tiny pools (zero-mean per-STEP noise
        # integrates away — the drift must be sustained within a query to
        # outrun a small pool's residency window, while a large pool still
        # tolerates it, exactly like the engine).  Deterministic hash of
        # (stream, query): pure, vmap-safe, zero-mean across queries.
        ur = _u01(jnp.arange(S, dtype=jnp.uint32), state.qidx, 48271)
        rate_j = rate * (1.0 + _RATE_JIT * (2.0 * ur - 1.0))
        adv_lim = adv_limit(win, state.resident) if adv_lim_in is None \
            else adv_lim_in
        runnable = active & (adv_lim > 0.0)
        remaining = length - state.pos
        adv_io = jnp.where(
            runnable,
            jnp.minimum(jnp.minimum(rate_j * dt, remaining), adv_lim),
            0.0,
        )
        adv_io = jnp.maximum(adv_io, 0.0)

        margin = jnp.maximum(0.5, 3e-5 * length)
        finished_io = runnable & (remaining - adv_io <= margin)

        # ============ cooperative CPU model (compiled on demand) ==========
        if has_coop:
            cstate: coop_mod.CoopState = state.pstate[coop_idx]
            q_tab = q_table[s_idx, jnp.clip(state.qidx, 0, Q - 1)]
            coop_cpu = coop_mod.cpu_phase(
                cc, cstate, active=active, start=start, end=end,
                cols=_cols, q_tab=q_tab, rate_j=rate_j, dt=dt,
                credit_cap=rate_j * dt, resident=state.resident,
                page_col=page_col, page_valid=page_valid, s_idx=s_idx,
            )
            adv = _sel(is_coop, coop_cpu.adv, adv_io)
            finished = _sel(is_coop, coop_cpu.finished, finished_io)
        else:
            adv, finished = adv_io, finished_io
        # invalid-lane freeze (see ok_id above): no consumption, no
        # completions — the lane must end truncated, not half-run
        adv = jnp.where(ok_id, adv, 0.0)
        finished = finished & ok_id
        cur2_pre = cur + adv_io

        qidx2 = state.qidx + finished.astype(jnp.int32)
        pos2 = jnp.where(finished, 0.0, state.pos + adv)
        newly_done = (qidx2 >= n_q) & (state.stream_done_t < 0)
        stream_done_t2 = jnp.where(newly_done, t2, state.stream_done_t)

        # speed estimation on the engine's report cadence, not per step: a
        # per-step EWMA collapses a blocked scan's estimate toward zero in
        # a few ms, which sends the very pages it waits for to far-future
        # buckets.  The engine instead measures (Δvirt_pos / Δt) between
        # consecutive bursts — stall time folded in, progressless intervals
        # skipped — so the estimate tracks the *effective* scan rate.  The
        # array analogue updates once per PBM slice from the cumulative
        # consumed-tuples counter (refresh step below).
        consumed2 = state.consumed + adv
        next_rate = q_rate[s_idx, jnp.clip(qidx2, 0, Q - 1)]
        speed1 = jnp.where(finished, next_rate, state.speed)  # fresh scan
        if refresh:
            prog = consumed2 - state.consumed_ref
            # a wake-exact refresh step may stand in for several slices:
            # the burst-report cadence is then slices_u slice lengths
            if slices_u is None:
                inst = prog / time_slice_f
            else:
                inst = prog / (time_slice_f
                               * slices_u.astype(jnp.float32))
            speed2 = jnp.where(
                active & (prog > _PROG_MIN) & ~finished,
                _BURST_W * next_rate + (1.0 - _BURST_W) * inst,
                speed1,
            )
            consumed_ref2 = consumed2
        else:
            speed2 = speed1
            consumed_ref2 = state.consumed_ref

        # pages consumed this step: resident windowed pages whose trigger
        # the cursor crossed (same predicate the next view's frontier uses,
        # so crossing and frontier advance can never disagree)
        crossed = (
            w_need[:, :, :W]
            & runnable[:, None, None]
            & state.resident[w_pidx[:, :, :W]]
            & (w_trig[:, :, :W] < (cur2_pre - eps)[:, None, None])
        )
        if has_coop:
            crossed = crossed & jnp.logical_not(is_coop)
        cross_pidx = w_pidx[:, :, :W]
        # engine parity: the LRU clock ticks when a page is consumed, and
        # only the pages of the running burst are pinned — a blocked scan
        # pins nothing, a mid-page scan pins nothing it already consumed
        last_used2 = state.last_used.at[cross_pidx].max(
            jnp.where(crossed, t2 + jit_p[cross_pidx], -INF)
        )
        if has_coop:
            touched_coop = is_coop & coop_cpu.consumed_pages
            last_used2 = jnp.where(touched_coop, t2 + jit_p, last_used2)

        # ================= post-advance view (I/O demand) =================
        view2 = query_view(qidx2, pos2)
        (active2, _l2, rate2, cols2, start2, cur2, end2, eps2, frontier2,
         fpidx2, ftrig2, need2) = view2

        # request set = the engine's plan window: the blocking page (the
        # trigger the cursor sits on) plus the next ~K *plan entries* in
        # (trigger, column, page) order.  Crucially a far-trigger frontier
        # page (a sparse column whose next boundary is many dense-pages
        # ahead) is NOT demanded early: the engine only requests it once it
        # enters the plan window.  Early fetches age out of a small pool
        # and reload — churn the engine does not have.
        inv_tpp = 1.0 / col_tpp[None, :]
        dens = jnp.sum(jnp.where(need2, inv_tpp, 0.0), axis=1, keepdims=True)
        # one fused scatter over K_LOOP+1 plan-window slots per (stream,
        # column); K_LOOP bounds the per-column scatter walk
        ks = jnp.arange(K_LOOP + 1)                    # (K_LOOP+1,)
        pf_local = frontier2[:, :, None] + ks[None, None, :]
        exists = (pf_local < col_npages[None, :, None]) & need2[:, :, None]
        pf_pidx = col_start[None, :, None] + jnp.minimum(
            pf_local, col_npages[None, :, None] - 1
        )
        pf_trig = jnp.maximum(page_first[pf_pidx], start2[:, None, None])
        exists &= page_first[pf_pidx] < end2[:, None, None]
        # the engine prefetches the next K *plan entries* — an entry-COUNT
        # window over the (trigger, column, page) plan order, resident
        # entries included in the budget.  The count cut matters: it can
        # leave a same-trigger group partner just outside the window, to be
        # discovered only at the next wake (see the request gate below) —
        # the separation behind the engine's small-pool churn.
        e_trig = jnp.where(exists, pf_trig, INF)
        flat_trig = e_trig.reshape(S, C * (K_LOOP + 1))
        flat_ord = jnp.argsort(jnp.argsort(flat_trig, axis=1), axis=1)
        # argsort twice = rank in the plan order; jnp.argsort is stable, so
        # ties resolve by (column, page) flat position — the engine's plan
        # sort key (trigger, column, index)
        rank = flat_ord.reshape(S, C, K_LOOP + 1)
        # the k=0 slot (the frontier itself) is always requested once its
        # trigger reaches the cursor — the blocking demand — even with
        # prefetch disabled
        blocking = (ks[None, None, :] == 0) & (
            pf_trig <= (cur2 + eps2)[:, None, None]
        )
        # request cadence gate, engine parity: a scan issues requests only
        # while it runs (burst ends) and at the instant it blocks — a
        # blocked scan's window is FROZEN until its demand loads.  Pages
        # entering the window mid-wait are not requested until the wake,
        # which is what separates group partners into distant queue
        # positions (continuous re-wanting erased that separation and with
        # it most of the engine's small-pool churn).
        ug = _u01(jnp.arange(S, dtype=jnp.uint32), state.steps,
                  3266489917, idx_mult=2246822519)
        # the engine's refresh rate follows its wake rate, which rises
        # with I/O pressure: scale by the lifetime duty cycle (a stalled
        # scan wakes per demand load ~= often; a CPU-bound scan re-issues
        # only per burst, where the continuous window already covers it)
        duty_g = jnp.clip(
            (state.consumed / jnp.maximum(state.t, 1e-9))
            / jnp.maximum(rate, 1.0),
            0.0, 1.0,
        )
        gate_p = _GATE_P * (1.0 - duty_g)
        if horizon:
            # a macro-step stands in for h_u fine steps: the blocked-scan
            # window-refresh is a per-fine-step Bernoulli process, so the
            # macro step fires it with the compounded probability —
            # otherwise longer jumps would silently freeze blocked
            # windows.  h_u == 1 keeps gate_p exactly (bit-parity with
            # the fixed stepper; pow would round at the ulp level).
            gate_p = jnp.where(
                h_u == 1, gate_p,
                1.0 - (1.0 - gate_p) ** h_u.astype(jnp.float32),
            )
        gate = (
            (adv_io > 0.0) | (state.steps == 0) | finished | (ug < gate_p)
        )
        # per-policy readahead width (ArrayPolicy.request_window): e.g. the
        # array LRU widens the engine's 8-entry window at single-table
        # deep thrash — a policy-provided value, indexed by the lane's id
        if n_pol == 1:
            k_win = int(k_wins_np[0])
        else:
            k_win = jnp.asarray(k_wins_np)[pol_local]
        # the blocking demand is exempt from the gate: the engine requests
        # the page it blocks on unconditionally, and a frontier page that
        # was resident at the block transition but evicted during the wait
        # would otherwise stall for a geometric number of steps before its
        # demand is even queued
        ok = exists & (((rank <= k_win) & gate[:, None, None]) | blocking)
        kb = jnp.where(ks == 0, 31, jnp.clip(K_LOOP + 1 - ks, 1, 30))
        bonus = jnp.full(P, -1, jnp.int32).at[pf_pidx].max(
            jnp.where(ok, kb[None, None, :], -1)
        )
        in_plan_window = (bonus >= 0) & ~state.resident & page_valid
        # FIFO request queue, array-form: every page keeps the step at which
        # it was first requested, and — engine parity — the request STAYS
        # queued after the cursor's plan window moves past it: the engine
        # only drops an entry when the page loads or the requesting query
        # ends.  Those stale early fetches (served hundreds of grants after
        # they were issued, evicted before their scan arrives, re-requested)
        # are most of the engine's small-pool churn; forgetting them made
        # the array 15-25% too fast below 20% buffer.  The array clears
        # stamps on load, and at each slice refresh for pages no active
        # scan is interested in (the query-end drop, slice-quantised).
        wanted = in_plan_window | (
            (state.req_step != _REQ_NONE) & ~state.resident & page_valid
        )
        req_step2 = jnp.where(
            wanted, jnp.minimum(state.req_step, state.steps + 1), _REQ_NONE
        )
        # strict FIFO by first-wanted step (engine parity: a demand request
        # does NOT jump ahead of older readahead in the serial queue).
        # Ties within one step's cohort resolve by a hash fixed at stamp
        # time — the engine's enqueue order is equally arbitrary, but a
        # deterministic page-index order would serve the same streams first
        # every cohort and freeze fine phase alignments between overlapping
        # scans that the event engine's noise dissolves.  The bonus only
        # defines membership of the wanted set, not the service order.
        stamp_age = jnp.clip(state.steps + 1 - req_step2, 0, 32767)
        # within-cohort service order: the engine enqueues a woken scan's
        # whole window CONTIGUOUSLY (one event = adjacent queue slots), and
        # the order of scans within one array step is event-timing noise.
        # So the cohort rank is (stream hash, plan rank) — a per-stream
        # block — fixed at stamp time like the engine's queue position.
        s_ord = (512.0 * _u01(jnp.arange(S, dtype=jnp.uint32),
                              state.steps, 40503)).astype(jnp.int32)
        slot = s_ord[:, None, None] * 64 + jnp.clip(rank, 0, 63)
        tie_now = jnp.full(P, 32767, jnp.int32).at[pf_pidx].min(
            jnp.where(ok, slot, 32767)
        )
        new_stamp = wanted & (state.req_step == _REQ_NONE)
        req_tie2 = jnp.where(new_stamp, tie_now, state.req_tie)
        tie_blk = 32767 - req_tie2
        tie_idx = 32767 - jnp.arange(P, dtype=jnp.int32)
        # per-policy cohort order (ArrayPolicy.fifo_tie): the array LRU
        # tracks the engine best with the stream-block order; estimate-
        # driven policies with the plan-deterministic index order (their
        # scores already absorb the timing noise)
        tie_tab = [tie_idx if p.fifo_tie == "plan" else tie_blk
                   for p in policies]
        if n_pol == 1:
            tie15 = tie_tab[0]
        else:
            tie15 = jnp.stack(tie_tab)[pol_local]
        load_key = jnp.where(wanted, stamp_age * 32768 + tie15, -1)

        # ================= I/O server: budgeted admission =================
        used = jnp.sum(page_size * state.resident)
        free = cfg.capacity_bytes - used
        # engine parity: a running scan pins the pages of its current CPU
        # burst — the last ~segment_pages plan entries behind the cursor —
        # for the burst's duration; a blocked scan pins nothing, so pools
        # far below streams x columns pages cannot livelock.  The array
        # analogue pins pages whose trigger lies within a segment length
        # (segment_pages plan entries ~= seg/dens tuples) behind the cursor
        # of a stream that advanced this step.
        seg_len = _SEG_PAGES / jnp.maximum(dens[:, 0], 1e-30)  # (S,) tuples
        bk = jnp.arange(_SEG_WIN)                           # (B,)
        b_local = frontier2[:, :, None] - 1 - bk[None, None, :]
        b_pidx = col_start[None, :, None] + jnp.clip(
            b_local, 0, col_npages[None, :, None] - 1
        )
        b_trig = jnp.maximum(page_first[b_pidx], start2[:, None, None])
        burst = (
            (b_local >= 0)
            & (cols2 & active2[:, None])[:, :, None]
            & runnable[:, None, None]
            & (b_trig >= (cur2 - seg_len)[:, None, None])
        )
        pin = jnp.zeros(P, jnp.int32).at[b_pidx].max(burst.astype(jnp.int32))
        evictable_io = state.resident & (pin == 0) & page_valid

        # ============ cooperative I/O model (compiled on demand) ==========
        if has_coop:
            done3 = coop_mod.clear_on_query_change(
                coop_cpu.done, coop_cpu.finished
            )
            q_tab2 = q_table[s_idx, jnp.clip(qidx2, 0, Q - 1)]
            coop_io = coop_mod.io_phase(
                cc, done=done3, cur_chunk=coop_cpu.cur_chunk,
                inflight=cstate.inflight, pin_pages=coop_cpu.pin_pages,
                active=active2, start=start2, end=end2, cols=cols2,
                q_tab=q_tab2, resident=state.resident, free=free,
                page_chunk_sizes=page_size, page_col=page_col,
                page_valid=page_valid, n_streams=S,
            )
            load_key = _sel(is_coop, coop_io.load_key, load_key)
            wanted = _sel(is_coop, coop_io.wanted, wanted)
            evictable = _sel(is_coop, coop_io.evictable, evictable_io)
        else:
            evictable = evictable_io
        evictable_bytes = jnp.sum(page_size * evictable)
        headroom = free + evictable_bytes
        credit = state.io_credit + cfg.bandwidth * dt

        # an invalid lane's server grants nothing (ok_id freeze)
        budget = jnp.where(ok_id, jnp.minimum(credit, headroom), 0.0)
        if horizon:
            # serial-server causality over a macro-step: credit accrued
            # while the queue was EMPTY must not fund requests that only
            # appear at the end of the jump (the engine's idle server
            # banks about one fine step of work, no more).  Cap this
            # step's serviceable bytes at the queue content present when
            # the jump began plus one fine step's credit — which also
            # makes the cap vacuous at h_u == 1 (fixed-stepper parity).
            # The queue bytes were computed by the previous step's
            # horizon (they ARE its io-credit candidate) and carried.
            pend_bytes0 = pend_in
            if has_coop:
                infl0 = cstate.inflight
                pend_c0 = (
                    (cc.page_chunk == jnp.clip(infl0, 0, cc.n_chunks - 1))
                    & (infl0 >= 0) & ~state.resident & page_valid
                )
                pend_bytes0 = _sel(
                    is_coop, jnp.sum(page_size * pend_c0), pend_bytes0
                )
            budget = jnp.minimum(
                budget,
                state.io_credit + pend_bytes0 + cfg.bandwidth * dt_ref,
            )
            # budgeted FIFO pop as ONE batched grant op — the macro grant
            # covers an h_io-fine-step jump without n_rounds serial
            # argmax passes over the page axis (Pallas MXU prefix kernel
            # on TPU, top_k + prefix-product oracle elsewhere).
            # Semantics match the fixed loop: strict head-of-line (the
            # first page that does not fit blocks the rest), ties by
            # lower page index, _LOAD_MAX pops per fine step stood in
            # for — all inside the static n_rounds top-k window, which
            # therefore also caps a multi-step grant's pop count (the
            # byte budget of an h_io-step jump fits the window at the
            # validated operating points; credit a short window leaves
            # unspent banks for the next step, like the fixed path's
            # leftover credit).
            if wake_exact:
                # non-saturated lanes keep the PR-9 pop cap bit-for-bit;
                # a wake-exact supersaturated jump needs every pop its
                # fine steps would have taken
                pop_cap = jnp.where(sat, n_rounds, n_rounds_io)
            else:
                pop_cap = n_rounds
            pops = jnp.minimum(h_u * _LOAD_MAX, pop_cap)
            load_mask, load_bytes, n_load = kops.fifo_grant(
                load_key, page_size, budget, pops, vmax=n_rounds,
                page_axis=page_axis,
            )
            cand = cand_ok = None
        else:
            # the server grants at most ~credit bytes (a handful of pages)
            # per step: pop the FIFO head a few times instead of sorting
            # anything.  Head-of-line semantics: the first page that does
            # not fit blocks the rest of the queue, like the engine's
            # serial server.
            kcur = load_key
            taken = jnp.float32(0.0)
            open_ = jnp.bool_(True)
            arange_p = jnp.arange(P)
            hit = jnp.zeros(P, bool)
            cand = []
            cand_ok = []
            for _ in range(n_rounds):
                j = jnp.argmax(kcur)
                ok_j = open_ & (kcur[j] >= 0) & (
                    taken + page_size[j] <= budget
                )
                open_ = ok_j
                is_j = arange_p == j   # arithmetic mask: fuses, scatter won't
                hit = hit | (is_j & ok_j)
                taken = taken + jnp.where(ok_j, page_size[j], 0.0)
                kcur = jnp.where(is_j, -1, kcur)
                cand.append(j)
                cand_ok.append(ok_j)
            load_mask = hit
            cand = jnp.stack(cand)                     # (n_rounds,)
            cand_ok = jnp.stack(cand_ok)
            load_bytes = taken
            n_load = jnp.sum(cand_ok)

        # bank leftover credit instead of zeroing it whenever the request
        # queue went momentarily empty — that dropped the partially-funded
        # head-of-line load and made effective bandwidth dip below
        # cfg.bandwidth on bursty workloads.  The cap stays at 4 pages
        # while requests remain unserved (funding the next grants); with an
        # empty queue one page-time is kept, compensating the idle server's
        # ability to start a load the instant a request arrives mid-step
        # (the engine's serial server never banks more idle time than that).
        leftover = credit - load_bytes
        starved_io = jnp.sum(wanted & ~load_mask) > 0
        credit_cap = jnp.where(starved_io, 4 * max_page, max_page)
        io_credit2 = jnp.minimum(leftover, credit_cap)

        # engine speed-estimate DIPS: the dict engine's per-burst EWMA
        # crashes toward the effective rate at every stall exit, and pages
        # pushed during a dip land in far-future buckets — prime eviction
        # victims although their consumption is imminent.  That mis-push
        # churn (7% of engine loads at 40% buffer, ~20% at 10%) never
        # happens with a smooth estimate, leaving the array faster than
        # the machine it models.  Sample the dips per (stream, step).
        ud = _u01(jnp.arange(S, dtype=jnp.uint32), state.steps, 3266489917)
        eff_rate = jnp.clip(
            state.consumed / jnp.maximum(state.t, 1e-9),
            1.0, None,
        )
        dip_p = jnp.float32(_DIP_P)
        if horizon:
            # the dip is a per-FINE-step Bernoulli (calibrated against
            # the engine's stall-exit EWMA crashes): a macro-step
            # standing in for h_u fine steps fires it with the
            # compounded probability, like the request gate above —
            # h_u == 1 keeps _DIP_P exactly (fixed-stepper bit parity).
            # Without this the wake-exact path under-samples dips and
            # ran ~18% too fast at the 10% deep-thrash point.
            dip_p = jnp.where(
                h_u == 1, dip_p,
                1.0 - (1.0 - dip_p) ** h_u.astype(jnp.float32),
            )
        speed_push = jnp.where(
            ud < dip_p, jnp.minimum(_DIP_DEPTH * eff_rate, speed2), speed2
        )

        # ================= policy hooks + batched eviction ================
        # pages whose consumption state changed this step (feeds the churn
        # diagnostic below and PBM's within-slice update set)
        was_crossed = jnp.zeros(P, bool).at[cross_pidx].max(crossed)
        if has_coop:
            was_crossed = _sel(is_coop, coop_cpu.consumed_pages,
                               was_crossed)
        if horizon:
            # compacted within-slice update set: the padded (S, C, W)
            # cross window grows with the horizon's longer trigger
            # lookahead, but the pages that actually changed stay few —
            # hand the policies a dense id list instead of the padded
            # window (duplicates and the fill id carry ``upd_on`` False
            # or an identical update value, so the min-combining scatter
            # is unchanged; overflow beyond the static cap merely leaves
            # a page's bucket stale until the slice refresh).
            upd_mask = (was_crossed | load_mask) & page_valid
            upd_pages = jnp.nonzero(upd_mask, size=min(P, 512),
                                    fill_value=0)[0]
            upd_on = upd_mask[upd_pages]
        else:
            upd_pages = upd_on = None
        ctx = StepCtx(
            spec=spec, refresh=refresh, time_slice=time_slice_f, now=t2,
            steps=state.steps, slices_done=state.slices_done,
            slices_elapsed=slices_u, dt=dt,
            page_first=page_first, page_last=page_last, page_col=page_col,
            page_valid=page_valid, resident=state.resident,
            last_used=last_used2, load_mask=load_mask, load_cand=cand,
            load_ok=cand_ok, cross_pidx=cross_pidx, crossed=crossed,
            upd_pages=upd_pages, upd_on=upd_on,
            active=active2, cols=cols2, cur=cur2, end=end2, start=start2,
            eps=eps2, rate=rate2, speed_push=speed_push,
            coop=coop_io if has_coop else None,
        )
        pstate2 = []
        for p, ps in zip(policies, state.pstate):
            if p.cooperative:
                # the cooperative substrate owns its state transitions
                pstate2.append(coop_mod.CoopState(
                    done=done3, cur_chunk=coop_cpu.cur_chunk,
                    chunk_pos=coop_cpu.chunk_pos, credit=coop_cpu.credit,
                    inflight=coop_io.inflight,
                ))
            else:
                pstate2.append(p.on_consume(p.on_request(ps, ctx), ctx))
        keys = [p.score_victims(ps, ctx)
                for p, ps in zip(policies, pstate2)]
        if n_pol == 1:
            key = keys[0]
        else:
            key = jnp.stack(keys)[pol_local]

        if refresh:
            # query-end request drop, slice-quantised: pending requests for
            # pages no active scan is interested in leave the queue
            interested = (ctx.eta_estimate() < BIG_CUT) & page_valid
            req_step2 = jnp.where(interested, req_step2, _REQ_NONE)
            slices_done2 = state.slices_done + (
                jnp.int32(1) if slices_u is None else slices_u
            )
        else:
            slices_done2 = state.slices_done

        # engine parity: evictions are amortised in batches (>= 16 pages),
        # so a triggered eviction frees up to a whole batch, not one page.
        # The cooperative server instead evicts exactly the victims its
        # chunk needs (ABM plans evictions per load decision).
        batch = jnp.minimum(16 * max_page, cfg.capacity_bytes)
        need_io = jnp.where(
            load_bytes > free,
            jnp.minimum(jnp.maximum(load_bytes, batch) - free,
                        evictable_bytes),
            0.0,
        )
        if has_coop:
            need_coop = jnp.where(
                load_bytes > free,
                jnp.minimum(load_bytes - free, evictable_bytes),
                0.0,
            )
            need_free = _sel(is_coop, need_coop, need_io)
        else:
            need_free = need_io
        evict = kops.batched_evict(key, page_size, evictable, need_free,
                                   vmax=vmax, page_axis=page_axis)

        resident2 = (state.resident & ~evict) | load_mask
        last_used3 = jnp.where(load_mask, t2 + jit_p, last_used2)
        # churn diagnostic: a page evicted while still "fresh" (loaded but
        # never consumed since) was a wasted load
        fresh2 = jnp.where(load_mask, True,
                           state.fresh & ~was_crossed & resident2)
        churn2 = state.churn + jnp.sum(state.fresh & evict & ~was_crossed)
        req_step3 = jnp.where(load_mask, _REQ_NONE, req_step2)
        demand_hit = load_mask & (bonus == 31)
        if has_coop:
            demand_hit = demand_hit & jnp.logical_not(is_coop)

        new_state = SimState(
            resident=resident2,
            last_used=last_used3,
            req_step=req_step3,
            req_tie=req_tie2,
            fresh=fresh2,
            qidx=qidx2,
            pos=pos2,
            speed=speed2,
            consumed=consumed2,
            consumed_ref=consumed_ref2,
            stream_done_t=stream_done_t2,
            t=t2,
            steps=state.steps + 1,
            slices_done=slices_done2,
            io_credit=io_credit2,
            io_bytes=state.io_bytes + load_bytes,
            loads=state.loads + n_load,
            loads_demand=state.loads_demand + jnp.sum(demand_hit),
            churn=churn2,
            pstate=tuple(pstate2),
        )

        # ============ obs tier 1: jit-pure carried counters ===============
        # (repro.obs, DESIGN.md §8).  Every source below is a value the
        # step computed anyway; every update goes through the pure
        # obs.count/obs.hist helpers — the analysis lint's host-callback
        # ban (rule jit-host-callback) keeps this the only telemetry
        # channel inside traced regions.  ``tele is None`` is static:
        # with telemetry off this whole block compiles to nothing.
        if tele is None:
            tele2 = None
        else:
            hits_ev = jnp.sum(crossed)
            if has_coop:
                hits_ev = hits_ev + jnp.sum(touched_coop)
                picks = coop_mod.chunk_pick(
                    cstate.inflight, coop_io.inflight
                ) & is_coop
            else:
                picks = jnp.bool_(False)
            depth = jnp.sum((req_step3 != _REQ_NONE) & page_valid)
            # victim rank in the policy's score order (0 = top victim):
            # double argsort of the masked score, the rank histogram's
            # high bins = the kernel digging past the policy preference
            vrank = jnp.argsort(jnp.argsort(
                -jnp.where(evictable, key, -INF)
            ))
            pol_rows = []
            for j, (p, ps) in enumerate(zip(policies, pstate2)):
                row = tele.pol_obs[j]
                if row.shape[0]:
                    o = p.observe(ps, ctx)
                    if n_pol > 1:
                        o = jnp.where(pol_local == j, o, 0.0)
                    row = row + o
                pol_rows.append(row)
            tele2 = tele._replace(
                hits=obs.count(tele.hits, hits_ev),
                misses=obs.count(tele.misses, demand_hit),
                loads=obs.count(tele.loads, n_load),
                evictions=obs.count(tele.evictions, evict),
                evict_rank=obs.hist(tele.evict_rank,
                                    obs.log2_bin(vrank + 1), evict),
                jump_hist=obs.hist(tele.jump_hist, obs.log2_bin(h_u), 1),
                ioq_depth_sum=obs.count(tele.ioq_depth_sum, depth),
                ioq_depth_max=jnp.maximum(tele.ioq_depth_max, depth),
                chunk_picks=obs.count(tele.chunk_picks, picks),
                pol_obs=tuple(pol_rows),
            )

        if not horizon:
            return new_state, view2, None, tele2

        # ================= event horizon of the NEXT step =================
        # The earliest "interesting" time ahead, from the same machinery
        # the policies already expose: the post-advance trigger window
        # (computed here ONCE and carried — it is the next step's view
        # window), the pending request queue, the cooperative chunk state,
        # and the per-policy scan_horizon hooks.  Everything is a lower
        # bound on "nothing the discretisation cares about happens before
        # then"; overshoot is impossible because the CPU advance clamps at
        # the first absent trigger and the refresh cadence is capped by
        # the slice remainder in the runner's loop nest.
        win2 = window(view2)
        adv_lim2 = adv_limit(win2, resident2)
        runnable2 = active2 & (adv_lim2 > 0.0)
        remaining2 = jnp.maximum(_l2 - pos2, 0.0)
        # next trigger arrival / stream completion: how long each runnable
        # scan can burn CPU before it blocks, finishes, or outruns the
        # window cap (rate without the per-query jitter: an 8% overshoot
        # only means the scan blocks slightly before the jump ends)
        t_cpu = jnp.where(
            runnable2,
            jnp.minimum(adv_lim2, remaining2) / jnp.maximum(rate2, 1.0),
            INF,
        )
        # io-credit horizon: while requests are pending the server is the
        # clock.  Non-saturated lanes jump at most h_io page-transfer
        # times at the lane's own bandwidth (the wake-quantisation knob;
        # blocked scans wake at jump end instead of mid-jump).
        # SUPERSATURATED lanes — pool below the scans' aggregate plan
        # window (streams x readahead entries), the engine's churn-spiral
        # regime — used to keep the fine cadence entirely.  Under
        # ``wake_exact`` they instead jump by the EXACT serial-server
        # wake: with the queue frozen at this step's end the server's
        # future is deterministic (each fine step banks bandwidth*dt_ref
        # more credit and pops at most _LOAD_MAX fitting heads), so each
        # queued page's grant step has a closed form (kernels.ops
        # wake_solve, DESIGN.md §10) and the lane jumps straight to the
        # first fine step that unblocks a stream — the dominant residual
        # cost at deep thrash was exactly these h=1 crawl steps.
        wanted3 = (req_step3 != _REQ_NONE) & ~resident2 & page_valid
        pend_bytes2 = jnp.sum(jnp.where(wanted3, page_size, 0.0))
        pend2 = pend_bytes2 > 0.0
        t_io_base = h_io_f * dt_ref
        if wake_exact:
            # the queue key the NEXT step will serve: same stamp-FIFO
            # construction as load_key, one step older (stamps are
            # carried, ties were fixed at stamp time — uniform aging
            # keeps the service order; later arrivals rank behind every
            # frozen entry, so the predicted prefix is exact)
            stamp_age3 = jnp.clip(state.steps + 2 - req_step3, 0, 32767)
            wake_key = jnp.where(wanted3, stamp_age3 * 32768 + tie15, -1)
            wake_step = kops.wake_solve(
                wake_key, page_size, io_credit2,
                cfg.bandwidth * dt_ref, jnp.int32(_LOAD_MAX),
                h_cap=wake_cap_i,
            )
            # a blocked stream wakes when EVERY absent page it sits on
            # (trigger at/behind the cursor, all columns) is granted:
            # per-stream max over those pages' grant steps, then the
            # lane jumps to the EARLIEST such wake
            w_pidx2, _wt2, w_need2, w_dist2 = win2
            absent2 = w_need2[:, :, :W] & ~resident2[w_pidx2[:, :, :W]]
            d0 = absent2 & (w_dist2[:, :, :W] <= 0.0)
            kp = jnp.where(
                d0, wake_step[w_pidx2[:, :, :W]].astype(jnp.float32), 0.0
            )
            k_stream = jnp.max(kp, axis=(1, 2))
            blocked_s = active2 & ~runnable2 & jnp.any(d0, axis=(1, 2))
            k_wake = jnp.min(jnp.where(blocked_s, k_stream, INF))
            # headroom guard: the solve's credit cadence is only real
            # while the pool (free + evictable bytes) can absorb it —
            # past that the budget pins at headroom and the schedule is
            # no longer a lower bound; fall back to the fine cadence.
            # No blocked stream at all: the CPU candidates own the
            # horizon, quantised like a non-saturated pending jump.
            can_jump = jnp.isfinite(k_wake) & (
                io_credit2 + k_wake * cfg.bandwidth * dt_ref <= headroom
            )
            t_wake = jnp.where(
                can_jump, (k_wake + 0.25) * dt_ref,
                jnp.where(jnp.isfinite(k_wake), dt_ref, t_io_base),
            )
            t_io_pend = jnp.where(sat, t_wake, t_io_base)
        else:
            t_io_pend = jnp.where(sat, 0.0, t_io_base)
        t_io = jnp.where(pend2, t_io_pend, INF)
        if has_coop:
            # cooperative lanes: the in-order trigger candidate is
            # meaningless (consumption is chunk-granular, out of order);
            # the chunk in flight plays the pending queue's role.  The
            # wake solve models the in-order stamp queue, not chunks —
            # cooperative lanes keep the pre-wake-exact candidates.
            t_cpu = _sel(is_coop, jnp.full(S, INF), t_cpu)
            t_io_coop = (jnp.where(sat, 0.0, t_io_base) if wake_exact
                         else t_io_pend)
            t_io = _sel(
                is_coop,
                jnp.where(coop_io.inflight >= 0, t_io_coop, INF),
                t_io,
            )
        # per-policy horizon providers (ArrayPolicy.scan_horizon): e.g.
        # array-CScan reports each stream's current-chunk completion
        hz = HorizonView(spec=spec, active=active2, start=start2, end=end2,
                         rate=rate2, dt_ref=dt_ref)
        t_tab = [p.scan_horizon(ps, hz) for p, ps in zip(policies, pstate2)]
        if any(t is not None for t in t_tab):
            t_tab = [jnp.full(S, INF) if t is None else t for t in t_tab]
            t_pol = t_tab[0] if n_pol == 1 else \
                jnp.stack(t_tab)[pol_local]
            t_pol_min = jnp.min(t_pol)
        else:
            t_pol_min = INF
        next_dt = jnp.minimum(jnp.minimum(jnp.min(t_cpu), t_io), t_pol_min)
        # quantise to whole fine steps (floor: undershooting a horizon
        # only costs an extra step; overshooting would cost fidelity).
        # Wake-exact supersaturated lanes may plan past h_max up to the
        # wake cap — the slice loop still clips each macro-step at the
        # boundary, and the refresh step absorbs whole slices from the
        # surplus (_MAX_ABSORB at most).
        if wake_exact:
            h_cap_lane = jnp.where(sat, wake_cap_i, h_max_i)
        else:
            h_cap_lane = h_max_i
        next_h = jnp.clip(
            jnp.floor(next_dt / dt_ref).astype(jnp.int32), 1, h_cap_lane
        )
        return new_state, view2, (win2, adv_lim2, pend_bytes2, next_h), tele2

    # telemetry rides at the END of every carry so the loop conditions'
    # positional reads (cond: c[0]; inner_cond: c[5], c[6]) are identical
    # with the knob on or off
    if not horizon:
        def step(carry, cfg: ArraySimConfig):
            if telemetry:
                state, view, tele = carry
            else:
                (state, view), tele = carry, None
            new_state, view2, _, tele2 = core(state, view, window(view),
                                              cfg, dt_ref, 1, tele=tele)
            if telemetry:
                return new_state, view2, tele2
            return new_state, view2
    elif refresh:
        def step(carry, cfg: ArraySimConfig):
            # slice-boundary step: absorb the slice remainder (at most
            # h_max fine steps — inner_cond only hands the tail over
            # once next_h reaches it), then re-arm the slice budget of
            # n_inner fine steps
            if telemetry:
                state, view, win, adv_lim, pend, rem_u, _next_h, tele = carry
            else:
                state, view, win, adv_lim, pend, rem_u, _next_h = carry
                tele = None
            if wake_exact:
                # a wake-exact supersaturated jump may clear whole
                # slices beyond this one's tail: absorb up to
                # _MAX_ABSORB of them into this refresh step — the PBM
                # timeline shift, the slice counter and the speed-EWMA
                # cadence all advance by the absorbed count
                # (shift_timeline takes the multi-slice k directly).
                # Non-saturated lanes absorb exactly the tail, as before.
                sat_l = cfg.capacity_bytes < sat_bytes
                extra = jnp.where(
                    sat_l,
                    jnp.clip((_next_h - rem_u) // n_inner, 0, _MAX_ABSORB),
                    0,
                )
                h_u = rem_u + extra * n_inner
                slices_u = jnp.int32(1) + extra
            else:
                h_u = rem_u
                slices_u = None
            new_state, view2, (win2, adv_lim2, pend2, next_h2), tele2 = core(
                state, view, win, cfg,
                h_u.astype(jnp.float32) * dt_ref, h_u, adv_lim, pend,
                slices_u=slices_u, tele=tele,
            )
            out = (new_state, view2, win2, adv_lim2, pend2,
                   jnp.int32(n_inner), next_h2)
            if telemetry:
                return (*out, tele2)
            return out
    else:
        def step(carry, cfg: ArraySimConfig):
            # within-slice macro-step: jump to the event horizon, keeping
            # at least one fine step of slice for the refresh to absorb
            if telemetry:
                state, view, win, adv_lim, pend, rem_u, next_h, tele = carry
            else:
                state, view, win, adv_lim, pend, rem_u, next_h = carry
                tele = None
            h = jnp.minimum(next_h, rem_u - 1)
            new_state, view2, (win2, adv_lim2, pend2, next_h2), tele2 = core(
                state, view, win, cfg,
                h.astype(jnp.float32) * dt_ref, h, adv_lim, pend,
                tele=tele,
            )
            out = (new_state, view2, win2, adv_lim2, pend2, rem_u - h,
                   next_h2)
            if telemetry:
                return (*out, tele2)
            return out

    step.adv_limit = adv_limit
    step.query_view = query_view
    step.window = window
    step.policies = policies
    step.trigger_w = W
    return step


_UNSET = object()


def make_runner(
    spec: SimSpec,
    bandwidth_ref: float = 700e6,
    time_slice: float = 0.1,
    prefetch_pages: int = 8,
    max_slices: int = 80_000,
    policies: Optional[Sequence] = None,
    step_pages: float = 1.0,
    vmax: Optional[int] = None,
    static_policy=_UNSET,
    stepper: str = "fixed",
    h_max: float = 8.0,
    h_io: float = 3.0,
    wake_exact: bool = True,
    mesh=None,
    sanitize: bool = False,
    telemetry: bool = False,
):
    """Jitted ``run(cfg) -> SimState``: steps until every stream finishes.

    The fine step length is ``step_pages`` page-transfer times at
    ``bandwidth_ref`` (other bandwidths flow through the per-step byte
    credit), and the PBM timeline refreshes structurally every
    ``time_slice`` — the refresh cadence is compiled into the loop nest
    instead of branching per step.  ``step_pages > 1`` is the coarse fast
    mode for batched sweeps: ~2x fewer steps for a few % fidelity.

    ``stepper`` picks the time engine:

    * ``"fixed"`` — every slice is ``round(time_slice/dt)`` fixed-length
      steps (bit-compatible with the pre-horizon engine);
    * ``"horizon"`` — each slice is a ``while`` of variable-length
      macro-steps: every step jumps to the event horizon the previous
      step computed (next trigger arrival / chunk completion / io-credit
      exhaustion / stream completion, capped at ``h_max`` fine steps and
      at the slice boundary), and the slice-boundary refresh step absorbs
      whatever remains — an uneventful slice is ONE step.  ``h_io``
      bounds the jump, in fine steps, while requests are pending (the
      wake-quantisation knob, calibrated against the validation bars);
      supersaturated lanes (pool below the scans' aggregate plan-window
      bytes) jump by the EXACT serial-server wake while pending
      (``wake_exact``, the default — see :func:`make_step`), or never
      jump at all with ``wake_exact=False`` (the pre-wake-exact rule,
      bit-equal to the fixed stepper at those points).  Finished lanes
      freeze at their final state while slower lanes continue.

    ``policies`` is the set of registry policies the runner's lanes may
    select (names or ``ArrayPolicy`` objects); the default is EVERY
    registered array policy, so one runner serves a whole four-policy
    sweep.  A single-name tuple specialises the compiled step for that
    policy (no stacked dispatch, no unused machinery) — the fast path for
    per-policy validation runs.  The pre-registry ``static_policy``
    spelling of that single-policy case was removed and now raises.

    vmap-ready: ``jax.vmap(make_runner(spec))`` over a stacked config runs
    a whole sweep axis in one call.  With ``mesh`` (a ``jax.sharding.Mesh``
    over the devices to use), the returned runner instead takes a STACKED
    config directly and executes it as a ``shard_map`` — a one-axis mesh
    shards the lane axis (lanes spread across the mesh devices, each
    shard running the vmapped runner with per-lane horizons intact; the
    lane count must divide the mesh size evenly), and a two-axis mesh
    ``('lane', 'page')`` additionally shards the global page axis: each
    page shard scans only its own ``P / n_page`` slice of the pool for
    evict/grant candidates, with the reductions combined over gathered
    compact candidate lists — bitwise-identical to the unsharded run
    (``repro.kernels.ops``); the page-shard count must divide the padded
    pool size ``spec.n_pages``.

    ``sanitize=True`` is the contract-checker mode (``repro.analysis``):
    the run compiles under ``jax.experimental.checkify`` NaN + OOB-index
    checks — any step primitive producing a NaN, or any gather/scatter
    index leaving its array, raises instead of propagating garbage
    through a sweep — and the runner hard-errors if it is ever traced
    more than once (a pytree leaf changing shape/dtype between calls is
    a silent 10x recompile slowdown; here it is a ``RuntimeError``).
    Every runner (sanitized or not) exposes ``runner.trace_count()``,
    the number of jit traces taken so far — the one-trace-per-sweep
    invariant is asserted in CI against the plain runners too.
    Incompatible with ``mesh`` (checkify does not compose with
    ``shard_map`` here; sanitize single lanes instead).

    ``telemetry=True`` (STATIC — a different runner, not a traced leaf)
    threads the jit-pure counter pytree of ``repro.obs`` through the
    carry: the runner then returns ``(state, telemetry)`` instead of the
    bare state.  Off (the default) compiles to the exact pre-telemetry
    program — bit-equal results; on adds carry leaves but zero extra
    traces (both are asserted in ``tests/test_obs.py``).
    """
    if static_policy is not _UNSET:
        raise TypeError(
            "make_runner(static_policy=...) was removed; pass "
            "policies=(name,) — resolved through "
            "repro.core.policy_registry (None still means every array "
            "policy)"
        )
    if sanitize and mesh is not None:
        raise ValueError(
            "make_runner(sanitize=True) does not compose with mesh= "
            "(checkify under shard_map); sanitize unsharded lanes instead"
        )
    pols = resolve_policies(policies)
    page_axis = None
    if mesh is not None:
        if len(mesh.axis_names) not in (1, 2):
            raise ValueError(
                f"make_runner(mesh=...) wants a one-axis lane mesh or a "
                f"two-axis ('lane', 'page') mesh, got axes "
                f"{mesh.axis_names}"
            )
        if len(mesh.axis_names) == 2:
            page_axis = mesh.axis_names[1]
    dt = float(step_pages) * float(np.max(spec.page_size)) / float(bandwidth_ref)
    cheap = make_step(spec, dt, time_slice, prefetch_pages, refresh=False,
                      policies=pols, vmax=vmax, stepper=stepper,
                      h_max=h_max, h_io=h_io, wake_exact=wake_exact,
                      page_axis=page_axis, telemetry=telemetry)
    full = make_step(spec, dt, time_slice, prefetch_pages, refresh=True,
                     policies=pols, vmax=vmax, stepper=stepper,
                     h_max=h_max, h_io=h_io, wake_exact=wake_exact,
                     page_axis=page_axis, telemetry=telemetry)

    if stepper == "fixed":
        n_inner = max(1, int(round(time_slice / dt)))

        def run(cfg: ArraySimConfig):
            state = init_state(spec, pols)
            carry = (state, cheap.query_view(state.qidx, state.pos))
            if telemetry:
                carry = (*carry, obs.init_telemetry(pols, spec))

            def slice_body(c):
                c = jax.lax.fori_loop(
                    0, n_inner - 1, lambda i, s: cheap(s, cfg), c
                )
                return full(c, cfg)

            def cond(c):
                st = c[0]
                return (
                    jnp.any(st.stream_done_t < 0)
                    & (st.t < cfg.max_time)
                    & (st.slices_done < max_slices)
                )

            out = jax.lax.while_loop(cond, slice_body, carry)
            if telemetry:
                return out[0], out[-1]
            return out[0]
    else:
        n_inner = max(1, int(round(time_slice / dt)))

        def run(cfg: ArraySimConfig):
            state = init_state(spec, pols)
            view0 = cheap.query_view(state.qidx, state.pos)
            win0 = cheap.window(view0)
            carry = (state, view0, win0,
                     cheap.adv_limit(win0, state.resident),
                     jnp.float32(0.0), jnp.int32(n_inner), jnp.int32(1))
            if telemetry:
                carry = (*carry, obs.init_telemetry(pols, spec))

            def inner_cond(c):
                # keep macro-stepping while the slice has more than one
                # fine step left AND the planned jump falls short of the
                # boundary — otherwise hand the tail to the refresh step
                rem_u, next_h = c[5], c[6]
                return (rem_u > 1) & (next_h < rem_u)

            def slice_body(c):
                c = jax.lax.while_loop(
                    inner_cond, lambda s: cheap(s, cfg), c
                )
                return full(c, cfg)

            def cond(c):
                st = c[0]
                return (
                    jnp.any(st.stream_done_t < 0)
                    & (st.t < cfg.max_time)
                    & (st.slices_done < max_slices)
                )

            out = jax.lax.while_loop(cond, slice_body, carry)
            if telemetry:
                return out[0], out[-1]
            return out[0]

    # one trace per (stepper x policy-set) is a substrate invariant: the
    # counter ticks inside the traced body, so it counts TRACES, not
    # calls — a leaf changing shape/dtype between configs shows up here
    trace_counter = {"n": 0}

    def counted_run(cfg: ArraySimConfig):
        trace_counter["n"] += 1
        return run(cfg)

    if mesh is not None:
        from jax.experimental.shard_map import shard_map

        # configs shard over the lane axis only; per-page state is
        # replicated across the page axis (each page shard scans its own
        # pool slice inside the kernels — kops page_axis dispatch above)
        pspec = jax.sharding.PartitionSpec(mesh.axis_names[0])
        runner = jax.jit(shard_map(
            jax.vmap(counted_run), mesh=mesh,
            in_specs=(pspec,), out_specs=pspec, check_rep=False,
        ))
    elif sanitize:
        from jax.experimental import checkify

        checked = jax.jit(checkify.checkify(
            counted_run,
            errors=checkify.nan_checks | checkify.index_checks,
        ))

        def runner(cfg: ArraySimConfig):
            err, state = checked(cfg)
            err.throw()
            if trace_counter["n"] > 1:
                raise RuntimeError(
                    f"make_runner(sanitize=True): {trace_counter['n']} jit "
                    "traces for one runner — a config leaf changed "
                    "shape/dtype between calls (stack configs with "
                    "stack_configs / keep leaves f32/i32 scalars); every "
                    "(stepper x policy-set) must compile exactly once"
                )
            return state
    else:
        runner = jax.jit(counted_run)
    runner.dt_ref = dt
    runner.stepper = stepper
    runner.lane_mesh = mesh
    runner.page_axis = page_axis
    runner.wake_exact = wake_exact
    runner.sanitize = sanitize
    runner.telemetry = telemetry
    runner.policy_names = tuple(p.name for p in pols)
    runner.trace_count = lambda: trace_counter["n"]
    return runner


def result_from_state(state: SimState, policy, sim_wall: float = 0.0,
                      dt_ref: Optional[float] = None) -> ArrayResult:
    """Convert a finished (device) state into an :class:`ArrayResult`.

    A run cut short by the ``max_time``/``max_slices`` livelock guard is
    NOT silently reported as complete: unfinished streams still contribute
    ``t_end`` to ``stream_times`` (a lower bound), but the result carries
    ``extras["truncated"] = True`` plus the unfinished-stream count so
    harnesses can refuse to compare it against a finished event run.

    ``dt_ref`` (the runner's fine-step length, ``runner.dt_ref``) makes
    the time engine's work observable instead of inferred: extras report
    ``steps``/``macro_steps`` (steps actually executed) plus
    ``skipped_time`` (simulated seconds covered beyond one fine step per
    step — 0 under the fixed stepper, the jumped time under the horizon
    stepper).
    """
    done_t = np.asarray(state.stream_done_t, np.float64)
    t_end = float(state.t)
    stream_times = [d if d >= 0 else t_end for d in done_t]
    unfinished = int(np.sum(done_t < 0))
    if isinstance(policy, str):
        name = policy
    else:
        name = policy_registry.array_name(int(policy)) or str(policy)
    steps = int(state.steps)
    extras = {
        "truncated": unfinished > 0,
        "unfinished_streams": unfinished,
        "churn_loads": int(state.churn),
        "demand_loads": int(state.loads_demand),
        "steps": steps,
        "macro_steps": steps,
        "slices_done": int(state.slices_done),
    }
    if dt_ref is not None:
        extras["skipped_time"] = round(max(0.0, t_end - steps * dt_ref), 6)
    return ArrayResult(
        policy=name,
        stream_times=stream_times,
        total_io_bytes=float(state.io_bytes),
        total_loads=int(state.loads),
        sim_time=t_end,
        steps=steps,
        wall_s=sim_wall,
        extras=extras,
    )


def run_workload_array(
    db,
    streams,
    policy_name: str,
    *,
    capacity_bytes: float,
    bandwidth: float = 700e6,
    time_slice: float = 0.1,
    prefetch_pages: int = 8,
    max_time: float = 3e5,
    spec: Optional[SimSpec] = None,
    runner=None,
    stepper: str = "fixed",
    wake_exact: bool = True,
    sanitize: bool = False,
    telemetry: bool = False,
) -> ArrayResult:
    """Array-backend counterpart of ``repro.core.run_workload`` for every
    registered array policy (lru / pbm / cscan / opt).  Accepts any
    workload the compiler can lower — multi-table streams included.
    ``stepper`` selects the time engine and ``sanitize`` the checkify
    contract-checker mode (see :func:`make_runner`) when no pre-built
    ``runner`` is passed.
    Check ``result.extras["truncated"]`` when lowering ``max_time``: a run
    cut short by the livelock guard reports lower bounds, not results."""
    from .compiler import compile_workload

    if spec is None:
        spec = compile_workload(db, streams)
    if runner is None:
        runner = make_runner(spec, bandwidth_ref=bandwidth,
                             time_slice=time_slice,
                             prefetch_pages=prefetch_pages,
                             policies=(policy_name,), stepper=stepper,
                             wake_exact=wake_exact,
                             sanitize=sanitize, telemetry=telemetry)
    cfg = make_config(spec, capacity_bytes, bandwidth, policy_name,
                      max_time=max_time)
    t0 = _time.time()
    out = jax.block_until_ready(runner(cfg))
    if getattr(runner, "telemetry", False):
        state, tele = out
    else:
        state, tele = out, None
    result = result_from_state(state, policy_name,
                               sim_wall=_time.time() - t0,
                               dt_ref=getattr(runner, "dt_ref", None))
    if tele is not None:
        result.extras["telemetry"] = obs.summarize(
            tele, policies=getattr(runner, "policy_names", None),
            steps=result.steps,
        )
    return result
