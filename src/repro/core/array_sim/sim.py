"""Array-native batched simulation of concurrent scans over a buffer pool.

The event engine (``repro.core.engine``) replays the paper's machine one
heapq event at a time in Python.  This module re-expresses the same system
as a **pure, fixed-shape array program**:

* per-page state (residency, LRU clock, PBM bucket, FIFO request stamp)
  and per-stream state (query index, cursor, speed estimate) live in dense
  JAX arrays (:class:`SimState`);
* a pure ``step(state, cfg) -> state`` advances the whole machine by one
  page-transfer time ``dt`` — scans consume tuples while their pages are
  resident and block exactly at page boundaries whose successor is absent;
  a bandwidth-budgeted I/O server pops the request FIFO; the plugged
  policy (array LRU or array PBM) picks batched eviction victims;
* steps come in two flavours on the paper's own cadence: *within* a PBM
  time slice the bucketed timeline is static (cheap step: consume, load,
  evict), and once per ``time_slice`` a *refresh* step recomputes every
  page's estimated next consumption, re-buckets transitions, and shifts
  the timeline — ``RefreshRequestedBuckets`` as one vector op;
* everything is ``jax.jit``- and ``jax.vmap``-compatible, so an entire
  sweep axis (buffer sizes x bandwidths x policies) runs as ONE batched
  computation instead of N serial Python event loops.

The PBM hot path — timeline shift + spill + batched Belady-rule eviction
— is dispatched through ``repro.kernels.ops.pbm_timeline_step``: a Pallas
kernel on TPU, its jnp oracle elsewhere.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .policies import BIG_CUT, next_consumption, target_buckets
from .spec import SimSpec, build_spec

_EWMA = 0.3           # speed smoothing; engine parity (ScanState ewma=0.3)
_REQ_NONE = 1 << 24   # FIFO stamp sentinel: page not currently requested
_LOAD_MAX = 6         # load grants per step (credit caps at ~5 pages)


class ArraySimConfig(NamedTuple):
    """Traced runtime knobs: a batch of configs (one per sweep point) can
    be stacked leaf-wise and vmapped over."""

    capacity_bytes: jax.Array   # f32 buffer-pool capacity
    bandwidth: jax.Array        # f32 bytes/sec of the I/O server
    policy: jax.Array           # i32: 0 = LRU, 1 = PBM
    max_time: jax.Array         # f32 livelock guard


class SimState(NamedTuple):
    # ---- per-page (P,) ---------------------------------------------------
    resident: jax.Array       # bool
    last_used: jax.Array      # f32 LRU clock
    bucket: jax.Array         # i32 PBM timeline position (nb == not-requested)
    req_step: jax.Array       # i32 FIFO stamp: step the page was first wanted
    # ---- per-stream (S,) -------------------------------------------------
    qidx: jax.Array           # i32 current query (== n_q when stream done)
    pos: jax.Array            # f32 tuples consumed within current query
    speed: jax.Array          # f32 EWMA tuples/sec
    stream_done_t: jax.Array  # f32 finish time, -1 while running
    # ---- scalars ---------------------------------------------------------
    t: jax.Array              # f32 sim clock
    steps: jax.Array          # i32
    time_passed: jax.Array    # i32 PBM slices elapsed
    io_credit: jax.Array      # f32 banked I/O bytes (partial in-flight load)
    io_bytes: jax.Array       # f32 lifetime loaded bytes (paper I/O volume)
    loads: jax.Array          # i32 lifetime page loads


@dataclass
class ArrayResult:
    """Mirror of ``EngineResult`` for the array backend rows."""

    policy: str
    stream_times: List[float]
    total_io_bytes: float
    total_loads: int
    sim_time: float
    steps: int
    wall_s: float = 0.0
    extras: dict = field(default_factory=dict)

    @property
    def avg_stream_time(self) -> float:
        return sum(self.stream_times) / max(1, len(self.stream_times))

    @property
    def io_gb(self) -> float:
        return self.total_io_bytes / 1e9


POLICY_IDS = {"lru": 0, "pbm": 1}
_POLICY_NAMES = {v: k for k, v in POLICY_IDS.items()}


class _View(NamedTuple):
    """Derived per-stream view of the current query + cursor.  Carried
    alongside :class:`SimState` so each step computes it once (this step's
    post-advance view is the next step's pre-advance view)."""

    active: jax.Array   # (S,) bool
    length: jax.Array   # (S,) f32
    rate: jax.Array     # (S,) f32
    cols: jax.Array     # (S, C) bool
    cur: jax.Array      # (S,) f32 absolute cursor
    end: jax.Array      # (S,) f32 absolute scan end
    local: jax.Array    # (S, C) i32 page index within column
    pidx: jax.Array     # (S, C) i32 global page id under the cursor
    need: jax.Array     # (S, C) bool


def make_config(
    spec: SimSpec,
    capacity_bytes: float,
    bandwidth: float = 700e6,
    policy: str | int = "pbm",
    max_time: float = 3e5,
) -> ArraySimConfig:
    pid = POLICY_IDS[policy] if isinstance(policy, str) else int(policy)
    return ArraySimConfig(
        capacity_bytes=jnp.float32(capacity_bytes),
        bandwidth=jnp.float32(bandwidth),
        policy=jnp.int32(pid),
        max_time=jnp.float32(max_time),
    )


def stack_configs(cfgs: Sequence[ArraySimConfig]) -> ArraySimConfig:
    """Stack N configs leaf-wise into one batched config for vmap."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *cfgs)


def init_state(spec: SimSpec) -> SimState:
    P, S = spec.n_pages, spec.n_streams
    n_q = jnp.asarray(spec.n_q)
    return SimState(
        resident=jnp.zeros(P, bool),
        last_used=jnp.full(P, -1e9, jnp.float32),
        bucket=jnp.full(P, spec.not_requested, jnp.int32),
        req_step=jnp.full(P, _REQ_NONE, jnp.int32),
        qidx=jnp.zeros(S, jnp.int32),
        pos=jnp.zeros(S, jnp.float32),
        speed=jnp.asarray(spec.q_rate[:, 0]),
        stream_done_t=jnp.where(n_q > 0, -1.0, 0.0).astype(jnp.float32),
        t=jnp.float32(0.0),
        steps=jnp.int32(0),
        time_passed=jnp.int32(0),
        io_credit=jnp.float32(0.0),
        io_bytes=jnp.float32(0.0),
        loads=jnp.int32(0),
    )


def make_step(spec: SimSpec, dt: float, time_slice: float,
              prefetch_pages: int = 8, refresh: bool = False,
              static_policy: Optional[str] = None):
    """Build the pure ``step(state, cfg) -> state``.

    ``refresh=False`` is the cheap within-slice step: the PBM timeline is
    static except for just-loaded pages (bucketed individually) and pages
    entering consumption (bucket 0).  ``refresh=True`` is the once-per-
    ``time_slice`` boundary step that recomputes every page's next
    consumption, demotes no-longer-requested pages, and shifts the
    timeline one slice (spilled buckets re-bucket at the fresh estimate).
    """
    from repro.kernels import ops as kops

    P, S, Q, C = spec.n_pages, spec.n_streams, spec.n_queries, spec.n_cols
    NR = spec.not_requested
    nb, m = spec.nb, spec.buckets_per_group
    K = int(prefetch_pages)
    # deepest per-column readahead actually reachable: the scatter that
    # publishes request slots walks K_LOOP+1 entries per (stream, column),
    # so a policy-specialised step (PBM readahead depth is 1) is cheaper
    K_LOOP = min(K, 1 if static_policy == "pbm" else 4)
    dt = jnp.float32(dt)
    time_slice_f = jnp.float32(time_slice)

    page_size = jnp.asarray(spec.page_size)
    page_first = jnp.asarray(spec.page_first)
    page_last = jnp.asarray(spec.page_last)
    page_col = jnp.asarray(spec.page_col)
    page_valid = jnp.asarray(spec.page_valid)
    col_start = jnp.asarray(spec.col_start)
    col_npages = jnp.asarray(spec.col_npages)
    col_tpp = jnp.asarray(spec.col_tpp)
    q_start = jnp.asarray(spec.q_start)
    q_len = jnp.asarray(spec.q_len)
    q_rate = jnp.asarray(spec.q_rate)
    q_cols = jnp.asarray(spec.q_cols)
    n_q = jnp.asarray(spec.n_q)
    s_idx = jnp.arange(S)
    max_page = jnp.float32(float(np.max(spec.page_size)))
    INF = jnp.float32(np.inf)

    def query_view(qidx, pos) -> _View:
        """Gather the per-stream view of the current query + cursor."""
        qi = jnp.clip(qidx, 0, Q - 1)
        active = qidx < n_q
        start = q_start[s_idx, qi]
        length = q_len[s_idx, qi]
        rate = q_rate[s_idx, qi]
        cols = q_cols[s_idx, qi]                       # (S, C)
        cur = start + pos
        end = start + length
        local = jnp.floor(cur[:, None] / col_tpp[None, :]).astype(jnp.int32)
        local = jnp.clip(local, 0, col_npages[None, :] - 1)
        # page boundaries are exact ints but tpp is fractional: correct the
        # division so cur lands in [first, last) of its page (a cursor at a
        # boundary must map to the NEXT page or it stalls with adv_lim=0)
        pidx0 = col_start[None, :] + local
        local = local + (cur[:, None] >= page_last[pidx0]).astype(jnp.int32)
        local = local - (cur[:, None] < page_first[pidx0]).astype(jnp.int32)
        local = jnp.clip(local, 0, col_npages[None, :] - 1)
        pidx = col_start[None, :] + local              # (S, C)
        need = active[:, None] & cols
        return _View(active, length, rate, cols, cur, end, local, pidx, need)

    def step(carry, cfg: ArraySimConfig):
        state, view = carry
        t2 = state.t + dt

        # ================= CPU: consume while resident ====================
        (active, length, rate, _cols, cur, end, local, pidx,
         need) = view
        res_need = state.resident[pidx]
        blocked = jnp.any(need & ~res_need, axis=1)
        runnable = active & ~blocked

        # block exactly at the boundary of a page whose successor is absent
        nxt_local = jnp.minimum(local + 1, col_npages[None, :] - 1)
        nxt_exists = (local + 1 < col_npages[None, :]) & (
            page_first[col_start[None, :] + nxt_local] < end[:, None]
        )
        nxt_missing = nxt_exists & ~state.resident[col_start[None, :] + nxt_local]
        boundary = page_last[pidx] - cur[:, None]
        lim = jnp.where(need & nxt_missing, jnp.maximum(boundary, 0.0), INF)
        adv_lim = jnp.min(lim, axis=1)
        remaining = length - state.pos
        adv = jnp.where(
            runnable, jnp.minimum(jnp.minimum(rate * dt, remaining), adv_lim), 0.0
        )
        adv = jnp.maximum(adv, 0.0)

        margin = jnp.maximum(0.5, 3e-5 * length)
        finished = runnable & (remaining - adv <= margin)
        qidx2 = state.qidx + finished.astype(jnp.int32)
        pos2 = jnp.where(finished, 0.0, state.pos + adv)
        newly_done = (qidx2 >= n_q) & (state.stream_done_t < 0)
        stream_done_t2 = jnp.where(newly_done, t2, state.stream_done_t)

        inst = adv / dt
        speed1 = jnp.where(
            active, _EWMA * inst + (1 - _EWMA) * state.speed, state.speed
        )
        next_rate = q_rate[s_idx, jnp.clip(qidx2, 0, Q - 1)]
        speed2 = jnp.where(finished, next_rate, speed1)  # fresh scan: reset

        # touch consumed pages (LRU clock)
        touch = need & runnable[:, None]
        last_used2 = state.last_used.at[pidx].max(jnp.where(touch, t2, -INF))

        # ================= post-advance view (I/O demand) =================
        view2 = query_view(qidx2, pos2)
        (active2, _l2, _r2, cols2, cur2, end2, local2, pidx2,
         need2) = view2
        res2 = state.resident[pidx2]
        demand = need2 & ~res2

        # readahead budget: K plan pages per scan, split across its columns
        # in proportion to page density (the engine's next-K-plan-pages)
        inv_tpp = 1.0 / col_tpp[None, :]
        dens = jnp.sum(jnp.where(need2, inv_tpp, 0.0), axis=1, keepdims=True)
        depth_dens = jnp.maximum(
            jnp.round(K * inv_tpp / jnp.maximum(dens, 1e-30)), 1.0
        )
        # calibrated against the event engine: LRU tracks best with the
        # density split of the plan-order readahead; PBM with a shallow
        # uniform depth (deep readahead lands in far-future buckets and
        # thrashes at small pools more than the engine's request queue does)
        if static_policy is None:
            pol_depth = jnp.where(cfg.policy == 1, 1.0, depth_dens)
        elif static_policy == "pbm":
            pol_depth = 1.0
        else:
            pol_depth = depth_dens
        depth = jnp.where(need2, pol_depth, 0.0).astype(jnp.int32)  # (S, C)
        # one fused scatter for demand (k=0) + readahead (k=1..K_LOOP);
        # per-column depth never exceeds ~K/2 on multi-column scans, so the
        # scatter walks K_LOOP+1 slots instead of K+1
        ks = jnp.arange(K_LOOP + 1)                    # (K_LOOP+1,)
        pf_local = local2[:, :, None] + ks[None, None, :]
        ok = (pf_local < col_npages[None, :, None]) & need2[:, :, None]
        ok &= (ks[None, None, :] <= depth[:, :, None])
        pf_pidx = col_start[None, :, None] + jnp.minimum(
            pf_local, col_npages[None, :, None] - 1
        )
        ok &= page_first[pf_pidx] < end2[:, None, None]
        kb = jnp.where(ks == 0, 31, jnp.clip(K_LOOP + 1 - ks, 1, 30))
        okd = ok.at[:, :, 0].set(demand)               # k=0 slot: demand only
        bonus = jnp.full(P, -1, jnp.int32).at[pf_pidx].max(
            jnp.where(okd, kb[None, None, :], -1)
        )
        wanted = (bonus >= 0) & ~state.resident & page_valid
        # FIFO service order, array-form: every page keeps the step at which
        # it was first requested (demand or readahead) and the I/O server
        # grants oldest requests first — the engine's request queue without
        # the queue.  Stamps clear when the page loads or loses all waiters.
        req_step2 = jnp.where(
            wanted, jnp.minimum(state.req_step, state.steps + 1), _REQ_NONE
        )
        # int key (f32 would round away the bonus): older request -> larger
        load_key = jnp.where(wanted, (_REQ_NONE - req_step2) * 32 + bonus, -1)

        # ================= I/O server: budgeted admission =================
        used = jnp.sum(page_size * state.resident)
        free = cfg.capacity_bytes - used
        # engine parity: pages are pinned only while a scan actually runs a
        # CPU burst over them — a blocked scan pins nothing (otherwise a
        # pool smaller than the union of current column sets livelocks)
        blocked2 = jnp.any(need2 & ~res2, axis=1)
        pin = jnp.zeros(P, jnp.int32).at[pidx2].max(
            (need2 & res2 & ~blocked2[:, None]).astype(jnp.int32)
        )
        evictable = state.resident & (pin == 0) & page_valid
        evictable_bytes = jnp.sum(page_size * evictable)
        headroom = free + evictable_bytes
        credit = state.io_credit + cfg.bandwidth * dt

        # the server grants at most ~credit bytes (a handful of pages) per
        # step: pop the FIFO head a few times instead of sorting anything.
        # Head-of-line semantics: the first page that does not fit blocks
        # the rest of the queue, like the engine's serial server.
        kcur = load_key
        taken = jnp.float32(0.0)
        open_ = jnp.bool_(True)
        budget = jnp.minimum(credit, headroom)
        arange_p = jnp.arange(P)
        hit = jnp.zeros(P, bool)
        cand = []
        cand_ok = []
        for _ in range(_LOAD_MAX):
            j = jnp.argmax(kcur)
            ok_j = open_ & (kcur[j] >= 0) & (taken + page_size[j] <= budget)
            open_ = ok_j
            is_j = arange_p == j       # arithmetic mask: fuses, scatter won't
            hit = hit | (is_j & ok_j)
            taken = taken + jnp.where(ok_j, page_size[j], 0.0)
            kcur = jnp.where(is_j, -1, kcur)
            cand.append(j)
            cand_ok.append(ok_j)
        load_mask = hit
        cand = jnp.stack(cand)                         # (LOAD_MAX,)
        cand_ok = jnp.stack(cand_ok)
        load_bytes = taken
        n_load = jnp.sum(cand_ok)

        leftover = credit - load_bytes
        starved_io = jnp.sum(wanted & ~load_mask) > 0
        io_credit2 = jnp.where(
            starved_io, jnp.minimum(leftover, 4 * max_page), 0.0
        )

        # ================= PBM bookkeeping ================================
        if refresh:
            # slice boundary: full PageNextConsumption recompute, bucket
            # transitions, and one timeline shift with spill re-bucketing
            eta = next_consumption(page_first, page_last, page_col, cols2,
                                   cur2, end2, speed2, active2)
            b_target = target_buckets(eta, time_slice_f, spec.n_groups, m,
                                      page_valid)
            interested = (eta < BIG_CUT) & page_valid
            assign = (
                load_mask | ((state.bucket == NR) & interested)
                | (b_target == 0)
            )
            bucket_pre = jnp.where(
                ~interested, NR, jnp.where(assign, b_target, state.bucket)
            ).astype(jnp.int32)
            k_shift = jnp.int32(1)
            time_passed2 = state.time_passed + 1
        else:
            # within a slice the timeline is static: bucket just-loaded
            # pages individually and mark pages entering consumption
            eta_c = next_consumption(
                page_first[cand], page_last[cand], page_col[cand],
                cols2, cur2, end2, speed2, active2,
            )
            b_c = target_buckets(
                eta_c, time_slice_f, spec.n_groups, m,
                jnp.ones(cand.shape[0], bool),
            )
            bucket_pre = state.bucket.at[cand].set(
                jnp.where(cand_ok, b_c, state.bucket[cand])
            )
            # pages under an active cursor are imminent: bucket 0 (the dict
            # impl pushes them with eta 0 on every consume event)
            bucket_pre = bucket_pre.at[pidx2].min(
                jnp.where(need2 & res2, 0, NR + 1)
            )
            bucket_pre = jnp.minimum(bucket_pre, NR)
            b_target = bucket_pre                      # no spill when k=0
            k_shift = jnp.int32(0)
            time_passed2 = state.time_passed

        # engine parity: evictions are amortised in batches (>= 16 pages),
        # so a triggered eviction frees up to a whole batch, not one page
        batch = jnp.minimum(16 * max_page, cfg.capacity_bytes)
        need_free = jnp.where(
            load_bytes > free,
            jnp.minimum(jnp.maximum(load_bytes, batch) - free,
                        evictable_bytes),
            0.0,
        )
        bucket_out, evict = kops.pbm_timeline_step(
            bucket_pre, b_target, last_used2, page_size, evictable,
            state.time_passed, k_shift, need_free, cfg.policy, t2, nb=nb, m=m,
        )

        resident2 = (state.resident & ~evict) | load_mask
        last_used3 = jnp.where(load_mask, t2, last_used2)
        req_step3 = jnp.where(load_mask, _REQ_NONE, req_step2)

        new_state = SimState(
            resident=resident2,
            last_used=last_used3,
            bucket=bucket_out,
            req_step=req_step3,
            qidx=qidx2,
            pos=pos2,
            speed=speed2,
            stream_done_t=stream_done_t2,
            t=t2,
            steps=state.steps + 1,
            time_passed=time_passed2,
            io_credit=io_credit2,
            io_bytes=state.io_bytes + load_bytes,
            loads=state.loads + n_load,
        )
        return new_state, view2

    step.query_view = query_view
    return step


def make_runner(
    spec: SimSpec,
    bandwidth_ref: float = 700e6,
    time_slice: float = 0.1,
    prefetch_pages: int = 8,
    max_slices: int = 80_000,
    static_policy: Optional[str] = None,
    step_pages: float = 1.0,
):
    """Jitted ``run(cfg) -> SimState``: steps until every stream finishes.

    The step length is ``step_pages`` page-transfer times at
    ``bandwidth_ref`` (other bandwidths flow through the per-step byte
    credit), and the PBM timeline refreshes structurally every
    ``time_slice`` — the refresh cadence is compiled into the loop nest
    instead of branching per step.  ``step_pages > 1`` is the coarse fast
    mode for batched sweeps: ~2x fewer steps for a few % fidelity.
    ``static_policy`` specialises the compiled step for one policy
    (smaller readahead scatter for PBM); leave ``None`` to vmap over the
    policy axis too.

    vmap-ready: ``jax.vmap(make_runner(spec))`` over a stacked config runs
    a whole sweep axis in one call.
    """
    dt = float(step_pages) * float(np.max(spec.page_size)) / float(bandwidth_ref)
    n_inner = max(1, int(round(time_slice / dt)))
    cheap = make_step(spec, dt, time_slice, prefetch_pages, refresh=False,
                      static_policy=static_policy)
    full = make_step(spec, dt, time_slice, prefetch_pages, refresh=True,
                     static_policy=static_policy)

    def run(cfg: ArraySimConfig) -> SimState:
        state = init_state(spec)
        carry = (state, cheap.query_view(state.qidx, state.pos))

        def slice_body(c):
            c = jax.lax.fori_loop(
                0, n_inner - 1, lambda i, s: cheap(s, cfg), c
            )
            return full(c, cfg)

        def cond(c):
            st = c[0]
            return (
                jnp.any(st.stream_done_t < 0)
                & (st.t < cfg.max_time)
                & (st.time_passed < max_slices)
            )

        return jax.lax.while_loop(cond, slice_body, carry)[0]

    return jax.jit(run)


def result_from_state(state: SimState, policy, sim_wall: float = 0.0,
                      ) -> ArrayResult:
    """Convert a finished (device) state into an :class:`ArrayResult`."""
    done_t = np.asarray(state.stream_done_t, np.float64)
    t_end = float(state.t)
    stream_times = [d if d >= 0 else t_end for d in done_t]
    name = _POLICY_NAMES.get(int(policy), str(policy)) \
        if not isinstance(policy, str) else policy
    return ArrayResult(
        policy=name,
        stream_times=stream_times,
        total_io_bytes=float(state.io_bytes),
        total_loads=int(state.loads),
        sim_time=t_end,
        steps=int(state.steps),
        wall_s=sim_wall,
    )


def run_workload_array(
    db,
    streams,
    policy_name: str,
    *,
    capacity_bytes: float,
    bandwidth: float = 700e6,
    time_slice: float = 0.1,
    prefetch_pages: int = 8,
    spec: Optional[SimSpec] = None,
    runner=None,
) -> ArrayResult:
    """Array-backend counterpart of ``repro.core.run_workload`` for the
    LRU / PBM policies (CScan and OPT stay on the event engine)."""
    import time

    if spec is None:
        spec = build_spec(db, streams)
    if runner is None:
        runner = make_runner(spec, bandwidth_ref=bandwidth,
                             time_slice=time_slice,
                             prefetch_pages=prefetch_pages)
    cfg = make_config(spec, capacity_bytes, bandwidth, policy_name)
    t0 = time.time()
    state = jax.block_until_ready(runner(cfg))
    return result_from_state(state, policy_name, sim_wall=time.time() - t0)
