"""Cooperative Scans substrate for the array backend (paper §2).

The ABM *inverts* buffer-management control flow: loading decisions are
global, CScan operators consume whichever chunk of their range is ready
(out-of-order, chunk-at-a-time), and eviction is relevance-driven.  None
of that is expressible as an eviction score over the in-order step — the
event CScan beats even OPT, which bounds every order-preserving policy —
so the step compiles this chunk-granular fluid model in whenever a
``cooperative`` :class:`~repro.core.array_sim.policies.ArrayPolicy`
(array-CScan) is among its policies, and blends per-lane with the
in-order model by the traced policy id.

The model mirrors ``policies/cscan.py`` at chunk granularity:

* **state** (:class:`CoopState`, the cooperative policy's pstate): per
  (stream, chunk) consumed flags for the stream's current query, the
  chunk each stream is consuming (+ fractional progress and banked CPU
  credit), and the single chunk the serial I/O server is loading;
* **CPU** (:func:`cpu_phase`): an idle scan picks the *available* chunk
  (all pages of its columns resident) the fewest other scans are
  interested in (``UseRelevance``), then consumes its tuple overlap at
  the query rate; completion leftovers bank one step of credit so chunk
  boundaries don't quantise the rate;
* **I/O** (:func:`io_phase`): when the server idles, pick the next load
  by ``QueryRelevance`` (starved scans first, then fewest chunks
  remaining) then ``LoadRelevance`` (most interested scans, lowest chunk
  id) — gated by the paper's eviction rule: a chunk is only loadable if
  enough bytes are held by chunks with strictly lower ``KeepRelevance``
  (interest count).  The selected chunk's missing pages (union of the
  interested scans' columns) drain through the step's shared byte-budget
  server; victims come from the least-interesting chunks via the same
  batched-evict kernel as every other policy, scored by
  ``ArrayCScan.score_victims``.

Chunk geometry (global chunk ids, page→chunk ownership by first tuple —
exactly ``ABM._ensure_chunk_meta``) is compiled by
``compiler.compile_workload`` into ``SimSpec``.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

_NEGI = -(1 << 30)


class CoopState(NamedTuple):
    """Cooperative-substrate state: the ``ArrayCScan`` policy pytree."""

    done: jnp.ndarray        # (S, CH) bool chunks consumed (current query)
    cur_chunk: jnp.ndarray   # (S,) i32 chunk being consumed, -1 = none
    chunk_pos: jnp.ndarray   # (S,) f32 tuples consumed within cur_chunk
    credit: jnp.ndarray      # (S,) f32 banked CPU budget (completion spill)
    inflight: jnp.ndarray    # () i32 chunk the I/O server loads, -1 = idle


class CoopCpu(NamedTuple):
    """CPU-phase outputs the step blends into its per-lane state."""

    adv: jnp.ndarray             # (S,) tuples credited to ``pos`` this step
    finished: jnp.ndarray        # (S,) bool current query completed
    consumed_pages: jnp.ndarray  # (P,) bool pages consumed (completed chunks)
    pin_pages: jnp.ndarray       # (P,) bool pages of chunks being consumed
    done: jnp.ndarray            # (S, CH) post-consumption flags
    cur_chunk: jnp.ndarray       # (S,) i32
    chunk_pos: jnp.ndarray       # (S,) f32
    credit: jnp.ndarray          # (S,) f32


class CoopIo(NamedTuple):
    """I/O-phase outputs: the cooperative lane's request set + evict view."""

    load_key: jnp.ndarray    # (P,) i32 server queue key (-1 = not wanted)
    wanted: jnp.ndarray      # (P,) bool missing pages of the inflight chunk
    evictable: jnp.ndarray   # (P,) bool Keep < Load rule applied
    keep_key: jnp.ndarray    # (P,) f32 eviction priority (fewest interest)
    inflight: jnp.ndarray    # () i32 updated inflight chunk
    starved: jnp.ndarray     # (S,) bool diagnostic


class CoopConsts(NamedTuple):
    """Device constants the step closes over (from ``SimSpec``)."""

    n_chunks: int
    page_chunk: jnp.ndarray   # (P,) i32
    chunk_first: jnp.ndarray  # (CH,) f32 table-local tuple coords
    chunk_last: jnp.ndarray   # (CH,) f32
    chunk_table: jnp.ndarray  # (CH,) i32


def coop_consts(spec) -> CoopConsts:
    if spec.page_chunk is None:
        raise ValueError(
            "spec has no chunk geometry — recompile the workload with "
            "compiler.compile_workload (seed-era SimSpecs cannot run the "
            "cooperative policy)"
        )
    return CoopConsts(
        n_chunks=int(spec.n_chunks),
        page_chunk=jnp.asarray(spec.page_chunk),
        chunk_first=jnp.asarray(spec.chunk_first),
        chunk_last=jnp.asarray(spec.chunk_last),
        chunk_table=jnp.asarray(spec.chunk_table),
    )


def init_coop_state(spec) -> CoopState:
    S, CH = spec.n_streams, int(spec.n_chunks)
    if CH <= 0:
        raise ValueError(
            "spec has no chunk geometry — recompile the workload with "
            "compiler.compile_workload"
        )
    return CoopState(
        done=jnp.zeros((S, CH), bool),
        cur_chunk=jnp.full(S, -1, jnp.int32),
        chunk_pos=jnp.zeros(S, jnp.float32),
        credit=jnp.zeros(S, jnp.float32),
        inflight=jnp.int32(-1),
    )


def chunk_pick(prev_inflight, new_inflight):
    """Did the I/O server switch to loading a NEW chunk this step?  The
    obs tier's CScan chunk-pick signal (``Telemetry.chunk_picks``): both
    args are the scalar inflight chunk id, ``-1`` = idle."""
    return (new_inflight >= 0) & (new_inflight != prev_inflight)


def _interest(cc: CoopConsts, active, start, end, q_tab, done):
    """(S, CH) pending interest + per-(stream, chunk) tuple overlap: a
    scan is interested in every not-yet-consumed chunk of its table that
    overlaps its range (``ScanState.chunks_remaining``)."""
    ov_lo = jnp.maximum(cc.chunk_first[None, :], start[:, None])
    ov_hi = jnp.minimum(cc.chunk_last[None, :], end[:, None])
    overlap = jnp.maximum(ov_hi - ov_lo, 0.0)
    in_range = (
        (overlap > 0.0)
        & (cc.chunk_table[None, :] == q_tab[:, None])
        & active[:, None]
    )
    return in_range & ~done, overlap


def _chunk_missing(cc: CoopConsts, cols, resident, page_col, page_valid,
                   n_cols: int):
    """(S, CH) "some page of my columns is absent" — the complement of the
    ABM's chunk availability.  One (CH, C) scatter + a broadcast AND keeps
    it fully vectorised (no per-stream scatter loop)."""
    missing = (~resident) & page_valid
    miss_cc = jnp.zeros((cc.n_chunks, n_cols), bool).at[
        cc.page_chunk, page_col
    ].max(missing)
    return jnp.any(miss_cc[None, :, :] & cols[:, None, :], axis=2)


#: pick→consume rounds unrolled per step: a chunk's CPU time can be ~one
#: step (TPC-H chunks at small scales), so completing one chunk and
#: starting the next must happen WITHIN a step or chunk boundaries
#: quantise every scan to <= 1 chunk/step — a 30-100% CPU-time inflation
#: the continuous event engine does not have.
_PICK_ROUNDS = 2


def cpu_phase(cc: CoopConsts, cstate: CoopState, *, active, start, end,
              cols, q_tab, rate_j, dt, credit_cap, resident, page_col,
              page_valid, s_idx) -> CoopCpu:
    """One CPU step of every CScan: pick-if-idle (UseRelevance), consume,
    complete — chained for ``_PICK_ROUNDS`` rounds so the leftover budget
    of a completed chunk flows into the next one within the same step
    (the event engine consumes continuously; any residue banks as capped
    credit for the next step).  Runs on the pre-advance view, like the
    in-order burst."""
    S, CH = cols.shape[0], cc.n_chunks
    n_cols = cols.shape[1]
    interest0, overlap = _interest(cc, active, start, end, q_tab,
                                   cstate.done)
    in_range = interest0 | cstate.done   # static within the step
    miss_sc = _chunk_missing(cc, cols, resident, page_col, page_valid,
                             n_cols)
    cid = jnp.arange(CH, dtype=jnp.int32)

    done = cstate.done
    cur = cstate.cur_chunk
    chunk_pos = cstate.chunk_pos
    budget = rate_j * dt + cstate.credit
    adv = jnp.zeros(S, jnp.float32)
    consumed_any = jnp.zeros(S, bool)
    completed_cc = jnp.zeros((CH, n_cols), bool)

    for _ in range(_PICK_ROUNDS):
        interest = in_range & active[:, None] & ~done
        avail = interest & ~miss_sc
        # UseRelevance pick for idle scans: the available chunk the FEWEST
        # scans are interested in (it becomes evictable soonest), lowest
        # chunk id on ties — exactly ``ABM.get_chunk``
        count = jnp.sum(interest, axis=0).astype(jnp.int32)   # (CH,)
        pick_key = jnp.where(
            avail, count[None, :] * (CH + 2) + cid[None, :],
            jnp.int32(1 << 30),
        )
        pick = jnp.argmin(pick_key, axis=1).astype(jnp.int32)
        can_pick = jnp.any(avail, axis=1)
        idle = cur < 0
        started = idle & can_pick
        cur = jnp.where(started, pick, cur)
        chunk_pos = jnp.where(started, 0.0, chunk_pos)

        consuming = cur >= 0
        ci = jnp.clip(cur, 0, CH - 1)
        cur_ov = overlap[s_idx, ci]
        room = jnp.maximum(cur_ov - chunk_pos, 0.0)
        adv_t = jnp.where(consuming, jnp.minimum(budget, room), 0.0)
        budget = budget - adv_t
        pos_in = chunk_pos + adv_t
        completed = consuming & (
            pos_in >= cur_ov - jnp.maximum(1e-3, 1e-6 * cur_ov)
        )
        consumed_any = consumed_any | (adv_t > 0.0) | completed
        done = done.at[s_idx, ci].max(completed)
        completed_cc = completed_cc.at[ci].max(cols & completed[:, None])
        adv = adv + jnp.where(completed, cur_ov, 0.0)
        cur = jnp.where(completed, -1, cur)
        chunk_pos = jnp.where(completed, 0.0, pos_in)

    # bank the residue ONLY for scans that did work and ended the step
    # between chunks — an idle (starved) scan accumulates nothing
    credit2 = jnp.where(
        consumed_any & (cur < 0),
        jnp.minimum(budget, credit_cap), 0.0,
    )

    # query completion: every interested chunk consumed (the engine's
    # ``chunks_remaining`` empty) — robust against f32 tuple rounding
    interest_after = in_range & active[:, None] & ~done
    finished = active & ~jnp.any(interest_after, axis=1)

    # pages consumed this step (completed chunks, consuming scan's
    # columns) — feeds the LRU clock and the churn diagnostic
    consumed_pages = completed_cc[cc.page_chunk, page_col] & page_valid
    # a chunk being consumed is pinned for its scan's columns
    # (``ABM.pin_chunk``); completed chunks unpin
    pin_cc = jnp.zeros((CH, n_cols), bool).at[jnp.clip(cur, 0, CH - 1)].max(
        cols & (cur >= 0)[:, None]
    )
    pin_pages = pin_cc[cc.page_chunk, page_col] & page_valid

    return CoopCpu(adv=adv, finished=finished,
                   consumed_pages=consumed_pages, pin_pages=pin_pages,
                   done=done, cur_chunk=cur, chunk_pos=chunk_pos,
                   credit=credit2)


def io_phase(cc: CoopConsts, *, done, cur_chunk, inflight, pin_pages,
             active, start, end, cols, q_tab, resident, free, page_chunk_sizes,
             page_col, page_valid, n_streams: int) -> CoopIo:
    """ABM's next-load decision as one batched selection.

    Runs on the post-advance view (new queries register their interest
    immediately).  The Keep<Load rule is enforced twice: chunk selection
    requires enough bytes held at strictly lower interest counts
    (feasibility), and the evictable mask the eviction kernel sees is
    restricted to pages of chunks with interest below the inflight
    chunk's LoadRelevance.
    """
    CH = cc.n_chunks
    S = n_streams
    page_size = page_chunk_sizes
    interest, _ = _interest(cc, active, start, end, q_tab, done)
    miss_sc = _chunk_missing(cc, cols, resident, page_col, page_valid,
                             cols.shape[1])
    avail = interest & ~miss_sc
    count = jnp.sum(interest, axis=0).astype(jnp.int32)       # (CH,)
    n_remaining = jnp.sum(interest, axis=1).astype(jnp.int32)  # (S,)
    consuming = cur_chunk >= 0
    starved = (
        active & ~consuming & (n_remaining > 0)
        & ~jnp.any(avail, axis=1)
    )

    # union of the interested scans' columns per chunk: the ABM loads a
    # chunk once for everyone (``_union_columns``)
    ucols = jnp.any(interest[:, :, None] & cols[:, None, :], axis=0)
    ucols_p = ucols[cc.page_chunk, page_col]
    missing_p = (~resident) & page_valid & ucols_p
    mb = jnp.zeros(CH, jnp.float32).at[cc.page_chunk].add(
        jnp.where(missing_p, page_size, 0.0)
    )

    # Keep < Load feasibility: bytes resident in chunks with interest
    # count strictly below k, via a bytes-by-count histogram (counts are
    # bounded by the stream count)
    count_p = count[cc.page_chunk]
    base_ev = resident & page_valid & ~pin_pages
    bb = jnp.zeros(S + 2, jnp.float32).at[jnp.clip(count_p, 0, S + 1)].add(
        jnp.where(base_ev, page_size, 0.0)
    )
    below = jnp.concatenate([jnp.zeros(1, jnp.float32), jnp.cumsum(bb)])
    feasible = free + below[jnp.clip(count, 0, S + 1)] >= mb

    # keep (or drop) the chunk in flight: it stays until fully resident
    # for the interested union, loses its interest, or turns infeasible
    infl_c = jnp.clip(inflight, 0, CH - 1)
    still = (
        (inflight >= 0) & (mb[infl_c] > 0) & (count[infl_c] > 0)
        & feasible[infl_c]
    )
    inflight1 = jnp.where(still, inflight, -1)

    # ABM next_load: best query first (starved, then fewest chunks
    # remaining), then that query's best chunk (most interested scans,
    # lowest id).  Lexicographic argmax in three masked reductions.
    qkey_s = (jnp.where(starved, 2048, 0)
              + (1023 - jnp.clip(n_remaining, 0, 1023)))      # (S,)
    qbest = jnp.max(
        jnp.where(interest, qkey_s[:, None], _NEGI), axis=0
    )                                                          # (CH,)
    loadable = (count > 0) & (mb > 0) & feasible
    q1 = jnp.where(loadable, qbest, _NEGI)
    qm = jnp.max(q1)
    c1 = jnp.where(loadable & (qbest == qm), count, _NEGI)
    cm = jnp.max(c1)
    sel_mask = loadable & (qbest == qm) & (count == cm)
    sel = jnp.argmax(sel_mask).astype(jnp.int32)   # first True = lowest id
    has_sel = jnp.any(sel_mask)
    inflight2 = jnp.where(
        inflight1 >= 0, inflight1, jnp.where(has_sel, sel, -1)
    )

    # the server's request set: missing pages of the inflight chunk in
    # page-index order (one chunk at a time — the serial ABM server)
    infl2_c = jnp.clip(inflight2, 0, CH - 1)
    P = cc.page_chunk.shape[0]
    want_p = (cc.page_chunk == infl2_c) & (inflight2 >= 0) & missing_p
    load_key = jnp.where(
        want_p, (1 << 24) - jnp.arange(P, dtype=jnp.int32), -1
    )

    # eviction view: only chunks with KeepRelevance strictly below the
    # inflight chunk's LoadRelevance may be evicted; fewest-interest
    # chunks go first, lowest chunk id on ties (whole chunks drain
    # together since all their pages share one key)
    infl_count = jnp.where(inflight2 >= 0, count[infl2_c], 0)
    evictable = base_ev & (count_p < infl_count)
    keep_key = (
        (S + 1.0 - count_p.astype(jnp.float32))
        + 0.5 * (CH - cc.page_chunk.astype(jnp.float32)) / CH
    )

    return CoopIo(load_key=load_key, wanted=want_p, evictable=evictable,
                  keep_key=keep_key, inflight=inflight2, starved=starved)


def chunk_horizon(spec, cstate: CoopState, hz):
    """Per-stream event horizon of the cooperative model (seconds): a
    consuming scan's next interesting moment is its current chunk's
    completion (``(overlap - chunk_pos) / rate``); an idle active scan
    needs a fine step to run the pick loop; inactive streams contribute
    nothing.  The chunk — not the page trigger — is CScan's clock, which
    is why this lives with the substrate and not the in-order step."""
    CH = int(spec.n_chunks)
    chunk_first = jnp.asarray(spec.chunk_first)
    chunk_last = jnp.asarray(spec.chunk_last)
    ci = jnp.clip(cstate.cur_chunk, 0, CH - 1)
    ov = jnp.maximum(
        jnp.minimum(chunk_last[ci], hz.end)
        - jnp.maximum(chunk_first[ci], hz.start),
        0.0,
    )
    rem_c = jnp.maximum(ov - cstate.chunk_pos, 0.0)
    t = jnp.where(
        cstate.cur_chunk >= 0,
        rem_c / jnp.maximum(hz.rate, 1.0),
        hz.dt_ref,
    )
    return jnp.where(hz.active, t, jnp.float32(np.inf))


def clear_on_query_change(done, finished):
    """A finished query's chunk flags reset — the next query registers a
    fresh ``chunks_remaining`` set (new ``ScanState``)."""
    return jnp.where(finished[:, None], False, done)


def chunk_geometry(db, tnames, page_rows):  # analysis: host
    """Compiler helper: global chunk ids for the compiled tables.

    Returns ``(n_chunks, chunk_first, chunk_last, chunk_table,
    page_chunk)`` where ``page_rows`` is the compiled page list as
    ``(table_index, first_tuple)`` pairs in global page order.  Page →
    chunk ownership follows ``ABM._ensure_chunk_meta``: a page belongs to
    the chunk containing its first tuple ("one page contains data from
    multiple adjacent chunks" — unique ownership by first tuple).
    """
    chunk_first, chunk_last, chunk_table = [], [], []
    offs = []
    for ti, tname in enumerate(tnames):
        t = db.tables[tname]
        offs.append(len(chunk_first))
        for ch in range(t.n_chunks):
            lo, hi = t.chunk_range(ch)
            chunk_first.append(float(lo))
            chunk_last.append(float(hi))
            chunk_table.append(ti)
    page_chunk = np.zeros(len(page_rows), np.int32)
    for gi, (ti, first) in enumerate(page_rows):
        t = db.tables[tnames[ti]]
        local = min(int(first // t.chunk_tuples), t.n_chunks - 1)
        page_chunk[gi] = offs[ti] + local
    return (
        len(chunk_first),
        np.asarray(chunk_first, np.float32),
        np.asarray(chunk_last, np.float32),
        np.asarray(chunk_table, np.int32),
        page_chunk,
    )
