"""Array-native batched simulation core (vmap-able PBM timeline).

Re-implements the buffer pool + scan machinery of ``repro.core.engine``
as fixed-shape JAX arrays with a pure ``step(state, cfg) -> state``:
one ``jax.vmap`` call batches an entire sweep axis, and the PBM bucketed
timeline runs as a Pallas kernel on TPU (jnp oracle elsewhere).

Kept separate from ``repro.core.__init__`` so the dict-based engine stays
importable without pulling in JAX.
"""

from .spec import SimSpec, build_spec
from .sim import (
    POLICY_IDS,
    ArrayResult,
    ArraySimConfig,
    SimState,
    init_state,
    make_config,
    make_runner,
    make_step,
    result_from_state,
    run_workload_array,
    stack_configs,
)
from .policies import next_consumption, target_buckets, time_to_bucket
from .validate import cross_validate

__all__ = [
    "ArrayResult",
    "ArraySimConfig",
    "POLICY_IDS",
    "SimSpec",
    "SimState",
    "build_spec",
    "cross_validate",
    "init_state",
    "make_config",
    "make_runner",
    "make_step",
    "next_consumption",
    "result_from_state",
    "run_workload_array",
    "stack_configs",
    "target_buckets",
    "time_to_bucket",
]
