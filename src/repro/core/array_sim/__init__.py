"""Array-native batched simulation core (vmap-able PBM timeline).

Re-implements the buffer pool + scan machinery of ``repro.core.engine``
as fixed-shape JAX arrays with a pure ``step(state, cfg) -> state``:
one ``jax.vmap`` call batches an entire sweep axis, and the batched
eviction selection runs as a Pallas kernel on TPU (jnp oracle elsewhere).

Buffer policies are *data*: the step drives a tuple of
:class:`~repro.core.array_sim.policies.ArrayPolicy` objects (pure-pytree
state + jit/vmap-safe hooks) and dispatches eviction on the score arrays
they provide, resolved by name through ``repro.core.policy_registry`` —
the same table the event engine uses.  All four paper policies run on
this substrate: in-order LRU/PBM/OPT, and CScan via the chunk-granular
cooperative substrate (``array_sim.coop``), blended per-lane so one
vmapped call covers a whole four-policy sweep.

Scans advance with the engine's per-page plan-trigger semantics (each
column keeps a fractional frontier cursor and blocks only at absent
triggers), so the full paper envelope runs batched — buffer pools from
10% of the accessed working set upward, cross-validated against the
event engine per ``validate.ERROR_BARS``.

``compiler.compile_workload`` lowers ANY multi-table workload (the §4.2
TPC-H throughput run included) into the same fixed-shape arrays via
global page indexing with per-table/per-column offsets; ``build_spec``
is the single-table legacy entry point over the same lowering.

Kept separate from ``repro.core.__init__`` so the dict-based engine stays
importable without pulling in JAX.
"""

from .spec import SimSpec, build_spec
from .compiler import compile_workload, referenced_tables
from .sim import (
    ArrayResult,
    ArraySimConfig,
    SimState,
    init_state,
    make_config,
    make_runner,
    make_step,
    resolve_policies,
    result_from_state,
    run_workload_array,
    stack_configs,
)
from .policies import (
    ArrayCScan,
    ArrayLRU,
    ArrayOPT,
    ArrayPBM,
    ArrayPolicy,
    HorizonView,
    StepCtx,
    next_consumption,
    shift_timeline,
    target_buckets,
    time_to_bucket,
)
from .validate import (
    cross_validate,
    cross_validate_sweep,
    cross_validate_tpch,
    cross_validate_tpch_sweep,
)

__all__ = [
    "ArrayCScan",
    "ArrayLRU",
    "ArrayOPT",
    "ArrayPBM",
    "ArrayPolicy",
    "ArrayResult",
    "ArraySimConfig",
    "HorizonView",
    "SimSpec",
    "SimState",
    "StepCtx",
    "build_spec",
    "compile_workload",
    "cross_validate",
    "cross_validate_sweep",
    "cross_validate_tpch",
    "cross_validate_tpch_sweep",
    "referenced_tables",
    "init_state",
    "make_config",
    "make_runner",
    "make_step",
    "next_consumption",
    "resolve_policies",
    "result_from_state",
    "run_workload_array",
    "shift_timeline",
    "stack_configs",
    "target_buckets",
    "time_to_bucket",
]
