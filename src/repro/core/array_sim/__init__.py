"""Array-native batched simulation core (vmap-able PBM timeline).

Re-implements the buffer pool + scan machinery of ``repro.core.engine``
as fixed-shape JAX arrays with a pure ``step(state, cfg) -> state``:
one ``jax.vmap`` call batches an entire sweep axis, and the PBM bucketed
timeline runs as a Pallas kernel on TPU (jnp oracle elsewhere).

Scans advance with the engine's per-page plan-trigger semantics (each
column keeps a fractional frontier cursor and blocks only at absent
triggers), so the full paper envelope runs batched — buffer pools from
10% of the accessed working set upward, cross-validated against the
event engine per ``validate.ERROR_BARS``.

Kept separate from ``repro.core.__init__`` so the dict-based engine stays
importable without pulling in JAX.
"""

from .spec import SimSpec, build_spec
from .sim import (
    POLICY_IDS,
    ArrayResult,
    ArraySimConfig,
    SimState,
    init_state,
    make_config,
    make_runner,
    make_step,
    result_from_state,
    run_workload_array,
    stack_configs,
)
from .policies import next_consumption, target_buckets, time_to_bucket
from .validate import cross_validate, cross_validate_sweep

__all__ = [
    "ArrayResult",
    "ArraySimConfig",
    "POLICY_IDS",
    "SimSpec",
    "SimState",
    "build_spec",
    "cross_validate",
    "cross_validate_sweep",
    "init_state",
    "make_config",
    "make_runner",
    "make_step",
    "next_consumption",
    "result_from_state",
    "run_workload_array",
    "stack_configs",
    "target_buckets",
    "time_to_bucket",
]
