"""Cross-validation harness: array backend vs the discrete-event engine.

Runs the same scaled microbenchmark workload through both simulators and
reports, per (buffer point, policy), the relative error of the two paper
metrics (average stream time and total I/O volume).  The array backend is
a discretised fluid approximation of the event engine, so small deviations
are expected; the acceptance envelope of this repo is the paper's small-
buffer operating range:

* ``buffer_frac`` 0.1, 0.2 and 0.4 of the accessed working set (700 MB/s,
  8 streams, quick-pass scale — the configuration of
  ``benchmarks/microbench.py``),
* <= 10% relative error on both metrics for PBM at every point and for
  LRU at 0.2 / 0.4,
* <= 13% for LRU at the 0.1 deep-thrash point — the event engine
  supersaturates there (its loads exceed one load per page consumption:
  sharing collapses entirely while ~23% of loads are evicted before
  first use), and the fluid step reproduces that churn spiral only
  partially; the residual is documented in the README.

A truncated array run (``max_time``/``max_slices`` livelock guard) is a
hard error: :func:`cross_validate` raises instead of comparing a lower
bound against a finished event run.

Usage::

    PYTHONPATH=src python -m repro.core.array_sim.validate            # 3-point sweep
    PYTHONPATH=src python -m repro.core.array_sim.validate --buffer-frac 0.4
    PYTHONPATH=src python -m repro.core.array_sim.validate --scale 0.1

Exits non-zero when a point misses its error bar.  Also consumed by
``tests/test_array_sim.py``.
"""

from __future__ import annotations

import argparse
import sys
import time
from typing import Dict, List, Optional, Sequence

from ..engine import EngineConfig, run_workload
from ..workload import make_lineitem_db, micro_accessed_bytes, micro_streams
from .sim import make_runner, run_workload_array
from .spec import build_spec

#: validated operating envelope (buffer_frac, policy) -> max |rel err|
ERROR_BARS = {
    (0.1, "lru"): 0.13,    # engine churn spiral, partially reproduced
    (0.1, "pbm"): 0.10,
    (0.2, "lru"): 0.10,
    (0.2, "pbm"): 0.10,
    (0.4, "lru"): 0.10,
    (0.4, "pbm"): 0.10,
}
DEFAULT_FRACS = (0.1, 0.2, 0.4)


def cross_validate(
    scale: float = 0.25,
    n_streams: int = 8,
    queries_per_stream: int = 16,
    seed: int = 3,
    buffer_frac: float = 0.4,
    bandwidth: float = 700e6,
    policies: Sequence[str] = ("lru", "pbm"),
    time_slice: Optional[float] = None,
    _shared=None,
) -> List[Dict]:
    """Run event + array backends on one microbenchmark point; return one
    row per policy with both results and their relative differences.

    Raises ``RuntimeError`` if the array run was truncated by the livelock
    guard — a truncated run reports lower bounds, not results.
    """
    if time_slice is None:
        time_slice = 0.1 * scale  # microbench convention
    if _shared is None:
        db = make_lineitem_db(scale_tuples=int(180_000_000 * scale))
        ws = micro_accessed_bytes(db)
        streams = micro_streams(db, n_streams=n_streams,
                                queries_per_stream=queries_per_stream,
                                seed=seed)
        spec = build_spec(db, streams)
        runners = {}
    else:
        db, ws, streams, spec, runners = _shared
    cap = max(1 << 22, int(buffer_frac * ws))

    rows: List[Dict] = []
    for pol in policies:
        cfg = EngineConfig(bandwidth=bandwidth, buffer_bytes=cap,
                           sample_interval=2.0, pbm_time_slice=time_slice)
        t0 = time.time()
        ev = run_workload(db, streams, pol, cfg)
        ev_wall = time.time() - t0
        if pol not in runners:
            runners[pol] = make_runner(spec, bandwidth_ref=bandwidth,
                                       time_slice=time_slice,
                                       static_policy=pol)
        ar = run_workload_array(
            db, streams, pol, capacity_bytes=cap, bandwidth=bandwidth,
            time_slice=time_slice, spec=spec, runner=runners[pol],
        )
        if ar.extras.get("truncated"):
            raise RuntimeError(
                f"array run truncated by the livelock guard at "
                f"buffer_frac={buffer_frac} policy={pol} "
                f"({ar.extras['unfinished_streams']} unfinished streams "
                f"after {ar.sim_time:.1f}s sim time) — refusing to compare "
                "a lower bound against a finished event run"
            )
        rows.append({
            "policy": pol,
            "buffer_frac": buffer_frac,
            "event_stream_time_s": round(ev.avg_stream_time, 4),
            "array_stream_time_s": round(ar.avg_stream_time, 4),
            "event_io_gb": round(ev.io_gb, 4),
            "array_io_gb": round(ar.io_gb, 4),
            "stream_time_rel_err": round(
                ar.avg_stream_time / max(ev.avg_stream_time, 1e-12) - 1, 4),
            "io_rel_err": round(ar.io_gb / max(ev.io_gb, 1e-12) - 1, 4),
            "event_wall_s": round(ev_wall, 3),
            "array_wall_s": round(ar.wall_s, 3),
            "array_steps": ar.steps,
            "truncated": ar.extras.get("truncated", False),
            "array_churn_loads": ar.extras.get("churn_loads", 0),
        })
    return rows


def cross_validate_sweep(
    fracs: Sequence[float] = DEFAULT_FRACS,
    scale: float = 0.25,
    **kw,
) -> List[Dict]:
    """:func:`cross_validate` over several buffer points, reusing the
    workload, spec, and compiled runners across points (capacity is a
    traced config scalar, so one runner serves the whole sweep)."""
    db = make_lineitem_db(scale_tuples=int(180_000_000 * scale))
    ws = micro_accessed_bytes(db)
    streams = micro_streams(db, n_streams=kw.get("n_streams", 8),
                            queries_per_stream=kw.get("queries_per_stream", 16),
                            seed=kw.get("seed", 3))
    spec = build_spec(db, streams)
    shared = (db, ws, streams, spec, {})
    rows: List[Dict] = []
    for f in fracs:
        rows.extend(cross_validate(scale=scale, buffer_frac=f,
                                   _shared=shared, **kw))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--buffer-frac", type=float, default=None,
                    help="single point; default sweeps 0.1, 0.2, 0.4")
    ap.add_argument("--streams", type=int, default=8)
    ap.add_argument("--queries", type=int, default=16)
    ap.add_argument("--seed", type=int, default=3)
    args = ap.parse_args()
    fracs = [args.buffer_frac] if args.buffer_frac is not None else \
        list(DEFAULT_FRACS)
    rows = cross_validate_sweep(
        fracs=fracs, scale=args.scale, n_streams=args.streams,
        queries_per_stream=args.queries, seed=args.seed,
    )
    failed = 0
    for r in rows:
        bar = ERROR_BARS.get((r["buffer_frac"], r["policy"]), 0.10)
        worst = max(abs(r["stream_time_rel_err"]), abs(r["io_rel_err"]))
        ok = worst <= bar
        failed += 0 if ok else 1
        print(
            f"buf={r['buffer_frac']:<4} {r['policy']:4s} "
            f"stream_time: event={r['event_stream_time_s']:.2f}s "
            f"array={r['array_stream_time_s']:.2f}s "
            f"({r['stream_time_rel_err']*100:+.1f}%) | io: "
            f"event={r['event_io_gb']:.3f}GB array={r['array_io_gb']:.3f}GB "
            f"({r['io_rel_err']*100:+.1f}%) | wall event={r['event_wall_s']:.2f}s "
            f"array={r['array_wall_s']:.2f}s | "
            f"{'OK' if ok else f'FAIL (bar {bar:.0%})'}"
        )
    if failed:
        print(f"{failed} point(s) outside the validated envelope",
              file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
