"""Cross-validation harness: array backend vs the discrete-event engine.

Runs the same scaled microbenchmark workload through both simulators and
reports, per policy, the relative error of the two paper metrics (average
stream time and total I/O volume).  The array backend is a discretised
fluid approximation of the event engine, so small deviations are expected;
the acceptance bar for this repo is 10% on the default operating point
(buffer = 40% of the accessed working set, 700 MB/s, 8 streams — the
quick-pass configuration of ``benchmarks/microbench.py``).

Usage::

    PYTHONPATH=src python -m repro.core.array_sim.validate           # default point
    PYTHONPATH=src python -m repro.core.array_sim.validate --scale 0.1

Also consumed by ``tests/test_array_sim.py``.
"""

from __future__ import annotations

import argparse
import time
from typing import Dict, List, Optional, Sequence

from ..engine import EngineConfig, run_workload
from ..workload import make_lineitem_db, micro_accessed_bytes, micro_streams
from .sim import make_runner, run_workload_array
from .spec import build_spec


def cross_validate(
    scale: float = 0.25,
    n_streams: int = 8,
    queries_per_stream: int = 16,
    seed: int = 3,
    buffer_frac: float = 0.4,
    bandwidth: float = 700e6,
    policies: Sequence[str] = ("lru", "pbm"),
    time_slice: Optional[float] = None,
) -> List[Dict]:
    """Run event + array backends on one microbenchmark point; return one
    row per policy with both results and their relative differences."""
    if time_slice is None:
        time_slice = 0.1 * scale  # microbench convention
    db = make_lineitem_db(scale_tuples=int(180_000_000 * scale))
    ws = micro_accessed_bytes(db)
    streams = micro_streams(db, n_streams=n_streams,
                            queries_per_stream=queries_per_stream, seed=seed)
    cap = max(1 << 22, int(buffer_frac * ws))
    spec = build_spec(db, streams)

    rows: List[Dict] = []
    for pol in policies:
        cfg = EngineConfig(bandwidth=bandwidth, buffer_bytes=cap,
                           sample_interval=2.0, pbm_time_slice=time_slice)
        t0 = time.time()
        ev = run_workload(db, streams, pol, cfg)
        ev_wall = time.time() - t0
        runner = make_runner(spec, bandwidth_ref=bandwidth,
                             time_slice=time_slice, static_policy=pol)
        ar = run_workload_array(
            db, streams, pol, capacity_bytes=cap, bandwidth=bandwidth,
            time_slice=time_slice, spec=spec, runner=runner,
        )
        rows.append({
            "policy": pol,
            "buffer_frac": buffer_frac,
            "event_stream_time_s": round(ev.avg_stream_time, 4),
            "array_stream_time_s": round(ar.avg_stream_time, 4),
            "event_io_gb": round(ev.io_gb, 4),
            "array_io_gb": round(ar.io_gb, 4),
            "stream_time_rel_err": round(
                ar.avg_stream_time / max(ev.avg_stream_time, 1e-12) - 1, 4),
            "io_rel_err": round(ar.io_gb / max(ev.io_gb, 1e-12) - 1, 4),
            "event_wall_s": round(ev_wall, 3),
            "array_wall_s": round(ar.wall_s, 3),
            "array_steps": ar.steps,
        })
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--buffer-frac", type=float, default=0.4)
    ap.add_argument("--streams", type=int, default=8)
    ap.add_argument("--queries", type=int, default=16)
    ap.add_argument("--seed", type=int, default=3)
    args = ap.parse_args()
    rows = cross_validate(
        scale=args.scale, n_streams=args.streams,
        queries_per_stream=args.queries, seed=args.seed,
        buffer_frac=args.buffer_frac,
    )
    for r in rows:
        print(
            f"{r['policy']:4s} stream_time: event={r['event_stream_time_s']:.2f}s "
            f"array={r['array_stream_time_s']:.2f}s "
            f"({r['stream_time_rel_err']*100:+.1f}%) | io: "
            f"event={r['event_io_gb']:.3f}GB array={r['array_io_gb']:.3f}GB "
            f"({r['io_rel_err']*100:+.1f}%) | wall event={r['event_wall_s']:.2f}s "
            f"array={r['array_wall_s']:.2f}s"
        )


if __name__ == "__main__":
    main()
