"""Cross-validation harness: array backend vs the discrete-event engine.

Runs the same workloads through both simulators and reports, per
(workload, buffer point, policy, stepper), the relative error of the two
paper metrics (average stream time and total I/O volume).  Every
registered array policy validates here — the paper's full four-way
comparison (lru / cscan / pbm / opt) on both suites — and BOTH time
engines (``--stepper both``, the default): the fixed-dt cadence and the
event-horizon stepper must each sit inside the same bars.  The slow
event-engine reference runs are computed once per point and shared
between steppers:

* **micro** — the scaled §4.1 microbenchmark (single table, the
  original envelope of PR 1/2);
* **tpch** — the §4.2 multi-table throughput workload lowered through
  ``compiler.compile_workload`` (8 tables, rotated 22-template streams),
  validated at the paper's default operating shape (buffer fracs
  0.15/0.3/0.5 of the accessed volume, 600 MB/s; bars in
  ``TPCH_ERROR_BARS``).

The array backend is a discretised fluid approximation of the event
engine, so small deviations are expected; the acceptance envelope of the
micro suite is the paper's small-buffer operating range:

* ``buffer_frac`` 0.1, 0.2 and 0.4 of the accessed working set (700 MB/s,
  8 streams, quick-pass scale — the configuration of
  ``benchmarks/microbench.py``),
* <= 10% relative error on both metrics for PBM at every point and for
  LRU at 0.2 / 0.4,
* <= 13% for LRU at the 0.1 deep-thrash point — the event engine
  supersaturates there (its loads exceed one load per page consumption:
  sharing collapses entirely while ~23% of loads are evicted before
  first use), and the fluid step reproduces that churn spiral only
  partially; the residual is documented in the README;
* <= 13% for OPT (largest at the 0.4 point) — the array oracle holds
  its victim ranking stale on the slice cadence to reproduce the event
  oracle's burst-stale churn (see ``policies.ArrayOPT``); the residual
  is the part of that churn the slice quantisation misses;
* <= 15% for CScan on TPC-H (largest at the 0.5 point) — the
  chunk-granular cooperative fluid (``array_sim.coop``) approximates
  ABM's choose-chunk/choose-scan loop without its per-event timing.

A truncated array run (``max_time``/``max_slices`` livelock guard) is a
hard error: :func:`cross_validate` raises instead of comparing a lower
bound against a finished event run.

Usage::

    PYTHONPATH=src python -m repro.core.array_sim.validate            # 3-point sweep
    PYTHONPATH=src python -m repro.core.array_sim.validate --buffer-frac 0.4
    PYTHONPATH=src python -m repro.core.array_sim.validate --scale 0.1
    PYTHONPATH=src python -m repro.core.array_sim.validate --fit-bars  # refit report

``--fit-bars`` reports measured errors without enforcing, and prints
ready-to-paste ``ERROR_BARS`` / ``TPCH_ERROR_BARS`` dict literals — the
CI ``refit-error-bars`` job runs it at any scale, and recalibrating is a
copy-paste of that output into this file.

Exits non-zero when a point misses its error bar.  Also consumed by
``tests/test_array_sim.py`` and ``tests/test_array_cscan_opt.py``.
"""

from __future__ import annotations

import argparse
import math
import sys
import time
from typing import Dict, List, Optional, Sequence

from .. import policy_registry
from ..engine import EngineConfig, run_workload
from ..workload import (
    make_lineitem_db,
    make_tpch_db,
    micro_accessed_bytes,
    micro_streams,
    tpch_accessed_bytes,
    tpch_streams,
)
from .compiler import compile_workload
from .sim import make_runner, run_workload_array
from .spec import build_spec

#: every policy both backends can run — the paper's four-way comparison
DEFAULT_POLICIES = tuple(policy_registry.names(backend="array"))

#: validated operating envelope (buffer_frac, policy) -> max |rel err|
#: (refit by ``--fit-bars`` at the quick-pass scale after PR 10's
#: wake-exact supersaturated macro-jumps — the horizon stepper now
#: macro-steps the churn spiral, so its deep-thrash LRU residual
#: grows from the fixed stepper's -12.6% to -16.0% stream time; the
#: documented partially-reproduced churn spiral, sampled on a coarser
#: cadence, hence the widened 0.1 LRU bar.  All other points measure
#: at or under their previous fit.)
ERROR_BARS = {
    (0.1, "cscan"): 0.10,
    (0.1, "lru"): 0.21,    # engine churn spiral + wake-exact cadence
    (0.1, "opt"): 0.10,
    (0.1, "pbm"): 0.10,
    (0.2, "cscan"): 0.10,
    (0.2, "lru"): 0.10,
    (0.2, "opt"): 0.10,
    (0.2, "pbm"): 0.10,
    (0.4, "cscan"): 0.10,
    (0.4, "lru"): 0.10,
    (0.4, "opt"): 0.13,    # slice-stale oracle residual (see ArrayOPT)
    (0.4, "pbm"): 0.10,
}
DEFAULT_FRACS = (0.1, 0.2, 0.4)

#: TPC-H multi-table envelope (buffer_frac, policy) -> max |rel err|,
#: fit at the quick-pass TPC-H point (scale 0.05, 4 streams, 600 MB/s,
#: seed 7 — the paper's §4.2 operating shape scaled down like the micro
#: bars were; re-fit at full scale via the CI ``refit-error-bars`` job).
#: Measured at fit time: <= 5% for lru/pbm everywhere except the
#: 0.5-buffer points (LRU +9.9% / PBM +7.6% I/O — mild-pressure churn
#: slightly over-reproduced); <= 8% for opt; cscan's cooperative fluid
#: runs +8/+1/+11% on stream time (fracs 0.15/0.3/0.5), hence its two
#: widened bars — all inside the <= 15% acceptance ceiling for the
#: array-CScan / array-OPT ports.  Refit after PR 10 (wake-exact
#: horizon macro-jumps): LRU's horizon rows move to -8.4% I/O at 0.3
#: and +10.8% I/O at 0.5 (macro-sampled churn cadence), nudging those
#: two bars up a point or two; every other point holds its fit.
TPCH_ERROR_BARS = {
    (0.15, "cscan"): 0.11,
    (0.15, "lru"): 0.10,
    (0.15, "opt"): 0.10,
    (0.15, "pbm"): 0.10,
    (0.3, "cscan"): 0.10,
    (0.3, "lru"): 0.11,
    (0.3, "opt"): 0.10,
    (0.3, "pbm"): 0.10,
    (0.5, "cscan"): 0.15,
    (0.5, "lru"): 0.14,
    (0.5, "opt"): 0.10,
    (0.5, "pbm"): 0.10,
}
TPCH_DEFAULTS = dict(scale=0.05, n_streams=4, buffer_frac=0.3,
                     bandwidth=600e6, seed=7)


def _compare_point(
    shared,
    policies: Sequence[str],
    buffer_frac: float,
    bandwidth: float,
    time_slice: float,
    sample_interval: float,
    workload: str,
    stepper: str = "fixed",
) -> List[Dict]:
    """One (buffer point) comparison, both backends, one row per policy —
    the single harness behind the micro AND TPC-H suites.

    ``stepper`` selects the array time engine (fixed | horizon); the
    event-engine reference runs are cached in ``shared`` so validating
    both steppers pays for the slow dict engine once.

    Raises ``RuntimeError`` if the array run was truncated by the livelock
    guard — a truncated run reports lower bounds, not results.
    """
    db, ws, streams, spec, runners = shared[:5]
    ev_cache = shared[5] if len(shared) > 5 else {}
    cap = max(1 << 22, int(buffer_frac * ws))
    rows: List[Dict] = []
    for pol in policies:
        ev_key = (pol, buffer_frac, bandwidth)
        if ev_key not in ev_cache:
            cfg = EngineConfig(bandwidth=bandwidth, buffer_bytes=cap,
                               sample_interval=sample_interval,
                               pbm_time_slice=time_slice)
            t0 = time.time()
            ev_cache[ev_key] = (run_workload(db, streams, pol, cfg),
                                time.time() - t0)
        ev, ev_wall = ev_cache[ev_key]
        if (pol, stepper) not in runners:
            runners[(pol, stepper)] = make_runner(
                spec, bandwidth_ref=bandwidth, time_slice=time_slice,
                policies=(pol,), stepper=stepper,
            )
        ar = run_workload_array(
            db, streams, pol, capacity_bytes=cap, bandwidth=bandwidth,
            time_slice=time_slice, spec=spec,
            runner=runners[(pol, stepper)],
        )
        if ar.extras.get("truncated"):
            raise RuntimeError(
                f"array run truncated by the livelock guard at {workload} "
                f"buffer_frac={buffer_frac} policy={pol} "
                f"stepper={stepper} "
                f"({ar.extras['unfinished_streams']} unfinished streams "
                f"after {ar.sim_time:.1f}s sim time) — refusing to compare "
                "a lower bound against a finished event run"
            )
        rows.append({
            "workload": workload,
            "policy": pol,
            "stepper": stepper,
            "buffer_frac": buffer_frac,
            "event_stream_time_s": round(ev.avg_stream_time, 4),
            "array_stream_time_s": round(ar.avg_stream_time, 4),
            "event_io_gb": round(ev.io_gb, 4),
            "array_io_gb": round(ar.io_gb, 4),
            "stream_time_rel_err": round(
                ar.avg_stream_time / max(ev.avg_stream_time, 1e-12) - 1, 4),
            "io_rel_err": round(ar.io_gb / max(ev.io_gb, 1e-12) - 1, 4),
            "event_wall_s": round(ev_wall, 3),
            "array_wall_s": round(ar.wall_s, 3),
            "array_steps": ar.steps,
            "array_macro_steps": ar.extras.get("macro_steps", ar.steps),
            "array_skipped_time": ar.extras.get("skipped_time", 0.0),
            "truncated": ar.extras.get("truncated", False),
            "array_churn_loads": ar.extras.get("churn_loads", 0),
        })
    return rows


def cross_validate(
    scale: float = 0.25,
    n_streams: int = 8,
    queries_per_stream: int = 16,
    seed: int = 3,
    buffer_frac: float = 0.4,
    bandwidth: float = 700e6,
    policies: Sequence[str] = DEFAULT_POLICIES,
    time_slice: Optional[float] = None,
    stepper: str = "fixed",
    _shared=None,
) -> List[Dict]:
    """Run event + array backends on one microbenchmark point; return one
    row per policy with both results and their relative differences."""
    if time_slice is None:
        time_slice = 0.1 * scale  # microbench convention
    if _shared is None:
        db = make_lineitem_db(scale_tuples=int(180_000_000 * scale))
        ws = micro_accessed_bytes(db)
        streams = micro_streams(db, n_streams=n_streams,
                                queries_per_stream=queries_per_stream,
                                seed=seed)
        _shared = (db, ws, streams, build_spec(db, streams), {}, {})
    return _compare_point(_shared, policies, buffer_frac, bandwidth,
                          time_slice, sample_interval=2.0, workload="micro",
                          stepper=stepper)


def cross_validate_sweep(
    fracs: Sequence[float] = DEFAULT_FRACS,
    scale: float = 0.25,
    steppers: Sequence[str] = ("fixed",),
    **kw,
) -> List[Dict]:
    """:func:`cross_validate` over several buffer points (and optionally
    both time engines), reusing the workload, spec, compiled runners AND
    the slow event-engine reference runs across points — capacity is a
    traced config scalar, so one runner serves the whole sweep, and the
    dict engine runs once per point however many steppers validate."""
    db = make_lineitem_db(scale_tuples=int(180_000_000 * scale))
    ws = micro_accessed_bytes(db)
    streams = micro_streams(db, n_streams=kw.get("n_streams", 8),
                            queries_per_stream=kw.get("queries_per_stream", 16),
                            seed=kw.get("seed", 3))
    spec = build_spec(db, streams)
    shared = (db, ws, streams, spec, {}, {})
    rows: List[Dict] = []
    for f in fracs:
        for stepper in steppers:
            rows.extend(cross_validate(scale=scale, buffer_frac=f,
                                       stepper=stepper, _shared=shared,
                                       **kw))
    return rows


def cross_validate_tpch(
    scale: float = 0.05,
    n_streams: int = 4,
    seed: int = 7,
    buffer_frac: float = 0.3,
    bandwidth: float = 600e6,
    policies: Sequence[str] = DEFAULT_POLICIES,
    time_slice: Optional[float] = None,
    stepper: str = "fixed",
    _shared=None,
) -> List[Dict]:
    """TPC-H cross-validation point: the §4.2 multi-table workload (8
    tables, 22 rotated query templates per stream, compiled through
    ``compiler.compile_workload``) run on both the event engine and the
    array backend via the same :func:`_compare_point` harness as the
    micro suite — all four paper policies."""
    if time_slice is None:
        time_slice = 0.1 * scale  # same scaling convention as the micro path
    if _shared is None:
        db = make_tpch_db(scale=scale)
        streams = tpch_streams(db, n_streams=n_streams, seed=seed)
        ws = tpch_accessed_bytes(db, streams)
        _shared = (db, ws, streams, compile_workload(db, streams), {}, {})
    return _compare_point(_shared, policies, buffer_frac, bandwidth,
                          time_slice, sample_interval=5.0, workload="tpch",
                          stepper=stepper)


def cross_validate_tpch_sweep(
    fracs: Optional[Sequence[float]] = None,
    scale: float = 0.05,
    steppers: Sequence[str] = ("fixed",),
    **kw,
) -> List[Dict]:
    """:func:`cross_validate_tpch` over the enforced TPC-H buffer points
    (default: every frac in ``TPCH_ERROR_BARS``), reusing the workload,
    compiled spec, runners, and event-engine reference runs across points
    (and steppers) — so the CLI and the ``refit-error-bars`` job measure
    the whole envelope, including the widened 0.5 LRU bar, not just the
    default operating point."""
    if fracs is None:
        fracs = sorted({f for (f, _pol) in TPCH_ERROR_BARS})
    db = make_tpch_db(scale=scale)
    streams = tpch_streams(db, n_streams=kw.get("n_streams", 4),
                           seed=kw.get("seed", 7))
    ws = tpch_accessed_bytes(db, streams)
    spec = compile_workload(db, streams)
    shared = (db, ws, streams, spec, {}, {})
    rows: List[Dict] = []
    for f in fracs:
        for stepper in steppers:
            rows.extend(cross_validate_tpch(scale=scale, buffer_frac=f,
                                            stepper=stepper,
                                            _shared=shared, **kw))
    return rows


def fit_bars_literal(rows: List[Dict]) -> str:
    """Render measured errors as ready-to-paste ``ERROR_BARS`` /
    ``TPCH_ERROR_BARS`` dict literals (the refit workflow's output:
    recalibrating the envelope is a copy-paste into this file, not a
    transcription).  Suggested bar = measured worst error + 25% headroom,
    floored at the 10% default, rounded up to the percent."""
    per_wl: Dict[str, Dict] = {}
    for r in rows:
        wl = r.get("workload", "micro")
        worst = max(abs(r["stream_time_rel_err"]), abs(r["io_rel_err"]))
        bar = max(0.10, math.ceil(worst * 1.25 * 100) / 100)
        key = (r["buffer_frac"], r["policy"])
        wl_bars = per_wl.setdefault(wl, {})
        # one bar per point covering EVERY validated stepper (the fixed
        # and horizon rows of one point fold into the max)
        wl_bars[key] = max(bar, wl_bars.get(key, 0.0))
    names = {"micro": "ERROR_BARS", "tpch": "TPCH_ERROR_BARS"}
    out = ["# fitted bars (measured worst error x1.25, >= 10%) — paste "
           "into validate.py:"]
    for wl in sorted(per_wl):
        out.append(f"{names.get(wl, wl.upper() + '_ERROR_BARS')} = {{")
        # deterministic key order — numeric frac ascending, then policy
        # name — so a refit diff is copy-paste stable (str-sorting put
        # (0.25, ...) before (0.1, ...) whenever both appeared)
        for (frac, pol), bar in sorted(per_wl[wl].items()):
            out.append(f"    ({frac}, {pol!r}): {bar:.2f},")
        out.append("}")
    return "\n".join(out)


def _print_rows(rows: List[Dict], enforce: bool = True) -> int:
    """Render rows; return the count outside the envelope (0 when
    ``enforce`` is off — the ``--fit-bars`` reporting mode)."""
    failed = 0
    for r in rows:
        wl = r.get("workload", "micro")
        bars = TPCH_ERROR_BARS if wl == "tpch" else ERROR_BARS
        bar = bars.get((r["buffer_frac"], r["policy"]), 0.10)
        worst = max(abs(r["stream_time_rel_err"]), abs(r["io_rel_err"]))
        ok = worst <= bar
        if enforce:
            failed += 0 if ok else 1
            verdict = "OK" if ok else f"FAIL (bar {bar:.0%})"
        else:
            verdict = f"measured {worst:.1%} (current bar {bar:.0%})"
        print(
            f"{wl:5s} buf={r['buffer_frac']:<4} {r['policy']:4s} "
            f"[{r.get('stepper', 'fixed'):7s}] "
            f"stream_time: event={r['event_stream_time_s']:.2f}s "
            f"array={r['array_stream_time_s']:.2f}s "
            f"({r['stream_time_rel_err']*100:+.1f}%) | io: "
            f"event={r['event_io_gb']:.3f}GB array={r['array_io_gb']:.3f}GB "
            f"({r['io_rel_err']*100:+.1f}%) | wall event={r['event_wall_s']:.2f}s "
            f"array={r['array_wall_s']:.2f}s | {verdict}"
        )
    return failed


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.25,
                    help="microbenchmark workload scale")
    ap.add_argument("--buffer-frac", type=float, default=None,
                    help="single micro point; default sweeps 0.1, 0.2, 0.4")
    ap.add_argument("--streams", type=int, default=8)
    ap.add_argument("--queries", type=int, default=16)
    ap.add_argument("--seed", type=int, default=3)
    ap.add_argument("--workload", choices=["micro", "tpch", "all"],
                    default="all",
                    help="which cross-validation suite(s) to run")
    ap.add_argument("--tpch-scale", type=float,
                    default=TPCH_DEFAULTS["scale"])
    ap.add_argument("--tpch-streams", type=int,
                    default=TPCH_DEFAULTS["n_streams"])
    ap.add_argument("--tpch-buffer-frac", type=float, default=None,
                    help="single TPC-H point; default sweeps every frac "
                         "in TPCH_ERROR_BARS")
    ap.add_argument("--fit-bars", action="store_true",
                    help="report measured errors without enforcing the "
                         "bars — the CI refit job runs this at full scale "
                         "to recalibrate ERROR_BARS / TPCH_ERROR_BARS")
    ap.add_argument("--stepper", choices=["fixed", "horizon", "both"],
                    default="both",
                    help="array time engine(s) to validate; the bars are "
                         "enforced for BOTH by default (the event-engine "
                         "reference runs are shared, so the second "
                         "stepper costs only its array runs)")
    args = ap.parse_args()
    steppers = ("fixed", "horizon") if args.stepper == "both" \
        else (args.stepper,)
    rows: List[Dict] = []
    if args.workload in ("micro", "all"):
        fracs = [args.buffer_frac] if args.buffer_frac is not None else \
            list(DEFAULT_FRACS)
        rows.extend(cross_validate_sweep(
            fracs=fracs, scale=args.scale, n_streams=args.streams,
            queries_per_stream=args.queries, seed=args.seed,
            steppers=steppers,
        ))
    if args.workload in ("tpch", "all"):
        tpch_fracs = [args.tpch_buffer_frac] \
            if args.tpch_buffer_frac is not None else None
        rows.extend(cross_validate_tpch_sweep(
            fracs=tpch_fracs, scale=args.tpch_scale,
            n_streams=args.tpch_streams,
            bandwidth=TPCH_DEFAULTS["bandwidth"],
            seed=TPCH_DEFAULTS["seed"],
            steppers=steppers,
        ))
    failed = _print_rows(rows, enforce=not args.fit_bars)
    if args.fit_bars:
        print(fit_bars_literal(rows))
    if failed:
        print(f"{failed} point(s) outside the validated envelope",
              file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
