"""PDT-lite: positional delta trees and SID/RID translation (paper §2.1).

Vectorwise handles updates with in-memory Positional Delta Trees; scans read
stale columnar data and merge PDT differences on the fly.  The paper's CScan
integration hinges on translating between

* **SID** (Stable ID) — 0-based dense enumeration of tuples in stable storage,
* **RID** (Row ID)    — 0-based dense enumeration of the *visible* stream
  (after applying inserts/deletes).

Key properties reproduced here, straight from the paper:

* RID→SID is **not injective** (all inserts anchored before a stable tuple map
  to that tuple's SID), hence two inverse variants exist:
  ``sid_to_rid_low`` and ``sid_to_rid_high``.
* For a *deleted* stable tuple there is no RID that maps to its SID, yet its
  SID still translates: "the lowest RID that translates into a SID higher
  than the one of the deleted tuple".
* Chunks are SID ranges; ABM works purely on SIDs.  A delivered chunk's SID
  range is widened to a RID range via (low, high) translation and must be
  **trimmed** against RID ranges already produced, because neighbouring
  chunks' RID ranges may overlap (out-of-order delivery!).  This is
  :class:`CScanMergeState`.

The structure here is list+bisect rather than an actual counted B-tree; the
translation semantics (which is what the paper's correctness depends on) are
identical, and the engine/test layers only rely on those semantics.
"""

from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


class PDT:
    """Positional delta tree over a stable table of ``n_stable`` tuples.

    Inserts are anchored to the SID of the first stable tuple that follows
    them (anchor ``n_stable`` = append at end).  Deletes mark stable SIDs.
    Modifications patch stable tuples in place (no positional effect).
    """

    def __init__(self, n_stable: int):
        self.n_stable = n_stable
        self._ins_keys: List[int] = []      # sorted anchor SIDs with inserts
        self._ins_counts: Dict[int, int] = {}
        self._ins_values: Dict[int, List[object]] = {}
        self._del_keys: List[int] = []      # sorted deleted SIDs
        self._mods: Dict[int, object] = {}

    # ---- update API --------------------------------------------------------
    def insert(self, anchor_sid: int, value: object = None) -> None:
        if not (0 <= anchor_sid <= self.n_stable):
            raise ValueError(f"anchor sid {anchor_sid} out of range")
        if anchor_sid not in self._ins_counts:
            bisect.insort(self._ins_keys, anchor_sid)
            self._ins_counts[anchor_sid] = 0
            self._ins_values[anchor_sid] = []
        self._ins_counts[anchor_sid] += 1
        self._ins_values[anchor_sid].append(value)

    def delete(self, sid: int) -> None:
        if not (0 <= sid < self.n_stable):
            raise ValueError(f"sid {sid} out of range")
        i = bisect.bisect_left(self._del_keys, sid)
        if i < len(self._del_keys) and self._del_keys[i] == sid:
            return  # idempotent
        self._del_keys.insert(i, sid)

    def modify(self, sid: int, value: object) -> None:
        if not (0 <= sid < self.n_stable):
            raise ValueError(f"sid {sid} out of range")
        self._mods[sid] = value

    def is_deleted(self, sid: int) -> bool:
        i = bisect.bisect_left(self._del_keys, sid)
        return i < len(self._del_keys) and self._del_keys[i] == sid

    # ---- running deltas ----------------------------------------------------
    def _inserts_before(self, sid: int) -> int:
        """Total insert count with anchor < sid."""
        i = bisect.bisect_left(self._ins_keys, sid)
        return sum(self._ins_counts[k] for k in self._ins_keys[:i])

    def _inserts_at(self, sid: int) -> int:
        return self._ins_counts.get(sid, 0)

    def _deletes_before(self, sid: int) -> int:
        return bisect.bisect_left(self._del_keys, sid)

    @property
    def n_visible(self) -> int:
        total_ins = sum(self._ins_counts.values())
        return self.n_stable + total_ins - len(self._del_keys)

    # ---- SID/RID translation (paper Fig. 4) ---------------------------------
    def sid_to_rid_low(self, sid: int) -> int:
        """Lowest RID that maps to ``sid`` (blue arrows in paper Fig. 4)."""
        if not (0 <= sid <= self.n_stable):
            raise ValueError(f"sid {sid} out of range")
        return sid + self._inserts_before(sid) - self._deletes_before(sid)

    def sid_to_rid_high(self, sid: int) -> int:
        """Highest RID that maps to ``sid`` (red arrows in paper Fig. 4).

        For a deleted tuple with no inserts anchored at it this equals
        ``sid_to_rid_low`` — the lowest RID of a *higher* SID, per the paper.
        """
        low = self.sid_to_rid_low(sid)
        at = self._inserts_at(sid)
        if sid < self.n_stable and not self.is_deleted(sid):
            return low + at  # inserts first, then the stable tuple itself
        if at > 0:
            return low + at - 1
        return low

    def rid_to_sid(self, rid: int) -> int:
        """Translate a visible RID to its SID (anchor SID for inserts)."""
        if not (0 <= rid < self.n_visible):
            raise ValueError(f"rid {rid} out of range (n_visible={self.n_visible})")
        # Largest sid with sid_to_rid_low(sid) <= rid; low is monotone in sid.
        lo, hi = 0, self.n_stable
        while lo < hi:
            mid = (lo + hi + 1) // 2
            if self.sid_to_rid_low(mid) <= rid:
                lo = mid
            else:
                hi = mid - 1
        return lo

    # ---- stacking (snapshot isolation, paper §2.1) ---------------------------
    def stacked_on(self) -> "PDT":
        """A fresh private PDT layered on this one's *visible* stream.

        Vectorwise stacks differences-on-differences: the topmost, smallest
        PDT is private to a snapshot.  The child treats this PDT's visible
        stream as its stable storage.
        """
        return PDT(self.n_visible)


@dataclass
class CScanMergeState:
    """Tracks RID ranges already produced by an out-of-order CScan.

    ABM delivers chunks (SID ranges) out of order.  Each delivered SID range
    widens to [sid_to_rid_low(lo), sid_to_rid_high(hi-1)] and *may overlap*
    the RID range of an adjacent, already-delivered chunk; the overlap must
    be trimmed so no tuple is produced twice (paper §2.1).
    """

    produced: List[Tuple[int, int]] = field(default_factory=list)  # sorted, disjoint

    def deliver_chunk(self, pdt: PDT, sid_lo: int, sid_hi: int) -> List[Tuple[int, int]]:
        """Return the trimmed, novel RID sub-ranges for chunk [sid_lo, sid_hi)."""
        if sid_hi <= sid_lo:
            return []
        rid_lo = pdt.sid_to_rid_low(sid_lo)
        rid_hi = pdt.sid_to_rid_high(max(sid_lo, sid_hi - 1)) + 1  # half-open
        # a trailing deleted tuple "translates" past the visible stream:
        # clamp to it (the paper's widening is about overlap, not overrun)
        rid_hi = min(rid_hi, pdt.n_visible)
        rid_lo = min(rid_lo, rid_hi)
        novel = self._subtract(rid_lo, rid_hi)
        for r in novel:
            self._add(r)
        return novel

    def _subtract(self, lo: int, hi: int) -> List[Tuple[int, int]]:
        out = []
        cur = lo
        for plo, phi in self.produced:
            if phi <= cur:
                continue
            if plo >= hi:
                break
            if plo > cur:
                out.append((cur, min(plo, hi)))
            cur = max(cur, phi)
            if cur >= hi:
                break
        if cur < hi:
            out.append((cur, hi))
        return [r for r in out if r[1] > r[0]]

    def _add(self, r: Tuple[int, int]) -> None:
        lo, hi = r
        i = bisect.bisect_left(self.produced, (lo, hi))
        self.produced.insert(i, (lo, hi))
        # coalesce
        merged: List[Tuple[int, int]] = []
        for a, b in self.produced:
            if merged and a <= merged[-1][1]:
                merged[-1] = (merged[-1][0], max(merged[-1][1], b))
            else:
                merged.append((a, b))
        self.produced = merged

    @property
    def produced_count(self) -> int:
        return sum(b - a for a, b in self.produced)
