"""Predictive Buffer Management — faithful implementation of paper §3 + Fig. 9.

PBM approximates Belady's OPT by *estimating the time of next consumption* of
every page from the disclosed page sets and observed positions/speeds of the
registered scans:

    PageNextConsumption(page) =
        min over (scan, tuples_behind) registered on the page of
            (tuples_behind - scan.tuples_consumed) / scan.speed

Pages are kept in a **bucketed timeline** rather than an exact priority queue
(the paper found a binary heap too expensive under concurrency):

* ``n_groups`` groups of ``m`` buckets; every bucket in group ``g`` spans
  ``2**g`` time slices, so ``n*m`` buckets cover an exponentially long
  horizon with O(1) ``TimeToBucketNumber``.
* A trailing **not-requested** bucket holds resident pages no active scan
  wants; it is kept in LRU order (paper's PBM/LRU hybrid for that bucket).
* Every ``time_slice`` the timeline shifts left one slice
  (``RefreshRequestedBuckets``): a bucket moves when ``slices_done`` is
  divisible by its length; a bucket shifted past position 0 is *spilled* —
  its pages get their priority recalculated and re-pushed (this is how
  stale speed estimates self-correct).
* Eviction pops from the not-requested bucket first, then from the
  highest-numbered (furthest-future) bucket — the Belady rule under
  estimation.

Deviations from the paper, recorded: (i) bucket collisions during shifting
are merged (the paper's pseudocode is ambiguous there; merging only blurs
priorities within one group transition, exactly the imprecision the bucket
design already accepts); (ii) eviction batching (>=16 pages) lives in the
engine so every policy is amortised identically.
"""

from __future__ import annotations

import math
from collections import OrderedDict
from typing import Dict, List, Optional, Set, TYPE_CHECKING

from ..pages import Page, PageId
from .base import Policy

if TYPE_CHECKING:  # pragma: no cover
    from ..scans import ScanState

NOT_REQUESTED = -2
UNBUCKETED = -1


class _PageMeta:
    __slots__ = ("page", "consuming_scans", "bucket")

    def __init__(self, page: Page):
        self.page = page
        # scan_id -> tuples_behind (virtual tuples before consumption starts)
        self.consuming_scans: Dict[int, int] = {}
        self.bucket: int = UNBUCKETED


class PBMPolicy(Policy):
    name = "pbm"

    def __init__(
        self,
        time_slice: float = 0.1,   # paper example: 100 ms
        n_groups: int = 10,
        buckets_per_group: int = 4,
    ) -> None:
        super().__init__()
        self.time_slice = float(time_slice)
        self.n_groups = int(n_groups)
        self.m = int(buckets_per_group)
        self.nb = self.n_groups * self.m
        # requested buckets: index 0 = imminent, nb-1 = furthest future
        self.buckets: List["OrderedDict[PageId, Page]"] = [
            OrderedDict() for _ in range(self.nb)
        ]
        self.not_requested: "OrderedDict[PageId, Page]" = OrderedDict()  # LRU order
        self._meta: Dict[PageId, _PageMeta] = {}
        self._scans: Dict[int, "ScanState"] = {}
        self._scan_pages: Dict[int, List[Page]] = {}
        self._slices_done = 0      # slices since attach
        self._epoch = 0.0

    # ------------------------------------------------------------------ util
    def attach(self, pool, now: float = 0.0) -> None:  # noqa: D401
        super().attach(pool, now)
        self._epoch = now

    def _m(self, page: Page) -> _PageMeta:
        meta = self._meta.get(page.pid)
        if meta is None:
            meta = self._meta[page.pid] = _PageMeta(page)
        return meta

    def _bucket_len_slices(self, i: int) -> int:
        return 1 << (i // self.m)

    def time_to_bucket(self, dt: float) -> int:
        """O(1) TimeToBucketNumber (paper Fig. 10 geometry)."""
        if dt <= 0:
            return 0
        s = dt / self.time_slice
        # group g covers slice offsets [m*(2^g - 1), m*(2^(g+1) - 1))
        g = int(math.log2(s / self.m + 1.0))
        if g >= self.n_groups:
            return self.nb - 1
        start = self.m * ((1 << g) - 1)
        idx = int((s - start) / (1 << g))
        return min(self.nb - 1, g * self.m + idx)

    # --------------------------------------------------- Fig. 9 core functions
    def page_next_consumption(self, page: Page, now: float) -> Optional[float]:
        meta = self._meta.get(page.pid)
        if meta is None or not meta.consuming_scans:
            return None
        nearest: Optional[float] = None
        for sid, tuples_behind in meta.consuming_scans.items():
            scan = self._scans.get(sid)
            if scan is None:
                continue
            speed = max(scan.speed, 1e-6)
            nxt = (tuples_behind - scan.virt_pos) / speed
            if nxt < 0:
                nxt = 0.0
            if nearest is None or nxt < nearest:
                nearest = nxt
        return nearest

    def _bucket_remove(self, meta: _PageMeta) -> None:
        if meta.bucket == NOT_REQUESTED:
            self.not_requested.pop(meta.page.pid, None)
        elif meta.bucket >= 0:
            self.buckets[meta.bucket].pop(meta.page.pid, None)
        meta.bucket = UNBUCKETED

    def page_push(self, page: Page, now: float) -> None:
        """Recalculate a resident page's priority and (re)bucket it."""
        assert self.pool is not None
        meta = self._m(page)
        self._bucket_remove(meta)
        if not self.pool.is_resident(page):
            return
        nxt = self.page_next_consumption(page, now)
        if nxt is None:
            self.not_requested[page.pid] = page   # MRU end
            meta.bucket = NOT_REQUESTED
        else:
            b = self.time_to_bucket(nxt)
            self.buckets[b][page.pid] = page
            meta.bucket = b

    def refresh_requested_buckets(self, now: float) -> None:
        """Shift the timeline left; recalc pages spilled past position 0."""
        target = int((now - self._epoch) / self.time_slice)
        if target <= self._slices_done:
            return
        steps = target - self._slices_done
        if steps > 2 * self.nb * (1 << (self.n_groups - 1)):
            # long idle period: rebuild instead of stepping
            self._slices_done = target
            for b in list(self.buckets):
                for page in list(b.values()):
                    self.page_push(page, now)
            return
        for _ in range(steps):
            self._slices_done += 1
            spill: List[Page] = []
            new: List[Optional["OrderedDict[PageId, Page]"]] = [None] * self.nb
            for i in range(self.nb):
                moved = (self._slices_done % self._bucket_len_slices(i)) == 0
                dest = i - 1 if moved else i
                if dest < 0:
                    spill.extend(self.buckets[i].values())
                    continue
                if new[dest] is None:
                    new[dest] = self.buckets[i]
                else:
                    new[dest].update(self.buckets[i])  # merge on collision
            self.buckets = [b if b is not None else OrderedDict() for b in new]
            # fix meta.bucket for everything that moved
            for i, b in enumerate(self.buckets):
                for pid in b:
                    self._meta[pid].bucket = i
            for page in spill:
                self._meta[page.pid].bucket = UNBUCKETED
                self.page_push(page, now)

    # ------------------------------------------------------- policy interface
    def register_scan(self, scan: "ScanState", now: float) -> None:
        self._scans[scan.scan_id] = scan
        pages: List[Page] = []
        for trigger, page in scan.plan:
            meta = self._m(page)
            meta.consuming_scans[scan.scan_id] = trigger
            pages.append(page)
            if self.pool is not None and self.pool.is_resident(page):
                self.page_push(page, now)
        self._scan_pages[scan.scan_id] = pages

    def unregister_scan(self, scan: "ScanState", now: float) -> None:
        for page in self._scan_pages.pop(scan.scan_id, []):
            meta = self._meta.get(page.pid)
            if meta is None:
                continue
            if meta.consuming_scans.pop(scan.scan_id, None) is not None:
                if self.pool is not None and self.pool.is_resident(page):
                    self.page_push(page, now)
        self._scans.pop(scan.scan_id, None)

    def report_position(self, scan: "ScanState", now: float) -> None:
        # speed EWMA is maintained on the ScanState; the timeline self-corrects
        # through bucket refresh + spill recalculation.
        self.refresh_requested_buckets(now)

    def on_loaded(self, page: Page, now: float) -> None:
        self.refresh_requested_buckets(now)
        self.page_push(page, now)

    def on_consumed(self, scan: "ScanState", page: Page, now: float) -> None:
        meta = self._meta.get(page.pid)
        if meta is not None:
            meta.consuming_scans.pop(scan.scan_id, None)
        self.page_push(page, now)

    def choose_victims(
        self, bytes_needed: int, protected: Set[PageId], now: float
    ) -> List[Page]:
        assert self.pool is not None
        self.refresh_requested_buckets(now)
        victims: List[Page] = []
        freed = self.pool.free_bytes

        def try_take(bucket: "OrderedDict[PageId, Page]") -> None:
            nonlocal freed
            for pid in list(bucket.keys()):
                if freed >= bytes_needed:
                    return
                page = bucket[pid]
                if pid in protected or self.pool.is_pinned(page):
                    continue
                bucket.pop(pid)
                self._meta[pid].bucket = UNBUCKETED
                victims.append(page)
                freed += page.size_bytes

        try_take(self.not_requested)              # LRU order (front = oldest)
        i = self.nb - 1
        while freed < bytes_needed and i >= 0:    # furthest future first
            try_take(self.buckets[i])
            i -= 1
        return victims
