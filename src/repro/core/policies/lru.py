"""LRU and MRU baselines — the "naive buffer management" of the paper.

LRU is the traditional default the paper benchmarks against; MRU is included
because classic DBMS buffer work (Chou & DeWitt) preferred MRU for looping
sequential scans — our benchmarks let you check that folklore against PBM.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import List, Set, TYPE_CHECKING

from ..pages import Page, PageId
from .base import Policy

if TYPE_CHECKING:  # pragma: no cover
    from ..scans import ScanState


class LRUPolicy(Policy):
    name = "lru"

    def __init__(self) -> None:
        super().__init__()
        # OrderedDict as recency list: least-recently-used at the front.
        self._recency: "OrderedDict[PageId, Page]" = OrderedDict()

    def _touch(self, page: Page) -> None:
        self._recency.pop(page.pid, None)
        self._recency[page.pid] = page

    def on_loaded(self, page: Page, now: float) -> None:
        self._touch(page)

    def on_consumed(self, scan: "ScanState", page: Page, now: float) -> None:
        self._touch(page)

    def choose_victims(
        self, bytes_needed: int, protected: Set[PageId], now: float
    ) -> List[Page]:
        assert self.pool is not None
        victims: List[Page] = []
        freed = self.pool.free_bytes
        for pid in list(self._recency.keys()):
            if freed >= bytes_needed:
                break
            page = self.pool.resident.get(pid)
            if page is None:
                self._recency.pop(pid, None)  # stale entry
                continue
            if pid in protected or self.pool.is_pinned(page):
                continue
            victims.append(page)
            freed += page.size_bytes
        for v in victims:
            self._recency.pop(v.pid, None)
        return victims


class MRUPolicy(LRUPolicy):
    name = "mru"

    def choose_victims(
        self, bytes_needed: int, protected: Set[PageId], now: float
    ) -> List[Page]:
        assert self.pool is not None
        victims: List[Page] = []
        freed = self.pool.free_bytes
        for pid in reversed(list(self._recency.keys())):
            if freed >= bytes_needed:
                break
            page = self.pool.resident.get(pid)
            if page is None:
                self._recency.pop(pid, None)
                continue
            if pid in protected or self.pool.is_pinned(page):
                continue
            victims.append(page)
            freed += page.size_bytes
        for v in victims:
            self._recency.pop(v.pid, None)
        return victims
