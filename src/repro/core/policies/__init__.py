from .base import BufferPool, Policy
from .lru import LRUPolicy, MRUPolicy
from .pbm import PBMPolicy
from .opt import OraclePolicy, simulate_belady
from .cscan import ABM
from .pbm_lru import PBMLRUPolicy
from .attach_throttle import AttachThrottlePBM

__all__ = [
    "ABM", "AttachThrottlePBM", "BufferPool", "LRUPolicy", "MRUPolicy",
    "OraclePolicy", "PBMLRUPolicy", "PBMPolicy", "Policy", "simulate_belady",
]
