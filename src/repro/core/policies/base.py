"""Buffer pool and the policy interface shared by LRU/PBM/OPT (+ serving tier).

In-order policies (LRU, MRU, PBM, OPT oracle) plug into the engine through
this interface: the *engine* decides the request order (physical scan order +
prefetch); the *policy* decides eviction and maintains whatever metadata it
needs via the notification hooks.  Cooperative Scans instead take over the
loading decisions themselves (``cscan.py``), mirroring the paper's
architectural distinction between Fig. 1/3 (Scan + buffer manager) and
Fig. 2 (ABM).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Set, TYPE_CHECKING

from ..pages import Page, PageId

if TYPE_CHECKING:  # pragma: no cover
    from ..scans import ScanState


class BufferPool:
    """Fixed-capacity page pool; residency + pin accounting."""

    def __init__(self, capacity_bytes: int):
        self.capacity_bytes = int(capacity_bytes)
        self.used_bytes = 0
        self.resident: Dict[PageId, Page] = {}
        self.pinned: Dict[PageId, int] = {}
        self.total_loaded_bytes = 0   # lifetime I/O volume (the paper metric)
        self.total_loads = 0
        self.total_hits = 0
        self.total_evictions = 0

    def is_resident(self, page: Page) -> bool:
        return page.pid in self.resident

    def has_space(self, nbytes: int) -> bool:
        return self.used_bytes + nbytes <= self.capacity_bytes

    def admit(self, page: Page) -> None:
        if page.pid in self.resident:
            return
        if not self.has_space(page.size_bytes):
            raise RuntimeError(
                f"admit without space: {page.pid} needs {page.size_bytes}, "
                f"free={self.capacity_bytes - self.used_bytes}"
            )
        self.resident[page.pid] = page
        self.used_bytes += page.size_bytes
        self.total_loaded_bytes += page.size_bytes
        self.total_loads += 1

    def evict(self, page: Page) -> None:
        if self.pinned.get(page.pid, 0) > 0:
            raise RuntimeError(f"evicting pinned page {page.pid}")
        p = self.resident.pop(page.pid, None)
        if p is not None:
            self.used_bytes -= p.size_bytes
            self.total_evictions += 1

    def pin(self, page: Page) -> None:
        self.pinned[page.pid] = self.pinned.get(page.pid, 0) + 1

    def unpin(self, page: Page) -> None:
        n = self.pinned.get(page.pid, 0) - 1
        if n <= 0:
            self.pinned.pop(page.pid, None)
        else:
            self.pinned[page.pid] = n

    def is_pinned(self, page: Page) -> bool:
        return self.pinned.get(page.pid, 0) > 0

    @property
    def free_bytes(self) -> int:
        return self.capacity_bytes - self.used_bytes


class Policy:
    """Eviction-policy interface for in-order scans."""

    name = "base"

    def __init__(self) -> None:
        self.pool: Optional[BufferPool] = None

    def attach(self, pool: BufferPool, now: float = 0.0) -> None:
        self.pool = pool

    # -- scan lifecycle (PBM Fig. 3: Register/Report/Unregister) -------------
    def register_scan(self, scan: "ScanState", now: float) -> None:  # noqa: D401
        pass

    def unregister_scan(self, scan: "ScanState", now: float) -> None:
        pass

    def report_position(self, scan: "ScanState", now: float) -> None:
        pass

    # -- page lifecycle -------------------------------------------------------
    def on_loaded(self, page: Page, now: float) -> None:
        pass

    def on_consumed(self, scan: "ScanState", page: Page, now: float) -> None:
        pass

    # -- the actual decision --------------------------------------------------
    def choose_victims(
        self, bytes_needed: int, protected: Set[PageId], now: float
    ) -> List[Page]:
        """Pick resident pages to evict so ``bytes_needed`` fits.

        Must return pages summing to >= bytes_needed - pool.free_bytes (or as
        many as it can); engine raises if the policy cannot make room.
        """
        raise NotImplementedError
