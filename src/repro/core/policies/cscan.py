"""Cooperative Scans: the Active Buffer Manager (paper §2, after [Zukowski 07]).

ABM inverts the control flow of buffer management: loading decisions are
taken *globally* by ABM, not by individual scans.  CScan operators register
their data interest up front, then repeatedly ask ABM for *any* chunk of
their range that is ready (out-of-order, chunk-at-a-time delivery).  Four
relevance functions drive the scheduling (paper §2):

* ``QueryRelevance``  — which CScan to serve next: prioritise *starved*
  queries (no available cached chunk) and *short* queries.
* ``LoadRelevance``   — which chunk to load for it: favour chunks that many
  other CScans are interested in (maximise buffer reuse); shared chunks
  (snapshot common prefix, §2.1) get a bonus over local chunks.
* ``UseRelevance``    — which cached chunk the CScan should consume next:
  chunks *fewest* CScans are interested in, so they become evictable early.
* ``KeepRelevance``   — which chunk to evict: fewest interested CScans; a
  chunk is only evicted if it scores *lower* than the LoadRelevance of the
  chunk that wants its space.

Decisions are chunk-granular: a chunk is a logical tuple range that maps to
a different page set per column (``Table.chunk_pages``).  A chunk is
*available* to a CScan when all pages of the CScan's columns are resident.

A CScan may demand in-order delivery (``spec.in_order_required``) and then
degrades to a drop-in Scan replacement (paper §2.3).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple, TYPE_CHECKING

from ..pages import Database, Page, PageId, Table
from .base import BufferPool

if TYPE_CHECKING:  # pragma: no cover
    from ..scans import ScanState

ChunkKey = Tuple[str, int]  # (table, chunk_id)


@dataclass
class LoadDecision:
    chunk: ChunkKey
    pages: List[Page]          # non-resident pages to fetch
    bytes: int
    evict: List[Page]          # pages to drop first (whole victim chunks)


class ABM:
    """Active Buffer Manager: global chunk scheduling for CScan operators."""

    name = "cscan"

    def __init__(
        self,
        db: Database,
        pool: BufferPool,
        shared_chunks: Optional[Set[ChunkKey]] = None,
        starved_bonus: float = 1e9,
        shared_bonus: float = 0.5,
    ) -> None:
        self.db = db
        self.pool = pool
        self.shared_chunks = shared_chunks or set()
        self.starved_bonus = starved_bonus
        self.shared_bonus = shared_bonus
        # chunk -> scans that still need it (not yet consumed by them)
        self.interest: Dict[ChunkKey, Set[int]] = {}
        self._scans: Dict[int, "ScanState"] = {}
        # page -> owning chunk (by first_tuple); chunk -> pages per column
        self._page_chunk: Dict[PageId, ChunkKey] = {}
        self._chunk_pages: Dict[ChunkKey, Dict[str, List[Page]]] = {}
        self.in_flight: Set[ChunkKey] = set()
        self.pinned_chunks: Dict[ChunkKey, int] = {}

    # ------------------------------------------------------------- plumbing
    def _ensure_chunk_meta(self, table: Table, chunk_id: int) -> ChunkKey:
        key = (table.name, chunk_id)
        if key in self._chunk_pages:
            return key
        per_col: Dict[str, List[Page]] = {}
        lo, hi = table.chunk_range(chunk_id)
        for cname, col in table.columns.items():
            pages = [
                p
                for p in col.pages_for_range(lo, hi)
                if lo <= p.first_tuple < hi  # unique chunk ownership
            ]
            per_col[cname] = pages
            for p in pages:
                self._page_chunk[p.pid] = key
        self._chunk_pages[key] = per_col
        return key

    def chunk_pages_for_columns(
        self, key: ChunkKey, columns: Sequence[str]
    ) -> List[Page]:
        per_col = self._chunk_pages[key]
        out: List[Page] = []
        for c in columns:
            out.extend(per_col.get(c, []))
        return out

    def _interested_scans(self, key: ChunkKey) -> List["ScanState"]:
        return [
            self._scans[sid]
            for sid in self.interest.get(key, ())
            if sid in self._scans
        ]

    def _union_columns(self, key: ChunkKey) -> List[str]:
        cols: List[str] = []
        seen = set()
        for s in self._interested_scans(key):
            for c in s.spec.columns:
                if c not in seen:
                    seen.add(c)
                    cols.append(c)
        return cols

    def available_for(self, scan: "ScanState", chunk_id: int) -> bool:
        key = (scan.table.name, chunk_id)
        return all(self.pool.is_resident(p)
                   for p in self.chunk_pages_for_columns(key, scan.spec.columns))

    # ---------------------------------------------------------- registration
    def register(self, scan: "ScanState", now: float) -> None:
        self._scans[scan.scan_id] = scan
        for cid in scan.chunks_remaining:
            key = self._ensure_chunk_meta(scan.table, cid)
            self.interest.setdefault(key, set()).add(scan.scan_id)

    def unregister(self, scan: "ScanState", now: float) -> None:
        for cid in set(scan.chunks):
            key = (scan.table.name, cid)
            s = self.interest.get(key)
            if s is not None:
                s.discard(scan.scan_id)
        self._scans.pop(scan.scan_id, None)

    # ---------------------------------------------- relevance functions (§2)
    def query_relevance(self, scan: "ScanState", starved: bool) -> float:
        rel = -float(len(scan.chunks_remaining))       # short queries first
        if starved:
            rel += self.starved_bonus                  # starved queries first
        return rel

    def load_relevance(self, key: ChunkKey) -> float:
        rel = float(len(self.interest.get(key, ())))
        if key in self.shared_chunks:
            rel += self.shared_bonus                   # shared chunks early
        return rel

    def use_relevance(self, key: ChunkKey, scan: "ScanState") -> float:
        others = len(self.interest.get(key, ())) - 1
        return -float(others)                          # rare chunks first

    def keep_relevance(self, key: ChunkKey) -> float:
        rel = float(len(self.interest.get(key, ())))
        if key in self.shared_chunks:
            rel += self.shared_bonus
        return rel

    # --------------------------------------------------------- GetChunk path
    def get_chunk(self, scan: "ScanState", now: float) -> Optional[int]:
        """Pick the cached chunk the CScan should consume next (UseRelevance)."""
        if scan.spec.in_order_required:
            if not scan.chunks_remaining:
                return None
            nxt = min(scan.chunks_remaining)
            return nxt if self.available_for(scan, nxt) else None
        best: Optional[int] = None
        best_rel = -float("inf")
        for cid in scan.chunks_remaining:
            if not self.available_for(scan, cid):
                continue
            rel = self.use_relevance((scan.table.name, cid), scan)
            if rel > best_rel or (rel == best_rel and (best is None or cid < best)):
                best, best_rel = cid, rel
        return best

    def pin_chunk(self, scan: "ScanState", chunk_id: int) -> None:
        key = (scan.table.name, chunk_id)
        self.pinned_chunks[key] = self.pinned_chunks.get(key, 0) + 1
        for p in self.chunk_pages_for_columns(key, scan.spec.columns):
            self.pool.pin(p)

    def consume_chunk(self, scan: "ScanState", chunk_id: int, now: float) -> None:
        key = (scan.table.name, chunk_id)
        n = self.pinned_chunks.get(key, 0) - 1
        if n <= 0:
            self.pinned_chunks.pop(key, None)
        else:
            self.pinned_chunks[key] = n
        for p in self.chunk_pages_for_columns(key, scan.spec.columns):
            self.pool.unpin(p)
        scan.chunks_remaining.discard(chunk_id)
        s = self.interest.get(key)
        if s is not None:
            s.discard(scan.scan_id)

    # --------------------------------------------------------- loading path
    def _load_candidates(self, scan: "ScanState") -> List[ChunkKey]:
        if scan.spec.in_order_required:
            pend = [
                cid
                for cid in sorted(scan.chunks_remaining)
                if (scan.table.name, cid) not in self.in_flight
                and not self.available_for(scan, cid)
            ]
            return [(scan.table.name, pend[0])] if pend else []
        return [
            (scan.table.name, cid)
            for cid in scan.chunks_remaining
            if (scan.table.name, cid) not in self.in_flight
            and not self.available_for(scan, cid)
        ]

    def next_load(
        self, now: float, starved: Set[int], max_queries: int = 8
    ) -> Optional[LoadDecision]:
        """ABM main-loop decision: (query, chunk) to load next, with evictions."""
        cands = [
            (self.query_relevance(s, s.scan_id in starved), -s.scan_id, s)
            for s in self._scans.values()
            if s.chunks_remaining
        ]
        cands.sort(key=lambda t: (-t[0], t[1]))
        for _, _, scan in cands[:max_queries]:
            chunk_keys = self._load_candidates(scan)
            if not chunk_keys:
                continue
            chunk_keys.sort(
                key=lambda k: (-self.load_relevance(k), k[1])
            )
            key = chunk_keys[0]
            pages = [
                p
                for p in self.chunk_pages_for_columns(key, self._union_columns(key))
                if not self.pool.is_resident(p)
            ]
            if not pages:  # resident for union already (race) -> nothing to do
                continue
            need = sum(p.size_bytes for p in pages)
            evict = self._plan_eviction(key, need)
            if evict is None:
                continue  # cannot make room for this chunk; try next query
            return LoadDecision(chunk=key, pages=pages, bytes=need, evict=evict)
        return None

    def _plan_eviction(self, for_chunk: ChunkKey, need: int) -> Optional[List[Page]]:
        free = self.pool.free_bytes
        if free >= need:
            return []
        load_rel = self.load_relevance(for_chunk)
        # victim chunks: resident, unpinned, not in flight, lower relevance
        victims: List[Tuple[float, ChunkKey, List[Page], int]] = []
        for key, per_col in self._chunk_pages.items():
            if key == for_chunk or key in self.in_flight:
                continue
            if self.pinned_chunks.get(key, 0) > 0:
                continue
            resident = [
                p
                for pages in per_col.values()
                for p in pages
                if self.pool.is_resident(p) and not self.pool.is_pinned(p)
            ]
            if not resident:
                continue
            keep = self.keep_relevance(key)
            if keep >= load_rel:
                continue  # paper rule: only evict if Keep < Load
            victims.append((keep, key, resident, sum(p.size_bytes for p in resident)))
        victims.sort(key=lambda t: (t[0], t[1]))
        out: List[Page] = []
        for _keep, _key, pages, nbytes in victims:
            if free >= need:
                break
            out.extend(pages)
            free += nbytes
        return out if free >= need else None
