"""OPT / Belady: the perfect-oracle bound (paper §3, §4 "OPT simulator").

Two artefacts, matching how the paper uses OPT:

* :class:`OraclePolicy` — an engine policy that evicts the resident page
  whose *exact* next consumption is furthest in the future.  Because
  in-order scans are deterministic, the distance of every registered scan to
  every page is exactly known — this is OPT restricted to the knowledge the
  paper grants it (registered queries only, no future queries), i.e. PBM
  with a perfect speed/position oracle.  Order of requests is preserved, so
  like the paper's OPT it bounds *order-preserving* policies and can lose to
  CScans (paper's "food for thought" footnote).

* :func:`simulate_belady` — the classic trace-driven Belady simulator: given
  a reference string (e.g. captured from a PBM engine run, exactly as the
  paper does) and a capacity, replay optimal eviction and report miss volume.
  Used for the paper's I/O-volume numbers and for optimality property tests.
"""

from __future__ import annotations

import heapq
from collections import OrderedDict
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple, TYPE_CHECKING

from ..pages import Page, PageId
from .base import Policy

if TYPE_CHECKING:  # pragma: no cover
    from ..scans import ScanState


class OraclePolicy(Policy):
    """Belady eviction with exact next-consumption distances (time units)."""

    name = "opt"

    def __init__(self) -> None:
        super().__init__()
        self._page_scans: Dict[PageId, Dict[int, int]] = {}  # pid -> {scan: trigger}
        self._scans: Dict[int, "ScanState"] = {}
        self._lru: "OrderedDict[PageId, Page]" = OrderedDict()  # unreferenced pages

    def register_scan(self, scan: "ScanState", now: float) -> None:
        self._scans[scan.scan_id] = scan
        for trigger, page in scan.plan:
            self._page_scans.setdefault(page.pid, {})[scan.scan_id] = trigger

    def unregister_scan(self, scan: "ScanState", now: float) -> None:
        self._scans.pop(scan.scan_id, None)
        for _, page in scan.plan:
            d = self._page_scans.get(page.pid)
            if d is not None:
                d.pop(scan.scan_id, None)

    def on_loaded(self, page: Page, now: float) -> None:
        self._lru.pop(page.pid, None)
        self._lru[page.pid] = page

    def on_consumed(self, scan: "ScanState", page: Page, now: float) -> None:
        d = self._page_scans.get(page.pid)
        if d is not None:
            d.pop(scan.scan_id, None)
        self._lru.pop(page.pid, None)
        self._lru[page.pid] = page

    def _next_use(self, pid: PageId) -> Optional[float]:
        """Exact seconds until next consumption; None if unreferenced."""
        d = self._page_scans.get(pid)
        if not d:
            return None
        best: Optional[float] = None
        for sid, trigger in d.items():
            scan = self._scans.get(sid)
            if scan is None:
                continue
            dist = max(0, trigger - scan.virt_pos)
            t = dist / max(scan.spec.tuple_rate, 1e-9)
            if best is None or t < best:
                best = t
        return best

    def choose_victims(
        self, bytes_needed: int, protected: Set[PageId], now: float
    ) -> List[Page]:
        assert self.pool is not None
        victims: List[Page] = []
        freed = self.pool.free_bytes
        # 1. unreferenced pages in LRU order
        for pid in list(self._lru.keys()):
            if freed >= bytes_needed:
                break
            page = self.pool.resident.get(pid)
            if page is None:
                self._lru.pop(pid, None)
                continue
            if self._next_use(pid) is not None:
                self._lru.pop(pid, None)  # referenced again: not in LRU set
                continue
            if pid in protected or self.pool.is_pinned(page):
                continue
            victims.append(page)
            self._lru.pop(pid, None)
            freed += page.size_bytes
        if freed >= bytes_needed:
            return victims
        # 2. Belady: furthest exact next use first
        scored: List[Tuple[float, PageId, Page]] = []
        chosen = {v.pid for v in victims}
        for pid, page in self.pool.resident.items():
            if pid in protected or pid in chosen or self.pool.is_pinned(page):
                continue
            nxt = self._next_use(pid)
            scored.append((nxt if nxt is not None else float("inf"), pid, page))
        scored.sort(key=lambda t: (-t[0], repr(t[1])))
        for _, _pid, page in scored:
            if freed >= bytes_needed:
                break
            victims.append(page)
            freed += page.size_bytes
        return victims


def simulate_belady(
    trace: Sequence[PageId],
    capacity_pages: Optional[int] = None,
    page_sizes: Optional[Dict[PageId, int]] = None,
    capacity_bytes: Optional[int] = None,
) -> Tuple[int, int]:
    """Replay Belady's MIN on a reference trace.

    Returns ``(misses, missed_bytes)``.  With ``capacity_pages`` all pages
    count 1; with ``capacity_bytes`` + ``page_sizes`` eviction frees bytes.
    """
    if (capacity_pages is None) == (capacity_bytes is None):
        raise ValueError("give exactly one of capacity_pages / capacity_bytes")
    sizes = page_sizes or {}

    # next-use index lists per page (ascending); consumed from the front
    next_use: Dict[PageId, List[int]] = {}
    for i, pid in enumerate(trace):
        next_use.setdefault(pid, []).append(i)
    cursor: Dict[PageId, int] = {pid: 0 for pid in next_use}

    resident: Set[PageId] = set()
    used = 0
    cap = capacity_pages if capacity_pages is not None else capacity_bytes
    misses = 0
    missed_bytes = 0
    # lazy max-heap of (-next_use_index, key, pid); stale entries skipped
    heap: List[Tuple[int, str, PageId]] = []

    def size_of(pid: PageId) -> int:
        return 1 if capacity_pages is not None else sizes.get(pid, 1)

    def nxt_idx(pid: PageId, after: int) -> int:
        lst = next_use[pid]
        c = cursor[pid]
        while c < len(lst) and lst[c] <= after:
            c += 1
        cursor[pid] = c
        return lst[c] if c < len(lst) else 1 << 60

    for i, pid in enumerate(trace):
        sz = size_of(pid)
        if pid in resident:
            pass
        else:
            misses += 1
            missed_bytes += sz if capacity_bytes is not None else sizes.get(pid, 0)
            while used + sz > cap and resident:
                while heap:
                    negidx, _, vic = heapq.heappop(heap)
                    if vic in resident and -negidx == nxt_idx(vic, i):
                        break
                else:
                    vic = next(iter(resident))
                resident.discard(vic)
                used -= size_of(vic)
            if used + sz <= cap:
                resident.add(pid)
                used += sz
        if pid in resident:
            heapq.heappush(heap, (-nxt_idx(pid, i), repr(pid), pid))
    return misses, missed_bytes
