"""PBM Attach & Throttle — the paper's §5 improvement direction, built.

PBM's one weak spot (paper Fig. 11) is extreme memory pressure with high
sharing potential: in-order scans scattered across the table cannot reuse
each other's pages.  The paper sketches the remedy: bring circular-scan
*attach* semantics and DB2-style *throttling* into PBM —

* **Attach**: a starting scan whose range overlaps an already-running scan
  is ordered to start near that scan's current position (we rotate its page
  request order: [pos, end) then [start, pos)), so the pair shares every
  page load from then on.  Order within a query no longer matters to PBM's
  estimates — both sub-ranges are registered with correct triggers.
* **Throttle**: PBM tracks ``next_consumption_evict`` — the estimated
  next-consumption time of recently evicted pages.  A scan whose freshly
  consumed pages would be re-consumed (by a trailing scan) *just after* that
  horizon is slowed down, letting the trailing scan catch up so the pages
  are reused before eviction.  We expose the throttle factor to the engine
  via ``throttle_factor(scan)``; the engine multiplies the scan's CPU rate.

This is a beyond-paper deliverable (the paper only outlines it); the
mechanism doubles as the serving-side straggler/group scheduler.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Set, TYPE_CHECKING

from ..pages import Page, PageId
from .pbm import PBMPolicy

if TYPE_CHECKING:  # pragma: no cover
    from ..scans import ScanState


class AttachThrottlePBM(PBMPolicy):
    name = "attach"

    def __init__(
        self,
        *args,
        attach: bool = True,
        throttle: bool = True,
        throttle_slowdown: float = 0.5,
        evict_horizon_ewma: float = 0.2,
        **kwargs,
    ) -> None:
        super().__init__(*args, **kwargs)
        self.attach_enabled = attach
        self.throttle_enabled = throttle
        self.throttle_slowdown = throttle_slowdown
        self._h_alpha = evict_horizon_ewma
        self.next_consumption_evict: Optional[float] = None  # EWMA horizon
        self._throttled: Set[int] = set()

    # ------------------------------------------------------------- attach --
    def register_scan(self, scan: "ScanState", now: float) -> None:
        if self.attach_enabled and not scan.spec.in_order_required:
            peer = self._best_peer(scan)
            if peer is not None:
                self._rotate_plan(scan, peer.virt_pos)
        super().register_scan(scan, now)

    def _best_peer(self, scan: "ScanState") -> Optional["ScanState"]:
        """Running scan on the same table with maximal overlapping remainder."""
        best, best_overlap = None, 0
        mine = {p.pid for p in scan.unique_pages}
        for other in self._scans.values():
            if other.spec.table != scan.spec.table or other.done:
                continue
            rest = {p.pid for _, p in other.plan[other.plan_idx:]}
            ov = len(mine & rest)
            if ov > best_overlap:
                best, best_overlap = other, ov
        # only attach when a useful fraction of the scan is shared
        if best is not None and best_overlap >= max(8, len(mine) // 8):
            return best
        return None

    def _rotate_plan(self, scan: "ScanState", peer_virt: int) -> None:
        """Rotate the access plan to start at the peer's position.

        Both halves keep correct trigger/end offsets in the *rotated* virtual
        timeline so PBM's tuples_behind bookkeeping stays exact.
        """
        plan = scan.plan_full
        if not plan:
            return
        total = scan.total_tuples
        # find split: first entry with trigger >= peer position (clamped)
        split_virt = min(max(0, peer_virt), total - 1)
        k = 0
        while k < len(plan) and plan[k][0] < split_virt:
            k += 1
        if k == 0 or k >= len(plan):
            return
        head, tail = plan[:k], plan[k:]
        base = tail[0][0]
        rotated = [
            (t - base, e - base, p) for (t, e, p) in tail
        ] + [
            (t + (total - base), e + (total - base), p) for (t, e, p) in head
        ]
        scan.plan_full = rotated
        scan.plan = [(t, p) for t, _, p in rotated]
        scan.plan_idx = 0

    # ------------------------------------------------------------ throttle --
    def choose_victims(
        self, bytes_needed: int, protected: Set[PageId], now: float
    ) -> List[Page]:
        victims = super().choose_victims(bytes_needed, protected, now)
        if self.throttle_enabled:
            for v in victims:
                nxt = self.page_next_consumption(v, now)
                if nxt is None:
                    continue
                if self.next_consumption_evict is None:
                    self.next_consumption_evict = nxt
                else:
                    self.next_consumption_evict = (
                        self._h_alpha * nxt
                        + (1 - self._h_alpha) * self.next_consumption_evict
                    )
        return victims

    def throttle_factor(self, scan: "ScanState", now: float) -> float:
        """CPU-rate multiplier for ``scan`` (engine hook).

        Throttle when pages this scan just consumed will be needed by a
        trailing scan *later than* the eviction horizon: slowing this scan
        down pulls the trailing scan's next-consumption estimates below the
        horizon, so the shared pages survive until reuse.
        """
        if not self.throttle_enabled or self.next_consumption_evict is None:
            return 1.0
        horizon = self.next_consumption_evict
        nxt = scan.next_needed()
        if nxt is None:
            return 1.0
        # trailing scans on my recent pages
        for _, page in scan.plan[max(0, scan.plan_idx - 4): scan.plan_idx]:
            meta = self._meta.get(page.pid)
            if meta is None:
                continue
            for sid, trig in meta.consuming_scans.items():
                other = self._scans.get(sid)
                if other is None or sid == scan.scan_id:
                    continue
                eta = (trig - other.virt_pos) / max(other.speed, 1e-6)
                if 0 < eta and eta > horizon:
                    self._throttled.add(scan.scan_id)
                    return self.throttle_slowdown
        self._throttled.discard(scan.scan_id)
        return 1.0
