"""PBM/LRU with counter-rotating buckets — the paper's §3 future work, built.

Basic PBM treats every page without an active scan as strictly colder than
any requested page; frequently-reused small-table (dimension) pages get
evicted between the short queries that love them.  The paper sketches the
fix: **two** bucket timelines,

* the PBM buckets (registered scans), shifting *left* as time passes, and
* LRU buckets (no active scan), placed by a *history-based* estimate of next
  consumption and shifting *right* (aging),

with eviction taking the furthest-future bucket of either set, preferring
the LRU side at equal range.  The history estimate is the paper's own
suggestion: keep the timestamps of the last ``k`` uses and take the average
gap as the predicted re-reference distance.

This is a beyond-paper deliverable: the paper explicitly leaves it
unimplemented ("We leave implementation of this algorithm as future work").
"""

from __future__ import annotations

from collections import OrderedDict, deque
from typing import Deque, Dict, List, Optional, Set, TYPE_CHECKING

from ..pages import Page, PageId
from .pbm import PBMPolicy, NOT_REQUESTED, UNBUCKETED

if TYPE_CHECKING:  # pragma: no cover
    from ..scans import ScanState

_HISTORY = 4  # paper: "timestamps of the last four uses"


class PBMLRUPolicy(PBMPolicy):
    name = "pbm_lru"

    def __init__(self, *args, **kwargs) -> None:
        super().__init__(*args, **kwargs)
        # mirror timeline for unrequested pages (aging moves them right)
        self.lru_buckets: List["OrderedDict[PageId, Page]"] = [
            OrderedDict() for _ in range(self.nb)
        ]
        self._lru_pos: Dict[PageId, int] = {}
        self._history: Dict[PageId, Deque[float]] = {}
        self._lru_slices_done = 0

    # ---- history-based next-consumption estimate ---------------------------
    def _history_estimate(self, pid: PageId, now: float) -> Optional[float]:
        h = self._history.get(pid)
        if not h or len(h) < 2:
            return None
        gaps = [b - a for a, b in zip(h, list(h)[1:])]
        avg = sum(gaps) / len(gaps)
        since = now - h[-1]
        return max(0.0, avg - since)

    def _record_use(self, pid: PageId, now: float) -> None:
        h = self._history.setdefault(pid, deque(maxlen=_HISTORY))
        h.append(now)

    # ---- override the "not requested" path ---------------------------------
    def page_push(self, page: Page, now: float) -> None:
        assert self.pool is not None
        meta = self._m(page)
        # remove from LRU mirror if present
        pos = self._lru_pos.pop(page.pid, None)
        if pos is not None:
            self.lru_buckets[pos].pop(page.pid, None)
        self._bucket_remove(meta)
        if not self.pool.is_resident(page):
            return
        nxt = self.page_next_consumption(page, now)
        if nxt is not None:
            b = self.time_to_bucket(nxt)
            self.buckets[b][page.pid] = page
            meta.bucket = b
            return
        est = self._history_estimate(page.pid, now)
        if est is None:
            self.not_requested[page.pid] = page  # no history: plain LRU tail
            meta.bucket = NOT_REQUESTED
        else:
            b = self.time_to_bucket(est)
            self.lru_buckets[b][page.pid] = page
            self._lru_pos[page.pid] = b
            meta.bucket = UNBUCKETED  # tracked by the mirror instead

    def on_consumed(self, scan: "ScanState", page: Page, now: float) -> None:
        self._record_use(page.pid, now)
        super().on_consumed(scan, page, now)

    def refresh_requested_buckets(self, now: float) -> None:
        before = self._slices_done
        super().refresh_requested_buckets(now)
        steps = self._slices_done - before
        # counter-rotation: age the LRU mirror to the *right*
        for _ in range(steps):
            self._lru_slices_done += 1
            for i in range(self.nb - 1, -1, -1):
                if self._lru_slices_done % self._bucket_len_slices(i) != 0:
                    continue
                src = self.lru_buckets[i]
                if not src:
                    continue
                if i == self.nb - 1:
                    continue  # oldest stays (next eviction candidates)
                self.lru_buckets[i + 1].update(src)
                for pid in src:
                    self._lru_pos[pid] = i + 1
                self.lru_buckets[i] = OrderedDict()

    def choose_victims(
        self, bytes_needed: int, protected: Set[PageId], now: float
    ) -> List[Page]:
        assert self.pool is not None
        self.refresh_requested_buckets(now)
        victims: List[Page] = []
        freed = self.pool.free_bytes

        def take(bucket: "OrderedDict[PageId, Page]", lru_side: bool) -> None:
            nonlocal freed
            for pid in list(bucket.keys()):
                if freed >= bytes_needed:
                    return
                page = bucket[pid]
                if pid in protected or self.pool.is_pinned(page):
                    continue
                bucket.pop(pid)
                if lru_side:
                    self._lru_pos.pop(pid, None)
                else:
                    self._meta[pid].bucket = UNBUCKETED
                victims.append(page)
                freed += page.size_bytes

        take(self.not_requested, lru_side=False)
        # walk both timelines from the far-future end, LRU side first
        i = self.nb - 1
        while freed < bytes_needed and i >= 0:
            take(self.lru_buckets[i], lru_side=True)
            if freed < bytes_needed:
                take(self.buckets[i], lru_side=False)
            i -= 1
        return victims
