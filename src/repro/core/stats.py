"""Result aggregation: the paper's metrics + sharing-potential analysis."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence

from .engine import EngineResult


@dataclass
class SharingPotential:
    """Time-integrated bytes by number of interested scans (Figs 17/18)."""

    by_count: Dict[int, float]  # interest count -> avg bytes over samples

    @property
    def reusable_fraction(self) -> float:
        """Fraction of in-demand data wanted by >= 2 scans."""
        total = sum(self.by_count.values())
        if total <= 0:
            return 0.0
        multi = sum(v for k, v in self.by_count.items() if k >= 2)
        return multi / total


def sharing_potential(result: EngineResult) -> SharingPotential:
    acc: Dict[int, float] = {}
    n = max(1, len(result.sharing_samples))
    for sample in result.sharing_samples:
        for k, v in sample.items():
            kk = min(k, 4)  # paper buckets: 1, 2, 3, 4+
            acc[kk] = acc.get(kk, 0.0) + v / n
    return SharingPotential(by_count=dict(sorted(acc.items())))


def summarize(results: Sequence[EngineResult]) -> List[Dict[str, object]]:
    rows = []
    for r in results:
        rows.append(
            {
                "policy": r.policy,
                "avg_stream_time_s": round(r.avg_stream_time, 3),
                "total_io_gb": round(r.io_gb, 3),
                "loads": r.total_loads,
                "hits": r.total_hits,
                "sim_time_s": round(r.sim_time, 3),
            }
        )
    return rows
