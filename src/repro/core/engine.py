"""Discrete-event engine for concurrent scans over a bandwidth-limited device.

Reproduces the paper's experimental machine abstractly:

* **CPU**: every scan processes tuples at ``spec.tuple_rate`` (the paper
  maxes out 8 threads/query; we fold parallel speedup into the rate).  A
  scan runs in *segments*: it consumes forward while the pages it needs are
  resident, pinning the in-use pages, then blocks on the first miss.
* **I/O**: a single bandwidth-limited server (the paper throttles page
  delivery from storage to the buffer manager exactly this way to simulate
  200 MB/s – 2 GB/s subsystems).  In-order mode services a FIFO of page
  requests (demand + readahead); cooperative mode services ABM chunk loads.
* **Buffer pool**: fixed capacity; eviction by the plugged policy
  (LRU/MRU/PBM/OPT) or by ABM's KeepRelevance (CScans).

Metrics match the paper: average stream time, total I/O volume, and the
sharing-potential sampling of Figs 17/18.  With ``record_trace`` the page
reference string is captured so Belady's MIN can be replayed on it — the
paper's OPT methodology.
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

from .pages import Database, Page, PageId
from .policies.base import BufferPool, Policy
from .policies.cscan import ABM, LoadDecision
from .scans import ScanSpec, ScanState


@dataclass
class EngineConfig:
    bandwidth: float = 700e6          # bytes/sec (paper default 700 MB/s)
    buffer_bytes: int = 620 << 20     # 40% of the microbenchmark working set
    prefetch_pages: int = 8           # per-scan readahead (in-order mode)
    segment_pages: int = 2            # pages pinned per CPU burst
    evict_batch_pages: int = 16       # paper: evictions amortised in groups
    sample_interval: float = 1.0      # sharing-potential sampling period
    record_trace: bool = False
    max_sim_time: float = 3e5
    # PBM bucket timeline resolution (paper: 100ms). Must be well below a
    # typical query duration or every page lands in bucket 0 — scale it
    # down together with the workload in reduced-scale runs.
    pbm_time_slice: float = 0.1


@dataclass
class EngineResult:
    policy: str
    stream_times: List[float]
    query_latencies: List[float]
    total_io_bytes: int
    total_hits: int
    total_loads: int
    sim_time: float
    total_evictions: int = 0
    sharing_samples: List[Dict[int, int]] = field(default_factory=list)
    trace: List[PageId] = field(default_factory=list)
    page_sizes: Dict[PageId, int] = field(default_factory=dict)

    @property
    def avg_stream_time(self) -> float:
        return sum(self.stream_times) / max(1, len(self.stream_times))

    @property
    def io_gb(self) -> float:
        return self.total_io_bytes / 1e9


# event kinds
_IO_DONE = 0
_CPU_DONE = 1
_SAMPLE = 2


class Engine:
    def __init__(
        self,
        db: Database,
        policy: Optional[Policy],
        config: EngineConfig,
        cooperative: bool = False,
    ) -> None:
        self.db = db
        self.cfg = config
        self.cooperative = cooperative
        self.pool = BufferPool(config.buffer_bytes)
        self.policy = policy
        if policy is not None:
            policy.attach(self.pool, 0.0)
        self.abm: Optional[ABM] = ABM(db, self.pool) if cooperative else None

        self._events: List[Tuple[float, int, int, object]] = []
        self._seq = itertools.count()
        self.now = 0.0
        # streams
        self._streams: List[List[ScanSpec]] = []
        self._stream_pos: List[int] = []
        self._stream_start: List[float] = []
        self._stream_done_t: List[Optional[float]] = []
        self._active: Dict[int, ScanState] = {}   # scan_id -> state
        self._scan_stream: Dict[int, int] = {}
        self._scan_running: Set[int] = set()      # has a CPU event in flight
        self._scan_pinned: Dict[int, List[Page]] = {}
        self._query_start: Dict[int, float] = {}
        self.query_latencies: List[float] = []
        # in-order I/O queue
        self._io_queue: List[Tuple[int, Page]] = []   # (seq, page) FIFO
        self._io_queued: Dict[PageId, Set[int]] = {}  # pid -> waiting scan ids
        self._io_busy = False
        self._blocked_on: Dict[PageId, Set[int]] = {}
        self._starved: Set[int] = set()               # cooperative mode
        # metrics
        self._interest_count: Dict[PageId, int] = {}
        self.sharing_samples: List[Dict[int, int]] = []
        self.trace: List[PageId] = []
        self._page_sizes: Dict[PageId, int] = {}

    # ------------------------------------------------------------- plumbing
    def _push(self, t: float, kind: int, payload: object) -> None:
        heapq.heappush(self._events, (t, kind, next(self._seq), payload))

    def add_stream(self, specs: Sequence[ScanSpec]) -> int:
        sid = len(self._streams)
        self._streams.append(list(specs))
        self._stream_pos.append(0)
        self._stream_start.append(0.0)
        self._stream_done_t.append(None)
        return sid

    # ------------------------------------------------------------------ run
    def run(self) -> EngineResult:
        for sid in range(len(self._streams)):
            self._submit_next(sid)
        if self.cfg.sample_interval > 0:
            self._push(self.cfg.sample_interval, _SAMPLE, None)
        self._kick_io()
        while self._events:
            t, kind, _, payload = heapq.heappop(self._events)
            if t > self.cfg.max_sim_time:
                raise RuntimeError("simulation exceeded max_sim_time (livelock?)")
            self.now = t
            if kind == _IO_DONE:
                self._on_io_done(payload)
            elif kind == _CPU_DONE:
                self._on_cpu_done(payload)
            elif kind == _SAMPLE:
                self._on_sample()
            if self._all_done():
                break
        stream_times = [
            (d if d is not None else self.now) - s
            for s, d in zip(self._stream_start, self._stream_done_t)
        ]
        return EngineResult(
            policy=(self.abm.name if self.abm else self.policy.name),
            stream_times=stream_times,
            query_latencies=self.query_latencies,
            total_io_bytes=self.pool.total_loaded_bytes,
            total_hits=self.pool.total_hits,
            total_loads=self.pool.total_loads,
            sim_time=self.now,
            total_evictions=self.pool.total_evictions,
            sharing_samples=self.sharing_samples,
            trace=self.trace,
            page_sizes=self._page_sizes,
        )

    def _all_done(self) -> bool:
        return not self._active and all(
            p >= len(s) for p, s in zip(self._stream_pos, self._streams)
        )

    # ------------------------------------------------------ stream lifecycle
    def _submit_next(self, stream: int) -> None:
        pos = self._stream_pos[stream]
        if pos >= len(self._streams[stream]):
            if self._stream_done_t[stream] is None and not any(
                st for st in self._active.values() if self._scan_stream[st.scan_id] == stream
            ):
                self._stream_done_t[stream] = self.now
            return
        self._stream_pos[stream] = pos + 1
        spec = self._streams[stream][pos]
        scan = ScanState(spec, self.db)
        scan.start_time = self.now
        self._active[scan.scan_id] = scan
        self._scan_stream[scan.scan_id] = stream
        self._query_start[scan.scan_id] = self.now
        for _, p in scan.plan:
            self._interest_count[p.pid] = self._interest_count.get(p.pid, 0) + 1
            self._page_sizes.setdefault(p.pid, p.size_bytes)
        if self.cooperative:
            assert self.abm is not None
            self.abm.register(scan, self.now)
            self._try_consume_chunk(scan)
        else:
            assert self.policy is not None
            self.policy.register_scan(scan, self.now)
            self._try_run(scan)
        self._kick_io()

    def _finish_scan(self, scan: ScanState) -> None:
        scan.done = True
        scan.finish_time = self.now
        self.query_latencies.append(self.now - self._query_start[scan.scan_id])
        if self.cooperative:
            self.abm.unregister(scan, self.now)
            self._starved.discard(scan.scan_id)
        else:
            self.policy.unregister_scan(scan, self.now)
        stream = self._scan_stream[scan.scan_id]
        del self._active[scan.scan_id]
        self._submit_next(stream)

    # ==================================================== in-order mode =====
    def _try_run(self, scan: ScanState) -> None:
        if scan.done or scan.scan_id in self._scan_running:
            return
        plan = scan.plan_full
        n = len(plan)
        i = scan.plan_idx
        if i >= n and scan.virt_pos >= scan.total_tuples:
            self._finish_scan(scan)
            return
        # One forward walk: consume while resident, stop at the first miss or
        # once the segment budget is exhausted at a strictly later trigger
        # (pages sharing one trigger are taken together or not at all).
        taken: List[Page] = []
        k = i
        t_end = scan.total_tuples
        blocking: Optional[Page] = None
        while k < n:
            trg, _, page = plan[k]
            if not self.pool.is_resident(page):
                t_end = trg
                blocking = page
                break
            if len(taken) >= self.cfg.segment_pages and trg > plan[k - 1][0]:
                t_end = trg
                break
            taken.append(page)
            k += 1
        # never consume a page whose trigger is at/after the segment end
        while taken and plan[k - 1][0] >= t_end:
            k -= 1
            taken.pop()
        if t_end <= scan.virt_pos:
            assert blocking is not None
            self._block_on(scan, blocking)
            return
        pinned: List[Page] = []
        for page in taken:
            self.pool.pin(page)
            pinned.append(page)
        self._scan_pinned[scan.scan_id] = pinned
        self._scan_running.add(scan.scan_id)
        rate = scan.spec.tuple_rate
        throttle = getattr(self.policy, "throttle_factor", None)
        if throttle is not None:
            rate *= throttle(scan, self.now)  # Attach&Throttle (paper §5)
        dt = (t_end - scan.virt_pos) / max(rate, 1e-9)
        self._push(self.now + dt, _CPU_DONE, (scan.scan_id, k, t_end))
        # readahead for the *next* misses
        self._issue_prefetch(scan, k)

    def _block_on(self, scan: ScanState, page: Page) -> None:
        self._blocked_on.setdefault(page.pid, set()).add(scan.scan_id)
        self._request_page(scan, page)
        self._issue_prefetch(scan, scan.plan_idx + 1)
        self._kick_io()

    def _issue_prefetch(self, scan: ScanState, from_idx: int) -> None:
        upto = min(len(scan.plan), from_idx + self.cfg.prefetch_pages)
        for j in range(from_idx, upto):
            page = scan.plan[j][1]
            if not self.pool.is_resident(page):
                self._request_page(scan, page)
        self._kick_io()

    def _request_page(self, scan: ScanState, page: Page) -> None:
        if self.pool.is_resident(page):
            self.pool.total_hits += 1
            return
        waiters = self._io_queued.get(page.pid)
        if waiters is not None:
            waiters.add(scan.scan_id)
            return
        self._io_queued[page.pid] = {scan.scan_id}
        self._io_queue.append((next(self._seq), page))

    def _on_cpu_done(self, payload: Tuple[int, int, int]) -> None:
        scan_id, new_idx, t_end = payload
        scan = self._active.get(scan_id)
        self._scan_running.discard(scan_id)
        if scan is None:
            return
        if self.cooperative:
            self._on_cpu_done_coop(scan, new_idx)  # new_idx carries chunk_id
            return
        for page in self._scan_pinned.pop(scan_id, []):
            self.pool.unpin(page)
        # consume pages passed
        for j in range(scan.plan_idx, new_idx):
            trigger, page = scan.plan[j]
            if self.cfg.record_trace:
                self.trace.append(page.pid)
            c = self._interest_count.get(page.pid, 0)
            if c > 0:
                self._interest_count[page.pid] = c - 1
            self.policy.on_consumed(scan, page, self.now)
        scan.plan_idx = new_idx
        scan.virt_pos = t_end
        scan.report_position(self.now)
        self.policy.report_position(scan, self.now)
        if scan.plan_idx >= len(scan.plan) and scan.virt_pos >= scan.total_tuples:
            self._finish_scan(scan)
        else:
            self._try_run(scan)
        self._kick_io()

    # ------------------------------------------------------------- I/O path
    def _kick_io(self) -> None:
        if self._io_busy:
            return
        if self.cooperative:
            self._kick_io_coop()
            return
        requeued = 0
        while self._io_queue:
            _, page = self._io_queue.pop(0)
            waiters = self._io_queued.pop(page.pid, set())
            if self.pool.is_resident(page):
                continue  # already loaded meanwhile
            if not any(w in self._active for w in waiters):
                continue  # everyone who wanted it is gone
            need = page.size_bytes
            if self.pool.free_bytes < need:
                batch = max(need, self.cfg.evict_batch_pages * page.size_bytes)
                batch = min(batch, self.pool.capacity_bytes)
                victims = self.policy.choose_victims(batch, set(), self.now)
                freed = self.pool.free_bytes + sum(v.size_bytes for v in victims)
                if freed < need:
                    # cannot make room now (pins): requeue and stall
                    self._io_queued[page.pid] = waiters
                    self._io_queue.append((next(self._seq), page))
                    requeued += 1
                    if requeued >= len(self._io_queue):
                        return  # full pass without progress; wait for unpin
                    continue
                for v in victims:
                    self.pool.evict(v)
            self._io_busy = True
            dt = page.size_bytes / self.cfg.bandwidth
            # payload carries the pages: _kick_io may be re-entered from the
            # wake path before this event is handled, so no shared slot.
            self._push(self.now + dt, _IO_DONE, [page])
            return

    def _on_io_done(self, payload: object) -> None:
        self._io_busy = False
        if self.cooperative:
            self._on_io_done_coop(payload)
            return
        for page in payload:  # type: ignore[union-attr]
            self.pool.admit(page)
            self.policy.on_loaded(page, self.now)
            # sorted: wake order must not depend on absolute scan-id values
            # (set iteration order over ints does), or results drift with
            # the global id counter
            for sid in sorted(self._blocked_on.pop(page.pid, set())):
                scan = self._active.get(sid)
                if scan is not None:
                    self._try_run(scan)
        self._kick_io()

    # =================================================== cooperative mode ===
    def _try_consume_chunk(self, scan: ScanState) -> None:
        if scan.done or scan.scan_id in self._scan_running:
            return
        assert self.abm is not None
        if not scan.chunks_remaining:
            self._finish_scan(scan)
            return
        cid = self.abm.get_chunk(scan, self.now)
        if cid is None:
            self._starved.add(scan.scan_id)
            return
        self._starved.discard(scan.scan_id)
        self.abm.pin_chunk(scan, cid)
        self._scan_running.add(scan.scan_id)
        tuples = max(1, scan.tuples_in_chunk(cid))
        dt = tuples / max(scan.spec.tuple_rate, 1e-9)
        self._push(self.now + dt, _CPU_DONE, (scan.scan_id, cid, -1))

    def _kick_io_coop(self) -> None:
        assert self.abm is not None
        decision = self.abm.next_load(self.now, self._starved)
        if decision is None:
            return
        for v in decision.evict:
            self.pool.evict(v)
        self.abm.in_flight.add(decision.chunk)
        self._io_busy = True
        dt = decision.bytes / self.cfg.bandwidth
        self._push(self.now + dt, _IO_DONE, decision)

    def _on_io_done_coop(self, decision: LoadDecision) -> None:
        assert self.abm is not None
        for page in decision.pages:
            self.pool.admit(page)
        self.abm.in_flight.discard(decision.chunk)
        for scan in list(self._active.values()):
            if scan.scan_id in self._starved:
                self._try_consume_chunk(scan)
        self._kick_io()

    def _on_cpu_done_coop(self, scan: ScanState, chunk_id: int) -> None:
        assert self.abm is not None
        self.abm.consume_chunk(scan, chunk_id, self.now)
        # account consumed pages (trace + interest)
        key = (scan.table.name, chunk_id)
        for page in self.abm.chunk_pages_for_columns(key, scan.spec.columns):
            if self.cfg.record_trace:
                self.trace.append(page.pid)
            c = self._interest_count.get(page.pid, 0)
            if c > 0:
                self._interest_count[page.pid] = c - 1
        tuples = scan.tuples_in_chunk(chunk_id)
        scan.virt_pos += tuples
        scan.report_position(self.now)
        if not scan.chunks_remaining:
            self._finish_scan(scan)
        else:
            self._try_consume_chunk(scan)
        self._kick_io()

    # --------------------------------------------------------------- sampling
    def _on_sample(self) -> None:
        hist: Dict[int, int] = {}
        for pid, cnt in self._interest_count.items():
            if cnt > 0:
                size = self._page_sizes.get(pid, 0)
                hist[cnt] = hist.get(cnt, 0) + size
        self.sharing_samples.append(hist)
        if not self._all_done():
            self._push(self.now + self.cfg.sample_interval, _SAMPLE, None)


def run_workload(
    db: Database,
    streams: Sequence[Sequence[ScanSpec]],
    policy_name: str,
    config: EngineConfig,
    policy_factory: Optional[Callable[[], Policy]] = None,
) -> EngineResult:
    """Build an engine for ``policy_name`` and run the streams to
    completion.  Names resolve through ``repro.core.policy_registry`` —
    the single policy table shared with the array backend; unknown names
    fail there with the registered-name list.  ``policy_factory``
    overrides the registry's construction (custom/parameterised
    policies)."""
    from . import policy_registry

    if policy_factory is not None:
        cooperative = policy_registry.get(policy_name).cooperative \
            if policy_name in policy_registry.names() else False
        policy: Optional[Policy] = policy_factory()
    else:
        policy, cooperative = policy_registry.event_policy(
            policy_name, config)
    eng = Engine(db, policy, config, cooperative=cooperative)
    for s in streams:
        eng.add_stream(s)
    return eng.run()
