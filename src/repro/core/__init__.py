"""Core of the reproduction: the paper's storage model, scan operators,
buffer-management policies (LRU/MRU, Cooperative Scans' ABM, PBM, OPT, and
the paper's sketched-but-unbuilt PBM/LRU and Attach&Throttle variants), and
the concurrent-scan execution engine + workloads of the evaluation."""

from . import policy_registry
from .pages import Column, Database, Page, PageId, Table
from .pdt import PDT, CScanMergeState
from .snapshots import Snapshot, SnapshotManager, classify_chunks
from .scans import ScanSpec, ScanState
from .engine import Engine, EngineConfig, EngineResult, run_workload
from .policies.base import BufferPool, Policy
from .policies.lru import LRUPolicy, MRUPolicy
from .policies.pbm import PBMPolicy
from .policies.opt import OraclePolicy, simulate_belady
from .policies.cscan import ABM
from .policies.pbm_lru import PBMLRUPolicy
from .policies.attach_throttle import AttachThrottlePBM

__all__ = [
    "ABM", "AttachThrottlePBM", "BufferPool", "Column", "CScanMergeState",
    "Database", "Engine", "EngineConfig", "EngineResult", "LRUPolicy",
    "MRUPolicy", "OraclePolicy", "PBMLRUPolicy", "PBMPolicy", "PDT", "Page",
    "PageId", "Policy", "ScanSpec", "ScanState", "Snapshot",
    "SnapshotManager", "Table", "classify_chunks", "policy_registry",
    "run_workload", "simulate_belady",
]
