"""Columnar storage model: tables, columns, chunks and pages.

This module reproduces the storage abstractions that the paper's buffer
management policies operate on (paper §2):

* A **table** is a set of columns over ``n_tuples`` tuples.
* Each **column** stores a (possibly compressed) byte stream; because columns
  compress differently, the *same* logical tuple range occupies a very
  different number of pages per column ("one column ... on a single page,
  while other columns ... thousands of pages").
* A **page** is the unit of I/O and buffering (fixed byte size).
* A **chunk** is a *logical tuple range* (>= a few hundred thousand tuples),
  NOT a set of pages — the paper is explicit about this for column stores.
  Chunk→page translation happens per column via :meth:`Table.chunk_pages`.

The same abstractions back the ML-side integrations: a dataset shard is a
"table" whose pages front a slow storage tier, and a paged KV cache reuses
:class:`Page` identity semantics (see ``repro.serving.kv_cache``).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class PageId:
    """Globally unique page identity: (table, column, index within column)."""

    table: str
    column: str
    index: int

    def __repr__(self) -> str:  # compact for traces
        return f"{self.table}.{self.column}[{self.index}]"


@dataclass
class Page:
    """A physical page of one column.

    ``first_tuple``/``last_tuple`` delimit the tuple range whose values the
    page stores (half-open).  One page may span multiple adjacent chunks
    (paper: "one page contains data from multiple adjacent chunks").
    """

    pid: PageId
    size_bytes: int
    first_tuple: int
    last_tuple: int  # exclusive

    @property
    def tuple_count(self) -> int:
        return self.last_tuple - self.first_tuple

    def __hash__(self) -> int:
        return hash(self.pid)

    def __eq__(self, other: object) -> bool:
        return isinstance(other, Page) and self.pid == other.pid


@dataclass
class Column:
    """One column of a table.

    ``bytes_per_tuple`` models width after compression; it drives how many
    pages the column occupies and therefore how much I/O a scan of this
    column costs.
    """

    name: str
    bytes_per_tuple: float
    table_name: str = ""
    n_tuples: int = 0
    page_bytes: int = 1 << 20
    pages: List[Page] = field(default_factory=list, repr=False)

    def build_pages(self) -> None:
        total_bytes = int(math.ceil(self.n_tuples * self.bytes_per_tuple))
        n_pages = max(1, int(math.ceil(total_bytes / self.page_bytes)))
        self.pages = []
        # Uniform tuples-per-page (integer boundaries, exact cover).
        for i in range(n_pages):
            first = (self.n_tuples * i) // n_pages
            last = (self.n_tuples * (i + 1)) // n_pages
            if last <= first:
                last = first + 1
            size = min(self.page_bytes, total_bytes - i * self.page_bytes)
            self.pages.append(
                Page(
                    pid=PageId(self.table_name, self.name, i),
                    size_bytes=max(1, size),
                    first_tuple=first,
                    last_tuple=last,
                )
            )

    def pages_for_range(self, first: int, last: int) -> List[Page]:
        """All pages overlapping tuple range [first, last)."""
        if not self.pages or last <= first:
            return []
        n_pages = len(self.pages)
        tup_per_page = self.n_tuples / n_pages
        lo = min(n_pages - 1, int(first / tup_per_page))
        while lo > 0 and self.pages[lo].first_tuple > first:
            lo -= 1
        while lo < n_pages - 1 and self.pages[lo].last_tuple <= first:
            lo += 1
        out = []
        i = lo
        while i < n_pages and self.pages[i].first_tuple < last:
            out.append(self.pages[i])
            i += 1
        return out


@dataclass
class Table:
    """A columnar table partitioned into logical chunks of tuples."""

    name: str
    n_tuples: int
    columns: Dict[str, Column] = field(default_factory=dict)
    chunk_tuples: int = 100_000
    page_bytes: int = 1 << 20

    def add_column(self, name: str, bytes_per_tuple: float) -> Column:
        col = Column(
            name=name,
            bytes_per_tuple=bytes_per_tuple,
            table_name=self.name,
            n_tuples=self.n_tuples,
            page_bytes=self.page_bytes,
        )
        col.build_pages()
        self.columns[name] = col
        return col

    # ---- chunks -----------------------------------------------------------
    @property
    def n_chunks(self) -> int:
        return max(1, int(math.ceil(self.n_tuples / self.chunk_tuples)))

    def chunk_range(self, chunk_id: int) -> Tuple[int, int]:
        first = chunk_id * self.chunk_tuples
        last = min(self.n_tuples, first + self.chunk_tuples)
        return first, last

    def chunks_for_range(self, first: int, last: int) -> List[int]:
        if last <= first:
            return []
        lo = first // self.chunk_tuples
        hi = (last - 1) // self.chunk_tuples
        return list(range(lo, hi + 1))

    def chunk_pages(self, chunk_id: int, columns: Sequence[str]) -> List[Page]:
        """Translate a logical chunk into pages, per column (paper §2)."""
        first, last = self.chunk_range(chunk_id)
        out: List[Page] = []
        for c in columns:
            out.extend(self.columns[c].pages_for_range(first, last))
        return out

    def scan_bytes(self, columns: Sequence[str], first: int, last: int) -> int:
        """Unique bytes a scan of [first,last) over ``columns`` touches."""
        total = 0
        for c in columns:
            for p in self.columns[c].pages_for_range(first, last):
                total += p.size_bytes
        return total

    def total_bytes(self, columns: Optional[Sequence[str]] = None) -> int:
        cols = columns if columns is not None else list(self.columns)
        return sum(
            sum(p.size_bytes for p in self.columns[c].pages) for c in cols
        )


@dataclass
class Database:
    """A set of tables — the unit the engine and workloads operate on."""

    tables: Dict[str, Table] = field(default_factory=dict)

    def add_table(
        self,
        name: str,
        n_tuples: int,
        columns: Dict[str, float],
        chunk_tuples: int = 100_000,
        page_bytes: int = 1 << 20,
    ) -> Table:
        t = Table(
            name=name,
            n_tuples=n_tuples,
            chunk_tuples=chunk_tuples,
            page_bytes=page_bytes,
        )
        for cname, bpt in columns.items():
            t.add_column(cname, bpt)
        self.tables[name] = t
        return t

    def all_pages(self) -> Iterable[Page]:
        for t in self.tables.values():
            for c in t.columns.values():
                yield from c.pages
