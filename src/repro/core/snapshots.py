"""Append snapshots and shared/local chunk detection (paper §2.1, Figs 5-7).

Bulk appends bypass PDTs: a storage snapshot is an array of page references
per column; appending creates new pages and a new (transaction-local)
snapshot sharing a prefix with its parent.  Commit promotes the local
snapshot to *master*.  Concurrent appenders conflict: only one can commit
(the paper proves all live snapshots share a single common prefix chain).

ABM exploits this: chunks made purely of pages that belong to >= 2 live
snapshots are **shared** (high reuse potential, load early / keep longer);
chunks whose pages belong to only one snapshot are **local** (load late,
use once).  A PDT *checkpoint* creates a brand-new page set — snapshots of
different table versions share nothing and are registered as distinct
tables inside ABM (cases (i)-(iv) in the paper).

The ML-side analogue is prompt-prefix sharing in the paged KV cache:
requests sharing a system-prompt prefix are transactions whose "snapshots"
share a page prefix; see ``repro.serving.kv_cache``.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

_snapshot_ids = itertools.count()


@dataclass
class Snapshot:
    """A storage snapshot: ordered page-id lists, one per column."""

    table: str
    pages: Dict[str, List[int]]  # column -> ordered page identifiers
    version: int = 0             # bumped by checkpoints (disjoint page sets)
    sid: int = field(default_factory=lambda: next(_snapshot_ids))

    def append(self, new_pages: Dict[str, List[int]]) -> "Snapshot":
        """Transaction-local snapshot: shares this one's prefix + new pages."""
        merged = {c: list(ps) for c, ps in self.pages.items()}
        for c, ps in new_pages.items():
            merged.setdefault(c, []).extend(ps)
        return Snapshot(table=self.table, pages=merged, version=self.version)

    def n_chunks(self, tuples_per_chunk_pages: int = 1) -> int:
        # Chunk granularity derived from the shortest column page list so a
        # chunk is well defined across all columns.
        return max(len(ps) for ps in self.pages.values()) if self.pages else 0

    def is_prefix_of(self, other: "Snapshot") -> bool:
        if self.version != other.version:
            return False
        for c, ps in self.pages.items():
            ops = other.pages.get(c, [])
            if len(ps) > len(ops) or ops[: len(ps)] != ps:
                return False
        return True

    def common_prefix_len(self, other: "Snapshot") -> Dict[str, int]:
        """Per-column length of the longest common page prefix."""
        if self.version != other.version:
            return {c: 0 for c in self.pages}
        out = {}
        for c, ps in self.pages.items():
            ops = other.pages.get(c, [])
            n = 0
            for a, b in zip(ps, ops):
                if a != b:
                    break
                n += 1
            out[c] = n
        return out


class SnapshotManager:
    """Tracks the master snapshot and commit conflicts for one table."""

    def __init__(self, master: Snapshot):
        self.master = master
        self._master_at_start: Dict[int, int] = {}  # txn -> master sid at start

    def begin(self, txn: int) -> Snapshot:
        self._master_at_start[txn] = self.master.sid
        return self.master

    def commit(self, txn: int, snapshot: Snapshot) -> bool:
        """Commit txn's (possibly appended) snapshot.

        Returns False (abort) if another appender committed since txn began —
        the paper: "only one of the concurrent transactions that applied
        Appends to its snapshot can commit".
        """
        started_on = self._master_at_start.pop(txn, None)
        if started_on is None:
            raise ValueError(f"unknown transaction {txn}")
        if snapshot.sid == self.master.sid or snapshot.version != self.master.version:
            # read-only txn, or checkpoint happened: nothing to promote
            return snapshot.sid == self.master.sid
        if started_on != self.master.sid:
            return False  # conflicting appender committed first -> abort
        self.master = snapshot
        return True

    def checkpoint(self, new_pages: Dict[str, List[int]]) -> Snapshot:
        """PDT checkpoint: brand-new page set, new version (paper Fig. 7)."""
        self.master = Snapshot(
            table=self.master.table,
            pages=new_pages,
            version=self.master.version + 1,
        )
        return self.master


def classify_chunks(
    live_snapshots: Sequence[Snapshot],
    chunk_pages: int = 1,
) -> Tuple[Set[int], Dict[int, Set[int]]]:
    """Shared/local chunk classification over live snapshots of one version.

    Returns ``(shared, local_by_snapshot)`` where chunk index ``i`` covers
    page positions ``[i*chunk_pages, (i+1)*chunk_pages)`` of every column.
    A chunk is **shared** iff *all* its pages in *all* columns belong to the
    snapshots of >= 2 live transactions (paper: "even after appending a
    single value to a table, its last chunk becomes local").
    """
    shared: Set[int] = set()
    local: Dict[int, Set[int]] = {}
    if not live_snapshots:
        return shared, local
    by_version: Dict[int, List[Snapshot]] = {}
    for s in live_snapshots:
        by_version.setdefault(s.version, []).append(s)

    for _version, snaps in by_version.items():
        # Longest prefix (in pages, per column) present in >= 2 snapshots.
        if len(snaps) >= 2:
            best: Optional[Dict[str, int]] = None
            for i in range(len(snaps)):
                for j in range(i + 1, len(snaps)):
                    cp = snaps[i].common_prefix_len(snaps[j])
                    score = min(cp.values()) if cp else 0
                    if best is None or score > (min(best.values()) if best else 0):
                        best = cp
            prefix = best or {}
        else:
            prefix = {c: 0 for c in snaps[0].pages}

        min_prefix_pages = min(prefix.values()) if prefix else 0
        n_shared_chunks = min_prefix_pages // chunk_pages
        shared.update(range(n_shared_chunks))
        for s in snaps:
            max_pages = max((len(ps) for ps in s.pages.values()), default=0)
            n_chunks = (max_pages + chunk_pages - 1) // chunk_pages
            local[s.sid] = set(range(n_shared_chunks, n_chunks))
    return shared, local
