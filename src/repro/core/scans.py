"""Scan operators and their registration metadata (paper §2-3).

Two operator flavours exist, mirroring the paper:

* :class:`ScanSpec` / :class:`ScanState` — an **in-order** range scan.  Under
  LRU/PBM/OPT the scan issues page requests in physical order; the policy
  only decides eviction.  PBM receives ``register/report/unregister`` calls
  (paper Fig. 3) and estimates per-scan speed.
* Cooperative scans (CScan) reuse the same spec but consume **chunks
  out-of-order** as delivered by ABM (see ``policies/cscan.py``); the engine
  drives that protocol.

A scan over multiple ranges/columns is linearised into *virtual tuple
positions* (cumulative tuples over its ranges).  The **access plan** is the
sorted list of (trigger_virtual_tuple, page): the page must be resident
before the cursor crosses its trigger.  ``tuples_behind`` as used by PBM's
``RegisterScan`` (paper Fig. 9) is exactly the trigger.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .pages import Database, Page, Table

_scan_ids = itertools.count()


@dataclass
class ScanSpec:
    """Static description of a range scan: what data it will consume."""

    table: str
    columns: Tuple[str, ...]
    ranges: Tuple[Tuple[int, int], ...]  # half-open tuple ranges, sorted
    tuple_rate: float = 50e6             # tuples/sec of CPU processing
    stream: int = 0
    in_order_required: bool = False      # paper §2.3: order-preserving CScan

    @property
    def total_tuples(self) -> int:
        return sum(b - a for a, b in self.ranges)


class ScanState:
    """Runtime state of one scan operator inside the engine."""

    def __init__(self, spec: ScanSpec, db: Database):
        self.spec = spec
        self.scan_id = next(_scan_ids)
        self.table: Table = db.tables[spec.table]
        # ---- access plan (in-order mode) ----
        # (trigger, page): page must be resident before cursor crosses trigger
        self.plan: List[Tuple[int, Page]] = []
        # (trigger, end, page): cursor in [trigger, end) means page is in use
        self.plan_full: List[Tuple[int, int, Page]] = []
        base = 0
        for (a, b) in spec.ranges:
            for col in spec.columns:
                for p in self.table.columns[col].pages_for_range(a, b):
                    trigger = base + max(0, p.first_tuple - a)
                    end = base + min(b - a, p.last_tuple - a)
                    self.plan_full.append((trigger, max(end, trigger + 1), p))
            base += b - a
        self.plan_full.sort(
            key=lambda tp: (tp[0], tp[2].pid.column, tp[2].pid.index)
        )
        self.plan = [(t, p) for t, _, p in self.plan_full]
        self.total_tuples = spec.total_tuples
        self.unique_pages: Set[Page] = {p for _, p in self.plan}
        # ---- chunk interest (cooperative mode) ----
        self.chunks: Set[int] = set()
        for (a, b) in spec.ranges:
            self.chunks.update(self.table.chunks_for_range(a, b))
        self.chunks_remaining: Set[int] = set(self.chunks)
        # ---- cursor ----
        self.virt_pos: int = 0           # virtual tuples consumed so far
        self.plan_idx: int = 0           # next page in plan not yet consumed
        self.done: bool = False
        self.start_time: Optional[float] = None
        self.finish_time: Optional[float] = None
        # ---- speed tracking (PBM) ----
        self.speed: float = spec.tuple_rate      # tuples/sec estimate (EWMA)
        self._last_report: Optional[Tuple[float, int]] = None

    # ---- helpers -----------------------------------------------------------
    def pages_with_trigger_in(self, lo: int, hi: int) -> List[Page]:
        """Pages whose trigger lies in [lo, hi) — prefetch window lookups."""
        out = []
        i = self.plan_idx
        while i < len(self.plan) and self.plan[i][0] < hi:
            if self.plan[i][0] >= lo:
                out.append(self.plan[i][1])
            i += 1
        return out

    def next_needed(self) -> Optional[Tuple[int, Page]]:
        if self.plan_idx < len(self.plan):
            return self.plan[self.plan_idx]
        return None

    def report_position(self, now: float, ewma: float = 0.3) -> None:
        """Update the EWMA speed estimate (PBM's ReportScanPosition)."""
        if self._last_report is not None:
            t0, p0 = self._last_report
            dt = now - t0
            if dt > 1e-9 and self.virt_pos > p0:
                inst = (self.virt_pos - p0) / dt
                self.speed = ewma * inst + (1 - ewma) * self.speed
        self._last_report = (now, self.virt_pos)

    def tuples_in_chunk(self, chunk_id: int) -> int:
        """Tuples of this scan's ranges that fall inside ``chunk_id``."""
        clo, chi = self.table.chunk_range(chunk_id)
        total = 0
        for (a, b) in self.spec.ranges:
            total += max(0, min(b, chi) - max(a, clo))
        return total

    def __repr__(self) -> str:
        return (
            f"Scan#{self.scan_id}({self.spec.table} cols={len(self.spec.columns)} "
            f"pos={self.virt_pos}/{self.total_tuples})"
        )
