"""Workload generators reproducing the paper's §4 evaluation setups.

**Microbenchmark** (paper §4.1): TPC-H Q1/Q6-style range scans over
``lineitem`` at SF30 (~180M tuples).  Queries are parameterised with a tuple
range starting at a random position; range length drawn from
{1%, 10%, 50%, 100%} of the table.  1–32 concurrent streams of 16-query
batches.  The accessed column set is Q1's / Q6's; per-column compressed
byte widths are sized so the total accessed volume is ~1550 MB, matching
the paper's default operating point (buffer = 40% of that, 700 MB/s I/O,
8 streams).

**TPC-H throughput** (paper §4.2): 8 tables / 61 columns, 22 query
templates of varying CPU intensity touching different tables/columns;
streams are rotated permutations (qgen-style).  Default operating point:
buffer 2250 MB = 30% of the ~7500 MB accessed by 8 streams, 600 MB/s.

CPU rates are calibrated so the LRU system turns CPU-bound at the paper's
crossover points (micro: ≥80% buffer at 700 MB/s; TPC-H: ≥1200 MB/s) —
absolute times differ from the paper's 2009 hardware, trend shapes are the
reproduction target (see EXPERIMENTS.md).
"""

from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from .pages import Database, Table
from .scans import ScanSpec

# ---------------------------------------------------------------------------
# Microbenchmark: lineitem @ SF30
# ---------------------------------------------------------------------------

LINEITEM_TUPLES = 180_000_000  # SF30
# Compressed bytes/tuple for the Q1/Q6 column set, scaled so the union
# accessed volume is ~1550MB (paper §4.1).
LINEITEM_COLUMNS: Dict[str, float] = {
    "l_quantity": 1.0,
    "l_extendedprice": 2.4,
    "l_discount": 0.7,
    "l_tax": 0.7,
    "l_returnflag": 0.3,
    "l_linestatus": 0.3,
    "l_shipdate": 1.6,
    "l_orderkey": 1.6,
}

Q1_COLUMNS = (
    "l_quantity",
    "l_extendedprice",
    "l_discount",
    "l_tax",
    "l_returnflag",
    "l_linestatus",
    "l_shipdate",
)
Q6_COLUMNS = ("l_quantity", "l_extendedprice", "l_discount", "l_shipdate")

# tuples/sec when CPU-bound, 8-way intra-query parallelism folded in.
# Q1 does ~2x the per-tuple work of Q6 (aggregates 8 expressions vs 1).
Q1_RATE = 120e6
Q6_RATE = 240e6


def make_lineitem_db(
    scale_tuples: int = LINEITEM_TUPLES,
    page_bytes: int = 512 << 10,
    chunk_tuples: Optional[int] = None,
) -> Database:
    if chunk_tuples is None:
        # ~90 chunks regardless of scale (SF30 -> the paper-ish 2M tuples)
        chunk_tuples = max(20_000, scale_tuples // 90)
    db = Database()
    db.add_table(
        "lineitem",
        n_tuples=scale_tuples,
        columns=LINEITEM_COLUMNS,
        chunk_tuples=chunk_tuples,
        page_bytes=page_bytes,
    )
    return db


def micro_query(
    table: Table,
    rng: random.Random,
    fraction: Optional[float] = None,
    stream: int = 0,
) -> ScanSpec:
    """One microbenchmark query: Q1 or Q6 over a random range."""
    frac = fraction if fraction is not None else rng.choice([0.01, 0.1, 0.5, 1.0])
    length = max(1, int(table.n_tuples * frac))
    start = rng.randrange(0, max(1, table.n_tuples - length + 1))
    if rng.random() < 0.5:
        cols, rate = Q1_COLUMNS, Q1_RATE
    else:
        cols, rate = Q6_COLUMNS, Q6_RATE
    return ScanSpec(
        table=table.name,
        columns=cols,
        ranges=((start, start + length),),
        tuple_rate=rate,
        stream=stream,
    )


def micro_streams(
    db: Database,
    n_streams: int = 8,
    queries_per_stream: int = 16,
    fraction: Optional[float] = None,
    seed: int = 42,
) -> List[List[ScanSpec]]:
    table = db.tables["lineitem"]
    rng = random.Random(seed)
    return [
        [
            micro_query(table, rng, fraction=fraction, stream=s)
            for _ in range(queries_per_stream)
        ]
        for s in range(n_streams)
    ]


def micro_accessed_bytes(db: Database) -> int:
    """Upper bound of the microbenchmark working set (all Q1∪Q6 columns)."""
    t = db.tables["lineitem"]
    cols = sorted(set(Q1_COLUMNS) | set(Q6_COLUMNS))
    return t.total_bytes(cols)


# ---------------------------------------------------------------------------
# TPC-H-like throughput run
# ---------------------------------------------------------------------------

# (table, tuples@SF30, {column: bytes/tuple}) — 8 tables, 61 columns total,
# compressed widths chosen to give TPC-H-like relative sizes.
_TPCH_TABLES: List[Tuple[str, int, Dict[str, float]]] = [
    ("lineitem", 180_000_000, {f"l_c{i}": w for i, w in enumerate(
        [1.0, 2.4, 0.7, 0.7, 0.3, 0.3, 1.6, 1.6, 2.0, 1.2, 1.6, 1.6, 0.8, 0.8, 2.8, 1.0])}),
    ("orders", 45_000_000, {f"o_c{i}": w for i, w in enumerate(
        [1.6, 1.2, 0.3, 2.4, 1.6, 1.0, 0.8, 2.6, 0.6])}),
    ("partsupp", 24_000_000, {f"ps_c{i}": w for i, w in enumerate(
        [1.6, 1.6, 1.2, 2.4, 3.0])}),
    ("part", 6_000_000, {f"p_c{i}": w for i, w in enumerate(
        [1.6, 3.2, 1.0, 1.0, 1.2, 0.8, 1.0, 2.4, 2.8])}),
    ("customer", 4_500_000, {f"c_c{i}": w for i, w in enumerate(
        [1.6, 2.6, 2.8, 0.6, 1.8, 2.4, 0.8, 2.8])}),
    ("supplier", 300_000, {f"s_c{i}": w for i, w in enumerate(
        [1.6, 2.4, 2.8, 0.6, 1.8, 2.4, 2.8])}),
    ("nation", 25, {f"n_c{i}": w for i, w in enumerate([4.0, 16.0, 4.0, 32.0])}),
    ("region", 5, {f"r_c{i}": w for i, w in enumerate([4.0, 16.0, 32.0])}),
]


@dataclass
class _QueryTemplate:
    table: str
    n_cols: int           # leading columns touched
    fraction: float       # of the table scanned
    rate: float           # tuples/sec (CPU intensity)
    extra_tables: Tuple[Tuple[str, int, float], ...] = ()  # joins: (table, cols, frac)


# 22 templates with TPC-H-flavoured access patterns: lineitem-heavy,
# CPU-intensive, some dimension lookups; rates in tuples/s.
_TPCH_QUERIES: List[_QueryTemplate] = [
    _QueryTemplate("lineitem", 7, 0.98, 60e6),                                  # Q1
    _QueryTemplate("partsupp", 4, 0.8, 40e6, (("part", 3, 0.2), ("supplier", 4, 1.0))),  # Q2
    _QueryTemplate("lineitem", 4, 0.54, 80e6, (("orders", 4, 0.5), ("customer", 2, 0.2))),  # Q3
    _QueryTemplate("orders", 3, 0.4, 70e6, (("lineitem", 3, 0.4),)),             # Q4
    _QueryTemplate("lineitem", 3, 0.6, 70e6, (("orders", 3, 0.6), ("customer", 3, 1.0), ("supplier", 3, 1.0))),  # Q5
    _QueryTemplate("lineitem", 4, 0.45, 120e6),                                  # Q6
    _QueryTemplate("lineitem", 5, 0.6, 60e6, (("supplier", 2, 1.0), ("orders", 2, 0.6))),  # Q7
    _QueryTemplate("lineitem", 4, 0.35, 60e6, (("part", 2, 0.1), ("orders", 3, 0.5))),     # Q8
    _QueryTemplate("lineitem", 6, 0.9, 50e6, (("part", 3, 0.3), ("partsupp", 3, 0.6))),    # Q9
    _QueryTemplate("lineitem", 4, 0.25, 80e6, (("orders", 4, 0.3), ("customer", 6, 1.0))), # Q10
    _QueryTemplate("partsupp", 4, 1.0, 60e6, (("supplier", 2, 1.0),)),           # Q11
    _QueryTemplate("lineitem", 5, 0.3, 90e6, (("orders", 2, 0.3),)),             # Q12
    _QueryTemplate("orders", 3, 1.0, 50e6, (("customer", 1, 1.0),)),             # Q13
    _QueryTemplate("lineitem", 4, 0.08, 110e6, (("part", 2, 0.6),)),             # Q14
    _QueryTemplate("lineitem", 4, 0.25, 100e6, (("supplier", 3, 1.0),)),         # Q15
    _QueryTemplate("partsupp", 3, 0.9, 70e6, (("part", 4, 0.5),)),               # Q16
    _QueryTemplate("lineitem", 3, 0.15, 90e6, (("part", 2, 0.05),)),             # Q17
    _QueryTemplate("lineitem", 3, 0.95, 60e6, (("orders", 3, 0.9), ("customer", 2, 0.4))), # Q18
    _QueryTemplate("lineitem", 5, 0.12, 90e6, (("part", 4, 0.15),)),             # Q19
    _QueryTemplate("lineitem", 3, 0.4, 80e6, (("partsupp", 3, 0.5), ("part", 2, 0.2))),    # Q20
    _QueryTemplate("lineitem", 4, 0.7, 55e6, (("orders", 2, 0.7), ("supplier", 3, 1.0))),  # Q21
    _QueryTemplate("customer", 4, 1.0, 80e6, (("orders", 2, 0.5),)),             # Q22
]


def make_tpch_db(
    scale: float = 1.0,
    page_bytes: int = 512 << 10,
    chunk_tuples: Optional[int] = None,
) -> Database:
    db = Database()
    for name, tuples, cols in _TPCH_TABLES:
        n = max(1, int(tuples * scale))
        db.add_table(
            name,
            n_tuples=n,
            columns=cols,
            chunk_tuples=chunk_tuples or max(10_000, n // 90),
            page_bytes=page_bytes,
        )
    return db


def _template_specs(
    db: Database, q: _QueryTemplate, rng: random.Random, stream: int
) -> List[ScanSpec]:
    """One query = one scan per touched table (plan leaves)."""
    out = []
    parts: List[Tuple[str, int, float]] = [(q.table, q.n_cols, q.fraction)]
    parts += list(q.extra_tables)
    for tname, ncols, frac in parts:
        t = db.tables[tname]
        cols = tuple(sorted(t.columns.keys())[:ncols])
        length = max(1, int(t.n_tuples * frac))
        start = rng.randrange(0, max(1, t.n_tuples - length + 1))
        out.append(
            ScanSpec(
                table=tname,
                columns=cols,
                ranges=((start, start + length),),
                tuple_rate=q.rate,
                stream=stream,
            )
        )
    return out


def tpch_streams(
    db: Database,
    n_streams: int = 8,
    seed: int = 7,
) -> List[List[ScanSpec]]:
    """qgen-style rotated permutations of the 22 templates; every query may
    expand to several table scans, run back-to-back within the stream."""
    rng = random.Random(seed)
    base = list(range(len(_TPCH_QUERIES)))
    streams: List[List[ScanSpec]] = []
    for s in range(n_streams):
        order = base[s % len(base):] + base[: s % len(base)]
        rng.shuffle(order)
        specs: List[ScanSpec] = []
        for qi in order:
            specs.extend(_template_specs(db, _TPCH_QUERIES[qi], rng, s))
        streams.append(specs)
    return streams


def tpch_accessed_bytes(db: Database, streams: Sequence[Sequence[ScanSpec]]) -> int:
    """Unique bytes touched by the given streams (the '100%' reference)."""
    seen = set()
    total = 0
    for stream in streams:
        for spec in stream:
            t = db.tables[spec.table]
            for c in spec.columns:
                for a, b in spec.ranges:
                    for p in t.columns[c].pages_for_range(a, b):
                        if p.pid not in seen:
                            seen.add(p.pid)
                            total += p.size_bytes
    return total
