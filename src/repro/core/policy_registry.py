"""One policy registry for every backend — simulators AND the serving path.

Every buffer-management policy in the tree — the paper's four-way
comparison (LRU, CScans' ABM, PBM, OPT) and the beyond-paper variants —
is described by exactly one :class:`PolicyEntry` here.  Three backends
resolve names through this table:

* the **event engine** (``repro.core.engine.run_workload``) instantiates
  ``entry.event_factory(config)`` — or drives the cooperative ABM when
  ``entry.cooperative`` is set;
* the **array backend** (``repro.core.array_sim``) instantiates
  ``entry.array_factory()``, an
  :class:`~repro.core.array_sim.policies.ArrayPolicy`, and encodes the
  policy in traced configs as the stable integer ``entry.array_id``;
* the **serving path** (``repro.serving``, the paged KV-cache) instantiates
  ``entry.serving_factory()``, a
  :class:`~repro.serving.policy_driver.ServingPolicy` the
  ``ServingEngine``'s driver consults for eviction / spill / prefetch —
  the decode schedule is the paper's "known future" on real traffic.

Policies are *data*: benchmarks derive their policy lists from
:func:`names` instead of hardcoded tuples, unknown names fail with the
known-name list, and adding a policy is one entry plus (optionally) an
``ArrayPolicy`` implementation — no engine or step surgery (see the
"adding a policy" section of EXPERIMENTS.md).

Factories import lazily so this module — and with it ``repro.core`` —
stays importable without JAX; only resolving an *array* policy touches
``repro.core.array_sim``.
"""

from __future__ import annotations

import argparse
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

__all__ = [
    "PolicyEntry", "register", "get", "names", "event_policy",
    "array_policy", "array_ids", "array_name", "serving_policy",
]


@dataclass(frozen=True)
class PolicyEntry:
    """One policy, both backends.

    ``event_factory(config) -> Policy`` builds the dict-engine policy
    from an :class:`~repro.core.engine.EngineConfig` (``None`` for
    array-only entries and for the cooperative mode, where the engine
    builds the ABM itself).  ``array_factory() -> ArrayPolicy`` builds
    the array-backend policy (``None`` for event-only entries).
    ``array_id`` is the stable integer the array backend carries in
    traced configs — part of the result-JSON contract, never reused.
    ``serving_factory() -> ServingPolicy`` builds the paged-KV-cache
    policy the serving engine's driver consults (``None`` for entries
    with no serving realisation).
    """

    name: str
    summary: str
    paper: bool = False          # one of the paper's four-way comparison
    cooperative: bool = False    # event engine drives it through the ABM
    event_factory: Optional[Callable[..., object]] = None
    array_factory: Optional[Callable[[], object]] = None
    array_id: Optional[int] = None
    serving_factory: Optional[Callable[[], object]] = None

    @property
    def backends(self) -> tuple:
        """Which backends can run this policy ("event", "array",
        "serving")."""
        out = []
        if self.event_factory is not None or self.cooperative:
            out.append("event")
        if self.array_factory is not None:
            out.append("array")
        if self.serving_factory is not None:
            out.append("serving")
        return tuple(out)


_REGISTRY: Dict[str, PolicyEntry] = {}


def register(entry: PolicyEntry) -> PolicyEntry:
    """Add a policy to the registry (name and array_id must be unused)."""
    if entry.name in _REGISTRY:
        raise ValueError(f"policy {entry.name!r} already registered")
    if not entry.backends:
        raise ValueError(
            f"policy {entry.name!r} has no event, array, or serving "
            "factory — register at least one backend"
        )
    if entry.array_id is not None:
        taken = {e.array_id: e.name for e in _REGISTRY.values()
                 if e.array_id is not None}
        if entry.array_id in taken:
            raise ValueError(
                f"array_id {entry.array_id} of {entry.name!r} is already "
                f"used by {taken[entry.array_id]!r} (ids are a stable "
                "result-JSON contract; pick a fresh one)"
            )
    if (entry.array_factory is not None) != (entry.array_id is not None):
        raise ValueError(
            f"policy {entry.name!r}: array_factory and array_id must be "
            "given together"
        )
    _REGISTRY[entry.name] = entry
    return entry


def get(name: str) -> PolicyEntry:
    """Look up a policy by name; unknown names list what exists."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown policy {name!r}; registered policies: "
            f"{sorted(_REGISTRY)} (see repro.core.policy_registry)"
        ) from None


def names(backend: Optional[str] = None, paper_only: bool = False,
          ) -> List[str]:
    """Registered policy names, in registration order.

    ``backend="event"|"array"|"serving"`` restricts to policies that
    backend can run; ``paper_only`` restricts to the paper's four-way
    comparison.
    """
    out = []
    for e in _REGISTRY.values():
        if backend is not None and backend not in e.backends:
            continue
        if paper_only and not e.paper:
            continue
        out.append(e.name)
    return out


def event_policy(name: str, config):
    """Resolve ``name`` for the event engine.

    Returns ``(policy, cooperative)``: the instantiated ``Policy`` (or
    ``None`` in cooperative mode, where the engine owns the ABM).
    """
    e = get(name)
    if "event" not in e.backends:
        raise KeyError(
            f"policy {name!r} is array-only; event-backend policies: "
            f"{names(backend='event')}"
        )
    if e.cooperative:
        return None, True
    return e.event_factory(config), False


def array_policy(name: str):
    """Resolve ``name`` to a fresh ``ArrayPolicy`` instance (imports the
    array backend, and with it JAX, lazily)."""
    e = get(name)
    if e.array_factory is None:
        raise KeyError(
            f"policy {name!r} is event-engine-only; array-backend "
            f"policies: {names(backend='array')}"
        )
    return e.array_factory()


def serving_policy(name: str):
    """Resolve ``name`` to a fresh ``ServingPolicy`` instance for the
    paged-KV serving engine (imports ``repro.serving`` lazily)."""
    e = get(name)
    if e.serving_factory is None:
        raise KeyError(
            f"policy {name!r} has no serving realisation; serving-capable "
            f"policies: {names(backend='serving')}"
        )
    return e.serving_factory()


def array_ids() -> Dict[str, int]:
    """name -> stable array id, for every array-capable policy."""
    return {e.name: e.array_id for e in _REGISTRY.values()
            if e.array_id is not None}


def array_name(array_id: int) -> Optional[str]:
    """Inverse of :func:`array_ids` (None for unknown ids)."""
    for e in _REGISTRY.values():
        if e.array_id == array_id:
            return e.name
    return None


# ---------------------------------------------------------------------------
# Registrations.  array_id values are a stable contract (result JSONs and
# stacked configs carry them): lru=0 and pbm=1 predate the registry.
# ---------------------------------------------------------------------------

def _event_lru(config):
    from .policies.lru import LRUPolicy
    return LRUPolicy()


def _event_mru(config):
    from .policies.lru import MRUPolicy
    return MRUPolicy()


def _event_pbm(config):
    from .policies.pbm import PBMPolicy
    return PBMPolicy(time_slice=config.pbm_time_slice)


def _event_opt(config):
    from .policies.opt import OraclePolicy
    return OraclePolicy()


def _event_pbm_lru(config):
    from .policies.pbm_lru import PBMLRUPolicy
    return PBMLRUPolicy(time_slice=config.pbm_time_slice)


def _event_attach(config):
    from .policies.attach_throttle import AttachThrottlePBM
    return AttachThrottlePBM(time_slice=config.pbm_time_slice)


def _array_lru():
    from .array_sim.policies import ArrayLRU
    return ArrayLRU()


def _array_pbm():
    from .array_sim.policies import ArrayPBM
    return ArrayPBM()


def _array_cscan():
    from .array_sim.policies import ArrayCScan
    return ArrayCScan()


def _array_opt():
    from .array_sim.policies import ArrayOPT
    return ArrayOPT()


def _serving_lru():
    from ..serving.policy_driver import ServingLRU
    return ServingLRU()


def _serving_pbm():
    from ..serving.policy_driver import ServingPBM
    return ServingPBM()


def _serving_cscan():
    from ..serving.policy_driver import ServingCScan
    return ServingCScan()


def _serving_opt():
    from ..serving.policy_driver import ServingOPT
    return ServingOPT()


register(PolicyEntry(
    name="lru", summary="least-recently-used eviction (paper baseline)",
    paper=True, event_factory=_event_lru,
    array_factory=_array_lru, array_id=0,
    serving_factory=_serving_lru,
))
register(PolicyEntry(
    name="cscan",
    summary="Cooperative Scans: ABM chunk scheduling (paper §2)",
    paper=True, cooperative=True,
    array_factory=_array_cscan, array_id=2,
    serving_factory=_serving_cscan,
))
register(PolicyEntry(
    name="pbm",
    summary="Predictive Buffer Manager: bucketed consumption timeline "
            "(paper §3)",
    paper=True, event_factory=_event_pbm,
    array_factory=_array_pbm, array_id=1,
    serving_factory=_serving_pbm,
))
register(PolicyEntry(
    name="opt",
    summary="Belady bound on exact next-consumption distances (paper §4)",
    paper=True, event_factory=_event_opt,
    array_factory=_array_opt, array_id=3,
    serving_factory=_serving_opt,
))
register(PolicyEntry(
    name="mru", summary="most-recently-used eviction (beyond-paper)",
    event_factory=_event_mru,
))
register(PolicyEntry(
    name="pbm_lru",
    summary="PBM with LRU inside buckets (paper §5, sketched)",
    event_factory=_event_pbm_lru,
))
register(PolicyEntry(
    name="attach",
    summary="Attach&Throttle PBM (paper §5, sketched)",
    event_factory=_event_attach,
))


def _check_serving(name: str) -> None:
    """Drive the serving engine end to end under ``name``: resolve the
    policy, run a tiny oversubscribed workload, and require every request
    to complete — a serving capability flag that doesn't actually serve
    is a registry lie."""
    from ..serving import PagePool, Request, ServingEngine

    pol = serving_policy(name)
    assert pol.name == name, (pol.name, name)
    eng = ServingEngine(
        PagePool(n_pages=12, page_size=4, page_bytes=256),
        lambda reqs: [0 for _ in reqs], policy=name, max_batch=3,
    )
    for _ in range(4):
        eng.submit(Request(prompt=[1, 2, 3, 4, 5, 6], max_new_tokens=8))
    eng.run_to_completion(max_steps=500)
    assert len(eng.finished) == 4, f"{name}: {len(eng.finished)}/4 served"


def _check(verbose: bool = True) -> int:
    """Registry completeness: every entry resolves on each backend it
    declares (or is explicitly single-backend).  The serving check runs a
    real mini-workload through the ServingEngine.  CI runs this."""
    from .engine import EngineConfig

    cfg = EngineConfig()
    failures = 0
    for name in names():
        e = get(name)
        marks = []
        for backend in ("event", "array", "serving"):
            if backend not in e.backends:
                marks.append(f"{backend}-skip")
                continue
            try:
                if backend == "event":
                    pol, coop = event_policy(name, cfg)
                    assert coop or pol is not None
                elif backend == "array":
                    assert array_policy(name) is not None
                else:
                    _check_serving(name)
                marks.append(f"{backend}-ok")
            except Exception as exc:  # noqa: BLE001
                marks.append(f"{backend}-FAIL({exc})")
                failures += 1
        if verbose:
            tag = "paper" if e.paper else "extra"
            only = ("" if len(e.backends) > 1
                    else f" [{e.backends[0]}-only]")
            print(f"  {name:8s} ({tag}){only}: {' '.join(marks)}")
    return failures


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="verify every registered policy resolves on its "
                         "declared backends (CI registry-completeness)")
    args = ap.parse_args()
    if args.check:
        n = _check()
        if n:
            raise SystemExit(f"{n} registry entries failed to resolve")
        print("policy registry OK:",
              f"{len(names())} policies,",
              f"event={names(backend='event')},",
              f"array={names(backend='array')},",
              f"serving={names(backend='serving')}")
    else:
        for nm in names():
            e = get(nm)
            print(f"{nm:8s} backends={'/'.join(e.backends)} "
                  f"paper={e.paper} — {e.summary}")
