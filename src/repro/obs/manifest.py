"""Run provenance: the :class:`RunManifest` stamped onto benchmark rows.

``trend.py`` diffs benchmark JSONs across CI runs; a regression flag is
only actionable if the two rows are *attributable* — same code? same
jax? same compiled workload?  The manifest answers that: git sha,
jax/jaxlib versions, a content hash of the SimSpec arrays, the time
engine and sanitize mode of the runner, its trace count, and (when the
run collected one) the telemetry summary.  Everything here runs on the
host after the jitted run — nothing touches a traced region.
"""

from __future__ import annotations

import hashlib
import platform
import subprocess
import sys
from typing import Optional

import numpy as np

_GIT_SHA: Optional[str] = None


def git_sha() -> str:
    """Current commit sha (cached; ``"unknown"`` outside a checkout)."""
    global _GIT_SHA
    if _GIT_SHA is None:
        try:
            _GIT_SHA = subprocess.run(
                ["git", "rev-parse", "--short=12", "HEAD"],
                capture_output=True, text=True, timeout=10, check=True,
            ).stdout.strip() or "unknown"
        except Exception:
            _GIT_SHA = "unknown"
    return _GIT_SHA


def spec_hash(spec) -> str:
    """Content hash of a :class:`SimSpec` (12 hex chars): the arrays and
    static dims that define the compiled workload.  Two runs with equal
    hashes stepped the same machine."""
    h = hashlib.sha1()
    for name, v in sorted(spec._asdict().items()):
        h.update(name.encode())
        if isinstance(v, np.ndarray):
            h.update(v.tobytes())
        else:
            h.update(repr(v).encode())
    return h.hexdigest()[:12]


def collect(*, spec=None, runner=None, stepper: Optional[str] = None,
            sanitize: Optional[bool] = None, telemetry: Optional[dict] = None,
            **extra) -> dict:
    """Build one manifest dict.  ``runner`` (a ``make_runner`` product)
    contributes its stepper/sanitize/trace_count; explicit keywords win;
    ``extra`` keys pass through for harness-specific context."""
    import jax
    import jaxlib

    if runner is not None:
        if stepper is None:
            stepper = getattr(runner, "stepper", None)
        if sanitize is None:
            sanitize = getattr(runner, "sanitize", None)
    man = {
        "git_sha": git_sha(),
        "python": platform.python_version(),
        "jax": jax.__version__,
        "jaxlib": jaxlib.__version__,
        "platform": sys.platform,
    }
    if spec is not None:
        man["spec_hash"] = spec_hash(spec)
    if stepper is not None:
        man["stepper"] = stepper
    if sanitize is not None:
        man["sanitize"] = bool(sanitize)
    if runner is not None and hasattr(runner, "trace_count"):
        man["trace_count"] = runner.trace_count()
    if telemetry is not None:
        man["telemetry"] = telemetry
    man.update(extra)
    return man
