"""Substrate telemetry (DESIGN.md §8) — three observability tiers.

1. **In-step counters** (:mod:`repro.obs.counters`): a jit-pure
   :class:`Telemetry` pytree threaded through the batched step's carry
   behind the static ``make_runner(telemetry=True)`` knob — off
   compiles to nothing, on adds zero traces.
2. **Flight recorder** (:mod:`repro.obs.trace`): host-side
   :class:`TraceSession` emitting Chrome/Perfetto JSON per macro-step,
   plus the serving engine's structured-event converter.
3. **Provenance** (:mod:`repro.obs.manifest`): the ``RunManifest`` dict
   stamped onto benchmark rows so trend diffs are attributable.

``trace`` imports the simulator lazily — importing :mod:`repro.obs`
from inside ``array_sim`` is cycle-free by construction.
"""

from .counters import (  # noqa: F401
    N_BINS,
    Telemetry,
    count,
    hist,
    init_telemetry,
    lane_slice,
    log2_bin,
    summarize,
)
from .manifest import collect as collect_manifest, spec_hash  # noqa: F401

__all__ = [
    "N_BINS", "Telemetry", "count", "hist", "init_telemetry",
    "lane_slice", "log2_bin", "summarize", "collect_manifest",
    "spec_hash",
]
