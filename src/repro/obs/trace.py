"""Flight recorder: host-side macro-step traces in Chrome/Perfetto JSON.

The carried counters (:mod:`repro.obs.counters`) answer *how much*; the
flight recorder answers *when*.  A :class:`TraceSession` drives the SAME
compiled step functions the runner uses (``make_step`` cheap + refresh,
each jitted once), but moves the loop nest to the host so every
macro-step boundary is observable: per step it records the jump reason
(``fine`` / ``jump`` / ``refresh``), the simulated interval, grants,
evictions (residency diff — the ground truth the eviction counter must
agree with), and the pending request-queue depth.  ``to_chrome()``
serialises the records as a Chrome ``traceEvents`` JSON that Perfetto
(https://ui.perfetto.dev) renders directly: one duration track of
macro-steps plus counter tracks for queue depth and pool occupancy.

This is the diagnostic tier — one lane, host-looped, device-synced per
macro-step — NOT the sweep tier.  Results are step-for-step identical
to the jitted runner (same compiled ``core``, same carry threading; the
host merely evaluates the loop conditions the runner's ``while_loop``
evaluates on device), which is what lets the exported trace reconstruct
the event engine's eviction count within the validation bars
(``tests/test_obs.py``).

CLI (the CI artifact generator)::

    python -m repro.obs.trace --scale 0.1 --frac 0.4 --policy pbm \
        --out trace_micro.perfetto.json --manifest run_manifest.json
"""

from __future__ import annotations

import argparse
import json
from typing import List, Optional, Sequence

import jax
import numpy as np

from . import counters, manifest as manifest_mod


class TraceSession:
    """Host-looped recorder over the compiled array-sim step pair."""

    def __init__(self, spec, *, bandwidth_ref: float = 700e6,
                 time_slice: float = 0.1, prefetch_pages: int = 8,
                 policies: Optional[Sequence] = None,
                 step_pages: float = 1.0, stepper: str = "horizon",
                 h_max: float = 8.0, h_io: float = 3.0,
                 max_events: int = 100_000):
        from ..core.array_sim import sim as _sim

        self._sim = _sim
        self.spec = spec
        self.stepper = stepper
        self.pols = _sim.resolve_policies(policies)
        self.dt = (float(step_pages) * float(np.max(spec.page_size))
                   / float(bandwidth_ref))
        self.n_inner = max(1, int(round(time_slice / self.dt)))
        kw = dict(policies=self.pols, stepper=stepper, h_max=h_max,
                  h_io=h_io)
        self._cheap = _sim.make_step(spec, self.dt, time_slice,
                                     prefetch_pages, refresh=False, **kw)
        self._full = _sim.make_step(spec, self.dt, time_slice,
                                    prefetch_pages, refresh=True, **kw)
        self._jit_cheap = jax.jit(self._cheap)
        self._jit_full = jax.jit(self._full)
        self.max_events = max_events
        self.events: List[dict] = []

    # ------------------------------------------------------------- record --
    def _record(self, kind: str, planned_h: int, prev, new) -> None:
        if len(self.events) >= self.max_events:
            return
        prev_res = np.asarray(prev.resident)
        new_res = np.asarray(new.resident)
        pend = int(np.sum(np.asarray(new.req_step) != self._sim._REQ_NONE))
        self.events.append({
            "ts": float(prev.t),
            "dur": float(new.t - prev.t),
            "kind": kind,
            "h": int(planned_h),
            "loads": int(new.loads) - int(prev.loads),
            "evicted": int(np.sum(prev_res & ~new_res)),
            "pending": pend,
            "resident_bytes": float(np.sum(
                np.asarray(self.spec.page_size) * new_res)),
        })

    # ---------------------------------------------------------------- run --
    def run(self, cfg, max_slices: int = 80_000):
        """Drive the workload of ``cfg`` to completion, recording every
        macro-step.  Returns the final :class:`SimState` — identical to
        what the jitted runner produces for the same config."""
        sim = self._sim
        state = sim.init_state(self.spec, self.pols)
        self.events = []

        def running(st) -> bool:
            return (bool(np.any(np.asarray(st.stream_done_t) < 0))
                    and float(st.t) < float(cfg.max_time)
                    and int(st.slices_done) < max_slices)

        if self.stepper == "fixed":
            carry = (state, self._cheap.query_view(state.qidx, state.pos))
            while running(carry[0]):
                for _ in range(self.n_inner - 1):
                    prev = carry[0]
                    carry = self._jit_cheap(carry, cfg)
                    self._record("fine", 1, prev, carry[0])
                prev = carry[0]
                carry = self._jit_full(carry, cfg)
                self._record("refresh", 1, prev, carry[0])
            return carry[0]

        view0 = self._cheap.query_view(state.qidx, state.pos)
        win0 = self._cheap.window(view0)
        carry = (state, view0, win0,
                 self._cheap.adv_limit(win0, state.resident),
                 np.float32(0.0), np.int32(self.n_inner), np.int32(1))
        while running(carry[0]):
            # mirror of the runner's inner while_loop (sim.make_runner):
            # macro-jump while the slice has budget and the planned jump
            # falls short of the boundary, then refresh absorbs the tail
            while int(carry[5]) > 1 and int(carry[6]) < int(carry[5]):
                h = min(int(carry[6]), int(carry[5]) - 1)
                prev = carry[0]
                carry = self._jit_cheap(carry, cfg)
                self._record("jump" if h > 1 else "fine", h,
                             prev, carry[0])
            h = int(carry[5])
            prev = carry[0]
            carry = self._jit_full(carry, cfg)
            self._record("refresh", h, prev, carry[0])
        return carry[0]

    # ------------------------------------------------------------ exports --
    def eviction_total(self) -> int:
        """Evictions reconstructed from the per-step residency diffs —
        the number the event engine's ``total_evictions`` must match."""
        return sum(e["evicted"] for e in self.events)

    def to_chrome(self, pid: int = 0) -> dict:
        """Chrome ``traceEvents`` JSON (Perfetto-loadable): macro-steps
        as duration events (1 sim second = 1 trace ms), queue depth and
        pool occupancy as counter tracks."""
        scale = 1e3  # sim seconds -> trace microseconds / 1000
        evs: List[dict] = [
            {"ph": "M", "pid": pid, "tid": 0, "name": "process_name",
             "args": {"name": f"array_sim [{self.stepper}]"}},
            {"ph": "M", "pid": pid, "tid": 0, "name": "thread_name",
             "args": {"name": "macro-steps"}},
        ]
        for e in self.events:
            ts = e["ts"] * scale
            evs.append({
                "ph": "X", "pid": pid, "tid": 0, "name": e["kind"],
                "ts": ts, "dur": max(e["dur"] * scale, 0.001),
                "args": {"fine_steps": e["h"], "loads": e["loads"],
                         "evicted": e["evicted"],
                         "pending": e["pending"]},
            })
            evs.append({"ph": "C", "pid": pid, "name": "io_queue",
                        "ts": ts, "args": {"pending": e["pending"]}})
            evs.append({"ph": "C", "pid": pid, "name": "pool",
                        "ts": ts,
                        "args": {"resident_mb":
                                 round(e["resident_bytes"] / 1e6, 3)}})
        return {"traceEvents": evs, "displayTimeUnit": "ms"}


def serving_events_to_chrome(events: Sequence[dict],
                             label: str = "serving") -> dict:
    """Chrome trace of ``ServingEngine`` structured events (one instant
    event per admit/preempt/resume/prefetch; 1 engine step = 1 ms)."""
    evs: List[dict] = [
        {"ph": "M", "pid": 1, "tid": 0, "name": "process_name",
         "args": {"name": f"ServingEngine [{label}]"}},
    ]
    for e in events:
        args = {k: v for k, v in e.items() if k not in ("step", "kind")}
        evs.append({
            "ph": "i", "s": "g", "pid": 1, "tid": 0,
            "name": e["kind"], "ts": e["step"] * 1e3, "args": args,
        })
    return {"traceEvents": evs, "displayTimeUnit": "ms"}


# ------------------------------------------------------------------- CLI --

def _build_point(scale: float, n_streams: int, queries: int, seed: int,
                 frac: float):
    from ..core.workload import (
        make_lineitem_db, micro_accessed_bytes, micro_streams,
    )
    from ..core.array_sim import build_spec

    db = make_lineitem_db(scale_tuples=max(1, int(6_001_215 * scale)))
    streams = micro_streams(db, n_streams=n_streams,
                            queries_per_stream=queries, seed=seed)
    spec = build_spec(db, streams)
    cap = max(1 << 22, int(frac * micro_accessed_bytes(db)))
    return spec, cap


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Record one micro-workload lane as a Perfetto trace")
    ap.add_argument("--scale", type=float, default=0.1,
                    help="lineitem scale fraction (default 0.1)")
    ap.add_argument("--frac", type=float, default=0.4,
                    help="buffer fraction of the working set (default 0.4)")
    ap.add_argument("--streams", type=int, default=4)
    ap.add_argument("--queries", type=int, default=4)
    ap.add_argument("--seed", type=int, default=3)
    ap.add_argument("--policy", default="pbm")
    ap.add_argument("--stepper", default="horizon",
                    choices=["fixed", "horizon"])
    ap.add_argument("--out", default="trace_micro.perfetto.json")
    ap.add_argument("--manifest", default=None,
                    help="also write a RunManifest JSON here")
    args = ap.parse_args(argv)

    from ..core.array_sim import make_config

    spec, cap = _build_point(args.scale, args.streams, args.queries,
                             args.seed, args.frac)
    sess = TraceSession(spec, policies=(args.policy,),
                        stepper=args.stepper)
    cfg = make_config(spec, cap, 700e6, args.policy)
    state = sess.run(cfg)
    with open(args.out, "w", encoding="utf-8") as f:
        json.dump(sess.to_chrome(), f)
    print(f"wrote {args.out}: {len(sess.events)} macro-steps, "
          f"{sess.eviction_total()} evictions, "
          f"sim_time={float(state.t):.2f}s")
    if args.manifest:
        man = manifest_mod.collect(
            spec=spec, stepper=args.stepper, sanitize=False,
            policy=args.policy, buffer_frac=args.frac, scale=args.scale,
            macro_steps=len(sess.events),
            evictions=sess.eviction_total(),
        )
        with open(args.manifest, "w", encoding="utf-8") as f:
            json.dump(man, f, indent=2)
        print(f"wrote {args.manifest}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
