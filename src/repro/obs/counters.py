"""Jit-pure in-step telemetry: the carried :class:`Telemetry` pytree.

The batched substrate's step is a pure ``carry -> carry`` function under
``jax.jit``/``jax.vmap`` — the ONLY place a per-step observation can
live without breaking that contract is the carry itself.  Host
callbacks (``jax.debug.print`` and friends) are banned from traced
regions by the analysis lint (rule ``jit-host-callback``, DESIGN.md §8)
precisely because they are the tempting wrong answer: they serialise
the vmapped lanes, defeat donated buffers, and change what XLA may
fuse.  So telemetry is data: fixed-shape integer counters threaded
through the step like any other state leaf, updated with the pure
``jnp`` helpers below, and summarised on the host only after the run.

The knob is **static** (``make_runner(telemetry=True)``): with it off
the step never constructs the pytree and compiles to exactly the
pre-telemetry program (bit-equal results, asserted in
``tests/test_obs.py``); with it on the counters are ordinary carry
leaves, so the one-trace-per-runner contract holds unchanged.

Counter taxonomy (one :class:`Telemetry` per lane; vmap batches them):

===============  ==========================================================
``hits``         plan-trigger crossings of *resident* pages — consumptions
                 served from the pool (cooperative lanes: chunk pages
                 consumed)
``misses``       demand grants — loads that un-blocked a scan frontier
``loads``        every I/O grant (demand + readahead)
``evictions``    pages evicted by the batched eviction kernel
``evict_rank``   log2 histogram of each victim's rank in the policy score
                 order (rank 0 = the policy's top victim; mass in high
                 bins means the kernel digs far past the policy's
                 preference to free bytes — the deep-thrash signature)
``jump_hist``    log2 histogram of macro-step length in fine steps (the
                 horizon stepper's jump sizes; all-ones under ``fixed``)
``ioq_depth_sum``/``ioq_depth_max``  pending request-queue depth,
                 integrated over steps / peak
``chunk_picks``  cooperative chunk selections (the I/O server switching
                 to a new CScan chunk)
``pol_obs``      per compiled policy, the row its ``observe`` hook
                 accumulates (PBM: bucket occupancy histogram; LRU:
                 resident age mass; OPT: referenced/unreferenced split;
                 see ``ArrayPolicy.observe``)
===============  ==========================================================
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

#: log2 histogram bins: bin b counts values in [2**b, 2**(b+1)), the
#: last bin absorbs the tail.  8 bins cover ranks/jumps up to 128+.
N_BINS = 8


class Telemetry(NamedTuple):
    """Per-lane counter pytree carried through the jitted step."""

    hits: jax.Array           # i32 resident plan-trigger crossings
    misses: jax.Array         # i32 demand (frontier-blocking) grants
    loads: jax.Array          # i32 all I/O grants
    evictions: jax.Array      # i32 pages evicted
    evict_rank: jax.Array     # (N_BINS,) i32 victim rank in score order
    jump_hist: jax.Array      # (N_BINS,) i32 macro-step length (fine steps)
    ioq_depth_sum: jax.Array  # i32 pending-request depth, step-integrated
    ioq_depth_max: jax.Array  # i32 pending-request depth, peak
    chunk_picks: jax.Array    # i32 cooperative chunk selections
    pol_obs: Tuple = ()       # per-policy observe rows (f32 vectors)


def count(c: jax.Array, event) -> jax.Array:
    """Accumulate ``event`` (a bool mask, a count, or a scalar flag)
    into counter ``c``.  Pure ``jnp`` — safe in traced regions."""
    return c + jnp.sum(event).astype(c.dtype)


def hist(h: jax.Array, bins, weights) -> jax.Array:
    """Scatter-add ``weights`` into histogram ``h`` at ``bins``."""
    return h.at[bins].add(jnp.asarray(weights).astype(h.dtype))


def log2_bin(x, n_bins: int = N_BINS) -> jax.Array:
    """Map positive values to log2 bins: 1 -> 0, 2-3 -> 1, 4-7 -> 2, ...
    clipped to ``[0, n_bins)`` (zero/negative values land in bin 0)."""
    xf = jnp.maximum(jnp.asarray(x).astype(jnp.float32), 1.0)
    return jnp.clip(jnp.floor(jnp.log2(xf)).astype(jnp.int32), 0, n_bins - 1)


def init_telemetry(policies, spec) -> Telemetry:  # analysis: host
    """Zeroed :class:`Telemetry` for one lane of a compiled policy set.

    Policies opt into a private row via ``observe_init`` (``None`` means
    no row; a zero-length placeholder keeps the pytree structure stable
    across policy sets, and the step skips accumulation on ``size == 0``
    — a static shape check, free under jit)."""
    rows = []
    for p in policies:
        proto = p.observe_init(spec)
        rows.append(jnp.zeros((0,), jnp.float32) if proto is None
                    else jnp.zeros_like(proto))
    z = jnp.int32(0)
    zh = jnp.zeros(N_BINS, jnp.int32)
    return Telemetry(
        hits=z, misses=z, loads=z, evictions=z,
        evict_rank=zh, jump_hist=zh,
        ioq_depth_sum=z, ioq_depth_max=z, chunk_picks=z,
        pol_obs=tuple(rows),
    )


def lane_slice(tele: Telemetry, i: int) -> Telemetry:  # analysis: host
    """Extract lane ``i`` of a vmapped (batched) telemetry pytree."""
    return jax.tree.map(lambda x: x[i], tele)


# analysis: host
def summarize(tele: Telemetry, policies=None, steps=None) -> dict:
    """Host-side digest of one lane's telemetry — the dict stamped into
    ``ArrayResult.extras['telemetry']`` and the RunManifest."""
    hits = int(tele.hits)
    misses = int(tele.misses)
    out = {
        "hits": hits,
        "misses": misses,
        "loads": int(tele.loads),
        "evictions": int(tele.evictions),
        "hit_rate": round(hits / max(1, hits + misses), 4),
        "evict_rank_hist": np.asarray(tele.evict_rank).tolist(),
        "jump_hist": np.asarray(tele.jump_hist).tolist(),
        "ioq_depth_max": int(tele.ioq_depth_max),
        "chunk_picks": int(tele.chunk_picks),
    }
    if steps is not None and int(steps) > 0:
        out["ioq_depth_mean"] = round(
            int(tele.ioq_depth_sum) / int(steps), 2)
    if policies is not None:
        pol = {}
        for p, row in zip(policies, tele.pol_obs):
            name = p if isinstance(p, str) else p.name
            arr = np.asarray(row)
            if arr.size and np.any(arr):   # other lanes' rows stay zero
                pol[name] = [round(float(v), 2) for v in arr.tolist()]
        if pol:
            out["policy_obs"] = pol
    return out
