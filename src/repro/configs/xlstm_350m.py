"""xLSTM-350M [arXiv:2405.04517; unverified]: xLSTM[7:1] — 7 chunked mLSTM
blocks per 1 sequential sLSTM block; d_ff=0 (blocks carry own projections)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm_350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
    xlstm_slstm_every=8, remat="dots",
    note="long_500k RUNS: O(1) recurrent state. Paged-KV integration "
         "inapplicable (no KV cache) — PBM applies via the data pipeline only "
         "(DESIGN.md §5)",
)

SMOKE_CONFIG = ArchConfig(
    name="xlstm_350m_smoke", family="ssm",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=512, xlstm_slstm_every=2,
)
