"""Gemma3-12B [hf:google/gemma-3; unverified]: 5:1 local:global attention,
sliding window 1024, head_dim 256, GeGLU, 262k vocab, 128k context."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3_12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8,
    d_ff=15360, vocab_size=262144, head_dim=256,
    sliding_window=1024, local_global_ratio=5,
    ffn_act="geglu", rope_theta=1e6, remat="dots",
    note="long_500k RUNS: sliding-window dominant (5:1) keeps decode caches "
         "O(window) for 5/6 of layers; global layers page over data axis",
)

SMOKE_CONFIG = ArchConfig(
    name="gemma3_12b_smoke", family="dense",
    n_layers=6, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=512, head_dim=16,
    sliding_window=16, local_global_ratio=5, ffn_act="geglu",
)
