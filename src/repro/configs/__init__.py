from .base import (
    ARCH_IDS, SHAPES, ArchConfig, ShapeConfig, cell_is_skipped, get_config,
    logical_to_spec, mesh_rules,
)

__all__ = [
    "ARCH_IDS", "SHAPES", "ArchConfig", "ShapeConfig", "cell_is_skipped",
    "get_config", "logical_to_spec", "mesh_rules",
]
