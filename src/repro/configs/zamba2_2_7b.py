"""Zamba2-2.7B [arXiv:2411.15242]: Mamba2 backbone + ONE shared attention
block applied every 6 layers over concat([h, emb]) (parameter sharing)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2_2_7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_conv=4, attn_every=6,
    ffn_act="swiglu", remat="dots",
    note="long_500k RUNS: O(1) SSM state; shared-attn KV pages over data axis",
)

SMOKE_CONFIG = ArchConfig(
    name="zamba2_2_7b_smoke", family="hybrid",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=512,
    ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_conv=4, attn_every=2,
)
