"""Llama4-Scout-17B-16E [hf:meta-llama; unverified]: MoE 16 experts top-1,
early fusion (text path modeled; fusion frontend out of assignment scope)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama4_scout_17b_a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab_size=202048,
    moe=True, n_experts=16, top_k=1, capacity_factor=1.25,
    ffn_act="swiglu", rope_theta=5e5, tie_embeddings=False, remat="full",
    note="long_500k SKIPPED: full attention in this implementation",
)

SMOKE_CONFIG = ArchConfig(
    name="llama4_scout_17b_a16e_smoke", family="moe",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
    d_ff=64, vocab_size=512, moe=True, n_experts=4, top_k=1,
    tie_embeddings=False,
)
