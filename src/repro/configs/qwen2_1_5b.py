"""Qwen2-1.5B [arXiv:2407.10671]: GQA kv=2, QKV bias."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2_1_5b", family="dense",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
    d_ff=8960, vocab_size=151936,
    qkv_bias=True, ffn_act="swiglu", rope_theta=1e6,
    note="long_500k SKIPPED: pure full attention",
)

SMOKE_CONFIG = ArchConfig(
    name="qwen2_1_5b_smoke", family="dense",
    n_layers=2, d_model=48, n_heads=6, n_kv_heads=2,
    d_ff=96, vocab_size=512, qkv_bias=True,
)
