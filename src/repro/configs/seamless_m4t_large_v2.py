"""SeamlessM4T-large-v2 [arXiv:2308.11596]: encoder-decoder; speech frontend
is a stub (frame embeddings arrive precomputed). 24L enc + 24L dec."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="seamless_m4t_large_v2", family="audio",
    n_layers=24, encoder_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab_size=256206,
    ffn_act="swiglu", frontend="audio", frontend_tokens=4096,
    remat="dots",
    note="audio frontend is a stub: input_specs provides frame embeddings",
)

SMOKE_CONFIG = ArchConfig(
    name="seamless_m4t_large_v2_smoke", family="audio",
    n_layers=2, encoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=512, frontend="audio", frontend_tokens=16,
)
