"""DeepSeek-67B [arXiv:2401.02954]: llama-arch dense, 95L, GQA kv=8."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek_67b", family="dense",
    n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab_size=102400,
    ffn_act="swiglu", rope_theta=1e4, tie_embeddings=False, remat="dots",
    note="long_500k SKIPPED: pure full attention",
)

SMOKE_CONFIG = ArchConfig(
    name="deepseek_67b_smoke", family="dense",
    n_layers=3, d_model=64, n_heads=8, n_kv_heads=2,
    d_ff=160, vocab_size=512, tie_embeddings=False,
)
