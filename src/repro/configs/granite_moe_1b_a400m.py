"""Granite-3.0-1B-A400M [hf:ibm-granite]: 32 experts, top-8, d_ff=512/expert."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite_moe_1b_a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
    d_ff=512, vocab_size=49155,
    moe=True, n_experts=32, top_k=8, capacity_factor=1.25,
    ffn_act="swiglu", remat="full",
    note="long_500k SKIPPED: pure full attention",
)

SMOKE_CONFIG = ArchConfig(
    name="granite_moe_1b_a400m_smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=32, vocab_size=512, moe=True, n_experts=4, top_k=2,
)
